//! Serving-coordinator benchmark: batched vs unbatched latency and
//! throughput on the native engine (and the online-Hadamard overhead the
//! paper's §5.3 discusses for unfused rotations).

use std::sync::Arc;
use std::time::Duration;

use llvq::coordinator::{BatchForward, BatcherConfig, Coordinator, NativeEngine};
use llvq::math::hadamard::RandomizedHadamard;
use llvq::model::config::config_by_name;
use llvq::model::corpus::Corpus;
use llvq::model::transformer::Weights;
use llvq::util::bench::{black_box, Bench};

fn main() {
    let b = Bench {
        warmup: Duration::from_millis(200),
        min_batch_time: Duration::from_millis(200),
        num_samples: 6,
    };
    let cfg = config_by_name("llama2-tiny").unwrap();
    let weights = Weights::random(&cfg, 1);
    let engine = Arc::new(NativeEngine { weights });

    let mut corpus = Corpus::new(17);
    let seqs: Vec<Vec<u8>> = (0..64).map(|_| corpus.generate(32).0).collect();

    println!("== engine forward (no coordinator) ==");
    let mut i = 0;
    b.run_throughput("forward batch=1 (seq/s)", 1.0, || {
        black_box(engine.forward_batch(std::slice::from_ref(&seqs[i % seqs.len()])));
        i += 1;
    });
    let batch8: Vec<Vec<u8>> = seqs[..8].to_vec();
    b.run_throughput("forward batch=8 (seq/s)", 8.0, || {
        black_box(engine.forward_batch(&batch8));
    });

    println!("\n== coordinator under concurrency ==");
    for &(max_batch, clients) in &[(1usize, 8usize), (8, 8), (8, 32)] {
        let coord = Coordinator::start(
            engine.clone(),
            BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(2),
            },
        );
        let t0 = std::time::Instant::now();
        let per = 24;
        std::thread::scope(|s| {
            for c in 0..clients {
                let coord = coord.clone();
                let seqs = &seqs;
                s.spawn(move || {
                    for r in 0..per {
                        let _ = coord.submit(seqs[(c + r) % seqs.len()].clone());
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "max_batch={max_batch:<2} clients={clients:<3} → {:>7.1} req/s  \
             mean batch {:.2}  mean latency {:.2} ms",
            (clients * per) as f64 / wall,
            coord.metrics.mean_batch(),
            coord.metrics.mean_latency_ms()
        );
        coord.stop();
    }

    println!("\n== online Hadamard overhead (unfused rotations, §5.3) ==");
    let h = RandomizedHadamard::new(cfg.d_model, 9);
    let mut x: Vec<f64> = (0..cfg.d_model).map(|k| (k as f64).sin()).collect();
    b.run_throughput("R_in · x (144-dim, ops/s)", 1.0, || {
        h.forward(black_box(&mut x));
    });
}
