//! Serving benchmarks: dense vs packed-cached vs packed-fused execution
//! backends (load time, first-token latency, steady-state throughput,
//! resident weight bytes), the generation path (KV-cached decode steps vs
//! full-prefix resubmission, single lane and slate), plus the
//! coordinator's batched-vs-unbatched latency and the online-Hadamard
//! overhead of §5.3.
//!
//! Besides the human-readable report, every backend measurement lands as a
//! JSON row in `BENCH_serving.json`, every generation measurement in
//! `BENCH_generation.json`, and the kernel thread-scaling sweep (fused and
//! cached × 1/2/4/8 pool threads × single-lane and 8-lane slate) in
//! `BENCH_kernel.json` (override with `LLVQ_BENCH_OUT` /
//! `LLVQ_BENCH_GEN_OUT` / `LLVQ_BENCH_KERNEL_OUT`; all files are rewritten
//! each run), in the flat row shape the `BENCH_*.json` trajectories use.

use std::sync::Arc;
use std::time::Duration;

use llvq::coordinator::{BackendEngine, BatchForward, BatcherConfig, Coordinator};
use llvq::math::hadamard::RandomizedHadamard;
use llvq::model::backend::{BackendKind, ExecutionBackend};
use llvq::model::config::config_by_name;
use llvq::model::corpus::Corpus;
use llvq::model::packed::{PackedFile, PackedModel};
use llvq::model::sample::argmax;
use llvq::model::transformer::{
    forward, forward_step, forward_step_batch, prefill, ActivationCapture, KvCache, StepLane,
    Weights,
};
use llvq::pipeline::driver::{quantize_model_packed, PtqOptions};
use llvq::pipeline::rotation::RotationMode;
use llvq::quant::llvq::LlvqShapeGain;
use llvq::util::bench::{black_box, Bench, BenchResult};
use llvq::util::json::Json;

fn suite_row(suite: &str, name: &str, r: &BenchResult, extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("suite", Json::Str(suite.into())),
        ("name", Json::Str(name.into())),
        ("mean_s", Json::Num(r.mean)),
        ("median_s", Json::Num(r.median)),
        ("p10_s", Json::Num(r.p10)),
        ("p90_s", Json::Num(r.p90)),
    ];
    pairs.extend(extra);
    Json::obj(pairs)
}

fn row(name: &str, r: &BenchResult, extra: Vec<(&str, Json)>) -> Json {
    suite_row("serving", name, r, extra)
}

fn build_backend(path: &std::path::Path, kind: BackendKind, threads: usize) -> ExecutionBackend {
    match kind {
        BackendKind::Dense => ExecutionBackend::dense(
            PackedModel::load(path).unwrap().unpack(threads).unwrap(),
        ),
        BackendKind::Cached => {
            ExecutionBackend::packed_cached(PackedFile::open(path).unwrap(), threads).unwrap()
        }
        BackendKind::Fused => {
            ExecutionBackend::packed_fused(PackedFile::open(path).unwrap(), threads).unwrap()
        }
    }
}

/// One greedy KV-cached generation pass: prefill + `gen_n - 1` decode
/// steps (the first logits come from prefill, the last token is terminal).
fn gen_kv(backend: &ExecutionBackend, prompt: &[u8], gen_n: usize) {
    let mut cache = KvCache::new(backend.cfg());
    let mut logits = prefill(backend, &mut cache, prompt);
    for _ in 0..gen_n - 1 {
        let t = argmax(&logits) as u8;
        logits = forward_step(backend, &mut cache, t);
    }
    black_box(argmax(&logits));
}

/// One greedy slate generation pass over `lanes_n` parallel sessions.
fn gen_slate(backend: &ExecutionBackend, prompt: &[u8], gen_n: usize, lanes_n: usize) {
    let mut caches: Vec<KvCache> =
        (0..lanes_n).map(|_| KvCache::new(backend.cfg())).collect();
    let mut logits: Vec<Vec<f32>> = caches
        .iter_mut()
        .map(|c| prefill(backend, c, prompt))
        .collect();
    let v = backend.cfg().vocab;
    for _ in 0..gen_n - 1 {
        let toks: Vec<u8> = logits.iter().map(|l| argmax(l) as u8).collect();
        let mut lanes: Vec<StepLane<'_>> = caches
            .iter_mut()
            .zip(&toks)
            .map(|(cache, &token)| StepLane { cache, token })
            .collect();
        let flat = forward_step_batch(backend, &mut lanes);
        logits = flat.chunks_exact(v).map(|c| c.to_vec()).collect();
    }
    black_box(&logits);
}

fn main() {
    let b = Bench {
        warmup: Duration::from_millis(200),
        min_batch_time: Duration::from_millis(200),
        num_samples: 6,
    };
    let mut rows: Vec<Json> = Vec::new();
    let cfg = config_by_name("llama2-tiny").unwrap();
    let weights = Weights::random(&cfg, 1);

    let mut corpus = Corpus::new(17);
    let seqs: Vec<Vec<u8>> = (0..64).map(|_| corpus.generate(32).0).collect();

    // ---- one-time PTQ: the paper's 2 bpw shape–gain configuration ----
    println!("== one-time PTQ (llama2-tiny, 2 bpw shape-gain) ==");
    let q = LlvqShapeGain::new(Arc::new(llvq::leech::index::LeechIndexer::new(12)), 1);
    let opts = PtqOptions {
        rotation: RotationMode::Input,
        calib_seqs: 4,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let art = quantize_model_packed(&weights, &q, &opts);
    println!(
        "(PTQ: {:.1}s, {:.4} code bpw)",
        t0.elapsed().as_secs_f64(),
        art.report.bits_per_weight()
    );
    let path = std::env::temp_dir().join(format!(
        "llvq-bench-serving-{}.llvqm",
        std::process::id()
    ));
    art.packed.save(&path).unwrap();
    let file_bytes = std::fs::metadata(&path).unwrap().len() as usize;
    let code_bytes = art.packed.code_bytes();
    let threads = llvq::util::threadpool::default_threads();

    // ---- backend comparison: load / first token / steady state ----
    let bq = Bench {
        warmup: Duration::from_millis(100),
        min_batch_time: Duration::from_millis(100),
        num_samples: 5,
    };
    let short: Vec<Vec<u8>> = (0..4).map(|i| seqs[i][..16].to_vec()).collect();
    for kind in [BackendKind::Dense, BackendKind::Cached, BackendKind::Fused] {
        let label = kind.label();
        println!("\n== backend: {label} ==");
        // load: open the artifact and build the backend (dense pays the
        // full parse+unpack; cached reads header+dense tail; fused reads
        // header+codes)
        let r = bq.run(&format!("{label}: load"), || {
            black_box(build_backend(&path, kind, threads));
        });
        rows.push(row(
            &format!("load_{label}"),
            &r,
            vec![("file_bytes", Json::Int(file_bytes as i64))],
        ));
        // first token: cold backend through one request (for cached this
        // includes the lazy decode of every touched layer)
        let r = bq.run(&format!("{label}: first token (cold)"), || {
            let be = build_backend(&path, kind, threads);
            let engine = BackendEngine { backend: be };
            black_box(engine.forward_batch(std::slice::from_ref(&short[0])));
        });
        rows.push(row(&format!("first_token_{label}"), &r, vec![]));
        // steady state: warm backend, batched forward throughput
        let engine = BackendEngine {
            backend: build_backend(&path, kind, threads),
        };
        engine.forward_batch(&short); // warm every layer
        let r = bq.run_throughput(
            &format!("{label}: steady batch=4 (seq/s)"),
            4.0,
            || {
                black_box(engine.forward_batch(&short));
            },
        );
        let resident = engine.resident_weight_bytes();
        println!("{label}: resident weight bytes = {resident} (codes on disk {code_bytes})");
        rows.push(row(
            &format!("steady_{label}"),
            &r,
            vec![
                ("seq_per_s", Json::Num(4.0 / r.mean)),
                ("resident_bytes", Json::Int(resident as i64)),
                ("code_bytes", Json::Int(code_bytes as i64)),
            ],
        ));
    }

    // ---- generation: KV-cached decode vs full-prefix resubmission ----
    // the tokens/s acceptance numbers for the session API: a KV-cached
    // GEN re-uses every prior position's K/V, while the pre-session
    // protocol re-ran the whole growing prefix per token
    let mut gen_rows: Vec<Json> = Vec::new();
    let prompt: Vec<u8> = seqs[0][..16].to_vec();
    let gen_n = 32usize;
    for kind in [BackendKind::Dense, BackendKind::Cached, BackendKind::Fused] {
        let label = kind.label();
        println!("\n== generation: {label} ==");
        let backend = build_backend(&path, kind, threads);
        {
            // warm every layer (cached decodes on first touch)
            let mut cache = KvCache::new(backend.cfg());
            black_box(prefill(&backend, &mut cache, &prompt));
        }
        let r = bq.run(&format!("{label}: kv-cached gen ({gen_n} tok)"), || {
            gen_kv(&backend, &prompt, gen_n);
        });
        println!("{label}: kv-cached {:.1} tok/s", gen_n as f64 / r.mean);
        gen_rows.push(suite_row(
            "generation",
            &format!("gen_kv_{label}"),
            &r,
            vec![
                ("tok_per_s", Json::Num(gen_n as f64 / r.mean)),
                ("ms_per_tok", Json::Num(r.mean * 1e3 / gen_n as f64)),
                ("gen_tokens", Json::Int(gen_n as i64)),
            ],
        ));
        let r = bq.run(&format!("{label}: full-prefix gen ({gen_n} tok)"), || {
            let mut toks = prompt.clone();
            let mut cap = ActivationCapture::default();
            let v = backend.cfg().vocab;
            for _ in 0..gen_n {
                let logits = forward(&backend, &toks, &mut cap);
                let last = &logits[(toks.len() - 1) * v..toks.len() * v];
                toks.push(argmax(last) as u8);
            }
            black_box(&toks);
        });
        println!("{label}: full-prefix {:.1} tok/s", gen_n as f64 / r.mean);
        gen_rows.push(suite_row(
            "generation",
            &format!("gen_prefix_{label}"),
            &r,
            vec![
                ("tok_per_s", Json::Num(gen_n as f64 / r.mean)),
                ("ms_per_tok", Json::Num(r.mean * 1e3 / gen_n as f64)),
                ("gen_tokens", Json::Int(gen_n as i64)),
            ],
        ));
    }
    // slate amortization: the fused backend decodes each weight row once
    // per decode step for all lanes — aggregate tok/s should beat 8 ×
    // single-lane stepping
    {
        println!("\n== generation: fused 8-lane slate ==");
        let backend = build_backend(&path, BackendKind::Fused, threads);
        let lanes_n = 8usize;
        let r = bq.run("fused: kv-cached gen, 8-lane slate", || {
            gen_slate(&backend, &prompt, gen_n, lanes_n);
        });
        let total = (gen_n * lanes_n) as f64;
        println!("fused slate-8: {:.1} tok/s aggregate", total / r.mean);
        gen_rows.push(suite_row(
            "generation",
            "gen_kv_fused_slate8",
            &r,
            vec![
                ("tok_per_s", Json::Num(total / r.mean)),
                ("ms_per_tok", Json::Num(r.mean * 1e3 / total)),
                ("gen_tokens", Json::Int((gen_n * lanes_n) as i64)),
                ("lanes", Json::Int(lanes_n as i64)),
            ],
        ));
    }
    let gen_out = std::env::var("LLVQ_BENCH_GEN_OUT")
        .unwrap_or_else(|_| "BENCH_generation.json".into());
    match std::fs::write(&gen_out, Json::Arr(gen_rows).to_string_pretty()) {
        Ok(()) => println!("\nwrote {gen_out}"),
        Err(e) => eprintln!("\n[warn] could not write {gen_out}: {e}"),
    }

    // ---- kernel scaling: threads × backend × slate → BENCH_kernel.json ----
    // the tentpole acceptance numbers at 1/2/4/8 pool threads, single lane
    // and 8-lane slate. The pool-parallel phase differs per backend, so
    // each is timed where its kernel actually runs:
    //   * fused — warm steady-state generation (the row-sharded
    //     dequant-matmul runs on every decode step; tok/s should improve
    //     monotonically 1 → 4 threads on this config, bit-identically);
    //   * cached — COLD start (build + generate, so the timed region
    //     contains the row-sharded first-touch decode of every layer —
    //     warm cached generation is plain dense matvecs and never touches
    //     the pool).
    let mut kernel_rows: Vec<Json> = Vec::new();
    let lanes_n = 8usize;
    println!("\n== kernel scaling: fused (warm steady-state) ==");
    for &t in &[1usize, 2, 4, 8] {
        let backend = build_backend(&path, BackendKind::Fused, t);
        {
            // warm the pool workers and scratch slots
            let mut cache = KvCache::new(backend.cfg());
            black_box(prefill(&backend, &mut cache, &prompt));
        }
        let r = bq.run(&format!("fused t={t}: kv gen ({gen_n} tok, 1 lane)"), || {
            gen_kv(&backend, &prompt, gen_n);
        });
        println!("fused t={t}: single-lane {:.1} tok/s", gen_n as f64 / r.mean);
        kernel_rows.push(suite_row(
            "kernel",
            &format!("fused_t{t}_lane1"),
            &r,
            vec![
                ("threads", Json::Int(t as i64)),
                ("lanes", Json::Int(1)),
                ("cold", Json::Bool(false)),
                ("tok_per_s", Json::Num(gen_n as f64 / r.mean)),
                ("ms_per_tok", Json::Num(r.mean * 1e3 / gen_n as f64)),
            ],
        ));
        let r = bq.run(
            &format!("fused t={t}: kv gen ({gen_n} tok, {lanes_n}-lane slate)"),
            || {
                gen_slate(&backend, &prompt, gen_n, lanes_n);
            },
        );
        let total = (gen_n * lanes_n) as f64;
        println!(
            "fused t={t}: slate-{lanes_n} {:.1} tok/s aggregate",
            total / r.mean
        );
        kernel_rows.push(suite_row(
            "kernel",
            &format!("fused_t{t}_slate{lanes_n}"),
            &r,
            vec![
                ("threads", Json::Int(t as i64)),
                ("lanes", Json::Int(lanes_n as i64)),
                ("cold", Json::Bool(false)),
                ("tok_per_s", Json::Num(total / r.mean)),
                ("ms_per_tok", Json::Num(r.mean * 1e3 / total)),
            ],
        ));
    }
    println!("\n== kernel scaling: cached (cold incl. first-touch decode) ==");
    for &t in &[1usize, 2, 4, 8] {
        let r = bq.run(
            &format!("cached t={t}: cold build + kv gen ({gen_n} tok, 1 lane)"),
            || {
                let backend = build_backend(&path, BackendKind::Cached, t);
                gen_kv(&backend, &prompt, gen_n);
            },
        );
        println!(
            "cached t={t}: cold single-lane {:.1} tok/s",
            gen_n as f64 / r.mean
        );
        kernel_rows.push(suite_row(
            "kernel",
            &format!("cached_t{t}_lane1_cold"),
            &r,
            vec![
                ("threads", Json::Int(t as i64)),
                ("lanes", Json::Int(1)),
                ("cold", Json::Bool(true)),
                ("tok_per_s", Json::Num(gen_n as f64 / r.mean)),
                ("ms_per_tok", Json::Num(r.mean * 1e3 / gen_n as f64)),
            ],
        ));
        let r = bq.run(
            &format!("cached t={t}: cold build + kv gen ({gen_n} tok, {lanes_n}-lane slate)"),
            || {
                let backend = build_backend(&path, BackendKind::Cached, t);
                gen_slate(&backend, &prompt, gen_n, lanes_n);
            },
        );
        let total = (gen_n * lanes_n) as f64;
        println!(
            "cached t={t}: cold slate-{lanes_n} {:.1} tok/s aggregate",
            total / r.mean
        );
        kernel_rows.push(suite_row(
            "kernel",
            &format!("cached_t{t}_slate{lanes_n}_cold"),
            &r,
            vec![
                ("threads", Json::Int(t as i64)),
                ("lanes", Json::Int(lanes_n as i64)),
                ("cold", Json::Bool(true)),
                ("tok_per_s", Json::Num(total / r.mean)),
                ("ms_per_tok", Json::Num(r.mean * 1e3 / total)),
            ],
        ));
    }
    let kernel_out = std::env::var("LLVQ_BENCH_KERNEL_OUT")
        .unwrap_or_else(|_| "BENCH_kernel.json".into());
    match std::fs::write(&kernel_out, Json::Arr(kernel_rows).to_string_pretty()) {
        Ok(()) => println!("\nwrote {kernel_out}"),
        Err(e) => eprintln!("\n[warn] could not write {kernel_out}: {e}"),
    }

    // ---- dense engine + coordinator (the historical serving numbers) ----
    let engine = Arc::new(BackendEngine::dense(weights));
    println!("\n== engine forward (no coordinator) ==");
    let mut i = 0;
    b.run_throughput("forward batch=1 (seq/s)", 1.0, || {
        black_box(engine.forward_batch(std::slice::from_ref(&seqs[i % seqs.len()])));
        i += 1;
    });
    let batch8: Vec<Vec<u8>> = seqs[..8].to_vec();
    b.run_throughput("forward batch=8 (seq/s)", 8.0, || {
        black_box(engine.forward_batch(&batch8));
    });

    println!("\n== coordinator under concurrency ==");
    for &(max_batch, clients) in &[(1usize, 8usize), (8, 8), (8, 32)] {
        let coord = Coordinator::start(
            engine.clone(),
            BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(2),
                ..Default::default()
            },
        );
        let t0 = std::time::Instant::now();
        let per = 24;
        std::thread::scope(|s| {
            for c in 0..clients {
                let coord = coord.clone();
                let seqs = &seqs;
                s.spawn(move || {
                    for r in 0..per {
                        let _ = coord.submit(seqs[(c + r) % seqs.len()].clone());
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "max_batch={max_batch:<2} clients={clients:<3} → {:>7.1} req/s  \
             mean batch {:.2}  mean latency {:.2} ms",
            (clients * per) as f64 / wall,
            coord.metrics.mean_batch(),
            coord.metrics.mean_latency_ms()
        );
        coord.stop();
    }

    println!("\n== online Hadamard overhead (unfused rotations, §5.3) ==");
    let h = RandomizedHadamard::new(cfg.d_model, 9);
    let mut x: Vec<f64> = (0..cfg.d_model).map(|k| (k as f64).sin()).collect();
    b.run_throughput("R_in · x (144-dim, ops/s)", 1.0, || {
        h.forward(black_box(&mut x));
    });

    std::fs::remove_file(&path).ok();
    let out_path =
        std::env::var("LLVQ_BENCH_OUT").unwrap_or_else(|_| "BENCH_serving.json".into());
    let doc = Json::Arr(rows).to_string_pretty();
    match std::fs::write(&out_path, &doc) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\n[warn] could not write {out_path}: {e}"),
    }
}
