//! Serving benchmarks: dense vs packed-cached vs packed-fused execution
//! backends (load time, first-token latency, steady-state throughput,
//! resident weight bytes), the generation path (KV-cached decode steps vs
//! full-prefix resubmission, single lane and slate), plus the
//! coordinator's batched-vs-unbatched latency and the online-Hadamard
//! overhead of §5.3.
//!
//! Besides the human-readable report, every backend measurement lands as a
//! JSON row in `BENCH_serving.json` (which also carries a `"sim"` suite:
//! one row per scheduler-simulator scenario with its wall time, virtual
//! ticks, counters, invariant verdict, and determinism fingerprint),
//! every generation measurement in `BENCH_generation.json`, the kernel thread-scaling sweep (fused and
//! cached × 1/2/4/8 pool threads × single-lane and 8-lane slate, every
//! row tagged with the `simd` kernel it dispatched) plus the
//! forced-scalar-vs-auto-detected SIMD comparison in `BENCH_kernel.json`,
//! the pipelined-prefill scheduler comparison
//! (time-to-first-token + active-lane throughput while a long prompt
//! prefills, chunked vs monolithic) in `BENCH_prefill.json`, and the
//! paged-KV comparison (sessions-per-GB for dense slabs vs f32 pages vs
//! llvq cold pages, plus decode tok/s dense vs paged vs paged+quantized)
//! in `BENCH_kv.json` (override with
//! `LLVQ_BENCH_OUT` / `LLVQ_BENCH_GEN_OUT` / `LLVQ_BENCH_KERNEL_OUT` /
//! `LLVQ_BENCH_PREFILL_OUT` / `LLVQ_BENCH_KV_OUT`; all files are
//! rewritten each run), in the
//! flat row shape the `BENCH_*.json` trajectories use. `LLVQ_BENCH_SMOKE=1`
//! shrinks iteration counts and codebook dims so CI produces every file in
//! seconds (rows then carry `"smoke": true`).

use std::sync::Arc;
use std::time::Duration;

use llvq::coordinator::{BackendEngine, BatchForward, BatcherConfig, Coordinator, GenEvent};
use llvq::math::hadamard::RandomizedHadamard;
use llvq::model::backend::{BackendKind, ExecutionBackend};
use llvq::model::config::config_by_name;
use llvq::model::corpus::Corpus;
use llvq::model::kvpage::{KvCodec, KvQuantKind, PageArena, PagedKvCache};
use llvq::model::packed::{PackedFile, PackedModel};
use llvq::model::sample::{argmax, SampleParams};
use llvq::model::transformer::{
    forward, forward_step, forward_step_batch, prefill, ActivationCapture, KvCache, StepLane,
    Weights,
};
use llvq::pipeline::driver::{quantize_model_packed, PtqOptions};
use llvq::pipeline::rotation::RotationMode;
use llvq::quant::kernel::Kernel;
use llvq::quant::llvq::LlvqShapeGain;
use llvq::sim::harness::Simulator;
use llvq::sim::scenario::Scenario;
use llvq::util::bench::{black_box, Bench, BenchResult};
use llvq::util::json::Json;

fn suite_row(suite: &str, name: &str, r: &BenchResult, extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("suite", Json::Str(suite.into())),
        ("name", Json::Str(name.into())),
        ("mean_s", Json::Num(r.mean)),
        ("median_s", Json::Num(r.median)),
        ("p10_s", Json::Num(r.p10)),
        ("p90_s", Json::Num(r.p90)),
    ];
    if llvq::util::bench::smoke() {
        pairs.push(("smoke", Json::Bool(true)));
    }
    pairs.extend(extra);
    Json::obj(pairs)
}

fn row(name: &str, r: &BenchResult, extra: Vec<(&str, Json)>) -> Json {
    suite_row("serving", name, r, extra)
}

fn build_backend(path: &std::path::Path, kind: BackendKind, threads: usize) -> ExecutionBackend {
    match kind {
        BackendKind::Dense => ExecutionBackend::dense(
            PackedModel::load(path).unwrap().unpack(threads).unwrap(),
        ),
        BackendKind::Cached => {
            ExecutionBackend::packed_cached(PackedFile::open(path).unwrap(), threads).unwrap()
        }
        BackendKind::Fused => {
            ExecutionBackend::packed_fused(PackedFile::open(path).unwrap(), threads).unwrap()
        }
    }
}

/// One greedy KV-cached generation pass: prefill + `gen_n - 1` decode
/// steps (the first logits come from prefill, the last token is terminal).
fn gen_kv(backend: &ExecutionBackend, prompt: &[u8], gen_n: usize) {
    let mut cache = KvCache::new(backend.cfg());
    let mut logits = prefill(backend, &mut cache, prompt);
    for _ in 0..gen_n - 1 {
        let t = argmax(&logits) as u8;
        logits = forward_step(backend, &mut cache, t);
    }
    black_box(argmax(&logits));
}

/// One greedy slate generation pass over `lanes_n` parallel sessions.
fn gen_slate(backend: &ExecutionBackend, prompt: &[u8], gen_n: usize, lanes_n: usize) {
    let mut caches: Vec<KvCache> =
        (0..lanes_n).map(|_| KvCache::new(backend.cfg())).collect();
    let mut logits: Vec<Vec<f32>> = caches
        .iter_mut()
        .map(|c| prefill(backend, c, prompt))
        .collect();
    let v = backend.cfg().vocab;
    for _ in 0..gen_n - 1 {
        let toks: Vec<u8> = logits.iter().map(|l| argmax(l) as u8).collect();
        let mut lanes: Vec<StepLane<'_>> = caches
            .iter_mut()
            .zip(&toks)
            .map(|(cache, &token)| StepLane { cache, token })
            .collect();
        let flat = forward_step_batch(backend, &mut lanes);
        logits = flat.chunks_exact(v).map(|c| c.to_vec()).collect();
    }
    black_box(&logits);
}

fn median(v: &mut [f64]) -> f64 {
    v.sort_by(f64::total_cmp);
    v[v.len() / 2]
}

/// One chunked-vs-monolithic scheduler measurement (see the prefill
/// section in `main`).
struct PrefillRun {
    /// FEED of the long prompt → its GEN's first token.
    ttft_s: f64,
    /// Active-lane tokens streamed during that window, per second.
    active_tok_per_s: f64,
    /// Worst inter-token gap seen on the active lane over its whole run.
    max_gap_s: f64,
}

/// Start a coordinator over `backend`, put one generation lane on the
/// slate, then FEED a long prompt on a second session and GEN one token:
/// returns the long prompt's time-to-first-token and how the active lane
/// fared while the prefill drained.
fn prefill_pipeline_run(
    backend: ExecutionBackend,
    prefill_chunk: usize,
    long_prompt: &[u8],
) -> PrefillRun {
    let coord = Coordinator::start(
        Arc::new(BackendEngine::new(backend)),
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            max_sessions: 8,
            prefill_chunk,
        },
    );
    let active_n = 48usize; // 4 prompt + 48 generated ≤ max_seq 64
    let sid = coord.open_session().unwrap();
    coord.feed(sid, vec![1, 2, 3, 4]).unwrap();
    let events = coord
        .generate(
            sid,
            active_n,
            SampleParams {
                temperature: 0.8,
                top_k: 8,
                seed: 11,
            },
        )
        .unwrap();
    let collector = std::thread::spawn(move || {
        let mut arrivals = Vec::with_capacity(active_n);
        loop {
            match events.recv().expect("active lane stream") {
                Ok(GenEvent::Token(_)) => arrivals.push(std::time::Instant::now()),
                Ok(GenEvent::Done { .. }) => return arrivals,
                Err(e) => panic!("active lane failed: {e}"),
            }
        }
    });
    // let the decode lane roll before the long FEED lands
    while coord
        .metrics
        .gen_tokens
        .load(std::sync::atomic::Ordering::Relaxed)
        < 4
    {
        std::thread::yield_now();
    }
    let bsid = coord.open_session().unwrap();
    let t_feed = std::time::Instant::now();
    coord.feed(bsid, long_prompt.to_vec()).unwrap();
    let ev = coord.generate(bsid, 1, SampleParams::default()).unwrap();
    let ttft = match ev.recv().expect("long-prompt stream") {
        Ok(GenEvent::Token(_)) => t_feed.elapsed(),
        Ok(GenEvent::Done { .. }) => t_feed.elapsed(),
        Err(e) => panic!("long-prompt generation failed: {e}"),
    };
    for _ in ev.iter() {} // drain the Done event
    let arrivals = collector.join().unwrap();
    coord.close_session(bsid).unwrap();
    coord.close_session(sid).unwrap();
    coord.stop();
    let window = t_feed..=t_feed + ttft;
    let in_window = arrivals.iter().filter(|&t| window.contains(t)).count();
    let max_gap_s = arrivals
        .windows(2)
        .map(|w| (w[1] - w[0]).as_secs_f64())
        .fold(0f64, f64::max);
    PrefillRun {
        ttft_s: ttft.as_secs_f64(),
        active_tok_per_s: in_window as f64 / ttft.as_secs_f64().max(1e-9),
        max_gap_s,
    }
}

fn main() {
    let smoke = llvq::util::bench::smoke();
    let b = if smoke {
        Bench::default() // smoke-sized by the harness
    } else {
        Bench {
            warmup: Duration::from_millis(200),
            min_batch_time: Duration::from_millis(200),
            num_samples: 6,
        }
    };
    let mut rows: Vec<Json> = Vec::new();
    let cfg = config_by_name("llama2-tiny").unwrap();
    let weights = Weights::random(&cfg, 1);

    let mut corpus = Corpus::new(17);
    let seqs: Vec<Vec<u8>> = (0..64).map(|_| corpus.generate(32).0).collect();

    // ---- one-time PTQ: the paper's 2 bpw shape–gain configuration ----
    // (smoke mode shrinks the Leech ball cut: same codec surface, much
    // cheaper indexer/PTQ, numbers flagged "smoke" in the rows)
    println!("== one-time PTQ (llama2-tiny, 2 bpw shape-gain) ==");
    let max_m = if smoke { 6 } else { 12 };
    let q = LlvqShapeGain::new(Arc::new(llvq::leech::index::LeechIndexer::new(max_m)), 1);
    let opts = PtqOptions {
        rotation: RotationMode::Input,
        calib_seqs: 4,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let art = quantize_model_packed(&weights, &q, &opts);
    println!(
        "(PTQ: {:.1}s, {:.4} code bpw)",
        t0.elapsed().as_secs_f64(),
        art.report.bits_per_weight()
    );
    let path = std::env::temp_dir().join(format!(
        "llvq-bench-serving-{}.llvqm",
        std::process::id()
    ));
    art.packed.save(&path).unwrap();
    let file_bytes = std::fs::metadata(&path).unwrap().len() as usize;
    let code_bytes = art.packed.code_bytes();
    let threads = llvq::util::threadpool::default_threads();

    // ---- backend comparison: load / first token / steady state ----
    let bq = if smoke {
        Bench::default()
    } else {
        Bench {
            warmup: Duration::from_millis(100),
            min_batch_time: Duration::from_millis(100),
            num_samples: 5,
        }
    };
    let short: Vec<Vec<u8>> = (0..4).map(|i| seqs[i][..16].to_vec()).collect();
    for kind in [BackendKind::Dense, BackendKind::Cached, BackendKind::Fused] {
        let label = kind.label();
        println!("\n== backend: {label} ==");
        // load: open the artifact and build the backend (dense pays the
        // full parse+unpack; cached reads header+dense tail; fused reads
        // header+codes)
        let r = bq.run(&format!("{label}: load"), || {
            black_box(build_backend(&path, kind, threads));
        });
        rows.push(row(
            &format!("load_{label}"),
            &r,
            vec![("file_bytes", Json::Int(file_bytes as i64))],
        ));
        // first token: cold backend through one request (for cached this
        // includes the lazy decode of every touched layer)
        let r = bq.run(&format!("{label}: first token (cold)"), || {
            let be = build_backend(&path, kind, threads);
            let engine = BackendEngine::new(be);
            black_box(engine.forward_batch(std::slice::from_ref(&short[0])));
        });
        rows.push(row(&format!("first_token_{label}"), &r, vec![]));
        // steady state: warm backend, batched forward throughput
        let engine = BackendEngine::new(build_backend(&path, kind, threads));
        engine.forward_batch(&short); // warm every layer
        let r = bq.run_throughput(
            &format!("{label}: steady batch=4 (seq/s)"),
            4.0,
            || {
                black_box(engine.forward_batch(&short));
            },
        );
        let resident = engine.resident_weight_bytes();
        println!("{label}: resident weight bytes = {resident} (codes on disk {code_bytes})");
        rows.push(row(
            &format!("steady_{label}"),
            &r,
            vec![
                ("seq_per_s", Json::Num(4.0 / r.mean)),
                ("resident_bytes", Json::Int(resident as i64)),
                ("code_bytes", Json::Int(code_bytes as i64)),
            ],
        ));
    }

    // ---- generation: KV-cached decode vs full-prefix resubmission ----
    // the tokens/s acceptance numbers for the session API: a KV-cached
    // GEN re-uses every prior position's K/V, while the pre-session
    // protocol re-ran the whole growing prefix per token
    let mut gen_rows: Vec<Json> = Vec::new();
    let prompt: Vec<u8> = seqs[0][..16].to_vec();
    let gen_n = if smoke { 8 } else { 32 };
    for kind in [BackendKind::Dense, BackendKind::Cached, BackendKind::Fused] {
        let label = kind.label();
        println!("\n== generation: {label} ==");
        let backend = build_backend(&path, kind, threads);
        {
            // warm every layer (cached decodes on first touch)
            let mut cache = KvCache::new(backend.cfg());
            black_box(prefill(&backend, &mut cache, &prompt));
        }
        let r = bq.run(&format!("{label}: kv-cached gen ({gen_n} tok)"), || {
            gen_kv(&backend, &prompt, gen_n);
        });
        println!("{label}: kv-cached {:.1} tok/s", gen_n as f64 / r.mean);
        gen_rows.push(suite_row(
            "generation",
            &format!("gen_kv_{label}"),
            &r,
            vec![
                ("tok_per_s", Json::Num(gen_n as f64 / r.mean)),
                ("ms_per_tok", Json::Num(r.mean * 1e3 / gen_n as f64)),
                ("gen_tokens", Json::Int(gen_n as i64)),
            ],
        ));
        let r = bq.run(&format!("{label}: full-prefix gen ({gen_n} tok)"), || {
            let mut toks = prompt.clone();
            let mut cap = ActivationCapture::default();
            let v = backend.cfg().vocab;
            for _ in 0..gen_n {
                let logits = forward(&backend, &toks, &mut cap);
                let last = &logits[(toks.len() - 1) * v..toks.len() * v];
                toks.push(argmax(last) as u8);
            }
            black_box(&toks);
        });
        println!("{label}: full-prefix {:.1} tok/s", gen_n as f64 / r.mean);
        gen_rows.push(suite_row(
            "generation",
            &format!("gen_prefix_{label}"),
            &r,
            vec![
                ("tok_per_s", Json::Num(gen_n as f64 / r.mean)),
                ("ms_per_tok", Json::Num(r.mean * 1e3 / gen_n as f64)),
                ("gen_tokens", Json::Int(gen_n as i64)),
            ],
        ));
    }
    // slate amortization: the fused backend decodes each weight row once
    // per decode step for all lanes — aggregate tok/s should beat 8 ×
    // single-lane stepping
    {
        println!("\n== generation: fused 8-lane slate ==");
        let backend = build_backend(&path, BackendKind::Fused, threads);
        let lanes_n = 8usize;
        let r = bq.run("fused: kv-cached gen, 8-lane slate", || {
            gen_slate(&backend, &prompt, gen_n, lanes_n);
        });
        let total = (gen_n * lanes_n) as f64;
        println!("fused slate-8: {:.1} tok/s aggregate", total / r.mean);
        gen_rows.push(suite_row(
            "generation",
            "gen_kv_fused_slate8",
            &r,
            vec![
                ("tok_per_s", Json::Num(total / r.mean)),
                ("ms_per_tok", Json::Num(r.mean * 1e3 / total)),
                ("gen_tokens", Json::Int((gen_n * lanes_n) as i64)),
                ("lanes", Json::Int(lanes_n as i64)),
            ],
        ));
    }
    let gen_out = std::env::var("LLVQ_BENCH_GEN_OUT")
        .unwrap_or_else(|_| "BENCH_generation.json".into());
    match std::fs::write(&gen_out, Json::Arr(gen_rows).to_string_pretty()) {
        Ok(()) => println!("\nwrote {gen_out}"),
        Err(e) => eprintln!("\n[warn] could not write {gen_out}: {e}"),
    }

    // ---- kernel scaling: threads × backend × slate → BENCH_kernel.json ----
    // the tentpole acceptance numbers at 1/2/4/8 pool threads, single lane
    // and 8-lane slate. The pool-parallel phase differs per backend, so
    // each is timed where its kernel actually runs:
    //   * fused — warm steady-state generation (the row-sharded
    //     dequant-matmul runs on every decode step; tok/s should improve
    //     monotonically 1 → 4 threads on this config, bit-identically);
    //   * cached — COLD start (build + generate, so the timed region
    //     contains the row-sharded first-touch decode of every layer —
    //     warm cached generation is plain dense matvecs and never touches
    //     the pool).
    let mut kernel_rows: Vec<Json> = Vec::new();
    let lanes_n = 8usize;
    println!("\n== kernel scaling: fused (warm steady-state) ==");
    for &t in &[1usize, 2, 4, 8] {
        let backend = build_backend(&path, BackendKind::Fused, t);
        {
            // warm the pool workers and scratch slots
            let mut cache = KvCache::new(backend.cfg());
            black_box(prefill(&backend, &mut cache, &prompt));
        }
        let r = bq.run(&format!("fused t={t}: kv gen ({gen_n} tok, 1 lane)"), || {
            gen_kv(&backend, &prompt, gen_n);
        });
        println!("fused t={t}: single-lane {:.1} tok/s", gen_n as f64 / r.mean);
        kernel_rows.push(suite_row(
            "kernel",
            &format!("fused_t{t}_lane1"),
            &r,
            vec![
                ("threads", Json::Int(t as i64)),
                ("lanes", Json::Int(1)),
                ("cold", Json::Bool(false)),
                ("simd", Json::Str(backend.simd().label().into())),
                ("tok_per_s", Json::Num(gen_n as f64 / r.mean)),
                ("ms_per_tok", Json::Num(r.mean * 1e3 / gen_n as f64)),
            ],
        ));
        let r = bq.run(
            &format!("fused t={t}: kv gen ({gen_n} tok, {lanes_n}-lane slate)"),
            || {
                gen_slate(&backend, &prompt, gen_n, lanes_n);
            },
        );
        let total = (gen_n * lanes_n) as f64;
        println!(
            "fused t={t}: slate-{lanes_n} {:.1} tok/s aggregate",
            total / r.mean
        );
        kernel_rows.push(suite_row(
            "kernel",
            &format!("fused_t{t}_slate{lanes_n}"),
            &r,
            vec![
                ("threads", Json::Int(t as i64)),
                ("lanes", Json::Int(lanes_n as i64)),
                ("cold", Json::Bool(false)),
                ("simd", Json::Str(backend.simd().label().into())),
                ("tok_per_s", Json::Num(total / r.mean)),
                ("ms_per_tok", Json::Num(r.mean * 1e3 / total)),
            ],
        ));
    }
    println!("\n== kernel scaling: cached (cold incl. first-touch decode) ==");
    for &t in &[1usize, 2, 4, 8] {
        let r = bq.run(
            &format!("cached t={t}: cold build + kv gen ({gen_n} tok, 1 lane)"),
            || {
                let backend = build_backend(&path, BackendKind::Cached, t);
                gen_kv(&backend, &prompt, gen_n);
            },
        );
        println!(
            "cached t={t}: cold single-lane {:.1} tok/s",
            gen_n as f64 / r.mean
        );
        kernel_rows.push(suite_row(
            "kernel",
            &format!("cached_t{t}_lane1_cold"),
            &r,
            vec![
                ("threads", Json::Int(t as i64)),
                ("lanes", Json::Int(1)),
                ("cold", Json::Bool(true)),
                ("simd", Json::Str("scalar".into())),
                ("tok_per_s", Json::Num(gen_n as f64 / r.mean)),
                ("ms_per_tok", Json::Num(r.mean * 1e3 / gen_n as f64)),
            ],
        ));
        let r = bq.run(
            &format!("cached t={t}: cold build + kv gen ({gen_n} tok, {lanes_n}-lane slate)"),
            || {
                let backend = build_backend(&path, BackendKind::Cached, t);
                gen_slate(&backend, &prompt, gen_n, lanes_n);
            },
        );
        let total = (gen_n * lanes_n) as f64;
        println!(
            "cached t={t}: cold slate-{lanes_n} {:.1} tok/s aggregate",
            total / r.mean
        );
        kernel_rows.push(suite_row(
            "kernel",
            &format!("cached_t{t}_slate{lanes_n}_cold"),
            &r,
            vec![
                ("threads", Json::Int(t as i64)),
                ("lanes", Json::Int(lanes_n as i64)),
                ("cold", Json::Bool(true)),
                ("simd", Json::Str("scalar".into())),
                ("tok_per_s", Json::Num(total / r.mean)),
                ("ms_per_tok", Json::Num(r.mean * 1e3 / total)),
            ],
        ));
    }

    // ---- simd: forced-scalar vs auto-detected fused kernel at t=1 ----
    // the tentpole acceptance comparison: same artifact, one pool thread,
    // only the dispatched kernel differs. When runtime detection lands on
    // the scalar oracle anyway (no AVX2/NEON and portable_simd off) there
    // is nothing to compare against, so only the forced-scalar row lands.
    {
        println!("\n== simd: forced-scalar vs auto-detected kernel (fused, t=1) ==");
        let auto = Kernel::detect();
        let mut kinds = vec![Kernel::Scalar];
        if auto != Kernel::Scalar {
            kinds.push(auto);
        }
        let mut tok_s: Vec<(Kernel, f64)> = Vec::new();
        for kind in kinds {
            let backend =
                ExecutionBackend::packed_fused_kernel(PackedFile::open(&path).unwrap(), 1, kind)
                    .unwrap();
            {
                // warm the worker and its scratch slot
                let mut cache = KvCache::new(backend.cfg());
                black_box(prefill(&backend, &mut cache, &prompt));
            }
            let label = kind.label();
            let r = bq.run(&format!("fused {label} t=1: kv gen ({gen_n} tok, 1 lane)"), || {
                gen_kv(&backend, &prompt, gen_n);
            });
            let tps = gen_n as f64 / r.mean;
            println!("fused {label} t=1: {tps:.1} tok/s");
            tok_s.push((kind, tps));
            kernel_rows.push(suite_row(
                "kernel",
                &format!("fused_{label}_t1_lane1"),
                &r,
                vec![
                    ("threads", Json::Int(1)),
                    ("lanes", Json::Int(1)),
                    ("cold", Json::Bool(false)),
                    ("simd", Json::Str(label.into())),
                    ("tok_per_s", Json::Num(tps)),
                    ("ms_per_tok", Json::Num(r.mean * 1e3 / gen_n as f64)),
                ],
            ));
        }
        if let [(_, scalar_tps), (auto_kind, auto_tps)] = tok_s[..] {
            println!(
                "simd speedup ({} vs scalar, fused t=1): {:.2}x",
                auto_kind.label(),
                auto_tps / scalar_tps
            );
        }
    }
    let kernel_out = std::env::var("LLVQ_BENCH_KERNEL_OUT")
        .unwrap_or_else(|_| "BENCH_kernel.json".into());
    match std::fs::write(&kernel_out, Json::Arr(kernel_rows).to_string_pretty()) {
        Ok(()) => println!("\nwrote {kernel_out}"),
        Err(e) => eprintln!("\n[warn] could not write {kernel_out}: {e}"),
    }

    // ---- pipelined prefill: TTFT + active-lane impact → BENCH_prefill.json ----
    // the scheduler-tier acceptance numbers: while a long FEED drains, an
    // already-active generation lane must keep producing tokens. Chunked
    // scheduling (prefill_chunk < prompt) bounds the active lane's worst
    // inter-token gap and keeps its tok/s up during the prefill window,
    // at a bounded time-to-first-token cost for the long prompt vs the
    // monolithic scheduler (prefill_chunk ≥ prompt: the whole prefill in
    // one worker tick — the pre-scheduler behavior).
    {
        println!("\n== pipelined prefill: chunked vs monolithic scheduler ==");
        let mut prefill_rows: Vec<Json> = Vec::new();
        let long_prompt: Vec<u8> = (0..48).map(|i| (i * 5 % 64) as u8).collect();
        let reps = if smoke { 1 } else { 3 };
        let mut summary: Vec<(&str, f64, f64)> = Vec::new();
        for (name, chunk) in [("chunked8", 8usize), ("monolithic", 64)] {
            let (mut ttfts, mut rates, mut gaps) = (Vec::new(), Vec::new(), Vec::new());
            for _ in 0..reps {
                let r = prefill_pipeline_run(
                    build_backend(&path, BackendKind::Fused, threads),
                    chunk,
                    &long_prompt,
                );
                ttfts.push(r.ttft_s);
                rates.push(r.active_tok_per_s);
                gaps.push(r.max_gap_s);
            }
            let (ttft, rate, gap) = (median(&mut ttfts), median(&mut rates), median(&mut gaps));
            println!(
                "{name:<11} (prefill_chunk={chunk:<2}): ttft {:.1} ms | active lane \
                 {rate:.1} tok/s during prefill | worst gap {:.1} ms",
                ttft * 1e3,
                gap * 1e3
            );
            let mut pairs = vec![
                ("suite", Json::Str("prefill".into())),
                ("name", Json::Str(name.into())),
                ("prefill_chunk", Json::Int(chunk as i64)),
                ("prompt_tokens", Json::Int(long_prompt.len() as i64)),
                ("ttft_ms", Json::Num(ttft * 1e3)),
                ("active_tok_per_s", Json::Num(rate)),
                ("active_max_gap_ms", Json::Num(gap * 1e3)),
            ];
            if smoke {
                pairs.push(("smoke", Json::Bool(true)));
            }
            prefill_rows.push(Json::obj(pairs));
            summary.push((name, rate, ttft));
        }
        if let [(_, rate_c, ttft_c), (_, rate_m, ttft_m)] = &summary[..] {
            println!(
                "chunked vs monolithic: active-lane {:.1}x tok/s during prefill, \
                 ttft {:.2}x",
                rate_c / rate_m.max(1e-9),
                ttft_c / ttft_m.max(1e-9)
            );
        }
        let prefill_out = std::env::var("LLVQ_BENCH_PREFILL_OUT")
            .unwrap_or_else(|_| "BENCH_prefill.json".into());
        match std::fs::write(&prefill_out, Json::Arr(prefill_rows).to_string_pretty()) {
            Ok(()) => println!("wrote {prefill_out}"),
            Err(e) => eprintln!("[warn] could not write {prefill_out}: {e}"),
        }
    }

    // ---- paged KV cache: capacity + throughput → BENCH_kv.json ----
    // the paged-KV acceptance numbers: sessions-per-GB from the exact
    // per-session byte shapes (dense worst-case slab vs f32 pages vs
    // llvq-coded cold pages), plus decode tok/s dense vs paged vs
    // paged+quantized on the fused backend (the hot serving path).
    {
        println!("\n== paged KV: dense slab vs f32 pages vs llvq cold pages ==");
        let mut kv_rows: Vec<Json> = Vec::new();
        let page_tokens = 16usize;
        let dense_bytes = cfg.n_layers * 2 * cfg.max_seq * cfg.d_model * 4;
        let page_bytes = cfg.n_layers * 2 * page_tokens * cfg.d_model * 4;
        let codec = KvCodec::build(KvQuantKind::Llvq, cfg.d_model)
            .unwrap()
            .unwrap();
        // a cold page is n_layers × 2 × page_tokens coded rows, each
        // carrying its bit-packed codes plus one f32 sigma
        let cold_page_bytes = cfg.n_layers * 2 * page_tokens * (codec.row_bytes() + 4);
        // capacity at a typical live session length (dense admission
        // charges max_seq regardless; paging charges actual pages)
        let live_tokens = 32usize;
        let live_pages = live_tokens.div_ceil(page_tokens);
        let gb = (1u64 << 30) as f64;
        let paged_session_bytes = live_pages * page_bytes;
        // quantized: the hottest page stays f32, the rest are cold codes
        let quant_session_bytes = page_bytes + (live_pages - 1) * cold_page_bytes;
        let per_gb = [
            ("dense", dense_bytes),
            ("paged", paged_session_bytes),
            ("paged_llvq", quant_session_bytes),
        ];
        for (name, bytes) in per_gb {
            println!(
                "{name:<11}: {bytes:>8} B/session ({live_tokens}-token live) → \
                 {:.0} sessions/GB",
                gb / bytes as f64
            );
        }
        let mut pairs = vec![
            ("suite", Json::Str("kv".into())),
            ("name", Json::Str("sessions_per_gb".into())),
            ("page_tokens", Json::Int(page_tokens as i64)),
            ("live_tokens", Json::Int(live_tokens as i64)),
            ("dense_bytes_per_session", Json::Int(dense_bytes as i64)),
            ("paged_bytes_per_session", Json::Int(paged_session_bytes as i64)),
            ("paged_llvq_bytes_per_session", Json::Int(quant_session_bytes as i64)),
            ("sessions_per_gb_dense", Json::Num(gb / dense_bytes as f64)),
            ("sessions_per_gb_paged", Json::Num(gb / paged_session_bytes as f64)),
            (
                "sessions_per_gb_paged_llvq",
                Json::Num(gb / quant_session_bytes as f64),
            ),
        ];
        if smoke {
            pairs.push(("smoke", Json::Bool(true)));
        }
        kv_rows.push(Json::obj(pairs));

        // decode throughput: same greedy run over the three cache shapes
        // (page_tokens=8 + hot=8 for the quantized leg, so attention
        // really reads decoded cold pages, not a trivially-all-hot cache)
        let backend = build_backend(&path, BackendKind::Fused, threads);
        {
            let mut cache = KvCache::new(backend.cfg());
            black_box(prefill(&backend, &mut cache, &prompt)); // warm
        }
        let r = bq.run(&format!("dense cache: kv gen ({gen_n} tok)"), || {
            gen_kv(&backend, &prompt, gen_n);
        });
        println!("dense cache: {:.1} tok/s", gen_n as f64 / r.mean);
        kv_rows.push(suite_row(
            "kv",
            "gen_dense",
            &r,
            vec![
                ("tok_per_s", Json::Num(gen_n as f64 / r.mean)),
                ("bytes_per_session", Json::Int(dense_bytes as i64)),
            ],
        ));
        let bench_pt = 8usize;
        for (name, quant, hot) in [
            ("paged_none", KvQuantKind::None, 16usize),
            ("paged_llvq", KvQuantKind::Llvq, 8),
        ] {
            let kv_codec = KvCodec::build(quant, cfg.d_model).unwrap();
            let total = prompt.len() + gen_n;
            let arena = PageArena::new(backend.cfg(), total.div_ceil(bench_pt), bench_pt);
            let r = bq.run(&format!("{name}: kv gen ({gen_n} tok)"), || {
                let mut cache = PagedKvCache::new(
                    backend.cfg(),
                    Arc::clone(&arena),
                    kv_codec.clone(),
                    hot,
                );
                let mut logits = prefill(&backend, &mut cache, &prompt);
                for _ in 0..gen_n - 1 {
                    let t = argmax(&logits) as u8;
                    logits = forward_step(&backend, &mut cache, t);
                }
                black_box(argmax(&logits));
            });
            println!("{name}: {:.1} tok/s", gen_n as f64 / r.mean);
            kv_rows.push(suite_row(
                "kv",
                &format!("gen_{name}"),
                &r,
                vec![
                    ("tok_per_s", Json::Num(gen_n as f64 / r.mean)),
                    ("page_tokens", Json::Int(bench_pt as i64)),
                    ("hot_window", Json::Int(hot as i64)),
                    ("kv_quant", Json::Str(quant.label().into())),
                ],
            ));
        }
        let kv_out =
            std::env::var("LLVQ_BENCH_KV_OUT").unwrap_or_else(|_| "BENCH_kv.json".into());
        match std::fs::write(&kv_out, Json::Arr(kv_rows).to_string_pretty()) {
            Ok(()) => println!("wrote {kv_out}"),
            Err(e) => eprintln!("[warn] could not write {kv_out}: {e}"),
        }
    }

    // ---- HTTP front door: end-to-end completion latency → BENCH_serving.json ----
    // the same artifact served through `serve_http` + the model registry:
    // whole-request wall time (connect → parse → registry lookup →
    // scheduler → JSON/SSE framing) for the non-streamed and streamed
    // paths, measured over a raw localhost socket like a real client.
    {
        use llvq::coordinator::ServeOptions;
        use llvq::http::api::serve_http;
        use llvq::model::registry::{parse_model_specs, ModelRegistry, RegistryConfig};
        use std::io::{Read as _, Write as _};
        use std::net::{TcpListener, TcpStream};

        println!("\n== HTTP front door: end-to-end completion latency ==");
        let specs = parse_model_specs(&format!("bench={}", path.display())).unwrap();
        let reg = ModelRegistry::open(
            specs,
            RegistryConfig {
                backend: BackendKind::Fused,
                threads,
                simd: Kernel::detect(),
                ..Default::default()
            },
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        {
            let reg = Arc::clone(&reg);
            std::thread::spawn(move || {
                let _ = serve_http(reg, listener, ServeOptions { max_conns: 64 });
            });
        }
        let gen_http = if smoke { 4 } else { 16 };
        let request = |stream: bool| {
            let body = format!(
                r#"{{"model":"bench","prompt":[1,2,3,4,5,6,7,8],"max_tokens":{gen_http},"stream":{stream}}}"#
            );
            let mut s = TcpStream::connect(addr).unwrap();
            let verb = "POST";
            write!(
                s,
                "{verb} /v1/completions HTTP/1.1\r\nHost: bench\r\nConnection: close\r\n\
                 Content-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            assert!(out.starts_with("HTTP/1.1 200 "), "bench request failed: {out}");
            out
        };
        request(false); // warm: first-request backend build stays untimed
        for (name, stream) in [("completion_json", false), ("completion_sse", true)] {
            let label = if stream { "SSE streamed" } else { "non-streamed" };
            let r = bq.run(&format!("http: {label} completion ({gen_http} tok)"), || {
                black_box(request(stream));
            });
            println!(
                "http {label}: {:.1} ms/request ({:.1} tok/s)",
                r.mean * 1e3,
                gen_http as f64 / r.mean
            );
            rows.push(suite_row(
                "http",
                name,
                &r,
                vec![
                    ("gen_tokens", Json::Int(gen_http as i64)),
                    ("tok_per_s", Json::Num(gen_http as f64 / r.mean)),
                ],
            ));
        }
        reg.stop();
    }

    // ---- dense engine + coordinator (the historical serving numbers) ----
    let engine = Arc::new(BackendEngine::dense(weights));
    println!("\n== engine forward (no coordinator) ==");
    let mut i = 0;
    b.run_throughput("forward batch=1 (seq/s)", 1.0, || {
        black_box(engine.forward_batch(std::slice::from_ref(&seqs[i % seqs.len()])));
        i += 1;
    });
    let batch8: Vec<Vec<u8>> = seqs[..8].to_vec();
    b.run_throughput("forward batch=8 (seq/s)", 8.0, || {
        black_box(engine.forward_batch(&batch8));
    });

    println!("\n== coordinator under concurrency ==");
    for &(max_batch, clients) in &[(1usize, 8usize), (8, 8), (8, 32)] {
        let coord = Coordinator::start(
            engine.clone(),
            BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(2),
                ..Default::default()
            },
        );
        let t0 = std::time::Instant::now();
        let per = if llvq::util::bench::smoke() { 6 } else { 24 };
        std::thread::scope(|s| {
            for c in 0..clients {
                let coord = coord.clone();
                let seqs = &seqs;
                s.spawn(move || {
                    for r in 0..per {
                        let _ = coord.submit(seqs[(c + r) % seqs.len()].clone());
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "max_batch={max_batch:<2} clients={clients:<3} → {:>7.1} req/s  \
             mean batch {:.2}  mean latency {:.2} ms",
            (clients * per) as f64 / wall,
            coord.metrics.mean_batch(),
            coord.metrics.mean_latency_ms()
        );
        coord.stop();
    }

    // ---- deterministic scheduler simulator: scenario corpus ----
    // virtual-clock replays of the named workload corpus (`llvq sim
    // --list`): wall seconds per scenario, virtual ticks to quiescence,
    // and the scheduler counters the run produced. `clean` is the
    // per-tick invariant verdict, `fingerprint` the log+stats FNV the
    // same seed must reproduce on any machine or thread count.
    {
        println!("\n== scheduler simulator: scenario corpus (virtual clock) ==");
        let seed = 1u64;
        for sc in Scenario::ALL {
            let trace = sc.trace(seed);
            let mut sim = Simulator::new(&trace).unwrap();
            let t0 = std::time::Instant::now();
            let report = sim.run_to_end(sc.max_ticks());
            let wall = t0.elapsed().as_secs_f64();
            let stat = |key: &str| -> i64 {
                report
                    .stats
                    .split_whitespace()
                    .find_map(|kv| kv.strip_prefix(&format!("{key}=")))
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(0)
            };
            println!(
                "{:<18}: {:>3} ticks in {:6.1} ms | gen {:>3} prefill {:>3} \
                 kv-oom {} | {}",
                sc.name(),
                report.ticks,
                wall * 1e3,
                stat("gen_tokens"),
                stat("prefill_toks"),
                stat("kv_oom"),
                if report.ok() { "clean" } else { "VIOLATION" }
            );
            let mut pairs = vec![
                ("suite", Json::Str("sim".into())),
                ("name", Json::Str(sc.name().into())),
                ("seed", Json::Int(seed as i64)),
                ("wall_s", Json::Num(wall)),
                ("ticks", Json::Int(report.ticks as i64)),
                ("gen_tokens", Json::Int(stat("gen_tokens"))),
                ("prefill_toks", Json::Int(stat("prefill_toks"))),
                ("kv_oom", Json::Int(stat("kv_oom"))),
                ("clean", Json::Bool(report.ok())),
                (
                    "fingerprint",
                    Json::Str(format!("{:016x}", report.fingerprint())),
                ),
            ];
            if smoke {
                pairs.push(("smoke", Json::Bool(true)));
            }
            rows.push(Json::obj(pairs));
        }
    }

    println!("\n== online Hadamard overhead (unfused rotations, §5.3) ==");
    let h = RandomizedHadamard::new(cfg.d_model, 9);
    let mut x: Vec<f64> = (0..cfg.d_model).map(|k| (k as f64).sin()).collect();
    b.run_throughput("R_in · x (144-dim, ops/s)", 1.0, || {
        h.forward(black_box(&mut x));
    });

    std::fs::remove_file(&path).ok();
    let out_path =
        std::env::var("LLVQ_BENCH_OUT").unwrap_or_else(|_| "BENCH_serving.json".into());
    let doc = Json::Arr(rows).to_string_pretty();
    match std::fs::write(&out_path, &doc) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\n[warn] could not write {out_path}: {e}"),
    }
}
