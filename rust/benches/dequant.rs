//! Dequantization benchmarks (EXPERIMENTS.md §Perf): the inference-side
//! hot path — hierarchical indexer vs flattened kernel tables, plus
//! encode (index construction) for completeness.

use llvq::leech::index::LeechIndexer;
use llvq::leech::tables::KernelTables;
use llvq::util::bench::{black_box, Bench};
use llvq::util::rng::Xoshiro256pp;

fn main() {
    let b = Bench::default();
    let ix = LeechIndexer::new(13);
    let t = KernelTables::build(&ix);
    let mut rng = Xoshiro256pp::new(2);
    let np = ix.num_points() as u64;
    let indices: Vec<u64> = (0..4096).map(|_| rng.next_range(np)).collect();

    println!("== dequantization @ M=13 (2 bits/weight codebook) ==");
    let mut i = 0;
    b.run_throughput("indexer.decode_index", 1.0, || {
        black_box(ix.decode_index(indices[i % indices.len()]));
        i += 1;
    });
    let mut j = 0;
    b.run_throughput("tables.dequantize (kernel twin)", 1.0, || {
        black_box(t.dequantize(indices[j % indices.len()]));
        j += 1;
    });

    // batch-64 flavour (the granularity the serving path uses)
    let mut base = 0usize;
    b.run_throughput("tables.dequantize ×64 batch", 64.0, || {
        for k in 0..64 {
            black_box(t.dequantize(indices[(base + k) % indices.len()]));
        }
        base += 64;
    });

    println!("\n== encode (vector → index) ==");
    let points: Vec<[i32; 24]> = indices
        .iter()
        .take(512)
        .map(|&ixx| ix.decode_index(ixx))
        .collect();
    let mut k = 0;
    b.run_throughput("indexer.encode_point", 1.0, || {
        black_box(ix.encode_point(&points[k % points.len()]));
        k += 1;
    });
}
