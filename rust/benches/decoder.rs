//! Decoder benchmarks — the compression hot path (EXPERIMENTS.md §Perf L3).
//!
//! Rows: infinite-lattice NN (fast byte-LUT path vs reference), ball-cut
//! search, angular search over the 2-bit shell union, single-block
//! quantization for both LLVQ variants.

use std::sync::Arc;

use llvq::golay::GolayCode;
use llvq::leech::decode::LeechDecoder;
use llvq::leech::index::LeechIndexer;
use llvq::quant::llvq::{LlvqShapeGain, LlvqSpherical};
use llvq::quant::VectorQuantizer;
use llvq::util::bench::{black_box, Bench};
use llvq::util::rng::Xoshiro256pp;

fn main() {
    let b = Bench::default();
    let golay = GolayCode::new();
    let dec = LeechDecoder::new(&golay);
    let mut rng = Xoshiro256pp::new(1);

    let targets: Vec<[f64; 24]> = (0..256)
        .map(|_| std::array::from_fn(|_| rng.next_gaussian() * 5.0))
        .collect();
    let mut i = 0;

    println!("== decoder (single thread) ==");
    b.run_throughput("decode_infinite (byte-LUT)", 1.0, || {
        let t = &targets[i % targets.len()];
        i += 1;
        black_box(dec.decode_infinite(t));
    });
    let mut j = 0;
    b.run_throughput("decode_infinite_ref (naive)", 1.0, || {
        let t = &targets[j % targets.len()];
        j += 1;
        black_box(dec.decode_infinite_ref(t));
    });
    let mut k = 0;
    b.run_throughput("decode_in_ball M=13", 1.0, || {
        let t = &targets[k % targets.len()];
        k += 1;
        black_box(dec.decode_in_ball(t, 13));
    });
    let mut l = 0;
    b.run_throughput("decode_angular union 2..12", 1.0, || {
        let t = &targets[l % targets.len()];
        l += 1;
        black_box(dec.decode_angular(t, 2, 12));
    });

    println!("\n== block quantization (codes incl. indexing) ==");
    let blocks: Vec<[f32; 24]> = (0..256)
        .map(|_| std::array::from_fn(|_| rng.next_gaussian() as f32))
        .collect();
    let sph = LlvqSpherical::new(Arc::new(LeechIndexer::new(13)));
    let mut m = 0;
    b.run_throughput("llvq-spherical quantize (2 bpw)", 1.0, || {
        let x = &blocks[m % blocks.len()];
        m += 1;
        black_box(sph.quantize(x));
    });
    let sg = LlvqShapeGain::new(Arc::new(LeechIndexer::new(12)), 1);
    let mut n = 0;
    b.run_throughput("llvq-shape-gain quantize (2 bpw)", 1.0, || {
        let x = &blocks[n % blocks.len()];
        n += 1;
        black_box(sg.quantize(x));
    });
}
