//! Packed-artifact (`.llvqm`) benchmarks — the storage hot paths.
//!
//! Rows: block codec throughput (encode/decode of LLVQ shape–gain codes,
//! the paper's 2 bits/weight configuration), whole-model pack/unpack
//! throughput, and packed-vs-dense artifact load latency.
//!
//! Besides the human-readable report, every measurement lands as a JSON
//! row in `BENCH_packed.json` (override with `LLVQ_BENCH_OUT`; the file is
//! rewritten each run), in the flat row shape the `BENCH_*.json`
//! trajectories use:
//! `{"suite","name","mean_s","median_s","p10_s","p90_s", ...throughput}`.

use std::sync::Arc;

use llvq::leech::index::LeechIndexer;
use llvq::model::config::config_by_name;
use llvq::model::io as model_io;
use llvq::model::packed::PackedModel;
use llvq::model::transformer::Weights;
use llvq::pipeline::driver::{quantize_model_packed, PtqOptions};
use llvq::pipeline::rotation::RotationMode;
use llvq::quant::llvq::LlvqShapeGain;
use llvq::quant::{read_code_with, write_code_with, Code, VectorQuantizer};
use llvq::util::bench::{black_box, Bench, BenchResult};
use llvq::util::bits::{BitReader, BitWriter};
use llvq::util::json::Json;
use llvq::util::rng::Xoshiro256pp;

fn row(name: &str, r: &BenchResult, extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("suite", Json::Str("packed".into())),
        ("name", Json::Str(name.into())),
        ("mean_s", Json::Num(r.mean)),
        ("median_s", Json::Num(r.median)),
        ("p10_s", Json::Num(r.p10)),
        ("p90_s", Json::Num(r.p90)),
    ];
    if llvq::util::bench::smoke() {
        pairs.push(("smoke", Json::Bool(true)));
    }
    pairs.extend(extra);
    Json::obj(pairs)
}

fn main() {
    // LLVQ_BENCH_SMOKE=1 (CI's bench-smoke tier): Bench::default() shrinks
    // its sample counts, and the codebook/block dims shrink below, so the
    // BENCH_packed.json artifact is produced in seconds per PR
    let smoke = llvq::util::bench::smoke();
    let b = Bench::default();
    let mut rows: Vec<Json> = Vec::new();

    // ---- block codec: LLVQ shape–gain M=12 + 1 gain bit (2 bpw) ----
    println!("== block codec (llvq shape-gain, 2 bpw) ==");
    let q = LlvqShapeGain::new(Arc::new(LeechIndexer::new(if smoke { 6 } else { 12 })), 1);
    let widths = q.code_widths();
    let mut rng = Xoshiro256pp::new(7);
    let nblk = if smoke { 128usize } else { 512usize };
    let blocks: Vec<[f32; 24]> = (0..nblk)
        .map(|_| std::array::from_fn(|_| rng.next_gaussian() as f32))
        .collect();
    let codes: Vec<Code> = blocks.iter().map(|x| q.quantize(x)).collect();

    let r = b.run_throughput(&format!("encode stream ({nblk} codes)"), nblk as f64, || {
        let mut w = BitWriter::with_capacity(nblk * 8);
        for c in &codes {
            write_code_with(&widths, c, &mut w);
        }
        black_box(w.finish());
    });
    rows.push(row(
        "encode_blocks",
        &r,
        vec![("blocks_per_s", Json::Num(nblk as f64 / r.mean))],
    ));

    let mut w = BitWriter::new();
    for c in &codes {
        write_code_with(&widths, c, &mut w);
    }
    let stream = w.finish();
    let r = b.run_throughput(&format!("decode stream ({nblk} blocks)"), nblk as f64, || {
        let mut br = BitReader::new(&stream);
        let mut code = Code::empty();
        let mut out = [0f32; 24];
        for _ in 0..nblk {
            read_code_with(&widths, &mut br, &mut code);
            q.dequantize(&code, &mut out);
            black_box(out[0]);
        }
    });
    rows.push(row(
        "decode_blocks",
        &r,
        vec![
            ("blocks_per_s", Json::Num(nblk as f64 / r.mean)),
            (
                "weights_gb_per_s",
                Json::Num(nblk as f64 * 24.0 * 4.0 / r.mean / 1e9),
            ),
        ],
    ));

    // ---- whole-model artifact: PTQ once (outside timers), then measure ----
    println!("\n== whole-model artifact (llama2-tiny, 2 bpw shape-gain) ==");
    let cfg = config_by_name("llama2-tiny").unwrap();
    let model = Weights::random(&cfg, 42);
    let opts = PtqOptions {
        rotation: RotationMode::Input,
        calib_seqs: 4,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let art = quantize_model_packed(&model, &q, &opts);
    println!(
        "(one-time PTQ: {:.1}s, {:.4} code bpw)",
        t0.elapsed().as_secs_f64(),
        art.report.bits_per_weight()
    );
    let packed_bytes = art.packed.to_bytes();
    let dense_bytes = model_io::to_bytes(&art.weights);
    let total_blocks: usize = art
        .packed
        .layers
        .iter()
        .map(|l| l.rows * l.codes.blocks_per_row)
        .sum();
    println!(
        "packed {} B vs dense {} B ({:.1}x)",
        packed_bytes.len(),
        dense_bytes.len(),
        dense_bytes.len() as f64 / packed_bytes.len() as f64
    );

    let r = b.run_throughput("pack (PackedModel::to_bytes)", 1.0, || {
        black_box(art.packed.to_bytes());
    });
    rows.push(row(
        "pack_to_bytes",
        &r,
        vec![(
            "gb_per_s",
            Json::Num(packed_bytes.len() as f64 / r.mean / 1e9),
        )],
    ));

    let r = b.run_throughput("parse (PackedModel::from_bytes)", 1.0, || {
        black_box(PackedModel::from_bytes(&packed_bytes).unwrap());
    });
    rows.push(row(
        "parse_from_bytes",
        &r,
        vec![(
            "gb_per_s",
            Json::Num(packed_bytes.len() as f64 / r.mean / 1e9),
        )],
    ));

    let threads = llvq::util::threadpool::default_threads();
    let r = b.run_throughput("unpack (block-parallel dequant)", total_blocks as f64, || {
        black_box(art.packed.unpack(threads).unwrap());
    });
    rows.push(row(
        "unpack_model",
        &r,
        vec![
            ("blocks_per_s", Json::Num(total_blocks as f64 / r.mean)),
            (
                "weights_gb_per_s",
                Json::Num(dense_bytes.len() as f64 / r.mean / 1e9),
            ),
            ("threads", Json::Int(threads as i64)),
        ],
    ));

    // ---- load latency: packed (parse+unpack) vs dense parse ----
    println!("\n== load latency ==");
    let r = b.run_throughput("packed load (parse + unpack)", 1.0, || {
        let p = PackedModel::from_bytes(&packed_bytes).unwrap();
        black_box(p.unpack(threads).unwrap());
    });
    rows.push(row(
        "load_packed",
        &r,
        vec![("file_bytes", Json::Int(packed_bytes.len() as i64))],
    ));
    let r = b.run_throughput("dense load (from_bytes)", 1.0, || {
        black_box(model_io::from_bytes(&dense_bytes).unwrap());
    });
    rows.push(row(
        "load_dense",
        &r,
        vec![("file_bytes", Json::Int(dense_bytes.len() as i64))],
    ));

    let out_path = std::env::var("LLVQ_BENCH_OUT").unwrap_or_else(|_| "BENCH_packed.json".into());
    let doc = Json::Arr(rows).to_string_pretty();
    match std::fs::write(&out_path, &doc) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\n[warn] could not write {out_path}: {e}"),
    }
}
