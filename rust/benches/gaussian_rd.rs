//! Gaussian-source experiment regenerators as benches: Table 4 and
//! Table 7 summary rows at reduced sample counts (full runs via
//! `llvq exp table4 table7`).

use llvq::experiments::{table4, table7, Effort};

fn main() {
    let e = Effort {
        leech_blocks: 400,
        cheap_blocks: 40_000,
        eval_seqs: 4,
        threads: llvq::util::threadpool::default_threads(),
    };
    table4(&e);
    table7(&e);
}
