//! PTQ pipeline benchmark — end-to-end layer quantization throughput for
//! each method (the compression-time cost the paper's Alg. 1 incurs).

use std::sync::Arc;

use llvq::leech::index::LeechIndexer;
use llvq::math::linalg::Matrix;
use llvq::pipeline::gptq::{quantize_layer, GptqConfig};
use llvq::quant::e8::{E8Codebook, E8Cut};
use llvq::quant::llvq::{LlvqShapeGain, LlvqSpherical};
use llvq::quant::scalar::UniformQuantizer;
use llvq::quant::VectorQuantizer;
use llvq::util::bench::{black_box, Bench};
use llvq::util::rng::Xoshiro256pp;

fn main() {
    let b = Bench {
        warmup: std::time::Duration::from_millis(100),
        min_batch_time: std::time::Duration::from_millis(100),
        num_samples: 5,
    };
    // llama2-tiny attention-shaped layer: 144×144, correlated Hessian
    let (rows, cols) = (144usize, 144usize);
    let mut rng = Xoshiro256pp::new(3);
    let w: Vec<f32> = (0..rows * cols).map(|_| rng.next_gaussian() as f32).collect();
    let mut a = Matrix::zeros(cols, cols);
    for v in a.data.iter_mut() {
        *v = rng.next_gaussian() * 0.2;
    }
    for i in 0..cols {
        *a.at_mut(i, i) += 1.0;
    }
    let h = a.matmul(&a.transpose());
    let cfg = GptqConfig::default();
    let params = (rows * cols) as f64;

    println!("== GPTQ layer quantization, 144×144, {} threads ==", cfg.threads);
    let uni = UniformQuantizer::new_gaussian_optimal(2);
    b.run_throughput("scalar-2b layer (params/s)", params, || {
        black_box(quantize_layer(&w, rows, cols, &h, &uni, &cfg));
    });
    let e8 = E8Codebook::new(E8Cut::Ball);
    b.run_throughput("e8p layer (params/s)", params, || {
        black_box(quantize_layer(&w, rows, cols, &h, &e8, &cfg));
    });
    let sph = LlvqSpherical::new(Arc::new(LeechIndexer::new(13)));
    b.run_throughput("llvq-spherical layer (params/s)", params, || {
        black_box(quantize_layer(&w, rows, cols, &h, &sph, &cfg));
    });
    let sg = LlvqShapeGain::new(Arc::new(LeechIndexer::new(12)), 1);
    b.run_throughput("llvq-shape-gain layer (params/s)", params, || {
        black_box(quantize_layer(&w, rows, cols, &h, &sg, &cfg));
    });
}
