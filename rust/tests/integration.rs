//! Cross-module integration tests: decoder ↔ indexer ↔ tables ↔ quantizers
//! ↔ pipeline ↔ model, all without artifacts (pure rust path).

use std::sync::Arc;

use llvq::golay::GolayCode;
use llvq::leech::decode::LeechDecoder;
use llvq::leech::index::LeechIndexer;
use llvq::leech::tables::KernelTables;
use llvq::leech::{coset, theta};
use llvq::model::config::config_by_name;
use llvq::model::eval::evaluate;
use llvq::model::transformer::Weights;
use llvq::pipeline::driver::{quantize_model, PtqOptions};
use llvq::pipeline::gptq::GptqConfig;
use llvq::pipeline::rotation::RotationMode;
use llvq::quant::e8::{E8Codebook, E8Cut};
use llvq::quant::llvq::{LlvqShapeGain, LlvqSpherical};
use llvq::quant::scalar::UniformQuantizer;
use llvq::quant::VectorQuantizer;
use llvq::util::rng::Xoshiro256pp;

/// Brute-force NN over the full kissing configuration — the decoder's
/// in-ball answer restricted to Shell(2) must match exactly.
#[test]
fn ball_decoder_exact_on_shell2_bruteforce() {
    let ix = LeechIndexer::new(2);
    let golay = GolayCode::new();
    let dec = LeechDecoder::new(&golay);
    // materialize all 196 560 minimal vectors once
    let all: Vec<[i32; 24]> = (0..196_560u64).map(|i| ix.decode_index(i)).collect();
    let mut rng = Xoshiro256pp::new(0xB0B);
    for _ in 0..12 {
        let mut t = [0f64; 24];
        for v in t.iter_mut() {
            *v = rng.next_gaussian() * 4.0;
        }
        let fast = dec.decode_in_ball(&t, 2);
        let mut best = f64::INFINITY;
        for p in &all {
            let d: f64 = p
                .iter()
                .zip(t.iter())
                .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
                .sum();
            if d < best {
                best = d;
            }
        }
        assert!(
            (fast.dist_sq - best).abs() < 1e-9,
            "ball decode not optimal: {} vs brute {}",
            fast.dist_sq,
            best
        );
    }
}

/// Angular search on Shell(2) must match brute-force max-cosine.
#[test]
fn angular_decoder_exact_on_shell2_bruteforce() {
    let ix = LeechIndexer::new(2);
    let golay = GolayCode::new();
    let dec = LeechDecoder::new(&golay);
    let all: Vec<[i32; 24]> = (0..196_560u64).map(|i| ix.decode_index(i)).collect();
    let mut rng = Xoshiro256pp::new(0xA27);
    let mut exact = 0;
    let trials = 12;
    for _ in 0..trials {
        let mut u = [0f64; 24];
        rng.fill_gaussian_f64(&mut u);
        let got = dec.decode_angular(&u, 2, 2);
        let cos_of = |p: &[i32; 24]| -> f64 {
            let dot: f64 = p.iter().zip(u.iter()).map(|(&a, &b)| a as f64 * b).sum();
            dot // all shell-2 points share a norm → dot ranking == cosine
        };
        let best = all.iter().map(cos_of).fold(f64::NEG_INFINITY, f64::max);
        if (cos_of(&got.point) - best).abs() < 1e-9 {
            exact += 1;
        }
    }
    // multi-radius candidate generation is a documented approximation; on
    // the single-shell case it should almost always be exact
    assert!(
        exact >= trials - 2,
        "angular search too loose: {exact}/{trials} exact"
    );
}

#[test]
fn index_bijection_against_tables_at_scale() {
    // sample the full 2-bit codebook (M=13): decode → encode → decode
    let ix = LeechIndexer::new(13);
    let t = KernelTables::build(&ix);
    assert_eq!(ix.num_points(), 280_974_212_784_720);
    let mut rng = Xoshiro256pp::new(0x1D5);
    let np = ix.num_points() as u64;
    for _ in 0..800 {
        let idx = rng.next_range(np);
        let x = ix.decode_index(idx);
        assert_eq!(t.dequantize(idx), x, "tables disagree at {idx}");
        assert_eq!(ix.encode_point(&x), Some(idx), "bijection broke at {idx}");
        let m = coset::shell_of(&x).unwrap();
        assert!((2..=13).contains(&m));
    }
}

#[test]
fn theta_consistency_with_indexer_offsets() {
    let ix = LeechIndexer::new(6);
    let cum = theta::cumulative_sizes(6);
    assert_eq!(ix.num_points(), cum[6]);
}

#[test]
fn quantizers_rank_correctly_on_gaussian_at_2bpw() {
    // the paper's headline ordering at 2 bits/weight:
    // uniform > e8-cube > e8p-ball > llvq-spherical > llvq-shape-gain (MSE)
    let e = llvq::experiments::Effort {
        leech_blocks: 250,
        cheap_blocks: 30_000,
        eval_seqs: 4,
        threads: llvq::util::threadpool::default_threads(),
    };
    let uni = UniformQuantizer::new_gaussian_optimal(2);
    let (m_uni, _) = llvq::experiments::gaussian_rd_parallel(&uni, e.cheap_blocks, 1, e.threads);
    let ball = E8Codebook::new(E8Cut::Ball);
    let (m_e8, _) = llvq::experiments::gaussian_rd_parallel(&ball, e.cheap_blocks / 4, 1, e.threads);
    let sph = LlvqSpherical::new(Arc::new(LeechIndexer::new(13)));
    let (m_sph, _) = llvq::experiments::gaussian_rd_parallel(&sph, e.leech_blocks, 1, e.threads);
    let sg = LlvqShapeGain::new(Arc::new(LeechIndexer::new(12)), 1);
    let (m_sg, _) = llvq::experiments::gaussian_rd_parallel(&sg, e.leech_blocks, 1, e.threads);

    assert!(m_uni > m_e8, "uniform {m_uni} !> e8 {m_e8}");
    assert!(m_e8 > m_sph, "e8 {m_e8} !> llvq-sph {m_sph}");
    assert!(m_sg < m_sph * 1.02, "shape-gain {m_sg} !<~ spherical {m_sph}");
    // absolute bands from Table 4 (generous tolerances for sample noise)
    assert!(m_sph > 0.07 && m_sph < 0.10, "spherical MSE {m_sph} out of band");
    assert!(m_sg > 0.065 && m_sg < 0.095, "shape-gain MSE {m_sg} out of band");
}

#[test]
fn end_to_end_ptq_ordering_on_tiny_model() {
    // random-weight model: quantization-noise ordering still must hold for
    // the proxy loss reported by the pipeline
    let cfg = config_by_name("qwen3-4b-tiny").unwrap();
    let w = Weights::random(&cfg, 77);
    let opts = PtqOptions {
        rotation: RotationMode::Input,
        finetune_scales: false,
        calib_seqs: 6,
        gptq: GptqConfig::default(),
        seed: 1000,
    };
    let run = |q: &dyn VectorQuantizer| -> f64 {
        let (_, rep) = quantize_model(&w, q, &opts);
        rep.layers.iter().map(|l| l.proxy_loss).sum()
    };
    let loss_scalar = run(&UniformQuantizer::new_gaussian_optimal(2));
    let loss_llvq = run(&LlvqSpherical::new(Arc::new(LeechIndexer::new(13))));
    assert!(
        loss_llvq < loss_scalar,
        "LLVQ {loss_llvq} must beat scalar {loss_scalar} at 2 bpw"
    );
}

#[test]
fn quantized_model_stays_usable() {
    let cfg = config_by_name("qwen3-4b-tiny").unwrap();
    let w = Weights::random(&cfg, 5);
    let base = evaluate(&w, 4, 2000, 2);
    let q = LlvqShapeGain::new(Arc::new(LeechIndexer::new(5)), 1);
    let opts = PtqOptions {
        calib_seqs: 4,
        ..Default::default()
    };
    let (wq, rep) = quantize_model(&w, &q, &opts);
    assert!(rep.bits_per_weight() < 1.55); // M=5: 33 bits + 1 gain over 24
    let quant = evaluate(&wq, 4, 2000, 2);
    assert!(quant.perplexity.is_finite());
    // random model: ppl ≈ vocab for both; quantized must stay in the band
    assert!(quant.perplexity < base.perplexity * 3.0);
}
