//! Property tests over the crate's core invariants (custom driver in
//! `util::proptest`; failing seeds are printed for reproduction).

use std::sync::Arc;

use llvq::golay::GolayCode;
use llvq::leech::decode::LeechDecoder;
use llvq::leech::index::{ms_perm_rank, ms_perm_unrank, LeechIndexer};
use llvq::leech::{coset, leaders};
use llvq::math::hadamard::RandomizedHadamard;
use llvq::math::linalg::{cholesky, solve_spd, Matrix};
use llvq::quant::product;
use llvq::quant::scalar::UniformQuantizer;
use llvq::quant::VectorQuantizer;
use llvq::util::proptest::check;

#[test]
fn prop_index_roundtrip_uniform_over_ball() {
    let ix = LeechIndexer::new(8);
    let n = ix.num_points() as u64;
    check("index-roundtrip-M8", 600, |rng| {
        let idx = rng.next_range(n);
        let x = ix.decode_index(idx);
        if !coset::is_lattice_point(ix.golay(), &x) {
            return Err(format!("decode({idx}) → non-lattice {x:?}"));
        }
        match ix.encode_point(&x) {
            Some(back) if back == idx => Ok(()),
            Some(back) => Err(format!("{idx} → {x:?} → {back}")),
            None => Err(format!("{idx} → {x:?} → encode failed")),
        }
    });
}

#[test]
fn prop_random_lattice_points_encode() {
    // build random lattice points CONSTRUCTIVELY (not via the indexer):
    // x = 2·(golay word) + 4·z, fixed up mod 8 — then encode must succeed
    // and decode back to the same point.
    let ix = LeechIndexer::new(10);
    let golay = GolayCode::new();
    check("constructive-points-encode", 300, |rng| {
        let c = golay.unrank(rng.next_range(4096) as u32);
        let mut x = [0i32; 24];
        for (i, v) in x.iter_mut().enumerate() {
            let z = (rng.next_range(3) as i32) - 1; // small multiples of 4
            *v = 4 * z + 2 * ((c >> i) & 1) as i32;
        }
        // repair Σ ≡ 0 (mod 8) by adjusting one coordinate by ±4
        let sum: i32 = x.iter().sum();
        if sum.rem_euclid(8) != 0 {
            x[0] += if sum.rem_euclid(8) == 4 { 4 } else { return Ok(()) };
        }
        if !coset::is_lattice_point(&golay, &x) {
            return Ok(()); // repair occasionally changes the Golay word; skip
        }
        let m = match coset::shell_of(&x) {
            Some(m) if (2..=10).contains(&m) => m,
            _ => return Ok(()), // outside the ball (or the origin) — skip
        };
        let idx = ix
            .encode_point(&x)
            .ok_or_else(|| format!("valid shell-{m} point failed to encode: {x:?}"))?;
        if ix.decode_index(idx) != x {
            return Err(format!("roundtrip mismatch for {x:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_ms_perm_rank_bijection() {
    check("ms-perm-rank", 300, |rng| {
        // random multiset over ≤4 symbols, length ≤ 12
        let k = 1 + rng.next_range(4) as usize;
        let mut mults: Vec<(u8, u8)> = (0..k)
            .map(|i| ((10 - 2 * i) as u8, 1 + rng.next_range(3) as u8))
            .collect();
        mults.sort_by(|a, b| b.0.cmp(&a.0));
        let total: u128 = {
            let len: usize = mults.iter().map(|&(_, c)| c as usize).sum();
            let mut t: u128 = (1..=len as u128).product();
            for &(_, c) in &mults {
                t /= (1..=c as u128).product::<u128>();
            }
            t
        };
        let r = rng.next_range(total.min(1_000_000) as u64) as u128;
        let mut seq = Vec::new();
        ms_perm_unrank(&mults, r, &mut seq);
        if ms_perm_rank(&seq) != r {
            return Err(format!("rank(unrank({r})) = {}", ms_perm_rank(&seq)));
        }
        Ok(())
    });
}

#[test]
fn prop_decoder_beats_random_lattice_points() {
    let golay = GolayCode::new();
    let dec = LeechDecoder::new(&golay);
    let ix = LeechIndexer::new(4);
    let n = ix.num_points() as u64;
    check("decoder-optimality-vs-sampling", 40, |rng| {
        let mut t = [0f64; 24];
        for v in t.iter_mut() {
            *v = rng.next_gaussian() * 5.0;
        }
        let out = dec.decode_infinite(&t);
        for _ in 0..50 {
            let p = ix.decode_index(rng.next_range(n));
            let d: f64 = p
                .iter()
                .zip(t.iter())
                .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
                .sum();
            if d < out.dist_sq - 1e-9 {
                return Err(format!("sampled point beats decoder: {d} < {}", out.dist_sq));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shell_class_sizes_factorization() {
    // eq. 12 invariant: every subclass size = A·2^B·arr_f1·arr_f0 and the
    // class sizes sum to the shell size for random shells ≤ 16
    let golay = GolayCode::new();
    let theta = llvq::leech::theta::shell_sizes(16);
    check("eq12-factorization", 8, |rng| {
        let m = 2 + rng.next_range(15) as usize;
        let s = leaders::enumerate_shell(&golay, m);
        let total: u128 = s.classes.iter().map(|c| c.size).sum();
        if total != theta[m] {
            return Err(format!("shell {m}: {total} != theta {}", theta[m]));
        }
        Ok(())
    });
}

#[test]
fn prop_hadamard_isometry_and_involution() {
    check("hadamard-isometry", 100, |rng| {
        let dim = 8 + rng.next_range(200) as usize;
        let h = RandomizedHadamard::new(dim, rng.next_u64());
        let orig: Vec<f64> = (0..dim).map(|_| rng.next_gaussian()).collect();
        let mut v = orig.clone();
        h.forward(&mut v);
        let n0: f64 = orig.iter().map(|x| x * x).sum();
        let n1: f64 = v.iter().map(|x| x * x).sum();
        if (n0 - n1).abs() > 1e-8 * n0.max(1.0) {
            return Err(format!("norm not preserved: {n0} → {n1} (dim {dim})"));
        }
        h.inverse(&mut v);
        for (a, b) in v.iter().zip(&orig) {
            if (a - b).abs() > 1e-9 {
                return Err("inverse∘forward ≠ id".to_string());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_spd_solve_residual() {
    check("spd-solve", 60, |rng| {
        let n = 2 + rng.next_range(24) as usize;
        let mut g = Matrix::zeros(n, n);
        for v in g.data.iter_mut() {
            *v = rng.next_gaussian();
        }
        let mut a = g.transpose().matmul(&g);
        a.damp_diagonal(0.05);
        if cholesky(&a).is_err() {
            return Err("damped Gram matrix not SPD".into());
        }
        let b: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let x = solve_spd(&a, &b).map_err(|e| e)?;
        let r = a.matvec(&x);
        for (ri, bi) in r.iter().zip(&b) {
            if (ri - bi).abs() > 1e-6 {
                return Err(format!("residual too large: {}", (ri - bi).abs()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_product_code_roundtrip_any_length() {
    let q = UniformQuantizer::new_gaussian_optimal(8);
    check("product-roundtrip", 80, |rng| {
        let len = 1 + rng.next_range(96) as usize;
        let row: Vec<f32> = (0..len).map(|_| rng.next_gaussian() as f32 * 0.5).collect();
        let mut out = vec![0f32; len];
        product::quantize_row(&q, &row, &mut out);
        for (a, b) in row.iter().zip(&out) {
            if (a - b).abs() > 0.05 {
                return Err(format!("8-bit roundtrip error {} too large", (a - b).abs()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_llvq_spherical_quantize_is_idempotent() {
    let ix = Arc::new(LeechIndexer::new(3));
    let q = llvq::quant::llvq::LlvqSpherical::with_scale(ix, 0.9);
    check("llvq-idempotent", 60, |rng| {
        let mut x = [0f32; 24];
        rng.fill_gaussian_f32(&mut x);
        let mut y = [0f32; 24];
        let mut z = [0f32; 24];
        q.reconstruct(&x, &mut y);
        q.reconstruct(&y, &mut z);
        for (a, b) in y.iter().zip(&z) {
            if (a - b).abs() > 1e-6 {
                return Err("reconstruction not a fixed point".into());
            }
        }
        Ok(())
    });
}
