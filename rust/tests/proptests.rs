//! Property tests over the crate's core invariants (custom driver in
//! `util::proptest`; failing seeds are printed for reproduction).

use std::sync::Arc;

use llvq::golay::GolayCode;
use llvq::leech::decode::LeechDecoder;
use llvq::leech::index::{ms_perm_rank, ms_perm_unrank, LeechIndexer};
use llvq::leech::{coset, leaders};
use llvq::math::hadamard::RandomizedHadamard;
use llvq::math::linalg::{cholesky, solve_spd, Matrix};
use llvq::model::config::config_by_name;
use llvq::model::packed::{unpack_layer, PackedLayer, PackedModel};
use llvq::model::transformer::Weights;
use llvq::pipeline::driver::{quantize_model_packed, PtqOptions};
use llvq::pipeline::gptq::{quantize_layer, GptqConfig};
use llvq::pipeline::rotation::RotationMode;
use llvq::quant::e8::{E8Codebook, E8Cut};
use llvq::quant::gain::ChiGainQuantizer;
use llvq::quant::llvq::{LlvqShapeGain, LlvqSpherical};
use llvq::quant::product;
use llvq::quant::scalar::{LloydMaxQuantizer, UniformQuantizer};
use llvq::quant::{quantizer_from_spec, VectorQuantizer};
use llvq::util::bits::{BitReader, BitWriter};
use llvq::util::proptest::check;
use llvq::util::rng::Xoshiro256pp;

#[test]
fn prop_index_roundtrip_uniform_over_ball() {
    let ix = LeechIndexer::new(8);
    let n = ix.num_points() as u64;
    check("index-roundtrip-M8", 600, |rng| {
        let idx = rng.next_range(n);
        let x = ix.decode_index(idx);
        if !coset::is_lattice_point(ix.golay(), &x) {
            return Err(format!("decode({idx}) → non-lattice {x:?}"));
        }
        match ix.encode_point(&x) {
            Some(back) if back == idx => Ok(()),
            Some(back) => Err(format!("{idx} → {x:?} → {back}")),
            None => Err(format!("{idx} → {x:?} → encode failed")),
        }
    });
}

#[test]
fn prop_random_lattice_points_encode() {
    // build random lattice points CONSTRUCTIVELY (not via the indexer):
    // x = 2·(golay word) + 4·z, fixed up mod 8 — then encode must succeed
    // and decode back to the same point.
    let ix = LeechIndexer::new(10);
    let golay = GolayCode::new();
    check("constructive-points-encode", 300, |rng| {
        let c = golay.unrank(rng.next_range(4096) as u32);
        let mut x = [0i32; 24];
        for (i, v) in x.iter_mut().enumerate() {
            let z = (rng.next_range(3) as i32) - 1; // small multiples of 4
            *v = 4 * z + 2 * ((c >> i) & 1) as i32;
        }
        // repair Σ ≡ 0 (mod 8) by adjusting one coordinate by ±4
        let sum: i32 = x.iter().sum();
        if sum.rem_euclid(8) != 0 {
            x[0] += if sum.rem_euclid(8) == 4 { 4 } else { return Ok(()) };
        }
        if !coset::is_lattice_point(&golay, &x) {
            return Ok(()); // repair occasionally changes the Golay word; skip
        }
        let m = match coset::shell_of(&x) {
            Some(m) if (2..=10).contains(&m) => m,
            _ => return Ok(()), // outside the ball (or the origin) — skip
        };
        let idx = ix
            .encode_point(&x)
            .ok_or_else(|| format!("valid shell-{m} point failed to encode: {x:?}"))?;
        if ix.decode_index(idx) != x {
            return Err(format!("roundtrip mismatch for {x:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_ms_perm_rank_bijection() {
    check("ms-perm-rank", 300, |rng| {
        // random multiset over ≤4 symbols, length ≤ 12
        let k = 1 + rng.next_range(4) as usize;
        let mut mults: Vec<(u8, u8)> = (0..k)
            .map(|i| ((10 - 2 * i) as u8, 1 + rng.next_range(3) as u8))
            .collect();
        mults.sort_by(|a, b| b.0.cmp(&a.0));
        let total: u128 = {
            let len: usize = mults.iter().map(|&(_, c)| c as usize).sum();
            let mut t: u128 = (1..=len as u128).product();
            for &(_, c) in &mults {
                t /= (1..=c as u128).product::<u128>();
            }
            t
        };
        let r = rng.next_range(total.min(1_000_000) as u64) as u128;
        let mut seq = Vec::new();
        ms_perm_unrank(&mults, r, &mut seq);
        if ms_perm_rank(&seq) != r {
            return Err(format!("rank(unrank({r})) = {}", ms_perm_rank(&seq)));
        }
        Ok(())
    });
}

#[test]
fn prop_decoder_beats_random_lattice_points() {
    let golay = GolayCode::new();
    let dec = LeechDecoder::new(&golay);
    let ix = LeechIndexer::new(4);
    let n = ix.num_points() as u64;
    check("decoder-optimality-vs-sampling", 40, |rng| {
        let mut t = [0f64; 24];
        for v in t.iter_mut() {
            *v = rng.next_gaussian() * 5.0;
        }
        let out = dec.decode_infinite(&t);
        for _ in 0..50 {
            let p = ix.decode_index(rng.next_range(n));
            let d: f64 = p
                .iter()
                .zip(t.iter())
                .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
                .sum();
            if d < out.dist_sq - 1e-9 {
                return Err(format!("sampled point beats decoder: {d} < {}", out.dist_sq));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shell_class_sizes_factorization() {
    // eq. 12 invariant: every subclass size = A·2^B·arr_f1·arr_f0 and the
    // class sizes sum to the shell size for random shells ≤ 16
    let golay = GolayCode::new();
    let theta = llvq::leech::theta::shell_sizes(16);
    check("eq12-factorization", 8, |rng| {
        let m = 2 + rng.next_range(15) as usize;
        let s = leaders::enumerate_shell(&golay, m);
        let total: u128 = s.classes.iter().map(|c| c.size).sum();
        if total != theta[m] {
            return Err(format!("shell {m}: {total} != theta {}", theta[m]));
        }
        Ok(())
    });
}

#[test]
fn prop_hadamard_isometry_and_involution() {
    check("hadamard-isometry", 100, |rng| {
        let dim = 8 + rng.next_range(200) as usize;
        let h = RandomizedHadamard::new(dim, rng.next_u64());
        let orig: Vec<f64> = (0..dim).map(|_| rng.next_gaussian()).collect();
        let mut v = orig.clone();
        h.forward(&mut v);
        let n0: f64 = orig.iter().map(|x| x * x).sum();
        let n1: f64 = v.iter().map(|x| x * x).sum();
        if (n0 - n1).abs() > 1e-8 * n0.max(1.0) {
            return Err(format!("norm not preserved: {n0} → {n1} (dim {dim})"));
        }
        h.inverse(&mut v);
        for (a, b) in v.iter().zip(&orig) {
            if (a - b).abs() > 1e-9 {
                return Err("inverse∘forward ≠ id".to_string());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_spd_solve_residual() {
    check("spd-solve", 60, |rng| {
        let n = 2 + rng.next_range(24) as usize;
        let mut g = Matrix::zeros(n, n);
        for v in g.data.iter_mut() {
            *v = rng.next_gaussian();
        }
        let mut a = g.transpose().matmul(&g);
        a.damp_diagonal(0.05);
        if cholesky(&a).is_err() {
            return Err("damped Gram matrix not SPD".into());
        }
        let b: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let x = solve_spd(&a, &b)?;
        let r = a.matvec(&x);
        for (ri, bi) in r.iter().zip(&b) {
            if (ri - bi).abs() > 1e-6 {
                return Err(format!("residual too large: {}", (ri - bi).abs()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_product_code_roundtrip_any_length() {
    let q = UniformQuantizer::new_gaussian_optimal(8);
    check("product-roundtrip", 80, |rng| {
        let len = 1 + rng.next_range(96) as usize;
        let row: Vec<f32> = (0..len).map(|_| rng.next_gaussian() as f32 * 0.5).collect();
        let mut out = vec![0f32; len];
        product::quantize_row(&q, &row, &mut out);
        for (a, b) in row.iter().zip(&out) {
            if (a - b).abs() > 0.05 {
                return Err(format!("8-bit roundtrip error {} too large", (a - b).abs()));
            }
        }
        Ok(())
    });
}

/// Shared codec property: for a random Gaussian block, `encode_into` →
/// `decode_from` must reproduce `dequantize` of the original code
/// bit-exactly, the stream must occupy exactly `code.bits` bits, and the
/// quantizer rebuilt from its own spec must decode the same stream to the
/// same floats (the `.llvqm` load-path contract).
fn codec_roundtrip_prop(
    q: &dyn VectorQuantizer,
    rebuilt: &dyn VectorQuantizer,
    rng: &mut Xoshiro256pp,
) -> Result<(), String> {
    let d = q.dim();
    let mut x = vec![0f32; d];
    rng.fill_gaussian_f32(&mut x);
    let code = q.quantize(&x);
    let widths = q.code_widths();
    if widths.iter().sum::<u32>() != code.bits {
        return Err(format!(
            "{}: code_widths sum {} != code.bits {}",
            q.name(),
            widths.iter().sum::<u32>(),
            code.bits
        ));
    }
    let mut w = BitWriter::new();
    q.encode_into(&code, &mut w);
    if w.bit_len() != code.bits as usize {
        return Err(format!("{}: wrote {} of {} bits", q.name(), w.bit_len(), code.bits));
    }
    let bytes = w.finish();
    let mut want = vec![0f32; d];
    q.dequantize(&code, &mut want);
    let mut got = vec![0f32; d];
    q.decode_from(&mut BitReader::new(&bytes), &mut got);
    if got != want {
        return Err(format!("{}: bitstream roundtrip diverged", q.name()));
    }
    let mut got2 = vec![0f32; d];
    rebuilt.decode_from(&mut BitReader::new(&bytes), &mut got2);
    if got2 != want {
        return Err(format!("{}: spec-rebuilt quantizer diverged", q.name()));
    }
    Ok(())
}

#[test]
fn prop_codec_roundtrips_every_quantizer() {
    let ix = Arc::new(LeechIndexer::new(4));
    let quantizers: Vec<Box<dyn VectorQuantizer>> = vec![
        Box::new(UniformQuantizer::new_gaussian_optimal(2)),
        Box::new(UniformQuantizer::new_gaussian_optimal(7)),
        Box::new(LloydMaxQuantizer::train_gaussian(3, 60_000, 5)),
        Box::new(ChiGainQuantizer::new(24, 0)), // zero-bit degenerate field
        Box::new(ChiGainQuantizer::new(24, 3)),
        Box::new(E8Codebook::new(E8Cut::Ball)),
        Box::new(LlvqSpherical::with_scale(ix.clone(), 0.8)),
        Box::new(LlvqShapeGain::new(ix.clone(), 1)), // split shape/gain fields
        Box::new(LlvqShapeGain::new(ix, 0)),
    ];
    for q in &quantizers {
        let rebuilt = quantizer_from_spec(&q.spec())
            .unwrap_or_else(|e| panic!("{}: spec not loadable: {e}", q.name()));
        assert_eq!(rebuilt.dim(), q.dim());
        assert_eq!(rebuilt.code_widths(), q.code_widths(), "{}", q.name());
        check(&format!("codec-{}", q.name()), 40, |rng| {
            codec_roundtrip_prop(q.as_ref(), rebuilt.as_ref(), rng)
        });
    }
}

#[test]
fn prop_packed_layer_reproduces_gptq_reconstruction() {
    // layer-level contract: gptq's packed code streams, pushed through
    // model::packed::unpack_layer with the recorded σ, reproduce w_hat
    // bit-exactly — for a scalar and a true 24-dim lattice quantizer.
    let ix = Arc::new(LeechIndexer::new(3));
    let quantizers: Vec<Box<dyn VectorQuantizer>> = vec![
        Box::new(UniformQuantizer::new_gaussian_optimal(4)),
        Box::new(LlvqShapeGain::new(ix, 1)),
    ];
    for q in &quantizers {
        check(&format!("packed-layer-{}", q.name()), 4, |rng| {
            let (rows, cols) = (6, 48);
            let w: Vec<f32> = (0..rows * cols)
                .map(|_| rng.next_gaussian() as f32)
                .collect();
            let h = Matrix::identity(cols);
            let out = quantize_layer(&w, rows, cols, &h, q.as_ref(), &GptqConfig::default());
            let pl = PackedLayer {
                layer: 0,
                kind: llvq::model::transformer::LinearKind::Wq,
                rows,
                cols,
                sigma: out.sigma,
                rot_mode: RotationMode::None,
                rot_seed: 0,
                col_scales: None,
                codes: out.packed.clone(),
            };
            let rec = unpack_layer(q.as_ref(), &pl, 2)?;
            if rec != out.w_hat {
                return Err(format!("{}: unpack_layer != w_hat", q.name()));
            }
            Ok(())
        });
    }
}

#[test]
fn packed_model_write_read_unpack_is_bit_exact() {
    // whole-artifact contract (rotation + finetune scales on): the .llvqm
    // bytes round-trip and unpack to exactly the driver's reconstruction.
    let cfg = config_by_name("qwen3-4b-tiny").unwrap();
    let w = Weights::random(&cfg, 9);
    let q = UniformQuantizer::new_gaussian_optimal(3);
    let opts = PtqOptions {
        calib_seqs: 4,
        rotation: RotationMode::InputOutput,
        finetune_scales: true,
        ..Default::default()
    };
    let art = quantize_model_packed(&w, &q, &opts);
    let bytes = art.packed.to_bytes();
    let back = PackedModel::from_bytes(&bytes).unwrap();
    assert_eq!(back, art.packed);
    let unpacked = back.unpack(llvq::util::threadpool::default_threads()).unwrap();
    assert_eq!(
        llvq::model::io::to_bytes(&unpacked),
        llvq::model::io::to_bytes(&art.weights),
        "packed unpack does not reproduce the driver's weights"
    );
    // and the packed file is smaller than the dense artifact
    assert!(bytes.len() < llvq::model::io::to_bytes(&art.weights).len() / 2);
}

#[test]
fn prop_llvq_spherical_quantize_is_idempotent() {
    let ix = Arc::new(LeechIndexer::new(3));
    let q = llvq::quant::llvq::LlvqSpherical::with_scale(ix, 0.9);
    check("llvq-idempotent", 60, |rng| {
        let mut x = [0f32; 24];
        rng.fill_gaussian_f32(&mut x);
        let mut y = [0f32; 24];
        let mut z = [0f32; 24];
        q.reconstruct(&x, &mut y);
        q.reconstruct(&y, &mut z);
        for (a, b) in y.iter().zip(&z) {
            if (a - b).abs() > 1e-6 {
                return Err("reconstruction not a fixed point".into());
            }
        }
        Ok(())
    });
}
