//! Deterministic scheduler-simulator tier (`llvq::sim`): committed-trace
//! replay, the named scenario corpus with per-tick invariants,
//! bit-identical determinism across runs and kernel thread counts, the
//! kv-oom reserve/rollback adversarial scenario, and TCP-vs-simulator
//! equivalence on a scripted trace. Everything here runs on a virtual
//! clock — no sleeps, no wall-time assertions, nothing to flake.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use llvq::coordinator::{serve_tcp_opts, BackendEngine, BatcherConfig, Coordinator, ServeOptions};
use llvq::model::backend::ExecutionBackend;
use llvq::model::config::config_by_name;
use llvq::model::packed::PackedFile;
use llvq::model::sample::SampleParams;
use llvq::model::transformer::Weights;
use llvq::pipeline::driver::{quantize_model_packed, PtqOptions};
use llvq::pipeline::rotation::RotationMode;
use llvq::quant::scalar::UniformQuantizer;
use llvq::sim::harness::{SimReport, Simulator};
use llvq::sim::scenario::Scenario;
use llvq::sim::trace::{Action, EngineSpec, Trace};
use llvq::util::proptest::{with_silenced_panics, TempArtifact};

fn traces_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/sim_traces")
}

fn run_trace(trace: &Trace, max_ticks: u64) -> SimReport {
    let mut sim = Simulator::new(trace).expect("trace engine builds");
    sim.run_to_end(max_ticks)
}

fn stat<'a>(report: &'a SimReport, key: &str) -> &'a str {
    report
        .stats
        .split_whitespace()
        .find_map(|kv| kv.strip_prefix(&format!("{key}=")[..]))
        .unwrap_or_else(|| panic!("{key} missing from stats: {}", report.stats))
}

/// Committed failure traces replay first (the CI contract): every
/// `.trace` file under `rust/tests/sim_traces/` must run clean and
/// byte-identically twice.
#[test]
fn committed_traces_replay_deterministically() {
    let dir = traces_dir();
    let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("read {}: {e}", dir.display()))
        .map(|e| e.expect("dir entry").path())
        .filter(|p| p.extension().is_some_and(|x| x == "trace"))
        .collect();
    paths.sort();
    assert!(!paths.is_empty(), "no committed traces in {}", dir.display());
    for path in paths {
        let trace = Trace::load(&path).unwrap_or_else(|e| panic!("{e}"));
        let a = with_silenced_panics(|| run_trace(&trace, 500));
        let b = with_silenced_panics(|| run_trace(&trace, 500));
        assert!(
            a.ok(),
            "{}: replay violated an invariant: {:?}\nlog:\n{}",
            path.display(),
            a.violation,
            a.log_text()
        );
        assert_eq!(
            a.log_text(),
            b.log_text(),
            "{}: two replays diverged",
            path.display()
        );
        assert_eq!(a.stats, b.stats, "{}: final metrics diverged", path.display());
        assert_eq!(a.fingerprint(), b.fingerprint());
        // a committed trace also round-trips through its own text form
        let reparsed = Trace::parse(&trace.to_text()).expect("canonical form parses");
        let c = with_silenced_panics(|| run_trace(&reparsed, 500));
        assert_eq!(a.fingerprint(), c.fingerprint(), "{}: canonical-form replay diverged", path.display());
    }
}

/// The trace text format round-trips every action kind.
#[test]
fn trace_text_roundtrip_covers_every_action() {
    let mut t = Trace::new(
        BatcherConfig {
            max_batch: 3,
            max_wait: Duration::from_millis(5),
            max_sessions: 6,
            prefill_chunk: 2,
        },
        EngineSpec::Paged {
            seed: 123,
            pages: 7,
            page_tokens: 3,
            hot_window: 6,
            quant: llvq::model::kvpage::KvQuantKind::E8,
        },
    );
    t.push(0, 1, Action::Open);
    t.push(0, 1, Action::Feed(vec![1, 2, 63]));
    t.push(
        1,
        1,
        Action::Gen {
            n: 4,
            params: SampleParams {
                temperature: 0.85,
                top_k: 8,
                seed: 42,
            },
        },
    );
    t.push(2, 2, Action::Next(vec![5, 6]));
    t.push(2, 2, Action::Stats);
    t.push(3, 0, Action::Panic { calls: 2 });
    t.push(9, 1, Action::Close);
    t.push(10, 2, Action::Disconnect);
    let text = t.to_text();
    let back = Trace::parse(&text).expect("canonical text parses");
    assert_eq!(back.events, t.events, "events did not survive the round-trip");
    assert_eq!(back.to_text(), text, "canonical form is not a fixed point");
    let b = back.setup.batcher;
    assert_eq!(
        (b.max_batch, b.max_wait, b.max_sessions, b.prefill_chunk),
        (3, Duration::from_millis(5), 6, 2)
    );
    assert_eq!(back.setup.engine, t.setup.engine);
}

/// Every named scenario runs its per-tick invariants clean, quiesces,
/// reclaims every session, and really exercises the scheduler.
#[test]
fn scenario_corpus_passes_per_tick_invariants() {
    for sc in Scenario::ALL {
        let trace = sc.trace(1);
        let report = with_silenced_panics(|| run_trace(&trace, sc.max_ticks()));
        assert!(
            report.ok(),
            "{}: {:?}\nlog:\n{}",
            sc.name(),
            report.violation,
            report.log_text()
        );
        assert_eq!(stat(&report, "sessions"), "0", "{}: session leaked", sc.name());
        let prefill: u64 = stat(&report, "prefill_toks").parse().unwrap();
        assert!(prefill > 0, "{}: no prefill work ran", sc.name());
        if !matches!(sc, Scenario::KvOomThrash) {
            // kv-oom-thrash legitimately aborts some streams; everyone
            // else must stream real tokens
            let gen: u64 = stat(&report, "gen_tokens").parse().unwrap();
            assert!(gen > 0, "{}: no tokens generated", sc.name());
        }
    }
}

/// Same seed + scenario ⇒ bit-identical event log and final metrics,
/// run after run (the determinism contract the tentpole is named for).
#[test]
fn same_seed_replays_bit_identically() {
    for sc in Scenario::ALL {
        for seed in [1u64, 7] {
            let a = with_silenced_panics(|| run_trace(&sc.trace(seed), sc.max_ticks()));
            let b = with_silenced_panics(|| run_trace(&sc.trace(seed), sc.max_ticks()));
            assert_eq!(
                a.log_text(),
                b.log_text(),
                "{} seed {seed}: logs diverged across runs",
                sc.name()
            );
            assert_eq!(a.stats, b.stats, "{} seed {seed}: final metrics diverged", sc.name());
        }
        // different seeds must actually vary the workload (the corpus is
        // seeded, not constant)
        let a = with_silenced_panics(|| run_trace(&sc.trace(1), sc.max_ticks()));
        let b = with_silenced_panics(|| run_trace(&sc.trace(7), sc.max_ticks()));
        assert_ne!(
            a.fingerprint(),
            b.fingerprint(),
            "{}: seeds 1 and 7 produced identical runs",
            sc.name()
        );
    }
}

/// The simulator log is invariant across kernel thread counts: the same
/// trace over the fused backend at 1 and 4 threads is bit-identical
/// (the kernels pin `threads=N ≡ threads=1`; the virtual clock removes
/// every other timing source). `threads=` differs in STATS by design,
/// so only the log and the thread-free counters are compared.
#[test]
fn fused_backend_thread_counts_replay_bit_identically() {
    let cfg = config_by_name("qwen3-4b-tiny").unwrap();
    let w = Weights::random(&cfg, 4242);
    let q = UniformQuantizer::new_gaussian_optimal(4);
    let opts = PtqOptions {
        calib_seqs: 2,
        rotation: RotationMode::Input,
        ..Default::default()
    };
    let art = quantize_model_packed(&w, &q, &opts);
    let tmp = TempArtifact::new("sim-fused", "llvqm");
    art.packed.save(tmp.path()).unwrap();
    let trace = Scenario::Burst.trace(3);
    let mut logs = Vec::new();
    for threads in [1usize, 4] {
        let backend =
            ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), threads).unwrap();
        let engine: Arc<dyn llvq::coordinator::BatchForward> =
            Arc::new(BackendEngine::new(backend));
        let mut sim = Simulator::with_engine(engine, &trace);
        let report = sim.run_to_end(Scenario::Burst.max_ticks());
        assert!(report.ok(), "threads={threads}: {:?}", report.violation);
        logs.push((report.log_text(), report.conn_tokens.clone()));
    }
    assert_eq!(logs[0].0, logs[1].0, "fused t1 vs t4: reply logs diverged");
    assert_eq!(logs[0].1, logs[1].1, "fused t1 vs t4: token streams diverged");
}

/// The adversarial kv-oom scenario, asserted in detail: a refused
/// admission answers `ERR kv-oom` but never destroys the session (the
/// same connection retries and generates), and after the storm every
/// page is back in the arena.
#[test]
fn kv_oom_reserve_rollback_keeps_sessions_and_drains_pages() {
    let sc = Scenario::KvOomThrash;
    let report = run_trace(&sc.trace(1), sc.max_ticks());
    assert!(report.ok(), "{:?}\nlog:\n{}", report.violation, report.log_text());
    // at least two refusals: conn 4's first FEED and conn 5's 20-token FEED
    let oom: u64 = stat(&report, "kv_oom").parse().unwrap();
    assert!(oom >= 2, "expected >= 2 kv-oom refusals, got {oom}");
    // every page drained back to the arena, every session slot reclaimed
    assert_eq!(stat(&report, "kv_pages"), "0/6", "arena did not drain");
    assert_eq!(stat(&report, "sessions"), "0");
    // conn 4's session survived its refused FEED: same sid retries to a
    // QUEUED and then streams both requested tokens
    let c4 = &report.conn_replies[&4];
    assert!(
        c4.iter().any(|l| l.starts_with("ERR kv-oom")),
        "conn 4 never hit kv-oom: {c4:?}"
    );
    let oom_at = c4.iter().position(|l| l.starts_with("ERR kv-oom")).unwrap();
    assert!(
        c4[oom_at + 1..].iter().any(|l| l.starts_with("QUEUED ")),
        "conn 4's retry after kv-oom was not queued: {c4:?}"
    );
    assert_eq!(report.conn_tokens[&4].len(), 2, "conn 4 lost generated tokens");
    // conn 5 equally: refused once, then feeds and generates
    let c5 = &report.conn_replies[&5];
    assert!(c5.iter().any(|l| l.starts_with("ERR kv-oom")), "conn 5: {c5:?}");
    assert_eq!(report.conn_tokens[&5].len(), 1, "conn 5 lost its token");
}

/// The TCP front-end and the simulator are two drivers of one
/// [`SchedulerCore`]: the same scripted session over real sockets
/// produces the same per-connection reply lines (greedy tokens
/// included) and the same timing-invariant final counters.
#[test]
fn tcp_path_matches_simulator_on_scripted_trace() {
    let cfg_batch = BatcherConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        max_sessions: 8,
        prefill_chunk: 4,
    };
    let spec = EngineSpec::Dense { seed: 9 };

    // the scripted run: one v2 session plus a v1 client
    let mut trace = Trace::new(cfg_batch, spec);
    trace.push(0, 1, Action::Open);
    trace.push(0, 1, Action::Feed(vec![5, 6, 7, 8, 9, 10]));
    trace.push(
        1,
        1,
        Action::Gen {
            n: 3,
            params: SampleParams::default(),
        },
    );
    trace.push(2, 2, Action::Next(vec![5, 6]));
    trace.push(3, 2, Action::Next(vec![5, 6, 7]));
    trace.push(30, 1, Action::Close);
    let mut sim = Simulator::new(&trace).unwrap();
    let sim_report = sim.run_to_end(200);
    assert!(sim_report.ok(), "{:?}", sim_report.violation);

    // the same script over real sockets against the worker thread
    let coord = Coordinator::start(spec.build().unwrap(), cfg_batch);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let c2 = coord.clone();
    std::thread::spawn(move || {
        let _ = serve_tcp_opts(c2, listener, ServeOptions { max_conns: 4 });
    });
    let round = |cmds: &[&str]| -> Vec<String> {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut replies = Vec::new();
        for cmd in cmds {
            writeln!(s, "{cmd}").unwrap();
            loop {
                let mut line = String::new();
                r.read_line(&mut line).unwrap();
                let line = line.trim().to_string();
                let streaming = line.starts_with("TOK ");
                replies.push(line);
                if !streaming {
                    break;
                }
            }
        }
        writeln!(s, "QUIT").unwrap();
        replies
    };
    // connection order mirrors the trace's sid-assignment order
    let tcp_c1 = round(&["OPEN", "FEED 5,6,7,8,9,10", "GEN 3", "CLOSE"]);
    let tcp_c2 = round(&["NEXT 5,6", "NEXT 5,6,7"]);
    assert_eq!(
        sim_report.conn_replies[&1], tcp_c1,
        "v2 session: TCP and simulator replies diverged"
    );
    assert_eq!(
        sim_report.conn_replies[&2], tcp_c2,
        "v1 client: TCP and simulator replies diverged"
    );

    // timing-invariant final counters agree (batching shape and latency
    // are timing artifacts, so they are deliberately excluded)
    coord.stop();
    let m = &coord.metrics;
    use std::sync::atomic::Ordering;
    for (key, tcp_value) in [
        ("requests", m.requests.load(Ordering::Relaxed)),
        ("sessions", m.open_sessions.load(Ordering::Relaxed)),
        ("gen_tokens", m.gen_tokens.load(Ordering::Relaxed)),
        ("prefill_jobs", m.prefill_jobs.load(Ordering::Relaxed)),
        ("prefill_toks", m.prefill_toks.load(Ordering::Relaxed)),
    ] {
        assert_eq!(
            stat(&sim_report, key),
            tcp_value.to_string(),
            "{key}: TCP and simulator final counters diverged"
        );
    }
}

/// The step-through dump exposes queue/slate occupancy and formats its
/// stats line through the same `Metrics::snapshot` as the TCP `STATS`
/// reply (the shared-formatter satellite, asserted from the sim side).
#[test]
fn dump_shows_occupancy_and_shared_stats_line() {
    let mut trace = Trace::new(
        BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            max_sessions: 4,
            prefill_chunk: 2,
        },
        EngineSpec::Dense { seed: 9 },
    );
    trace.push(0, 1, Action::Open);
    trace.push(0, 1, Action::Feed(vec![1, 2, 3, 4, 5, 6]));
    let mut sim = Simulator::new(&trace).unwrap();
    sim.step();
    let dump = sim.dump();
    assert!(dump.starts_with("t=1 "), "tick stamp missing: {dump}");
    assert!(dump.contains("prefill=[1:"), "prefill job missing: {dump}");
    let stats_line = dump.lines().nth(1).expect("two-line dump");
    assert!(stats_line.starts_with("stats: requests="), "{dump}");
    assert!(
        stats_line.ends_with(&format!(
            "resident_bytes={}",
            sim.core().engine().resident_weight_bytes()
        )),
        "resident_bytes not last: {dump}"
    );
    // drive it to quiescence so the harness invariants get a full pass
    let report = sim.run_to_end(100);
    // the un-closed session is still parked — the scripted client never
    // closed it, and the simulator must not leak or invent a close
    assert_eq!(stat(&report, "sessions"), "1");
    assert!(report.ok(), "{:?}", report.violation);
}
