//! SIMD kernel tier: forced-selection coverage and scalar-oracle parity.
//!
//! The dispatch contract (documented in `quant::kernel`): the dequant
//! stage of every kernel is **bit-exact** vs the scalar path — grouped
//! `decode_blocks_into` overrides stream the same bit fields through the
//! same arithmetic expressions as `dequantize` — while the dot stage uses
//! a fixed 4-wide partial-sum shape, so vector kernels agree with the
//! scalar oracle to ≤ 1e-5 *relative* (FMA vs split rounding), are
//! bit-identical across reruns / thread counts / lane counts, and
//! `Kernel::Scalar` *is* the oracle (bit-identical delegation). Pinned
//! here across all five quantizer specs, at the code-stream level and
//! through whole forward passes of forced-kernel backends.

use std::sync::Arc;

use llvq::leech::index::LeechIndexer;
use llvq::model::backend::ExecutionBackend;
use llvq::model::config::config_by_name;
use llvq::model::packed::PackedFile;
use llvq::model::transformer::{forward, ActivationCapture, Weights};
use llvq::pipeline::driver::{quantize_model_packed, PtqArtifacts, PtqOptions};
use llvq::pipeline::rotation::RotationMode;
use llvq::quant::e8::{E8Codebook, E8Cut};
use llvq::quant::kernel::{decode_row_dot_multi_kernel, Kernel, KernelScratch};
use llvq::quant::llvq::{LlvqShapeGain, LlvqSpherical};
use llvq::quant::product::encode_row_into;
use llvq::quant::scalar::{LloydMaxQuantizer, UniformQuantizer};
use llvq::quant::{Code, VectorQuantizer};
use llvq::util::bits::{BitReader, BitWriter};
use llvq::util::proptest::{check, TempArtifact};

/// The five quantizer specs of the `.llvqm` codec surface (scalar uniform,
/// scalar Lloyd–Max, E8, LLVQ spherical, LLVQ shape–gain).
fn five_quantizers() -> Vec<(&'static str, Box<dyn VectorQuantizer>)> {
    let ix = Arc::new(LeechIndexer::new(3));
    vec![
        (
            "uniform",
            Box::new(UniformQuantizer::new_gaussian_optimal(4)) as Box<dyn VectorQuantizer>,
        ),
        (
            "lloyd-max",
            Box::new(LloydMaxQuantizer::train_gaussian(3, 40_000, 4)),
        ),
        ("e8", Box::new(E8Codebook::new(E8Cut::Ball))),
        (
            "llvq-spherical",
            Box::new(LlvqSpherical::with_scale(ix.clone(), 0.9)),
        ),
        ("llvq-shape-gain", Box::new(LlvqShapeGain::new(ix, 1))),
    ]
}

/// Every kernel the current host can actually run (scalar always first).
fn available_kernels() -> Vec<Kernel> {
    [Kernel::Scalar, Kernel::Avx2, Kernel::Neon, Kernel::Portable]
        .into_iter()
        .filter(Kernel::available)
        .collect()
}

/// PTQ the padding-exercising tiny config into a packed artifact.
fn pack_tiny(q: &dyn VectorQuantizer, seed: u64, finetune: bool) -> PtqArtifacts {
    let cfg = config_by_name("qwen3-4b-tiny").unwrap();
    let w = Weights::random(&cfg, seed);
    let opts = PtqOptions {
        calib_seqs: 2,
        finetune_scales: finetune,
        rotation: RotationMode::InputOutput,
        ..Default::default()
    };
    quantize_model_packed(&w, q, &opts)
}

fn save_temp(art: &PtqArtifacts, tag: &str) -> TempArtifact {
    let tmp = TempArtifact::new(&format!("kernels-{tag}"), "llvqm");
    art.packed.save(tmp.path()).unwrap();
    tmp
}

fn argmax(row: &[f32]) -> usize {
    let mut best = (0usize, f32::NEG_INFINITY);
    for (i, &v) in row.iter().enumerate() {
        if v > best.1 {
            best = (i, v);
        }
    }
    best.0
}

#[test]
fn prop_every_kernel_matches_the_scalar_oracle_across_specs() {
    // code-stream level: random rows through encode_row_into, decoded by
    // decode_row_dot_multi_kernel under every available kernel. Scalar is
    // bit-identical to the trait oracle; vector kernels are ≤ 1e-5
    // relative, rerun-bit-identical, and each lane of a multi-lane pass
    // is bit-identical to a single-lane pass of the same kernel.
    for (name, q) in five_quantizers() {
        let q = q.as_ref();
        let widths = q.code_widths();
        check(&format!("kernel-oracle-{name}"), 3, |rng| {
            // cols crosses segment (192) and block boundaries, with tails
            let cols = 1 + rng.next_range(400) as usize;
            let mut row = vec![0f32; cols];
            rng.fill_gaussian_f32(&mut row);
            let mut w = BitWriter::new();
            encode_row_into(q, &row, &mut w);
            let bytes = w.finish();
            let n = 3usize;
            let mut xs = vec![0f64; n * cols];
            rng.fill_gaussian_f64(&mut xs);

            let mut want = vec![0f64; n];
            let mut code = Code::empty();
            let mut block = vec![0f32; q.dim()];
            q.decode_row_dot_multi(
                &widths,
                &mut BitReader::new(&bytes),
                &mut code,
                &mut block,
                &xs,
                cols,
                &mut want,
            );
            for kind in available_kernels() {
                let mut s = KernelScratch::default();
                let mut got = vec![0f64; n];
                decode_row_dot_multi_kernel(
                    q,
                    kind,
                    &widths,
                    &mut BitReader::new(&bytes),
                    &mut s,
                    &xs,
                    cols,
                    &mut got,
                );
                for (lane, (a, b)) in want.iter().zip(&got).enumerate() {
                    if kind == Kernel::Scalar {
                        if a.to_bits() != b.to_bits() {
                            return Err(format!(
                                "{name}: Scalar kind is not the oracle (lane {lane})"
                            ));
                        }
                    } else {
                        let tol = 1e-5 * a.abs().max(1.0);
                        if (a - b).abs() > tol {
                            return Err(format!(
                                "{name}/{kind:?} cols={cols} lane {lane}: {a} vs {b}"
                            ));
                        }
                    }
                }
                // reruns are bit-identical (no hidden state in dispatch)
                let mut again = vec![0f64; n];
                decode_row_dot_multi_kernel(
                    q,
                    kind,
                    &widths,
                    &mut BitReader::new(&bytes),
                    &mut s,
                    &xs,
                    cols,
                    &mut again,
                );
                if got.iter().zip(&again).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err(format!("{name}/{kind:?}: rerun not bit-identical"));
                }
                // each lane equals a fresh single-lane pass (lane-count
                // invariance of the partial-sum shape)
                for lane in 0..n {
                    let mut solo = vec![0f64; 1];
                    let mut s1 = KernelScratch::default();
                    decode_row_dot_multi_kernel(
                        q,
                        kind,
                        &widths,
                        &mut BitReader::new(&bytes),
                        &mut s1,
                        &xs[lane * cols..(lane + 1) * cols],
                        cols,
                        &mut solo,
                    );
                    if solo[0].to_bits() != got[lane].to_bits() {
                        return Err(format!(
                            "{name}/{kind:?}: lane {lane} differs from single-lane pass"
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}

#[test]
fn prop_grouped_block_decode_is_bit_exact_across_specs() {
    // the dequant-stage half of the contract: decode_blocks_into (and its
    // streaming overrides in every quantizer) reproduces one-block-at-a-
    // time decode_from_with bit for bit, partial tail blocks included.
    for (name, q) in five_quantizers() {
        let q = q.as_ref();
        let d = q.dim();
        let widths = q.code_widths();
        check(&format!("kernel-grouped-decode-{name}"), 4, |rng| {
            let cols = 1 + rng.next_range(300) as usize;
            let mut row = vec![0f32; cols];
            rng.fill_gaussian_f32(&mut row);
            let mut w = BitWriter::new();
            encode_row_into(q, &row, &mut w);
            let bytes = w.finish();

            let mut code = Code::empty();
            let mut block = vec![0f32; d];
            let mut per_block = vec![0f32; cols];
            let mut r = BitReader::new(&bytes);
            let mut i = 0;
            while i < cols {
                q.decode_from_with(&widths, &mut r, &mut code, &mut block);
                let take = d.min(cols - i);
                per_block[i..i + take].copy_from_slice(&block[..take]);
                i += take;
            }

            let mut grouped = vec![0f32; cols];
            q.decode_blocks_into(
                &widths,
                &mut BitReader::new(&bytes),
                &mut code,
                &mut block,
                &mut grouped,
            );
            if per_block
                .iter()
                .zip(&grouped)
                .any(|(a, b)| a.to_bits() != b.to_bits())
            {
                return Err(format!("{name}: grouped decode not bit-exact (cols={cols})"));
            }
            Ok(())
        });
    }
}

#[test]
fn forced_scalar_backend_matches_auto_kernel_forward_pass() {
    // backend level, two specs to bound runtime: a forced-scalar fused
    // backend vs the auto-detected kernel over whole forward passes must
    // agree to ≤ 1e-5 relative with identical argmax. On hosts where
    // detection lands on scalar this degenerates to bit-equality — the
    // forced-scalar leg itself runs everywhere (the CI scalar-fallback
    // matrix leg relies on that).
    let ix = Arc::new(LeechIndexer::new(3));
    let specs: Vec<(&str, Box<dyn VectorQuantizer>)> = vec![
        (
            "uniform",
            Box::new(UniformQuantizer::new_gaussian_optimal(4)),
        ),
        ("llvq-shape-gain", Box::new(LlvqShapeGain::new(ix, 1))),
    ];
    let auto = Kernel::detect();
    for (i, (name, q)) in specs.into_iter().enumerate() {
        let art = pack_tiny(q.as_ref(), 900 + i as u64, i % 2 == 0);
        let tmp = save_temp(&art, name);
        let scalar = ExecutionBackend::packed_fused_kernel(
            PackedFile::open(tmp.path()).unwrap(),
            2,
            Kernel::Scalar,
        )
        .unwrap();
        assert_eq!(scalar.simd(), Kernel::Scalar);
        let vectored =
            ExecutionBackend::packed_fused_kernel(PackedFile::open(tmp.path()).unwrap(), 2, auto)
                .unwrap();
        assert_eq!(vectored.simd(), auto);
        let vocab = art.weights.cfg.vocab;
        check(&format!("kernel-backend-{name}"), 3, |rng| {
            let len = 1 + rng.next_range(10) as usize;
            let toks: Vec<u8> = (0..len).map(|_| rng.next_range(64) as u8).collect();
            let mut cap = ActivationCapture::default();
            let s = forward(&scalar, &toks, &mut cap);
            let v = forward(&vectored, &toks, &mut cap);
            let linf = s.iter().fold(0f32, |a, &b| a.max(b.abs()));
            let tol = 1e-5 * linf.max(1.0);
            for (a, b) in s.iter().zip(&v) {
                if (a - b).abs() > tol {
                    return Err(format!(
                        "{name}: {} kernel drifted {} > {tol} from scalar",
                        auto.label(),
                        (a - b).abs()
                    ));
                }
            }
            for p in 0..len {
                let sl = &s[p * vocab..(p + 1) * vocab];
                let vl = &v[p * vocab..(p + 1) * vocab];
                if argmax(sl) != argmax(vl) {
                    return Err(format!("{name}: argmax parity lost at position {p}"));
                }
            }
            Ok(())
        });
    }
}

#[test]
fn forced_kernels_are_thread_count_invariant() {
    // segment boundaries depend only on dim and cols, and the pool shards
    // whole rows — so for a *fixed* kernel the pool size must not change a
    // single bit. Checked for every kernel the host can run.
    let q = E8Codebook::new(E8Cut::Ball);
    let art = pack_tiny(&q, 77, true);
    let tmp = save_temp(&art, "threads");
    for kind in available_kernels() {
        let b1 = ExecutionBackend::packed_fused_kernel(
            PackedFile::open(tmp.path()).unwrap(),
            1,
            kind,
        )
        .unwrap();
        let b4 = ExecutionBackend::packed_fused_kernel(
            PackedFile::open(tmp.path()).unwrap(),
            4,
            kind,
        )
        .unwrap();
        let toks: Vec<u8> = (0..9).map(|i| (i * 7 % 64) as u8).collect();
        let mut cap = ActivationCapture::default();
        let l1 = forward(&b1, &toks, &mut cap);
        let l4 = forward(&b4, &toks, &mut cap);
        assert!(
            l1.iter().zip(&l4).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{kind:?}: threads=4 diverged from threads=1"
        );
    }
}

#[test]
fn unavailable_kernels_are_rejected_not_silently_downgraded() {
    let q = UniformQuantizer::new_gaussian_optimal(4);
    let art = pack_tiny(&q, 5, false);
    let tmp = save_temp(&art, "reject");
    for kind in [Kernel::Avx2, Kernel::Neon, Kernel::Portable] {
        if kind.available() {
            continue;
        }
        let err = ExecutionBackend::packed_fused_kernel(
            PackedFile::open(tmp.path()).unwrap(),
            1,
            kind,
        )
        .unwrap_err();
        assert!(err.contains(kind.label()), "{err}");
        assert!(Kernel::resolve(kind.label()).is_err());
    }
    // and the string-level override surface agrees with programmatic force
    assert_eq!(Kernel::resolve("scalar").unwrap(), Kernel::Scalar);
    assert_eq!(Kernel::resolve("off").unwrap(), Kernel::Scalar);
    assert!(Kernel::resolve("not-a-kernel").is_err());
}
