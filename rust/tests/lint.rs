//! Lint-engine tests: every rule proven by a fixture it must flag and a
//! fixture it must pass, the self-check that the repo's own tree is
//! lint-clean, and determinism of the JSON report.
//!
//! Fixtures live under `lint_fixtures/` on disk (the engine's walker
//! skips that directory — they are deliberately dirty) and are fed to
//! the pure [`lint_files`] entry point under *virtual* repo paths, so a
//! single snippet can be tested as a serving module, a test file, or the
//! coordinator.

use llvq::lint::engine::{
    collect_inputs, lint_files, lint_files_with_docs, render_json, render_text, run_lint,
};
use llvq::lint::rules::{
    Finding, ALLOW_SYNTAX, DOCS_SYNC, LOCK_POISON, NO_PANIC_SERVING, SAFETY_COMMENT,
    STATS_WIRE_ORDER, TARGET_FEATURE_UNSAFE,
};
use std::path::Path;

const SAFETY_BAD: &str = include_str!("lint_fixtures/safety_bad.rs");
const SAFETY_OK: &str = include_str!("lint_fixtures/safety_ok.rs");
const PANIC_BAD: &str = include_str!("lint_fixtures/panic_bad.rs");
const PANIC_OK: &str = include_str!("lint_fixtures/panic_ok.rs");
const LOCK_BAD: &str = include_str!("lint_fixtures/lock_bad.rs");
const LOCK_OK: &str = include_str!("lint_fixtures/lock_ok.rs");
const TF_BAD: &str = include_str!("lint_fixtures/tf_bad.rs");
const TF_OK: &str = include_str!("lint_fixtures/tf_ok.rs");
const STATS_BAD: &str = include_str!("lint_fixtures/stats_bad.rs");
const STATS_OK: &str = include_str!("lint_fixtures/stats_ok.rs");
const STATS_LINE_BAD: &str = include_str!("lint_fixtures/stats_line_bad.rs");
const ALLOW_BAD: &str = include_str!("lint_fixtures/allow_bad.rs");
const DOCS_PROTOCOL_OK: &str = include_str!("lint_fixtures/docs_protocol_ok.md");
const DOCS_PROTOCOL_BAD: &str = include_str!("lint_fixtures/docs_protocol_bad.md");
const DOCS_OPERATIONS_OK: &str = include_str!("lint_fixtures/docs_operations_ok.md");
const DOCS_OPERATIONS_BAD: &str = include_str!("lint_fixtures/docs_operations_bad.md");
const DOCS_API_OK: &str = include_str!("lint_fixtures/docs_api_ok.rs");
const DOCS_API_BAD: &str = include_str!("lint_fixtures/docs_api_bad.rs");

fn lint_one(path: &str, text: &str) -> Vec<Finding> {
    lint_files(&[(path.to_string(), text.to_string())])
}

/// Sorted lines at which `rule` fired.
fn lines_of(findings: &[Finding], rule: &str) -> Vec<usize> {
    let mut v: Vec<usize> = findings
        .iter()
        .filter(|f| f.rule == rule)
        .map(|f| f.line)
        .collect();
    v.sort_unstable();
    v
}

// ------------------------------------------------------------- rule 1

#[test]
fn safety_rule_flags_every_unjustified_site() {
    let f = lint_one("rust/src/model/fixture.rs", SAFETY_BAD);
    assert_eq!(
        lines_of(&f, SAFETY_COMMENT),
        vec![5, 10, 11, 16, 19],
        "block, unsafe fn, inner block, and both impls must all be flagged: {f:?}"
    );
}

#[test]
fn safety_rule_accepts_justified_sites_and_type_positions() {
    let f = lint_one("rust/src/model/fixture.rs", SAFETY_OK);
    assert!(
        f.is_empty(),
        "SAFETY comments, # Safety doc sections, trailing comments, and \
         fn-pointer types must all pass: {f:?}"
    );
}

// ------------------------------------------------------------- rule 2

#[test]
fn panic_rule_flags_serving_modules_only() {
    let serving = lint_one("rust/src/model/kvpage.rs", PANIC_BAD);
    assert_eq!(
        lines_of(&serving, NO_PANIC_SERVING),
        vec![5, 9, 16, 21, 25],
        "unwrap, expect, unreachable!, todo!, panic!: {serving:?}"
    );

    let library = lint_one("rust/src/leech/coset.rs", PANIC_BAD);
    assert_eq!(lines_of(&library, NO_PANIC_SERVING), Vec::<usize>::new());

    let test_file = lint_one("rust/tests/fixture.rs", PANIC_BAD);
    assert_eq!(lines_of(&test_file, NO_PANIC_SERVING), Vec::<usize>::new());
}

#[test]
fn panic_rule_accepts_results_allows_and_test_regions() {
    let f = lint_one("rust/src/model/kvpage.rs", PANIC_OK);
    assert!(
        f.is_empty(),
        "Result flow, a justified allow, and cfg(test) panics must pass: {f:?}"
    );
}

// ------------------------------------------------------------- rule 3

#[test]
fn lock_rule_flags_bare_unwrap_and_expect() {
    let f = lint_one("rust/src/pipeline/fixture.rs", LOCK_BAD);
    assert_eq!(
        lines_of(&f, LOCK_POISON),
        vec![6, 11],
        "same-line and split-across-lines bare locks: {f:?}"
    );
}

#[test]
fn lock_rule_accepts_poison_recovery_and_test_regions() {
    let f = lint_one("rust/src/pipeline/fixture.rs", LOCK_OK);
    assert!(f.is_empty(), "{f:?}");
}

// ------------------------------------------------------------- rule 4

#[test]
fn target_feature_rule_flags_safe_fn_and_foreign_module() {
    let in_kernel = lint_one("rust/src/quant/kernel.rs", TF_BAD);
    assert_eq!(
        lines_of(&in_kernel, TARGET_FEATURE_UNSAFE),
        vec![1, 5],
        "missing detection macro (file-level) + safe fn (attr line): {in_kernel:?}"
    );

    let foreign = lint_one("rust/src/math/linalg.rs", TF_BAD);
    assert_eq!(
        lines_of(&foreign, TARGET_FEATURE_UNSAFE),
        vec![5, 5],
        "safe fn + outside-dispatch-module, both at the attribute: {foreign:?}"
    );
}

#[test]
fn target_feature_rule_accepts_dispatched_unsafe_fn() {
    let f = lint_one("rust/src/quant/kernel.rs", TF_OK);
    assert!(f.is_empty(), "{f:?}");
}

// ------------------------------------------------------------- rule 5

#[test]
fn stats_rule_flags_order_drift_and_unknown_verbs() {
    let f = lint_one("rust/src/coordinator.rs", STATS_BAD);
    assert_eq!(
        lines_of(&f, STATS_WIRE_ORDER),
        vec![11, 11, 22, 22, 36],
        "doc row out of order + multi-field line out of order (11), \
         resident_bytes not last + kv counter behind threads (22), \
         unknown reply verb (36): {f:?}"
    );
}

#[test]
fn stats_rule_accepts_consistent_surface_and_flags_drifted_parser() {
    let clean = lint_one("rust/src/coordinator.rs", STATS_OK);
    assert!(clean.is_empty(), "{clean:?}");

    let pair = lint_files(&[
        ("rust/src/coordinator.rs".to_string(), STATS_OK.to_string()),
        ("rust/src/util/bench.rs".to_string(), STATS_LINE_BAD.to_string()),
    ]);
    assert_eq!(pair.len(), 1, "{pair:?}");
    assert_eq!(pair[0].rule, STATS_WIRE_ORDER);
    assert_eq!((pair[0].file.as_str(), pair[0].line), ("rust/src/util/bench.rs", 5));
}

// ------------------------------------------------------------- rule 6

#[test]
fn docs_rule_flags_missing_doc_files_only_when_docs_are_in_scope() {
    let src = [("rust/src/coordinator.rs".to_string(), STATS_OK.to_string())];
    // the pure entry point never sees docs — fixture-driven rule tests
    // stay byte-identical with or without a docs tree on disk
    assert!(lint_files(&src).is_empty());

    let f = lint_files_with_docs(&src, &[]);
    let missing: Vec<&str> = f
        .iter()
        .filter(|x| x.rule == DOCS_SYNC)
        .map(|x| x.file.as_str())
        .collect();
    assert_eq!(
        missing,
        vec!["docs/OPERATIONS.md", "docs/PROTOCOL.md"],
        "both reference docs must be demanded: {f:?}"
    );
}

#[test]
fn docs_rule_accepts_complete_references() {
    let src = [
        ("rust/src/coordinator.rs".to_string(), STATS_OK.to_string()),
        ("rust/src/http/api.rs".to_string(), DOCS_API_OK.to_string()),
    ];
    let docs = [
        ("docs/PROTOCOL.md".to_string(), DOCS_PROTOCOL_OK.to_string()),
        ("docs/OPERATIONS.md".to_string(), DOCS_OPERATIONS_OK.to_string()),
    ];
    let f = lint_files_with_docs(&src, &docs);
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn docs_rule_flags_verb_route_field_and_error_code_gaps() {
    let src = [("rust/src/coordinator.rs".to_string(), STATS_OK.to_string())];
    let docs = [
        ("docs/PROTOCOL.md".to_string(), DOCS_PROTOCOL_BAD.to_string()),
        ("docs/OPERATIONS.md".to_string(), DOCS_OPERATIONS_BAD.to_string()),
    ];
    let f = lint_files_with_docs(&src, &docs);
    assert_eq!(f.len(), 4, "{f:?}");
    assert!(f.iter().all(|x| x.rule == DOCS_SYNC));
    // REQUEUED / kv_pages_total are superstrings — word-boundary
    // matching must still demand the verb and the field themselves
    for needle in ["`QUEUED`", "`/metrics`", "`kv-oom`", "`kv_pages`"] {
        assert!(
            f.iter().any(|x| x.message.contains(needle)),
            "missing a finding about {needle}: {f:?}"
        );
    }
}

#[test]
fn docs_rule_pins_route_literals_in_the_http_front_door() {
    let clean = lint_one("rust/src/http/api.rs", DOCS_API_OK);
    assert!(clean.is_empty(), "{clean:?}");

    let f = lint_one("rust/src/http/api.rs", DOCS_API_BAD);
    assert_eq!(lines_of(&f, DOCS_SYNC), vec![1], "{f:?}");
    assert!(f[0].message.contains("`/metrics`"), "{f:?}");
}

// ----------------------------------------------------------- meta rule

#[test]
fn allow_rule_flags_bad_directives_without_suppressing() {
    let f = lint_one("rust/src/util/fixture.rs", ALLOW_BAD);
    assert_eq!(
        lines_of(&f, ALLOW_SYNTAX),
        vec![7, 12, 17],
        "unknown rule, missing reason, unterminated: {f:?}"
    );
    assert_eq!(
        lines_of(&f, LOCK_POISON),
        vec![8, 13, 18],
        "an invalid directive must not suppress the underlying finding: {f:?}"
    );
}

// ----------------------------------------------------- repo self-check

/// The committed tree is lint-clean — this is the same gate
/// `scripts/verify.sh` and CI's lint job apply via `llvq lint`.
#[test]
fn repo_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = run_lint(root, None).expect("walking the repo");
    assert!(
        findings.is_empty(),
        "the tree must pass its own lint gate:\n{}",
        render_text(&findings)
    );
}

#[test]
fn walker_skips_the_deliberately_dirty_fixtures() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let inputs = collect_inputs(root).expect("walking the repo");
    assert!(inputs.iter().any(|(p, _)| p == "rust/src/lint/engine.rs"));
    assert!(inputs.iter().any(|(p, _)| p == "rust/tests/lint.rs"));
    assert!(
        !inputs.iter().any(|(p, _)| p.contains("lint_fixtures")),
        "fixtures must never be linted as part of the tree"
    );
}

// ------------------------------------------------------- determinism

#[test]
fn json_report_is_deterministic_and_order_independent() {
    let a = vec![
        ("rust/src/coordinator.rs".to_string(), STATS_BAD.to_string()),
        ("rust/src/model/kvpage.rs".to_string(), PANIC_BAD.to_string()),
    ];
    let b: Vec<(String, String)> = a.iter().rev().cloned().collect();
    let fa = lint_files(&a);
    let fb = lint_files(&b);
    assert_eq!(fa, fb, "input order must not change the report");
    assert_eq!(render_json(&fa), render_json(&fb));
    assert!(render_json(&fa).starts_with("{\"findings\":["));

    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let once = run_lint(root, None).expect("walking the repo");
    let twice = run_lint(root, None).expect("walking the repo");
    assert_eq!(render_json(&once), render_json(&twice));
}

#[test]
fn rule_filter_restricts_output() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let only = run_lint(root, Some(SAFETY_COMMENT)).expect("walking the repo");
    assert!(only.iter().all(|f| f.rule == SAFETY_COMMENT));
    assert!(run_lint(root, Some("no-such-rule")).is_err());
}
