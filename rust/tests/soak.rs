//! Scheduler soak/stress tier: many concurrent v2 sessions with mixed
//! long FEEDs and GENs over TCP, including mid-prefill and mid-GEN
//! disconnects, against the fused backend with a small `prefill_chunk`
//! (so every long prompt crosses many scheduler ticks).
//!
//! Assertions: no `ERR` on any well-formed command, every session's slot
//! is reclaimed (STATS drains to `sessions=0`), and `Coordinator::stop`
//! returns — a clean drain, not a hang. This tier keeps only what needs
//! real sockets and threads; the timing-sensitive scheduling bounds that
//! used to be sampled here in wall time (no per-token stall, no starved
//! prefill job, slate limits) are asserted deterministically every
//! virtual tick by the simulator tier (`rust/tests/sim.rs` over
//! `llvq::sim`), where they cannot flake on a loaded runner.
//!
//! The test is `#[ignore]`d: it runs in CI's dedicated soak job via
//! `cargo test --release --test soak -- --ignored` under an
//! `LLVQ_THREADS ∈ {1, 4}` matrix (the kernel pool reads that env var
//! through `threadpool::default_threads`), not in the tier-1 suite.
//!
//! `LLVQ_SOAK_KV_PAGES` > 0 switches the engine to paged KV sessions over
//! an arena of that many 4-token pages (`LLVQ_SOAK_KV_QUANT` picks the
//! cold-page codec). A small budget makes `ERR kv-oom` a *normal* answer
//! under the storm: clients retry it with backoff, and the final STATS
//! poll additionally asserts the arena drained to `kv_pages=0/…` — rude
//! disconnects and panics must return every page, not just the session
//! slot.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use llvq::coordinator::{serve_tcp_opts, BackendEngine, BatcherConfig, Coordinator, ServeOptions};
use llvq::model::backend::ExecutionBackend;
use llvq::model::config::config_by_name;
use llvq::model::kvpage::KvQuantKind;
use llvq::model::packed::PackedFile;
use llvq::model::transformer::Weights;
use llvq::pipeline::driver::{quantize_model_packed, PtqOptions};
use llvq::pipeline::rotation::RotationMode;
use llvq::quant::scalar::UniformQuantizer;
use llvq::util::proptest::TempArtifact;

/// Deadline for `ERR kv-oom` retries to clear (liveness only — the
/// per-token pacing bounds live in the deterministic simulator tier).
const STALL_LIMIT: Duration = Duration::from_secs(20);

fn read_line(r: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    line.trim().to_string()
}

/// One full client round; panics on any ERR. Returns streamed token
/// count.
fn client_round(addr: std::net::SocketAddr, seed: u64, feed_len: usize, gen_n: usize) -> usize {
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    writeln!(s, "OPEN").unwrap();
    let l = read_line(&mut r);
    assert!(l.starts_with("OK session="), "OPEN: {l}");
    // mixed chunked FEED: half the prompt, then the rest while the first
    // half's job may still be draining
    let toks: Vec<String> = (0..feed_len).map(|i| ((seed as usize + i) % 64).to_string()).collect();
    let split = feed_len / 2;
    for part in [&toks[..split], &toks[split..]] {
        if part.is_empty() {
            continue;
        }
        // under a small --kv-pages budget, kv-oom is a normal answer
        // while other sessions hold the arena: retry with backoff
        let oom_deadline = Instant::now() + STALL_LIMIT;
        loop {
            writeln!(s, "FEED {}", part.join(",")).unwrap();
            let l = read_line(&mut r);
            if l.starts_with("QUEUED ") {
                break;
            }
            assert!(l.starts_with("ERR kv-oom"), "FEED: {l}");
            assert!(Instant::now() < oom_deadline, "kv-oom never cleared: {l}");
            std::thread::sleep(Duration::from_millis(20));
        }
    }
    let oom_deadline = Instant::now() + STALL_LIMIT;
    writeln!(s, "GEN {gen_n} temp=0.8 topk=8 seed={seed}").unwrap();
    let mut got = 0usize;
    loop {
        let l = read_line(&mut r);
        if l.starts_with("ERR kv-oom") {
            // arena full at GEN admission: the session survived — retry
            assert_eq!(got, 0, "kv-oom after tokens streamed: {l}");
            assert!(Instant::now() < oom_deadline, "kv-oom never cleared: {l}");
            std::thread::sleep(Duration::from_millis(20));
            writeln!(s, "GEN {gen_n} temp=0.8 topk=8 seed={seed}").unwrap();
            continue;
        }
        if l.starts_with("TOK ") {
            got += 1;
        } else {
            assert!(l.starts_with(&format!("OK generated={gen_n}")), "GEN end: {l}");
            break;
        }
    }
    writeln!(s, "CLOSE").unwrap();
    let l = read_line(&mut r);
    assert!(l.starts_with("OK closed len="), "CLOSE: {l}");
    writeln!(s, "QUIT").unwrap();
    got
}

/// A client that walks away mid-flight: after FEED (mid-prefill) on even
/// seeds, after issuing GEN but before reading the stream on odd seeds.
fn rude_client(addr: std::net::SocketAddr, seed: u64) {
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    writeln!(s, "OPEN").unwrap();
    let l = read_line(&mut r);
    assert!(l.starts_with("OK session="), "OPEN: {l}");
    let toks: Vec<String> = (0..40).map(|i| ((seed as usize + i) % 64).to_string()).collect();
    writeln!(s, "FEED {}", toks.join(",")).unwrap();
    let l = read_line(&mut r);
    // a rude client under a small page budget may be refused — it walks
    // away either way, and either way no page may leak
    assert!(
        l.starts_with("QUEUED ") || l.starts_with("ERR kv-oom"),
        "FEED: {l}"
    );
    if seed % 2 == 1 {
        writeln!(s, "GEN 8 temp=0.9 seed={seed}").unwrap();
    }
    // drop without CLOSE/QUIT: the server must reclaim the session
}

#[test]
#[ignore = "soak tier: run via CI's soak job (cargo test --test soak -- --ignored)"]
fn soak_mixed_long_feeds_and_gens_over_tcp() {
    // fused backend so the LLVQ_THREADS matrix exercises the kernel pool
    // under the scheduler; UniformQuantizer keeps the one-time PTQ cheap
    let cfg = config_by_name("qwen3-4b-tiny").unwrap();
    let w = Weights::random(&cfg, 4242);
    let q = UniformQuantizer::new_gaussian_optimal(4);
    let opts = PtqOptions {
        calib_seqs: 2,
        rotation: RotationMode::Input,
        ..Default::default()
    };
    let art = quantize_model_packed(&w, &q, &opts);
    let tmp = TempArtifact::new("soak", "llvqm");
    art.packed.save(tmp.path()).unwrap();
    let threads = llvq::util::threadpool::default_threads();
    let fused =
        ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), threads).unwrap();
    println!("soak: fused backend, {threads} kernel threads (LLVQ_THREADS matrix)");

    // CI's paged-KV leg sets LLVQ_SOAK_KV_PAGES (and LLVQ_SOAK_KV_QUANT)
    // to run the same storm over a small shared page arena
    let kv_pages: usize = std::env::var("LLVQ_SOAK_KV_PAGES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let paged = kv_pages > 0;
    let engine = if paged {
        let quant = KvQuantKind::parse(
            &std::env::var("LLVQ_SOAK_KV_QUANT").unwrap_or_else(|_| "none".into()),
        )
        .unwrap();
        println!("soak: paged KV, {kv_pages} pages × 4 tokens, quant={}", quant.label());
        BackendEngine::paged(fused, kv_pages, 4, 8, quant).unwrap()
    } else {
        BackendEngine::new(fused)
    };
    let coord = Coordinator::start(
        Arc::new(engine),
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            max_sessions: 48,
            prefill_chunk: 4, // long FEEDs cross many ticks
        },
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let c2 = coord.clone();
    std::thread::spawn(move || {
        let _ = serve_tcp_opts(c2, listener, ServeOptions { max_conns: 48 });
    });

    let clients = 8usize;
    let rounds = 3usize;
    std::thread::scope(|sc| {
        for c in 0..clients {
            sc.spawn(move || {
                for round in 0..rounds {
                    let seed = (c * 100 + round) as u64;
                    // prompt length 16..=44, generation 4..=8 (≤ max_seq 64)
                    let feed_len = 16 + (seed as usize * 7) % 29;
                    let gen_n = 4 + (seed as usize) % 5;
                    let got = client_round(addr, seed, feed_len, gen_n);
                    assert_eq!(got, gen_n, "client {c} round {round} lost tokens");
                }
            });
        }
        // a rude cohort disconnecting mid-prefill / mid-GEN, concurrently
        for c in 0..4u64 {
            sc.spawn(move || rude_client(addr, c));
        }
    });

    // every slot must come back: disconnect cleanup is asynchronous, so
    // poll STATS until sessions=0 (bounded)
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut drained = false;
    while Instant::now() < deadline {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        writeln!(s, "STATS").unwrap();
        let l = read_line(&mut r);
        assert!(l.starts_with("OK "), "STATS: {l}");
        writeln!(s, "QUIT").unwrap();
        if l.split_whitespace().any(|kv| kv == "sessions=0") {
            drained = true;
            // the scheduler really ran chunked prefill work
            let toks: u64 = l
                .split_whitespace()
                .find_map(|kv| kv.strip_prefix("prefill_toks="))
                .expect("prefill_toks in STATS")
                .parse()
                .unwrap();
            assert!(toks > 0, "no prefill work recorded: {l}");
            if paged {
                // every session is gone, so every page must be back in
                // the free list — rude disconnects included
                let occ = l
                    .split_whitespace()
                    .find_map(|kv| kv.strip_prefix("kv_pages="))
                    .expect("kv_pages in STATS");
                assert!(
                    occ.starts_with("0/"),
                    "arena did not drain to zero allocated pages: {l}"
                );
            }
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(drained, "sessions never drained to 0 after the storm");
    // clean drain on stop: returns instead of hanging, then rejects
    coord.stop();
    assert!(coord.submit(vec![1, 2]).is_err(), "stopped coordinator must reject");
}
