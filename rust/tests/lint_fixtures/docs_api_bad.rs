// Fixture: the metrics route literal has been dropped from the front
// door — docs-sync must flag the missing "/metrics".

pub fn route(path: &str) -> &'static str {
    match path {
        "/v1/completions" => "completions",
        "/v1/models" => "models",
        _ => "not-found",
    }
}
