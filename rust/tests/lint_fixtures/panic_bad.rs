// Fixture: panicking calls on what the test presents as a serving-path
// module (the test feeds this text under a serving module's path).

pub fn lookup(map: &std::collections::HashMap<u64, u32>, k: u64) -> u32 {
    *map.get(&k).unwrap()
}

pub fn read(v: &[u32], i: usize) -> u32 {
    *v.get(i).expect("index in bounds")
}

pub fn dispatch(kind: u8) -> u32 {
    match kind {
        0 => 1,
        1 => 2,
        _ => unreachable!("kinds are validated at the boundary"),
    }
}

pub fn not_done() {
    todo!()
}

pub fn boom() {
    panic!("unconditional");
}
