// Fixture: a miniature coordinator with a consistent STATS surface —
// canonical field list (resident_bytes last, kv_* before threads), a
// rustdoc row in the same order, and only known wire verbs in replies.

use std::fmt::Write as _;

pub struct Snapshot {
    pub fields: Vec<(&'static str, String)>,
}

/// Replies to `STATS` with `OK requests=… kv_pages=… threads=… resident_bytes=…`.
pub struct Metrics {
    requests: u64,
    kv_pages: u64,
    threads: usize,
    resident_bytes: usize,
}

impl Metrics {
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            fields: vec![
                ("requests", self.requests.to_string()),
                ("kv_pages", self.kv_pages.to_string()),
                ("threads", self.threads.to_string()),
                ("resident_bytes", self.resident_bytes.to_string()),
            ],
        }
    }
}

pub fn reply(out: &mut String, line: &str, m: &Metrics) {
    let verbs = ["OPEN", "FEED ", "GEN ", "CLOSE", "NEXT ", "STATS", "QUIT"];
    if line == verbs[5] {
        let mut s = String::new();
        for (k, v) in &m.snapshot().fields {
            let _ = write!(s, "{k}={v} ");
        }
        let _ = writeln!(out, "OK {s}");
    } else {
        let _ = writeln!(out, "ERR unknown request");
    }
}
