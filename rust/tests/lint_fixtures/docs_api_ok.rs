// Fixture: an HTTP front door that keeps every documented route as a
// string literal — docs-sync must pass (and the file is on the serving
// path, so it is also panic-free).

pub fn route(path: &str) -> &'static str {
    match path {
        "/v1/completions" => "completions",
        "/v1/models" => "models",
        "/metrics" => "metrics",
        _ => "not-found",
    }
}
