// Fixture: a "bench parser" quoting three snapshot fields out of the
// canonical order on one line (paired with stats_ok.rs as the
// coordinator side of the virtual tree).

/// Parses `threads=… kv_pages=… resident_bytes=…` tails from STATS lines.
pub fn parse_tail(line: &str) -> Option<(&str, &str)> {
    line.rsplit_once("resident_bytes=")
}
