// Fixture: the compliant shapes — Result flow, a justified allow
// directive, and test-region panics, all under a serving module's path.

pub fn lookup(map: &std::collections::HashMap<u64, u32>, k: u64) -> Result<u32, String> {
    map.get(&k).copied().ok_or_else(|| format!("unknown session {k}"))
}

pub fn checked(v: &[u32]) -> u32 {
    let i = v.iter().position(|&x| x > 0).unwrap_or(0);
    // lint:allow(no-panic-serving): position() above proves the index is
    // in bounds of the same slice
    *v.get(i).expect("index from position")
}

#[cfg(test)]
mod tests {
    #[test]
    fn panics_are_fine_in_tests() {
        let v = vec![1u32];
        assert_eq!(*v.first().unwrap(), 1);
        if v.len() > 1 {
            panic!("impossible");
        }
    }
}
