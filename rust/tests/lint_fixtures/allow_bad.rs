// Fixture: malformed allow directives. A bad directive must not
// suppress the underlying finding either.

use std::sync::Mutex;

pub fn unknown_rule(counter: &Mutex<u64>) {
    // lint:allow(no-such-rule): confidently citing a rule that is not real
    *counter.lock().unwrap() += 1;
}

pub fn missing_reason(counter: &Mutex<u64>) {
    // lint:allow(lock-poison)
    *counter.lock().unwrap() += 1;
}

pub fn unterminated(counter: &Mutex<u64>) {
    // lint:allow(lock-poison
    *counter.lock().unwrap() += 1;
}
