// Fixture: every unsafe site here lacks a SAFETY justification.
// (Never compiled — the lint scanner only lexes these files.)

pub fn write_through(p: *mut u8) {
    unsafe {
        *p = 0;
    }
}

pub unsafe fn no_doc_section(p: *const u8) -> u8 {
    unsafe { *p }
}

struct Wrapper(*mut u8);

unsafe impl Send for Wrapper {}

// a comment that is not a justification
unsafe impl Sync for Wrapper {}
