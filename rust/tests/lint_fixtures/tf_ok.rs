// Fixture: the compliant shape — unsafe fn, extra attribute in between,
// and the runtime-detection dispatch present in the same module.

pub fn dot(seg: &[f32]) -> f32 {
    if std::arch::is_x86_feature_detected!("avx2") {
        // SAFETY: detection above proves AVX2 support
        unsafe { dot_avx2(seg) }
    } else {
        seg.iter().sum()
    }
}

// SAFETY(contract): callers must have verified AVX2 support.
#[target_feature(enable = "avx2")]
#[allow(dead_code)]
unsafe fn dot_avx2(seg: &[f32]) -> f32 {
    seg.iter().sum()
}
