// Fixture: bare poison-propagating lock acquisitions.

use std::sync::Mutex;

pub fn bump(counter: &Mutex<u64>) {
    *counter.lock().unwrap() += 1;
}

pub fn read(counter: &Mutex<u64>) -> u64 {
    *counter
        .lock()
        .expect("not poisoned")
}
