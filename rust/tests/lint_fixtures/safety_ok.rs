// Fixture: every unsafe site carries a justification the rule accepts.

pub fn write_through(p: *mut u8) {
    // SAFETY: caller handed us a valid, exclusively-owned pointer
    unsafe {
        *p = 0;
    }
}

/// Reads a byte.
///
/// # Safety
///
/// `p` must be valid for reads.
pub unsafe fn doc_section(p: *const u8) -> u8 {
    // SAFETY: forwarded caller contract from the doc section above
    unsafe { *p }
}

struct Wrapper(*mut u8);

// SAFETY: the pointer is only dereferenced on the owning thread
#[allow(dead_code)]
unsafe impl Send for Wrapper {}

fn trailing(p: *mut u8) {
    unsafe { *p = 1 } // SAFETY: same-line trailing justification
}

// an `unsafe fn` in type position is not a site needing justification
struct Table {
    call: unsafe fn(*const ()) -> u8,
}

fn casts(f: unsafe fn(*const ()) -> u8) -> unsafe fn(*const ()) -> u8 {
    f
}
