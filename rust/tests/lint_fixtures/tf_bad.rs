// Fixture: a safe #[target_feature] function — callers could reach it
// without any CPU check. The test feeds this under the dispatch module's
// path (missing detection macro) and under a foreign module's path.

#[target_feature(enable = "avx2")]
fn dot(seg: &[f32]) -> f32 {
    seg.iter().sum()
}
