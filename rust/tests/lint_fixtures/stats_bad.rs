// Fixture: every way the STATS/wire surface can drift — resident_bytes
// not last, a kv counter behind threads, a rustdoc row out of order, and
// a reply verb no client knows.

use std::fmt::Write as _;

pub struct Snapshot {
    pub fields: Vec<(&'static str, String)>,
}

/// Replies to `STATS` with `OK kv_pages=… requests=… resident_bytes=… threads=…`.
pub struct Metrics {
    requests: u64,
    kv_pages: u64,
    threads: usize,
    resident_bytes: usize,
}

impl Metrics {
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            fields: vec![
                ("requests", self.requests.to_string()),
                ("threads", self.threads.to_string()),
                ("kv_pages", self.kv_pages.to_string()),
                ("resident_bytes", self.resident_bytes.to_string()),
                ("requests_dup", self.requests.to_string()),
            ],
        }
    }
}

pub fn reply(out: &mut String, line: &str, m: &Metrics) {
    let verbs = ["OPEN", "FEED ", "GEN ", "CLOSE", "NEXT ", "STATS", "QUIT"];
    if line == verbs[5] {
        let _ = writeln!(out, "BUSY {}", m.snapshot().fields.len());
    } else {
        let _ = writeln!(out, "ERR unknown request");
    }
}
