// Fixture: the poison-recovering pattern from PR 4, plus the test-region
// exemption (tests poison locks on purpose to exercise recovery).

use std::sync::Mutex;

pub fn bump(counter: &Mutex<u64>) {
    *counter.lock().unwrap_or_else(|e| e.into_inner()) += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deliberate_bare_lock_in_test() {
        let m = Mutex::new(1u64);
        assert_eq!(*m.lock().unwrap(), 1);
    }
}
