//! HTTP front door + model registry acceptance.
//!
//! Pins the PR 10 contract: `llvq serve-http` serves multiple named
//! models from one process; greedy completions — streamed over SSE or
//! not — are token-identical to the offline `prefill` + `argmax` +
//! `forward_step` oracle (the same one `llvq generate` runs); malformed
//! requests map to stable 4xx codes; a client disconnect mid-stream
//! closes its session; and the registry's LRU residency budget evicts
//! cold models without ever killing one that has open sessions.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use llvq::coordinator::{BatcherConfig, ServeOptions};
use llvq::http::api::serve_http;
use llvq::model::backend::{BackendKind, ExecutionBackend};
use llvq::model::config::config_by_name;
use llvq::model::packed::{PackedFile, PackedModel};
use llvq::model::registry::{parse_model_specs, ModelRegistry, RegistryConfig};
use llvq::model::sample::argmax;
use llvq::model::transformer::{forward_step, prefill, KvCache, Weights};
use llvq::pipeline::driver::{quantize_model_packed, PtqArtifacts, PtqOptions};
use llvq::pipeline::rotation::RotationMode;
use llvq::quant::kernel::Kernel;
use llvq::quant::llvq::LlvqShapeGain;
use llvq::leech::index::LeechIndexer;
use llvq::util::json::{self, Json};
use llvq::util::proptest::TempArtifact;

fn pack_tiny(seed: u64) -> PtqArtifacts {
    let cfg = config_by_name("qwen3-4b-tiny").unwrap();
    let w = Weights::random(&cfg, seed);
    let q = LlvqShapeGain::new(Arc::new(LeechIndexer::new(3)), 1);
    let opts = PtqOptions {
        calib_seqs: 2,
        rotation: RotationMode::InputOutput,
        ..Default::default()
    };
    quantize_model_packed(&w, &q, &opts)
}

fn save_temp(art: &PtqArtifacts, tag: &str) -> TempArtifact {
    let tmp = TempArtifact::new(&format!("http-{tag}"), "llvqm");
    art.packed.save(tmp.path()).unwrap();
    tmp
}

/// Scheduler shape shared by every test: tiny ticks, a couple of
/// session slots, scalar kernel so the oracle runs the same float ops.
fn test_cfg(backend: BackendKind, max_resident_bytes: usize) -> RegistryConfig {
    RegistryConfig {
        backend,
        threads: 1,
        simd: Kernel::Scalar,
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            max_sessions: 2,
            prefill_chunk: 8,
        },
        kv_pages: 0,
        kv_page_tokens: 16,
        kv_hot: 32,
        kv_quant: llvq::model::kvpage::KvQuantKind::None,
        max_resident_bytes,
    }
}

/// Spawn `serve_http` on an OS-assigned port; returns the address and a
/// second registry handle for direct observation.
fn spawn_server(reg: Arc<ModelRegistry>, max_conns: usize) -> (SocketAddr, Arc<ModelRegistry>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let observer = Arc::clone(&reg);
    std::thread::spawn(move || {
        let _ = serve_http(reg, listener, ServeOptions { max_conns });
    });
    (addr, observer)
}

/// One `Connection: close` request; returns (status, body) with the
/// body read to EOF.
fn http_request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    split_response(&raw)
}

fn split_response(raw: &str) -> (u16, String) {
    let status: u16 = raw
        .split(' ')
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("unparseable response: {raw:?}"));
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}

/// Read one framed (Content-Length) response off a keep-alive stream.
fn read_keepalive_response<R: BufRead>(r: &mut R) -> (u16, String) {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let status: u16 = line.split(' ').nth(1).unwrap().parse().unwrap();
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        r.read_line(&mut h).unwrap();
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            len = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

/// The offline greedy oracle `llvq generate` runs: prefill, then argmax
/// + one decode step per token.
fn greedy_oracle(backend: &ExecutionBackend, prompt: &[u8], n: usize) -> Vec<u8> {
    let mut cache = KvCache::new(backend.cfg());
    let mut logits = prefill(backend, &mut cache, prompt);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let t = argmax(&logits) as u8;
        out.push(t);
        if i + 1 < n {
            logits = forward_step(backend, &mut cache, t);
        }
    }
    out
}

fn completion_tokens(body: &str) -> Vec<u8> {
    let doc = json::parse(body).unwrap();
    let arr = doc
        .path(&["choices"])
        .and_then(|c| c.as_arr())
        .and_then(|c| c.first())
        .and_then(|c| c.get("tokens"))
        .and_then(|t| t.as_arr())
        .unwrap_or_else(|| panic!("no choices[0].tokens in {body}"));
    arr.iter().map(|v| v.as_i64().unwrap() as u8).collect()
}

/// Poll until every model's snapshot reports zero open sessions.
fn wait_sessions_drained(reg: &ModelRegistry) {
    for _ in 0..500 {
        let open: u64 = reg
            .snapshots()
            .iter()
            .map(|(_, s)| s.get("sessions").unwrap().parse::<u64>().unwrap())
            .sum();
        if open == 0 {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("sessions never drained: {:?}", reg.snapshots());
}

#[test]
fn serves_two_models_with_oracle_parity_streamed_and_not() {
    let art = pack_tiny(11);
    let tmp = save_temp(&art, "parity");
    let path = tmp.path().to_string_lossy().to_string();
    let specs = parse_model_specs(&format!("tiny-a={path},tiny-b={path}")).unwrap();
    let reg = ModelRegistry::open(specs, test_cfg(BackendKind::Fused, 0)).unwrap();
    let (addr, reg) = spawn_server(reg, 8);

    // oracle on an identically-configured standalone backend
    let oracle_backend =
        ExecutionBackend::packed_fused_kernel(PackedFile::open(tmp.path()).unwrap(), 1, Kernel::Scalar)
            .unwrap();
    let prompt: Vec<u8> = vec![5, 6, 7, 8];
    let want = greedy_oracle(&oracle_backend, &prompt, 6);

    // GET /v1/models lists both names, cold before any completion
    let (status, body) = http_request(addr, "GET", "/v1/models", "");
    assert_eq!(status, 200);
    let doc = json::parse(&body).unwrap();
    let data = doc.get("data").and_then(|d| d.as_arr()).unwrap();
    let ids: Vec<&str> = data.iter().filter_map(|m| m.get("id").and_then(|v| v.as_str())).collect();
    assert_eq!(ids, vec!["tiny-a", "tiny-b"]);
    for m in data {
        assert_eq!(m.get("resident"), Some(&Json::Bool(false)), "cold at registration");
    }

    // non-streamed greedy completion on tiny-a
    let req = r#"{"model":"tiny-a","prompt":[5,6,7,8],"max_tokens":6}"#;
    let (status, body) = http_request(addr, "POST", "/v1/completions", req);
    assert_eq!(status, 200, "{body}");
    assert_eq!(completion_tokens(&body), want, "non-streamed != oracle");
    let doc = json::parse(&body).unwrap();
    assert_eq!(doc.path(&["usage", "prompt_tokens"]).and_then(|v| v.as_i64()), Some(4));
    assert_eq!(doc.path(&["usage", "completion_tokens"]).and_then(|v| v.as_i64()), Some(6));

    // SSE-streamed greedy completion on tiny-b: same artifact, its own
    // coordinator — and the same tokens
    let req = r#"{"model":"tiny-b","prompt":[5,6,7,8],"max_tokens":6,"stream":true}"#;
    let (status, raw) = http_request(addr, "POST", "/v1/completions", req);
    assert_eq!(status, 200, "{raw}");
    let events: Vec<&str> = raw
        .lines()
        .filter_map(|l| l.strip_prefix("data: "))
        .collect();
    assert_eq!(events.last(), Some(&"[DONE]"), "stream must end with [DONE]");
    let got: Vec<u8> = events[..events.len() - 1]
        .iter()
        .map(|e| {
            let chunk = json::parse(e).unwrap();
            assert_eq!(
                chunk.get("object").and_then(|v| v.as_str()),
                Some("text_completion.chunk")
            );
            chunk
                .path(&["choices"])
                .and_then(|c| c.as_arr())
                .and_then(|c| c.first())
                .and_then(|c| c.get("token"))
                .and_then(|t| t.as_i64())
                .unwrap() as u8
        })
        .collect();
    assert_eq!(got, want, "SSE stream != oracle");

    // both models now resident; /metrics shows the registry summary and
    // one canonical per-model line each
    let (status, metrics) = http_request(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    assert!(metrics.contains("registry models=2 resident=2"), "{metrics}");
    assert!(metrics.contains("model name=tiny-a "), "{metrics}");
    assert!(metrics.contains("model name=tiny-b "), "{metrics}");
    for line in metrics.lines().filter(|l| l.starts_with("model name=")) {
        assert!(line.contains("backend=fused"), "{line}");
        assert!(line.contains("models=2"), "shared gauge: {line}");
        let (_, tail) = line.rsplit_once("resident_bytes=").expect("resident_bytes last");
        assert!(tail.parse::<u64>().is_ok(), "{line}");
    }
    wait_sessions_drained(&reg);
}

#[test]
fn malformed_requests_map_to_stable_4xx_codes() {
    let art = pack_tiny(12);
    let tmp = save_temp(&art, "errors");
    let path = tmp.path().to_string_lossy().to_string();
    let specs = parse_model_specs(&format!("tiny={path}")).unwrap();
    let reg = ModelRegistry::open(specs, test_cfg(BackendKind::Cached, 0)).unwrap();
    let (addr, _reg) = spawn_server(reg, 8);

    let code_of = |body: &str| {
        json::parse(body)
            .ok()
            .and_then(|d| d.path(&["error", "code"]).and_then(|c| c.as_str().map(String::from)))
            .unwrap_or_else(|| panic!("no error code in {body}"))
    };

    // bad JSON / bad shapes → 400 bad-request
    for req in [
        "not json",
        r#"{"model":"tiny"}"#,
        r#"{"model":"tiny","prompt":[]}"#,
        r#"{"model":"tiny","prompt":"text"}"#,
        r#"{"model":"tiny","prompt":[999]}"#,
        // prompt + max_tokens over the tiny config's max_seq of 64
        r#"{"model":"tiny","prompt":[1,2,3,4],"max_tokens":200}"#,
    ] {
        let (status, body) = http_request(addr, "POST", "/v1/completions", req);
        assert_eq!(status, 400, "{req} -> {body}");
        assert_eq!(code_of(&body), "bad-request", "{req}");
    }

    // unknown model → 404 unknown-model
    let (status, body) =
        http_request(addr, "POST", "/v1/completions", r#"{"model":"ghost","prompt":[1]}"#);
    assert_eq!(status, 404, "{body}");
    assert_eq!(code_of(&body), "unknown-model");

    // unknown path → 404, known path + wrong method → 405
    let (status, body) = http_request(addr, "GET", "/v2/nope", "");
    assert_eq!(status, 404);
    assert_eq!(code_of(&body), "not-found");
    let (status, body) = http_request(addr, "DELETE", "/v1/models", "");
    assert_eq!(status, 405);
    assert_eq!(code_of(&body), "method-not-allowed");

    // a framing violation answers 400 and the connection closes
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"garbage\r\n\r\n").unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 400 "), "{raw}");

    // fixed-length responses keep the connection alive: two requests on
    // one socket
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    for _ in 0..2 {
        s.write_all(b"GET /v1/models HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let (status, body) = read_keepalive_response(&mut r);
        assert_eq!(status, 200);
        assert!(body.contains("\"tiny\""), "{body}");
    }
}

#[test]
fn client_disconnect_mid_stream_closes_the_session() {
    let art = pack_tiny(13);
    let tmp = save_temp(&art, "disconnect");
    let path = tmp.path().to_string_lossy().to_string();
    let specs = parse_model_specs(&path).unwrap(); // bare path → stem name
    let reg = ModelRegistry::open(specs, test_cfg(BackendKind::Fused, 0)).unwrap();
    let (addr, reg) = spawn_server(reg, 8);

    let mut s = TcpStream::connect(addr).unwrap();
    let req = format!(
        r#"{{"model":"{}","prompt":[1,2,3],"max_tokens":50,"stream":true}}"#,
        reg.models()[0].name
    );
    let verb = "POST";
    write!(
        s,
        "{verb} /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{req}",
        req.len()
    )
    .unwrap();
    // read just the first SSE event, then hang up mid-stream
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut line = String::new();
    loop {
        line.clear();
        r.read_line(&mut line).unwrap();
        if line.starts_with("data: ") {
            break;
        }
        assert!(!line.is_empty(), "stream ended before the first token");
    }
    drop(r);
    drop(s);
    // the guard on the server closes the session once its next write
    // fails; the registry's per-model snapshot must drain to zero
    wait_sessions_drained(&reg);
}

#[test]
fn lru_eviction_respects_budget_and_spares_open_sessions() {
    let art = pack_tiny(14);
    let tmp = save_temp(&art, "evict");
    let path = tmp.path().to_string_lossy().to_string();

    // dense backends have a fixed resident footprint (cached ones grow
    // lazily) — measure one to size a one-model budget
    let w = PackedModel::load(tmp.path()).unwrap().unpack(1).unwrap();
    let one = ExecutionBackend::dense(w).resident_weight_bytes();
    assert!(one > 0);

    let specs = parse_model_specs(&format!("a={path},b={path}")).unwrap();
    let reg = ModelRegistry::open(specs, test_cfg(BackendKind::Dense, one + one / 2)).unwrap();
    assert_eq!(reg.len(), 2);
    assert_eq!(reg.resident_count(), 0, "registration is header-only");

    // first touches build lazily; the second build pushes over budget
    // and evicts the LRU (a)
    let _a = reg.coordinator("a").unwrap();
    assert_eq!(reg.resident_count(), 1);
    let _b = reg.coordinator("b").unwrap();
    let resident: Vec<(String, bool)> =
        reg.models().into_iter().map(|m| (m.name, m.resident)).collect();
    assert_eq!(resident, vec![("a".into(), false), ("b".into(), true)]);
    assert!(reg.resident_bytes() <= one + one / 2, "budget respected");

    // touching a again rebuilds it and evicts b
    let _a = reg.coordinator("a").unwrap();
    let resident: Vec<(String, bool)> =
        reg.models().into_iter().map(|m| (m.name, m.resident)).collect();
    assert_eq!(resident, vec![("a".into(), true), ("b".into(), false)]);

    assert!(reg.coordinator("ghost").is_err(), "unknown model stays an error");
    reg.stop();
}

#[test]
fn eviction_never_kills_a_model_with_open_sessions() {
    let art = pack_tiny(15);
    let tmp = save_temp(&art, "pinned");
    let path = tmp.path().to_string_lossy().to_string();
    let specs = parse_model_specs(&format!("a={path},b={path}")).unwrap();
    // a 1-byte budget: everything is always over budget, so only the
    // open-session and just-touched exemptions keep models alive
    let reg = ModelRegistry::open(specs, test_cfg(BackendKind::Dense, 1)).unwrap();

    let coord_a = reg.coordinator("a").unwrap();
    let sid = coord_a.open_session().unwrap();
    assert_eq!(coord_a.metrics.open_sessions.load(Ordering::SeqCst), 1);

    // building b would normally evict LRU a — but a has an open session
    let _b = reg.coordinator("b").unwrap();
    assert_eq!(reg.resident_count(), 2, "pinned model survives the budget");
    // the session is still fully usable on the surviving coordinator
    assert_eq!(coord_a.feed(sid, vec![1, 2, 3]).unwrap(), 3);
    coord_a.close_session(sid).unwrap();

    // with the session closed, the next touch of b evicts idle a
    let _b = reg.coordinator("b").unwrap();
    let resident: Vec<(String, bool)> =
        reg.models().into_iter().map(|m| (m.name, m.resident)).collect();
    assert_eq!(resident, vec![("a".into(), false), ("b".into(), true)]);
    reg.stop();
}
