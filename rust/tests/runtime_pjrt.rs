//! PJRT integration tests — the full three-layer contract:
//! JAX/Pallas kernels AOT-lowered to HLO text execute on the rust PJRT
//! runtime and agree numerically with the rust-native implementations.
//!
//! These tests need `make artifacts`; they skip politely when the bundle
//! is absent so `cargo test` works on a fresh checkout. The whole file is
//! additionally gated on the `pjrt_runtime` cfg (the offline default build
//! has no `xla` dependency — see `src/runtime.rs`).
#![cfg(pjrt_runtime)]

use llvq::leech::index::LeechIndexer;
use llvq::leech::tables::KernelTables;
use llvq::runtime::{artifact, artifacts_available, Runtime};
use llvq::util::json;
use llvq::util::rng::Xoshiro256pp;

fn config() -> Option<json::Json> {
    let text = std::fs::read_to_string(artifact("config.json")).ok()?;
    json::parse(&text).ok()
}

enum Cols {
    I64(Vec<i64>),
    I32(Vec<i32>),
}

/// Table literals in the exact argument order of `compile/aot.py`.
fn table_literals(t: &KernelTables, cfg: &json::Json) -> Vec<xla::Literal> {
    let g = t.num_groups as i64;
    let v = llvq::leech::tables::MAX_DISTINCT as i64;
    let keys: Vec<String> = cfg
        .path(&["table_keys"])
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|k| k.as_str().unwrap().to_string())
        .collect();
    keys.iter()
        .map(|k| {
            let (data, shape): (Cols, Vec<i64>) = match k.as_str() {
                "group_offsets" => (Cols::I64(t.group_offsets.clone()), vec![g + 1]),
                "num_codewords" => (
                    Cols::I64(t.num_codewords.iter().map(|&x| x as i64).collect()),
                    vec![g],
                ),
                "sign_bits" => (
                    Cols::I64(t.sign_bits.iter().map(|&x| x as i64).collect()),
                    vec![g],
                ),
                "f0_arrangements" => (Cols::I64(t.f0_arrangements.clone()), vec![g]),
                "f1_arrangements" => (Cols::I64(t.f1_arrangements.clone()), vec![g]),
                "weight" => (Cols::I32(t.weight.clone()), vec![g]),
                "cw_base" => (Cols::I32(t.cw_base.clone()), vec![g]),
                "parity_odd" => (Cols::I32(t.parity_odd.clone()), vec![g]),
                "f1_neg_parity" => (Cols::I32(t.f1_neg_parity.clone()), vec![g]),
                "f1_values" => (Cols::I32(t.f1_values.clone()), vec![g, v]),
                "f1_counts" => (Cols::I32(t.f1_counts.clone()), vec![g, v]),
                "f0_values" => (Cols::I32(t.f0_values.clone()), vec![g, v]),
                "f0_counts" => (Cols::I32(t.f0_counts.clone()), vec![g, v]),
                "golay_sorted" => (Cols::I32(t.golay_sorted.clone()), vec![4096]),
                other => panic!("unknown table key {other}"),
            };
            match data {
                Cols::I64(d) => xla::Literal::vec1(&d[..]).reshape(&shape).unwrap(),
                Cols::I32(d) => xla::Literal::vec1(&d[..]).reshape(&shape).unwrap(),
            }
        })
        .collect()
}

#[test]
fn dequant_kernel_matches_rust_tables() {
    if !artifacts_available() {
        eprintln!("[skip] artifacts/ missing — run `make artifacts`");
        return;
    }
    let cfg = config().expect("config.json unreadable");
    let max_m = cfg.path(&["max_m"]).unwrap().as_i64().unwrap() as usize;
    let n = cfg.path(&["dequant_batch"]).unwrap().as_i64().unwrap() as usize;

    let ix = LeechIndexer::new(max_m);
    let t = KernelTables::build(&ix);
    assert_eq!(
        t.num_groups as i64,
        cfg.path(&["num_groups"]).unwrap().as_i64().unwrap(),
        "rust and python enumerations disagree on group count"
    );

    let rt = Runtime::cpu().expect("PJRT CPU client");
    let exe = rt
        .load(&artifact(&format!("dequant_M{max_m}_N{n}.hlo.txt")))
        .expect("load dequant artifact");

    let mut rng = Xoshiro256pp::new(0xA07);
    let mut idx = vec![0i64; n];
    let np = t.num_points() as u64;
    for (i, v) in idx.iter_mut().enumerate() {
        *v = if i < 4 {
            [0, 1, 196_559, 196_560][i]
        } else {
            rng.next_range(np) as i64
        };
    }

    let mut lits = vec![xla::Literal::vec1(&idx[..]).reshape(&[n as i64]).unwrap()];
    lits.extend(table_literals(&t, &cfg));
    let outs = rt.run_literals(&exe, &lits).expect("execute dequant");
    assert_eq!(outs.len(), 1);
    let flat: Vec<i32> = outs[0].to_vec().expect("i32 output");
    assert_eq!(flat.len(), n * 24);

    for (i, &index) in idx.iter().enumerate() {
        let expect = t.dequantize(index as u64);
        let got = &flat[i * 24..(i + 1) * 24];
        assert_eq!(got, &expect[..], "kernel disagrees at index {index}");
    }
    println!("dequant kernel ✓ ({n} indices, M={max_m})");
}

#[test]
fn lm_forward_artifact_matches_native_oracle() {
    if !artifacts_available() {
        eprintln!("[skip] artifacts/ missing — run `make artifacts`");
        return;
    }
    let name = "llama2-tiny";
    let path = artifact(&format!("{name}.llvqw"));
    let w = match llvq::model::io::load(&path) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("[skip] {e}");
            return;
        }
    };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let exe = rt
        .load(&artifact(&format!("lm_forward_{name}_B1.hlo.txt")))
        .expect("load lm artifact");

    let s = w.cfg.max_seq;
    let mut corpus = llvq::model::corpus::Corpus::new(4242);
    let (toks, _) = corpus.generate(s);
    let toks_i32: Vec<i32> = toks.iter().map(|&t| t as i32).collect();

    let d = w.cfg.d_model as i64;
    let mut lits = vec![xla::Literal::vec1(&toks_i32[..])
        .reshape(&[1, s as i64])
        .unwrap()];
    let push = |lits: &mut Vec<xla::Literal>, data: &[f32], dims: &[i64]| {
        lits.push(xla::Literal::vec1(data).reshape(dims).unwrap());
    };
    push(&mut lits, &w.tok_emb, &[w.cfg.vocab as i64, d]);
    push(&mut lits, &w.pos_emb, &[w.cfg.max_seq as i64, d]);
    for b in &w.blocks {
        push(&mut lits, &b.norm1, &[d]);
        push(&mut lits, &b.wq, &[d, d]);
        push(&mut lits, &b.wk, &[d, d]);
        push(&mut lits, &b.wv, &[d, d]);
        push(&mut lits, &b.wo, &[d, d]);
        push(&mut lits, &b.norm2, &[d]);
        push(&mut lits, &b.w1, &[w.cfg.d_ff as i64, d]);
        push(&mut lits, &b.w2, &[d, w.cfg.d_ff as i64]);
    }
    push(&mut lits, &w.norm_f, &[d]);
    push(&mut lits, &w.lm_head, &[w.cfg.vocab as i64, d]);

    let outs = rt.run_literals(&exe, &lits).expect("execute lm forward");
    let logits: Vec<f32> = outs[0].to_vec().expect("f32 logits");
    assert_eq!(logits.len(), s * w.cfg.vocab);

    let mut cap = llvq::model::transformer::ActivationCapture::default();
    let native = llvq::model::transformer::forward(&w, &toks, &mut cap);
    let mut max_abs = 0f32;
    for (a, b) in logits.iter().zip(&native) {
        max_abs = max_abs.max((a - b).abs());
    }
    assert!(
        max_abs < 2e-3,
        "PJRT vs native logits diverge: max |Δ| = {max_abs}"
    );
    println!("lm forward ✓ (max |Δ| = {max_abs:.2e})");
}

#[test]
fn quant_linear_artifact_runs_end_to_end() {
    if !artifacts_available() {
        eprintln!("[skip] artifacts/ missing — run `make artifacts`");
        return;
    }
    let cfg = config().expect("config.json unreadable");
    let max_m = cfg.path(&["max_m"]).unwrap().as_i64().unwrap() as usize;
    let rows = cfg.path(&["quant_linear", "rows"]).unwrap().as_i64().unwrap() as usize;
    let cols = cfg.path(&["quant_linear", "cols"]).unwrap().as_i64().unwrap() as usize;
    let batch = cfg.path(&["quant_linear", "batch"]).unwrap().as_i64().unwrap() as usize;
    let nblocks = rows * cols / 24;

    let ix = LeechIndexer::new(max_m);
    let t = KernelTables::build(&ix);
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let exe = rt
        .load(&artifact(&format!("quant_linear_M{max_m}.hlo.txt")))
        .expect("load quant_linear artifact");

    let mut rng = Xoshiro256pp::new(0x91);
    let np = t.num_points() as u64;
    let idx: Vec<i64> = (0..nblocks).map(|_| rng.next_range(np) as i64).collect();
    let gains: Vec<f32> = (0..nblocks).map(|_| rng.next_f32() * 0.2 + 0.05).collect();
    let mut x = vec![0f32; batch * cols];
    rng.fill_gaussian_f32(&mut x);

    let mut lits = vec![
        xla::Literal::vec1(&idx[..]).reshape(&[nblocks as i64]).unwrap(),
        xla::Literal::vec1(&gains[..]).reshape(&[nblocks as i64]).unwrap(),
        xla::Literal::vec1(&x[..]).reshape(&[batch as i64, cols as i64]).unwrap(),
    ];
    lits.extend(table_literals(&t, &cfg));
    let outs = rt.run_literals(&exe, &lits).expect("execute quant_linear");
    let y: Vec<f32> = outs[0].to_vec().expect("f32 output");
    assert_eq!(y.len(), batch * rows);

    // native reference: dequantize blocks, assemble W, multiply
    let mut w_hat = vec![0f32; rows * cols];
    for (bidx, (&i, &g)) in idx.iter().zip(&gains).enumerate() {
        let pt = t.dequantize(i as u64);
        for k in 0..24 {
            w_hat[bidx * 24 + k] = pt[k] as f32 * g;
        }
    }
    let mut max_abs = 0f32;
    for bi in 0..batch {
        for r in 0..rows {
            let mut acc = 0f32;
            for c in 0..cols {
                acc += w_hat[r * cols + c] * x[bi * cols + c];
            }
            max_abs = max_abs.max((acc - y[bi * rows + r]).abs());
        }
    }
    assert!(max_abs < 1e-2, "quant_linear diverges: {max_abs}");
    println!("quant_linear ✓ (max |Δ| = {max_abs:.2e})");
}
