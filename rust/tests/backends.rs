//! Cross-backend equivalence: the same `.llvqm` artifact served through
//! the dense, packed-cached, and packed-fused execution backends must
//! produce the same model.
//!
//! Numerical contract (documented in `model::backend`): dense and cached
//! backends are **bit-identical** to the PTQ driver's reconstruction —
//! cached decodes each layer with the same `unpack_layer` float-op
//! sequence and runs the same f32 matvec kernel. The fused backend
//! accumulates each row dot product in f64 over the raw code stream
//! (the dense path rounds every weight to f32 first and accumulates the
//! matvec in f32), so its logits agree to ~1e-5 *relative* and must be
//! argmax-identical — that difference in accumulation order is the only
//! divergence allowed.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use llvq::coordinator::{serve_tcp, BackendEngine, BatcherConfig, Coordinator};
use llvq::leech::index::LeechIndexer;
use llvq::model::backend::{ExecutionBackend, LinearOp};
use llvq::model::config::config_by_name;
use llvq::model::eval::evaluate;
use llvq::model::packed::PackedFile;
use llvq::model::transformer::{forward, ActivationCapture, LinearKind, Weights};
use llvq::pipeline::driver::{quantize_model_packed, PtqArtifacts, PtqOptions};
use llvq::pipeline::rotation::RotationMode;
use llvq::quant::e8::{E8Codebook, E8Cut};
use llvq::quant::llvq::{LlvqShapeGain, LlvqSpherical};
use llvq::quant::scalar::{LloydMaxQuantizer, UniformQuantizer};
use llvq::quant::VectorQuantizer;
use llvq::util::proptest::{check, TempArtifact};

/// The five quantizer specs of the `.llvqm` codec surface (scalar uniform,
/// scalar Lloyd–Max, E8, LLVQ spherical, LLVQ shape–gain).
fn five_quantizers() -> Vec<(&'static str, Box<dyn VectorQuantizer>)> {
    let ix = Arc::new(LeechIndexer::new(3));
    vec![
        (
            "uniform",
            Box::new(UniformQuantizer::new_gaussian_optimal(4)) as Box<dyn VectorQuantizer>,
        ),
        (
            "lloyd-max",
            Box::new(LloydMaxQuantizer::train_gaussian(3, 40_000, 4)),
        ),
        ("e8", Box::new(E8Codebook::new(E8Cut::Ball))),
        (
            "llvq-spherical",
            Box::new(LlvqSpherical::with_scale(ix.clone(), 0.9)),
        ),
        ("llvq-shape-gain", Box::new(LlvqShapeGain::new(ix, 1))),
    ]
}

/// PTQ the padding-exercising tiny config into a packed artifact.
fn pack_tiny(q: &dyn VectorQuantizer, seed: u64, finetune: bool) -> PtqArtifacts {
    let cfg = config_by_name("qwen3-4b-tiny").unwrap();
    let w = Weights::random(&cfg, seed);
    let opts = PtqOptions {
        calib_seqs: 2,
        finetune_scales: finetune,
        rotation: RotationMode::InputOutput,
        ..Default::default()
    };
    quantize_model_packed(&w, q, &opts)
}

/// Save the artifact under a drop-guarded temp path: an assert failure
/// anywhere in the test no longer leaks the `.llvqm` into /tmp.
fn save_temp(art: &PtqArtifacts, tag: &str) -> TempArtifact {
    let tmp = TempArtifact::new(&format!("backends-{tag}"), "llvqm");
    art.packed.save(tmp.path()).unwrap();
    tmp
}

fn argmax(row: &[f32]) -> usize {
    let mut best = (0usize, f32::NEG_INFINITY);
    for (i, &v) in row.iter().enumerate() {
        if v > best.1 {
            best = (i, v);
        }
    }
    best.0
}

#[test]
fn prop_three_backends_agree_across_all_quantizer_specs() {
    for (i, (name, q)) in five_quantizers().into_iter().enumerate() {
        // alternate fine-tuned column scales on/off so both reconstruction
        // paths are exercised across the spec matrix
        let art = pack_tiny(q.as_ref(), 100 + i as u64, i % 2 == 0);
        let tmp = save_temp(&art, name);
        let dense = ExecutionBackend::dense(art.weights.clone());
        let cached =
            ExecutionBackend::packed_cached(PackedFile::open(tmp.path()).unwrap(), 2).unwrap();
        let fused =
            ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), 2).unwrap();
        let vocab = art.weights.cfg.vocab;
        check(&format!("backends-{name}"), 4, |rng| {
            let len = 1 + rng.next_range(12) as usize;
            let toks: Vec<u8> = (0..len).map(|_| rng.next_range(64) as u8).collect();
            let mut cap = ActivationCapture::default();
            let oracle = forward(&art.weights, &toks, &mut cap);
            let d = forward(&dense, &toks, &mut cap);
            if d != oracle {
                return Err(format!("{name}: dense backend diverged bit-wise"));
            }
            let c = forward(&cached, &toks, &mut cap);
            if c != oracle {
                return Err(format!("{name}: cached backend diverged bit-wise"));
            }
            let f = forward(&fused, &toks, &mut cap);
            let linf = oracle.iter().fold(0f32, |a, &b| a.max(b.abs()));
            let tol = 1e-5 * linf.max(1.0);
            for (a, b) in oracle.iter().zip(&f) {
                if (a - b).abs() > tol {
                    return Err(format!(
                        "{name}: fused logit drift {} > {tol}",
                        (a - b).abs()
                    ));
                }
            }
            let last = &oracle[(len - 1) * vocab..len * vocab];
            let flast = &f[(len - 1) * vocab..len * vocab];
            if argmax(last) != argmax(flast) {
                return Err(format!("{name}: fused argmax diverged from dense oracle"));
            }
            Ok(())
        });
    }
}

#[test]
fn prop_pooled_kernels_are_bit_identical_to_one_thread_across_specs() {
    // the tentpole contract: the row-sharded worker-pool kernels (fused
    // matmul, cached first-touch decode) reproduce the threads=1 kernels
    // bit for bit — per quantizer spec, per thread count, single lane and
    // slate. Rows accumulate independently, so this holds by construction;
    // pin it anyway.
    for (i, (name, q)) in five_quantizers().into_iter().enumerate() {
        let art = pack_tiny(q.as_ref(), 500 + i as u64, i % 2 == 1);
        let tmp = save_temp(&art, &format!("pool-{name}"));
        let fused1 =
            ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), 1).unwrap();
        let cached1 =
            ExecutionBackend::packed_cached(PackedFile::open(tmp.path()).unwrap(), 1).unwrap();
        for threads in [2usize, 4, 8] {
            let fused_t =
                ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), threads)
                    .unwrap();
            let cached_t =
                ExecutionBackend::packed_cached(PackedFile::open(tmp.path()).unwrap(), threads)
                    .unwrap();
            check(&format!("pool-parity-{name}-t{threads}"), 2, |rng| {
                // whole forward passes: single sequence (lane) and a full
                // 8-lane slate through matmul_into via linear_batch
                let len = 1 + rng.next_range(10) as usize;
                let toks: Vec<u8> = (0..len).map(|_| rng.next_range(64) as u8).collect();
                let mut cap = ActivationCapture::default();
                let f1 = forward(&fused1, &toks, &mut cap);
                let ft = forward(&fused_t, &toks, &mut cap);
                if f1.iter().zip(&ft).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err(format!("{name}: fused threads={threads} != threads=1"));
                }
                let c1 = forward(&cached1, &toks, &mut cap);
                let ct = forward(&cached_t, &toks, &mut cap);
                if c1.iter().zip(&ct).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err(format!("{name}: cached threads={threads} != threads=1"));
                }
                // op-level slate: 8 lanes through the fused matmul_into
                let op1 = fused1.op(0, LinearKind::W1);
                let opt = fused_t.op(0, LinearKind::W1);
                let (d_out, d_in) = op1.shape();
                let n = 8usize;
                let xs: Vec<f32> = (0..n * d_in)
                    .map(|_| (rng.next_f64() * 2.0 - 1.0) as f32)
                    .collect();
                let mut want = vec![0f32; n * d_out];
                let mut got = vec![0f32; n * d_out];
                op1.matmul_into(&xs, &mut want, n);
                opt.matmul_into(&xs, &mut got, n);
                if want.iter().zip(&got).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err(format!(
                        "{name}: fused slate matmul threads={threads} != threads=1"
                    ));
                }
                Ok(())
            });
        }
    }
}

#[test]
fn cached_backend_evaluates_identically_under_threads() {
    // evaluate() is generic over ForwardOps and fans sequences out over
    // the pool — concurrent first touches race on the per-layer OnceLock
    // and must still yield the dense oracle's metrics exactly.
    let q = UniformQuantizer::new_gaussian_optimal(4);
    let art = pack_tiny(&q, 11, true);
    let tmp = save_temp(&art, "eval");
    let cached =
        ExecutionBackend::packed_cached(PackedFile::open(tmp.path()).unwrap(), 2).unwrap();
    let a = evaluate(&art.weights, 4, 2000, 4);
    let b = evaluate(&cached, 4, 2000, 4);
    assert_eq!(a.perplexity.to_bits(), b.perplexity.to_bits());
    assert_eq!(a.accuracy_pct.to_bits(), b.accuracy_pct.to_bits());
    assert_eq!(a.tokens, b.tokens);
}

#[test]
fn fused_tcp_serving_matches_dense_oracle_within_packed_resident_bytes() {
    // the acceptance path end to end: `serve --backend fused` answers NEXT
    // with logits matching the dense oracle (argmax-identical) while STATS
    // reports resident weight bytes ≤ 1.1× the on-disk code bytes — dense
    // f32 never materializes.
    let q = LlvqShapeGain::new(Arc::new(LeechIndexer::new(3)), 1);
    let art = pack_tiny(&q, 7, false);
    let tmp = save_temp(&art, "tcp");
    let fused =
        ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), 2).unwrap();
    let code_bytes = art.packed.code_bytes();
    assert!(
        fused.resident_weight_bytes() as f64 <= 1.1 * code_bytes as f64,
        "resident {} vs on-disk code bytes {code_bytes}",
        fused.resident_weight_bytes()
    );
    // and nowhere near a dense f32 materialization
    assert!(fused.resident_weight_bytes() < art.packed.linear_params());

    // dense-oracle answer for the request below
    let toks = [5u8, 6, 7, 8, 9];
    let mut cap = ActivationCapture::default();
    let oracle = forward(&art.weights, &toks, &mut cap);
    let vocab = art.weights.cfg.vocab;
    let expect = argmax(&oracle[(toks.len() - 1) * vocab..toks.len() * vocab]);

    let engine = Arc::new(BackendEngine::new(fused));
    let coord = Coordinator::start(engine, BatcherConfig::default());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let c2 = coord.clone();
    std::thread::spawn(move || {
        let _ = serve_tcp(c2, listener);
    });

    let mut s = TcpStream::connect(addr).unwrap();
    writeln!(s, "NEXT 5,6,7,8,9").unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK next="), "{line}");
    let got: usize = line
        .trim()
        .strip_prefix("OK next=")
        .unwrap()
        .split_whitespace()
        .next()
        .unwrap()
        .parse()
        .unwrap();
    assert_eq!(got, expect, "fused argmax != dense oracle ({line})");

    writeln!(s, "STATS").unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("backend=fused"), "{line}");
    let resident: usize = line
        .trim()
        .rsplit('=')
        .next()
        .unwrap()
        .parse()
        .expect("resident_bytes field");
    assert!(
        resident as f64 <= 1.1 * code_bytes as f64,
        "STATS resident {resident} vs code bytes {code_bytes}"
    );
    assert!(line.contains("threads=2"), "STATS must report the pool size: {line}");
    writeln!(s, "QUIT").unwrap();
    coord.stop();
}
