//! Generation-session acceptance: KV-cached decoding must be bit-identical
//! to full-prefix recomputation on every backend and every quantizer spec,
//! and the v2 wire protocol (`OPEN`/`FEED`/`GEN`/`CLOSE`) must stream the
//! same tokens a client would get by resubmitting the growing prefix
//! through v1 `NEXT`.
//!
//! The oracle logic: `prefill(P)` then N × `forward_step` replays the
//! exact float-op sequence of `forward(P + generated…)` at each new
//! position (the full pass is itself implemented over a scratch KV cache),
//! so logits — not just argmaxes — are compared with `to_bits`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use llvq::coordinator::{
    serve_tcp_opts, BackendEngine, BatchForward, BatcherConfig, Coordinator, ServeOptions,
};
use llvq::leech::index::LeechIndexer;
use llvq::model::backend::ExecutionBackend;
use llvq::model::config::config_by_name;
use llvq::model::packed::PackedFile;
use llvq::model::sample::argmax;
use llvq::model::transformer::{
    forward, forward_step, forward_step_batch, prefill, prefill_chunked, ActivationCapture,
    ForwardOps, KvCache, KvStore, StepLane, Weights,
};
use llvq::pipeline::driver::{quantize_model_packed, PtqArtifacts, PtqOptions};
use llvq::pipeline::rotation::RotationMode;
use llvq::quant::e8::{E8Codebook, E8Cut};
use llvq::quant::llvq::{LlvqShapeGain, LlvqSpherical};
use llvq::quant::scalar::{LloydMaxQuantizer, UniformQuantizer};
use llvq::quant::VectorQuantizer;
use llvq::util::proptest::{check, TempArtifact};

/// The five quantizer specs of the `.llvqm` codec surface.
fn five_quantizers() -> Vec<(&'static str, Box<dyn VectorQuantizer>)> {
    let ix = Arc::new(LeechIndexer::new(3));
    vec![
        (
            "uniform",
            Box::new(UniformQuantizer::new_gaussian_optimal(4)) as Box<dyn VectorQuantizer>,
        ),
        (
            "lloyd-max",
            Box::new(LloydMaxQuantizer::train_gaussian(3, 40_000, 4)),
        ),
        ("e8", Box::new(E8Codebook::new(E8Cut::Ball))),
        (
            "llvq-spherical",
            Box::new(LlvqSpherical::with_scale(ix.clone(), 0.9)),
        ),
        ("llvq-shape-gain", Box::new(LlvqShapeGain::new(ix, 1))),
    ]
}

fn pack_tiny(q: &dyn VectorQuantizer, seed: u64, finetune: bool) -> PtqArtifacts {
    let cfg = config_by_name("qwen3-4b-tiny").unwrap();
    let w = Weights::random(&cfg, seed);
    let opts = PtqOptions {
        calib_seqs: 2,
        finetune_scales: finetune,
        rotation: RotationMode::InputOutput,
        ..Default::default()
    };
    quantize_model_packed(&w, q, &opts)
}

/// Save the artifact under a drop-guarded temp path: an assert failure
/// anywhere in the test no longer leaks the `.llvqm` into /tmp.
fn save_temp(art: &PtqArtifacts, tag: &str) -> TempArtifact {
    let tmp = TempArtifact::new(&format!("generation-{tag}"), "llvqm");
    art.packed.save(tmp.path()).unwrap();
    tmp
}

/// Assert: on backend `m`, prefill + greedy steps reproduce full-forward
/// last-position logits bit-for-bit at every position.
fn assert_session_matches_full<M: ForwardOps + ?Sized>(
    m: &M,
    prefix: &[u8],
    steps: usize,
    label: &str,
) -> Result<(), String> {
    let vocab = m.cfg().vocab;
    let mut cap = ActivationCapture::default();
    let mut cache = KvCache::new(m.cfg());
    // feed the prefix in two chunks to also exercise incremental prefill
    let split = (prefix.len() / 2).max(1).min(prefix.len());
    prefill(m, &mut cache, &prefix[..split]);
    let mut step_logits = if split < prefix.len() {
        prefill(m, &mut cache, &prefix[split..])
    } else {
        // re-derive last logits from a fresh cache for the 1-token case
        let mut c2 = KvCache::new(m.cfg());
        let l = prefill(m, &mut c2, prefix);
        cache = c2;
        l
    };
    let mut toks = prefix.to_vec();
    for s in 0..steps {
        let full = forward(m, &toks, &mut cap);
        let last = &full[(toks.len() - 1) * vocab..toks.len() * vocab];
        if !step_logits
            .iter()
            .zip(last)
            .all(|(a, b)| a.to_bits() == b.to_bits())
        {
            return Err(format!(
                "{label}: cached logits diverged from full forward at step {s}"
            ));
        }
        let next = argmax(last) as u8;
        toks.push(next);
        step_logits = forward_step(m, &mut cache, next);
    }
    Ok(())
}

#[test]
fn prop_kv_cached_generation_is_bit_identical_across_specs_and_backends() {
    for (i, (name, q)) in five_quantizers().into_iter().enumerate() {
        let art = pack_tiny(q.as_ref(), 300 + i as u64, i % 2 == 0);
        let tmp = save_temp(&art, name);
        let dense = ExecutionBackend::dense(art.weights.clone());
        let cached =
            ExecutionBackend::packed_cached(PackedFile::open(tmp.path()).unwrap(), 2).unwrap();
        let fused =
            ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), 2).unwrap();
        check(&format!("generation-{name}"), 3, |rng| {
            let plen = 1 + rng.next_range(10) as usize;
            let prefix: Vec<u8> = (0..plen).map(|_| rng.next_range(64) as u8).collect();
            let steps = 2 + rng.next_range(3) as usize;
            assert_session_matches_full(&dense, &prefix, steps, &format!("{name}/dense"))?;
            assert_session_matches_full(&cached, &prefix, steps, &format!("{name}/cached"))?;
            assert_session_matches_full(&fused, &prefix, steps, &format!("{name}/fused"))?;
            Ok(())
        });
    }
}

#[test]
fn slate_decode_matches_single_lane_on_fused() {
    // the amortized multi-lane decode step (one row decode per step for
    // the whole slate) must not change any lane's logits
    let q = LlvqShapeGain::new(Arc::new(LeechIndexer::new(3)), 1);
    let art = pack_tiny(&q, 21, true);
    let tmp = save_temp(&art, "slate");
    let fused =
        ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), 2).unwrap();
    let cfg = fused.cfg().clone();
    let prefixes: [&[u8]; 4] = [&[1, 2, 3], &[60, 2], &[9, 8, 7, 6, 5, 4], &[33]];
    let mut slate: Vec<KvCache> = prefixes.iter().map(|_| KvCache::new(&cfg)).collect();
    let mut solo: Vec<KvCache> = prefixes.iter().map(|_| KvCache::new(&cfg)).collect();
    for (i, p) in prefixes.iter().enumerate() {
        prefill(&fused, &mut slate[i], p);
        prefill(&fused, &mut solo[i], p);
    }
    let toks = [7u8, 11, 13, 17];
    let mut lanes: Vec<StepLane<'_>> = slate
        .iter_mut()
        .zip(toks)
        .map(|(cache, token)| StepLane { cache, token })
        .collect();
    let batched = forward_step_batch(&fused, &mut lanes);
    for (l, (cache, token)) in solo.iter_mut().zip(toks).enumerate() {
        let single = forward_step(&fused, cache, token);
        let row = &batched[l * cfg.vocab..(l + 1) * cfg.vocab];
        assert!(
            single.iter().zip(row).all(|(a, b)| a.to_bits() == b.to_bits()),
            "fused slate lane {l} diverged from single-lane decode"
        );
    }
}

#[test]
fn slate_decode_is_thread_count_invariant_on_fused() {
    // the pooled fused kernel must stream the exact token-by-token logits
    // of the sequential kernel through the whole session path: prefill,
    // then batched decode steps over an 8-lane slate, at 1/2/4/8 threads
    let q = LlvqShapeGain::new(Arc::new(LeechIndexer::new(3)), 1);
    let art = pack_tiny(&q, 31, true);
    let tmp = save_temp(&art, "slate-threads");
    let lanes_n = 8usize;
    let steps = 3usize;
    let run = |threads: usize| -> Vec<Vec<f32>> {
        let fused =
            ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), threads)
                .unwrap();
        let cfg = fused.cfg().clone();
        let mut caches: Vec<KvCache> = (0..lanes_n).map(|_| KvCache::new(&cfg)).collect();
        let mut out: Vec<Vec<f32>> = Vec::new();
        for (i, cache) in caches.iter_mut().enumerate() {
            out.push(prefill(&fused, cache, &[(i as u8) + 1, 2, 3]));
        }
        for step in 0..steps {
            let toks: Vec<u8> = (0..lanes_n).map(|l| ((step * 7 + l) % 64) as u8).collect();
            let mut lanes: Vec<StepLane<'_>> = caches
                .iter_mut()
                .zip(&toks)
                .map(|(cache, &token)| StepLane { cache, token })
                .collect();
            let flat = forward_step_batch(&fused, &mut lanes);
            out.extend(flat.chunks_exact(cfg.vocab).map(|c| c.to_vec()));
        }
        out
    };
    let want = run(1);
    for threads in [2usize, 4, 8] {
        let got = run(threads);
        assert_eq!(want.len(), got.len());
        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
            assert!(
                a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "threads={threads}: logit row {i} diverged from the sequential kernel"
            );
        }
    }
}

#[test]
fn prop_chunked_prefill_is_bit_identical_across_specs_and_threads() {
    // the pipelined-prefill scheduler's foundation: slicing a prompt into
    // resumable chunks must reproduce one-shot prefill logits bit for bit
    // on every quantizer spec, on the fused backend at 1 and 4 kernel
    // threads (and on the dense oracle), for every chunk size
    for (i, (name, q)) in five_quantizers().into_iter().enumerate() {
        let art = pack_tiny(q.as_ref(), 700 + i as u64, i % 2 == 1);
        let tmp = save_temp(&art, &format!("chunked-{name}"));
        let dense = ExecutionBackend::dense(art.weights.clone());
        let fused1 =
            ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), 1).unwrap();
        let fused4 =
            ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), 4).unwrap();
        let backends: [(&str, &dyn ForwardOps); 3] =
            [("dense", &dense), ("fused-t1", &fused1), ("fused-t4", &fused4)];
        check(&format!("chunked-prefill-{name}"), 3, |rng| {
            let plen = 2 + rng.next_range(40) as usize;
            let prompt: Vec<u8> = (0..plen).map(|_| rng.next_range(64) as u8).collect();
            let chunk = 1 + rng.next_range(9) as usize;
            for &(label, m) in &backends {
                let mut one = KvCache::new(m.cfg());
                let want = prefill(m, &mut one, &prompt);
                let mut chunked = KvCache::new(m.cfg());
                let got = prefill_chunked(m, &mut chunked, &prompt, chunk);
                if chunked.len() != prompt.len() {
                    return Err(format!("{name}/{label}: chunked cache length drifted"));
                }
                if want.iter().zip(&got).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err(format!(
                        "{name}/{label}: chunk={chunk} diverged from one-shot prefill"
                    ));
                }
            }
            Ok(())
        });
    }
}

/// Engine wrapper whose prefill sleeps per call, so mid-prefill states
/// stay observable over TCP.
struct SlowPrefill {
    inner: BackendEngine,
    delay: std::time::Duration,
}

impl BatchForward for SlowPrefill {
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }
    fn max_seq(&self) -> usize {
        self.inner.max_seq()
    }
    fn forward_batch(&self, batch: &[Vec<u8>]) -> Vec<Vec<f32>> {
        self.inner.forward_batch(batch)
    }
    fn open_session(&self) -> Box<dyn KvStore> {
        self.inner.open_session()
    }
    fn prefill(&self, cache: &mut dyn KvStore, tokens: &[u8]) -> Vec<f32> {
        std::thread::sleep(self.delay);
        self.inner.prefill(cache, tokens)
    }
    fn decode_step(&self, lanes: &mut [StepLane<'_>]) -> Vec<Vec<f32>> {
        self.inner.decode_step(lanes)
    }
    fn close_session(&self, cache: Box<dyn KvStore>) {
        self.inner.close_session(cache)
    }
    fn kv_counters(&self) -> Option<Arc<llvq::model::kvpage::KvPageCounters>> {
        self.inner.kv_counters()
    }
}

#[test]
fn disconnect_mid_prefill_frees_the_session_slot_over_tcp() {
    // a client that drops its connection while its FEED is still
    // queued/half-done must not leak the session: the cache is freed and
    // the (single) session slot becomes claimable again
    let cfg = config_by_name("qwen3-4b-tiny").unwrap();
    let engine = SlowPrefill {
        inner: BackendEngine::dense(Weights::random(&cfg, 8)),
        delay: std::time::Duration::from_millis(5),
    };
    let coord = Coordinator::start(
        Arc::new(engine),
        BatcherConfig {
            prefill_chunk: 1,
            max_sessions: 1,
            ..Default::default()
        },
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let c2 = coord.clone();
    std::thread::spawn(move || {
        let _ = serve_tcp_opts(c2, listener, ServeOptions { max_conns: 4 });
    });

    {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        writeln!(s, "OPEN").unwrap();
        assert!(read_line(&mut r).starts_with("OK session="));
        let toks: Vec<String> = (0..40).map(|i| (i % 64).to_string()).collect();
        writeln!(s, "FEED {}", toks.join(",")).unwrap();
        assert_eq!(read_line(&mut r), "QUEUED 40");
        // drop the connection with ~200 ms of prefill still queued
    }
    // the server-side cleanup closes the session; the slot must free
    let mut reclaimed = false;
    for _ in 0..200 {
        std::thread::sleep(std::time::Duration::from_millis(10));
        let mut s = match TcpStream::connect(addr) {
            Ok(s) => s,
            Err(_) => continue,
        };
        let mut r = BufReader::new(s.try_clone().unwrap());
        writeln!(s, "OPEN").unwrap();
        if read_line(&mut r).starts_with("OK session=") {
            reclaimed = true;
            writeln!(s, "QUIT").unwrap();
            break;
        }
    }
    assert!(reclaimed, "session slot never reclaimed after mid-prefill disconnect");
    coord.stop();
}

fn read_line(r: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    line.trim().to_string()
}

/// Drive one OPEN/FEED/GEN/CLOSE session over TCP; returns the streamed
/// token ids.
fn run_tcp_session(
    addr: std::net::SocketAddr,
    prefix: &str,
    n: usize,
    gen_args: &str,
) -> Vec<u8> {
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    writeln!(s, "OPEN").unwrap();
    let l = read_line(&mut r);
    assert!(l.starts_with("OK session="), "{l}");
    writeln!(s, "FEED {prefix}").unwrap();
    let l = read_line(&mut r);
    assert!(l.starts_with("QUEUED "), "{l}");
    writeln!(s, "GEN {n}{gen_args}").unwrap();
    let mut toks = Vec::new();
    loop {
        let l = read_line(&mut r);
        if let Some(t) = l.strip_prefix("TOK ") {
            toks.push(t.parse::<u8>().unwrap());
        } else {
            assert!(
                l.starts_with(&format!("OK generated={n}")),
                "unexpected GEN terminator: {l}"
            );
            break;
        }
    }
    writeln!(s, "CLOSE").unwrap();
    let l = read_line(&mut r);
    assert!(l.starts_with("OK closed len="), "{l}");
    writeln!(s, "QUIT").unwrap();
    toks
}

#[test]
fn tcp_v2_protocol_generates_streams_and_replays_deterministically() {
    // end-to-end over the wire on the fused backend: OPEN → FEED → GEN
    // with a seeded sampler → CLOSE, exercised twice (same seed ⇒ same
    // stream), plus greedy GEN ≡ repeated NEXT with the growing prefix
    let q = LlvqShapeGain::new(Arc::new(LeechIndexer::new(3)), 1);
    let art = pack_tiny(&q, 77, false);
    let tmp = save_temp(&art, "tcp");
    let fused =
        ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), 2).unwrap();
    let coord = Coordinator::start(
        Arc::new(BackendEngine::new(fused)),
        BatcherConfig::default(),
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let c2 = coord.clone();
    std::thread::spawn(move || {
        let _ = serve_tcp_opts(c2, listener, ServeOptions { max_conns: 8 });
    });

    // seeded sampling replays exactly
    let a = run_tcp_session(addr, "5,6,7,8", 6, " temp=0.9 topk=8 seed=42");
    let b = run_tcp_session(addr, "5,6,7,8", 6, " temp=0.9 topk=8 seed=42");
    assert_eq!(a.len(), 6);
    assert!(a.iter().all(|&t| (t as usize) < 64));
    assert_eq!(a, b, "same seed must replay the same stream");
    let c = run_tcp_session(addr, "5,6,7,8", 6, " temp=0.9 topk=8 seed=43");
    assert!(c.len() == 6 && c.iter().all(|&t| (t as usize) < 64));

    // greedy GEN over a session ≡ repeated NEXT with the growing prefix
    let greedy = run_tcp_session(addr, "5,6,7,8", 5, "");
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut prefix: Vec<String> = vec!["5".into(), "6".into(), "7".into(), "8".into()];
    for (i, &want) in greedy.iter().enumerate() {
        writeln!(s, "NEXT {}", prefix.join(",")).unwrap();
        let l = read_line(&mut r);
        let got: u8 = l
            .strip_prefix("OK next=")
            .unwrap_or_else(|| panic!("bad NEXT reply: {l}"))
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(got, want, "greedy GEN token {i} != NEXT oracle");
        prefix.push(want.to_string());
    }
    // STATS reflects the session traffic; resident_bytes stays last
    writeln!(s, "STATS").unwrap();
    let l = read_line(&mut r);
    assert!(l.contains("backend=fused"), "{l}");
    assert!(l.contains("gen_tokens="), "{l}");
    let resident: usize = l.rsplit('=').next().unwrap().parse().unwrap();
    assert!(
        resident as f64 <= 1.1 * art.packed.code_bytes() as f64,
        "fused serving must stay at code-byte residency: {l}"
    );
    writeln!(s, "QUIT").unwrap();
    coord.stop();
}

#[test]
fn tcp_error_paths_and_connection_cap() {
    let cfg = config_by_name("qwen3-4b-tiny").unwrap();
    let coord = Coordinator::start(
        Arc::new(BackendEngine::dense(Weights::random(&cfg, 4))),
        BatcherConfig::default(),
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let c2 = coord.clone();
    std::thread::spawn(move || {
        let _ = serve_tcp_opts(c2, listener, ServeOptions { max_conns: 1 });
    });

    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    // v2 misuse answers ERR lines, never silence
    writeln!(s, "FEED 1,2").unwrap();
    assert!(read_line(&mut r).starts_with("ERR no open session"));
    writeln!(s, "GEN 3").unwrap();
    assert!(read_line(&mut r).starts_with("ERR no open session"));
    writeln!(s, "CLOSE").unwrap();
    assert!(read_line(&mut r).starts_with("ERR no open session"));
    writeln!(s, "OPEN").unwrap();
    assert!(read_line(&mut r).starts_with("OK session="));
    writeln!(s, "OPEN").unwrap();
    assert!(read_line(&mut r).starts_with("ERR session already open"));
    writeln!(s, "GEN 2").unwrap();
    assert!(read_line(&mut r).starts_with("ERR FEED"), "GEN before FEED");
    // bad token ids are rejected at parse/validate time (poison fix)
    writeln!(s, "FEED 1,999").unwrap();
    assert!(read_line(&mut r).starts_with("ERR bad token list"));
    writeln!(s, "NEXT 1,200").unwrap();
    assert!(read_line(&mut r).contains("out of range"));
    writeln!(s, "GEN x").unwrap();
    assert!(read_line(&mut r).starts_with("ERR bad GEN"));
    writeln!(s, "GEN 3 warp=9").unwrap();
    assert!(read_line(&mut r).contains("unknown sampling arg"));

    // the second concurrent connection is refused with ERR busy
    let s2 = TcpStream::connect(addr).unwrap();
    let mut r2 = BufReader::new(s2);
    assert!(
        read_line(&mut r2).starts_with("ERR busy"),
        "connection cap must answer ERR busy"
    );

    // the capped slot frees after QUIT: a later connection gets served
    writeln!(s, "QUIT").unwrap();
    drop(r);
    drop(s);
    let served = (0..100).any(|_| {
        std::thread::sleep(std::time::Duration::from_millis(10));
        let mut s3 = match TcpStream::connect(addr) {
            Ok(s3) => s3,
            Err(_) => return false,
        };
        writeln!(s3, "STATS").unwrap();
        let mut r3 = BufReader::new(s3);
        read_line(&mut r3).starts_with("OK requests=")
    });
    assert!(served, "slot never freed after QUIT");
    coord.stop();
}
