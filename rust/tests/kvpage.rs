//! Paged-KV integration tier: the paged cache against the dense oracle
//! across quantizer specs, backends, kernel thread counts, and page
//! geometry.
//!
//! The load-bearing property (`paged-kv-bit-identity`, seed-replayed from
//! `proptest-regressions/`): with `--kv-quant none` a [`PagedKvCache`] is
//! a pure re-layout — prefill plus greedy steps must reproduce the dense
//! [`KvCache`] logits bit for bit on every backend, for every page size
//! and hot window. Quantized cold pages are lossy by design, so they get
//! weaker (but still pinned) assertions: deterministic replay, real
//! arena-page release on cooling, and greedy argmax parity on seeded
//! prompts.

use std::sync::Arc;

use llvq::coordinator::{BackendEngine, BatchForward};
use llvq::model::backend::ExecutionBackend;
use llvq::model::config::config_by_name;
use llvq::model::kvpage::{KvCodec, KvQuantKind, PageArena, PagedKvCache};
use llvq::model::packed::PackedFile;
use llvq::model::sample::argmax;
use llvq::model::transformer::{forward_step, prefill, ForwardOps, KvCache, KvStore, Weights};
use llvq::pipeline::driver::{quantize_model_packed, PtqOptions};
use llvq::pipeline::rotation::RotationMode;
use llvq::quant::e8::{E8Codebook, E8Cut};
use llvq::quant::llvq::LlvqSpherical;
use llvq::quant::scalar::UniformQuantizer;
use llvq::quant::VectorQuantizer;
use llvq::util::proptest::{check, TempArtifact};

/// Weight-quantizer specs whose backends the paged cache must be
/// layout-transparent over (a subset of the five: enough to cover
/// scalar, E8, and Leech code paths without a minutes-long tier-1).
fn specs() -> Vec<(&'static str, Box<dyn VectorQuantizer>)> {
    vec![
        (
            "uniform",
            Box::new(UniformQuantizer::new_gaussian_optimal(4)) as Box<dyn VectorQuantizer>,
        ),
        ("e8", Box::new(E8Codebook::new(E8Cut::Ball))),
        (
            "llvq-spherical",
            Box::new(LlvqSpherical::with_scale(
                Arc::new(llvq::leech::index::LeechIndexer::new(3)),
                0.9,
            )),
        ),
    ]
}

/// Dense-vs-paged bit-identity over one backend for one geometry.
fn assert_paged_matches_dense<M: ForwardOps + ?Sized>(
    m: &M,
    prompt: &[u8],
    steps: usize,
    page_tokens: usize,
    hot_window: usize,
    label: &str,
) -> Result<(), String> {
    let cfg = m.cfg();
    let total = prompt.len() + steps;
    let arena = PageArena::new(cfg, total.div_ceil(page_tokens), page_tokens);
    let mut paged = PagedKvCache::new(cfg, Arc::clone(&arena), None, hot_window);
    let mut dense = KvCache::new(cfg);
    let a = prefill(m, &mut dense, prompt);
    let b = prefill(m, &mut paged, prompt);
    if a.iter().zip(&b).any(|(x, y)| x.to_bits() != y.to_bits()) {
        return Err(format!("{label}: prefill logits diverged"));
    }
    // greedy continuation, stepping both caches with the dense argmax
    let mut logits = a;
    for s in 0..steps {
        let t = argmax(&logits) as u8;
        let x = forward_step(m, &mut dense, t);
        let y = forward_step(m, &mut paged, t);
        if x.iter().zip(&y).any(|(p, q)| p.to_bits() != q.to_bits()) {
            return Err(format!(
                "{label}: step {s} diverged (page_tokens={page_tokens} hot={hot_window})"
            ));
        }
        logits = x;
    }
    if paged.len() != dense.len() || paged.len() != total {
        return Err(format!("{label}: cache length drifted"));
    }
    if paged.page_count() != total.div_ceil(page_tokens) {
        return Err(format!("{label}: unexpected page count"));
    }
    drop(paged);
    let leaked = arena.counters().allocated.load(std::sync::atomic::Ordering::Relaxed);
    if leaked != 0 {
        return Err(format!("{label}: dropped cache leaked {leaked} pages"));
    }
    Ok(())
}

#[test]
fn prop_paged_kv_bit_identity_across_specs_backends_and_geometry() {
    // the paged-vs-dense pin, mirroring the chunked-prefill property:
    // quant=none paging is invisible to the math on the dense oracle and
    // the fused backend at 1 and 4 kernel threads, for random prompts,
    // page sizes, and hot windows (including hot=0: every full page
    // "cools" — a no-op without a codec, but it walks the cooling path)
    for (i, (name, q)) in specs().into_iter().enumerate() {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 900 + i as u64);
        let opts = PtqOptions {
            calib_seqs: 2,
            rotation: RotationMode::Input,
            ..Default::default()
        };
        let art = quantize_model_packed(&w, q.as_ref(), &opts);
        let tmp = TempArtifact::new(&format!("kvpage-{name}"), "llvqm");
        art.packed.save(tmp.path()).unwrap();
        let dense = ExecutionBackend::dense(art.weights.clone());
        let fused1 =
            ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), 1).unwrap();
        let fused4 =
            ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), 4).unwrap();
        let backends: [(&str, &dyn ForwardOps); 3] =
            [("dense", &dense), ("fused-t1", &fused1), ("fused-t4", &fused4)];
        check(&format!("paged-kv-bit-identity-{name}"), 3, |rng| {
            let plen = 2 + rng.next_range(30) as usize;
            let prompt: Vec<u8> = (0..plen).map(|_| rng.next_range(64) as u8).collect();
            let steps = 1 + rng.next_range(8) as usize;
            let page_tokens = 1 + rng.next_range(9) as usize;
            let hot_window = rng.next_range(24) as usize;
            for &(label, m) in &backends {
                assert_paged_matches_dense(
                    m,
                    &prompt,
                    steps,
                    page_tokens,
                    hot_window,
                    &format!("{name}/{label}"),
                )?;
            }
            Ok(())
        });
    }
}

#[test]
fn quantized_cold_pages_replay_deterministically_and_release_pages() {
    // lossy cold storage still has exact obligations: the same token run
    // must produce the same logits twice (encode/decode is a pure
    // function), cooling must hand hot buffers back to the arena, and
    // occupancy accounting must balance
    let cfg = config_by_name("qwen3-4b-tiny").unwrap();
    let w = Weights::random(&cfg, 77);
    let prompt: Vec<u8> = (0..24).map(|i| (i * 11 % 64) as u8).collect();
    for kind in [KvQuantKind::E8, KvQuantKind::Llvq] {
        let codec = KvCodec::build(kind, cfg.d_model).unwrap();
        let run = || {
            let arena = PageArena::new(&cfg, 16, 4);
            let mut cache = PagedKvCache::new(&cfg, Arc::clone(&arena), codec.clone(), 4);
            let mut logits = prefill(&w, &mut cache, &prompt);
            for _ in 0..4 {
                logits = forward_step(&w, &mut cache, argmax(&logits) as u8);
            }
            let cold = cache.cold_page_count();
            let hot_allocated = arena
                .counters()
                .allocated
                .load(std::sync::atomic::Ordering::Relaxed);
            (logits, cold, hot_allocated, cache.page_count())
        };
        let (l1, cold, hot_allocated, total_pages) = run();
        let (l2, cold2, ..) = run();
        assert_eq!(cold, cold2, "{kind:?}: cooling not deterministic");
        assert!(cold > 0, "{kind:?}: the 4-token hot window never cooled a page");
        assert_eq!(
            hot_allocated,
            total_pages - cold,
            "{kind:?}: arena occupancy out of balance with cold-page count"
        );
        assert!(
            l1.iter().zip(&l2).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{kind:?}: quantized replay diverged"
        );
    }
}

#[test]
fn paged_engine_packs_more_sessions_into_the_same_bytes() {
    // the subsystem's reason to exist, measured through the engine
    // surface: under a byte budget equal to FOUR dense worst-case caches,
    // a paged engine holds many more live 8-token sessions
    let cfg = config_by_name("qwen3-4b-tiny").unwrap();
    let dense_cache_bytes = cfg.n_layers * 2 * cfg.max_seq * cfg.d_model * 4;
    let page_tokens = 8usize;
    let page_bytes = cfg.n_layers * 2 * page_tokens * cfg.d_model * 4;
    let budget_bytes = 4 * dense_cache_bytes;
    let pages = budget_bytes / page_bytes;
    let engine = BackendEngine::paged(
        ExecutionBackend::dense(Weights::random(&cfg, 5)),
        pages,
        page_tokens,
        16,
        KvQuantKind::None,
    )
    .unwrap();
    assert_eq!(engine.kv_page_budget(), pages);
    let mut sessions = Vec::new();
    loop {
        let mut c = engine.open_session();
        if c.reserve(page_tokens).is_err() {
            break;
        }
        engine.prefill(c.as_mut(), &vec![3u8; page_tokens]);
        sessions.push(c);
    }
    assert_eq!(sessions.len(), pages, "every page should host one session");
    assert!(
        sessions.len() > 4 * 2,
        "paged admission ({}) should beat dense worst-case (4) by far",
        sessions.len()
    );
    // and they all come back
    for c in sessions {
        engine.close_session(c);
    }
    assert_eq!(
        engine
            .kv_counters()
            .unwrap()
            .allocated
            .load(std::sync::atomic::Ordering::Relaxed),
        0
    );
}
