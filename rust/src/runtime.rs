//! PJRT runtime — loads AOT-compiled JAX/Pallas artifacts and executes
//! them from the rust request path (Python is never loaded at runtime).
//!
//! Interchange format is **HLO text**: `python/compile/aot.py` lowers
//! jitted functions with `return_tuple=True`; this module parses the text
//! with `HloModuleProto::from_text_file`, compiles on the PJRT CPU client,
//! and wraps execution with typed literal conversion. Compiled executables
//! are cached per **canonicalized** artifact path, so `./a.hlo` and
//! `a.hlo` share one compilation.
//!
//! The PJRT bridge depends on the external `xla` and `anyhow` crates,
//! which the offline build cannot vendor. The real implementation is
//! therefore gated behind `RUSTFLAGS="--cfg pjrt_runtime"` (add `xla` and
//! `anyhow` to Cargo.toml when enabling it); the default build exposes a
//! stub [`Runtime`] whose constructor reports the missing backend, so
//! callers can degrade gracefully. Artifact-path helpers are unconditional.

use std::path::{Path, PathBuf};

/// Cache key for compiled artifacts: the canonicalized path when the file
/// exists (collapsing `./a.hlo` vs `a.hlo` vs symlinks to one entry), the
/// verbatim path otherwise (the subsequent open will produce the real
/// error).
#[cfg_attr(not(pjrt_runtime), allow(dead_code))] // used by the gated impl + tests
pub(crate) fn cache_key(path: &Path) -> PathBuf {
    std::fs::canonicalize(path).unwrap_or_else(|_| path.to_path_buf())
}

#[cfg(pjrt_runtime)]
mod imp {
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::Mutex;

    use anyhow::{Context, Result};

    use super::cache_key;

    /// A thin registry of compiled executables over one PJRT client.
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<std::path::PathBuf, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self {
                client,
                cache: Mutex::new(HashMap::new()),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load (or fetch from cache) an HLO-text artifact. The cache is
        /// keyed on the canonicalized path so spelling variants of the
        /// same file compile exactly once.
        pub fn load(&self, path: &Path) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
            let key = cache_key(path);
            if let Some(e) = self.cache.lock().unwrap_or_else(|e| e.into_inner()).get(&key) {
                return Ok(e.clone());
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = std::sync::Arc::new(
                self.client
                    .compile(&comp)
                    .with_context(|| format!("compiling {}", path.display()))?,
            );
            self.cache
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .insert(key, exe.clone());
            Ok(exe)
        }

        /// Execute with f32 input buffers of the given shapes; returns the
        /// flattened f32 outputs of the result tuple.
        pub fn run_f32(
            &self,
            exe: &xla::PjRtLoadedExecutable,
            inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<Vec<f32>>> {
            let lits = inputs
                .iter()
                .map(|(data, shape)| {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data).reshape(&dims).map_err(Into::into)
                })
                .collect::<Result<Vec<_>>>()?;
            self.run_literals(exe, &lits).and_then(|outs| {
                outs.iter()
                    .map(|l| l.to_vec::<f32>().map_err(Into::into))
                    .collect()
            })
        }

        /// Execute with i64 + f32 mixed inputs (for the dequant kernel,
        /// which takes index arrays and table arrays).
        pub fn run_mixed(
            &self,
            exe: &xla::PjRtLoadedExecutable,
            int_inputs: &[(&[i64], &[usize])],
            f32_inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<xla::Literal>> {
            let mut lits = Vec::with_capacity(int_inputs.len() + f32_inputs.len());
            for (data, shape) in int_inputs {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                lits.push(xla::Literal::vec1(data).reshape(&dims)?);
            }
            for (data, shape) in f32_inputs {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                lits.push(xla::Literal::vec1(data).reshape(&dims)?);
            }
            self.run_literals(exe, &lits)
        }

        /// Core execution: run and unpack the (tupled) result.
        pub fn run_literals(
            &self,
            exe: &xla::PjRtLoadedExecutable,
            inputs: &[xla::Literal],
        ) -> Result<Vec<xla::Literal>> {
            let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True → decompose the tuple
            let outs = result.to_tuple()?;
            Ok(outs)
        }
    }
}

#[cfg(not(pjrt_runtime))]
mod imp {
    /// Stub runtime for builds without the PJRT bridge: constructing it
    /// reports the missing backend so callers degrade gracefully.
    pub struct Runtime;

    impl Runtime {
        pub fn cpu() -> Result<Self, String> {
            Err("PJRT runtime not compiled in — rebuild with \
                 RUSTFLAGS=\"--cfg pjrt_runtime\" and the `xla`/`anyhow` \
                 dependencies added to Cargo.toml"
                .to_string())
        }

        pub fn platform(&self) -> String {
            "stub".to_string()
        }
    }
}

pub use imp::Runtime;

/// Canonical artifact locations relative to the repo root.
pub fn artifact_dir() -> PathBuf {
    std::env::var("LLVQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

pub fn artifact(name: &str) -> PathBuf {
    artifact_dir().join(name)
}

/// True when `make artifacts` has produced the AOT bundle (tests that need
/// PJRT skip politely otherwise).
pub fn artifacts_available() -> bool {
    artifact("config.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_key_collapses_path_spellings() {
        // `dir/f` and `dir/./f` must map to one cache entry once the file
        // exists — the executable-cache regression this key fixes.
        let dir = std::env::temp_dir().join("llvq_cache_key_test");
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("artifact.hlo.txt");
        std::fs::write(&f, "dummy").unwrap();
        let plain = cache_key(&f);
        let dotted = cache_key(&dir.join(".").join("artifact.hlo.txt"));
        assert_eq!(plain, dotted);
        // missing files fall back to the verbatim path (no panic)
        let missing = dir.join("nope.hlo.txt");
        assert_eq!(cache_key(&missing), missing);
        let _ = std::fs::remove_file(&f);
    }

    #[test]
    fn stub_or_real_runtime_reports_platform_shape() {
        // Whichever implementation is compiled in, the constructor must be
        // callable; the stub must explain itself rather than panic.
        match Runtime::cpu() {
            Ok(rt) => assert!(!rt.platform().is_empty()),
            Err(e) => {
                let msg = format!("{e:?}");
                assert!(msg.contains("PJRT") || msg.contains("pjrt"), "{msg}");
            }
        }
    }
}
