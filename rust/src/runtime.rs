//! PJRT runtime — loads AOT-compiled JAX/Pallas artifacts and executes
//! them from the rust request path (Python is never loaded at runtime).
//!
//! Interchange format is **HLO text** (see /opt-level docs in
//! DESIGN.md §1): `python/compile/aot.py` lowers jitted functions with
//! `return_tuple=True`; this module parses the text with
//! `HloModuleProto::from_text_file`, compiles on the PJRT CPU client, and
//! wraps execution with typed literal conversion. Compiled executables are
//! cached per artifact path.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

/// A thin registry of compiled executables over one PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (or fetch from cache) an HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(path) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?,
        );
        self.cache
            .lock()
            .unwrap()
            .insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    /// Execute with f32 input buffers of the given shapes; returns the
    /// flattened f32 outputs of the result tuple.
    pub fn run_f32(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let lits = inputs
            .iter()
            .map(|(data, shape)| {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims).map_err(Into::into)
            })
            .collect::<Result<Vec<_>>>()?;
        self.run_literals(exe, &lits)
            .and_then(|outs| outs.iter().map(|l| l.to_vec::<f32>().map_err(Into::into)).collect())
    }

    /// Execute with i64 + f32 mixed inputs (for the dequant kernel, which
    /// takes index arrays and table arrays).
    pub fn run_mixed(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        int_inputs: &[(&[i64], &[usize])],
        f32_inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<xla::Literal>> {
        let mut lits = Vec::with_capacity(int_inputs.len() + f32_inputs.len());
        for (data, shape) in int_inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            lits.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        for (data, shape) in f32_inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            lits.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        self.run_literals(exe, &lits)
    }

    /// Core execution: run and unpack the (tupled) result.
    pub fn run_literals(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → decompose the tuple
        let outs = result.to_tuple()?;
        Ok(outs)
    }
}

/// Canonical artifact locations relative to the repo root.
pub fn artifact_dir() -> PathBuf {
    std::env::var("LLVQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

pub fn artifact(name: &str) -> PathBuf {
    artifact_dir().join(name)
}

/// True when `make artifacts` has produced the AOT bundle (tests that need
/// PJRT skip politely otherwise).
pub fn artifacts_available() -> bool {
    artifact("config.json").exists()
}
