//! `llvq` — command-line entry point of the LLVQ coordinator.
//!
//! Subcommands:
//! * `exp <id>` — regenerate a paper table/figure (table1, table2, fig1,
//!   table4, table3, table5, table6, fig6, table7, all).
//! * `tables` — export the kernel dequantization tables as JSON.
//! * `quantize` — PTQ a model artifact with a chosen method (dense out).
//! * `pack` — PTQ a model and write the packed `.llvqm` artifact.
//! * `unpack` — expand a `.llvqm` back to a dense `.llvqw`.
//! * `stats` — header-only stats of a `.llvqm` (no payload read).
//! * `eval` — evaluate a model artifact (PPL + probes).
//! * `serve` — start the batching + generation inference server (TCP line
//!   protocol, v1 `NEXT` and v2 `OPEN`/`FEED`/`GEN`/`CLOSE` sessions);
//!   `--packed <file>` serves a packed artifact, `--backend
//!   dense|cached|fused` picks how its layers execute (dequantized at
//!   load / lazily decoded on first touch / matvec over the bit-packed
//!   code streams — no dense materialization at all), `--threads` sizes
//!   the persistent kernel pool the fused matmul and cached first-touch
//!   decode row-shard over, `--simd` forces the fused SIMD kernel
//!   (off|scalar|avx2|neon|portable; default `LLVQ_SIMD`, then runtime
//!   detection), `--prefill-chunk` bounds the prompt tokens a
//!   queued FEED may prefill per scheduler tick (pipelined
//!   prefill-while-decoding: a long prompt no longer stalls active
//!   generations), `--max-sessions` / `--max-conns` bound the session and
//!   connection pools, and `--kv-pages`/`--kv-page-size`/`--kv-quant`/
//!   `--kv-hot` switch sessions from dense worst-case caches to paged KV
//!   over a shared arena with optionally lattice-quantized cold pages
//!   (admission answers `ERR kv-oom` when the arena is exhausted).
//! * `serve-http` — the HTTP/SSE front door: `POST /v1/completions`
//!   (SSE-streamed or fixed-length), `GET /v1/models`, `GET /metrics`
//!   over a multi-model registry (`--model name=path[,name=path...]`,
//!   header-only registration, backends built on first request, LRU
//!   hot-set eviction under `--max-resident-bytes`). Flag glossary:
//!   `docs/OPERATIONS.md`; wire reference: `docs/PROTOCOL.md`.
//! * `sim` — deterministic scheduler simulator: replay a named workload
//!   scenario (`--scenario burst --seed 7`) or a committed `.trace` file
//!   (`--trace rust/tests/sim_traces/smoke.trace`) on a virtual clock —
//!   no threads, sockets, or wall time — with per-tick invariant checks;
//!   `--step` prints the occupancy dump every tick, `--save-trace`
//!   exports the run as a canonical trace for committing as a
//!   regression test, and a violation exits 1.
//! * `lint` — repo-native static analysis (see `LINTS.md`): run the
//!   in-tree rule engine over `rust/` and `examples/` and exit 1 on any
//!   finding; `--json` emits the deterministic machine report,
//!   `--rule <name>` restricts output to one rule, `--list` names the
//!   rule set. `scripts/verify.sh` and CI's lint job gate on it.
//! * `generate` — KV-cached local generation from a prompt (greedy /
//!   temperature / top-k, seeded), over any backend (`--threads` and the
//!   `--kv-*` paging flags as in `serve`).
//! * `gen-model` — write a random-weight model (testing without python).
//! * `info` — lattice summary (shell sizes, codebook bits, table VMEM).

use std::sync::Arc;

use llvq::coordinator::{BackendEngine, BatchForward, BatcherConfig, Coordinator, ServeOptions};
use llvq::experiments as exp;
use llvq::leech::index::LeechIndexer;
use llvq::leech::tables::KernelTables;
use llvq::model::backend::{BackendKind, ExecutionBackend};
use llvq::model::config::{config_by_name, model_zoo, ModelConfig};
use llvq::model::eval::evaluate;
use llvq::model::io as model_io;
use llvq::model::kvpage::KvQuantKind;
use llvq::model::packed::{PackedFile, PackedModel};
use llvq::model::sample::{SampleParams, Sampler};
use llvq::model::transformer::{forward_step, prefill, KvStore, Weights};
use llvq::pipeline::driver::{quantize_model, quantize_model_packed, PtqOptions};
use llvq::pipeline::rotation::RotationMode;
use llvq::quant::kernel::Kernel;
use llvq::quant::VectorQuantizer;
use llvq::util::cli::Args;
use llvq::util::threadpool;

fn main() {
    let mut argv = std::env::args().skip(1);
    let cmd = argv.next().unwrap_or_else(|| "help".to_string());
    let rest: Vec<String> = argv.collect();
    let code = match cmd.as_str() {
        "exp" => cmd_exp(rest),
        "tables" => cmd_tables(rest),
        "quantize" => cmd_quantize(rest),
        "pack" => cmd_pack(rest),
        "unpack" => cmd_unpack(rest),
        "stats" => cmd_stats(rest),
        "eval" => cmd_eval(rest),
        "serve" => cmd_serve(rest),
        "serve-http" => cmd_serve_http(rest),
        "sim" => cmd_sim(rest),
        "lint" => cmd_lint(rest),
        "generate" => cmd_generate(rest),
        "gen-model" => cmd_gen_model(rest),
        "info" => cmd_info(rest),
        _ => {
            eprintln!(
                "usage: llvq <exp|tables|quantize|pack|unpack|stats|eval|serve|serve-http|sim|lint|generate|gen-model|info> [flags]\n\
                 try: llvq exp table1"
            );
            2
        }
    };
    std::process::exit(code);
}

/// The pack stats line: on-disk bytes and the effective rate of the file
/// (codes + header + fp32 embeddings/norms) over the linear parameters.
/// Takes the exact code-bit count so callers can feed it from a full
/// [`PackedModel`] or a header-only [`llvq::model::packed::PackedMeta`].
fn packed_stats_line(file_bytes: usize, code_bits: u64, cfg: &ModelConfig) -> String {
    let linear = cfg.num_linear_params().max(1);
    format!(
        "on-disk {file_bytes} B | effective {:.4} bits/weight over {linear} linear \
         params (codes alone: {:.4} bpw; fp32 dense parts included in the file)",
        file_bytes as f64 * 8.0 / linear as f64,
        code_bits as f64 / linear as f64,
    )
}

fn effort_from(a: &Args) -> exp::Effort {
    let mut e = if a.get_bool("quick") {
        exp::Effort::quick()
    } else {
        exp::Effort::default()
    };
    if let Some(n) = a.get("leech-blocks").and_then(|v| v.parse().ok()) {
        e.leech_blocks = n;
    }
    if let Some(n) = a.get("eval-seqs").and_then(|v| v.parse().ok()) {
        e.eval_seqs = n;
    }
    e
}

fn cmd_exp(rest: Vec<String>) -> i32 {
    let a = Args::new("llvq exp <id> — regenerate a paper table/figure")
        .switch("quick", "reduced sample counts")
        .switch("allow-random", "fall back to random weights if artifacts missing")
        .flag("leech-blocks", "", "override Leech-quantizer sample blocks")
        .flag("eval-seqs", "", "override eval sequence count")
        .parse(rest.into_iter())
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    let e = effort_from(&a);
    let allow_random = a.get_bool("allow-random");
    let ids: Vec<String> = if a.positional().is_empty() {
        vec!["all".into()]
    } else {
        a.positional().to_vec()
    };
    for id in ids {
        let run_all = id == "all";
        let ok: Result<(), String> = (|| {
            let mut matched = run_all;
            if run_all || id == "table1" {
                exp::table1(true);
                matched = true;
            }
            if run_all || id == "table2" {
                exp::table2();
                matched = true;
            }
            if run_all || id == "fig1" {
                exp::fig1(&e);
                matched = true;
            }
            if run_all || id == "table4" {
                exp::table4(&e);
                matched = true;
            }
            if run_all || id == "table7" {
                exp::table7(&e);
                matched = true;
            }
            if run_all || id == "fig6" {
                exp::fig6(&e);
                matched = true;
            }
            if run_all || id == "table3" {
                exp::table3(&e, allow_random)?;
                matched = true;
            }
            if run_all || id == "table5" {
                exp::table5(&e, allow_random)?;
                matched = true;
            }
            if run_all || id == "table6" {
                exp::table6(&e, allow_random)?;
                matched = true;
            }
            if !matched {
                return Err(format!("unknown experiment id '{id}'"));
            }
            Ok(())
        })();
        if let Err(msg) = ok {
            eprintln!("experiment {id} failed: {msg}");
            return 1;
        }
    }
    0
}

fn cmd_tables(rest: Vec<String>) -> i32 {
    let a = Args::new("llvq tables — export kernel dequant tables as JSON")
        .flag("max-m", "13", "ball cut (max shell)")
        .flag("out", "artifacts/tables.rust.json", "output path")
        .parse(rest.into_iter())
        .unwrap();
    let max_m = a.get_usize("max-m");
    let ix = LeechIndexer::new(max_m);
    let t = KernelTables::build(&ix);
    let json = t.to_json().to_string_compact();
    let out = a.get("out").unwrap();
    if let Some(dir) = std::path::Path::new(&out).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(&out, &json) {
        Ok(()) => {
            println!(
                "wrote {} groups ({} points, {} bits, ~{} B VMEM) to {out}",
                t.num_groups,
                t.num_points(),
                ix.index_bits(),
                t.vmem_bytes()
            );
            0
        }
        Err(e) => {
            eprintln!("write failed: {e}");
            1
        }
    }
}

fn parse_method(name: &str) -> Option<exp::Method> {
    match name {
        "scalar" | "gptq" => Some(exp::Method::ScalarGptq),
        "e8p" => Some(exp::Method::E8p),
        "llvq-spherical" | "spherical" => Some(exp::Method::LlvqSpherical),
        "llvq-shape-gain" | "shape-gain" => Some(exp::Method::LlvqShapeGain),
        _ => None,
    }
}

/// Everything the PTQ subcommands (`quantize`, `pack`) resolve from their
/// shared flags: zoo config, source weights, quantizer, and PTQ options.
struct PtqSetup {
    cfg: ModelConfig,
    w: Weights,
    q: Box<dyn VectorQuantizer>,
    method_name: String,
    opts: PtqOptions,
}

/// Resolve the shared `--model/--method/--rotation/--finetune/--allow-random`
/// flags; `Err` carries the process exit code (usage errors already printed).
fn ptq_setup(a: &Args) -> Result<PtqSetup, i32> {
    let cfg = match config_by_name(&a.get("model").unwrap()) {
        Some(c) => c,
        None => {
            eprintln!(
                "unknown model; zoo: {:?}",
                model_zoo().iter().map(|c| c.name.clone()).collect::<Vec<_>>()
            );
            return Err(2);
        }
    };
    let w = match exp::load_model(&cfg, a.get_bool("allow-random")) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("{e}");
            return Err(1);
        }
    };
    let method_name = a.get("method").unwrap();
    let method = match parse_method(&method_name) {
        Some(m) => m,
        None => {
            eprintln!("unknown method {method_name}");
            return Err(2);
        }
    };
    let rotation = match a.get("rotation").unwrap().as_str() {
        "none" => RotationMode::None,
        "input" => RotationMode::Input,
        "input+output" => RotationMode::InputOutput,
        other => {
            eprintln!("unknown rotation '{other}' (none|input|input+output)");
            return Err(2);
        }
    };
    let opts = PtqOptions {
        rotation,
        finetune_scales: a.get_bool("finetune"),
        ..Default::default()
    };
    Ok(PtqSetup {
        cfg,
        w,
        q: method.build(),
        method_name,
        opts,
    })
}

fn cmd_quantize(rest: Vec<String>) -> i32 {
    let a = Args::new("llvq quantize — PTQ a model artifact")
        .flag("model", "llama2-tiny", "model name from the zoo")
        .flag("method", "llvq-shape-gain", "scalar|e8p|llvq-spherical|llvq-shape-gain")
        .flag("rotation", "input+output", "none|input|input+output")
        .switch("finetune", "closed-form per-column scale finetuning")
        .switch("allow-random", "use random weights if artifact missing")
        .flag("out", "", "output .llvqw path (default artifacts/<model>.<method>.llvqw)")
        .parse(rest.into_iter())
        .unwrap();
    let s = match ptq_setup(&a) {
        Ok(s) => s,
        Err(code) => return code,
    };
    println!("quantizing {} with {} …", s.cfg.name, s.q.name());
    let t0 = std::time::Instant::now();
    let (wq, rep) = quantize_model(&s.w, s.q.as_ref(), &s.opts);
    println!(
        "done in {:.1}s — {:.4} bits/weight over {} linear params",
        t0.elapsed().as_secs_f64(),
        rep.bits_per_weight(),
        rep.total_params
    );
    let out = {
        let o = a.get("out").unwrap();
        if o.is_empty() {
            llvq::runtime::artifact(&format!("{}.{}.llvqw", s.cfg.name, s.method_name))
        } else {
            o.into()
        }
    };
    if let Some(dir) = out.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    if let Err(e) = model_io::save(&wq, &out) {
        eprintln!("save failed: {e}");
        return 1;
    }
    println!("wrote {}", out.display());
    0
}

fn cmd_pack(rest: Vec<String>) -> i32 {
    let a = Args::new("llvq pack — PTQ a model and write the packed .llvqm artifact")
        .flag("model", "llama2-tiny", "model name from the zoo")
        .flag("method", "llvq-shape-gain", "scalar|e8p|llvq-spherical|llvq-shape-gain")
        .flag("rotation", "input+output", "none|input|input+output")
        .switch("finetune", "closed-form per-column scale finetuning")
        .switch("allow-random", "use random weights if artifact missing")
        .flag("out", "", "output .llvqm path (default artifacts/<model>.<method>.llvqm)")
        .flag("dense-out", "", "also write the dequantized dense .llvqw here")
        .parse(rest.into_iter())
        .unwrap();
    let s = match ptq_setup(&a) {
        Ok(s) => s,
        Err(code) => return code,
    };
    println!("packing {} with {} …", s.cfg.name, s.q.name());
    let t0 = std::time::Instant::now();
    let art = quantize_model_packed(&s.w, s.q.as_ref(), &s.opts);
    println!(
        "quantized in {:.1}s — {:.4} code bits/weight over {} linear params",
        t0.elapsed().as_secs_f64(),
        art.report.bits_per_weight(),
        art.report.total_params
    );
    let out = {
        let o = a.get("out").unwrap();
        if o.is_empty() {
            llvq::runtime::artifact(&format!("{}.{}.llvqm", s.cfg.name, s.method_name))
        } else {
            o.into()
        }
    };
    if let Some(dir) = out.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    let bytes = art.packed.to_bytes();
    if let Err(e) = std::fs::write(&out, &bytes) {
        eprintln!("save failed: {e}");
        return 1;
    }
    let dense_len = model_io::dense_file_size(&s.cfg);
    println!("wrote {}", out.display());
    println!(
        "pack stats: {} | dense .llvqw equivalent {} B ({:.1}x smaller)",
        packed_stats_line(bytes.len(), art.packed.code_bits(), &s.cfg),
        dense_len,
        dense_len as f64 / bytes.len() as f64
    );
    let dense_out = a.get("dense-out").unwrap();
    if !dense_out.is_empty() {
        let p = std::path::PathBuf::from(dense_out);
        if let Err(e) = model_io::save(&art.weights, &p) {
            eprintln!("dense save failed: {e}");
            return 1;
        }
        println!("wrote {} (dense reconstruction)", p.display());
    }
    0
}

fn cmd_unpack(rest: Vec<String>) -> i32 {
    let a = Args::new("llvq unpack — expand a packed .llvqm to a dense .llvqw")
        .flag("path", "", "input .llvqm file")
        .flag("out", "", "output .llvqw path (default: input with .llvqw extension)")
        .flag("threads", "0", "dequant workers (0 = auto)")
        .flag("verify", "", "optional dense .llvqw to compare bit-exactly against")
        .parse(rest.into_iter())
        .unwrap();
    let path = a.get("path").unwrap();
    if path.is_empty() {
        eprintln!("need --path <file.llvqm>");
        return 2;
    }
    let path = std::path::PathBuf::from(path);
    let packed = match PackedModel::load(&path) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let threads = threads_from(&a);
    let t0 = std::time::Instant::now();
    let w = match packed.unpack(threads) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("unpack failed: {e}");
            return 1;
        }
    };
    let unpack_ms = t0.elapsed().as_secs_f64() * 1e3;
    let out = {
        let o = a.get("out").unwrap();
        if o.is_empty() {
            path.with_extension("llvqw")
        } else {
            o.into()
        }
    };
    if let Err(e) = model_io::save(&w, &out) {
        eprintln!("save failed: {e}");
        return 1;
    }
    let dense_len = model_io::dense_file_size(&w.cfg);
    let packed_len = std::fs::metadata(&path).map(|m| m.len() as usize).unwrap_or(0);
    println!(
        "unpacked {} → {} in {unpack_ms:.0} ms ({threads} threads)",
        path.display(),
        out.display()
    );
    println!(
        "unpack stats: {} | dense {} B",
        packed_stats_line(packed_len, packed.code_bits(), &w.cfg),
        dense_len
    );
    let verify = a.get("verify").unwrap();
    if !verify.is_empty() {
        match model_io::load(std::path::Path::new(&verify)) {
            Ok(reference) => {
                let same = model_io::to_bytes(&reference) == model_io::to_bytes(&w);
                println!(
                    "verify vs {verify}: {}",
                    if same { "bit-exact ✓" } else { "MISMATCH ✗" }
                );
                if !same {
                    return 1;
                }
            }
            Err(e) => {
                eprintln!("verify load failed: {e}");
                return 1;
            }
        }
    }
    0
}

fn cmd_stats(rest: Vec<String>) -> i32 {
    let a = Args::new("llvq stats — header-only stats of a packed .llvqm artifact")
        .flag("path", "", "input .llvqm file")
        .flag("threads", "0", "kernel worker threads serve/generate would use (0 = auto)")
        .flag(
            "simd",
            "",
            "fused SIMD kernel to report: off|scalar|avx2|neon|portable \
             (default: $LLVQ_SIMD, then runtime detection)",
        )
        .parse(rest.into_iter())
        .unwrap();
    let path = a.get("path").unwrap();
    if path.is_empty() {
        eprintln!("need --path <file.llvqm>");
        return 2;
    }
    let simd = match simd_from(&a) {
        Ok(k) => k,
        Err(code) => return code,
    };
    let path = std::path::PathBuf::from(path);
    // load_meta reads magic + JSON header only — stats never touch the
    // payload, so this stays O(header) even for big artifacts
    match PackedModel::load_meta(&path) {
        Ok(meta) => {
            println!(
                "{}: {}",
                path.display(),
                packed_stats_line(meta.file_len, meta.code_bits(), &meta.cfg)
            );
            println!(
                "  config    : {} (d_model {}, {} layers, vocab {})",
                meta.cfg.name, meta.cfg.d_model, meta.cfg.n_layers, meta.cfg.vocab
            );
            println!("  quantizer : {}", meta.quantizer.to_string_compact());
            println!(
                "  layers    : {} quantized ({} code B); dense fp32 tail {} B",
                meta.layers.len(),
                meta.code_bytes(),
                meta.file_len - meta.dense_off
            );
            println!(
                "  threads   : {} (kernel pool serve/generate would run here)",
                threads_from(&a)
            );
            println!(
                "  simd      : {} (fused kernel serve/generate would dispatch)",
                simd.label()
            );
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn cmd_eval(rest: Vec<String>) -> i32 {
    let a = Args::new("llvq eval — perplexity + probes of a .llvqw artifact")
        .flag("path", "", "model file (or zoo name via --model)")
        .flag("model", "", "zoo model name (loads artifacts/<name>.llvqw)")
        .flag("seqs", "64", "eval sequences")
        .parse(rest.into_iter())
        .unwrap();
    let path = {
        let p = a.get("path").unwrap();
        if !p.is_empty() {
            std::path::PathBuf::from(p)
        } else {
            let m = a.get("model").unwrap();
            if m.is_empty() {
                eprintln!("need --path or --model");
                return 2;
            }
            llvq::runtime::artifact(&format!("{m}.llvqw"))
        }
    };
    match model_io::load(&path) {
        Ok(w) => {
            let m = evaluate(
                &w,
                a.get_usize("seqs"),
                2000,
                llvq::util::threadpool::default_threads(),
            );
            println!(
                "{}: ppl={:.3} acc(csr*)={:.1}% cloze(mmlu*)={:.1}% over {} tokens",
                path.display(),
                m.perplexity,
                m.accuracy_pct,
                m.cloze_pct,
                m.tokens
            );
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

/// Build the serving backend for `--packed <file>` under `--backend`:
/// dense unpacks everything at load (oracle), cached defers each layer's
/// decode to first touch, fused keeps only the bit-packed code streams.
fn packed_backend(
    path: &std::path::Path,
    kind: BackendKind,
    threads: usize,
    simd: Kernel,
) -> Result<ExecutionBackend, String> {
    match kind {
        BackendKind::Dense => {
            let packed = PackedModel::load(path)?;
            let w = packed.unpack(threads).map_err(|e| format!("unpack failed: {e}"))?;
            Ok(ExecutionBackend::dense(w))
        }
        BackendKind::Cached => ExecutionBackend::packed_cached(PackedFile::open(path)?, threads),
        BackendKind::Fused => {
            ExecutionBackend::packed_fused_kernel(PackedFile::open(path)?, threads, simd)
        }
    }
}

/// Resolve a `--threads` flag value (0 = auto-detect; a non-numeric value
/// is a usage error, not a silent fallback).
fn threads_from(a: &Args) -> usize {
    match a.get_usize("threads") {
        0 => threadpool::default_threads(),
        n => n,
    }
}

/// Resolve the `--simd` flag (empty = `LLVQ_SIMD` env, then runtime
/// detection; forcing an unavailable kernel is a usage error, not a silent
/// fallback). `Err` carries the process exit code.
fn simd_from(a: &Args) -> Result<Kernel, i32> {
    Kernel::resolve(&a.get("simd").unwrap()).map_err(|e| {
        eprintln!("{e}");
        2
    })
}

/// Resolve the shared `--packed/--path/--model/--backend/--allow-random`
/// flags of `serve` and `generate` into a ready [`ExecutionBackend`]
/// (printing load stats); `Err` carries the process exit code.
fn serving_backend(a: &Args) -> Result<ExecutionBackend, i32> {
    let kind = match BackendKind::parse(&a.get("backend").unwrap()) {
        Some(k) => k,
        None => {
            eprintln!(
                "unknown backend '{}' (dense|cached|fused)",
                a.get("backend").unwrap()
            );
            return Err(2);
        }
    };
    let packed_path = a.get("packed").unwrap();
    let p = a.get("path").unwrap();
    if !packed_path.is_empty() {
        let path = std::path::PathBuf::from(&packed_path);
        // stats come from the header alone (parse-validated file_len /
        // code bits) — read it up front so a bad artifact fails before
        // any payload work, and nothing re-reads the file afterwards
        let meta = match PackedModel::load_meta(&path) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("{e}");
                return Err(1);
            }
        };
        let t0 = std::time::Instant::now();
        let threads = threads_from(a);
        let simd = simd_from(a)?;
        let backend = match packed_backend(&path, kind, threads, simd) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("{e}");
                return Err(1);
            }
        };
        println!(
            "loaded packed model ({} backend, {} simd kernel, {} kernel threads, \
             {} B resident weights) in {:.0} ms: {}",
            backend.kind().label(),
            backend.simd().label(),
            threads,
            backend.resident_weight_bytes(),
            t0.elapsed().as_secs_f64() * 1e3,
            packed_stats_line(meta.file_len, meta.code_bits(), &meta.cfg)
        );
        Ok(backend)
    } else {
        if kind != BackendKind::Dense {
            eprintln!("--backend {} requires --packed <file.llvqm>", kind.label());
            return Err(2);
        }
        let w = if !p.is_empty() {
            match model_io::load(std::path::Path::new(&p)) {
                Ok(w) => w,
                Err(e) => {
                    eprintln!("{e}");
                    return Err(1);
                }
            }
        } else {
            let cfg = config_by_name(&a.get("model").unwrap()).expect("unknown model");
            match exp::load_model(&cfg, a.get_bool("allow-random")) {
                Ok(w) => w,
                Err(e) => {
                    eprintln!("{e}");
                    return Err(1);
                }
            }
        };
        Ok(ExecutionBackend::dense(w))
    }
}

/// Add the shared paged-KV flags (`serve` and `generate` take the same
/// four) to an [`Args`] builder.
fn kv_flags(a: Args) -> Args {
    a.flag(
        "kv-pages",
        "0",
        "KV page-arena budget in pages shared by all sessions (0 = dense \
         worst-case caches, the historical behaviour)",
    )
    .flag("kv-page-size", "16", "tokens per KV page")
    .flag(
        "kv-quant",
        "none",
        "cold-page codec: none (f32, bit-identical to dense) | e8 | llvq; \
         pages fully behind the hot window are re-encoded through the \
         weight codecs and decoded page-at-a-time on attention reads",
    )
    .flag(
        "kv-hot",
        "32",
        "f32 hot window in tokens; only pages entirely behind it cool to \
         the --kv-quant codec",
    )
}

/// Resolve `--kv-pages/--kv-page-size/--kv-quant/--kv-hot` into an engine
/// over `backend`; `Err` carries the process exit code.
fn engine_from(a: &Args, backend: ExecutionBackend) -> Result<BackendEngine, i32> {
    let quant = match KvQuantKind::parse(&a.get("kv-quant").unwrap()) {
        Ok(q) => q,
        Err(e) => {
            eprintln!("{e}");
            return Err(2);
        }
    };
    let pages = a.get_usize("kv-pages");
    if pages == 0 {
        if quant != KvQuantKind::None {
            eprintln!("--kv-quant {} requires --kv-pages > 0", quant.label());
            return Err(2);
        }
        return Ok(BackendEngine::new(backend));
    }
    BackendEngine::paged(
        backend,
        pages,
        a.get_usize("kv-page-size").max(1),
        a.get_usize("kv-hot"),
        quant,
    )
    .map_err(|e| {
        eprintln!("{e}");
        2
    })
}

fn cmd_serve(rest: Vec<String>) -> i32 {
    let a = kv_flags(Args::new("llvq serve — batching + generation inference server"))
        .flag("path", "", "model .llvqw to serve")
        .flag("packed", "", "packed .llvqm to serve")
        .flag(
            "backend",
            "dense",
            "execution over --packed: dense (unpack at load) | cached (lazy \
             per-layer decode) | fused (matvec over bit-packed codes)",
        )
        .flag("model", "llama2-tiny", "zoo name (artifacts/<name>.llvqw)")
        .flag("addr", "127.0.0.1:7199", "listen address")
        .flag("threads", "0", "kernel worker threads for the packed backends (0 = auto)")
        .flag(
            "simd",
            "",
            "fused SIMD kernel: off|scalar|avx2|neon|portable (default: \
             $LLVQ_SIMD, then runtime detection)",
        )
        .flag("max-batch", "8", "dynamic batch limit / decode-slate width")
        .flag("max-wait-ms", "2", "batch window")
        .flag(
            "prefill-chunk",
            "64",
            "prompt tokens a queued FEED prefills per scheduler tick",
        )
        .flag("max-sessions", "64", "concurrently open generation sessions")
        .flag("max-conns", "64", "concurrent TCP connections (ERR busy beyond)")
        .switch("allow-random", "serve random weights if artifact missing")
        .parse(rest.into_iter())
        .unwrap();
    let backend = match serving_backend(&a) {
        Ok(b) => b,
        Err(code) => return code,
    };
    let engine = match engine_from(&a, backend) {
        Ok(e) => Arc::new(e),
        Err(code) => return code,
    };
    if engine.kv_page_budget() > 0 {
        println!(
            "paged KV sessions: {} pages × {} tokens, cold-page codec {}",
            engine.kv_page_budget(),
            engine.kv_page_tokens(),
            engine.kv_quant_label()
        );
    }
    let coord = Coordinator::start(
        engine,
        BatcherConfig {
            max_batch: a.get_usize("max-batch"),
            max_wait: std::time::Duration::from_millis(a.get_u64("max-wait-ms")),
            max_sessions: a.get_usize("max-sessions"),
            prefill_chunk: a.get_usize("prefill-chunk").max(1),
        },
    );
    let addr = a.get("addr").unwrap();
    let listener = match std::net::TcpListener::bind(&addr) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("bind {addr}: {e}");
            return 1;
        }
    };
    println!(
        "serving on {addr} (v1: NEXT t1,t2,… | STATS | QUIT — v2 sessions: \
         OPEN | FEED t1,t2,… | GEN n [temp=…] [topk=…] [seed=…] | CLOSE)"
    );
    if let Err(e) = llvq::coordinator::serve_tcp_opts(
        coord,
        listener,
        ServeOptions {
            max_conns: a.get_usize("max-conns"),
        },
    ) {
        eprintln!("server error: {e}");
        return 1;
    }
    0
}

fn cmd_serve_http(rest: Vec<String>) -> i32 {
    use llvq::http::api::serve_http;
    use llvq::model::registry::{parse_model_specs, ModelRegistry, RegistryConfig};
    let a = kv_flags(Args::new(
        "llvq serve-http — HTTP/SSE front door over a multi-model registry",
    ))
    .flag(
        "model",
        "",
        "registry spec: name=path.llvqm[,name=path...]; a bare path names \
         itself after its file stem",
    )
    .flag(
        "backend",
        "fused",
        "execution for every model: dense (unpack at load) | cached (lazy \
         per-layer decode) | fused (matvec over bit-packed codes)",
    )
    .flag("addr", "127.0.0.1:7200", "listen address")
    .flag("threads", "0", "kernel worker threads per model backend (0 = auto)")
    .flag(
        "simd",
        "",
        "fused SIMD kernel: off|scalar|avx2|neon|portable (default: \
         $LLVQ_SIMD, then runtime detection)",
    )
    .flag("max-batch", "8", "dynamic batch limit / decode-slate width per model")
    .flag("max-wait-ms", "2", "batch window")
    .flag(
        "prefill-chunk",
        "64",
        "prompt tokens a queued prefill job drains per scheduler tick",
    )
    .flag("max-sessions", "64", "concurrently open generation sessions per model")
    .flag("max-conns", "64", "concurrent HTTP connections (503 busy beyond)")
    .flag(
        "max-resident-bytes",
        "0",
        "LRU hot-set budget over resident model backend bytes (0 = \
         unlimited; models with open sessions are never evicted)",
    )
    .parse(rest.into_iter())
    .unwrap();
    let specs = match parse_model_specs(&a.get("model").unwrap()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let backend = match BackendKind::parse(&a.get("backend").unwrap()) {
        Some(k) => k,
        None => {
            eprintln!(
                "unknown backend '{}' (dense|cached|fused)",
                a.get("backend").unwrap()
            );
            return 2;
        }
    };
    let simd = match simd_from(&a) {
        Ok(k) => k,
        Err(code) => return code,
    };
    let kv_quant = match KvQuantKind::parse(&a.get("kv-quant").unwrap()) {
        Ok(q) => q,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let kv_pages = a.get_usize("kv-pages");
    if kv_pages == 0 && kv_quant != KvQuantKind::None {
        eprintln!("--kv-quant {} requires --kv-pages > 0", kv_quant.label());
        return 2;
    }
    let cfg = RegistryConfig {
        backend,
        threads: threads_from(&a),
        simd,
        batcher: BatcherConfig {
            max_batch: a.get_usize("max-batch"),
            max_wait: std::time::Duration::from_millis(a.get_u64("max-wait-ms")),
            max_sessions: a.get_usize("max-sessions"),
            prefill_chunk: a.get_usize("prefill-chunk").max(1),
        },
        kv_pages,
        kv_page_tokens: a.get_usize("kv-page-size").max(1),
        kv_hot: a.get_usize("kv-hot"),
        kv_quant,
        max_resident_bytes: a.get_usize("max-resident-bytes"),
    };
    let registry = match ModelRegistry::open(specs, cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    for m in registry.models() {
        println!(
            "registered model {} ({}, {} params, {} B on disk)",
            m.name, m.config, m.params, m.file_bytes
        );
    }
    let addr = a.get("addr").unwrap();
    let listener = match std::net::TcpListener::bind(&addr) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("bind {addr}: {e}");
            return 1;
        }
    };
    println!(
        "serving HTTP on {addr} (POST /v1/completions [SSE via \"stream\": true] \
         | GET /v1/models | GET /metrics) — {} models registered, \
         resident budget {}",
        registry.len(),
        match registry.max_resident_bytes() {
            0 => "unlimited".to_string(),
            b => format!("{b} B"),
        }
    );
    if let Err(e) = serve_http(
        registry,
        listener,
        ServeOptions {
            max_conns: a.get_usize("max-conns"),
        },
    ) {
        eprintln!("server error: {e}");
        return 1;
    }
    0
}

fn cmd_sim(rest: Vec<String>) -> i32 {
    use llvq::sim::harness::Simulator;
    use llvq::sim::scenario::Scenario;
    use llvq::sim::trace::Trace;
    let a = Args::new("llvq sim — deterministic scheduler simulator (virtual clock)")
        .flag("scenario", "", "named workload from the corpus (see --list)")
        .flag("trace", "", "replay a committed .trace file instead of a scenario")
        .flag("seed", "1", "scenario seed (prompt contents, lengths, sampling)")
        .flag(
            "max-ticks",
            "0",
            "quiescence bound in virtual ticks (0 = the scenario's own bound)",
        )
        .flag(
            "save-trace",
            "",
            "export the run as a canonical .trace (commit it under \
             rust/tests/sim_traces/ to pin a failure forever)",
        )
        .switch("step", "step-through: print the occupancy dump after every tick")
        .switch("log", "print the full reply log after the run")
        .switch("list", "list the scenario corpus and exit")
        .parse(rest.into_iter())
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    if a.get_bool("list") {
        for sc in Scenario::ALL {
            println!("{}", sc.name());
        }
        return 0;
    }
    let scenario = a.get("scenario").filter(|s| !s.is_empty());
    let trace_path = a.get("trace").filter(|s| !s.is_empty());
    let (trace, default_ticks) = match (scenario, trace_path) {
        (Some(name), None) => match Scenario::parse(&name) {
            Ok(sc) => (sc.trace(a.get_u64("seed")), sc.max_ticks()),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
        (None, Some(path)) => match Trace::load(std::path::Path::new(&path)) {
            Ok(t) => (t, 500),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
        _ => {
            eprintln!("pick exactly one of --scenario <name> or --trace <file> (or --list)");
            return 2;
        }
    };
    if let Some(path) = a.get("save-trace").filter(|s| !s.is_empty()) {
        if let Err(e) = trace.save(std::path::Path::new(&path)) {
            eprintln!("{e}");
            return 1;
        }
        println!("wrote {path}");
    }
    let mut sim = match Simulator::new(&trace) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let max_ticks = match a.get_u64("max-ticks") {
        0 => default_ticks,
        n => n,
    };
    if a.get_bool("step") {
        while !sim.done() && sim.now() < max_ticks {
            sim.step();
            println!("{}", sim.dump());
        }
    }
    // after a --step walk this returns immediately (or records
    // non-quiescence at the bound — a liveness failure, not a timeout)
    let report = sim.run_to_end(max_ticks);
    if a.get_bool("log") {
        print!("{}", report.log_text());
    }
    println!(
        "{} ticks, fingerprint {:016x}\nstats: {}",
        report.ticks,
        report.fingerprint(),
        report.stats
    );
    match &report.violation {
        Some(v) => {
            eprintln!("INVARIANT VIOLATION: {v}");
            1
        }
        None => 0,
    }
}

fn cmd_lint(rest: Vec<String>) -> i32 {
    use llvq::lint::engine;
    use llvq::lint::rules::RULES;
    let a = Args::new("llvq lint — repo-native static analysis (rules in LINTS.md)")
        .flag("rule", "", "report findings of a single rule by name")
        .flag("root", "", "repo root (default: walk up from the cwd)")
        .switch("json", "emit the deterministic JSON report instead of text")
        .switch("list", "list the rule set and exit")
        .parse(rest.into_iter())
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    if a.get_bool("list") {
        for (name, summary) in RULES {
            println!("{name:<22} {summary}");
        }
        return 0;
    }
    let root = match a.get("root").filter(|s| !s.is_empty()) {
        Some(r) => std::path::PathBuf::from(r),
        None => {
            let cwd = std::env::current_dir().unwrap_or_else(|_| std::path::PathBuf::from("."));
            match engine::find_repo_root(&cwd) {
                Some(r) => r,
                None => {
                    eprintln!(
                        "no repo root (Cargo.toml + rust/) above {} — pass --root",
                        cwd.display()
                    );
                    return 2;
                }
            }
        }
    };
    let rule = a.get("rule").filter(|s| !s.is_empty());
    let findings = match engine::run_lint(&root, rule.as_deref()) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if a.get_bool("json") {
        println!("{}", engine::render_json(&findings));
    } else {
        print!("{}", engine::render_text(&findings));
    }
    if findings.is_empty() {
        0
    } else {
        1
    }
}

fn cmd_generate(rest: Vec<String>) -> i32 {
    let a = kv_flags(Args::new("llvq generate — KV-cached token generation from a prompt"))
        .flag("path", "", "model .llvqw to load")
        .flag("packed", "", "packed .llvqm to load")
        .flag(
            "backend",
            "dense",
            "execution over --packed: dense | cached | fused",
        )
        .flag("model", "llama2-tiny", "zoo name (artifacts/<name>.llvqw)")
        .flag("threads", "0", "kernel worker threads for the packed backends (0 = auto)")
        .flag(
            "simd",
            "",
            "fused SIMD kernel: off|scalar|avx2|neon|portable (default: \
             $LLVQ_SIMD, then runtime detection)",
        )
        .flag("prompt", "1,2,3", "comma-separated prompt token ids")
        .flag("n", "16", "tokens to generate")
        .flag("temp", "0", "sampling temperature (0 = greedy)")
        .flag("topk", "0", "top-k truncation (0 = off)")
        .flag("seed", "7", "sampler seed")
        .switch("allow-random", "use random weights if artifact missing")
        .parse(rest.into_iter())
        .unwrap();
    let backend = match serving_backend(&a) {
        Ok(b) => b,
        Err(code) => return code,
    };
    let cfg = backend.cfg().clone();
    if cfg.vocab > 256 {
        // the token path is u8 end to end; sampled ids above 255 would
        // silently wrap (the serving GEN path enforces the same bound)
        eprintln!("generate requires vocab <= 256 (u8 token ids); model has {}", cfg.vocab);
        return 2;
    }
    let prompt: Vec<u8> = {
        let parsed: Result<Vec<u8>, _> = a
            .get("prompt")
            .unwrap()
            .split(',')
            .map(|t| t.trim().parse::<u8>())
            .collect();
        match parsed {
            Ok(p) if !p.is_empty() && p.iter().all(|&t| (t as usize) < cfg.vocab) => p,
            _ => {
                eprintln!("--prompt must be non-empty token ids < vocab {}", cfg.vocab);
                return 2;
            }
        }
    };
    let n = a.get_usize("n");
    if prompt.len() + n > cfg.max_seq {
        eprintln!(
            "prompt ({}) + n ({n}) exceeds max_seq {}",
            prompt.len(),
            cfg.max_seq
        );
        return 2;
    }
    let params = SampleParams {
        temperature: a.get_f64("temp") as f32,
        top_k: a.get_usize("topk"),
        seed: a.get_u64("seed"),
    };
    let engine = match engine_from(&a, backend) {
        Ok(e) => e,
        Err(code) => return code,
    };
    let mut cache = engine.open_session();
    // paged sessions admit against actual pages: reserve the whole run up
    // front so an undersized --kv-pages budget fails cleanly before any
    // forward work
    if let Err(e) = cache.reserve(prompt.len() + n) {
        eprintln!("{e}");
        return 1;
    }
    let t0 = std::time::Instant::now();
    let mut logits = prefill(&engine.backend, cache.as_mut(), &prompt);
    let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut sampler = Sampler::new(params);
    let mut toks: Vec<u8> = Vec::with_capacity(n);
    let t1 = std::time::Instant::now();
    for i in 0..n {
        let t = sampler.sample(&logits) as u8;
        toks.push(t);
        // the last sampled token needs no decode step — nothing is
        // sampled after it
        if i + 1 < n {
            logits = forward_step(&engine.backend, cache.as_mut(), t);
        }
    }
    let gen_s = t1.elapsed().as_secs_f64();
    let rendered: Vec<String> = toks.iter().map(|t| t.to_string()).collect();
    println!("prompt : {}", a.get("prompt").unwrap());
    println!("tokens : {}", rendered.join(","));
    println!(
        "prefill {prefill_ms:.1} ms | {n} tokens in {:.1} ms → {:.1} tok/s \
         ({} backend, kv={}, temp={} topk={} seed={})",
        gen_s * 1e3,
        n as f64 / gen_s.max(1e-9),
        engine.backend.kind().label(),
        if engine.kv_page_budget() > 0 {
            format!(
                "paged {}x{} quant={}",
                engine.kv_page_budget(),
                engine.kv_page_tokens(),
                engine.kv_quant_label()
            )
        } else {
            "dense".into()
        },
        params.temperature,
        params.top_k,
        params.seed
    );
    0
}

fn cmd_gen_model(rest: Vec<String>) -> i32 {
    let a = Args::new("llvq gen-model — write random weights (testing)")
        .flag("model", "llama2-tiny", "zoo model name")
        .flag("seed", "7", "rng seed")
        .flag("out", "", "output path (default artifacts/<name>.llvqw)")
        .parse(rest.into_iter())
        .unwrap();
    let cfg = config_by_name(&a.get("model").unwrap()).expect("unknown model");
    let w = Weights::random(&cfg, a.get_u64("seed"));
    let out = {
        let o = a.get("out").unwrap();
        if o.is_empty() {
            llvq::runtime::artifact(&format!("{}.llvqw", cfg.name))
        } else {
            o.into()
        }
    };
    if let Some(dir) = out.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match model_io::save(&w, &out) {
        Ok(()) => {
            println!("wrote {} ({} params)", out.display(), cfg.num_params());
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn cmd_info(rest: Vec<String>) -> i32 {
    let a = Args::new("llvq info — lattice/codebook summary")
        .flag("max-m", "13", "ball cut")
        .parse(rest.into_iter())
        .unwrap();
    let max_m = a.get_usize("max-m");
    let ix = LeechIndexer::new(max_m);
    let t = KernelTables::build(&ix);
    println!("Leech ball cut Λ24({max_m}):");
    println!("  points        : {}", ix.num_points());
    println!("  index bits    : {}", ix.index_bits());
    println!("  bits/dim      : {:.4}", ix.bits_per_dim());
    println!(
        "  classes       : {}",
        ix.shells().iter().map(|s| s.classes.len()).sum::<usize>()
    );
    println!("  kernel groups : {}", t.num_groups);
    println!("  table bytes   : {} (VMEM budget 262144)", t.vmem_bytes());
    0
}
