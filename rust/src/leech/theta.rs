//! Theta series of the Leech lattice — independent ground truth for the
//! shell enumeration.
//!
//! The number of lattice vectors of squared norm `2m` is
//!
//! ```text
//! n(m) = 65520/691 · (σ₁₁(m) − τ(m))
//! ```
//!
//! where σ₁₁ is the 11th-power divisor sum and τ is the Ramanujan tau
//! function (coefficients of the discriminant cusp form
//! Δ = q·∏(1−qⁿ)²⁴). We compute τ exactly with i128 power-series
//! arithmetic; the enumeration layer ([`super::leaders`]) must reproduce
//! these counts exactly — this is the strongest self-test in the crate.

/// Ramanujan τ(1..=max_m) via the η-product Δ = q ∏ₙ (1−qⁿ)²⁴.
pub fn ramanujan_tau(max_m: usize) -> Vec<i128> {
    // coefficients of ∏ (1-q^n)^24 up to q^(max_m-1)
    let n = max_m; // need coef index up to max_m-1
    let mut coef = vec![0i128; n];
    coef[0] = 1;
    for k in 1..n {
        for _ in 0..24 {
            // multiply in-place by (1 - q^k)
            for i in (k..n).rev() {
                let (lo, hi) = coef.split_at_mut(i);
                hi[0] -= lo[i - k];
            }
        }
    }
    // tau[m] = coef[m-1]; tau[0] unused (set 0)
    let mut tau = vec![0i128; max_m + 1];
    for m in 1..=max_m {
        tau[m] = coef[m - 1];
    }
    tau
}

/// σ₁₁(m) = Σ_{d|m} d¹¹.
pub fn sigma11(m: usize) -> i128 {
    let mut s: i128 = 0;
    for d in 1..=m {
        if m % d == 0 {
            s += (d as i128).pow(11);
        }
    }
    s
}

/// Shell sizes n(m) = |{v ∈ Λ₂₄ : ‖v‖² = 2m}| for m = 0..=max_m.
/// n(0) = 1 (the origin), n(1) = 0 (minimum norm is 4 = 2·2).
pub fn shell_sizes(max_m: usize) -> Vec<u128> {
    let tau = ramanujan_tau(max_m);
    let mut out = Vec::with_capacity(max_m + 1);
    out.push(1u128); // the origin
    for m in 1..=max_m {
        let v = 65520 * (sigma11(m) - tau[m]);
        assert!(v >= 0 && v % 691 == 0, "theta arithmetic broke at m={m}");
        out.push((v / 691) as u128);
    }
    out
}

/// Cumulative counts N(M) = Σ_{m=2..=M} n(m) — the codebook sizes of the
/// ball-cut Λ₂₄(M) (paper Table 1; the origin and the empty shell m=1 are
/// excluded, matching the paper's convention of starting at the first
/// nonempty shell).
pub fn cumulative_sizes(max_m: usize) -> Vec<u128> {
    let n = shell_sizes(max_m);
    let mut cum = vec![0u128; max_m + 1];
    let mut acc = 0u128;
    for m in 2..=max_m {
        acc += n[m];
        cum[m] = acc;
    }
    cum
}

/// Bits per dimension of an index over Λ₂₄(M): ⌈log₂ N(M)⌉ / 24.
pub fn bits_per_dim(n_points: u128) -> f64 {
    ((n_points as f64).log2()).ceil() / 24.0
}

/// Exact log2 (not ceiled) — used for rate accounting in experiments.
pub fn exact_bits_per_dim(n_points: u128) -> f64 {
    (n_points as f64).log2() / 24.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tau_known_values() {
        let tau = ramanujan_tau(13);
        assert_eq!(tau[1], 1);
        assert_eq!(tau[2], -24);
        assert_eq!(tau[3], 252);
        assert_eq!(tau[4], -1472);
        assert_eq!(tau[5], 4830);
        assert_eq!(tau[6], -6048);
        assert_eq!(tau[7], -16744);
        assert_eq!(tau[11], 534612);
        assert_eq!(tau[12], -370944);
        assert_eq!(tau[13], -577738);
    }

    #[test]
    fn shell_sizes_match_table1() {
        let n = shell_sizes(19);
        assert_eq!(n[0], 1);
        assert_eq!(n[1], 0); // minimum squared norm of Λ24 is 4
        assert_eq!(n[2], 196_560); // kissing number
        assert_eq!(n[3], 16_773_120);
        assert_eq!(n[4], 398_034_000);
        assert_eq!(n[5], 4_629_381_120);
        // Paper Table 1 prints n(13)=16,993,109,532,672 — a dropped digit;
        // the cumulative N(13) below confirms the correct value is 10×.
        assert_eq!(n[13], 169_931_095_326_720);
        assert_eq!(n[19], 11_045_500_816_896_000);
    }

    #[test]
    fn cumulative_match_table1() {
        let cum = cumulative_sizes(19);
        assert_eq!(cum[2], 196_560);
        assert_eq!(cum[3], 16_969_680);
        assert_eq!(cum[4], 415_003_680);
        assert_eq!(cum[5], 5_044_384_800);
        assert_eq!(cum[13], 280_974_212_784_720); // exactly the paper's N(13)
        // bits/dim at M=13 is 48/24 = 2.0 — the paper's headline bitrate
        assert_eq!(bits_per_dim(cum[13]), 2.0);
        assert!((exact_bits_per_dim(cum[3]) - 1.0).abs() < 0.05);
    }

    #[test]
    fn bits_per_dim_table1_column() {
        let cum = cumulative_sizes(19);
        assert!((bits_per_dim(cum[2]) - 0.75).abs() < 1e-12);
        assert!((bits_per_dim(cum[3]) - 25.0 / 24.0).abs() < 1e-12); // 1.042
        assert!((bits_per_dim(cum[4]) - 29.0 / 24.0).abs() < 1e-12); // 1.208
        assert!((bits_per_dim(cum[5]) - 33.0 / 24.0).abs() < 1e-12); // 1.375
        assert!((bits_per_dim(cum[19]) - 55.0 / 24.0).abs() < 1e-12); // 2.292
    }
}
