//! Bijective indexing of the ball-cut Leech lattice Λ₂₄(M) — the paper's
//! central contribution (§3.2, §3.3).
//!
//! Every lattice point of shell 2 ≤ m ≤ M maps to a unique integer in
//! `[0, N(M))` through the natural hierarchy:
//!
//! ```text
//! global index = shell offset
//!              + class offset          (within shell)
//!              + subclass offset       (within class)
//!              + local index           (within subclass)
//! local index  = (perm_rank · 2^B + sign_rank) · A + codeword_rank
//! perm_rank    = f1_rank · |F₀ arrangements| + f0_rank
//! ```
//!
//! mirroring eq. 15 of the paper: `codeword_rank = I mod A` is the Golay
//! refinement, then the sign pattern, then the permutation coset, each
//! recovered by a modulo / integer-division pair. The permutation rank is a
//! *multiset-permutation rank* over the class leader's value multiset, with
//! the descending-value alphabet so the canonical leader has rank 0.
//!
//! `encode_point` (vector → index) and `decode_index` (index → vector, the
//! paper's *dequantizer*) are exact inverses — enforced by property tests
//! over every shell and class.

use std::collections::HashMap;

use crate::golay::GolayCode;
use crate::leech::coset;
use crate::leech::leaders::{self, ClassInfo, Parity, ShellClasses, Subclass};
use crate::DIM;

/// Multiset-permutation rank of `seq` (alphabet ordered by descending
/// value: the non-increasing arrangement has rank 0).
pub fn ms_perm_rank(seq: &[u8]) -> u128 {
    // distinct values descending with counts
    let mut syms: Vec<(u8, u8)> = Vec::new();
    {
        let mut sorted: Vec<u8> = seq.to_vec();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        for &v in &sorted {
            match syms.last_mut() {
                Some((lv, c)) if *lv == v => *c += 1,
                _ => syms.push((v, 1)),
            }
        }
    }
    let mut total: u128 = {
        let mut t = (1..=seq.len() as u128).product::<u128>();
        for &(_, c) in &syms {
            t /= (1..=c as u128).product::<u128>();
        }
        t
    };
    let mut len = seq.len() as u128;
    let mut rank: u128 = 0;
    for &cur in seq {
        for &(v, c) in syms.iter() {
            if c == 0 {
                continue;
            }
            if v > cur {
                rank += total * c as u128 / len;
            } else if v == cur {
                break;
            }
        }
        let e = syms.iter_mut().find(|(v, _)| *v == cur).expect("symbol");
        total = total * e.1 as u128 / len;
        e.1 -= 1;
        len -= 1;
    }
    rank
}

/// Inverse of [`ms_perm_rank`]: reconstruct the sequence from the rank and
/// the multiset (given as (value, count) pairs, descending values).
pub fn ms_perm_unrank(mults: &[(u8, u8)], mut rank: u128, out: &mut Vec<u8>) {
    let mut syms: Vec<(u8, u8)> = mults.to_vec();
    let len_total: usize = syms.iter().map(|&(_, c)| c as usize).sum();
    let mut total: u128 = {
        let mut t = (1..=len_total as u128).product::<u128>();
        for &(_, c) in &syms {
            t /= (1..=c as u128).product::<u128>();
        }
        t
    };
    let mut len = len_total as u128;
    out.clear();
    for _ in 0..len_total {
        for i in 0..syms.len() {
            let (v, c) = syms[i];
            if c == 0 {
                continue;
            }
            let cnt = total * c as u128 / len;
            if rank < cnt {
                out.push(v);
                total = cnt;
                syms[i].1 -= 1;
                len -= 1;
                break;
            }
            rank -= cnt;
        }
    }
    debug_assert_eq!(rank, 0, "unrank left residue");
}

/// The indexer over Λ₂₄(M): shells 2..=max_m with all class metadata.
pub struct LeechIndexer {
    golay: GolayCode,
    max_m: usize,
    shells: Vec<ShellClasses>,
    /// shell_offsets[k] = Σ_{m<2+k} n(m); len = shells.len()+1.
    shell_offsets: Vec<u128>,
    /// Per shell: leader value-tuple → class index.
    class_lookup: Vec<HashMap<[u8; DIM], u32>>,
}

impl LeechIndexer {
    /// Build the indexer for the ball cut up to shell `max_m` (inclusive).
    /// `max_m = 13` gives the paper's 2.0 bits/dim codebook (N = 2^47.99).
    pub fn new(max_m: usize) -> Self {
        let golay = GolayCode::new();
        Self::with_golay(golay, max_m)
    }

    pub fn with_golay(golay: GolayCode, max_m: usize) -> Self {
        assert!(max_m >= 2, "ball cut needs at least shell 2");
        let mut shells = Vec::with_capacity(max_m - 1);
        let mut shell_offsets = vec![0u128];
        let mut class_lookup = Vec::with_capacity(max_m - 1);
        let mut acc = 0u128;
        for m in 2..=max_m {
            let s = leaders::enumerate_shell(&golay, m);
            acc += s.size;
            shell_offsets.push(acc);
            let mut lut = HashMap::with_capacity(s.classes.len());
            for (i, c) in s.classes.iter().enumerate() {
                lut.insert(c.values, i as u32);
            }
            class_lookup.push(lut);
            shells.push(s);
        }
        Self {
            golay,
            max_m,
            shells,
            shell_offsets,
            class_lookup,
        }
    }

    pub fn golay(&self) -> &GolayCode {
        &self.golay
    }

    pub fn max_m(&self) -> usize {
        self.max_m
    }

    /// Total number of indexable points N(M).
    pub fn num_points(&self) -> u128 {
        *self.shell_offsets.last().unwrap()
    }

    /// Bits needed for one block index: ⌈log₂ N(M)⌉.
    pub fn index_bits(&self) -> u32 {
        let n = self.num_points();
        128 - (n - 1).leading_zeros()
    }

    /// Bits per dimension of this codebook.
    pub fn bits_per_dim(&self) -> f64 {
        self.index_bits() as f64 / DIM as f64
    }

    pub fn shells(&self) -> &[ShellClasses] {
        &self.shells
    }

    /// Encode an integer lattice point into its global index.
    /// Returns None if `x` is not a lattice point within the ball cut.
    pub fn encode_point(&self, x: &[i32; DIM]) -> Option<u64> {
        let m = coset::shell_of(x)?;
        if m < 2 || m > self.max_m {
            return None;
        }
        let even = coset::coset_parity(x)?;
        if !coset::is_lattice_point(&self.golay, x) {
            return None;
        }
        let shell = &self.shells[m - 2];

        // class: sorted |values| descending
        let mut values = [0u8; DIM];
        for i in 0..DIM {
            values[i] = x[i].unsigned_abs() as u8;
        }
        values.sort_unstable_by(|a, b| b.cmp(a));
        let class_idx = *self.class_lookup[m - 2].get(&values)? as usize;
        let class = &shell.classes[class_idx];
        debug_assert_eq!(
            class.parity == Parity::Even,
            even,
            "class parity disagrees with coset parity"
        );

        // Golay refinement
        let c = coset::golay_word_of(x, even);
        let w = c.count_ones() as usize;
        let c_rank = self.golay.rank_in_weight(c)? as u128;

        // subclass: split vector k_v = #|x_i| = v with i ∈ supp(c)
        let mut split = vec![0u8; class.counts.len()];
        for i in 0..DIM {
            if c & (1 << i) != 0 {
                let v = x[i].unsigned_abs() as u8;
                let vi = class.counts.iter().position(|&(cv, _)| cv == v)?;
                split[vi] += 1;
            }
        }
        let (sub_idx, sub) = class
            .subclasses
            .iter()
            .enumerate()
            .find(|(_, s)| s.weight == w && s.split == split)?;

        // sign rank (even classes only)
        let sign_rank: u128 = if even {
            let mut s: u128 = 0;
            let mut bit = 0u32;
            // F0 nonzero positions, ascending
            for i in 0..DIM {
                if c & (1 << i) == 0 && x[i] != 0 {
                    if x[i] < 0 {
                        s |= 1 << bit;
                    }
                    bit += 1;
                }
            }
            // F1 positions ascending, except the last (parity-determined)
            let f1_pos: Vec<usize> = (0..DIM).filter(|&i| c & (1 << i) != 0).collect();
            if let Some((_, rest)) = f1_pos.split_last() {
                for &i in rest {
                    if x[i] < 0 {
                        s |= 1 << bit;
                    }
                    bit += 1;
                }
            }
            debug_assert_eq!(bit, sub.sign_bits);
            s
        } else {
            0
        };

        // permutation ranks: the |value| sequences restricted to F1 / F0
        // positions in ascending position order.
        let mut f1_vals: Vec<u8> = Vec::with_capacity(w);
        let mut f0_vals: Vec<u8> = Vec::with_capacity(DIM - w);
        for i in 0..DIM {
            let v = x[i].unsigned_abs() as u8;
            if c & (1 << i) != 0 {
                f1_vals.push(v);
            } else {
                f0_vals.push(v);
            }
        }
        let f1_rank = ms_perm_rank(&f1_vals);
        let f0_rank = ms_perm_rank(&f0_vals);

        let perm_rank = f1_rank * sub.f0_arrangements as u128 + f0_rank;
        let local =
            (perm_rank * (1u128 << sub.sign_bits) + sign_rank) * sub.num_codewords as u128
                + c_rank;
        debug_assert!(local < sub.size);

        let global = self.shell_offsets[m - 2]
            + shell.class_offsets[class_idx]
            + class.subclass_offsets[sub_idx]
            + local;
        debug_assert!(global < self.num_points());
        Some(global as u64)
    }

    /// The dequantizer (paper §3.3): global index → integer lattice point.
    pub fn decode_index(&self, index: u64) -> [i32; DIM] {
        let idx = index as u128;
        assert!(idx < self.num_points(), "index out of range");

        // 1. shell identification (binary search over cumulative sizes)
        let k = match self.shell_offsets.binary_search(&idx) {
            Ok(exact) => exact, // idx == offset[k] → first point of shell k
            Err(ins) => ins - 1,
        };
        let shell = &self.shells[k];
        let in_shell = idx - self.shell_offsets[k];

        // 2. class identification
        let ci = match shell.class_offsets.binary_search(&in_shell) {
            Ok(e) => e,
            Err(ins) => ins - 1,
        };
        let class = &shell.classes[ci];
        let in_class = in_shell - shell.class_offsets[ci];

        // subclass
        let si = match class.subclass_offsets.binary_search(&in_class) {
            Ok(e) => e,
            Err(ins) => ins - 1,
        };
        let sub = &class.subclasses[si];
        let mut local = in_class - class.subclass_offsets[si];

        // 3. unpack local symmetries (eq. 15)
        let c_rank = (local % sub.num_codewords as u128) as u32;
        local /= sub.num_codewords as u128;
        let sign_rank = local % (1u128 << sub.sign_bits);
        local >>= sub.sign_bits;
        let f0_arr = sub.f0_arrangements as u128;
        let f1_rank = local / f0_arr;
        let f0_rank = local % f0_arr;

        self.reconstruct(class, sub, c_rank, sign_rank, f1_rank, f0_rank)
    }

    /// 4. reconstruction (paper §3.3 step 4).
    fn reconstruct(
        &self,
        class: &ClassInfo,
        sub: &Subclass,
        c_rank: u32,
        sign_rank: u128,
        f1_rank: u128,
        f0_rank: u128,
    ) -> [i32; DIM] {
        let c = self.golay.unrank_in_weight(sub.weight, c_rank);

        // multiset-permutation unrank of both halves
        let mut f1_mults: Vec<(u8, u8)> = Vec::new();
        for &v in &sub.f1_seq {
            match f1_mults.last_mut() {
                Some((lv, n)) if *lv == v => *n += 1,
                _ => f1_mults.push((v, 1)),
            }
        }
        let mut f0_mults: Vec<(u8, u8)> = Vec::new();
        for &v in &sub.f0_seq {
            match f0_mults.last_mut() {
                Some((lv, n)) if *lv == v => *n += 1,
                _ => f0_mults.push((v, 1)),
            }
        }
        let mut f1_vals = Vec::with_capacity(sub.weight);
        let mut f0_vals = Vec::with_capacity(DIM - sub.weight);
        ms_perm_unrank(&f1_mults, f1_rank, &mut f1_vals);
        ms_perm_unrank(&f0_mults, f0_rank, &mut f0_vals);

        let mut x = [0i32; DIM];
        match class.parity {
            Parity::Odd => {
                // signs fully forced by the mod-4 congruences
                let (mut i1, mut i0) = (0usize, 0usize);
                for i in 0..DIM {
                    if c & (1 << i) != 0 {
                        x[i] = leaders::odd_signed_value(f1_vals[i1], true);
                        i1 += 1;
                    } else {
                        x[i] = leaders::odd_signed_value(f0_vals[i0], false);
                        i0 += 1;
                    }
                }
            }
            Parity::Even => {
                // F0: free signs on nonzero coords; F1: w−1 free signs, the
                // last F1 coordinate fixes Σ ≡ 0 (mod 8) via neg-count parity.
                let mut bit = 0u32;
                let (mut i1, mut i0) = (0usize, 0usize);
                let f1_pos: Vec<usize> = (0..DIM).filter(|&i| c & (1 << i) != 0).collect();
                let mut f1_negs = 0u32;
                for i in 0..DIM {
                    if c & (1 << i) != 0 {
                        x[i] = f1_vals[i1] as i32;
                        i1 += 1;
                    } else {
                        let v = f0_vals[i0] as i32;
                        i0 += 1;
                        if v != 0 {
                            let neg = (sign_rank >> bit) & 1 == 1;
                            bit += 1;
                            x[i] = if neg { -v } else { v };
                        }
                    }
                }
                if let Some((&last, rest)) = f1_pos.split_last() {
                    for &i in rest {
                        let neg = (sign_rank >> bit) & 1 == 1;
                        bit += 1;
                        if neg {
                            x[i] = -x[i];
                            f1_negs += 1;
                        }
                    }
                    // fix parity of negatives among F1
                    if f1_negs % 2 != class.f1_neg_parity as u32 {
                        x[last] = -x[last];
                    }
                }
                debug_assert_eq!(bit, sub.sign_bits);
            }
        }
        debug_assert!(
            coset::is_lattice_point(&self.golay, &x),
            "reconstructed non-lattice point {x:?} (class {:?})",
            class.values
        );
        x
    }

    /// Uniformly sample a lattice point of Λ₂₄(M) (by uniform index).
    pub fn sample(&self, rng: &mut crate::util::rng::Xoshiro256pp) -> [i32; DIM] {
        let n = self.num_points();
        debug_assert!(n <= u64::MAX as u128);
        let idx = rng.next_range(n as u64);
        self.decode_index(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn ms_perm_rank_roundtrip() {
        let mults = [(4u8, 2u8), (2, 3), (0, 3)];
        let total: u128 = 8 * 7 * 6 * 5 * 4 * 3 * 2 / (2 * 6 * 6);
        let mut seen = std::collections::HashSet::new();
        let mut buf = Vec::new();
        for r in 0..total {
            ms_perm_unrank(&mults, r, &mut buf);
            assert_eq!(ms_perm_rank(&buf), r);
            assert!(seen.insert(buf.clone()), "duplicate sequence");
        }
        assert_eq!(seen.len() as u128, total);
        // canonical descending sequence has rank 0
        assert_eq!(ms_perm_rank(&[4, 4, 2, 2, 2, 0, 0, 0]), 0);
    }

    #[test]
    fn small_indexer_counts() {
        let ix = LeechIndexer::new(3);
        assert_eq!(ix.num_points(), 16_969_680);
        assert_eq!(ix.index_bits(), 25);
        assert!((ix.bits_per_dim() - 25.0 / 24.0).abs() < 1e-12);
    }

    #[test]
    fn decode_encode_roundtrip_shell2() {
        let ix = LeechIndexer::new(2);
        let n = ix.num_points() as u64;
        assert_eq!(n, 196_560);
        // full sweep of the kissing configuration
        for idx in 0..n {
            let x = ix.decode_index(idx);
            assert_eq!(coset::shell_of(&x), Some(2));
            let back = ix.encode_point(&x).expect("encode failed");
            assert_eq!(back, idx, "roundtrip failed at index {idx}: {x:?}");
        }
    }

    #[test]
    fn decode_encode_roundtrip_sampled_high_shells() {
        let ix = LeechIndexer::new(6);
        let mut rng = Xoshiro256pp::new(31);
        let n = ix.num_points() as u64;
        for _ in 0..4000 {
            let idx = rng.next_range(n);
            let x = ix.decode_index(idx);
            let back = ix.encode_point(&x).expect("encode failed");
            assert_eq!(back, idx);
        }
    }

    #[test]
    fn per_class_boundary_indices_roundtrip() {
        // stress subclass/class/shell boundaries: first & last index of
        // every subclass for shells ≤ 5
        let ix = LeechIndexer::new(5);
        for (k, shell) in ix.shells().iter().enumerate() {
            let shell_base = ix.shell_offsets[k];
            for (ci, class) in shell.classes.iter().enumerate() {
                let class_base = shell_base + shell.class_offsets[ci];
                for (si, _sub) in class.subclasses.iter().enumerate() {
                    for &off in &[
                        class.subclass_offsets[si],
                        class.subclass_offsets[si + 1] - 1,
                    ] {
                        let idx = (class_base + off) as u64;
                        let x = ix.decode_index(idx);
                        assert_eq!(ix.encode_point(&x), Some(idx));
                    }
                }
            }
        }
    }

    #[test]
    fn rejects_out_of_ball_points() {
        let ix = LeechIndexer::new(2);
        // shell 3 point: (4, 2^8 on an octad, ...) → encode must fail
        let mut x = [1i32; DIM];
        x[0] = -3;
        // that's shell 2; craft shell 3 odd leader (5, 1^23): sum=28≡4 ✓
        let mut y = [1i32; DIM];
        y[0] = 5;
        // 5 ≡ 1 mod 4 so golay word must be 0 → all others ≡1 mod 4 ✓
        let sum: i32 = y.iter().sum();
        assert_eq!(sum.rem_euclid(8), 4);
        assert_eq!(coset::shell_of(&y), Some(3));
        assert!(ix.encode_point(&y).is_none());
        assert!(ix.encode_point(&x).is_some());
    }
}
