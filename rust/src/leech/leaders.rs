//! Shell → class → subclass enumeration of the Leech lattice (paper §2.4–2.6).
//!
//! We work in the integer embedding `L^int` (paper eq. 6): lattice points are
//! integer 24-vectors with squared norm `16·m` for shell m (the real lattice
//! is `L^int/√8`, giving squared norm `2m`).
//!
//! A **class** is the set of lattice points sharing an unordered multiset of
//! absolute coordinate values (the *leader*). Classes decompose further into
//! **subclasses**: for *even* classes the split of values between the Golay
//! support `F₁(c)` (values ≡ 2 mod 4) and its complement `F₀(c)` (values ≡ 0
//! mod 4) is forced, so there is exactly one subclass; for *odd* classes a
//! value `v` may sit in `F₁` (as `+v` if v ≡ 3 mod 4, else `−v`) or in `F₀`
//! (sign mirrored), so each admissible *split vector* — how many copies of
//! each distinct value live in `F₁` — forms its own subclass, filtered by the
//! global sum ≡ 4 (mod 8) constraint.
//!
//! Cardinalities follow paper eq. 12 in the subclass-resolved form
//!
//! ```text
//! |subclass| = A_w · 2^B · w!/∏ k_v! · (24−w)!/∏ (c_v − k_v)!
//! ```
//!
//! with `A_w` the number of Golay codewords of weight `w`, and `B` the free
//! sign bits (even classes only; odd-class signs are congruence-forced).
//! The module's correctness contract: Σ |class| over a shell equals the theta
//! series coefficient n(m) *exactly* — enforced in tests for every m ≤ 19.

use crate::golay::GolayCode;
use crate::DIM;

/// Coset parity of a class (paper eqs. 7–8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parity {
    Even,
    Odd,
}

/// One admissible split of the leader multiset between F₁ and F₀.
#[derive(Clone, Debug)]
pub struct Subclass {
    /// Golay codeword weight w = |F₁|.
    pub weight: usize,
    /// Number of admissible codewords of this weight (the `A` of eq. 12).
    pub num_codewords: u64,
    /// Per distinct value (aligned with [`ClassInfo::counts`]): how many
    /// copies sit in F₁.
    pub split: Vec<u8>,
    /// Canonical F₁ value sequence (descending), length `weight`.
    pub f1_seq: Vec<u8>,
    /// Canonical F₀ value sequence (descending), length `24 − weight`.
    pub f0_seq: Vec<u8>,
    /// w! / ∏ k_v! — multiset arrangements within F₁.
    pub f1_arrangements: u64,
    /// (24−w)! / ∏ (c_v − k_v)! — multiset arrangements within F₀.
    pub f0_arrangements: u64,
    /// Free sign bits `B` (even classes: #nonzero F₀ coords + max(w−1, 0);
    /// odd classes: 0).
    pub sign_bits: u32,
    /// Total subclass cardinality.
    pub size: u128,
}

/// A class: leader multiset + parity + its subclasses.
#[derive(Clone, Debug)]
pub struct ClassInfo {
    pub parity: Parity,
    /// Leader absolute values, non-increasing, length 24.
    pub values: [u8; DIM],
    /// Distinct (value, multiplicity), descending by value.
    pub counts: Vec<(u8, u8)>,
    /// For even classes with w > 0: required parity of the number of
    /// negative signs among F₁ coordinates (so that Σxᵢ ≡ 0 mod 8).
    pub f1_neg_parity: u8,
    pub subclasses: Vec<Subclass>,
    /// Cumulative subclass offsets (len = subclasses.len()+1), for local
    /// index ↔ subclass resolution.
    pub subclass_offsets: Vec<u128>,
    /// Total class cardinality = last subclass offset.
    pub size: u128,
}

/// All classes of one shell, in the crate's canonical deterministic order:
/// even classes before odd, then ascending lexicographic on the value tuple.
#[derive(Clone, Debug)]
pub struct ShellClasses {
    pub m: usize,
    pub classes: Vec<ClassInfo>,
    /// Cumulative class offsets within the shell (len = classes.len()+1).
    pub class_offsets: Vec<u128>,
    /// Shell cardinality n(m).
    pub size: u128,
}

fn factorial_u128(n: usize) -> u128 {
    (1..=n as u128).product()
}

/// w!/∏ mult! for the multiset described by `(value, mult)` pairs.
fn multiset_arrangements(len: usize, mults: &[u8]) -> u128 {
    let mut v = factorial_u128(len);
    for &m in mults {
        v /= factorial_u128(m as usize);
    }
    v
}

/// Enumerate all non-increasing 24-tuples of non-negative integers with the
/// given parity (0 = even values incl. zero, 1 = odd values) whose squared
/// sum is `total`.
fn enumerate_value_multisets(total: u32, parity: u8) -> Vec<[u8; DIM]> {
    let mut out = Vec::new();
    let mut seq = [0u8; DIM];

    fn rec(
        remaining: u32,
        slot: usize,
        cap: u8,
        parity: u8,
        seq: &mut [u8; DIM],
        out: &mut Vec<[u8; DIM]>,
    ) {
        let slots_left = DIM - slot;
        if slots_left == 0 {
            if remaining == 0 {
                out.push(*seq);
            }
            return;
        }
        let min_v: u32 = if parity == 0 { 0 } else { 1 };
        if remaining < min_v * min_v * slots_left as u32 {
            return;
        }
        let mut v = cap;
        loop {
            let vv = (v as u32) * (v as u32);
            if vv <= remaining {
                // feasibility: rest must fit under v, and reach the min
                let rest = remaining - vv;
                let max_rest = vv * (slots_left as u32 - 1);
                let min_rest = min_v * min_v * (slots_left as u32 - 1);
                if rest <= max_rest && rest >= min_rest {
                    seq[slot] = v;
                    rec(rest, slot + 1, v, parity, seq, out);
                }
            }
            if v < 2 {
                break;
            }
            v -= 2;
            if parity == 1 && v == 0 {
                break;
            }
        }
        // parity 1 loop must stop at v=1 handled above (v -= 2 from 1 wraps)
    }

    let mut cap = (total as f64).sqrt() as u8 + 1;
    while cap as u32 * cap as u32 > total || cap % 2 != parity {
        if cap == 0 {
            break;
        }
        cap -= 1;
    }
    if cap as u32 * cap as u32 <= total && cap % 2 == parity {
        rec(total, 0, cap, parity, &mut seq, &mut out);
    }
    out
}

fn distinct_counts(values: &[u8; DIM]) -> Vec<(u8, u8)> {
    let mut out: Vec<(u8, u8)> = Vec::new();
    for &v in values {
        match out.last_mut() {
            Some((lv, c)) if *lv == v => *c += 1,
            _ => out.push((v, 1)),
        }
    }
    out
}

/// Build the (single) subclass of an even class, or None if inadmissible.
fn build_even_class(golay: &GolayCode, values: [u8; DIM]) -> Option<ClassInfo> {
    let counts = distinct_counts(&values);
    // F1 = values ≡ 2 mod 4; F0 = values ≡ 0 mod 4
    let w: usize = values.iter().filter(|&&v| v % 4 == 2).count();
    let num_codewords = golay.count_of_weight(w);
    if num_codewords == 0 {
        return None;
    }
    let sum: u32 = values.iter().map(|&v| v as u32).sum();
    if w == 0 {
        // all coords ≡ 0 mod 4: sign flips change the sum by 0 mod 8, so the
        // all-positive sum itself must satisfy the constraint.
        if sum % 8 != 0 {
            return None;
        }
    }
    debug_assert_eq!(sum % 4, 0, "even-class sum must be ≡ 0 mod 4");
    let f1_neg_parity = ((sum % 8) / 4) as u8; // negatives among F1 must have this parity

    let f1_seq: Vec<u8> = values.iter().copied().filter(|v| v % 4 == 2).collect();
    let f0_seq: Vec<u8> = values.iter().copied().filter(|v| v % 4 == 0).collect();
    let split: Vec<u8> = counts
        .iter()
        .map(|&(v, c)| if v % 4 == 2 { c } else { 0 })
        .collect();
    let f1_mults: Vec<u8> = counts
        .iter()
        .filter(|&&(v, _)| v % 4 == 2)
        .map(|&(_, c)| c)
        .collect();
    let f0_mults: Vec<u8> = counts
        .iter()
        .filter(|&&(v, _)| v % 4 == 0)
        .map(|&(_, c)| c)
        .collect();
    let f1_arr = multiset_arrangements(w, &f1_mults);
    let f0_arr = multiset_arrangements(DIM - w, &f0_mults);
    let n_f0_nonzero = f0_seq.iter().filter(|&&v| v != 0).count() as u32;
    let sign_bits = n_f0_nonzero + if w > 0 { w as u32 - 1 } else { 0 };
    let size = num_codewords as u128 * (1u128 << sign_bits) * f1_arr * f0_arr;

    let sub = Subclass {
        weight: w,
        num_codewords: num_codewords as u64,
        split,
        f1_seq,
        f0_seq,
        f1_arrangements: f1_arr as u64,
        f0_arrangements: f0_arr as u64,
        sign_bits,
        size,
    };
    Some(ClassInfo {
        parity: Parity::Even,
        values,
        counts,
        f1_neg_parity,
        subclass_offsets: vec![0, size],
        subclasses: vec![sub],
        size,
    })
}

/// Signed value a coordinate takes in F₁ / F₀ for the odd coset: positions
/// in F₀ carry x ≡ 1 (mod 4), positions in F₁ carry x ≡ 3 (mod 4); the sign
/// of |x| is therefore forced by |x| mod 4.
#[inline]
pub fn odd_signed_value(abs: u8, in_f1: bool) -> i32 {
    let v = abs as i32;
    if in_f1 {
        if v % 4 == 3 {
            v
        } else {
            -v
        }
    } else if v % 4 == 1 {
        v
    } else {
        -v
    }
}

/// Build an odd class: enumerate admissible splits (subclasses).
fn build_odd_class(golay: &GolayCode, values: [u8; DIM]) -> Option<ClassInfo> {
    let counts = distinct_counts(&values);
    let mut subclasses = Vec::new();

    for &w in &crate::golay::WEIGHTS {
        let a_w = golay.count_of_weight(w) as u64;
        // enumerate split vectors k_v ∈ [0, c_v], Σ k_v = w
        let k = counts.len();
        let mut split = vec![0u8; k];
        fn rec(
            i: usize,
            left: usize,
            counts: &[(u8, u8)],
            split: &mut Vec<u8>,
            sum: i64,
            out: &mut Vec<(Vec<u8>, i64)>,
        ) {
            if i == counts.len() {
                if left == 0 {
                    out.push((split.clone(), sum));
                }
                return;
            }
            let (v, c) = counts[i];
            // remaining capacity check
            let cap_rest: usize = counts[i + 1..].iter().map(|&(_, c)| c as usize).sum();
            for kv in 0..=c.min(left as u8) {
                if (left - kv as usize) > cap_rest {
                    continue;
                }
                split[i] = kv;
                let s_f1 = odd_signed_value(v, true) as i64 * kv as i64;
                let s_f0 = odd_signed_value(v, false) as i64 * (c - kv) as i64;
                rec(i + 1, left - kv as usize, counts, split, sum + s_f1 + s_f0, out);
            }
            split[i] = 0;
        }
        let mut found: Vec<(Vec<u8>, i64)> = Vec::new();
        rec(0, w, &counts, &mut split, 0, &mut found);

        for (split, sum) in found {
            if a_w == 0 {
                continue;
            }
            if sum.rem_euclid(8) != 4 {
                continue; // violates Σxᵢ ≡ 4 (mod 8)
            }
            let mut f1_seq = Vec::with_capacity(w);
            let mut f0_seq = Vec::with_capacity(DIM - w);
            let mut f1_mults = Vec::new();
            let mut f0_mults = Vec::new();
            for (i, &(v, c)) in counts.iter().enumerate() {
                let kv = split[i];
                for _ in 0..kv {
                    f1_seq.push(v);
                }
                for _ in 0..(c - kv) {
                    f0_seq.push(v);
                }
                if kv > 0 {
                    f1_mults.push(kv);
                }
                if c - kv > 0 {
                    f0_mults.push(c - kv);
                }
            }
            let f1_arr = multiset_arrangements(w, &f1_mults);
            let f0_arr = multiset_arrangements(DIM - w, &f0_mults);
            let size = a_w as u128 * f1_arr * f0_arr;
            subclasses.push(Subclass {
                weight: w,
                num_codewords: a_w,
                split,
                f1_seq,
                f0_seq,
                f1_arrangements: f1_arr as u64,
                f0_arrangements: f0_arr as u64,
                sign_bits: 0,
                size,
            });
        }
    }

    if subclasses.is_empty() {
        return None;
    }
    // deterministic subclass order: by (weight, split lexicographic)
    subclasses.sort_by(|a, b| (a.weight, &a.split).cmp(&(b.weight, &b.split)));
    let mut offsets = Vec::with_capacity(subclasses.len() + 1);
    let mut acc = 0u128;
    offsets.push(0);
    for s in &subclasses {
        acc += s.size;
        offsets.push(acc);
    }
    Some(ClassInfo {
        parity: Parity::Odd,
        values,
        counts,
        f1_neg_parity: 0,
        subclasses,
        subclass_offsets: offsets,
        size: acc,
    })
}

/// Enumerate all classes of shell `m` (squared integer norm 16m) in
/// canonical order.
pub fn enumerate_shell(golay: &GolayCode, m: usize) -> ShellClasses {
    let total = 16 * m as u32;
    let mut classes: Vec<ClassInfo> = Vec::new();
    for values in enumerate_value_multisets(total, 0) {
        if let Some(c) = build_even_class(golay, values) {
            classes.push(c);
        }
    }
    for values in enumerate_value_multisets(total, 1) {
        if let Some(c) = build_odd_class(golay, values) {
            classes.push(c);
        }
    }
    // canonical order: even first, then odd; ascending on the value tuple
    classes.sort_by(|a, b| {
        let pa = matches!(a.parity, Parity::Odd) as u8;
        let pb = matches!(b.parity, Parity::Odd) as u8;
        (pa, a.values).cmp(&(pb, b.values))
    });

    let mut class_offsets = Vec::with_capacity(classes.len() + 1);
    let mut acc = 0u128;
    class_offsets.push(0);
    for c in &classes {
        acc += c.size;
        class_offsets.push(acc);
    }
    ShellClasses {
        m,
        classes,
        class_offsets,
        size: acc,
    }
}

impl ShellClasses {
    /// Human-readable composition row for the paper's Table 2: multiset of
    /// (value → multiplicity) with parity and count.
    pub fn composition_rows(&self) -> Vec<String> {
        self.classes
            .iter()
            .map(|c| {
                let comp: Vec<String> = c
                    .counts
                    .iter()
                    .map(|&(v, n)| format!("±{v}×{n}"))
                    .collect();
                format!(
                    "m={} {:5} {:>16}  {}",
                    self.m,
                    match c.parity {
                        Parity::Even => "even",
                        Parity::Odd => "odd",
                    },
                    c.size,
                    comp.join(" ")
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::leech::theta;

    fn golay() -> GolayCode {
        GolayCode::new()
    }

    #[test]
    fn shell2_classes_match_table2() {
        let g = golay();
        let s = enumerate_shell(&g, 2);
        assert_eq!(s.classes.len(), 3);
        // canonical order: even classes first, ascending value tuple —
        // (2^8, 0^16) sorts before (4,4,0^22)
        let c0 = &s.classes[0];
        assert_eq!(c0.parity, Parity::Even);
        assert_eq!(c0.size, 97152);
        // (4,4,0^22) even, 1104
        assert_eq!(s.classes[1].size, 1104);
        // (3,1^23) odd, 98304
        let c2 = &s.classes[2];
        assert_eq!(c2.parity, Parity::Odd);
        assert_eq!(c2.size, 98304);
        assert_eq!(s.size, 196_560);
    }

    #[test]
    fn shell3_and_4_match_table2() {
        let g = golay();
        let s3 = enumerate_shell(&g, 3);
        let sizes3: Vec<u128> = s3.classes.iter().map(|c| c.size).collect();
        let mut sorted3 = sizes3.clone();
        sorted3.sort();
        assert_eq!(sorted3, [98304, 3108864, 5275648, 8290304]);
        assert_eq!(s3.size, 16_773_120);

        let s4 = enumerate_shell(&g, 4);
        let mut sizes4: Vec<u128> = s4.classes.iter().map(|c| c.size).collect();
        sizes4.sort();
        assert_eq!(
            sizes4,
            vec![48, 170016, 777216, 24870912, 24870912, 46632960, 126615552, 174096384]
        );
        assert_eq!(s4.size, 398_034_000);
    }

    #[test]
    fn all_shells_match_theta_series() {
        let g = golay();
        let n = theta::shell_sizes(19);
        for m in 2..=19 {
            let s = enumerate_shell(&g, m);
            assert_eq!(s.size, n[m], "shell {m} enumeration != theta series");
        }
    }

    #[test]
    fn offsets_are_consistent() {
        let g = golay();
        for m in 2..=6 {
            let s = enumerate_shell(&g, m);
            assert_eq!(*s.class_offsets.last().unwrap(), s.size);
            for (i, c) in s.classes.iter().enumerate() {
                assert_eq!(
                    s.class_offsets[i + 1] - s.class_offsets[i],
                    c.size,
                    "class offset gap mismatch"
                );
                assert_eq!(*c.subclass_offsets.last().unwrap(), c.size);
                for (j, sub) in c.subclasses.iter().enumerate() {
                    assert_eq!(c.subclass_offsets[j + 1] - c.subclass_offsets[j], sub.size);
                    assert_eq!(sub.f1_seq.len(), sub.weight);
                    assert_eq!(sub.f0_seq.len(), DIM - sub.weight);
                    // subclass size formula
                    let expect = sub.num_codewords as u128
                        * (1u128 << sub.sign_bits)
                        * sub.f1_arrangements as u128
                        * sub.f0_arrangements as u128;
                    assert_eq!(sub.size, expect);
                }
            }
        }
    }

    #[test]
    fn odd_split_sums_are_4_mod_8() {
        let g = golay();
        for m in 2..=8 {
            let s = enumerate_shell(&g, m);
            for c in s.classes.iter().filter(|c| c.parity == Parity::Odd) {
                for sub in &c.subclasses {
                    let sum: i64 = sub
                        .f1_seq
                        .iter()
                        .map(|&v| odd_signed_value(v, true) as i64)
                        .chain(sub.f0_seq.iter().map(|&v| odd_signed_value(v, false) as i64))
                        .sum();
                    assert_eq!(sum.rem_euclid(8), 4);
                }
            }
        }
    }

    #[test]
    fn value_multiset_enumeration_sane() {
        // all 24-tuples for shell 2 (norm 32): even {4,4,0...}, {2^8,0^16},
        // and more that fail admissibility (e.g. {4,2,2,...}? 16+4k...)
        let evens = enumerate_value_multisets(32, 0);
        assert!(evens.iter().any(|v| v[0] == 4 && v[1] == 4 && v[2] == 0));
        assert!(evens.iter().any(|v| v[0] == 2 && v[7] == 2 && v[8] == 0));
        let odds = enumerate_value_multisets(32, 1);
        assert!(odds.iter().any(|v| v[0] == 3 && v[1] == 1));
        for v in evens.iter().chain(odds.iter()) {
            let ss: u32 = v.iter().map(|&x| (x as u32) * (x as u32)).sum();
            assert_eq!(ss, 32);
            for w in v.windows(2) {
                assert!(w[0] >= w[1], "not non-increasing");
            }
        }
    }
}
