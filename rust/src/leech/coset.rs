//! Integer-coordinate membership tests for the Leech lattice (paper eqs. 6–8).
//!
//! `L^int = L^even ∪ L^odd ⊂ ℤ²⁴`, with `Λ₂₄ = L^int / √8`. A point of
//! shell m has integer squared norm `16·m`.

use crate::golay::GolayCode;
use crate::DIM;

/// Scale between the integer embedding and the unit-covolume lattice:
/// `Λ₂₄ = L^int / √8`.
pub const SCALE: f64 = 2.828_427_124_746_190_3; // √8

/// Classify an integer vector: `Some(true)` = even coset, `Some(false)` =
/// odd coset, `None` = mixed parity (not in the lattice).
pub fn coset_parity(x: &[i32; DIM]) -> Option<bool> {
    let p = x[0].rem_euclid(2);
    if x.iter().all(|&v| v.rem_euclid(2) == p) {
        Some(p == 0)
    } else {
        None
    }
}

/// Full membership test for `L^int` (paper eqs. 7–8).
pub fn is_lattice_point(golay: &GolayCode, x: &[i32; DIM]) -> bool {
    match coset_parity(x) {
        None => false,
        Some(true) => {
            // (ii) (x/2) mod 2 ∈ G24 ; (iii) Σ x_i ≡ 0 (mod 8)
            let mut word = 0u32;
            for (i, &v) in x.iter().enumerate() {
                if (v / 2).rem_euclid(2) == 1 {
                    word |= 1 << i;
                }
            }
            let sum: i64 = x.iter().map(|&v| v as i64).sum();
            golay.contains(word) && sum.rem_euclid(8) == 0
        }
        Some(false) => {
            // (ii) ((x−1)/2) mod 2 ∈ G24 ; (iii) Σ x_i ≡ 4 (mod 8)
            let mut word = 0u32;
            for (i, &v) in x.iter().enumerate() {
                // ((v-1)/2) mod 2 == 1  ⇔  v ≡ 3 (mod 4)
                if v.rem_euclid(4) == 3 {
                    word |= 1 << i;
                }
            }
            let sum: i64 = x.iter().map(|&v| v as i64).sum();
            golay.contains(word) && sum.rem_euclid(8) == 4
        }
    }
}

/// Squared integer norm; shell index is `norm²/16` when it divides evenly.
pub fn norm_sq(x: &[i32; DIM]) -> i64 {
    x.iter().map(|&v| (v as i64) * (v as i64)).sum()
}

/// Shell index m of a lattice point (`‖x‖² = 16m`), or None for the origin /
/// non-multiples (non-lattice input).
pub fn shell_of(x: &[i32; DIM]) -> Option<usize> {
    let n = norm_sq(x);
    if n == 0 || n % 16 != 0 {
        None
    } else {
        Some((n / 16) as usize)
    }
}

/// Convert an integer lattice point to real coordinates (`/√8`).
pub fn to_real(x: &[i32; DIM]) -> [f64; DIM] {
    let mut out = [0.0; DIM];
    for i in 0..DIM {
        out[i] = x[i] as f64 / SCALE;
    }
    out
}

/// The Golay word induced by a lattice point (support of halved/shifted
/// mod-2 reduction). Assumes `x` has uniform parity.
pub fn golay_word_of(x: &[i32; DIM], even: bool) -> u32 {
    let mut word = 0u32;
    for (i, &v) in x.iter().enumerate() {
        let bit = if even {
            (v / 2).rem_euclid(2) == 1 // |v| ≡ 2 (mod 4)
        } else {
            v.rem_euclid(4) == 3
        };
        if bit {
            word |= 1 << i;
        }
    }
    word
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_minimal_vectors() {
        let g = GolayCode::new();
        // (±4, ±4, 0^22) with matching sum ≡ 0 mod 8
        let mut x = [0i32; DIM];
        x[0] = 4;
        x[1] = 4;
        assert!(is_lattice_point(&g, &x));
        assert_eq!(shell_of(&x), Some(2));
        x[1] = -4;
        assert!(is_lattice_point(&g, &x)); // sum 0 ≡ 0 ✓
        // (4, 2, 0...) mixed residues — not a point
        let mut y = [0i32; DIM];
        y[0] = 4;
        y[1] = 2;
        assert!(!is_lattice_point(&g, &y));
    }

    #[test]
    fn golay_support_class() {
        let g = GolayCode::new();
        // take a weight-8 codeword, build (2^8 on its support, 0 elsewhere),
        // fix the sign parity so Σ ≡ 0 mod 8: 8 coords of +2 → sum 16 ≡ 0 ✓
        let c = g.of_weight(8)[0];
        let mut x = [0i32; DIM];
        for i in 0..DIM {
            if c & (1 << i) != 0 {
                x[i] = 2;
            }
        }
        assert!(is_lattice_point(&g, &x));
        assert_eq!(shell_of(&x), Some(2));
        // flipping ONE sign breaks the mod-8 sum (16 − 4 = 12 ≢ 0)
        let i0 = (0..DIM).find(|&i| x[i] != 0).unwrap();
        x[i0] = -2;
        assert!(!is_lattice_point(&g, &x));
        // flipping TWO signs restores it (16 − 8 = 8 ≡ 0)
        let i1 = (i0 + 1..DIM).find(|&i| x[i] > 0).unwrap();
        x[i1] = -2;
        assert!(is_lattice_point(&g, &x));
    }

    #[test]
    fn odd_coset_member() {
        let g = GolayCode::new();
        // (-3, 1^23): all ≡ 1 mod 4 ⇒ Golay word 0 ∈ G24; sum = 20 ≡ 4 ✓
        let mut x = [1i32; DIM];
        x[0] = -3;
        assert!(is_lattice_point(&g, &x));
        assert_eq!(shell_of(&x), Some(2));
        // (+3, 1^23): 3 ≡ 3 mod 4 ⇒ word = e₀ ∉ G24 (weight 1)
        x[0] = 3;
        assert!(!is_lattice_point(&g, &x));
    }
}
