//! Flattened, kernel-ready dequantization tables.
//!
//! The paper's parallel dequantizer (§3.3 step 5) depends only on "small
//! static tables, integer prefix-sum scans, integer division and modulo".
//! This module flattens the shell → class → subclass hierarchy of
//! [`super::index::LeechIndexer`] into dense arrays consumable by
//!
//! * the Pallas kernel (`python/compile/kernels/llvq_dequant.py`) — fed as
//!   runtime inputs to the AOT-compiled HLO, so the HLO itself stays
//!   table-agnostic, and
//! * the Rust fast dequantization path used by benches and the serving
//!   coordinator.
//!
//! Every subclass becomes one **group** with a global cumulative offset;
//! dequantization is: `searchsorted(group_offsets, idx)` → fixed-radix
//! unpack (`A`, `2^B`, F₀ arrangements) → Golay unrank via one table read →
//! two multiset-permutation unranks over ≤ `max_distinct` symbols → sign
//! assembly. No data-dependent trip counts anywhere (TPU-friendly).

use crate::golay::{GolayCode, WEIGHTS};
use crate::leech::index::LeechIndexer;
use crate::leech::leaders::Parity;
use crate::util::json::Json;
use crate::DIM;

/// Maximum number of distinct |values| on either side of any class we
/// support. Verified at build time; 8 is ample for m ≤ 19.
pub const MAX_DISTINCT: usize = 8;

/// Dense dequantization tables (one "group" per subclass).
#[derive(Clone, Debug)]
pub struct KernelTables {
    pub max_m: usize,
    pub num_groups: usize,
    /// Global cumulative index offsets, len = num_groups + 1.
    pub group_offsets: Vec<i64>,
    /// Golay weight w per group.
    pub weight: Vec<i32>,
    /// A = number of admissible codewords per group.
    pub num_codewords: Vec<i32>,
    /// Offset of the group's weight bucket in `golay_sorted`.
    pub cw_base: Vec<i32>,
    /// Free sign bits B per group.
    pub sign_bits: Vec<i32>,
    /// 1 if the group belongs to the odd coset.
    pub parity_odd: Vec<i32>,
    /// Required parity of negative signs among F₁ (even groups).
    pub f1_neg_parity: Vec<i32>,
    /// (24−w)!/∏(c_v−k_v)! per group.
    pub f0_arrangements: Vec<i64>,
    /// w!/∏k_v! per group (diagnostics / ref implementations).
    pub f1_arrangements: Vec<i64>,
    /// F₁ distinct values / multiplicities, row-major [num_groups × MAX_DISTINCT].
    pub f1_values: Vec<i32>,
    pub f1_counts: Vec<i32>,
    /// F₀ distinct values / multiplicities, row-major [num_groups × MAX_DISTINCT].
    pub f0_values: Vec<i32>,
    pub f0_counts: Vec<i32>,
    /// All 4096 codewords sorted by (weight, value) — unrank-in-weight is
    /// `golay_sorted[cw_base[g] + rank]`.
    pub golay_sorted: Vec<i32>,
    /// Start offset of each weight bucket in `golay_sorted`, len = 6.
    pub weight_offsets: Vec<i32>,
}

impl KernelTables {
    pub fn build(ix: &LeechIndexer) -> Self {
        let golay = ix.golay();
        // golay table sorted by (weight, value)
        let mut golay_sorted = Vec::with_capacity(4096);
        let mut weight_offsets = Vec::with_capacity(WEIGHTS.len() + 1);
        weight_offsets.push(0i32);
        for &w in &WEIGHTS {
            for &c in golay.of_weight(w) {
                golay_sorted.push(c as i32);
            }
            weight_offsets.push(golay_sorted.len() as i32);
        }

        let weight_offsets_copy = weight_offsets.clone();
        let cw_base_of = move |w: usize| -> i32 {
            let b = WEIGHTS.iter().position(|&x| x == w).unwrap();
            weight_offsets_copy[b]
        };

        let mut t = KernelTables {
            max_m: ix.max_m(),
            num_groups: 0,
            group_offsets: vec![0],
            weight: vec![],
            num_codewords: vec![],
            cw_base: vec![],
            sign_bits: vec![],
            parity_odd: vec![],
            f1_neg_parity: vec![],
            f0_arrangements: vec![],
            f1_arrangements: vec![],
            f1_values: vec![],
            f1_counts: vec![],
            f0_values: vec![],
            f0_counts: vec![],
            golay_sorted,
            weight_offsets,
        };

        let mut acc: u128 = 0;
        for shell in ix.shells() {
            for class in &shell.classes {
                for sub in &class.subclasses {
                    acc += sub.size;
                    t.group_offsets.push(acc as i64);
                    t.weight.push(sub.weight as i32);
                    t.num_codewords.push(sub.num_codewords as i32);
                    t.cw_base.push(cw_base_of(sub.weight));
                    t.sign_bits.push(sub.sign_bits as i32);
                    t.parity_odd.push((class.parity == Parity::Odd) as i32);
                    t.f1_neg_parity.push(class.f1_neg_parity as i32);
                    t.f0_arrangements.push(sub.f0_arrangements as i64);
                    t.f1_arrangements.push(sub.f1_arrangements as i64);

                    let pack = |seq: &[u8], values: &mut Vec<i32>, counts: &mut Vec<i32>| {
                        let mut pairs: Vec<(u8, u8)> = Vec::new();
                        for &v in seq {
                            match pairs.last_mut() {
                                Some((lv, c)) if *lv == v => *c += 1,
                                _ => pairs.push((v, 1)),
                            }
                        }
                        assert!(
                            pairs.len() <= MAX_DISTINCT,
                            "class exceeds MAX_DISTINCT: {pairs:?}"
                        );
                        for k in 0..MAX_DISTINCT {
                            if k < pairs.len() {
                                values.push(pairs[k].0 as i32);
                                counts.push(pairs[k].1 as i32);
                            } else {
                                values.push(0);
                                counts.push(0);
                            }
                        }
                    };
                    pack(&sub.f1_seq, &mut t.f1_values, &mut t.f1_counts);
                    pack(&sub.f0_seq, &mut t.f0_values, &mut t.f0_counts);
                }
            }
        }
        t.num_groups = t.weight.len();
        assert_eq!(acc, ix.num_points());
        t
    }

    /// Total number of indexable points.
    pub fn num_points(&self) -> i64 {
        *self.group_offsets.last().unwrap()
    }

    /// Fast table-driven dequantization — mirrors the Pallas kernel's
    /// arithmetic exactly (used by benches, the serving path, and as the
    /// rust-side oracle for the kernel integration test).
    pub fn dequantize(&self, index: u64) -> [i32; DIM] {
        let idx = index as i64;
        debug_assert!(idx < self.num_points());
        // group lookup
        let g = match self.group_offsets.binary_search(&idx) {
            Ok(e) => e,
            Err(ins) => ins - 1,
        };
        let mut local = (idx - self.group_offsets[g]) as u128;

        let a = self.num_codewords[g] as u128;
        let c_rank = (local % a) as usize;
        local /= a;
        let b = self.sign_bits[g] as u32;
        let sign_rank = (local & ((1u128 << b) - 1)) as u64;
        local >>= b;
        let f0_arr = self.f0_arrangements[g] as u128;
        let f1_rank = local / f0_arr;
        let f0_rank = local % f0_arr;

        let codeword = self.golay_sorted[(self.cw_base[g] + c_rank as i32) as usize] as u32;
        let w = self.weight[g] as usize;

        // unrank both multiset permutations
        let row = g * MAX_DISTINCT;
        let mut f1_vals = [0u8; DIM];
        let mut f0_vals = [0u8; DIM];
        unrank_into(
            &self.f1_values[row..row + MAX_DISTINCT],
            &self.f1_counts[row..row + MAX_DISTINCT],
            w,
            f1_rank,
            &mut f1_vals,
        );
        unrank_into(
            &self.f0_values[row..row + MAX_DISTINCT],
            &self.f0_counts[row..row + MAX_DISTINCT],
            DIM - w,
            f0_rank,
            &mut f0_vals,
        );

        // assemble with signs
        let mut x = [0i32; DIM];
        if self.parity_odd[g] == 1 {
            let (mut i1, mut i0) = (0usize, 0usize);
            for i in 0..DIM {
                if codeword & (1 << i) != 0 {
                    x[i] = crate::leech::leaders::odd_signed_value(f1_vals[i1], true);
                    i1 += 1;
                } else {
                    x[i] = crate::leech::leaders::odd_signed_value(f0_vals[i0], false);
                    i0 += 1;
                }
            }
        } else {
            let mut bit = 0u32;
            let (mut i1, mut i0) = (0usize, 0usize);
            let mut f1_negs = 0u32;
            let mut last_f1 = usize::MAX;
            for i in 0..DIM {
                if codeword & (1 << i) != 0 {
                    x[i] = f1_vals[i1] as i32;
                    i1 += 1;
                    last_f1 = i;
                } else {
                    let v = f0_vals[i0] as i32;
                    i0 += 1;
                    if v != 0 {
                        if (sign_rank >> bit) & 1 == 1 {
                            x[i] = -v;
                        } else {
                            x[i] = v;
                        }
                        bit += 1;
                    }
                }
            }
            if w > 0 {
                // w−1 free F1 sign bits (ascending order over F1 positions
                // except the last), then parity repair on the last
                for i in 0..DIM {
                    if codeword & (1 << i) != 0 && i != last_f1 {
                        if (sign_rank >> bit) & 1 == 1 {
                            x[i] = -x[i];
                            f1_negs += 1;
                        }
                        bit += 1;
                    }
                }
                if f1_negs % 2 != self.f1_neg_parity[g] as u32 {
                    x[last_f1] = -x[last_f1];
                }
            }
            debug_assert_eq!(bit, b);
        }
        x
    }

    /// Serialize to JSON (consumed by pytest cross-checks and available for
    /// external tooling). Large i64s are exact: our JSON codec keeps
    /// integers as i64.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("max_m", Json::Int(self.max_m as i64)),
            ("num_groups", Json::Int(self.num_groups as i64)),
            ("max_distinct", Json::Int(MAX_DISTINCT as i64)),
            ("group_offsets", Json::arr_i64(&self.group_offsets)),
            (
                "weight",
                Json::Arr(self.weight.iter().map(|&v| Json::Int(v as i64)).collect()),
            ),
            (
                "num_codewords",
                Json::Arr(
                    self.num_codewords
                        .iter()
                        .map(|&v| Json::Int(v as i64))
                        .collect(),
                ),
            ),
            (
                "cw_base",
                Json::Arr(self.cw_base.iter().map(|&v| Json::Int(v as i64)).collect()),
            ),
            (
                "sign_bits",
                Json::Arr(self.sign_bits.iter().map(|&v| Json::Int(v as i64)).collect()),
            ),
            (
                "parity_odd",
                Json::Arr(
                    self.parity_odd
                        .iter()
                        .map(|&v| Json::Int(v as i64))
                        .collect(),
                ),
            ),
            (
                "f1_neg_parity",
                Json::Arr(
                    self.f1_neg_parity
                        .iter()
                        .map(|&v| Json::Int(v as i64))
                        .collect(),
                ),
            ),
            ("f0_arrangements", Json::arr_i64(&self.f0_arrangements)),
            ("f1_arrangements", Json::arr_i64(&self.f1_arrangements)),
            (
                "f1_values",
                Json::Arr(self.f1_values.iter().map(|&v| Json::Int(v as i64)).collect()),
            ),
            (
                "f1_counts",
                Json::Arr(self.f1_counts.iter().map(|&v| Json::Int(v as i64)).collect()),
            ),
            (
                "f0_values",
                Json::Arr(self.f0_values.iter().map(|&v| Json::Int(v as i64)).collect()),
            ),
            (
                "f0_counts",
                Json::Arr(self.f0_counts.iter().map(|&v| Json::Int(v as i64)).collect()),
            ),
            (
                "golay_sorted",
                Json::Arr(
                    self.golay_sorted
                        .iter()
                        .map(|&v| Json::Int(v as i64))
                        .collect(),
                ),
            ),
            (
                "weight_offsets",
                Json::Arr(
                    self.weight_offsets
                        .iter()
                        .map(|&v| Json::Int(v as i64))
                        .collect(),
                ),
            ),
        ])
    }

    /// Approximate VMEM footprint of all tables in bytes — used by the
    /// §Hardware-Adaptation analysis (must stay well under a TPU core's
    /// ~16 MiB VMEM; measured ≈ 1.8 MiB at M = 13, dominated by the
    /// ~10k odd-class subclass groups).
    pub fn vmem_bytes(&self) -> usize {
        self.group_offsets.len() * 8
            + self.num_groups * (4 * 7 + 8 * 2 + MAX_DISTINCT * 4 * 4)
            + self.golay_sorted.len() * 4
            + self.weight_offsets.len() * 4
    }
}

fn unrank_into(values: &[i32], counts: &[i32], len: usize, mut rank: u128, out: &mut [u8]) {
    let mut cnt = [0i64; MAX_DISTINCT];
    for k in 0..MAX_DISTINCT {
        cnt[k] = counts[k] as i64;
    }
    let mut total: u128 = {
        let mut t = (1..=len as u128).product::<u128>();
        for &c in counts {
            t /= (1..=c as u128).product::<u128>();
        }
        t
    };
    let mut rem = len as u128;
    for pos in 0..len {
        for k in 0..MAX_DISTINCT {
            if cnt[k] == 0 {
                continue;
            }
            let c = total * cnt[k] as u128 / rem;
            if rank < c {
                out[pos] = values[k] as u8;
                total = c;
                cnt[k] -= 1;
                rem -= 1;
                break;
            }
            rank -= c;
        }
    }
}

/// The `GolayCode` used to build tables; re-exported for tests.
pub fn build_default(max_m: usize) -> (LeechIndexer, KernelTables) {
    let _ = GolayCode::new(); // (cheap; explicit for readability)
    let ix = LeechIndexer::new(max_m);
    let t = KernelTables::build(&ix);
    (ix, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn tables_match_indexer_dequantizer() {
        let (ix, t) = build_default(4);
        let mut rng = Xoshiro256pp::new(123);
        let n = ix.num_points() as u64;
        for _ in 0..3000 {
            let idx = rng.next_range(n);
            assert_eq!(
                t.dequantize(idx),
                ix.decode_index(idx),
                "table dequant disagrees at {idx}"
            );
        }
        // boundaries
        for idx in [0u64, 1, n - 1, 196_559, 196_560] {
            assert_eq!(t.dequantize(idx), ix.decode_index(idx));
        }
    }

    #[test]
    fn group_offsets_cover_everything() {
        let (ix, t) = build_default(3);
        assert_eq!(t.num_points() as u128, ix.num_points());
        for w in t.group_offsets.windows(2) {
            assert!(w[0] < w[1], "empty or unordered group");
        }
    }

    #[test]
    fn vmem_budget_holds_at_2bpd() {
        let (_, t) = build_default(13);
        let bytes = t.vmem_bytes();
        assert!(
            bytes < 4 * 1024 * 1024,
            "kernel tables {bytes}B exceed the 4 MiB VMEM budget"
        );
    }

    #[test]
    fn json_roundtrip_shapes() {
        let (_, t) = build_default(2);
        let j = t.to_json();
        let s = j.to_string_compact();
        let back = crate::util::json::parse(&s).unwrap();
        assert_eq!(
            back.get("num_groups").unwrap().as_i64().unwrap() as usize,
            t.num_groups
        );
        assert_eq!(
            back.get("group_offsets").unwrap().as_arr().unwrap().len(),
            t.num_groups + 1
        );
    }
}
