//! Closed-form per-column scale fine-tuning (paper §5.4, App. D.1 eq. 23).
//!
//! After quantization we learn an element-wise multiplicative correction on
//! the layer *inputs* — equivalently per-column scales β for the quantized
//! weights Q: the model computes `Q·diag(β)·x ≈ W·x`. Because β is shared
//! across rows its bit cost is negligible (< 0.001 bpw, per the paper).
//!
//! Minimizing `E‖(W − Q·diag(β))x‖²  = Tr((W−QD)·H·(W−QD)ᵀ)` in β is a
//! linear system:  `M·β = v` with `M = (QᵀQ) ⊙ Hᵀ` (Hadamard product, SPD)
//! and `v = diag(Qᵀ·W·H)` — solved by one Cholesky. This is eq. 23 in its
//! population (Hessian) form.

use crate::math::linalg::{solve_spd, Matrix};

/// Solve for the optimal per-column scales of `q_hat` against reference
/// weights `w` (both row-major rows×cols) under input Hessian `h`.
/// Returns β (len = cols).
pub fn optimal_column_scales(
    w: &[f32],
    q_hat: &[f32],
    rows: usize,
    cols: usize,
    h: &Matrix,
) -> Vec<f64> {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(q_hat.len(), rows * cols);
    // QᵀQ and QᵀW (cols × cols) — accumulate in f64
    let mut qtq = Matrix::zeros(cols, cols);
    let mut qtw = Matrix::zeros(cols, cols);
    for r in 0..rows {
        let wr = &w[r * cols..(r + 1) * cols];
        let qr = &q_hat[r * cols..(r + 1) * cols];
        for i in 0..cols {
            let qi = qr[i] as f64;
            if qi == 0.0 {
                continue;
            }
            let rowq = &mut qtq.data[i * cols..(i + 1) * cols];
            let roww = &mut qtw.data[i * cols..(i + 1) * cols];
            for j in 0..cols {
                rowq[j] += qi * qr[j] as f64;
                roww[j] += qi * wr[j] as f64;
            }
        }
    }
    // M = (QᵀQ) ⊙ Hᵀ ;  v_k = [Qᵀ W H]_{kk} = Σ_j (QᵀW)_{kj} H_{jk}
    let mut m = Matrix::zeros(cols, cols);
    let mut v = vec![0f64; cols];
    for k in 0..cols {
        for j in 0..cols {
            *m.at_mut(k, j) = qtq.at(k, j) * h.at(j, k);
            v[k] += qtw.at(k, j) * h.at(j, k);
        }
    }
    m.damp_diagonal(1e-6);
    match solve_spd(&m, &v) {
        Ok(beta) => beta
            .into_iter()
            .map(|b| if b.is_finite() { b.clamp(0.25, 4.0) } else { 1.0 })
            .collect(),
        Err(_) => vec![1.0; cols],
    }
}

/// Apply scales in place: `q_hat[:, j] *= β[j]`.
pub fn apply_column_scales(q_hat: &mut [f32], cols: usize, beta: &[f64]) {
    for row in q_hat.chunks_exact_mut(cols) {
        for (x, &b) in row.iter_mut().zip(beta) {
            *x = (*x as f64 * b) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::gptq::proxy_loss;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn recovers_planted_scales_exactly() {
        // if Q = W·diag(1/β) then β must be recovered and the loss → 0
        let (rows, cols) = (32, 16);
        let mut rng = Xoshiro256pp::new(1);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.next_gaussian() as f32).collect();
        let beta_true: Vec<f64> = (0..cols).map(|j| 0.8 + 0.03 * j as f64).collect();
        let q: Vec<f32> = w
            .iter()
            .enumerate()
            .map(|(i, &x)| (x as f64 / beta_true[i % cols]) as f32)
            .collect();
        let h = Matrix::identity(cols);
        let beta = optimal_column_scales(&w, &q, rows, cols, &h);
        for (b, bt) in beta.iter().zip(&beta_true) {
            assert!((b - bt).abs() < 1e-3, "{b} vs {bt}");
        }
    }

    #[test]
    fn finetune_never_hurts_proxy_loss() {
        let (rows, cols) = (24, 24);
        let mut rng = Xoshiro256pp::new(2);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.next_gaussian() as f32).collect();
        // crude quantization: round to 0.5 grid
        let q: Vec<f32> = w.iter().map(|&x| (x * 2.0).round() / 2.0).collect();
        // correlated H
        let mut a = Matrix::zeros(cols, cols);
        for v in a.data.iter_mut() {
            *v = rng.next_gaussian() * 0.2;
        }
        for i in 0..cols {
            *a.at_mut(i, i) += 1.0;
        }
        let h = a.matmul(&a.transpose());
        let before = proxy_loss(&w, &q, rows, cols, &h);
        let beta = optimal_column_scales(&w, &q, rows, cols, &h);
        let mut q2 = q.clone();
        apply_column_scales(&mut q2, cols, &beta);
        let after = proxy_loss(&w, &q2, rows, cols, &h);
        assert!(
            after <= before * 1.0001,
            "finetune increased loss: {before} → {after}"
        );
    }

    #[test]
    fn scales_are_clamped_and_finite() {
        let w = vec![0f32; 4 * 4];
        let q = vec![0f32; 4 * 4]; // degenerate: all zeros
        let h = Matrix::identity(4);
        let beta = optimal_column_scales(&w, &q, 4, 4, &h);
        for b in beta {
            assert!(b.is_finite() && (0.25..=4.0).contains(&b));
        }
    }
}
