//! Hessian-corrected layer quantization — GPTQ generalized to 24-dim
//! vector quantization (paper App. D.2; the LDLQ-style block update).
//!
//! Columns of `W ∈ ℝ^{N×D}` are processed in blocks matching the
//! quantizer's dimension. After quantizing block `C`, the *remaining*
//! columns receive the analytic correction
//!
//! ```text
//! ΔW_R★ = −ΔW_C · H_CR · H_RR⁻¹     (H restricted to remaining columns)
//! ```
//!
//! — sequential Gaussian conditioning, the explicit form of the paper's
//! `Δw_R★ = −L_RR⁻¹ L_RC Δw_C` — so errors committed on early blocks are
//! compensated by later ones. All quantizers run through the *same* update
//! — the paper's point that comparisons isolate the representation.
//!
//! Rows are independent (eq. after 25), so the row loop is parallelized
//! over the thread pool.

use crate::math::linalg::Matrix;
use crate::quant::{write_code_with, Code, PackedCodes, VectorQuantizer};
use crate::util::bits::BitWriter;
use crate::util::threadpool;

/// Per-layer quantization result.
pub struct QuantizedLayer {
    /// Reconstructed (dequantized) weights, row-major N×D.
    pub w_hat: Vec<f32>,
    /// Exact payload bits consumed.
    pub total_bits: u64,
    /// Tr(ΔW·H·ΔWᵀ) proxy loss after correction (diagnostic).
    pub proxy_loss: f64,
    /// Per-layer input scale applied before quantization (`w_hat` is
    /// already multiplied back); recorded in the packed artifact so the
    /// load path reproduces the reconstruction bit-exactly.
    pub sigma: f64,
    /// The codes themselves, bit-packed per row — the payload of the
    /// `.llvqm` packed-model format.
    pub packed: PackedCodes,
}

/// Configuration for the correction pass.
#[derive(Clone, Copy, Debug)]
pub struct GptqConfig {
    /// Diagonal damping as a fraction of mean(diag(H)) (GPTQ default 0.01).
    pub damp: f64,
    /// If false, skip error propagation (pure round-to-nearest per block —
    /// the "RTN" ablation).
    pub use_corrections: bool,
    /// Worker threads for the row loop.
    pub threads: usize,
}

impl Default for GptqConfig {
    fn default() -> Self {
        Self {
            damp: 0.01,
            use_corrections: true,
            threads: threadpool::default_threads(),
        }
    }
}

/// Quantize `w` (row-major, `rows × cols`) against input Hessian `h`
/// (cols × cols) with the given block quantizer.
///
/// A per-layer scale is applied so the quantizer sees ≈ unit-variance
/// blocks: `σ = rms(w)`; LLVQ/E8/scalar codebooks are all calibrated for
/// N(0,1) inputs.
pub fn quantize_layer(
    w: &[f32],
    rows: usize,
    cols: usize,
    h: &Matrix,
    q: &dyn VectorQuantizer,
    cfg: &GptqConfig,
) -> QuantizedLayer {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(h.rows, cols);
    let d = q.dim();
    let nblocks = cols.div_ceil(d);

    // layer scale: unit RMS for the quantizer
    let sigma = {
        let ss: f64 = w.iter().map(|&v| (v as f64) * (v as f64)).sum();
        (ss / w.len() as f64).sqrt().max(1e-12)
    };

    // damped Hessian (shared across rows)
    let hd = {
        let mut hd = h.clone();
        hd.damp_diagonal(cfg.damp);
        hd
    };

    // Precompute, per block b, the conditional-mean operator
    //   M_b = (H_RR⁻¹ · H_RC)ᵀ = H_CR · H_RR⁻¹            (bw × rest)
    // over the REMAINING columns R = hi..cols (sequential Gaussian
    // conditioning — the greedy-optimal update of App. D.2). The row
    // update is then ΔW_R ← ΔW_R − Δ_B · M_b.
    let mut correction: Vec<Matrix> = Vec::with_capacity(nblocks);
    for b in 0..nblocks {
        let lo = b * d;
        let hi = ((b + 1) * d).min(cols);
        let bw = hi - lo;
        let rest = cols - hi;
        if !cfg.use_corrections || rest == 0 {
            correction.push(Matrix::zeros(bw, 0));
            continue;
        }
        // H_RR (rest × rest) of the damped Hessian
        let mut hrr = Matrix::zeros(rest, rest);
        for i in 0..rest {
            for j in 0..rest {
                *hrr.at_mut(i, j) = hd.at(hi + i, hi + j);
            }
        }
        let l = crate::math::linalg::cholesky(&hrr).expect("damped H_RR must be SPD");
        // columns of H_RC are rows of H_CR: solve H_RR · m_i = H_{R, lo+i}
        let mut m = Matrix::zeros(bw, rest);
        let mut rhs = vec![0f64; rest];
        for i in 0..bw {
            for r in 0..rest {
                rhs[r] = hd.at(hi + r, lo + i);
            }
            let y = crate::math::linalg::solve_lower(&l, &rhs);
            let col = crate::math::linalg::solve_lower_t(&l, &y);
            for r in 0..rest {
                *m.at_mut(i, r) = col[r];
            }
        }
        correction.push(m);
    }

    // Codec geometry for the packed payload: every row becomes one
    // byte-aligned MSB-first stream so the load path can decode rows in
    // parallel from fixed byte offsets.
    let widths = q.code_widths();
    let code_bits: u32 = widths.iter().sum();
    let row_bytes = ((nblocks as u64 * code_bits as u64).div_ceil(8)) as usize;

    // Row-parallel quantization with error propagation. Each row slot
    // holds (reconstructed weights, packed code stream).
    let w_hat: Vec<std::sync::Mutex<(Vec<f32>, Vec<u8>)>> = (0..rows)
        .map(|_| std::sync::Mutex::new((Vec::new(), Vec::new())))
        .collect();
    let bits_acc = std::sync::atomic::AtomicU64::new(0);

    threadpool::parallel_dynamic(rows, cfg.threads, 4, |r| {
        let mut row: Vec<f64> = w[r * cols..(r + 1) * cols]
            .iter()
            .map(|&v| v as f64 / sigma)
            .collect();
        let mut out = vec![0f32; cols];
        let mut bits = 0u64;
        let mut blk_in = vec![0f32; d];
        let mut blk_out = vec![0f32; d];
        // one scratch code + one bit stream per row: the block loop never
        // allocates (`quantize_into` reuses the words buffer)
        let mut code = Code::empty();
        let mut stream = BitWriter::with_capacity(row_bytes);
        for b in 0..nblocks {
            let lo = b * d;
            let hi = ((b + 1) * d).min(cols);
            let bw = hi - lo;
            for i in 0..bw {
                blk_in[i] = row[lo + i] as f32;
            }
            for v in blk_in[bw..].iter_mut() {
                *v = 0.0;
            }
            q.quantize_into(&blk_in, &mut code);
            bits += code.bits as u64;
            write_code_with(&widths, &code, &mut stream);
            q.dequantize(&code, &mut blk_out);
            for i in 0..bw {
                out[lo + i] = blk_out[i];
            }
            // propagate the committed error into remaining columns:
            // Δ_R★ = −Δ_B · H_CR·H_RR⁻¹ , applied as W_R ← W_R + Δ_R★
            let m = &correction[b];
            if m.cols > 0 {
                let mut delta = vec![0f64; bw];
                for i in 0..bw {
                    delta[i] = blk_out[i] as f64 - row[lo + i];
                }
                for jc in 0..m.cols {
                    let mut acc = 0.0;
                    for i in 0..bw {
                        acc += delta[i] * m.at(i, jc);
                    }
                    row[hi + jc] -= acc;
                }
            }
        }
        for v in out.iter_mut() {
            *v = (*v as f64 * sigma) as f32;
        }
        let row_stream = stream.finish();
        debug_assert_eq!(row_stream.len(), row_bytes);
        bits_acc.fetch_add(bits, std::sync::atomic::Ordering::Relaxed);
        *w_hat[r].lock().unwrap_or_else(|e| e.into_inner()) = (out, row_stream);
    });

    // assemble + proxy loss
    let mut flat = vec![0f32; rows * cols];
    let mut data = vec![0u8; rows * row_bytes];
    for (r, m) in w_hat.iter().enumerate() {
        let v = m.lock().unwrap_or_else(|e| e.into_inner());
        flat[r * cols..(r + 1) * cols].copy_from_slice(&v.0);
        data[r * row_bytes..(r + 1) * row_bytes].copy_from_slice(&v.1);
    }
    let proxy_loss = proxy_loss(w, &flat, rows, cols, h);
    QuantizedLayer {
        w_hat: flat,
        total_bits: bits_acc.into_inner(),
        proxy_loss,
        sigma,
        packed: PackedCodes {
            code_bits,
            blocks_per_row: nblocks,
            row_bytes,
            data,
        },
    }
}

/// Tr(ΔW·H·ΔWᵀ) — the paper's local objective (eq. 25), for diagnostics
/// and for the Table 6 style ablations.
pub fn proxy_loss(w: &[f32], w_hat: &[f32], rows: usize, cols: usize, h: &Matrix) -> f64 {
    let mut total = 0.0;
    let mut delta = vec![0f64; cols];
    for r in 0..rows {
        for j in 0..cols {
            delta[j] = w_hat[r * cols + j] as f64 - w[r * cols + j] as f64;
        }
        let hd = h.matvec(&delta);
        for j in 0..cols {
            total += delta[j] * hd[j];
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scalar::UniformQuantizer;
    use crate::util::rng::Xoshiro256pp;

    fn random_problem(rows: usize, cols: usize, seed: u64) -> (Vec<f32>, Matrix) {
        let mut rng = Xoshiro256pp::new(seed);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.next_gaussian() as f32).collect();
        // correlated activations: x = A g → H = A Aᵀ-ish
        let mut a = Matrix::zeros(cols, cols);
        for v in a.data.iter_mut() {
            *v = rng.next_gaussian() * 0.3;
        }
        for i in 0..cols {
            *a.at_mut(i, i) += 1.0;
        }
        let h = a.matmul(&a.transpose());
        (w, h)
    }

    #[test]
    fn corrections_reduce_proxy_loss() {
        let (w, h) = random_problem(16, 48, 5);
        let q = UniformQuantizer::new_gaussian_optimal(3);
        let cfg_on = GptqConfig {
            threads: 2,
            ..Default::default()
        };
        let cfg_off = GptqConfig {
            use_corrections: false,
            threads: 2,
            ..Default::default()
        };
        let on = quantize_layer(&w, 16, 48, &h, &q, &cfg_on);
        let off = quantize_layer(&w, 16, 48, &h, &q, &cfg_off);
        assert!(
            on.proxy_loss < off.proxy_loss,
            "GPTQ correction did not help: {} vs {}",
            on.proxy_loss,
            off.proxy_loss
        );
        // typical gains are substantial on correlated Hessians
        assert!(on.proxy_loss < 0.9 * off.proxy_loss);
    }

    #[test]
    fn bit_accounting_exact() {
        let (w, h) = random_problem(4, 24, 6);
        let q = UniformQuantizer::new_gaussian_optimal(2);
        let out = quantize_layer(&w, 4, 24, &h, &q, &GptqConfig::default());
        assert_eq!(out.total_bits, 4 * 24 * 2);
    }

    #[test]
    fn identity_hessian_reduces_to_rtn() {
        // with H = I the correction matrix M = 0ish? No: Hinv = I; M =
        // (I_BB)^-1 I_BR = 0 since off-diagonal blocks vanish → update is 0,
        // so corrected == uncorrected exactly.
        let mut rng = Xoshiro256pp::new(9);
        let w: Vec<f32> = (0..8 * 16).map(|_| rng.next_gaussian() as f32).collect();
        let h = Matrix::identity(16);
        let q = UniformQuantizer::new_gaussian_optimal(4);
        let a = quantize_layer(&w, 8, 16, &h, &q, &GptqConfig::default());
        let b = quantize_layer(
            &w,
            8,
            16,
            &h,
            &q,
            &GptqConfig {
                use_corrections: false,
                ..Default::default()
            },
        );
        for (x, y) in a.w_hat.iter().zip(&b.w_hat) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let (w, h) = random_problem(12, 24, 11);
        let q = UniformQuantizer::new_gaussian_optimal(3);
        let a = quantize_layer(&w, 12, 24, &h, &q, &GptqConfig { threads: 1, ..Default::default() });
        let b = quantize_layer(&w, 12, 24, &h, &q, &GptqConfig { threads: 8, ..Default::default() });
        assert_eq!(a.w_hat, b.w_hat);
        assert_eq!(a.total_bits, b.total_bits);
        assert_eq!(a.packed, b.packed);
    }

    #[test]
    fn packed_codes_reproduce_w_hat_bit_exactly() {
        // decoding the per-row bitstreams and re-applying σ must land on
        // exactly the reconstruction the pipeline produced
        let (w, h) = random_problem(6, 48, 13);
        let q = UniformQuantizer::new_gaussian_optimal(3);
        let out = quantize_layer(&w, 6, 48, &h, &q, &GptqConfig::default());
        let widths = q.code_widths();
        let nblocks = out.packed.blocks_per_row;
        assert_eq!(nblocks, 48);
        assert_eq!(out.packed.rows(), 6);
        let mut code = crate::quant::Code::empty();
        let mut blk = vec![0f32; q.dim()];
        for r in 0..6 {
            let rb = out.packed.row_bytes;
            let mut br = crate::util::bits::BitReader::new(&out.packed.data[r * rb..(r + 1) * rb]);
            for b in 0..nblocks {
                crate::quant::read_code_with(&widths, &mut br, &mut code);
                q.dequantize(&code, &mut blk);
                let got = (blk[0] as f64 * out.sigma) as f32;
                assert_eq!(got, out.w_hat[r * 48 + b], "row {r} block {b}");
            }
        }
    }
}
