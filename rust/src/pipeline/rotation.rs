//! Hadamard incoherence preprocessing (paper §5.3, Table 6).
//!
//! A linear layer `y = W·x` is reparameterized with randomized Hadamard
//! rotations: `W ← R_out · W · R_inᵀ`, `x ← R_in·x`, `y ← R_outᵀ·y` — the
//! function is preserved while the weight marginals become Gaussian-like.
//! Three modes, matching the paper's ablation: none / input / input+output.
//!
//! The pipeline rotates (W, H) before quantization and un-rotates the
//! reconstruction afterwards, so downstream evaluation never needs to know
//! which mode was used (this mirrors "fused/merged" rotations; the paper's
//! discussion of *online* Hadamard cost is reproduced in the serving bench,
//! which can apply R_in on the request path).

use crate::math::hadamard::RandomizedHadamard;
use crate::math::linalg::Matrix;

/// Rotation mode for a layer (paper Table 6 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RotationMode {
    None,
    Input,
    InputOutput,
}

impl RotationMode {
    pub fn label(&self) -> &'static str {
        match self {
            RotationMode::None => "No Rotation",
            RotationMode::Input => "Input",
            RotationMode::InputOutput => "Input + Output",
        }
    }
}

/// The rotation pair for one layer.
pub struct LayerRotation {
    pub mode: RotationMode,
    r_in: Option<RandomizedHadamard>,
    r_out: Option<RandomizedHadamard>,
}

impl LayerRotation {
    pub fn new(mode: RotationMode, d_in: usize, d_out: usize, seed: u64) -> Self {
        let r_in = match mode {
            RotationMode::None => None,
            _ => Some(RandomizedHadamard::new(d_in, seed ^ 0x1A)),
        };
        let r_out = match mode {
            RotationMode::InputOutput => Some(RandomizedHadamard::new(d_out, seed ^ 0x0B)),
        _ => None,
        };
        Self { mode, r_in, r_out }
    }

    /// Rotate the weight matrix in place: `W ← R_out · W · R_inᵀ`.
    /// Row-major W is (d_out × d_in): right-multiplying by R_inᵀ rotates
    /// every row; left-multiplying by R_out rotates every column.
    pub fn rotate_weights(&self, w: &mut Matrix) {
        if let Some(r) = &self.r_in {
            // rows of W get R_in applied (W·R_inᵀ ⇔ rowᵢ ← R_in·rowᵢ since
            // (W·R_inᵀ)[i,:] = R_in·W[i,:] for orthogonal symmetric-block R)
            for i in 0..w.rows {
                r.forward(w.row_mut(i));
            }
        }
        if let Some(r) = &self.r_out {
            // columns: transpose-process
            let mut col = vec![0f64; w.rows];
            for j in 0..w.cols {
                for i in 0..w.rows {
                    col[i] = w.at(i, j);
                }
                r.forward(&mut col);
                for i in 0..w.rows {
                    *w.at_mut(i, j) = col[i];
                }
            }
        }
    }

    /// Undo [`rotate_weights`] on a reconstruction.
    pub fn unrotate_weights(&self, w: &mut Matrix) {
        if let Some(r) = &self.r_out {
            let mut col = vec![0f64; w.rows];
            for j in 0..w.cols {
                for i in 0..w.rows {
                    col[i] = w.at(i, j);
                }
                r.inverse(&mut col);
                for i in 0..w.rows {
                    *w.at_mut(i, j) = col[i];
                }
            }
        }
        if let Some(r) = &self.r_in {
            for i in 0..w.rows {
                r.inverse(w.row_mut(i));
            }
        }
    }

    /// Rotate the input Hessian: `H ← R_in · H · R_inᵀ` (activations are
    /// rotated by R_in, so their second moment conjugates).
    pub fn rotate_hessian(&self, h: &mut Matrix) {
        if let Some(r) = &self.r_in {
            // rows then columns (R H Rᵀ)
            for i in 0..h.rows {
                r.forward(h.row_mut(i));
            }
            let mut col = vec![0f64; h.rows];
            for j in 0..h.cols {
                for i in 0..h.rows {
                    col[i] = h.at(i, j);
                }
                r.forward(&mut col);
                for i in 0..h.rows {
                    *h.at_mut(i, j) = col[i];
                }
            }
        }
    }

    /// Apply R_in to a single activation vector (the *online* Hadamard of
    /// §5.3 — used by the serving bench to price unfused rotations).
    pub fn rotate_activation(&self, x: &mut [f64]) {
        if let Some(r) = &self.r_in {
            r.forward(x);
        }
    }

    /// Apply R_outᵀ to a single output vector — the other half of the
    /// online (unfused) evaluation `y = R_outᵀ · W_rot · (R_in · x)`. The
    /// fused packed backend uses this to serve rotated code streams without
    /// ever materializing the un-rotated weight matrix.
    pub fn unrotate_output(&self, y: &mut [f64]) {
        if let Some(r) = &self.r_out {
            r.inverse(y);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn random_matrix(r: usize, c: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256pp::new(seed);
        let mut m = Matrix::zeros(r, c);
        for v in m.data.iter_mut() {
            *v = rng.next_gaussian();
        }
        m
    }

    #[test]
    fn rotate_unrotate_is_identity() {
        for mode in [RotationMode::None, RotationMode::Input, RotationMode::InputOutput] {
            let rot = LayerRotation::new(mode, 96, 64, 5);
            let orig = random_matrix(64, 96, 1);
            let mut w = orig.clone();
            rot.rotate_weights(&mut w);
            rot.unrotate_weights(&mut w);
            for (a, b) in w.data.iter().zip(&orig.data) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn function_preservation() {
        // y = W x must equal R_outᵀ · (RW) · (R_in x)
        let rot = LayerRotation::new(RotationMode::InputOutput, 32, 16, 9);
        let w0 = random_matrix(16, 32, 2);
        let mut wr = w0.clone();
        rot.rotate_weights(&mut wr);
        let mut rng = Xoshiro256pp::new(3);
        let x: Vec<f64> = (0..32).map(|_| rng.next_gaussian()).collect();
        let y_ref = w0.matvec(&x);
        let mut xr = x.clone();
        rot.rotate_activation(&mut xr);
        let mut y = wr.matvec(&xr);
        rot.unrotate_output(&mut y);
        for (a, b) in y.iter().zip(&y_ref) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn hessian_conjugation_matches_rotated_activations() {
        let rot = LayerRotation::new(RotationMode::Input, 16, 8, 11);
        let mut rng = Xoshiro256pp::new(4);
        use crate::pipeline::hessian::HessianAccumulator;
        let mut acc_plain = HessianAccumulator::new(16);
        let mut acc_rot = HessianAccumulator::new(16);
        for _ in 0..2000 {
            let x: Vec<f64> = (0..16).map(|_| rng.next_gaussian() * 2.0).collect();
            acc_plain.add(&x);
            let mut xr = x.clone();
            rot.rotate_activation(&mut xr);
            acc_rot.add(&xr);
        }
        let mut h = acc_plain.finalize();
        let h_rot_direct = acc_rot.finalize();
        rot.rotate_hessian(&mut h);
        for (a, b) in h.data.iter().zip(&h_rot_direct.data) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn rotation_gaussianizes_outlier_rows() {
        // spiky weight row → rotated row has much smaller kurtosis proxy
        let rot = LayerRotation::new(RotationMode::Input, 128, 4, 21);
        let mut w = Matrix::zeros(4, 128);
        *w.at_mut(0, 7) = 10.0; // single huge outlier
        *w.at_mut(0, 80) = -9.0;
        let max_before = w.row(0).iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        rot.rotate_weights(&mut w);
        let max_after = w.row(0).iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        assert!(max_after < max_before / 3.0, "{max_before} → {max_after}");
    }
}
