//! End-to-end PTQ driver (paper Alg. 1): calibrate → rotate → Hessian →
//! GPTQ-style vector quantization → optional scale fine-tuning → assemble
//! the quantized model.
//!
//! This is the L3 coordination piece for a *compression* paper: the unit of
//! work is one linear layer; layers are processed sequentially (activations
//! for layer ℓ come from the ORIGINAL model, the standard layer-local GPTQ
//! setup — §D.2 "local vs global"), while rows inside a layer fan out over
//! the thread pool.
//!
//! The driver's primary output is a [`PackedModel`]: the bit-packed lattice
//! codes plus the per-layer reconstruction metadata (σ, rotation seed,
//! fine-tuned scales) — the deployment artifact of the `.llvqm` format. The
//! dense reconstruction is kept alongside for evaluation; `PackedModel::
//! unpack` reproduces it bit-exactly, and the serving-side execution
//! backends (`model::backend`) consume the same artifact either lazily
//! (per-layer decode on first touch) or fused (matvec straight over the
//! code streams), replaying exactly the reconstruction algebra recorded
//! here.

use std::collections::HashMap;

use crate::model::corpus::Corpus;
use crate::model::packed::{PackedLayer, PackedModel};
use crate::model::transformer::{forward, ActivationCapture, LinearKind, Weights, LINEAR_KINDS};
use crate::pipeline::finetune;
use crate::pipeline::gptq::{self, GptqConfig};
use crate::pipeline::hessian::HessianAccumulator;
use crate::pipeline::rotation::{LayerRotation, RotationMode};
use crate::quant::VectorQuantizer;

/// Driver options.
#[derive(Clone, Debug)]
pub struct PtqOptions {
    pub rotation: RotationMode,
    /// Closed-form per-column scale fine-tuning (§5.4 / App. D.1).
    pub finetune_scales: bool,
    /// Calibration sequences (paper uses 6,100 on DCLM; scaled to testbed).
    pub calib_seqs: usize,
    pub gptq: GptqConfig,
    pub seed: u64,
}

impl Default for PtqOptions {
    fn default() -> Self {
        Self {
            rotation: RotationMode::InputOutput,
            finetune_scales: false,
            calib_seqs: 48,
            gptq: GptqConfig::default(),
            seed: 1000,
        }
    }
}

/// Per-layer quantization report.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub layer: usize,
    pub kind: LinearKind,
    pub bits: u64,
    pub params: usize,
    pub proxy_loss: f64,
}

/// Whole-model report.
#[derive(Clone, Debug, Default)]
pub struct PtqReport {
    pub layers: Vec<LayerReport>,
    pub total_bits: u64,
    pub total_params: usize,
    pub wall_secs: f64,
}

impl PtqReport {
    pub fn bits_per_weight(&self) -> f64 {
        self.total_bits as f64 / self.total_params.max(1) as f64
    }
}

/// Collect calibration activations for every linear layer.
pub fn calibrate(w: &Weights, opts: &PtqOptions) -> ActivationCapture {
    let mut corpus = Corpus::new(opts.seed);
    let seq_len = w.cfg.max_seq.min(64);
    let mut cap = ActivationCapture::enabled();
    for _ in 0..opts.calib_seqs {
        let (toks, _) = corpus.generate(seq_len);
        forward(w, &toks, &mut cap);
    }
    cap
}

/// Everything one PTQ run produces: the dense reconstruction (for eval),
/// the packed `.llvqm` artifact (for deployment), and the report.
pub struct PtqArtifacts {
    pub weights: Weights,
    pub report: PtqReport,
    pub packed: PackedModel,
}

/// Quantize every linear layer of the model. Embeddings, norms, and the
/// LM head stay in f32 (as in the paper, whose bpw covers linear weights).
///
/// Returns the dense reconstruction **and** the [`PackedModel`] built from
/// the very codes the GPTQ pass committed — `packed.unpack(..)` reproduces
/// `weights` bit-exactly (the σ scaling, fine-tuned column scales, and
/// inverse rotation are replayed in the same float-op order).
pub fn quantize_model_packed(
    w: &Weights,
    q: &dyn VectorQuantizer,
    opts: &PtqOptions,
) -> PtqArtifacts {
    let (out, report, packed_layers) = quantize_model_core(w, q, opts);
    let packed = PackedModel {
        cfg: w.cfg.clone(),
        quantizer: q.spec(),
        layers: packed_layers,
        tok_emb: out.tok_emb.clone(),
        pos_emb: out.pos_emb.clone(),
        norms1: out.blocks.iter().map(|b| b.norm1.clone()).collect(),
        norms2: out.blocks.iter().map(|b| b.norm2.clone()).collect(),
        norm_f: out.norm_f.clone(),
        lm_head: out.lm_head.clone(),
    };
    PtqArtifacts {
        weights: out,
        report,
        packed,
    }
}

/// Shared PTQ loop. Collecting [`PackedLayer`]s is free (the code streams
/// already exist inside each gptq result); the fp32 clones that assemble a
/// [`PackedModel`] are not, so dense-only callers ([`quantize_model`])
/// stop here.
fn quantize_model_core(
    w: &Weights,
    q: &dyn VectorQuantizer,
    opts: &PtqOptions,
) -> (Weights, PtqReport, Vec<PackedLayer>) {
    let t0 = std::time::Instant::now();
    let cap = calibrate(w, opts);
    let mut out = w.clone();
    let mut report = PtqReport::default();
    let mut packed_layers: Vec<PackedLayer> = Vec::with_capacity(w.cfg.n_layers * 6);

    for li in 0..w.cfg.n_layers {
        for kind in LINEAR_KINDS {
            let (rows, cols) = kind.shape(&w.cfg);
            let x = cap
                .store
                .get(&(li, kind))
                .unwrap_or_else(|| panic!("no calibration capture for layer {li} {kind:?}"));

            // Hessian from captured activations
            let mut acc = HessianAccumulator::new(cols);
            acc.add_batch(x, cols);
            let mut h = acc.finalize();

            // rotation (deterministic per layer/kind so eval — and the
            // packed load path — reproduces it from the recorded seed)
            let rot_seed = opts.seed ^ ((li as u64) << 8) ^ kind_tag(kind);
            let rot = LayerRotation::new(opts.rotation, cols, rows, rot_seed);
            let mut wmat = crate::math::linalg::Matrix::zeros(rows, cols);
            {
                let src = w.blocks[li].linear(kind);
                for (dst, &s) in wmat.data.iter_mut().zip(src.iter()) {
                    *dst = s as f64;
                }
            }
            rot.rotate_weights(&mut wmat);
            rot.rotate_hessian(&mut h);

            let wf: Vec<f32> = wmat.data.iter().map(|&v| v as f32).collect();
            let result = gptq::quantize_layer(&wf, rows, cols, &h, q, &opts.gptq);
            let mut w_hat = result.w_hat;

            let col_scales = if opts.finetune_scales {
                let beta = finetune::optimal_column_scales(&wf, &w_hat, rows, cols, &h);
                finetune::apply_column_scales(&mut w_hat, cols, &beta);
                Some(beta)
            } else {
                None
            };

            // un-rotate the reconstruction back to model coordinates
            let mut rec = crate::math::linalg::Matrix::zeros(rows, cols);
            for (dst, &s) in rec.data.iter_mut().zip(w_hat.iter()) {
                *dst = s as f64;
            }
            rot.unrotate_weights(&mut rec);
            let dst = out.blocks[li].linear_mut(kind);
            for (d, &s) in dst.iter_mut().zip(rec.data.iter()) {
                *d = s as f32;
            }

            packed_layers.push(PackedLayer {
                layer: li,
                kind,
                rows,
                cols,
                sigma: result.sigma,
                rot_mode: opts.rotation,
                rot_seed,
                col_scales,
                codes: result.packed,
            });
            report.layers.push(LayerReport {
                layer: li,
                kind,
                bits: result.total_bits,
                params: rows * cols,
                proxy_loss: result.proxy_loss,
            });
            report.total_bits += result.total_bits;
            report.total_params += rows * cols;
        }
    }
    report.wall_secs = t0.elapsed().as_secs_f64();
    (out, report, packed_layers)
}

/// Compatibility entry for callers that only need the dense
/// reconstruction (experiments, examples, tests) — skips the fp32 clones
/// of [`quantize_model_packed`]'s artifact assembly.
pub fn quantize_model(
    w: &Weights,
    q: &dyn VectorQuantizer,
    opts: &PtqOptions,
) -> (Weights, PtqReport) {
    let (out, report, _) = quantize_model_core(w, q, opts);
    (out, report)
}

fn kind_tag(kind: LinearKind) -> u64 {
    match kind {
        LinearKind::Wq => 0x11,
        LinearKind::Wk => 0x22,
        LinearKind::Wv => 0x33,
        LinearKind::Wo => 0x44,
        LinearKind::W1 => 0x55,
        LinearKind::W2 => 0x66,
    }
}

/// Hessians per layer/kind as reusable objects (exposed for experiments
/// that sweep quantizers without re-running calibration).
pub fn hessians_from_capture(
    w: &Weights,
    cap: &ActivationCapture,
) -> HashMap<(usize, LinearKind), crate::math::linalg::Matrix> {
    let mut out = HashMap::new();
    for li in 0..w.cfg.n_layers {
        for kind in LINEAR_KINDS {
            let (_, cols) = kind.shape(&w.cfg);
            if let Some(x) = cap.store.get(&(li, kind)) {
                let mut acc = HessianAccumulator::new(cols);
                acc.add_batch(x, cols);
                out.insert((li, kind), acc.finalize());
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::config_by_name;
    use crate::model::eval::evaluate;
    use crate::quant::scalar::UniformQuantizer;

    #[test]
    fn quantize_model_smoke_and_bit_accounting() {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 3);
        let q = UniformQuantizer::new_gaussian_optimal(4);
        let opts = PtqOptions {
            calib_seqs: 4,
            rotation: RotationMode::Input,
            ..Default::default()
        };
        let art = quantize_model_packed(&w, &q, &opts);
        let (wq, rep) = (art.weights, art.report);
        assert_eq!(rep.total_params, cfg.num_linear_params());
        assert!((rep.bits_per_weight() - 4.0).abs() < 1e-9);
        // the packed artifact covers every linear layer with exact bit
        // accounting (padding lanes included in the payload, not the rate)
        assert_eq!(art.packed.layers.len(), rep.layers.len());
        assert_eq!(art.packed.linear_params(), rep.total_params);
        assert!(art.packed.code_bits() >= rep.total_bits);
        // quantized model still runs
        let m = evaluate(&wq, 2, 2000, 1);
        assert!(m.perplexity.is_finite());
    }

    #[test]
    fn four_bit_barely_degrades_random_model() {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 5);
        let base = evaluate(&w, 6, 2000, 2);
        let q = UniformQuantizer::new_gaussian_optimal(6);
        let opts = PtqOptions {
            calib_seqs: 6,
            ..Default::default()
        };
        let (wq, _) = quantize_model(&w, &q, &opts);
        let quant = evaluate(&wq, 6, 2000, 2);
        // 6-bit quantization of any reasonable model is near-lossless
        assert!(
            (quant.perplexity - base.perplexity).abs() / base.perplexity < 0.05,
            "base {} vs quant {}",
            base.perplexity,
            quant.perplexity
        );
    }
}
