//! Layer-input Hessian estimation (paper §D.2, eq. 25).
//!
//! The local proxy objective is `Tr(ΔW · H_in · ΔWᵀ)` with
//! `H_in = E[x xᵀ]`, estimated as `X̃ᵀX̃/N` over the calibration set. The
//! accumulator is streaming (constant memory in the number of calibration
//! sequences) and symmetrized on finalize; GPTQ-style `damp·mean(diag)`
//! regularization is applied by the caller.

use crate::math::linalg::Matrix;

/// Streaming accumulator for `H = Σ xxᵀ / N`.
pub struct HessianAccumulator {
    dim: usize,
    h: Matrix,
    count: u64,
}

impl HessianAccumulator {
    pub fn new(dim: usize) -> Self {
        Self {
            dim,
            h: Matrix::zeros(dim, dim),
            count: 0,
        }
    }

    /// Accumulate one activation vector.
    pub fn add(&mut self, x: &[f64]) {
        assert_eq!(x.len(), self.dim);
        for i in 0..self.dim {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = &mut self.h.data[i * self.dim..(i + 1) * self.dim];
            for j in 0..self.dim {
                row[j] += xi * x[j];
            }
        }
        self.count += 1;
    }

    /// Accumulate a batch of row-major activations (rows = tokens).
    pub fn add_batch(&mut self, xs: &[f32], cols: usize) {
        assert_eq!(cols, self.dim);
        let mut buf = vec![0f64; cols];
        for row in xs.chunks_exact(cols) {
            for (b, &v) in buf.iter_mut().zip(row) {
                *b = v as f64;
            }
            self.add(&buf);
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Finalize: H/N, symmetrized.
    pub fn finalize(mut self) -> Matrix {
        let n = self.count.max(1) as f64;
        for v in self.h.data.iter_mut() {
            *v /= n;
        }
        // enforce exact symmetry (floating accumulation drift)
        for i in 0..self.dim {
            for j in 0..i {
                let s = 0.5 * (self.h.at(i, j) + self.h.at(j, i));
                *self.h.at_mut(i, j) = s;
                *self.h.at_mut(j, i) = s;
            }
        }
        self.h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::cholesky;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn recovers_identity_for_white_noise() {
        let mut acc = HessianAccumulator::new(16);
        let mut rng = Xoshiro256pp::new(1);
        let mut x = vec![0f64; 16];
        for _ in 0..20_000 {
            rng.fill_gaussian_f64(&mut x);
            acc.add(&x);
        }
        let h = acc.finalize();
        for i in 0..16 {
            for j in 0..16 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (h.at(i, j) - want).abs() < 0.05,
                    "H[{i}][{j}] = {}",
                    h.at(i, j)
                );
            }
        }
    }

    #[test]
    fn captures_correlation_structure() {
        // x = (g, g, independent...) → H[0][1] ≈ 1
        let mut acc = HessianAccumulator::new(4);
        let mut rng = Xoshiro256pp::new(2);
        for _ in 0..20_000 {
            let g = rng.next_gaussian();
            acc.add(&[g, g, rng.next_gaussian(), 0.5 * rng.next_gaussian()]);
        }
        let h = acc.finalize();
        assert!((h.at(0, 1) - 1.0).abs() < 0.05);
        assert!((h.at(3, 3) - 0.25).abs() < 0.02);
    }

    #[test]
    fn damped_hessian_is_spd() {
        let mut acc = HessianAccumulator::new(8);
        let mut rng = Xoshiro256pp::new(3);
        // rank-deficient inputs (only 3 distinct directions)
        for _ in 0..100 {
            let a = rng.next_gaussian();
            acc.add(&[a, 2.0 * a, 0.0, 0.0, a, 0.0, 0.0, -a]);
        }
        let mut h = acc.finalize();
        assert!(cholesky(&h).is_err(), "rank-1 H should not be SPD");
        h.damp_diagonal(0.01);
        assert!(cholesky(&h).is_ok(), "damped H must be SPD");
    }

    #[test]
    fn batch_equals_loop() {
        let mut a1 = HessianAccumulator::new(3);
        let mut a2 = HessianAccumulator::new(3);
        let data: Vec<f32> = (0..30).map(|i| (i as f32).sin()).collect();
        a1.add_batch(&data, 3);
        for row in data.chunks_exact(3) {
            a2.add(&[row[0] as f64, row[1] as f64, row[2] as f64]);
        }
        let (h1, h2) = (a1.finalize(), a2.finalize());
        for (x, y) in h1.data.iter().zip(&h2.data) {
            assert!((x - y).abs() < 1e-9);
        }
    }
}
