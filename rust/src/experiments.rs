//! Regenerators for every table and figure in the paper's evaluation.
//!
//! Each `table*`/`fig*` function prints the paper's reported numbers next
//! to the values measured on this testbed and returns the measured rows
//! for programmatic use (benches, EXPERIMENTS.md generation, tests).
//!
//! | id     | paper artefact                                   |
//! |--------|--------------------------------------------------|
//! | table1 | Λ₂₄ shell structure                              |
//! | table2 | class compositions of shells 2–4                 |
//! | fig1   | SQNR vs bitrate on a Gaussian source             |
//! | table4 | retention @ 2 bits/dim                           |
//! | table3 | PTQ across the model zoo (Wiki/MMLU/CSR proxies) |
//! | table5 | literature comparison (llama2-tiny)              |
//! | table6 | Hadamard-rotation ablation                       |
//! | fig6   | angular distance: single shell vs union vs E8P12 |
//! | table7 | spherical shaping vs shape–gain gain-bit sweep   |

use std::sync::Arc;

use crate::leech::decode::LeechDecoder;
use crate::leech::index::LeechIndexer;
use crate::leech::{coset, leaders, theta};
use crate::math::stats;
use crate::model::config::{config_by_name, model_zoo, ModelConfig};
use crate::model::eval::{evaluate, EvalMetrics};
use crate::model::io as model_io;
use crate::model::transformer::Weights;
use crate::pipeline::driver::{quantize_model, PtqOptions};
use crate::pipeline::gptq::GptqConfig;
use crate::pipeline::rotation::RotationMode;
use crate::quant::e8::{E8Codebook, E8Cut};
use crate::quant::llvq::{LlvqShapeGain, LlvqSpherical};
use crate::quant::scalar::{LloydMaxQuantizer, UniformQuantizer};
use crate::quant::VectorQuantizer;
use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool;
use crate::DIM;

/// Effort knob shared by the experiment CLI: scales sample counts.
#[derive(Clone, Copy, Debug)]
pub struct Effort {
    /// Gaussian blocks per Leech-quantizer measurement.
    pub leech_blocks: usize,
    /// Gaussian blocks per cheap-quantizer measurement.
    pub cheap_blocks: usize,
    /// Eval sequences for model experiments.
    pub eval_seqs: usize,
    pub threads: usize,
}

impl Default for Effort {
    fn default() -> Self {
        Self {
            leech_blocks: 2_000,
            cheap_blocks: 120_000,
            eval_seqs: 48,
            threads: threadpool::default_threads(),
        }
    }
}

impl Effort {
    pub fn quick() -> Self {
        Self {
            leech_blocks: 300,
            cheap_blocks: 20_000,
            eval_seqs: 8,
            threads: threadpool::default_threads(),
        }
    }
}

/// Parallel Gaussian rate–distortion (same estimator as
/// [`crate::quant::gaussian_rd`], fanned over the thread pool).
pub fn gaussian_rd_parallel(
    q: &dyn VectorQuantizer,
    num_blocks: usize,
    seed: u64,
    threads: usize,
) -> (f64, f64) {
    let nchunks = threads.max(1) * 4;
    let per = num_blocks.div_ceil(nchunks);
    let results = threadpool::parallel_map(nchunks, threads, |c| {
        let mut rng = Xoshiro256pp::new(seed ^ ((c as u64 + 1) * 0x9E37));
        let d = q.dim();
        let mut x = vec![0f32; d];
        let mut y = vec![0f32; d];
        let mut se = 0f64;
        let mut bits = 0u64;
        for _ in 0..per {
            rng.fill_gaussian_f32(&mut x);
            let code = q.quantize(&x);
            bits += code.bits as u64;
            q.dequantize(&code, &mut y);
            for i in 0..d {
                let e = x[i] as f64 - y[i] as f64;
                se += e * e;
            }
        }
        (se, bits)
    });
    let total_blocks = per * nchunks;
    let (se, bits) = results
        .into_iter()
        .fold((0f64, 0u64), |(a, b), (x, y)| (a + x, b + y));
    let n = (total_blocks * q.dim()) as f64;
    (se / n, bits as f64 / n)
}

fn hline(w: usize) {
    println!("{}", "-".repeat(w));
}

// ---------------------------------------------------------------------------
// Table 1 — shell structure
// ---------------------------------------------------------------------------

pub struct Table1Row {
    pub m: usize,
    pub n: u128,
    pub cumulative: u128,
    pub bits_per_dim: f64,
}

pub fn table1(verify_enumeration: bool) -> Vec<Table1Row> {
    println!("\n== Table 1: shell structure of the Leech lattice ==");
    println!(
        "{:>3} {:>10} {:>24} {:>26} {:>10}",
        "m", "radius²", "n(m)", "N(m)", "bits/dim"
    );
    hline(80);
    let maxm = 19;
    let n = theta::shell_sizes(maxm);
    let cum = theta::cumulative_sizes(maxm);
    let golay = crate::golay::GolayCode::new();
    let mut rows = Vec::new();
    for m in 2..=maxm {
        if verify_enumeration {
            let s = leaders::enumerate_shell(&golay, m);
            assert_eq!(s.size, n[m], "enumeration mismatch at shell {m}");
        }
        let bpd = theta::bits_per_dim(cum[m]);
        println!("{:>3} {:>10} {:>24} {:>26} {:>10.3}", m, 2 * m, n[m], cum[m], bpd);
        rows.push(Table1Row {
            m,
            n: n[m],
            cumulative: cum[m],
            bits_per_dim: bpd,
        });
    }
    println!(
        "[paper check] N(13) = 280,974,212,784,720 → {} ; bits/dim @13 = 2.0 → {:.3}",
        cum[13],
        theta::bits_per_dim(cum[13])
    );
    println!("[erratum] paper's n(13) misses a digit; theta & enumeration agree on {}", n[13]);
    rows
}

// ---------------------------------------------------------------------------
// Table 2 — class compositions
// ---------------------------------------------------------------------------

pub fn table2() -> Vec<String> {
    println!("\n== Table 2: coordinate composition of classes, shells 2–4 ==");
    let golay = crate::golay::GolayCode::new();
    let mut all = Vec::new();
    for m in 2..=4 {
        let s = leaders::enumerate_shell(&golay, m);
        for row in s.composition_rows() {
            println!("{row}");
            all.push(row);
        }
    }
    all
}

// ---------------------------------------------------------------------------
// Fig. 1 — SQNR vs rate, and Table 4 — retention @ 2 bits/dim
// ---------------------------------------------------------------------------

pub struct RdPoint {
    pub method: String,
    pub bits_per_dim: f64,
    pub mse: f64,
    pub sqnr_bits: f64,
    pub retention_pct: f64,
}

fn rd_point(q: &dyn VectorQuantizer, blocks: usize, threads: usize) -> RdPoint {
    let (mse, bits) = gaussian_rd_parallel(q, blocks, 0xF16, threads);
    let s = stats::sqnr_bits(mse);
    RdPoint {
        method: q.name(),
        bits_per_dim: bits,
        mse,
        sqnr_bits: s,
        retention_pct: stats::retention_pct(s, bits),
    }
}

pub fn fig1(e: &Effort) -> Vec<RdPoint> {
    println!("\n== Figure 1: SQNR (bits) vs bitrate on N(0,1) source ==");
    println!(
        "{:<38} {:>9} {:>9} {:>9} {:>8}",
        "method", "bits/dim", "MSE", "SQNR", "Ret %"
    );
    hline(80);
    let mut pts = Vec::new();
    let mut emit = |p: RdPoint| {
        println!(
            "{:<38} {:>9.3} {:>9.4} {:>9.3} {:>8.1}",
            p.method, p.bits_per_dim, p.mse, p.sqnr_bits, p.retention_pct
        );
        pts.push(p);
    };

    for bits in 1..=3u32 {
        emit(rd_point(
            &UniformQuantizer::new_gaussian_optimal(bits),
            e.cheap_blocks,
            e.threads,
        ));
    }
    for bits in 1..=3u32 {
        emit(rd_point(
            &LloydMaxQuantizer::train_gaussian(bits, 400_000, 5),
            e.cheap_blocks,
            e.threads,
        ));
    }
    emit(rd_point(&E8Codebook::new(E8Cut::Cube), e.cheap_blocks / 4, e.threads));
    emit(rd_point(&E8Codebook::new(E8Cut::Ball), e.cheap_blocks / 4, e.threads));
    // LLVQ spherical across rates (shared indexer per M)
    for max_m in [3usize, 5, 8, 13] {
        let ix = Arc::new(LeechIndexer::new(max_m));
        emit(rd_point(&LlvqSpherical::new(ix), e.leech_blocks, e.threads));
    }
    // LLVQ shape–gain at the paper's headline setting and one lower rate
    for (max_m, gain_bits) in [(5usize, 1u32), (12, 1)] {
        let ix = Arc::new(LeechIndexer::new(max_m));
        emit(rd_point(
            &LlvqShapeGain::new(ix, gain_bits),
            e.leech_blocks,
            e.threads,
        ));
    }
    println!("[shannon] SQNR*(R) = R ; retention = 100%");
    pts
}

pub fn table4(e: &Effort) -> Vec<RdPoint> {
    println!("\n== Table 4: information retention at 2 bits/dim (Gaussian) ==");
    println!(
        "{:<38} {:>4} {:>9} {:>9} {:>8}   {}",
        "method", "dim", "MSE", "SQNR", "Ret %", "paper (MSE / Ret%)"
    );
    hline(96);
    let paper: &[(&str, f64, f64)] = &[
        ("uniform", 0.15, 69.0),
        ("lloyd-max", 0.12, 77.0),
        ("e8-cube", 0.103, 82.0),
        ("e8p-ball", 0.092, 86.1),
        ("llvq-spherical", 0.084, 89.4),
        ("llvq-shape-gain", 0.078, 92.1),
    ];
    let mut out = Vec::new();
    let mut emit = |key: &str, dim: usize, p: RdPoint| {
        let (pm, pr) = paper
            .iter()
            .find(|(k, _, _)| key == *k)
            .map(|&(_, m, r)| (m, r))
            .unwrap_or((f64::NAN, f64::NAN));
        println!(
            "{:<38} {:>4} {:>9.4} {:>9.3} {:>8.1}   {:.3} / {:.1}",
            p.method, dim, p.mse, p.sqnr_bits, p.retention_pct, pm, pr
        );
        out.push(p);
    };
    emit(
        "uniform",
        1,
        rd_point(&UniformQuantizer::new_gaussian_optimal(2), e.cheap_blocks, e.threads),
    );
    emit(
        "lloyd-max",
        1,
        rd_point(
            &LloydMaxQuantizer::train_gaussian(2, 400_000, 5),
            e.cheap_blocks,
            e.threads,
        ),
    );
    emit("e8-cube", 8, rd_point(&E8Codebook::new(E8Cut::Cube), e.cheap_blocks / 4, e.threads));
    emit("e8p-ball", 8, rd_point(&E8Codebook::new(E8Cut::Ball), e.cheap_blocks / 4, e.threads));
    {
        let ix = Arc::new(LeechIndexer::new(13));
        emit(
            "llvq-spherical",
            24,
            rd_point(&LlvqSpherical::new(ix), e.leech_blocks, e.threads),
        );
    }
    {
        let ix = Arc::new(LeechIndexer::new(12));
        emit(
            "llvq-shape-gain",
            24,
            rd_point(&LlvqShapeGain::new(ix, 1), e.leech_blocks, e.threads),
        );
    }
    println!("{:<38} {:>4} {:>9.4} {:>9.3} {:>8.1}   (Shannon)", "theoretical limit", 0, 0.0625, 2.0, 100.0);
    out
}

// ---------------------------------------------------------------------------
// Table 7 — spherical shaping vs shape–gain gain-bit sweep @ 2 bits/dim
// ---------------------------------------------------------------------------

pub fn table7(e: &Effort) -> Vec<RdPoint> {
    println!("\n== Table 7: spherical vs shape–gain bit allocation @ 2 bits/dim ==");
    println!(
        "{:<44} {:>9} {:>9} {:>9} {:>8}   {}",
        "code", "bits/dim", "MSE", "SQNR", "Ret %", "paper MSE"
    );
    hline(104);
    let mut out = Vec::new();
    let paper = [0.084, 0.085, 0.078, 0.080, 0.085];
    let mut emit = |p: RdPoint, paper_mse: f64| {
        println!(
            "{:<44} {:>9.3} {:>9.4} {:>9.3} {:>8.1}   {:.3}",
            p.method, p.bits_per_dim, p.mse, p.sqnr_bits, p.retention_pct, paper_mse
        );
        out.push(p);
    };
    {
        let ix = Arc::new(LeechIndexer::new(13));
        emit(rd_point(&LlvqSpherical::new(ix), e.leech_blocks, e.threads), paper[0]);
    }
    for (i, (max_m, gain_bits)) in [(13usize, 0u32), (12, 1), (11, 2), (10, 4)]
        .into_iter()
        .enumerate()
    {
        let ix = Arc::new(LeechIndexer::new(max_m));
        emit(
            rd_point(&LlvqShapeGain::new(ix, gain_bits), e.leech_blocks, e.threads),
            paper[i + 1],
        );
    }
    out
}

// ---------------------------------------------------------------------------
// Fig. 6 — angular separation: single shell vs union vs E8P12
// ---------------------------------------------------------------------------

pub struct Fig6Row {
    pub code: String,
    pub bits_per_dim: f64,
    pub summary: stats::Summary,
}

pub fn fig6(e: &Effort) -> Vec<Fig6Row> {
    println!("\n== Figure 6 (App. E): angular distance to nearest code point ==");
    println!(
        "{:<26} {:>9} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "code", "bits/dim", "p5", "p25", "p50", "p75", "p95"
    );
    hline(88);
    let golay = crate::golay::GolayCode::new();
    let dec = LeechDecoder::new(&golay);
    let nsamples = e.leech_blocks.max(400);
    let mut rows = Vec::new();

    let mut measure = |label: String, bits: f64, min_m: usize, max_m: usize| {
        let angles: Vec<f64> = threadpool::parallel_map(nsamples, e.threads, |i| {
            let mut rng = Xoshiro256pp::new(0xF6 ^ (i as u64 * 7919));
            let mut u = [0f64; DIM];
            rng.fill_gaussian_f64(&mut u);
            let d = dec.decode_angular(&u, min_m, max_m);
            let m = coset::shell_of(&d.point).unwrap();
            let un: f64 = u.iter().map(|v| v * v).sum::<f64>().sqrt();
            let pn = (16.0 * m as f64).sqrt();
            let cos = u
                .iter()
                .zip(d.point.iter())
                .map(|(&a, &b)| a * b as f64)
                .sum::<f64>()
                / (un * pn);
            cos.clamp(-1.0, 1.0).acos() / std::f64::consts::PI
        });
        let mut a = angles;
        let s = stats::summarize(&mut a);
        println!(
            "{:<26} {:>9.3} {:>8.4} {:>8.4} {:>8.4} {:>8.4} {:>8.4}",
            label, bits, s.p5, s.p25, s.p50, s.p75, s.p95
        );
        rows.push(Fig6Row {
            code: label,
            bits_per_dim: bits,
            summary: s,
        });
    };

    let n = theta::shell_sizes(8);
    let cum = theta::cumulative_sizes(8);
    for m in 2..=6usize {
        let bits_single = (n[m] as f64).log2() / 24.0;
        measure(format!("leech-shell-{m}"), bits_single, m, m);
        let bits_union = (cum[m] as f64).log2() / 24.0;
        measure(format!("leech-union-2..{m}"), bits_union, 2, m);
    }

    // E8P12 reference: 3 stacked, normalized 8-dim codes → on 24-dim
    // directions the achievable cosine factorizes; measure empirically.
    {
        let book = E8Codebook::new(E8Cut::Ball);
        let angles: Vec<f64> = threadpool::parallel_map(nsamples, e.threads, |i| {
            let mut rng = Xoshiro256pp::new(0xE8F6 ^ (i as u64 * 104729));
            let mut u = [0f64; DIM];
            rng.fill_gaussian_f64(&mut u);
            let un: f64 = u.iter().map(|v| v * v).sum::<f64>().sqrt();
            // quantize each 8-dim third with the (normalized) E8P codebook:
            // the best spherical match per sub-block is the quantized
            // sub-direction scaled to the sub-block's norm
            let mut vhat = [0f64; DIM];
            for b in 0..3 {
                let sub: [f32; 8] = std::array::from_fn(|k| u[b * 8 + k] as f32);
                let code = book.quantize(&sub);
                let mut rec = [0f32; 8];
                book.dequantize(&code, &mut rec);
                let rn: f64 = rec.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
                let sn: f64 = sub.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
                for k in 0..8 {
                    vhat[b * 8 + k] = if rn > 1e-9 { rec[k] as f64 / rn * sn } else { 0.0 };
                }
            }
            let vn: f64 = vhat.iter().map(|v| v * v).sum::<f64>().sqrt();
            let cos = u.iter().zip(&vhat).map(|(&a, &b)| a * b).sum::<f64>() / (un * vn);
            cos.clamp(-1.0, 1.0).acos() / std::f64::consts::PI
        });
        let mut a = angles;
        let s = stats::summarize(&mut a);
        println!(
            "{:<26} {:>9.3} {:>8.4} {:>8.4} {:>8.4} {:>8.4} {:>8.4}",
            "e8p12-stacked×3", 2.0, s.p5, s.p25, s.p50, s.p75, s.p95
        );
        rows.push(Fig6Row {
            code: "e8p12-stacked×3".into(),
            bits_per_dim: 2.0,
            summary: s,
        });
    }
    println!("[expected shape] union ≤ single shell at matched bits; E8P12 above both");
    rows
}

// ---------------------------------------------------------------------------
// Tables 3 / 5 / 6 — model PTQ experiments
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct ModelRow {
    pub model: String,
    pub method: String,
    pub finetuned: bool,
    pub bpw: f64,
    pub metrics: EvalMetrics,
}

/// Load a trained model from artifacts, or synthesize a random one when
/// `allow_random` (tests / no-artifacts runs; ordering conclusions still
/// hold, absolute PPLs become meaningless).
pub fn load_model(cfg: &ModelConfig, allow_random: bool) -> Result<Weights, String> {
    let path = crate::runtime::artifact(&format!("{}.llvqw", cfg.name));
    match model_io::load(&path) {
        Ok(w) => {
            if w.cfg != *cfg {
                return Err(format!("artifact config mismatch for {}", cfg.name));
            }
            Ok(w)
        }
        Err(e) if allow_random => {
            eprintln!(
                "[warn] {e}; using RANDOM weights for {} (run `make artifacts`)",
                cfg.name
            );
            Ok(Weights::random(cfg, 0xBAD0 ^ cfg.d_model as u64))
        }
        Err(e) => Err(format!(
            "{e}. Run `make artifacts` to train the tiny model zoo first."
        )),
    }
}

/// The method lineup used by Tables 3/5/6 at 2 bits/weight.
pub enum Method {
    /// GPTQ-style 2-bit scalar with rotations = the paper's "GPTQ+Quarot".
    ScalarGptq,
    E8p,
    LlvqSpherical,
    LlvqShapeGain,
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::ScalarGptq => "GPTQ+Rotation (scalar 2b)",
            Method::E8p => "Quip#/E8P-style (E8 ball 2b)",
            Method::LlvqSpherical => "LLVQ spherical (ours)",
            Method::LlvqShapeGain => "LLVQ shape-gain (ours)",
        }
    }

    pub fn build(&self) -> Box<dyn VectorQuantizer> {
        match self {
            Method::ScalarGptq => Box::new(UniformQuantizer::new_gaussian_optimal(2)),
            Method::E8p => Box::new(E8Codebook::new(E8Cut::Ball)),
            Method::LlvqSpherical => {
                Box::new(LlvqSpherical::new(Arc::new(LeechIndexer::new(13))))
            }
            Method::LlvqShapeGain => {
                Box::new(LlvqShapeGain::new(Arc::new(LeechIndexer::new(12)), 1))
            }
        }
    }
}

fn eval_row(
    model: &str,
    method: &str,
    finetuned: bool,
    bpw: f64,
    w: &Weights,
    e: &Effort,
) -> ModelRow {
    let m = evaluate(w, e.eval_seqs, 2000, e.threads);
    println!(
        "{:<16} {:<30} ft={:<5} bpw={:<5.2} ppl={:>8.3} mmlu*={:>5.1} csr*={:>5.1}",
        model, method, finetuned, bpw, m.perplexity, m.cloze_pct, m.accuracy_pct
    );
    ModelRow {
        model: model.into(),
        method: method.into(),
        finetuned,
        bpw,
        metrics: m,
    }
}

pub fn table3(e: &Effort, allow_random: bool) -> Result<Vec<ModelRow>, String> {
    println!("\n== Table 3: 2-bit PTQ across the model zoo (same pipeline) ==");
    println!("(substitution: tiny trained LMs; see DESIGN.md — orderings are the claim)");
    let mut rows = Vec::new();
    for cfg in model_zoo() {
        let w = load_model(&cfg, allow_random)?;
        rows.push(eval_row(&cfg.name, "baseline fp32", false, 32.0, &w, e));
        for ft in [false, true] {
            for method in [
                Method::ScalarGptq,
                Method::E8p,
                Method::LlvqSpherical,
                Method::LlvqShapeGain,
            ] {
                let q = method.build();
                let opts = PtqOptions {
                    rotation: RotationMode::InputOutput,
                    finetune_scales: ft,
                    calib_seqs: e.eval_seqs.max(16),
                    gptq: GptqConfig {
                        threads: e.threads,
                        ..Default::default()
                    },
                    seed: 1000,
                };
                let (wq, rep) = quantize_model(&w, q.as_ref(), &opts);
                rows.push(eval_row(
                    &cfg.name,
                    method.label(),
                    ft,
                    rep.bits_per_weight(),
                    &wq,
                    e,
                ));
            }
        }
    }
    Ok(rows)
}

pub fn table5(e: &Effort, allow_random: bool) -> Result<Vec<ModelRow>, String> {
    println!("\n== Table 5: literature comparison on llama2-tiny ==");
    println!("paper-reported Llama-2 7B rows (NOT rerun here — different substrate):");
    for (name, ft, bpw, wiki) in [
        ("Quip# (paper T5)", false, 2.0, 8.22),
        ("AQLM (paper T5)", true, 2.07, 6.93),
        ("Quip# (paper T5)", true, 2.0, 6.19),
        ("QTIP (paper T5)", true, 2.0, 5.86),
        ("PV-tuning (paper T5)", true, 2.0, 5.84),
        ("LLVQ spherical (paper)", true, 2.0, 5.60),
        ("LLVQ shape-gain (paper)", true, 2.0, 5.48),
    ] {
        println!("  [paper] {name:<28} ft={ft:<5} bpw={bpw:<5.2} wiki={wiki}");
    }
    println!("our measured rows (tiny substrate, same pipeline):");
    let cfg = config_by_name("llama2-tiny").unwrap();
    let w = load_model(&cfg, allow_random)?;
    let mut rows = Vec::new();
    rows.push(eval_row(&cfg.name, "baseline fp32", false, 32.0, &w, e));
    for ft in [false, true] {
        for method in [Method::E8p, Method::LlvqSpherical, Method::LlvqShapeGain] {
            let q = method.build();
            let opts = PtqOptions {
                finetune_scales: ft,
                calib_seqs: e.eval_seqs.max(16),
                gptq: GptqConfig {
                    threads: e.threads,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (wq, rep) = quantize_model(&w, q.as_ref(), &opts);
            rows.push(eval_row(&cfg.name, method.label(), ft, rep.bits_per_weight(), &wq, e));
        }
    }
    Ok(rows)
}

pub fn table6(e: &Effort, allow_random: bool) -> Result<Vec<ModelRow>, String> {
    println!("\n== Table 6: Hadamard rotation ablation (llama2-tiny, no finetune) ==");
    let cfg = config_by_name("llama2-tiny").unwrap();
    let w = load_model(&cfg, allow_random)?;
    let mut rows = Vec::new();
    rows.push(eval_row(&cfg.name, "baseline fp32", false, 32.0, &w, e));
    for method in [
        Method::ScalarGptq,
        Method::E8p,
        Method::LlvqSpherical,
        Method::LlvqShapeGain,
    ] {
        for mode in [RotationMode::None, RotationMode::Input, RotationMode::InputOutput] {
            let q = method.build();
            let opts = PtqOptions {
                rotation: mode,
                finetune_scales: false,
                calib_seqs: e.eval_seqs.max(16),
                gptq: GptqConfig {
                    threads: e.threads,
                    ..Default::default()
                },
                seed: 1000,
            };
            let (wq, rep) = quantize_model(&w, q.as_ref(), &opts);
            let label = format!("{} [{}]", method.label(), mode.label());
            rows.push(eval_row(&cfg.name, &label, false, rep.bits_per_weight(), &wq, e));
        }
    }
    Ok(rows)
}
