//! Paged KV-cache arena with lattice-quantized cold pages.
//!
//! Session memory, not weight memory, caps concurrency: a dense
//! [`KvCache`](crate::model::transformer::KvCache) is a `layers × 2 ×
//! max_seq × d_model` f32 slab allocated at worst-case capacity. This
//! module pages that storage in fixed-size token blocks (the vLLM move):
//! a [`PageArena`] owns a bounded free-list of page buffers shared by
//! every session, and a [`PagedKvCache`] implements the same
//! [`KvStore`] surface as the dense cache over a list of pages, so
//! `prefill` / `forward_step_batch` run over either — sessions are
//! admitted against *actual* token pages, not worst-case `max_seq`.
//!
//! Stage two is compression: pages that fall entirely behind the last
//! `hot_window` tokens are *cold* — their K/V rows are RMS-normalized
//! per row and encoded through an existing [`VectorQuantizer`] codec
//! ([`KvQuantKind`]: `none | e8 | llvq`, built via `quantizer_from_spec`),
//! then the f32 buffer returns to the arena. Attention reads decode cold
//! pages row-by-row (`decode_blocks_into`) into reusable gather scratch.
//! Hot pages stay f32, and the gather path moves those floats by copy
//! only, so a paged cache with `KvQuantKind::None` is **bit-identical**
//! to the dense cache (pinned by proptest in `rust/tests/kvpage.rs`).
//!
//! One page buffer covers *all* layers for `page_tokens` positions:
//! layer `li`'s K rows live at `li·2·pt·d`, its V rows at
//! `li·2·pt·d + pt·d` (`pt` = page tokens, `d` = d_model). Appends only
//! ever land in trailing pages (which cannot be cold: a page cools only
//! once it is full *and* behind the hot window), and cold pages are
//! always full, so decode never sees a partial page.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

use crate::model::config::ModelConfig;
use crate::model::transformer::KvStore;
use crate::quant::traits::{quantizer_from_spec, Code, VectorQuantizer};
use crate::util::bits::{BitReader, BitWriter};
use crate::util::json::Json;

/// Which codec compresses cold pages (`--kv-quant`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvQuantKind {
    /// Cold pages stay f32 in the arena (no compression, bit-identical
    /// to the dense cache).
    None,
    /// E8 lattice codebook (ball cut), 8-dim blocks.
    E8,
    /// Spherical Leech quantizer, 24-dim blocks.
    Llvq,
}

impl KvQuantKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "none" => Ok(Self::None),
            "e8" => Ok(Self::E8),
            "llvq" => Ok(Self::Llvq),
            other => Err(format!("unknown --kv-quant '{other}' (none|e8|llvq)")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::E8 => "e8",
            Self::Llvq => "llvq",
        }
    }

    /// Quantizer spec for this kind, in the exact shape
    /// `quantizer_from_spec` consumes. Rows are RMS-normalized to unit
    /// scale before encoding, so the scales here are the codecs' own
    /// unit-variance operating points (llvq: β = √24/√(2M) at M = 6).
    fn spec(&self) -> Option<Json> {
        match self {
            Self::None => None,
            Self::E8 => Some(Json::obj(vec![
                ("kind", Json::Str("e8".into())),
                ("cut", Json::Str("ball".into())),
                ("scale", Json::Num(0.9)),
            ])),
            Self::Llvq => Some(Json::obj(vec![
                ("kind", Json::Str("llvq-spherical".into())),
                ("max_m", Json::Int(6)),
                ("scale", Json::Num(std::f64::consts::SQRT_2)),
            ])),
        }
    }
}

/// Row codec for cold pages: a [`VectorQuantizer`] plus the derived
/// per-row stream geometry. Each `d_model` row is its own byte-aligned
/// MSB-first bitstream of `⌈d_model/dim⌉` codes, prefixed (out of band,
/// in [`ColdPage::sigma`]) by its RMS scale — activations vary wildly in
/// magnitude per position, so the unit-scale codebooks see normalized
/// rows.
pub struct KvCodec {
    q: Box<dyn VectorQuantizer>,
    widths: Vec<u32>,
    row_bytes: usize,
    d_model: usize,
}

impl KvCodec {
    /// Build the codec for `kind` (None ⇒ `Ok(None)`: pages stay f32).
    pub fn build(kind: KvQuantKind, d_model: usize) -> Result<Option<Arc<KvCodec>>, String> {
        let Some(spec) = kind.spec() else {
            return Ok(None);
        };
        let q = quantizer_from_spec(&spec)?;
        let widths = q.code_widths();
        let code_bits: u64 = widths.iter().map(|&w| w as u64).sum();
        let blocks = d_model.div_ceil(q.dim()) as u64;
        let row_bytes = ((blocks * code_bits).div_ceil(8)) as usize;
        Ok(Some(Arc::new(KvCodec {
            q,
            widths,
            row_bytes,
            d_model,
        })))
    }

    /// Encoded bytes per `d_model` row (excluding the f32 sigma).
    pub fn row_bytes(&self) -> usize {
        self.row_bytes
    }

    pub fn block_dim(&self) -> usize {
        self.q.dim()
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    /// Encode one row: RMS-normalize into `norm_scratch`, quantize, and
    /// append exactly [`KvCodec::row_bytes`] to `bytes`. Returns the
    /// row's sigma (1.0 for all-zero / non-finite rows so decode is
    /// always well-defined).
    fn encode_row(&self, row: &[f32], norm_scratch: &mut Vec<f32>, bytes: &mut Vec<u8>) -> f32 {
        debug_assert_eq!(row.len(), self.d_model);
        let ms: f64 =
            row.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / row.len() as f64;
        let sigma = ms.sqrt() as f32;
        let sigma = if sigma.is_finite() && sigma > 0.0 {
            sigma
        } else {
            1.0
        };
        norm_scratch.clear();
        norm_scratch.extend(row.iter().map(|&x| x / sigma));
        let mut w = BitWriter::new();
        crate::quant::product::encode_row_into(self.q.as_ref(), norm_scratch, &mut w);
        let enc = w.finish();
        debug_assert_eq!(enc.len(), self.row_bytes);
        bytes.extend_from_slice(&enc);
        sigma
    }

    /// Inverse of [`KvCodec::encode_row`]: decode one row stream and
    /// denormalize by `sigma`. `block_scratch.len() == self.block_dim()`.
    fn decode_row(
        &self,
        bytes: &[u8],
        sigma: f32,
        code: &mut Code,
        block_scratch: &mut [f32],
        out: &mut [f32],
    ) {
        let mut r = BitReader::new(bytes);
        self.q
            .decode_blocks_into(&self.widths, &mut r, code, block_scratch, out);
        for v in out.iter_mut() {
            *v *= sigma;
        }
    }
}

/// Live page-arena occupancy, shared (by `Arc`) between the arena, the
/// coordinator's `Metrics`, and STATS. All counters are monotonic except
/// `allocated` / `quantized`, which track current residency.
#[derive(Debug, Default)]
pub struct KvPageCounters {
    /// f32 pages currently checked out of the arena.
    pub allocated: AtomicUsize,
    /// Lifetime page allocations.
    pub alloc_total: AtomicU64,
    /// Lifetime page frees (returns to the free list).
    pub freed_total: AtomicU64,
    /// Cold (quantized) pages currently resident.
    pub quantized: AtomicUsize,
    /// Lifetime page-cooling events.
    pub quantized_total: AtomicU64,
    /// Reservations refused because the arena budget was exhausted.
    pub oom: AtomicU64,
}

/// Fixed-size-block page allocator shared by every session of one
/// engine: a budgeted free-list of zeroed f32 page buffers. Allocation
/// past the budget fails with a `kv-oom:`-prefixed error — the
/// coordinator surfaces that verbatim as a distinct protocol error line.
pub struct PageArena {
    n_layers: usize,
    d_model: usize,
    page_tokens: usize,
    max_pages: usize,
    free: Mutex<Vec<Box<[f32]>>>,
    counters: Arc<KvPageCounters>,
}

impl PageArena {
    pub fn new(cfg: &ModelConfig, max_pages: usize, page_tokens: usize) -> Arc<Self> {
        assert!(page_tokens >= 1, "page_tokens must be >= 1");
        assert!(max_pages >= 1, "page budget must be >= 1");
        Arc::new(Self {
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            page_tokens,
            max_pages,
            free: Mutex::new(Vec::new()),
            counters: Arc::new(KvPageCounters::default()),
        })
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn max_pages(&self) -> usize {
        self.max_pages
    }

    pub fn counters(&self) -> Arc<KvPageCounters> {
        Arc::clone(&self.counters)
    }

    /// f32 slots in one page buffer: all layers × (K + V) × page rows.
    pub fn page_floats(&self) -> usize {
        self.n_layers * 2 * self.page_tokens * self.d_model
    }

    pub fn page_bytes(&self) -> usize {
        self.page_floats() * std::mem::size_of::<f32>()
    }

    fn try_alloc(&self) -> Result<Box<[f32]>, String> {
        let mut free = self.free.lock().unwrap_or_else(|e| e.into_inner());
        // `allocated` is only mutated under this lock, so check+bump is
        // race-free; lock-free STATS reads may lag by one page at most.
        if self.counters.allocated.load(Relaxed) >= self.max_pages {
            self.counters.oom.fetch_add(1, Relaxed);
            return Err(format!(
                "kv-oom: page arena exhausted ({} pages of {} tokens)",
                self.max_pages, self.page_tokens
            ));
        }
        let buf = match free.pop() {
            Some(mut b) => {
                b.fill(0.0);
                b
            }
            None => vec![0f32; self.page_floats()].into_boxed_slice(),
        };
        self.counters.allocated.fetch_add(1, Relaxed);
        self.counters.alloc_total.fetch_add(1, Relaxed);
        Ok(buf)
    }

    fn free_page(&self, buf: Box<[f32]>) {
        let mut free = self.free.lock().unwrap_or_else(|e| e.into_inner());
        free.push(buf);
        self.counters.allocated.fetch_sub(1, Relaxed);
        self.counters.freed_total.fetch_add(1, Relaxed);
    }
}

/// A cooled page: per-row byte-aligned code streams ordered
/// `[layer][K rows.. V rows..]` plus the parallel per-row RMS scales.
struct ColdPage {
    bytes: Vec<u8>,
    sigma: Vec<f32>,
}

enum Page {
    Hot(Box<[f32]>),
    Cold(ColdPage),
}

/// A session KV cache backed by arena pages (see the module docs for the
/// page layout). Implements [`KvStore`], so every transformer entry
/// point (`prefill`, `forward_step`, `forward_step_batch`) runs over it
/// unchanged. Dropping the cache returns every hot page to the arena —
/// reclamation on close / disconnect / worker panic is the owning
/// session being dropped, with no separate bookkeeping to leak.
pub struct PagedKvCache {
    arena: Arc<PageArena>,
    codec: Option<Arc<KvCodec>>,
    hot_window: usize,
    n_layers: usize,
    d_model: usize,
    max_seq: usize,
    len: usize,
    pages: Vec<Page>,
    // reusable gather scratch: one layer's contiguous K/V prefix
    k_gather: Vec<f32>,
    v_gather: Vec<f32>,
    // reusable decode scratch
    code: Code,
    block_scratch: Vec<f32>,
    norm_scratch: Vec<f32>,
}

impl PagedKvCache {
    /// A zero-page session cache; pages are allocated by
    /// [`KvStore::reserve`] as tokens actually arrive. `hot_window` is
    /// the trailing token count kept f32 (0 = quantize every full page;
    /// ignored when `codec` is `None`).
    pub fn new(
        cfg: &ModelConfig,
        arena: Arc<PageArena>,
        codec: Option<Arc<KvCodec>>,
        hot_window: usize,
    ) -> Self {
        assert!(
            arena.n_layers == cfg.n_layers && arena.d_model == cfg.d_model,
            "page arena shape does not match model config"
        );
        if let Some(c) = &codec {
            assert_eq!(c.d_model(), cfg.d_model, "kv codec d_model mismatch");
        }
        let block_scratch = vec![0f32; codec.as_ref().map(|c| c.block_dim()).unwrap_or(1)];
        Self {
            arena,
            codec,
            hot_window,
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            max_seq: cfg.max_seq,
            len: 0,
            pages: Vec::new(),
            k_gather: Vec::new(),
            v_gather: Vec::new(),
            code: Code::empty(),
            block_scratch,
            norm_scratch: Vec::new(),
        }
    }

    /// Pages currently held (hot + cold).
    pub fn page_count(&self) -> usize {
        self.pages.len()
    }

    /// Cold (quantized) pages currently held.
    pub fn cold_page_count(&self) -> usize {
        self.pages
            .iter()
            .filter(|p| matches!(p, Page::Cold(_)))
            .count()
    }

    fn write_rows(&mut self, li: usize, base: usize, k_new: &[f32], v_new: &[f32]) {
        let d = self.d_model;
        let pt = self.arena.page_tokens();
        let s = k_new.len() / d;
        for j in 0..s {
            let p = base + j;
            let (pi, slot) = (p / pt, p % pt);
            let page = match &mut self.pages[pi] {
                Page::Hot(b) => b,
                // appends only target positions >= len, and a page cools
                // only once it is full and strictly behind len
                // lint:allow(no-panic-serving): per the invariant above, a
                // cold page here means the arena accounting is already
                // corrupt — crashing the lane beats silently mixing dtypes
                Page::Cold(_) => unreachable!("append into cold page"),
            };
            let ko = li * 2 * pt * d + slot * d;
            let vo = ko + pt * d;
            page[ko..ko + d].copy_from_slice(&k_new[j * d..(j + 1) * d]);
            page[vo..vo + d].copy_from_slice(&v_new[j * d..(j + 1) * d]);
        }
    }

    /// Materialize layer `li`'s contiguous K/V prefix (`rows` positions)
    /// into the gather scratch. Hot pages are moved by `copy_from_slice`
    /// (bit-preserving); cold pages decode row-by-row.
    fn gather_layer(&mut self, li: usize, rows: usize) {
        let d = self.d_model;
        let pt = self.arena.page_tokens();
        if self.k_gather.len() < rows * d {
            self.k_gather.resize(rows * d, 0.0);
            self.v_gather.resize(rows * d, 0.0);
        }
        let pages = &self.pages;
        let k_gather = &mut self.k_gather;
        let v_gather = &mut self.v_gather;
        let code = &mut self.code;
        let scr = &mut self.block_scratch;
        let mut done = 0usize;
        for (pi, page) in pages.iter().enumerate() {
            if done >= rows {
                break;
            }
            let take = pt.min(rows - done);
            debug_assert_eq!(done, pi * pt);
            match page {
                Page::Hot(b) => {
                    let ko = li * 2 * pt * d;
                    let vo = ko + pt * d;
                    k_gather[done * d..(done + take) * d].copy_from_slice(&b[ko..ko + take * d]);
                    v_gather[done * d..(done + take) * d].copy_from_slice(&b[vo..vo + take * d]);
                }
                Page::Cold(cp) => {
                    // lint:allow(no-panic-serving): pages only cool inside
                    // commit(), which is gated on this codec being Some
                    let codec = self.codec.as_ref().expect("cold page without codec");
                    let rb = codec.row_bytes();
                    // cold pages are always full (they cool only once
                    // every slot is behind len), so take == pt here
                    for slot in 0..take {
                        let kr = li * 2 * pt + slot;
                        let vr = kr + pt;
                        codec.decode_row(
                            &cp.bytes[kr * rb..(kr + 1) * rb],
                            cp.sigma[kr],
                            code,
                            scr,
                            &mut k_gather[(done + slot) * d..(done + slot + 1) * d],
                        );
                        codec.decode_row(
                            &cp.bytes[vr * rb..(vr + 1) * rb],
                            cp.sigma[vr],
                            code,
                            scr,
                            &mut v_gather[(done + slot) * d..(done + slot + 1) * d],
                        );
                    }
                }
            }
            done += take;
        }
    }

    /// Quantize every full page that now sits entirely behind the hot
    /// window, returning its f32 buffer to the arena. Runs on commit, so
    /// cooling happens between forward passes, never between layers of
    /// one pass.
    fn cool_pages(&mut self) {
        let Some(codec) = self.codec.clone() else {
            return;
        };
        let pt = self.arena.page_tokens();
        let d = self.d_model;
        let cold_limit = self.len.saturating_sub(self.hot_window);
        for pi in 0..self.pages.len() {
            if (pi + 1) * pt > cold_limit {
                break;
            }
            if matches!(self.pages[pi], Page::Cold(_)) {
                continue;
            }
            let mut bytes = Vec::with_capacity(self.n_layers * 2 * pt * codec.row_bytes());
            let mut sigma = Vec::with_capacity(self.n_layers * 2 * pt);
            {
                let Page::Hot(buf) = &self.pages[pi] else {
                    // lint:allow(no-panic-serving): the matches! guard at
                    // the top of this loop iteration skipped cold pages
                    unreachable!()
                };
                for li in 0..self.n_layers {
                    for half in 0..2 {
                        let off = li * 2 * pt * d + half * pt * d;
                        for slot in 0..pt {
                            let row = &buf[off + slot * d..off + (slot + 1) * d];
                            sigma.push(codec.encode_row(row, &mut self.norm_scratch, &mut bytes));
                        }
                    }
                }
            }
            let old = std::mem::replace(&mut self.pages[pi], Page::Cold(ColdPage { bytes, sigma }));
            if let Page::Hot(buf) = old {
                self.arena.free_page(buf);
            }
            let c = self.arena.counters();
            c.quantized.fetch_add(1, Relaxed);
            c.quantized_total.fetch_add(1, Relaxed);
        }
    }
}

impl KvStore for PagedKvCache {
    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.max_seq
    }

    fn check_append(&self, n: usize) -> Result<(), String> {
        if self.len + n <= self.max_seq {
            Ok(())
        } else {
            Err(format!(
                "sequence of {n} tokens at position {} exceeds cache capacity {}",
                self.len, self.max_seq
            ))
        }
    }

    fn reserve(&mut self, n: usize) -> Result<(), String> {
        self.check_append(n)?;
        let target = (self.len + n).div_ceil(self.arena.page_tokens());
        let start = self.pages.len();
        while self.pages.len() < target {
            match self.arena.try_alloc() {
                Ok(buf) => self.pages.push(Page::Hot(buf)),
                Err(e) => {
                    // roll back this call's allocations so a refused
                    // reservation leaves the session (and budget) as-is
                    while self.pages.len() > start {
                        if let Some(Page::Hot(buf)) = self.pages.pop() {
                            self.arena.free_page(buf);
                        }
                    }
                    return Err(e);
                }
            }
        }
        Ok(())
    }

    fn check_model(&self, cfg: &ModelConfig) {
        assert!(
            self.n_layers == cfg.n_layers
                && self.d_model == cfg.d_model
                && self.max_seq <= cfg.max_seq,
            "PagedKvCache shape does not match model config"
        );
    }

    fn append_layer(
        &mut self,
        li: usize,
        k_new: &[f32],
        v_new: &[f32],
        attend_fn: &mut dyn FnMut(&[f32], &[f32]),
    ) {
        let d = self.d_model;
        debug_assert_eq!(k_new.len() % d, 0);
        let s = k_new.len() / d;
        let base = self.len;
        self.write_rows(li, base, k_new, v_new);
        self.gather_layer(li, base + s);
        attend_fn(
            &self.k_gather[..(base + s) * d],
            &self.v_gather[..(base + s) * d],
        );
    }

    fn commit(&mut self, s: usize) {
        self.len += s;
        self.cool_pages();
    }
}

impl Drop for PagedKvCache {
    fn drop(&mut self) {
        let counters = self.arena.counters();
        for page in self.pages.drain(..) {
            match page {
                Page::Hot(buf) => self.arena.free_page(buf),
                Page::Cold(_) => {
                    counters.quantized.fetch_sub(1, Relaxed);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::config_by_name;
    use crate::model::transformer::{forward_step, prefill, KvCache, Weights};

    fn cfg() -> ModelConfig {
        config_by_name("qwen3-4b-tiny").unwrap()
    }

    #[test]
    fn arena_alloc_free_recycles_and_counts() {
        let cfg = cfg();
        let arena = PageArena::new(&cfg, 2, 4);
        let a = arena.try_alloc().unwrap();
        let b = arena.try_alloc().unwrap();
        assert_eq!(arena.counters().allocated.load(Relaxed), 2);
        let err = arena.try_alloc().unwrap_err();
        assert!(err.starts_with("kv-oom"), "got {err}");
        assert_eq!(arena.counters().oom.load(Relaxed), 1);
        arena.free_page(a);
        arena.free_page(b);
        assert_eq!(arena.counters().allocated.load(Relaxed), 0);
        // recycled buffers come back zeroed
        let c = arena.try_alloc().unwrap();
        assert!(c.iter().all(|&x| x == 0.0));
        assert_eq!(arena.counters().alloc_total.load(Relaxed), 3);
        arena.free_page(c);
    }

    #[test]
    fn reserve_rolls_back_on_oom_and_drop_drains() {
        let cfg = cfg();
        let arena = PageArena::new(&cfg, 3, 4);
        let mut cache = PagedKvCache::new(&cfg, Arc::clone(&arena), None, 32);
        cache.reserve(6).unwrap(); // 2 pages
        assert_eq!(cache.page_count(), 2);
        // needs 2 more pages but only 1 remains: refuse and roll back
        let err = cache.reserve(10).unwrap_err();
        assert!(err.starts_with("kv-oom"), "got {err}");
        assert_eq!(cache.page_count(), 2);
        assert_eq!(arena.counters().allocated.load(Relaxed), 2);
        // capacity check still wins over the page budget
        assert!(cache
            .reserve(cfg.max_seq + 1)
            .unwrap_err()
            .contains("exceeds cache capacity"));
        drop(cache);
        assert_eq!(arena.counters().allocated.load(Relaxed), 0);
    }

    // full transformer forward — too slow under Miri's interpreter; the
    // arena/reserve tests above cover the pointer-heavy paths it checks
    #[cfg_attr(miri, ignore)]
    #[test]
    fn paged_prefill_and_steps_match_dense_bitwise() {
        // quant=none: gather copies f32s, so the paged cache must equal
        // the dense cache bit-for-bit (the full property, across specs /
        // backends / page geometry, lives in rust/tests/kvpage.rs)
        let cfg = cfg();
        let w = Weights::random(&cfg, 41);
        let arena = PageArena::new(&cfg, 64, 5);
        let mut paged = PagedKvCache::new(&cfg, arena, None, 8);
        let mut dense = KvCache::new(&cfg);
        let prompt: Vec<u8> = (0..13).map(|i| (i * 7 % 64) as u8).collect();
        let a = prefill(&w, &mut dense, &prompt);
        let b = prefill(&w, &mut paged, &prompt);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        for step in 0..9u8 {
            let a = forward_step(&w, &mut dense, step % 64);
            let b = forward_step(&w, &mut paged, step % 64);
            assert!(
                a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "paged cache diverged at step {step}"
            );
        }
        assert_eq!(paged.len(), dense.len());
        assert_eq!(paged.page_count(), (13 + 9usize).div_ceil(5));
    }

    // builds a model + E8 codec and runs prefill — minutes under Miri
    #[cfg_attr(miri, ignore)]
    #[test]
    fn cold_pages_quantize_free_arena_pages_and_stay_close() {
        let cfg = cfg();
        let w = Weights::random(&cfg, 43);
        let arena = PageArena::new(&cfg, 64, 4);
        let codec = KvCodec::build(KvQuantKind::E8, cfg.d_model).unwrap();
        let mut paged = PagedKvCache::new(&cfg, Arc::clone(&arena), codec, 4);
        let mut dense = KvCache::new(&cfg);
        let prompt: Vec<u8> = (0..24).map(|i| (i * 5 % 64) as u8).collect();
        let a = prefill(&w, &mut dense, &prompt);
        let b = prefill(&w, &mut paged, &prompt);
        // positions 0..20 are behind the 4-token hot window: 5 pages cold
        assert_eq!(paged.cold_page_count(), 5);
        assert_eq!(arena.counters().quantized.load(Relaxed), 5);
        // cold pages released their f32 buffers back to the arena
        assert_eq!(
            arena.counters().allocated.load(Relaxed) as usize,
            paged.page_count() - paged.cold_page_count()
        );
        // lossy but sane: reconstructed attention keeps logits close
        let rel: f32 = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max)
            / a.iter().map(|x| x.abs()).fold(0.0, f32::max).max(1e-6);
        assert!(rel < 0.5, "quantized-KV logits unreasonably far: {rel}");
        drop(paged);
        assert_eq!(arena.counters().allocated.load(Relaxed), 0);
        assert_eq!(arena.counters().quantized.load(Relaxed), 0);
    }

    #[test]
    fn quantized_decode_is_deterministic() {
        let cfg = cfg();
        let w = Weights::random(&cfg, 47);
        let run = || {
            let arena = PageArena::new(&cfg, 64, 4);
            let codec = KvCodec::build(KvQuantKind::E8, cfg.d_model).unwrap();
            let mut paged = PagedKvCache::new(&cfg, arena, codec, 2);
            let mut logits = prefill(&w, &mut paged, &[3, 1, 4, 1, 5, 9, 2, 6]);
            for s in 0..12u8 {
                logits = forward_step(&w, &mut paged, s % 64);
            }
            logits
        };
        let (a, b) = (run(), run());
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    // Llvq codec construction enumerates Leech leaders — minutes under
    // Miri; the zero-row E8 roundtrip below keeps codec coverage
    #[cfg_attr(miri, ignore)]
    #[test]
    fn codec_row_roundtrip_bounds() {
        let cfg = cfg();
        for kind in [KvQuantKind::E8, KvQuantKind::Llvq] {
            let codec = KvCodec::build(kind, cfg.d_model).unwrap().unwrap();
            let row: Vec<f32> = (0..cfg.d_model)
                .map(|i| ((i as f32) * 0.37).sin() * 3.0)
                .collect();
            let mut bytes = Vec::new();
            let mut norm = Vec::new();
            let sigma = codec.encode_row(&row, &mut norm, &mut bytes);
            assert_eq!(bytes.len(), codec.row_bytes());
            let mut out = vec![0f32; cfg.d_model];
            let mut code = Code::empty();
            let mut scr = vec![0f32; codec.block_dim()];
            codec.decode_row(&bytes, sigma, &mut code, &mut scr, &mut out);
            let err: f32 = row
                .iter()
                .zip(&out)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                / row.iter().map(|a| a * a).sum::<f32>();
            assert!(err < 0.5, "{}: relative row error {err}", kind.label());
        }
    }

    #[test]
    fn zero_rows_roundtrip_without_nan() {
        let cfg = cfg();
        let codec = KvCodec::build(KvQuantKind::E8, cfg.d_model)
            .unwrap()
            .unwrap();
        let row = vec![0f32; cfg.d_model];
        let mut bytes = Vec::new();
        let mut norm = Vec::new();
        let sigma = codec.encode_row(&row, &mut norm, &mut bytes);
        assert_eq!(sigma, 1.0);
        let mut out = vec![1f32; cfg.d_model];
        let mut code = Code::empty();
        let mut scr = vec![0f32; codec.block_dim()];
        codec.decode_row(&bytes, sigma, &mut code, &mut scr, &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn kv_quant_kind_parses() {
        assert_eq!(KvQuantKind::parse("none").unwrap(), KvQuantKind::None);
        assert_eq!(KvQuantKind::parse("e8").unwrap(), KvQuantKind::E8);
        assert_eq!(KvQuantKind::parse("llvq").unwrap(), KvQuantKind::Llvq);
        assert!(KvQuantKind::parse("lattice").is_err());
        assert!(KvCodec::build(KvQuantKind::None, 144).unwrap().is_none());
    }
}
