//! Model evaluation: perplexity and probe-task accuracies (the Wiki /
//! MMLU / CSR columns of Tables 3/5/6, under the DESIGN.md substitutions).
//!
//! Evaluation runs over held-out synthetic-corpus sequences through the
//! Rust-native forward pass. Sequences are processed in parallel; metrics
//! aggregate exactly (token-weighted).

use crate::model::corpus::Corpus;
use crate::model::transformer::{forward, sequence_loss, ActivationCapture, ForwardOps};
use crate::util::threadpool;

/// Evaluation metrics for one model.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalMetrics {
    /// Perplexity = exp(mean NLL in nats) — the "Wiki ↓" column analogue.
    pub perplexity: f64,
    /// Top-1 next-token accuracy (%) — the "CSR ↑" analogue.
    pub accuracy_pct: f64,
    /// Accuracy on deterministic motif positions (%) — the "MMLU ↑"
    /// analogue (knowledge recall).
    pub cloze_pct: f64,
    pub tokens: usize,
}

/// Evaluate on `num_seqs` held-out sequences from `seed` (use a seed
/// disjoint from training — the convention is train seed 1000, eval 2000).
/// Generic over [`ForwardOps`]: dense weights and every packed execution
/// backend evaluate through the identical code path.
pub fn evaluate<M: ForwardOps + ?Sized>(
    w: &M,
    num_seqs: usize,
    seed: u64,
    threads: usize,
) -> EvalMetrics {
    let seq_len = w.cfg().max_seq.min(64);
    let mut corpus = Corpus::new(seed);
    let seqs = corpus.sequences(num_seqs, seq_len);

    #[derive(Clone, Default)]
    struct Partial {
        nll_sum: f64,
        hits: f64,
        cloze_hits: f64,
        cloze_n: f64,
        tokens: usize,
    }

    let partials = threadpool::parallel_map(seqs.len(), threads, |i| {
        let (toks, det) = &seqs[i];
        let inputs = &toks[..seq_len];
        let targets = &toks[1..=seq_len];
        let det_mask = &det[1..=seq_len];
        let mut cap = ActivationCapture::default();
        let logits = forward(w, inputs, &mut cap);
        let (nll, acc, _cloze) = sequence_loss(&logits, targets, det_mask, w.cfg().vocab);
        // recompute cloze counts exactly (weighted)
        let det_n = det_mask.iter().filter(|&&d| d).count();
        Partial {
            nll_sum: nll * seq_len as f64,
            hits: acc * seq_len as f64,
            cloze_hits: _cloze * det_n as f64,
            cloze_n: det_n as f64,
            tokens: seq_len,
        }
    });

    let mut total = Partial::default();
    for p in partials {
        total.nll_sum += p.nll_sum;
        total.hits += p.hits;
        total.cloze_hits += p.cloze_hits;
        total.cloze_n += p.cloze_n;
        total.tokens += p.tokens;
    }
    EvalMetrics {
        perplexity: (total.nll_sum / total.tokens as f64).exp(),
        accuracy_pct: 100.0 * total.hits / total.tokens as f64,
        cloze_pct: if total.cloze_n > 0.0 {
            100.0 * total.cloze_hits / total.cloze_n
        } else {
            0.0
        },
        tokens: total.tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::config_by_name;
    use crate::model::transformer::Weights;

    #[test]
    fn random_model_is_near_chance() {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 7);
        let m = evaluate(&w, 8, 2000, 2);
        // untrained → ppl near vocab size (64), accuracy near 1/64
        assert!(m.perplexity > 25.0, "ppl {}", m.perplexity);
        assert!(m.accuracy_pct < 20.0, "acc {}", m.accuracy_pct);
        assert_eq!(m.tokens, 8 * 64);
    }

    #[test]
    fn eval_is_deterministic() {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 7);
        let a = evaluate(&w, 4, 2000, 1);
        let b = evaluate(&w, 4, 2000, 4);
        assert!((a.perplexity - b.perplexity).abs() < 1e-9);
        assert!((a.cloze_pct - b.cloze_pct).abs() < 1e-9);
    }
}
