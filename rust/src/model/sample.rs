//! Token sampling for generation sessions.
//!
//! A [`Sampler`] turns a logit row into the next token id: greedy argmax
//! when `temperature == 0` (the serving default, and the mode the
//! KV-cache correctness oracle pins against repeated `NEXT` calls), or a
//! seeded softmax draw with optional temperature scaling and top-k
//! truncation. The RNG is the crate's own deterministic
//! [`Xoshiro256pp`](crate::util::rng::Xoshiro256pp), so a `(params, seed)`
//! pair replays the same token stream on any backend — the TCP `GEN`
//! command and `llvq generate` both parse their `temp=`/`topk=`/`seed=`
//! arguments through [`SampleParams::from_kv_args`].

use crate::util::rng::Xoshiro256pp;

/// Index of the largest logit, ties broken toward the lowest id — the
/// same rule the v1 `NEXT` reply uses, shared so greedy generation and
/// one-shot serving can never disagree.
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Sampling configuration for one `GEN` run. The all-zero default is
/// greedy decoding.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SampleParams {
    /// `0` = greedy argmax (deterministic); otherwise the softmax
    /// temperature (higher = flatter).
    pub temperature: f32,
    /// `0` = no truncation; otherwise sample only among the `k` largest
    /// logits.
    pub top_k: usize,
    /// Seed of the sampler's private RNG stream.
    pub seed: u64,
}

impl SampleParams {
    /// Parse `temp=… topk=… seed=…` key/value arguments (any subset, any
    /// order) — the wire format of `GEN <n> [args…]` and the flag format
    /// of `llvq generate`.
    pub fn from_kv_args<'a, I: Iterator<Item = &'a str>>(args: I) -> Result<Self, String> {
        let mut p = SampleParams::default();
        for a in args {
            let (key, val) = a
                .split_once('=')
                .ok_or_else(|| format!("bad sampling arg '{a}' (want key=value)"))?;
            match key {
                "temp" | "temperature" => {
                    p.temperature = val
                        .parse()
                        .map_err(|_| format!("bad temperature '{val}'"))?;
                }
                "topk" | "top_k" => {
                    p.top_k = val.parse().map_err(|_| format!("bad topk '{val}'"))?;
                }
                "seed" => {
                    p.seed = val.parse().map_err(|_| format!("bad seed '{val}'"))?;
                }
                other => return Err(format!("unknown sampling arg '{other}'")),
            }
        }
        if !p.temperature.is_finite() || p.temperature < 0.0 {
            return Err("temperature must be finite and >= 0".into());
        }
        Ok(p)
    }
}

/// Seeded token sampler (greedy / temperature / top-k).
pub struct Sampler {
    params: SampleParams,
    rng: Xoshiro256pp,
}

impl Sampler {
    pub fn new(params: SampleParams) -> Self {
        Self {
            rng: Xoshiro256pp::new(params.seed),
            params,
        }
    }

    /// The deterministic argmax sampler.
    pub fn greedy() -> Self {
        Self::new(SampleParams::default())
    }

    pub fn params(&self) -> SampleParams {
        self.params
    }

    /// Pick a token id from one logit row.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        assert!(!logits.is_empty(), "empty logit row");
        if self.params.temperature <= 0.0 {
            return argmax(logits);
        }
        // rank candidates by logit (descending, ties toward lower id).
        // total_cmp, not partial_cmp: a NaN logit from a corrupt artifact
        // gives partial_cmp an incomparable pair, and sort_by panics on a
        // non-total comparator — total_cmp keeps the draw panic-free (NaN
        // candidates rank first but collapse the softmax weights to NaN,
        // so the `u <= 0` walk falls through to the last candidate).
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]).then(a.cmp(&b)));
        let k = match self.params.top_k {
            0 => logits.len(),
            k => k.min(logits.len()),
        };
        let cand = &idx[..k];
        // max-subtracted softmax over the candidate set, in f64
        let t = self.params.temperature as f64;
        let maxv = logits[cand[0]] as f64;
        let weights: Vec<f64> = cand
            .iter()
            .map(|&i| ((logits[i] as f64 - maxv) / t).exp())
            .collect();
        let total: f64 = weights.iter().sum();
        let mut u = self.rng.next_f64() * total;
        for (w, &i) in weights.iter().zip(cand) {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        cand[k - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> Vec<f32> {
        vec![0.1, 2.5, -1.0, 2.5, 0.0, 1.9]
    }

    #[test]
    fn greedy_is_argmax_with_low_tie() {
        let mut s = Sampler::greedy();
        // ids 1 and 3 tie at 2.5 → lowest wins, matching the NEXT reply
        assert_eq!(s.sample(&row()), 1);
        assert_eq!(argmax(&row()), 1);
    }

    #[test]
    fn seeded_sampling_is_deterministic() {
        let p = SampleParams {
            temperature: 0.8,
            top_k: 4,
            seed: 42,
        };
        let mut a = Sampler::new(p);
        let mut b = Sampler::new(p);
        let r = row();
        for _ in 0..50 {
            assert_eq!(a.sample(&r), b.sample(&r));
        }
        let mut c = Sampler::new(SampleParams { seed: 43, ..p });
        let mut d = Sampler::new(p);
        let stream_c: Vec<usize> = (0..50).map(|_| c.sample(&r)).collect();
        let stream_d: Vec<usize> = (0..50).map(|_| d.sample(&r)).collect();
        assert_ne!(
            stream_c, stream_d,
            "different seeds produced identical 50-draw streams"
        );
    }

    #[test]
    fn top_k_restricts_support() {
        let p = SampleParams {
            temperature: 1.5,
            top_k: 2,
            seed: 7,
        };
        let mut s = Sampler::new(p);
        let r = row();
        for _ in 0..200 {
            let t = s.sample(&r);
            assert!(t == 1 || t == 3, "sampled {t} outside top-2 {{1, 3}}");
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let mut s = Sampler::new(SampleParams {
            temperature: 10.0,
            top_k: 0,
            seed: 3,
        });
        let r = row();
        let mut seen = [false; 6];
        for _ in 0..2000 {
            seen[s.sample(&r)] = true;
        }
        assert!(seen.iter().all(|&x| x), "10x temperature should reach every id");
    }

    #[test]
    fn nan_logits_never_panic_the_sampler() {
        // corrupt artifacts can produce NaN logits; sampling must stay
        // panic-free and in range on every mode
        let r = vec![0.5, f32::NAN, 0.25, f32::NAN, 1.0, f32::NEG_INFINITY];
        let mut s = Sampler::new(SampleParams {
            temperature: 0.8,
            top_k: 3,
            seed: 11,
        });
        for _ in 0..200 {
            assert!(s.sample(&r) < r.len());
        }
        let mut unbounded = Sampler::new(SampleParams {
            temperature: 1.2,
            top_k: 0,
            seed: 5,
        });
        assert!(unbounded.sample(&r) < r.len());
        // greedy ignores NaN entirely (argmax keeps the documented
        // lowest-id tie-break over comparable values)
        let mut g = Sampler::greedy();
        assert_eq!(g.sample(&r), 4);
        assert_eq!(argmax(&r), 4);
    }

    #[test]
    fn kv_args_parse_and_validate() {
        let p = SampleParams::from_kv_args(
            "temp=0.7 topk=8 seed=99".split_whitespace(),
        )
        .unwrap();
        assert_eq!(
            p,
            SampleParams {
                temperature: 0.7,
                top_k: 8,
                seed: 99
            }
        );
        assert_eq!(
            SampleParams::from_kv_args("".split_whitespace()).unwrap(),
            SampleParams::default()
        );
        for bad in ["temp=-1", "temp=nan", "warp=9", "topk", "seed=x"] {
            assert!(
                SampleParams::from_kv_args(bad.split_whitespace()).is_err(),
                "accepted '{bad}'"
            );
        }
    }
}
