//! Execution backends: serving a model through per-layer [`LinearOp`]s.
//!
//! This is where the paper's "no expensive lookup mechanisms or explicit
//! codebook storage on the inference path" claim stops being a storage
//! property and becomes a serving property. The forward pass
//! (`model::transformer::forward`) is generic over `ForwardOps`; an
//! [`ExecutionBackend`] implements it by owning one [`LinearOp`] per
//! quantized linear layer, and three op families ship behind the same API:
//!
//! * **dense** ([`DenseOp`]) — a materialized f32 matrix; bit-identical to
//!   the historical `forward(&Weights, …)` path (it calls the same matvec
//!   kernel) and therefore the oracle the other two are tested against.
//! * **cached** ([`CachedLayerOp`]) — holds only the `.llvqm` header until
//!   a layer is first touched, then reads that layer's code stream from
//!   its recorded byte offset ([`PackedFile::read_layer`]) and decodes it
//!   once ([`unpack_layer_pool`], row-sharded over the backend's persistent
//!   worker pool, bit-exact vs the PTQ driver). Load time and peak RSS
//!   track what is actually touched, and a fully-warm cache reproduces
//!   dense logits bit-for-bit.
//! * **fused** ([`FusedLayerOp`]) — matvec *directly over the bit-packed
//!   code stream*: each row's codes are decoded a segment of consecutive
//!   blocks at a time and accumulated against the (rotated, scale-folded)
//!   activation through the SIMD kernel selected at construction
//!   ([`crate::quant::kernel`]; `LLVQ_SIMD`/`--simd` override runtime
//!   detection), so the dense matrix never exists in memory. Resident
//!   weight bytes equal the on-disk code bytes (+ f64 column scales when
//!   fine-tuning was enabled). Its `matmul_into` decodes each row **once
//!   per call** and dots it against every activation lane — the decode
//!   cost of a batched decode step (or a prefill run: the scheduler's
//!   chunk-sized prefills arrive here as `linear_batch` calls with
//!   `n = chunk_len`, so each chunk amortizes its row decodes across all
//!   its positions exactly like a slate does) is amortized across
//!   the whole slate, bit-identically to per-lane matvecs — and the row
//!   loop is **sharded across a persistent worker pool** (the backend's
//!   `--threads` knob): rows accumulate independently, so any thread count
//!   is bit-identical to the sequential kernel by construction.
//!
//! ### Numerical contract
//!
//! Dense and cached backends are **bit-identical** to the oracle. The
//! fused backend evaluates `y = R_outᵀ · (C · diag(β) · (R_in · x)) · σ`
//! with f64 row accumulation, whereas the dense reconstruction rounds each
//! weight to f32 first and accumulates the matvec in f32 — the same
//! mathematical function with a different accumulation order, so fused
//! logits agree to ~1e-5 *relative* (tested, argmax-stable) rather than
//! bit-exactly. The same 1e-5/argmax contract holds between SIMD kernels
//! and the scalar oracle; for a *given* kernel, results are bit-identical
//! across thread counts and batch shapes (`rust/tests/kernels.rs`).

use std::sync::{Arc, OnceLock};

use crate::model::config::ModelConfig;
use crate::model::packed::{unpack_layer_pool, PackedFile, PackedLayer};
use crate::model::transformer::{linear, ForwardOps, LinearKind, Weights, LINEAR_KINDS};
use crate::pipeline::rotation::LayerRotation;
use crate::quant::kernel::{decode_row_dot_multi_kernel, Kernel, KernelScratch};
use crate::quant::{PackedCodes, VectorQuantizer};
use crate::util::bits::BitReader;
use crate::util::threadpool::{Pool, ShardedSlice};

/// One linear layer as an *operation* — the unit the serving stack
/// composes, independent of how (or whether) the weight matrix exists in
/// memory.
pub trait LinearOp: Send + Sync {
    /// `(d_out, d_in)`.
    fn shape(&self) -> (usize, usize);

    /// `y = W·x` with `x.len() == d_in`, `y.len() == d_out`.
    fn matvec(&self, x: &[f32], y: &mut [f32]);

    /// Apply the op to `n` row-major activation vectors at once (the
    /// batched entry; the default loops [`LinearOp::matvec`]).
    fn matmul_into(&self, xs: &[f32], ys: &mut [f32], n: usize) {
        let (d_out, d_in) = self.shape();
        debug_assert_eq!(xs.len(), n * d_in);
        debug_assert_eq!(ys.len(), n * d_out);
        for (x, y) in xs.chunks_exact(d_in).zip(ys.chunks_exact_mut(d_out)) {
            self.matvec(x, y);
        }
    }

    /// Weight-payload bytes currently resident in memory for this op
    /// (dense f32 bytes, decoded-cache bytes, or packed code/scale bytes —
    /// *not* counting metadata).
    fn resident_bytes(&self) -> usize;

    /// Human-readable label, e.g. `dense:L0.wq`.
    fn name(&self) -> String;
}

/// Materialized f32 matrix op — the current/oracle behavior.
pub struct DenseOp {
    w: Vec<f32>,
    rows: usize,
    cols: usize,
    label: String,
}

impl DenseOp {
    pub fn new(w: Vec<f32>, rows: usize, cols: usize, label: impl Into<String>) -> Self {
        assert_eq!(w.len(), rows * cols);
        Self {
            w,
            rows,
            cols,
            label: label.into(),
        }
    }
}

impl LinearOp for DenseOp {
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn matvec(&self, x: &[f32], y: &mut [f32]) {
        linear(&self.w, self.rows, self.cols, x, y);
    }

    fn resident_bytes(&self) -> usize {
        self.w.len() * 4
    }

    fn name(&self) -> String {
        format!("dense:{}", self.label)
    }
}

/// Lazily-decoded packed layer: nothing but header metadata until the
/// first `matvec`, which reads the layer's code stream from its byte
/// offset in the `.llvqm` file and decodes it once (bit-exact vs the PTQ
/// driver's reconstruction). Subsequent calls hit the dense cache.
pub struct CachedLayerOp {
    file: Arc<PackedFile>,
    q: Arc<dyn VectorQuantizer>,
    /// Index into `file.meta.layers`.
    idx: usize,
    rows: usize,
    cols: usize,
    /// Backend-wide persistent worker pool: first-touch decode row-shards
    /// over it instead of spawning scoped threads per layer.
    pool: Arc<Pool>,
    label: String,
    dense: OnceLock<Vec<f32>>,
}

impl CachedLayerOp {
    fn decoded(&self) -> &Vec<f32> {
        self.dense.get_or_init(|| {
            let pl = self
                .file
                .read_layer(self.idx)
                // lint:allow(no-panic-serving): LinearOp::matvec has no
                // Result channel; a first-touch read failure of a file
                // that was validated at load is unrecoverable, and the
                // coordinator's catch_unwind contains it per-request
                .unwrap_or_else(|e| panic!("lazy layer read ({}): {e}", self.label));
            unpack_layer_pool(self.q.as_ref(), &pl, &self.pool)
                // lint:allow(no-panic-serving): same containment as the
                // read above — decode of a load-validated layer cannot
                // fail without artifact corruption
                .unwrap_or_else(|e| panic!("lazy layer decode ({}): {e}", self.label))
        })
    }

    /// Whether this layer has been touched (and thus decoded) yet.
    pub fn is_resident(&self) -> bool {
        self.dense.get().is_some()
    }
}

impl LinearOp for CachedLayerOp {
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn matvec(&self, x: &[f32], y: &mut [f32]) {
        linear(self.decoded(), self.rows, self.cols, x, y);
    }

    fn resident_bytes(&self) -> usize {
        self.dense.get().map_or(0, |w| w.len() * 4)
    }

    fn name(&self) -> String {
        format!(
            "cached:{}{}",
            self.label,
            if self.is_resident() { "" } else { " (cold)" }
        )
    }
}

/// Call-level fused-matmul scratch (prepared once per `matmul_into`, on
/// the calling thread, before the row shards fan out).
#[derive(Default)]
struct FusedCall {
    /// `n × cols` rotated, β-scaled activation lanes (read-only for shards).
    xr: Vec<f64>,
    /// `rows × n` row-major accumulators (each shard writes its own rows).
    acc: Vec<f64>,
    /// `rows`-length per-lane gather buffer for the output unrotation.
    ao: Vec<f64>,
}

/// Per-worker fused-matmul scratch (kernel segment/code buffers, per-lane
/// dots) — owned by the pool, one slot per executor, warm across calls and
/// layers (the quantizer is fixed per model).
#[derive(Default)]
struct FusedWorker {
    scratch: KernelScratch,
    lane_accs: Vec<f64>,
}

thread_local! {
    /// [`FusedCall`] per *calling* thread (not pool-owned): concurrent
    /// forward passes over one backend (the eval path fans sequences
    /// across threads) prepare their activations in parallel, and the
    /// serving hot loop stays allocation-free after warm-up — the same
    /// hoisting discipline as the gptq encode loop and `unpack_layer`.
    static FUSED_CALL: std::cell::RefCell<FusedCall> =
        std::cell::RefCell::new(FusedCall::default());
}

/// Fused dequant-matvec over the bit-packed code stream. The layer's dense
/// matrix never exists: each row is decoded a segment of consecutive
/// blocks at a time into flat scratch and immediately accumulated against
/// the prepared activation through the kernel fixed at construction
/// ([`crate::quant::kernel`]), replaying the PTQ driver's reconstruction
/// algebra (σ scaling, fine-tuned column scales, inverse rotation) around
/// the matvec instead of around a matrix.
pub struct FusedLayerOp {
    q: Arc<dyn VectorQuantizer>,
    widths: Vec<u32>,
    rows: usize,
    cols: usize,
    sigma: f64,
    col_scales: Option<Vec<f64>>,
    codes: PackedCodes,
    rot: LayerRotation,
    /// Backend-wide persistent worker pool the matmul row-shards over.
    pool: Arc<Pool>,
    /// Inner decode+dot kernel, fixed at backend construction
    /// ([`Kernel::Scalar`] is the per-block oracle path).
    kernel: Kernel,
    label: String,
}

impl FusedLayerOp {
    /// Build from a loaded packed layer (codes stay packed; this is the
    /// only copy the op keeps). `pool` is the backend's shared worker
    /// pool; `Pool::new(1)` gives the sequential kernel. `kernel` selects
    /// the inner decode+dot path (see [`crate::quant::kernel`]).
    pub fn new(
        q: Arc<dyn VectorQuantizer>,
        pl: PackedLayer,
        label: impl Into<String>,
        pool: Arc<Pool>,
        kernel: Kernel,
    ) -> Self {
        let widths = q.code_widths();
        let rot = LayerRotation::new(pl.rot_mode, pl.cols, pl.rows, pl.rot_seed);
        Self {
            q,
            widths,
            rows: pl.rows,
            cols: pl.cols,
            sigma: pl.sigma,
            col_scales: pl.col_scales,
            codes: pl.codes,
            rot,
            pool,
            kernel,
            label: label.into(),
        }
    }
}

impl LinearOp for FusedLayerOp {
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn matvec(&self, x: &[f32], y: &mut [f32]) {
        self.matmul_into(x, y, 1);
    }

    /// The slate kernel: every weight row's code stream is decoded ONCE
    /// per call and dotted against all `n` lanes — this is what amortizes
    /// dequantization across batch lanes / prefill positions — and the
    /// row loop is sharded across the backend's persistent worker pool
    /// (rows are independent: each shard reads its own byte ranges and
    /// writes its own accumulator rows). Per lane and per row, the
    /// float-op sequence (rotate, β, the selected kernel's fixed
    /// accumulation shape, σ, R_outᵀ) depends only on the kernel chosen at
    /// construction — never on batching or thread count — so neither ever
    /// changes a logit bit for a given kernel.
    fn matmul_into(&self, xs: &[f32], ys: &mut [f32], n: usize) {
        debug_assert_eq!(xs.len(), n * self.cols);
        debug_assert_eq!(ys.len(), n * self.rows);
        if n == 0 {
            return;
        }
        let rb = self.codes.row_bytes;
        FUSED_CALL.with(|cell| {
            let mut call = cell.borrow_mut();
            let FusedCall { xr, acc, ao } = &mut *call;
            // per lane: x' = diag(β) · R_in · x  (σ is scalar; folded in
            // per row)
            xr.clear();
            xr.resize(n * self.cols, 0f64);
            for (xl, x) in xr
                .chunks_exact_mut(self.cols)
                .zip(xs.chunks_exact(self.cols))
            {
                for (xi, &v) in xl.iter_mut().zip(x) {
                    *xi = v as f64;
                }
                self.rot.rotate_activation(xl);
                if let Some(beta) = &self.col_scales {
                    for (xi, &b) in xl.iter_mut().zip(beta) {
                        *xi *= b;
                    }
                }
            }
            acc.clear();
            acc.resize(self.rows * n, 0f64);
            {
                let lanes: &[f64] = xr;
                let shard = ShardedSlice::new(&mut acc[..]);
                self.pool.run_partitioned(self.rows, |range, scratch| {
                    let w = scratch.get_or(FusedWorker::default);
                    w.lane_accs.clear();
                    w.lane_accs.resize(n, 0f64);
                    for r in range {
                        let mut br =
                            BitReader::new(&self.codes.data[r * rb..(r + 1) * rb]);
                        decode_row_dot_multi_kernel(
                            self.q.as_ref(),
                            self.kernel,
                            &self.widths,
                            &mut br,
                            &mut w.scratch,
                            lanes,
                            self.cols,
                            &mut w.lane_accs,
                        );
                        // SAFETY: row ranges are disjoint across shards
                        let out = unsafe { shard.range_mut(r * n..(r + 1) * n) };
                        for (o, &a) in out.iter_mut().zip(w.lane_accs.iter()) {
                            *o = a * self.sigma;
                        }
                    }
                });
            }
            // per lane: y = R_outᵀ · acc  (gather the lane's column out of
            // the row-major accumulators — same values, same unrotation
            // input, as the historical lane-major layout)
            ao.clear();
            ao.resize(self.rows, 0f64);
            for (lane, y) in ys.chunks_exact_mut(self.rows).enumerate() {
                for (r, a) in ao.iter_mut().enumerate() {
                    *a = acc[r * n + lane];
                }
                self.rot.unrotate_output(ao);
                for (yo, &v) in y.iter_mut().zip(ao.iter()) {
                    *yo = v as f32;
                }
            }
        });
    }

    fn resident_bytes(&self) -> usize {
        self.codes.data.len() + self.col_scales.as_ref().map_or(0, |b| b.len() * 8)
    }

    fn name(&self) -> String {
        format!("fused:{}", self.label)
    }
}

/// Which op family a backend instantiates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Dense,
    Cached,
    Fused,
}

impl BackendKind {
    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::Dense => "dense",
            BackendKind::Cached => "cached",
            BackendKind::Fused => "fused",
        }
    }

    /// Parse a CLI flag value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "dense" => Some(BackendKind::Dense),
            "cached" | "packed-cached" => Some(BackendKind::Cached),
            "fused" | "packed-fused" => Some(BackendKind::Fused),
            _ => None,
        }
    }
}

/// Slot of `kind` in [`LINEAR_KINDS`] order — derived, so the op grid,
/// `check_layout`, and the dense constructor can never disagree about
/// ordering.
fn kind_index(kind: LinearKind) -> usize {
    LINEAR_KINDS
        .iter()
        .position(|k| *k == kind)
        // lint:allow(no-panic-serving): LINEAR_KINDS is a const listing
        // every enum variant; a miss is a compile-time-shaped invariant
        // break, not a runtime condition
        .expect("every LinearKind appears in LINEAR_KINDS")
}

/// A model ready to execute: dense fp32 parts (embeddings, norms, LM head
/// — dense in the `.llvqm` format itself) plus one [`LinearOp`] per
/// quantized linear layer. Implements `ForwardOps`, so
/// `transformer::forward` / `model::eval::evaluate` / the serving
/// coordinator all run on it unchanged.
pub struct ExecutionBackend {
    cfg: ModelConfig,
    kind: BackendKind,
    /// Kernel worker threads (executors of the shared [`Pool`]); 1 = the
    /// sequential kernels.
    threads: usize,
    /// SIMD kernel the fused ops dispatch to ([`Kernel::Scalar`] for
    /// dense/cached backends, which have no fused inner loop).
    simd: Kernel,
    tok_emb: Vec<f32>,
    pos_emb: Vec<f32>,
    norms1: Vec<Vec<f32>>,
    norms2: Vec<Vec<f32>>,
    norm_f: Vec<f32>,
    lm_head: DenseOp,
    /// `ops[layer][kind_index]`, LINEAR_KINDS order.
    ops: Vec<Vec<Box<dyn LinearOp>>>,
}

impl ExecutionBackend {
    /// Wrap dense weights (the current behavior / oracle). Consumes the
    /// matrices; logits are bit-identical to `forward(&Weights, …)`.
    pub fn dense(w: Weights) -> Self {
        let cfg = w.cfg.clone();
        let mut norms1 = Vec::with_capacity(cfg.n_layers);
        let mut norms2 = Vec::with_capacity(cfg.n_layers);
        let mut ops: Vec<Vec<Box<dyn LinearOp>>> = Vec::with_capacity(cfg.n_layers);
        for (li, blk) in w.blocks.into_iter().enumerate() {
            norms1.push(blk.norm1);
            norms2.push(blk.norm2);
            let mut row: Vec<Box<dyn LinearOp>> = Vec::with_capacity(LINEAR_KINDS.len());
            for (kind, mat) in LINEAR_KINDS
                .into_iter()
                .zip([blk.wq, blk.wk, blk.wv, blk.wo, blk.w1, blk.w2])
            {
                let (rows, cols) = kind.shape(&cfg);
                row.push(Box::new(DenseOp::new(
                    mat,
                    rows,
                    cols,
                    format!("L{li}.{}", kind.label()),
                )));
            }
            ops.push(row);
        }
        let lm_head = DenseOp::new(w.lm_head, cfg.vocab, cfg.d_model, "lm_head");
        Self {
            cfg,
            kind: BackendKind::Dense,
            threads: 1,
            simd: Kernel::Scalar,
            tok_emb: w.tok_emb,
            pos_emb: w.pos_emb,
            norms1,
            norms2,
            norm_f: w.norm_f,
            lm_head,
            ops,
        }
    }

    /// Lazy per-layer decode: only the header and the dense fp32 tail are
    /// read at construction; each linear layer is fetched from its byte
    /// offset and dequantized on first touch, row-sharded over `threads`
    /// persistent pool workers.
    pub fn packed_cached(file: PackedFile, threads: usize) -> Result<Self, String> {
        Self::from_packed(file, threads, BackendKind::Cached, Kernel::Scalar)
    }

    /// Fused dequant-matvec: reads every layer's *code stream* (not its
    /// dense expansion) at construction; matvecs run directly over the
    /// packed bits forever after, row-sharded over `threads` persistent
    /// pool workers (`threads = 1` is the sequential kernel; any thread
    /// count is bit-identical to it for a given SIMD kernel). The inner
    /// kernel honours `LLVQ_SIMD` and falls back to auto-detection
    /// ([`Kernel::resolve`]); use [`ExecutionBackend::packed_fused_kernel`]
    /// to force one programmatically.
    pub fn packed_fused(file: PackedFile, threads: usize) -> Result<Self, String> {
        let kernel = Kernel::resolve("")?;
        Self::from_packed(file, threads, BackendKind::Fused, kernel)
    }

    /// [`ExecutionBackend::packed_fused`] with an explicit SIMD kernel —
    /// errors if the host cannot run `kernel` (no silent fallback).
    pub fn packed_fused_kernel(
        file: PackedFile,
        threads: usize,
        kernel: Kernel,
    ) -> Result<Self, String> {
        if !kernel.available() {
            return Err(format!(
                "SIMD kernel '{}' is not available on this host",
                kernel.label()
            ));
        }
        Self::from_packed(file, threads, BackendKind::Fused, kernel)
    }

    fn from_packed(
        file: PackedFile,
        threads: usize,
        kind: BackendKind,
        kernel: Kernel,
    ) -> Result<Self, String> {
        file.meta.check_layout()?;
        let q: Arc<dyn VectorQuantizer> =
            Arc::from(crate::quant::quantizer_from_spec(&file.meta.quantizer)?);
        // code geometry vs quantizer spec — validated for EVERY packed
        // backend up front (metadata only, no payload reads), so a
        // mismatched artifact fails at load instead of panicking the
        // serving worker when a cached layer first decodes mid-request
        let code_bits: u32 = q.code_widths().iter().sum();
        for lm in &file.meta.layers {
            let nblocks = lm.cols.div_ceil(q.dim());
            let min_row_bytes =
                ((nblocks as u64 * lm.code_bits as u64).div_ceil(8)) as usize;
            if nblocks != lm.blocks_per_row
                || lm.code_bits != code_bits
                || lm.row_bytes < min_row_bytes
            {
                return Err(format!(
                    "{}: code geometry does not match quantizer spec",
                    lm.label()
                ));
            }
        }
        let cfg = file.meta.cfg.clone();
        let tail = file.read_dense()?;
        if tail.tok_emb.len() != cfg.vocab * cfg.d_model
            || tail.lm_head.len() != cfg.vocab * cfg.d_model
        {
            return Err("dense tensor size mismatch".into());
        }
        let slots = LINEAR_KINDS.len();
        let mut ops: Vec<Vec<Option<Box<dyn LinearOp>>>> = (0..cfg.n_layers)
            .map(|_| (0..slots).map(|_| None).collect())
            .collect();
        let file = Arc::new(file);
        // one persistent pool per backend, shared by every op: workers are
        // spawned once at load, not per matmul / per first-touch decode
        let threads = threads.max(1);
        let pool = Arc::new(Pool::new(threads));
        for (idx, lm) in file.meta.layers.iter().enumerate() {
            let (li, ki) = (lm.layer, kind_index(lm.kind));
            let label = lm.label();
            let op: Box<dyn LinearOp> = match kind {
                BackendKind::Cached => Box::new(CachedLayerOp {
                    file: file.clone(),
                    q: q.clone(),
                    idx,
                    rows: lm.rows,
                    cols: lm.cols,
                    pool: pool.clone(),
                    label,
                    dense: OnceLock::new(),
                }),
                BackendKind::Fused => {
                    let pl = file.read_layer(idx)?;
                    Box::new(FusedLayerOp::new(q.clone(), pl, label, pool.clone(), kernel))
                }
                // lint:allow(no-panic-serving): the public constructors
                // route Dense through Weights before reaching this loop
                BackendKind::Dense => unreachable!("dense backends wrap Weights"),
            };
            ops[li][ki] = Some(op);
        }
        let ops: Vec<Vec<Box<dyn LinearOp>>> = ops
            .into_iter()
            // lint:allow(no-panic-serving): the loop above filled every
            // (layer, kind) slot — check_layout validated the artifact
            // lists each one exactly once
            .map(|row| row.into_iter().map(|o| o.unwrap()).collect())
            .collect();
        let lm_head = DenseOp::new(tail.lm_head, cfg.vocab, cfg.d_model, "lm_head");
        Ok(Self {
            cfg,
            kind,
            threads,
            simd: if kind == BackendKind::Fused {
                kernel
            } else {
                Kernel::Scalar
            },
            tok_emb: tail.tok_emb,
            pos_emb: tail.pos_emb,
            norms1: tail.norms1,
            norms2: tail.norms2,
            norm_f: tail.norm_f,
            lm_head,
            ops,
        })
    }

    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    /// Kernel worker threads this backend's pool runs with.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// SIMD kernel the fused ops dispatch to (scalar for dense/cached
    /// backends, which have no fused inner loop).
    pub fn simd(&self) -> Kernel {
        self.simd
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    /// The op serving one linear layer.
    pub fn op(&self, layer: usize, kind: LinearKind) -> &dyn LinearOp {
        self.ops[layer][kind_index(kind)].as_ref()
    }

    /// Bytes of *quantized linear-layer* weight payload currently resident
    /// across all ops (the paper's bits-per-weight figures cover exactly
    /// these parameters; embeddings/norms/LM head are dense fp32 in the
    /// artifact itself and excluded here, as in `.llvqm` code-byte stats).
    pub fn resident_weight_bytes(&self) -> usize {
        self.ops
            .iter()
            .flat_map(|row| row.iter())
            .map(|op| op.resident_bytes())
            .sum()
    }
}

impl ForwardOps for ExecutionBackend {
    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn tok_emb(&self) -> &[f32] {
        &self.tok_emb
    }

    fn pos_emb(&self) -> &[f32] {
        &self.pos_emb
    }

    fn norm1(&self, layer: usize) -> &[f32] {
        &self.norms1[layer]
    }

    fn norm2(&self, layer: usize) -> &[f32] {
        &self.norms2[layer]
    }

    fn norm_f(&self) -> &[f32] {
        &self.norm_f
    }

    fn linear(&self, layer: usize, kind: LinearKind, x: &[f32], y: &mut [f32]) {
        self.ops[layer][kind_index(kind)].matvec(x, y);
    }

    /// Route batched activations through the op's `matmul_into`, so the
    /// fused backend decodes each weight row once per call for the whole
    /// slate (dense/cached ops loop the same matvec — bit-identical either
    /// way).
    fn linear_batch(&self, layer: usize, kind: LinearKind, xs: &[f32], ys: &mut [f32], n: usize) {
        self.ops[layer][kind_index(kind)].matmul_into(xs, ys, n);
    }

    fn lm_head(&self, x: &[f32], y: &mut [f32]) {
        self.lm_head.matvec(x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::config_by_name;
    use crate::model::packed::PackedModel;
    use crate::model::transformer::{forward, ActivationCapture};
    use crate::pipeline::driver::{quantize_model_packed, PtqOptions};
    use crate::quant::scalar::UniformQuantizer;
    use crate::util::proptest::TempArtifact;

    fn artifact_on_disk() -> (crate::pipeline::driver::PtqArtifacts, TempArtifact) {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 33);
        let q = UniformQuantizer::new_gaussian_optimal(4);
        let opts = PtqOptions {
            calib_seqs: 4,
            finetune_scales: true,
            ..Default::default()
        };
        let art = quantize_model_packed(&w, &q, &opts);
        let tmp = TempArtifact::new("backend-test", "llvqm");
        art.packed.save(tmp.path()).unwrap();
        (art, tmp)
    }

    #[test]
    fn dense_backend_matches_weights_bitwise() {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 5);
        let backend = ExecutionBackend::dense(w.clone());
        let toks: Vec<u8> = (0..20).map(|i| (i * 5 % 64) as u8).collect();
        let mut cap = ActivationCapture::default();
        let a = forward(&w, &toks, &mut cap);
        let b = forward(&backend, &toks, &mut cap);
        assert_eq!(a, b);
        assert_eq!(backend.kind(), BackendKind::Dense);
        assert_eq!(
            backend.resident_weight_bytes(),
            cfg.num_linear_params() * 4
        );
    }

    #[test]
    fn cached_backend_is_lazy_then_bit_exact() {
        let (art, tmp) = artifact_on_disk();
        let backend =
            ExecutionBackend::packed_cached(PackedFile::open(tmp.path()).unwrap(), 2).unwrap();
        assert_eq!(backend.threads(), 2);
        // cold: nothing decoded yet
        assert_eq!(backend.resident_weight_bytes(), 0);
        let toks: Vec<u8> = (0..16).map(|i| (i * 3 % 64) as u8).collect();
        let mut cap = ActivationCapture::default();
        let oracle = forward(&art.weights, &toks, &mut cap);
        let got = forward(&backend, &toks, &mut cap);
        assert_eq!(oracle, got, "cached backend must be bit-exact");
        // warm: every layer touched by a forward pass is resident
        assert_eq!(
            backend.resident_weight_bytes(),
            art.packed.linear_params() * 4
        );
    }

    #[test]
    fn fused_backend_close_and_code_resident() {
        let (art, tmp) = artifact_on_disk();
        let backend =
            ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), 1).unwrap();
        // resident = packed code bytes + f64 scales, never the dense f32
        let scale_bytes: usize = art
            .packed
            .layers
            .iter()
            .map(|l| l.col_scales.as_ref().map_or(0, |b| b.len() * 8))
            .sum();
        assert_eq!(
            backend.resident_weight_bytes(),
            art.packed.code_bytes() + scale_bytes
        );
        assert!(backend.resident_weight_bytes() < art.packed.linear_params());
        let toks: Vec<u8> = (0..16).map(|i| (i * 7 % 64) as u8).collect();
        let mut cap = ActivationCapture::default();
        let oracle = forward(&art.weights, &toks, &mut cap);
        let got = forward(&backend, &toks, &mut cap);
        let linf = oracle.iter().fold(0f32, |a, &b| a.max(b.abs()));
        let tol = 1e-5 * linf.max(1.0);
        for (a, b) in oracle.iter().zip(&got) {
            assert!(
                (a - b).abs() <= tol,
                "fused logit drift {} > {tol}",
                (a - b).abs()
            );
        }
    }

    #[test]
    fn fused_matmul_into_is_bitwise_per_lane() {
        // the slate amortization must not change a single output bit vs
        // looping matvec lane by lane
        let (art, tmp) = artifact_on_disk();
        let backend =
            ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), 2).unwrap();
        let cfg = backend.cfg().clone();
        let op = backend.op(0, LinearKind::W1);
        let (d_out, d_in) = op.shape();
        assert_eq!((d_out, d_in), (cfg.d_ff, cfg.d_model));
        let n = 5usize;
        let xs: Vec<f32> = (0..n * d_in).map(|i| ((i * 37 % 101) as f32) * 0.02 - 1.0).collect();
        let mut batched = vec![0f32; n * d_out];
        op.matmul_into(&xs, &mut batched, n);
        let mut solo = vec![0f32; d_out];
        for lane in 0..n {
            op.matvec(&xs[lane * d_in..(lane + 1) * d_in], &mut solo);
            let row = &batched[lane * d_out..(lane + 1) * d_out];
            assert!(
                solo.iter().zip(row).all(|(a, b)| a.to_bits() == b.to_bits()),
                "fused slate lane {lane} diverged from matvec"
            );
        }
        drop(art);
    }

    #[test]
    fn fused_matmul_into_is_thread_count_invariant() {
        // the row-sharded pool kernel must reproduce the sequential kernel
        // bit for bit at every thread count, single lane and slate
        let (_art, tmp) = artifact_on_disk();
        let base =
            ExecutionBackend::packed_fused(PackedFile::open(tmp.path()).unwrap(), 1).unwrap();
        let (d_out, d_in) = base.op(0, LinearKind::W1).shape();
        for n in [1usize, 8] {
            let xs: Vec<f32> = (0..n * d_in)
                .map(|i| ((i * 29 % 97) as f32) * 0.03 - 1.4)
                .collect();
            let mut want = vec![0f32; n * d_out];
            base.op(0, LinearKind::W1).matmul_into(&xs, &mut want, n);
            for threads in [2usize, 4, 8] {
                let par = ExecutionBackend::packed_fused(
                    PackedFile::open(tmp.path()).unwrap(),
                    threads,
                )
                .unwrap();
                assert_eq!(par.threads(), threads);
                let mut got = vec![0f32; n * d_out];
                par.op(0, LinearKind::W1).matmul_into(&xs, &mut got, n);
                assert!(
                    want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "threads={threads} n={n} diverged from the sequential kernel"
                );
            }
        }
    }

    #[test]
    fn forced_kernel_constructor_and_simd_accessor() {
        let (_art, tmp) = artifact_on_disk();
        let b = ExecutionBackend::packed_fused_kernel(
            PackedFile::open(tmp.path()).unwrap(),
            1,
            Kernel::Scalar,
        )
        .unwrap();
        assert_eq!(b.simd(), Kernel::Scalar);
        // dense/cached backends have no fused inner loop → scalar label
        let c = ExecutionBackend::packed_cached(PackedFile::open(tmp.path()).unwrap(), 1).unwrap();
        assert_eq!(c.simd(), Kernel::Scalar);
        // forcing a kernel the host cannot run must error, never fall back
        for k in [Kernel::Avx2, Kernel::Neon, Kernel::Portable] {
            if !k.available() {
                let r = ExecutionBackend::packed_fused_kernel(
                    PackedFile::open(tmp.path()).unwrap(),
                    1,
                    k,
                );
                assert!(r.is_err(), "{k:?} accepted despite being unavailable");
            }
        }
    }

    #[test]
    fn packed_backends_reject_malformed_layouts() {
        let (art, _tmp) = artifact_on_disk();
        // drop one layer from the header → layout check must fail
        let mut packed = art.packed.clone();
        packed.layers.pop();
        let bad = TempArtifact::new("backend-bad", "llvqm");
        packed.save(bad.path()).unwrap();
        // file_len bookkeeping: removing a layer changes section sizes, so
        // parse may fail at meta or at layout — either way it must Err
        let r = PackedFile::open(bad.path())
            .and_then(|f| ExecutionBackend::packed_cached(f, 1));
        assert!(r.is_err());
        // sanity: the untampered artifact still opens
        let ok = TempArtifact::new("backend-ok", "llvqm");
        PackedModel::from_bytes(&art.packed.to_bytes())
            .unwrap()
            .save(ok.path())
            .unwrap();
        assert!(PackedFile::open(ok.path())
            .and_then(|f| ExecutionBackend::packed_fused(f, 2))
            .is_ok());
    }
}
