//! The packed quantized-model format — `artifacts/<name>.llvqm`.
//!
//! This is where the paper's storage claim becomes real: the deployment
//! artifact holds the **bijective lattice indices themselves** as bit
//! streams (paper §3.3, "conversion to and from bitstrings without
//! materializing the codebook"), not dequantized f32 tensors. A 2
//! bits/weight model therefore occupies ≈ bits/32 of its dense `.llvqw`
//! size on disk, plus the fp32 parts the paper also keeps dense
//! (embeddings, norms, LM head).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic "LLVQMDL1"
//! u32   header length
//! JSON  header { config, quantizer spec, per-layer metadata }
//! per layer (header order):
//!     rows × row_bytes   bit-packed code streams (MSB-first, row-aligned)
//!     cols × f64         optional fine-tuned column scales β
//! dense f32 section: tok_emb · pos_emb · per block [norm1, norm2] ·
//!                    norm_f · lm_head
//! ```
//!
//! Per-layer metadata records everything the PTQ driver applied around the
//! quantizer — input scale σ, rotation mode + seed, fine-tuned scales — so
//! [`PackedModel::unpack`] replays the exact same float operations and
//! reproduces the driver's reconstructed weights **bit-exactly**. Rows
//! decode independently (each row stream is byte-aligned), which is what
//! lets the load path fan out over the thread pool.

use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::math::linalg::Matrix;
use crate::model::config::ModelConfig;
use crate::model::io;
use crate::model::transformer::{BlockWeights, LinearKind, Weights, LINEAR_KINDS};
use crate::pipeline::finetune;
use crate::pipeline::rotation::{LayerRotation, RotationMode};
use crate::quant::{product, quantizer_from_spec, Code, PackedCodes, VectorQuantizer};
use crate::util::bits::BitReader;
use crate::util::json::{self, Json};
use crate::util::threadpool;

const MAGIC: &[u8; 8] = b"LLVQMDL1";

/// One quantized linear layer: packed codes plus the reconstruction
/// metadata the PTQ driver applied around them.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedLayer {
    /// Transformer block index.
    pub layer: usize,
    pub kind: LinearKind,
    pub rows: usize,
    pub cols: usize,
    /// Per-layer input scale σ (weights were quantized as w/σ).
    pub sigma: f64,
    pub rot_mode: RotationMode,
    pub rot_seed: u64,
    /// Fine-tuned per-column scales β (paper §5.4), when enabled.
    pub col_scales: Option<Vec<f64>>,
    pub codes: PackedCodes,
}

/// A whole quantized model in packed form: codes for every linear layer,
/// fp32 for everything the paper keeps dense.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedModel {
    pub cfg: ModelConfig,
    /// Quantizer spec header ([`VectorQuantizer::spec`]); the load path
    /// rebuilds the quantizer from this, never from a stored codebook.
    pub quantizer: Json,
    pub layers: Vec<PackedLayer>,
    pub tok_emb: Vec<f32>,
    pub pos_emb: Vec<f32>,
    /// Per-block RMSNorm weights (norm1, norm2).
    pub norms1: Vec<Vec<f32>>,
    pub norms2: Vec<Vec<f32>>,
    pub norm_f: Vec<f32>,
    pub lm_head: Vec<f32>,
}

fn kind_to_str(k: LinearKind) -> &'static str {
    k.label()
}

fn kind_from_str(s: &str) -> Option<LinearKind> {
    LINEAR_KINDS.iter().copied().find(|k| k.label() == s)
}

fn rot_to_str(m: RotationMode) -> &'static str {
    match m {
        RotationMode::None => "none",
        RotationMode::Input => "input",
        RotationMode::InputOutput => "input+output",
    }
}

fn rot_from_str(s: &str) -> Option<RotationMode> {
    match s {
        "none" => Some(RotationMode::None),
        "input" => Some(RotationMode::Input),
        "input+output" => Some(RotationMode::InputOutput),
        _ => None,
    }
}

fn take<'a>(data: &'a [u8], off: &mut usize, n: usize) -> Result<&'a [u8], String> {
    // `data.len() - *off` never underflows (off only advances past checks)
    // and, unlike `*off + n`, cannot overflow on a hostile header's n.
    if n > data.len() - *off {
        return Err(format!("truncated .llvqm at byte {}", *off));
    }
    let s = &data[*off..*off + n];
    *off += n;
    Ok(s)
}

fn take_f32s(data: &[u8], off: &mut usize, n: usize) -> Result<Vec<f32>, String> {
    let bytes = n.checked_mul(4).ok_or("tensor size overflow")?;
    let raw = take(data, off, bytes)?;
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn take_f64s(data: &[u8], off: &mut usize, n: usize) -> Result<Vec<f64>, String> {
    let bytes = n.checked_mul(8).ok_or("tensor size overflow")?;
    let raw = take(data, off, bytes)?;
    Ok(raw
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Header-level description of one quantized layer: everything a
/// [`PackedLayer`] records except the payload itself, plus the absolute
/// byte offsets of its code stream and optional column scales — the
/// random-access handle the lazy/fused execution backends load from.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedLayerMeta {
    pub layer: usize,
    pub kind: LinearKind,
    pub rows: usize,
    pub cols: usize,
    pub sigma: f64,
    pub rot_mode: RotationMode,
    pub rot_seed: u64,
    pub code_bits: u32,
    pub blocks_per_row: usize,
    pub row_bytes: usize,
    pub code_bytes: usize,
    pub has_scales: bool,
    /// Absolute file offset of this layer's bit-packed code stream.
    pub code_off: usize,
    /// Absolute file offset of the f64 column scales (valid iff
    /// `has_scales`).
    pub scales_off: usize,
}

impl PackedLayerMeta {
    /// Display label, e.g. `L2.wo`.
    pub fn label(&self) -> String {
        format!("L{}.{}", self.layer, self.kind.label())
    }
}

/// Everything the `.llvqm` JSON header describes, plus derived section
/// offsets — obtainable via [`PackedModel::load_meta`] without reading a
/// single payload byte. Stats paths and the packed execution backends
/// start here; [`PackedModel::from_bytes`] is built on the same parse, so
/// the two can never disagree about the layout.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedMeta {
    pub cfg: ModelConfig,
    /// Quantizer spec header ([`VectorQuantizer::spec`]).
    pub quantizer: Json,
    pub layers: Vec<PackedLayerMeta>,
    /// Absolute offset of the dense fp32 tail (embeddings, norms, head).
    pub dense_off: usize,
    /// Total file length the header implies (== the real file length for
    /// a well-formed artifact; enforced by [`PackedMeta::parse`]).
    pub file_len: usize,
}

impl PackedMeta {
    /// Total bytes of code payload across layers.
    pub fn code_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.code_bytes).sum()
    }

    /// Exact code bits over the quantized linear parameters.
    pub fn code_bits(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.rows as u64 * l.blocks_per_row as u64 * l.code_bits as u64)
            .sum()
    }

    /// Linear parameters covered by codes.
    pub fn linear_params(&self) -> usize {
        self.layers.iter().map(|l| l.rows * l.cols).sum()
    }

    /// Validate that the layer table covers exactly the config's linear
    /// layers, shapes and block geometry included — what the execution
    /// backends require before trusting per-layer offsets.
    pub fn check_layout(&self) -> Result<(), String> {
        let slots = self.cfg.n_layers * LINEAR_KINDS.len();
        if self.layers.len() != slots {
            return Err(format!(
                "packed model has {} layers, config implies {slots}",
                self.layers.len()
            ));
        }
        let mut seen = vec![false; slots];
        for lm in &self.layers {
            if lm.layer >= self.cfg.n_layers {
                return Err(format!("layer index {} out of range", lm.layer));
            }
            let (rows, cols) = lm.kind.shape(&self.cfg);
            if (rows, cols) != (lm.rows, lm.cols) {
                return Err(format!(
                    "layer {} {:?}: shape {}×{} does not match config {}×{}",
                    lm.layer, lm.kind, lm.rows, lm.cols, rows, cols
                ));
            }
            let kidx = LINEAR_KINDS.iter().position(|k| *k == lm.kind).unwrap();
            let slot = lm.layer * LINEAR_KINDS.len() + kidx;
            if seen[slot] {
                return Err(format!("duplicate layer {} {:?}", lm.layer, lm.kind));
            }
            seen[slot] = true;
        }
        Ok(())
    }

    /// Parse the magic + JSON header (the first `12 + hlen` bytes of
    /// `data`), lay out every section offset, and validate the implied
    /// layout against `total_len` — so offsets handed out here are always
    /// in bounds for a file of that length.
    pub fn parse(data: &[u8], total_len: usize) -> Result<Self, String> {
        if data.len() < 12 || &data[..8] != MAGIC {
            return Err("bad .llvqm magic".into());
        }
        let hlen = u32::from_le_bytes(data[8..12].try_into().unwrap()) as usize;
        if 12 + hlen > data.len() || 12 + hlen > total_len {
            return Err("truncated .llvqm header".into());
        }
        let hdr_text =
            std::str::from_utf8(&data[12..12 + hlen]).map_err(|e| e.to_string())?;
        let hdr = json::parse(hdr_text)?;
        let cfg = io::config_from_header(
            hdr.get("config").ok_or("header missing 'config'")?,
        )?;
        cfg.check()?;
        let quantizer = hdr
            .get("quantizer")
            .ok_or("header missing 'quantizer'")?
            .clone();
        let layer_rows = hdr
            .get("layers")
            .and_then(|v| v.as_arr())
            .ok_or("header missing 'layers' array")?;

        let mut off = 12 + hlen;
        let mut layers = Vec::with_capacity(layer_rows.len());
        for (i, row) in layer_rows.iter().enumerate() {
            let geti = |k: &str| -> Result<i64, String> {
                row.get(k)
                    .and_then(|v| v.as_i64())
                    .ok_or_else(|| format!("layer {i}: missing int '{k}'"))
            };
            // size fields must be non-negative and small enough that no
            // product below can overflow (cfg dims are already ≤ 2^24)
            let getsize = |k: &str| -> Result<usize, String> {
                match geti(k)? {
                    v if (0..=1 << 40).contains(&v) => Ok(v as usize),
                    v => Err(format!("layer {i}: '{k}' = {v} out of range")),
                }
            };
            let kind = row
                .get("kind")
                .and_then(|v| v.as_str())
                .and_then(kind_from_str)
                .ok_or_else(|| format!("layer {i}: missing or unknown kind"))?;
            let rot_mode = row
                .get("rot_mode")
                .and_then(|v| v.as_str())
                .and_then(rot_from_str)
                .ok_or_else(|| format!("layer {i}: missing or unknown rot_mode"))?;
            let sigma = row
                .get("sigma")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("layer {i}: missing sigma"))?;
            let rows = getsize("rows")?;
            let cols = getsize("cols")?;
            let row_bytes = getsize("row_bytes")?;
            let code_bytes = getsize("code_bytes")?;
            if rows.checked_mul(row_bytes) != Some(code_bytes) {
                return Err(format!(
                    "layer {i}: code_bytes {code_bytes} != rows {rows} × row_bytes {row_bytes}"
                ));
            }
            let code_bits = getsize("code_bits")?;
            if code_bits > u32::MAX as usize {
                return Err(format!("layer {i}: code_bits {code_bits} out of range"));
            }
            let has_scales = matches!(row.get("has_scales"), Some(Json::Bool(true)));
            let code_off = off;
            off = off
                .checked_add(code_bytes)
                .ok_or("section offset overflow")?;
            let scales_off = off;
            if has_scales {
                let scale_bytes = cols.checked_mul(8).ok_or("tensor size overflow")?;
                off = off
                    .checked_add(scale_bytes)
                    .ok_or("section offset overflow")?;
            }
            layers.push(PackedLayerMeta {
                layer: getsize("layer")?,
                kind,
                rows,
                cols,
                sigma,
                rot_mode,
                rot_seed: geti("rot_seed")? as u64,
                code_bits: code_bits as u32,
                blocks_per_row: getsize("blocks_per_row")?,
                row_bytes,
                code_bytes,
                has_scales,
                code_off,
                scales_off,
            });
        }

        let dense_off = off;
        let d = cfg.d_model;
        let dense_elems = cfg.vocab * d      // tok_emb
            + cfg.max_seq * d                // pos_emb
            + cfg.n_layers * 2 * d           // norms
            + d                              // final norm
            + cfg.vocab * d; // lm head
        let file_len = dense_off
            .checked_add(dense_elems.checked_mul(4).ok_or("tensor size overflow")?)
            .ok_or("section offset overflow")?;
        if file_len != total_len {
            return Err(format!(
                "file length mismatch: header implies {file_len} B, file has {total_len}"
            ));
        }
        Ok(Self {
            cfg,
            quantizer,
            layers,
            dense_off,
            file_len,
        })
    }
}

/// The fp32 tail of a `.llvqm` file — everything the paper keeps dense.
#[derive(Clone, Debug)]
pub struct DenseTail {
    pub tok_emb: Vec<f32>,
    pub pos_emb: Vec<f32>,
    pub norms1: Vec<Vec<f32>>,
    pub norms2: Vec<Vec<f32>>,
    pub norm_f: Vec<f32>,
    pub lm_head: Vec<f32>,
}

/// Parse the dense fp32 tail starting at `off`; must consume `data`
/// exactly (shared by [`PackedModel::from_bytes`] on the whole file and
/// [`PackedFile::read_dense`] on just the tail).
fn parse_dense_tail(data: &[u8], mut off: usize, cfg: &ModelConfig) -> Result<DenseTail, String> {
    let d = cfg.d_model;
    let tok_emb = take_f32s(data, &mut off, cfg.vocab * d)?;
    let pos_emb = take_f32s(data, &mut off, cfg.max_seq * d)?;
    let mut norms1 = Vec::with_capacity(cfg.n_layers);
    let mut norms2 = Vec::with_capacity(cfg.n_layers);
    for _ in 0..cfg.n_layers {
        norms1.push(take_f32s(data, &mut off, d)?);
        norms2.push(take_f32s(data, &mut off, d)?);
    }
    let norm_f = take_f32s(data, &mut off, d)?;
    let lm_head = take_f32s(data, &mut off, cfg.vocab * d)?;
    if off != data.len() {
        return Err(format!(
            "trailing bytes: consumed {off}, file has {}",
            data.len()
        ));
    }
    Ok(DenseTail {
        tok_emb,
        pos_emb,
        norms1,
        norms2,
        norm_f,
        lm_head,
    })
}

/// Random access into a `.llvqm` file on disk: the parsed header plus a
/// seekable handle, so layers can be read (and decoded) individually on
/// first touch instead of loading the whole artifact up front. Shared
/// behind an `Arc` by the packed execution backends; reads are serialized
/// by a mutex (the seek+read pairs are tiny next to decode cost).
pub struct PackedFile {
    pub meta: PackedMeta,
    path: PathBuf,
    file: Mutex<std::fs::File>,
}

impl PackedFile {
    pub fn open(path: &Path) -> Result<Self, String> {
        let meta = PackedModel::load_meta(path)?;
        let file = std::fs::File::open(path)
            .map_err(|e| format!("open {}: {e}", path.display()))?;
        Ok(Self {
            meta,
            path: path.to_path_buf(),
            file: Mutex::new(file),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn read_at(&self, off: usize, buf: &mut [u8]) -> Result<(), String> {
        // recover from poison: a panicking decode elsewhere can't corrupt
        // a File handle (seek position is re-set on every read)
        let mut f = self.file.lock().unwrap_or_else(|e| e.into_inner());
        f.seek(SeekFrom::Start(off as u64))
            .map_err(|e| format!("seek {}: {e}", self.path.display()))?;
        f.read_exact(buf)
            .map_err(|e| format!("read {}: {e}", self.path.display()))
    }

    /// Load one layer's codes (and column scales) from their recorded
    /// offsets — the only payload I/O a lazy backend pays per layer.
    pub fn read_layer(&self, idx: usize) -> Result<PackedLayer, String> {
        let lm = self
            .meta
            .layers
            .get(idx)
            .ok_or_else(|| format!("layer index {idx} out of range"))?;
        let mut data = vec![0u8; lm.code_bytes];
        self.read_at(lm.code_off, &mut data)?;
        let col_scales = if lm.has_scales {
            let mut raw = vec![0u8; lm.cols * 8];
            self.read_at(lm.scales_off, &mut raw)?;
            Some(
                raw.chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            )
        } else {
            None
        };
        Ok(PackedLayer {
            layer: lm.layer,
            kind: lm.kind,
            rows: lm.rows,
            cols: lm.cols,
            sigma: lm.sigma,
            rot_mode: lm.rot_mode,
            rot_seed: lm.rot_seed,
            col_scales,
            codes: PackedCodes {
                code_bits: lm.code_bits,
                blocks_per_row: lm.blocks_per_row,
                row_bytes: lm.row_bytes,
                data,
            },
        })
    }

    /// Load the dense fp32 tail (embeddings, norms, LM head).
    pub fn read_dense(&self) -> Result<DenseTail, String> {
        let n = self.meta.file_len - self.meta.dense_off;
        let mut buf = vec![0u8; n];
        self.read_at(self.meta.dense_off, &mut buf)?;
        parse_dense_tail(&buf, 0, &self.meta.cfg)
    }
}

impl PackedModel {
    /// Total bytes of code payload (excluding header, scales, and the
    /// dense fp32 section).
    pub fn code_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.codes.data.len()).sum()
    }

    /// Exact code bits over the quantized linear parameters.
    pub fn code_bits(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.rows as u64 * l.codes.blocks_per_row as u64 * l.codes.code_bits as u64)
            .sum()
    }

    /// Linear parameters covered by codes.
    pub fn linear_params(&self) -> usize {
        self.layers.iter().map(|l| l.rows * l.cols).sum()
    }

    /// Serialize to the `.llvqm` byte format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let layer_rows: Vec<Json> = self
            .layers
            .iter()
            .map(|pl| {
                Json::obj(vec![
                    ("layer", Json::Int(pl.layer as i64)),
                    ("kind", Json::Str(kind_to_str(pl.kind).into())),
                    ("rows", Json::Int(pl.rows as i64)),
                    ("cols", Json::Int(pl.cols as i64)),
                    ("sigma", Json::Num(pl.sigma)),
                    ("rot_mode", Json::Str(rot_to_str(pl.rot_mode).into())),
                    ("rot_seed", Json::Int(pl.rot_seed as i64)),
                    ("code_bits", Json::Int(pl.codes.code_bits as i64)),
                    (
                        "blocks_per_row",
                        Json::Int(pl.codes.blocks_per_row as i64),
                    ),
                    ("row_bytes", Json::Int(pl.codes.row_bytes as i64)),
                    ("code_bytes", Json::Int(pl.codes.data.len() as i64)),
                    ("has_scales", Json::Bool(pl.col_scales.is_some())),
                ])
            })
            .collect();
        let hdr = Json::obj(vec![
            ("config", io::header_json(&self.cfg)),
            ("quantizer", self.quantizer.clone()),
            ("layers", Json::Arr(layer_rows)),
        ])
        .to_string_compact();

        let mut buf = Vec::with_capacity(hdr.len() + 64 + self.code_bytes());
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(hdr.len() as u32).to_le_bytes());
        buf.extend_from_slice(hdr.as_bytes());
        for pl in &self.layers {
            buf.extend_from_slice(&pl.codes.data);
            if let Some(beta) = &pl.col_scales {
                for &b in beta {
                    buf.extend_from_slice(&b.to_le_bytes());
                }
            }
        }
        io::push_f32s(&mut buf, &self.tok_emb);
        io::push_f32s(&mut buf, &self.pos_emb);
        for (n1, n2) in self.norms1.iter().zip(&self.norms2) {
            io::push_f32s(&mut buf, n1);
            io::push_f32s(&mut buf, n2);
        }
        io::push_f32s(&mut buf, &self.norm_f);
        io::push_f32s(&mut buf, &self.lm_head);
        buf
    }

    /// Parse the `.llvqm` byte format, validating every section length
    /// (layout via [`PackedMeta::parse`], payloads sliced at its offsets).
    pub fn from_bytes(data: &[u8]) -> Result<Self, String> {
        let meta = PackedMeta::parse(data, data.len())?;
        let mut layers = Vec::with_capacity(meta.layers.len());
        for lm in &meta.layers {
            // in bounds: parse() proved all offsets ≤ file_len == data.len()
            let mut off = lm.code_off;
            let codes = PackedCodes {
                code_bits: lm.code_bits,
                blocks_per_row: lm.blocks_per_row,
                row_bytes: lm.row_bytes,
                data: take(data, &mut off, lm.code_bytes)?.to_vec(),
            };
            let col_scales = if lm.has_scales {
                let mut soff = lm.scales_off;
                Some(take_f64s(data, &mut soff, lm.cols)?)
            } else {
                None
            };
            layers.push(PackedLayer {
                layer: lm.layer,
                kind: lm.kind,
                rows: lm.rows,
                cols: lm.cols,
                sigma: lm.sigma,
                rot_mode: lm.rot_mode,
                rot_seed: lm.rot_seed,
                col_scales,
                codes,
            });
        }
        let tail = parse_dense_tail(data, meta.dense_off, &meta.cfg)?;
        Ok(Self {
            cfg: meta.cfg,
            quantizer: meta.quantizer,
            layers,
            tok_emb: tail.tok_emb,
            pos_emb: tail.pos_emb,
            norms1: tail.norms1,
            norms2: tail.norms2,
            norm_f: tail.norm_f,
            lm_head: tail.lm_head,
        })
    }

    /// Dequantize the whole model back into dense [`Weights`], replaying
    /// the driver's reconstruction (σ scaling → fine-tuned column scales →
    /// inverse rotation) bit-exactly. Rows of each layer decode in
    /// parallel over `threads` workers.
    pub fn unpack(&self, threads: usize) -> Result<Weights, String> {
        let q = quantizer_from_spec(&self.quantizer)?;
        let cfg = &self.cfg;
        if self.layers.len() != cfg.n_layers * LINEAR_KINDS.len() {
            return Err(format!(
                "packed model has {} layers, config implies {}",
                self.layers.len(),
                cfg.n_layers * LINEAR_KINDS.len()
            ));
        }
        let d = cfg.d_model;
        let mut blocks: Vec<BlockWeights> = (0..cfg.n_layers)
            .map(|li| BlockWeights {
                norm1: self.norms1[li].clone(),
                wq: Vec::new(),
                wk: Vec::new(),
                wv: Vec::new(),
                wo: Vec::new(),
                norm2: self.norms2[li].clone(),
                w1: Vec::new(),
                w2: Vec::new(),
            })
            .collect();
        for pl in &self.layers {
            if pl.layer >= cfg.n_layers {
                return Err(format!("layer index {} out of range", pl.layer));
            }
            let (rows, cols) = pl.kind.shape(cfg);
            if (rows, cols) != (pl.rows, pl.cols) {
                return Err(format!(
                    "layer {} {:?}: shape {}×{} does not match config {}×{}",
                    pl.layer, pl.kind, pl.rows, pl.cols, rows, cols
                ));
            }
            let dst = blocks[pl.layer].linear_mut(pl.kind);
            if !dst.is_empty() {
                return Err(format!("duplicate layer {} {:?}", pl.layer, pl.kind));
            }
            *dst = unpack_layer(q.as_ref(), pl, threads)?;
        }
        if self.tok_emb.len() != cfg.vocab * d || self.lm_head.len() != cfg.vocab * d {
            return Err("dense tensor size mismatch".into());
        }
        Ok(Weights {
            cfg: cfg.clone(),
            tok_emb: self.tok_emb.clone(),
            pos_emb: self.pos_emb.clone(),
            blocks,
            norm_f: self.norm_f.clone(),
            lm_head: self.lm_head.clone(),
        })
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(&self.to_bytes())
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        let mut data = Vec::new();
        std::fs::File::open(path)
            .map_err(|e| format!("open {}: {e}", path.display()))?
            .read_to_end(&mut data)
            .map_err(|e| e.to_string())?;
        Self::from_bytes(&data)
    }

    /// Read only the magic + JSON header of a `.llvqm` file — enough for
    /// stats, layout validation, and random-access layer loading — without
    /// touching any payload byte. The CLI `stats` path and the packed
    /// execution backends start here instead of [`PackedModel::load`].
    pub fn load_meta(path: &Path) -> Result<PackedMeta, String> {
        let mut f = std::fs::File::open(path)
            .map_err(|e| format!("open {}: {e}", path.display()))?;
        let total = f.metadata().map_err(|e| e.to_string())?.len();
        if total > usize::MAX as u64 {
            return Err("file too large".into());
        }
        let mut head = [0u8; 12];
        f.read_exact(&mut head)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        if &head[..8] != MAGIC {
            return Err("bad .llvqm magic".into());
        }
        let hlen = u32::from_le_bytes(head[8..12].try_into().unwrap()) as usize;
        if 12 + hlen > total as usize {
            return Err("truncated .llvqm header".into());
        }
        let mut buf = vec![0u8; 12 + hlen];
        buf[..12].copy_from_slice(&head);
        f.read_exact(&mut buf[12..])
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        PackedMeta::parse(&buf, total as usize)
    }
}

/// Validate a packed layer's code geometry against `q`; returns the field
/// widths and row stride the decode loops need.
fn check_layer_geometry(
    q: &dyn VectorQuantizer,
    pl: &PackedLayer,
) -> Result<(Vec<u32>, usize), String> {
    let d = q.dim();
    let nblocks = pl.cols.div_ceil(d);
    if nblocks != pl.codes.blocks_per_row {
        return Err(format!(
            "blocks_per_row {} does not match cols {} / quantizer dim {}",
            pl.codes.blocks_per_row, pl.cols, d
        ));
    }
    let widths = q.code_widths();
    if widths.iter().sum::<u32>() != pl.codes.code_bits {
        return Err(format!(
            "quantizer code width {} != recorded code_bits {}",
            widths.iter().sum::<u32>(),
            pl.codes.code_bits
        ));
    }
    let rb = pl.codes.row_bytes;
    if pl.codes.data.len() != pl.rows * rb
        || rb < ((nblocks as u64 * pl.codes.code_bits as u64).div_ceil(8)) as usize
    {
        return Err("packed payload size mismatch".into());
    }
    Ok((widths, rb))
}

/// Decode one row stream into `out` and apply σ — the per-row float-op
/// sequence shared by every unpack path (scoped threads, worker pool),
/// which is what keeps them bit-identical to each other and to the PTQ
/// driver's reconstruction.
fn decode_row_scaled(
    q: &dyn VectorQuantizer,
    widths: &[u32],
    row_bytes: &[u8],
    sigma: f64,
    code: &mut Code,
    scratch: &mut [f32],
    out: &mut [f32],
) {
    let mut br = BitReader::new(row_bytes);
    product::decode_row_with(q, widths, &mut br, code, scratch, out);
    for v in out.iter_mut() {
        *v = (*v as f64 * sigma) as f32;
    }
}

/// Apply the post-decode reconstruction steps (fine-tuned column scales,
/// inverse incoherence rotation) to a fully-decoded layer.
fn finish_layer(pl: &PackedLayer, flat: &mut [f32]) -> Result<(), String> {
    // fine-tuned column scales (if the driver applied them)
    if let Some(beta) = &pl.col_scales {
        if beta.len() != pl.cols {
            return Err("column scale count mismatch".into());
        }
        finetune::apply_column_scales(flat, pl.cols, beta);
    }
    // undo the incoherence rotation in f64, as the driver did
    let rot = LayerRotation::new(pl.rot_mode, pl.cols, pl.rows, pl.rot_seed);
    let mut rec = Matrix::zeros(pl.rows, pl.cols);
    for (dst, &s) in rec.data.iter_mut().zip(flat.iter()) {
        *dst = s as f64;
    }
    rot.unrotate_weights(&mut rec);
    for (dst, &s) in flat.iter_mut().zip(rec.data.iter()) {
        *dst = s as f32;
    }
    Ok(())
}

/// Dequantize one packed layer to its row-major reconstruction — the same
/// float-op sequence as the PTQ driver, hence bit-exact agreement with the
/// weights it kept for evaluation. Row streams decode block-parallel over
/// scoped threads (for the persistent-pool flavour the serving backends
/// use, see [`unpack_layer_pool`] — the two are bit-identical).
pub fn unpack_layer(
    q: &dyn VectorQuantizer,
    pl: &PackedLayer,
    threads: usize,
) -> Result<Vec<f32>, String> {
    let d = q.dim();
    let (widths, rb) = check_layer_geometry(q, pl)?;
    let rows_out: Vec<Vec<f32>> = threadpool::parallel_map(pl.rows, threads, |r| {
        let mut code = Code::empty();
        let mut scratch = vec![0f32; d];
        let mut out = vec![0f32; pl.cols];
        decode_row_scaled(
            q,
            &widths,
            &pl.codes.data[r * rb..(r + 1) * rb],
            pl.sigma,
            &mut code,
            &mut scratch,
            &mut out,
        );
        out
    });
    let mut flat = vec![0f32; pl.rows * pl.cols];
    for (r, row) in rows_out.iter().enumerate() {
        flat[r * pl.cols..(r + 1) * pl.cols].copy_from_slice(row);
    }
    finish_layer(pl, &mut flat)?;
    Ok(flat)
}

/// Per-worker scratch of the pool decode path (persists across layers on
/// the same pool — the quantizer, and hence `dim`, is fixed per model).
#[derive(Default)]
struct RowDecodeScratch {
    code: Code,
    block: Vec<f32>,
}

/// [`unpack_layer`] over a persistent [`threadpool::Pool`]: rows decode
/// into disjoint shards of the output with no per-call thread spawns —
/// the first-touch path of the cached execution backend. Bit-identical to
/// [`unpack_layer`] (same per-row float ops, any thread count).
pub fn unpack_layer_pool(
    q: &dyn VectorQuantizer,
    pl: &PackedLayer,
    pool: &threadpool::Pool,
) -> Result<Vec<f32>, String> {
    let d = q.dim();
    let (widths, rb) = check_layer_geometry(q, pl)?;
    let mut flat = vec![0f32; pl.rows * pl.cols];
    let shard = threadpool::ShardedSlice::new(&mut flat);
    pool.run_partitioned(pl.rows, |range, scratch| {
        let s = scratch.get_or(RowDecodeScratch::default);
        s.block.clear();
        s.block.resize(d, 0f32);
        for r in range {
            // SAFETY: row ranges are disjoint across shards
            let out = unsafe { shard.range_mut(r * pl.cols..(r + 1) * pl.cols) };
            decode_row_scaled(
                q,
                &widths,
                &pl.codes.data[r * rb..(r + 1) * rb],
                pl.sigma,
                &mut s.code,
                &mut s.block,
                out,
            );
        }
    });
    finish_layer(pl, &mut flat)?;
    Ok(flat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::config_by_name;
    use crate::pipeline::driver::{quantize_model_packed, PtqOptions};
    use crate::quant::scalar::UniformQuantizer;

    fn packed_fixture() -> (crate::pipeline::driver::PtqArtifacts, ModelConfig) {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 21);
        let q = UniformQuantizer::new_gaussian_optimal(4);
        let opts = PtqOptions {
            calib_seqs: 4,
            finetune_scales: true,
            ..Default::default()
        };
        (quantize_model_packed(&w, &q, &opts), cfg)
    }

    #[test]
    fn bytes_roundtrip_and_unpack_is_bit_exact() {
        let (art, cfg) = packed_fixture();
        let bytes = art.packed.to_bytes();
        let back = PackedModel::from_bytes(&bytes).unwrap();
        assert_eq!(back.cfg, cfg);
        assert_eq!(back, art.packed);
        let wq = back.unpack(3).unwrap();
        assert_eq!(wq.tok_emb, art.weights.tok_emb);
        for (a, b) in wq.blocks.iter().zip(&art.weights.blocks) {
            assert_eq!(a.wq, b.wq);
            assert_eq!(a.wk, b.wk);
            assert_eq!(a.wv, b.wv);
            assert_eq!(a.wo, b.wo);
            assert_eq!(a.w1, b.w1);
            assert_eq!(a.w2, b.w2);
            assert_eq!(a.norm1, b.norm1);
            assert_eq!(a.norm2, b.norm2);
        }
        assert_eq!(wq.lm_head, art.weights.lm_head);
        // unpack must be thread-count independent too
        let wq1 = back.unpack(1).unwrap();
        assert_eq!(wq1.blocks[0].wq, wq.blocks[0].wq);
    }

    #[test]
    fn packed_is_much_smaller_than_dense() {
        let (art, _) = packed_fixture();
        let packed_len = art.packed.to_bytes().len();
        let dense_len = crate::model::io::to_bytes(&art.weights).len();
        // 4-bit codes + fp32 dense parts + scales: well under half
        assert!(
            (packed_len as f64) < 0.5 * dense_len as f64,
            "packed {packed_len} vs dense {dense_len}"
        );
    }

    #[test]
    fn load_meta_and_packed_file_match_full_load() {
        let (art, cfg) = packed_fixture();
        let tmp = crate::util::proptest::TempArtifact::new("packedfile-test", "llvqm");
        let path = tmp.path();
        art.packed.save(path).unwrap();
        // header-only meta agrees with the in-memory artifact on every stat
        let meta = PackedModel::load_meta(path).unwrap();
        assert_eq!(meta.cfg, cfg);
        assert_eq!(meta.code_bytes(), art.packed.code_bytes());
        assert_eq!(meta.code_bits(), art.packed.code_bits());
        assert_eq!(meta.linear_params(), art.packed.linear_params());
        assert_eq!(meta.layers.len(), art.packed.layers.len());
        assert_eq!(
            meta.file_len,
            std::fs::metadata(path).unwrap().len() as usize
        );
        meta.check_layout().unwrap();
        // random-access layer reads reproduce the eagerly-loaded payloads
        let f = PackedFile::open(path).unwrap();
        for (i, pl) in art.packed.layers.iter().enumerate() {
            assert_eq!(&f.read_layer(i).unwrap(), pl, "layer {i}");
        }
        let tail = f.read_dense().unwrap();
        assert_eq!(tail.tok_emb, art.packed.tok_emb);
        assert_eq!(tail.pos_emb, art.packed.pos_emb);
        assert_eq!(tail.norms1, art.packed.norms1);
        assert_eq!(tail.norms2, art.packed.norms2);
        assert_eq!(tail.norm_f, art.packed.norm_f);
        assert_eq!(tail.lm_head, art.packed.lm_head);
    }

    #[test]
    fn unpack_layer_pool_matches_scoped_unpack_bitwise() {
        // the persistent-pool first-touch decode is the same per-row float
        // ops as the scoped-thread unpack — pin bit-identity across thread
        // counts
        let (art, _) = packed_fixture();
        let q = quantizer_from_spec(&art.packed.quantizer).unwrap();
        let pool1 = threadpool::Pool::new(1);
        let pool4 = threadpool::Pool::new(4);
        for pl in &art.packed.layers {
            let want = unpack_layer(q.as_ref(), pl, 2).unwrap();
            let got1 = unpack_layer_pool(q.as_ref(), pl, &pool1).unwrap();
            let got4 = unpack_layer_pool(q.as_ref(), pl, &pool4).unwrap();
            assert!(want.iter().zip(&got1).all(|(a, b)| a.to_bits() == b.to_bits()));
            assert!(want.iter().zip(&got4).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn rejects_corruption() {
        let (art, _) = packed_fixture();
        let mut bytes = art.packed.to_bytes();
        assert!(PackedModel::from_bytes(&bytes[..64]).is_err()); // truncated
        let n = bytes.len();
        bytes.truncate(n - 3);
        assert!(PackedModel::from_bytes(&bytes).is_err()); // short dense tail
        let mut bad_magic = art.packed.to_bytes();
        bad_magic[0] = b'X';
        assert!(PackedModel::from_bytes(&bad_magic).is_err());
        let mut trailing = art.packed.to_bytes();
        trailing.extend_from_slice(&[0, 0, 0, 0]);
        assert!(PackedModel::from_bytes(&trailing).is_err());
    }

    #[test]
    fn rejects_hostile_header_without_panicking() {
        // negative size fields in the JSON header must yield Err, not a
        // wrapped-arithmetic panic deep in the section parser
        let (art, _) = packed_fixture();
        let bytes = art.packed.to_bytes();
        let hlen = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        for field in ["\"rows\":", "\"code_bytes\":", "\"d_model\":"] {
            let mut tampered = bytes.clone();
            let hdr = std::str::from_utf8(&tampered[12..12 + hlen]).unwrap();
            let pos = 12 + hdr.find(field).unwrap() + field.len();
            tampered[pos] = b'-'; // first digit → minus sign
            assert!(
                PackedModel::from_bytes(&tampered).is_err(),
                "tampered {field} accepted"
            );
        }
    }
}
