//! Model zoo configuration.
//!
//! The paper evaluates five LLM families (Llama-2 7B, Llama-3 8B,
//! Ministral 8B, Qwen-3 4B/8B). Running 7–8 B-parameter models is out of
//! scope for this testbed (see DESIGN.md substitution ledger), so the zoo
//! holds five *architecturally analogous* tiny decoder-only transformers
//! that differ along the same axes the real families do (depth, width,
//! FFN ratio). Head dim is fixed at 24 — matching the Leech block size, so
//! attention projections quantize without padding (the general padding
//! path is exercised by separate tests and by `qwen3-4b-tiny`'s FFN).

/// Decoder-only transformer hyperparameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count (embeddings + blocks + head).
    pub fn num_params(&self) -> usize {
        let d = self.d_model;
        let attn = 4 * d * d;
        let mlp = 2 * d * self.d_ff;
        let norms = 2 * d;
        self.vocab * d                  // token embedding
            + self.max_seq * d          // positional embedding
            + self.n_layers * (attn + mlp + norms)
            + d                         // final norm
            + self.vocab * d // lm head
    }

    /// Parameters inside quantizable linear layers only (what the paper's
    /// bits-per-weight figures cover; embeddings/norms stay fp16/fp32).
    pub fn num_linear_params(&self) -> usize {
        let d = self.d_model;
        self.n_layers * (4 * d * d + 2 * d * self.d_ff)
    }

    pub fn validate(&self) {
        self.check().unwrap();
    }

    /// Non-panicking validation — the artifact parsers (`model::io`,
    /// `model::packed`) run untrusted headers through this so a corrupt
    /// file yields an `Err`, not an abort. The size ceiling also keeps
    /// every derived `rows × cols × 4` product far from usize overflow.
    pub fn check(&self) -> Result<(), String> {
        const MAX_DIM: usize = 1 << 24;
        let dims = [
            ("vocab", self.vocab),
            ("max_seq", self.max_seq),
            ("d_model", self.d_model),
            ("d_ff", self.d_ff),
            ("n_layers", self.n_layers),
            ("n_heads", self.n_heads),
        ];
        for (name, v) in dims {
            if v == 0 || v > MAX_DIM {
                return Err(format!("config {name}={v} out of range [1, {MAX_DIM}]"));
            }
        }
        if self.vocab < 2 || self.max_seq < 2 {
            return Err("config vocab and max_seq must be > 1".into());
        }
        if self.d_model % self.n_heads != 0 {
            return Err(format!(
                "d_model {} not divisible by n_heads {}",
                self.d_model, self.n_heads
            ));
        }
        Ok(())
    }
}

/// The five tiny analogues used by Tables 3/5/6.
pub fn model_zoo() -> Vec<ModelConfig> {
    vec![
        ModelConfig {
            name: "llama2-tiny".into(),
            vocab: 64,
            d_model: 144,
            n_layers: 3,
            n_heads: 6,
            d_ff: 384,
            max_seq: 64,
        },
        ModelConfig {
            name: "llama3-tiny".into(),
            vocab: 64,
            d_model: 168,
            n_layers: 3,
            n_heads: 7,
            d_ff: 456,
            max_seq: 64,
        },
        ModelConfig {
            name: "ministral-tiny".into(),
            vocab: 64,
            d_model: 144,
            n_layers: 4,
            n_heads: 6,
            d_ff: 384,
            max_seq: 64,
        },
        ModelConfig {
            name: "qwen3-4b-tiny".into(),
            vocab: 64,
            d_model: 120,
            n_layers: 2,
            n_heads: 5,
            d_ff: 308, // deliberately NOT a multiple of 24: exercises padding
            max_seq: 64,
        },
        ModelConfig {
            name: "qwen3-8b-tiny".into(),
            vocab: 64,
            d_model: 168,
            n_layers: 4,
            n_heads: 7,
            d_ff: 432,
            max_seq: 64,
        },
    ]
}

pub fn config_by_name(name: &str) -> Option<ModelConfig> {
    model_zoo().into_iter().find(|c| c.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_is_valid_and_distinct() {
        let zoo = model_zoo();
        assert_eq!(zoo.len(), 5);
        for c in &zoo {
            c.validate();
            assert_eq!(c.head_dim(), 24, "{}: head dim must be 24", c.name);
            assert!(c.num_params() > 100_000, "{} too small", c.name);
        }
        let names: std::collections::HashSet<_> = zoo.iter().map(|c| c.name.clone()).collect();
        assert_eq!(names.len(), 5);
    }

    #[test]
    fn param_counts_consistent() {
        let c = config_by_name("llama2-tiny").unwrap();
        assert!(c.num_linear_params() < c.num_params());
        // llama2-tiny: 3·(4·144² + 2·144·384) = 580 608 linear params
        assert_eq!(c.num_linear_params(), 3 * (4 * 144 * 144 + 2 * 144 * 384));
    }
}
