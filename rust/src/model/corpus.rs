//! Synthetic structured corpus (DESIGN.md substitution for DCLM-edu /
//! Wikitext-2).
//!
//! A seeded second-order Markov chain over a 64-symbol alphabet, overlaid
//! with deterministic *motifs* (fixed 6-token phrases that always complete
//! the same way once their 2-token prefix appears). The motifs make two
//! probe tasks well-defined:
//!
//! * **cloze accuracy** ("MMLU-proxy"): next-token accuracy restricted to
//!   positions inside a motif body, where the continuation is deterministic
//!   given context — a knowledge-recall probe;
//! * **copy/common-sense accuracy** ("CSR-proxy"): top-1 next-token
//!   accuracy over all positions — a broad-coverage probe.
//!
//! The generator is reimplemented identically in `python/compile/corpus.py`
//! for training; cross-language agreement is pinned by a golden prefix
//! test in both suites.

use crate::util::rng::Xoshiro256pp;

pub const VOCAB: usize = 64;
pub const NUM_MOTIFS: usize = 8;
pub const MOTIF_LEN: usize = 6;

/// Deterministic corpus generator.
pub struct Corpus {
    /// Transition logits table [VOCAB × VOCAB] (first-order backbone).
    trans: Vec<u16>, // cumulative distribution rows, fixed-point /65535
    motifs: Vec<[u8; MOTIF_LEN]>,
    rng: Xoshiro256pp,
    /// Probability (per token) of entering a motif, ×2^16.
    motif_p16: u32,
}

impl Corpus {
    pub fn new(seed: u64) -> Self {
        // Build a sparse-ish random Markov backbone deterministically from
        // the seed. Row r: unnormalized weights w_c = 1 + (mix(r,c) % 97)
        // boosted ×24 for 6 "preferred" successors — gives low-entropy,
        // learnable structure.
        // The "language" (Markov table + motifs) is FIXED: all seeds sample
        // the same distribution, so train/calibration/eval streams are i.i.d.
        // draws from one corpus rather than different languages.
        let mut setup = Xoshiro256pp::new(0xC0_FFEE);
        let mut trans = vec![0u16; VOCAB * VOCAB];
        for r in 0..VOCAB {
            let mut w = [0f64; VOCAB];
            for c in 0..VOCAB {
                w[c] = 1.0 + (setup.next_range(97)) as f64;
            }
            for _ in 0..6 {
                w[setup.next_range(VOCAB as u64) as usize] *= 24.0;
            }
            let total: f64 = w.iter().sum();
            let mut acc = 0.0;
            for c in 0..VOCAB {
                acc += w[c];
                trans[r * VOCAB + c] = ((acc / total) * 65535.0) as u16;
            }
            trans[r * VOCAB + VOCAB - 1] = 65535;
        }
        let mut motifs = Vec::with_capacity(NUM_MOTIFS);
        for _ in 0..NUM_MOTIFS {
            let mut m = [0u8; MOTIF_LEN];
            for v in m.iter_mut() {
                *v = setup.next_range(VOCAB as u64) as u8;
            }
            motifs.push(m);
        }
        Self {
            trans,
            motifs,
            rng: Xoshiro256pp::new(seed),
            motif_p16: (0.08 * 65536.0) as u32,
        }
    }

    /// Generate `n` tokens, also returning a mask of positions whose value
    /// is deterministic given context (inside a motif body, offset ≥ 2).
    pub fn generate(&mut self, n: usize) -> (Vec<u8>, Vec<bool>) {
        let mut out = Vec::with_capacity(n);
        let mut det = Vec::with_capacity(n);
        let mut prev = 0u8;
        while out.len() < n {
            if ((self.rng.next_u64() & 0xFFFF) as u32) < self.motif_p16 {
                // emit a full motif
                let m = self.motifs[self.rng.next_range(NUM_MOTIFS as u64) as usize];
                for (k, &t) in m.iter().enumerate() {
                    if out.len() >= n {
                        break;
                    }
                    out.push(t);
                    det.push(k >= 2); // body is deterministic after 2-prefix
                    prev = t;
                }
            } else {
                // markov step
                let u = (self.rng.next_u64() & 0xFFFF) as u16;
                let row = &self.trans[prev as usize * VOCAB..(prev as usize + 1) * VOCAB];
                // first bucket whose cumulative weight reaches u
                // (bisect_left — matches python/compile/corpus.py exactly)
                let c = row.partition_point(|&x| x < u).min(VOCAB - 1);
                out.push(c as u8);
                det.push(false);
                prev = c as u8;
            }
        }
        (out, det)
    }

    /// Convenience: `count` sequences of length `seq_len` (+1 for targets).
    pub fn sequences(&mut self, count: usize, seq_len: usize) -> Vec<(Vec<u8>, Vec<bool>)> {
        (0..count)
            .map(|_| {
                let (t, d) = self.generate(seq_len + 1);
                (t, d)
            })
            .collect()
    }

    pub fn motifs(&self) -> &[[u8; MOTIF_LEN]] {
        &self.motifs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_prefix_cross_language() {
        // pinned in python/tests/test_corpus.py as GOLDEN_1234
        let mut c = Corpus::new(1234);
        let (t, _) = c.generate(12);
        assert_eq!(t, [58, 7, 5, 18, 19, 22, 32, 43, 37, 28, 52, 21]);
    }

    #[test]
    fn all_seeds_share_one_language() {
        // the transition table is seed-independent
        let a = Corpus::new(1);
        let b = Corpus::new(999);
        assert_eq!(a.trans, b.trans);
        assert_eq!(a.motifs, b.motifs);
    }

    #[test]
    fn deterministic_and_in_vocab() {
        let mut a = Corpus::new(1234);
        let mut b = Corpus::new(1234);
        let (ta, _) = a.generate(5000);
        let (tb, _) = b.generate(5000);
        assert_eq!(ta, tb);
        assert!(ta.iter().all(|&t| (t as usize) < VOCAB));
        let mut c = Corpus::new(99);
        let (tc, _) = c.generate(5000);
        assert_ne!(ta, tc);
    }

    #[test]
    fn motif_positions_are_deterministic() {
        let mut g = Corpus::new(7);
        let (toks, det) = g.generate(200_000);
        let motifs = g.motifs().to_vec();
        let frac = det.iter().filter(|&&d| d).count() as f64 / det.len() as f64;
        assert!(frac > 0.02 && frac < 0.35, "det fraction {frac}");
        // every deterministic position must indeed extend some motif prefix
        for i in 0..toks.len() {
            if det[i] {
                let ok = motifs.iter().any(|m| {
                    (2..MOTIF_LEN).any(|k| {
                        i >= k
                            && toks[i - k..=i]
                                .iter()
                                .zip(m[..=k].iter())
                                .all(|(a, b)| a == b)
                    })
                });
                assert!(ok, "position {i} marked deterministic but no motif matches");
            }
        }
    }

    #[test]
    fn corpus_has_learnable_structure() {
        // backbone bigram entropy must be clearly below uniform (6 bits)
        let mut g = Corpus::new(5);
        let (toks, _) = g.generate(300_000);
        let mut counts = vec![0u32; VOCAB * VOCAB];
        for w in toks.windows(2) {
            counts[w[0] as usize * VOCAB + w[1] as usize] += 1;
        }
        let mut h = 0.0f64;
        let mut row_tot = vec![0u32; VOCAB];
        for r in 0..VOCAB {
            row_tot[r] = (0..VOCAB).map(|c| counts[r * VOCAB + c]).sum();
        }
        let total: u32 = row_tot.iter().sum();
        for r in 0..VOCAB {
            if row_tot[r] == 0 {
                continue;
            }
            let pr = row_tot[r] as f64 / total as f64;
            let mut hr = 0.0;
            for c in 0..VOCAB {
                let n = counts[r * VOCAB + c];
                if n > 0 {
                    let p = n as f64 / row_tot[r] as f64;
                    hr -= p * p.log2();
                }
            }
            h += pr * hr;
        }
        assert!(h < 5.3, "conditional entropy {h} too close to uniform");
        assert!(h > 2.0, "degenerate corpus, entropy {h}");
    }
}
