//! Decoder-only transformer forward pass (Rust-native f32 oracle).
//!
//! This is the evaluation substrate for the PTQ experiments: the same
//! architecture is trained in JAX (`python/compile/train.py`), its weights
//! load here, and the quantization pipeline swaps individual linear-layer
//! weights while this module measures perplexity / probe accuracy. It also
//! exposes *activation capture* for Hessian calibration (layer inputs X
//! feed `pipeline::hessian`).
//!
//! Architecture (kept deliberately mirror-friendly with the JAX side):
//! token embedding + learned positional embedding → N × [RMSNorm →
//! causal MHA (head dim 24) → residual → RMSNorm → MLP (SiLU) → residual]
//! → final RMSNorm → LM head.
//!
//! ## Incremental decoding
//!
//! Generation sessions run through a [`KvCache`]: [`prefill`] appends a
//! token run and returns last-position logits ([`prefill_chunked`] does
//! the same in bounded resumable chunks — the serving scheduler's
//! pipelined-prefill unit), [`forward_step`] /
//! [`forward_step_batch`] append one token (per lane) and return its
//! logits. Both paths execute the exact float-op sequence of the full
//! [`forward`] pass — `forward` itself is implemented over a scratch
//! cache — so N cached decode steps are **bit-identical** to re-running
//! the growing prefix through `forward`, on dense weights and on every
//! execution backend. Linear layers go through [`ForwardOps::linear_batch`]
//! so backends may amortize per-row work across positions / batch lanes
//! (the fused code-stream backend decodes each weight row once per step
//! for the whole slate).

use crate::model::config::ModelConfig;

/// Which linear layers exist per block (the quantization targets).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinearKind {
    Wq,
    Wk,
    Wv,
    Wo,
    W1,
    W2,
}

pub const LINEAR_KINDS: [LinearKind; 6] = [
    LinearKind::Wq,
    LinearKind::Wk,
    LinearKind::Wv,
    LinearKind::Wo,
    LinearKind::W1,
    LinearKind::W2,
];

impl LinearKind {
    pub fn label(&self) -> &'static str {
        match self {
            LinearKind::Wq => "wq",
            LinearKind::Wk => "wk",
            LinearKind::Wv => "wv",
            LinearKind::Wo => "wo",
            LinearKind::W1 => "w1",
            LinearKind::W2 => "w2",
        }
    }

    /// (rows, cols) = (d_out, d_in) for this layer under `cfg`.
    pub fn shape(&self, cfg: &ModelConfig) -> (usize, usize) {
        let d = cfg.d_model;
        match self {
            LinearKind::Wq | LinearKind::Wk | LinearKind::Wv | LinearKind::Wo => (d, d),
            LinearKind::W1 => (cfg.d_ff, d),
            LinearKind::W2 => (d, cfg.d_ff),
        }
    }
}

/// One transformer block's weights (row-major `(d_out × d_in)` matrices).
#[derive(Clone, Debug)]
pub struct BlockWeights {
    pub norm1: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub norm2: Vec<f32>,
    pub w1: Vec<f32>,
    pub w2: Vec<f32>,
}

impl BlockWeights {
    pub fn linear(&self, k: LinearKind) -> &Vec<f32> {
        match k {
            LinearKind::Wq => &self.wq,
            LinearKind::Wk => &self.wk,
            LinearKind::Wv => &self.wv,
            LinearKind::Wo => &self.wo,
            LinearKind::W1 => &self.w1,
            LinearKind::W2 => &self.w2,
        }
    }

    pub fn linear_mut(&mut self, k: LinearKind) -> &mut Vec<f32> {
        match k {
            LinearKind::Wq => &mut self.wq,
            LinearKind::Wk => &mut self.wk,
            LinearKind::Wv => &mut self.wv,
            LinearKind::Wo => &mut self.wo,
            LinearKind::W1 => &mut self.w1,
            LinearKind::W2 => &mut self.w2,
        }
    }
}

/// Full model weights.
#[derive(Clone, Debug)]
pub struct Weights {
    pub cfg: ModelConfig,
    pub tok_emb: Vec<f32>,  // vocab × d
    pub pos_emb: Vec<f32>,  // max_seq × d
    pub blocks: Vec<BlockWeights>,
    pub norm_f: Vec<f32>,   // d
    pub lm_head: Vec<f32>,  // vocab × d
}

impl Weights {
    /// Random initialization (for tests and the untrained baseline).
    pub fn random(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = crate::util::rng::Xoshiro256pp::new(seed);
        let d = cfg.d_model;
        let mut mk = |n: usize, scale: f64| -> Vec<f32> {
            (0..n).map(|_| (rng.next_gaussian() * scale) as f32).collect()
        };
        let s_attn = 1.0 / (d as f64).sqrt();
        let s_mlp = 1.0 / (cfg.d_ff as f64).sqrt();
        let blocks = (0..cfg.n_layers)
            .map(|_| BlockWeights {
                norm1: vec![1.0; d],
                wq: mk(d * d, s_attn),
                wk: mk(d * d, s_attn),
                wv: mk(d * d, s_attn),
                wo: mk(d * d, s_attn),
                norm2: vec![1.0; d],
                w1: mk(cfg.d_ff * d, s_attn),
                w2: mk(d * cfg.d_ff, s_mlp),
            })
            .collect();
        Self {
            cfg: cfg.clone(),
            tok_emb: mk(cfg.vocab * d, 0.05),
            pos_emb: mk(cfg.max_seq * d, 0.05),
            blocks,
            norm_f: vec![1.0; d],
            lm_head: mk(cfg.vocab * d, s_attn),
        }
    }
}

/// Captured layer inputs during a forward pass, keyed (layer, kind).
/// Row-major token activations; feeds the Hessian accumulator.
#[derive(Default)]
pub struct ActivationCapture {
    pub store: std::collections::HashMap<(usize, LinearKind), Vec<f32>>,
    pub enabled: bool,
}

impl ActivationCapture {
    pub fn enabled() -> Self {
        Self {
            enabled: true,
            ..Default::default()
        }
    }

    fn record(&mut self, layer: usize, kind: LinearKind, x: &[f32]) {
        if self.enabled {
            self.store
                .entry((layer, kind))
                .or_default()
                .extend_from_slice(x);
        }
    }
}

fn rmsnorm(x: &mut [f32], gamma: &[f32]) {
    let d = x.len();
    let ms: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    for (xi, g) in x.iter_mut().zip(gamma) {
        *xi = ((*xi as f64) * inv) as f32 * g;
    }
}

/// y = W·x for row-major W (d_out × d_in) — the dense matvec kernel shared
/// by the Weights fast path and `model::backend::DenseOp` (keeping the two
/// bit-identical).
pub(crate) fn linear(w: &[f32], d_out: usize, d_in: usize, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(w.len(), d_out * d_in);
    for (o, yo) in y.iter_mut().enumerate().take(d_out) {
        let row = &w[o * d_in..(o + 1) * d_in];
        let mut acc = 0f32;
        for (ri, xi) in row.iter().zip(x) {
            acc += ri * xi;
        }
        *yo = acc;
    }
}

#[inline]
fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// What [`forward`] needs from a model representation: the dense fp32
/// parts (embeddings, norms) by reference, plus every linear layer as an
/// *operation* `y = W·x` rather than a materialized matrix. [`Weights`]
/// implements it directly (the f32 oracle); `model::backend::
/// ExecutionBackend` implements it over [`model::backend::LinearOp`]s so
/// the same forward pass runs on dense, lazily-decoded, or fused
/// bit-packed representations.
///
/// `Sync` is a supertrait because evaluation fans sequences out over the
/// thread pool.
pub trait ForwardOps: Sync {
    fn cfg(&self) -> &ModelConfig;
    fn tok_emb(&self) -> &[f32];
    fn pos_emb(&self) -> &[f32];
    fn norm1(&self, layer: usize) -> &[f32];
    fn norm2(&self, layer: usize) -> &[f32];
    fn norm_f(&self) -> &[f32];
    /// `y = W_{layer,kind} · x`.
    fn linear(&self, layer: usize, kind: LinearKind, x: &[f32], y: &mut [f32]);
    /// Apply `W_{layer,kind}` to `n` row-major activation vectors at once.
    /// The default loops [`ForwardOps::linear`], so results are
    /// bit-identical to the per-vector path; backends whose ops amortize
    /// per-row work across vectors (the fused code-stream matvec) override
    /// this with an equally bit-stable batched kernel.
    fn linear_batch(&self, layer: usize, kind: LinearKind, xs: &[f32], ys: &mut [f32], n: usize) {
        let (d_out, d_in) = kind.shape(self.cfg());
        debug_assert_eq!(xs.len(), n * d_in);
        debug_assert_eq!(ys.len(), n * d_out);
        for (x, y) in xs.chunks_exact(d_in).zip(ys.chunks_exact_mut(d_out)) {
            self.linear(layer, kind, x, y);
        }
    }
    /// `y = W_head · x` (vocab × d_model).
    fn lm_head(&self, x: &[f32], y: &mut [f32]);
}

impl ForwardOps for Weights {
    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn tok_emb(&self) -> &[f32] {
        &self.tok_emb
    }

    fn pos_emb(&self) -> &[f32] {
        &self.pos_emb
    }

    fn norm1(&self, layer: usize) -> &[f32] {
        &self.blocks[layer].norm1
    }

    fn norm2(&self, layer: usize) -> &[f32] {
        &self.blocks[layer].norm2
    }

    fn norm_f(&self) -> &[f32] {
        &self.norm_f
    }

    fn linear(&self, layer: usize, kind: LinearKind, x: &[f32], y: &mut [f32]) {
        let (rows, cols) = kind.shape(&self.cfg);
        linear(self.blocks[layer].linear(kind), rows, cols, x, y);
    }

    fn lm_head(&self, x: &[f32], y: &mut [f32]) {
        linear(&self.lm_head, self.cfg.vocab, self.cfg.d_model, x, y);
    }
}

/// Per-layer K/V buffers backing a generation session: `n_layers ×
/// max_seq × d_model` each, with `len` tokens appended so far. The cache
/// is pure storage — it carries no weights, so one engine serves any
/// number of concurrent sessions, each with its own cache.
#[derive(Clone, Debug)]
pub struct KvCache {
    n_layers: usize,
    d_model: usize,
    max_seq: usize,
    len: usize,
    /// `[layer][pos][d]`, row-major.
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    /// A full-capacity session cache (up to the model's `max_seq`).
    pub fn new(cfg: &ModelConfig) -> Self {
        Self::with_capacity(cfg, cfg.max_seq)
    }

    /// A cache bounded to `capacity` tokens — [`forward`] uses this for
    /// its scratch cache so a short one-shot request allocates `s × d`
    /// K/V per layer, not `max_seq × d`.
    pub fn with_capacity(cfg: &ModelConfig, capacity: usize) -> Self {
        assert!(
            capacity >= 1 && capacity <= cfg.max_seq,
            "KvCache capacity {capacity} outside [1, max_seq {}]",
            cfg.max_seq
        );
        let sz = cfg.n_layers * capacity * cfg.d_model;
        Self {
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            max_seq: capacity,
            len: 0,
            k: vec![0f32; sz],
            v: vec![0f32; sz],
        }
    }

    /// Tokens appended so far (the next token lands at this position).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum tokens this cache can hold (`max_seq` for session caches).
    pub fn capacity(&self) -> usize {
        self.max_seq
    }

    /// Positions still free.
    pub fn remaining(&self) -> usize {
        self.max_seq - self.len
    }

    /// Reset to an empty session without reallocating.
    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Err when appending `n` more tokens would overflow this cache — the
    /// capacity check every append path shares ([`run_blocks`] panics on
    /// it; the serving scheduler calls it up front so an oversized FEED is
    /// a clean protocol error instead of a worker panic).
    pub fn check_append(&self, n: usize) -> Result<(), String> {
        if self.len + n <= self.max_seq {
            Ok(())
        } else {
            Err(format!(
                "sequence of {n} tokens at position {} exceeds cache capacity {}",
                self.len, self.max_seq
            ))
        }
    }

    fn layer_offset(&self, li: usize) -> usize {
        li * self.max_seq * self.d_model
    }

    fn check_model(&self, cfg: &ModelConfig) {
        assert!(
            self.n_layers == cfg.n_layers
                && self.d_model == cfg.d_model
                && self.max_seq <= cfg.max_seq,
            "KvCache shape does not match model config"
        );
    }
}

/// The session-cache surface every transformer entry point runs over:
/// the dense [`KvCache`] slab and the paged
/// [`PagedKvCache`](crate::model::kvpage::PagedKvCache) both implement
/// it, so [`prefill`] / [`forward_step`] / [`forward_step_batch`] are
/// storage-agnostic. `Send` is a supertrait because the serving
/// coordinator moves boxed session caches into its worker thread.
///
/// The append contract mirrors [`run_blocks`]' historical in-place
/// sequence exactly: per layer, [`KvStore::append_layer`] stores the
/// run's new K/V rows and hands the *whole contiguous prefix* (positions
/// `0..len()+s`) to the callback for attention, and a final
/// [`KvStore::commit`] advances `len` once every layer has appended.
/// Implementations must reproduce stored f32 rows bit-exactly for
/// unquantized storage — that is what keeps paged sessions bit-identical
/// to dense ones.
pub trait KvStore: Send {
    /// Tokens appended so far (the next token lands at this position).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Maximum tokens this cache can hold.
    fn capacity(&self) -> usize;

    /// Err when appending `n` more tokens would overflow [`KvStore::capacity`].
    fn check_append(&self, n: usize) -> Result<(), String>;

    /// Ensure backing storage exists for `n` more tokens: the capacity
    /// check plus (for paged caches) eager page allocation against the
    /// shared arena budget — a refusal is a `kv-oom:`-prefixed error and
    /// leaves the cache unchanged. The serving scheduler calls this at
    /// admission so budget exhaustion is a clean protocol error, not a
    /// worker panic.
    fn reserve(&mut self, n: usize) -> Result<(), String>;

    /// Panic if this cache was built for a different model shape.
    fn check_model(&self, cfg: &ModelConfig);

    /// Append layer `li`'s K/V rows for the run's new positions
    /// (`k_new`/`v_new` are `s × d_model`, positions `len()..len()+s`),
    /// then call `attend_fn(kc, vc)` with contiguous row-major K/V
    /// covering positions `0..len()+s` of that layer.
    fn append_layer(
        &mut self,
        li: usize,
        k_new: &[f32],
        v_new: &[f32],
        attend_fn: &mut dyn FnMut(&[f32], &[f32]),
    );

    /// Finish a run of [`KvStore::append_layer`] calls: advance `len` by
    /// `s`. Paged caches also quantize pages that fell behind the hot
    /// window here — strictly between forward passes, never mid-pass.
    fn commit(&mut self, s: usize);
}

impl KvStore for KvCache {
    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.max_seq
    }

    fn check_append(&self, n: usize) -> Result<(), String> {
        KvCache::check_append(self, n)
    }

    fn reserve(&mut self, n: usize) -> Result<(), String> {
        // dense storage is preallocated at worst case: reserving is just
        // the capacity check
        KvCache::check_append(self, n)
    }

    fn check_model(&self, cfg: &ModelConfig) {
        KvCache::check_model(self, cfg);
    }

    fn append_layer(
        &mut self,
        li: usize,
        k_new: &[f32],
        v_new: &[f32],
        attend_fn: &mut dyn FnMut(&[f32], &[f32]),
    ) {
        let d = self.d_model;
        debug_assert_eq!(k_new.len() % d, 0);
        let s = k_new.len() / d;
        let base = self.len;
        let lo = self.layer_offset(li);
        self.k[lo + base * d..lo + (base + s) * d].copy_from_slice(k_new);
        self.v[lo + base * d..lo + (base + s) * d].copy_from_slice(v_new);
        attend_fn(
            &self.k[lo..lo + (base + s) * d],
            &self.v[lo..lo + (base + s) * d],
        );
    }

    fn commit(&mut self, s: usize) {
        self.len += s;
    }
}

/// Causal attention for one query position over cached K/V (`kc`/`vc` hold
/// positions `0..=pos` of one layer, row-major `pos × d`). `out` receives
/// the concatenated head outputs; `scores` is reusable scratch. The float
/// ops replay the historical full-forward attention loop exactly.
#[allow(clippy::too_many_arguments)]
fn attend(
    kc: &[f32],
    vc: &[f32],
    pos: usize,
    d: usize,
    hd: usize,
    nh: usize,
    qt_row: &[f32],
    out: &mut [f32],
    scores: &mut Vec<f32>,
) {
    let scale = 1.0 / (hd as f32).sqrt();
    out.iter_mut().for_each(|x| *x = 0.0);
    for head in 0..nh {
        let off = head * hd;
        scores.clear();
        scores.resize(pos + 1, 0f32);
        let qt = &qt_row[off..off + hd];
        let mut maxs = f32::NEG_INFINITY;
        for u in 0..=pos {
            let ku = &kc[u * d + off..u * d + off + hd];
            let mut sdot = 0f32;
            for (qi, ki) in qt.iter().zip(ku) {
                sdot += qi * ki;
            }
            scores[u] = sdot * scale;
            maxs = maxs.max(scores[u]);
        }
        let mut z = 0f32;
        for sc in scores.iter_mut() {
            *sc = (*sc - maxs).exp();
            z += *sc;
        }
        let zi = 1.0 / z;
        for u in 0..=pos {
            let p = scores[u] * zi;
            let vu = &vc[u * d + off..u * d + off + hd];
            for i in 0..hd {
                out[off + i] += p * vu[i];
            }
        }
    }
}

/// Run `tokens` through every transformer block, appending their K/V to
/// `cache` and returning the new positions' final hidden states (`s × d`,
/// pre-final-norm). Shared by [`forward`] (fresh cache, all logits) and
/// [`prefill`] (session cache, last logits) so the two can never diverge.
fn run_blocks<M: ForwardOps + ?Sized, C: KvStore + ?Sized>(
    m: &M,
    cache: &mut C,
    tokens: &[u8],
    capture: &mut ActivationCapture,
) -> Vec<f32> {
    let cfg = m.cfg();
    let (s, d) = (tokens.len(), cfg.d_model);
    let base = cache.len();
    assert!(s > 0, "empty token sequence");
    if let Err(e) = cache.reserve(s) {
        panic!("{e}");
    }
    cache.check_model(cfg);
    let hd = cfg.head_dim();
    let nh = cfg.n_heads;

    // embeddings (token ids are validated here so a bad id is a clean
    // panic with a message, not an out-of-bounds index in tok_emb)
    let (tok_emb, pos_emb) = (m.tok_emb(), m.pos_emb());
    let mut h = vec![0f32; s * d];
    for (t, &tk) in tokens.iter().enumerate() {
        let tok = tk as usize;
        assert!(tok < cfg.vocab, "token id {tok} >= vocab {}", cfg.vocab);
        let p = base + t;
        for i in 0..d {
            h[t * d + i] = tok_emb[tok * d + i] + pos_emb[p * d + i];
        }
    }

    let mut xs = vec![0f32; s * d];
    let mut q = vec![0f32; s * d];
    let mut k = vec![0f32; s * d];
    let mut v = vec![0f32; s * d];
    let mut attn_out = vec![0f32; s * d];
    let mut ff = vec![0f32; s * cfg.d_ff];
    let mut out = vec![0f32; s * d];
    let mut scores: Vec<f32> = Vec::new();

    for li in 0..cfg.n_layers {
        // --- attention ---
        for t in 0..s {
            let normed = &mut xs[t * d..(t + 1) * d];
            normed.copy_from_slice(&h[t * d..(t + 1) * d]);
            rmsnorm(normed, m.norm1(li));
            capture.record(li, LinearKind::Wq, normed);
            capture.record(li, LinearKind::Wk, normed);
            capture.record(li, LinearKind::Wv, normed);
        }
        m.linear_batch(li, LinearKind::Wq, &xs, &mut q, s);
        m.linear_batch(li, LinearKind::Wk, &xs, &mut k, s);
        m.linear_batch(li, LinearKind::Wv, &xs, &mut v, s);
        // append this run's K/V, then attend over the whole prefix
        {
            let (q_ref, ao, sc) = (&q, &mut attn_out, &mut scores);
            cache.append_layer(li, &k, &v, &mut |kc, vc| {
                for t in 0..s {
                    attend(
                        kc,
                        vc,
                        base + t,
                        d,
                        hd,
                        nh,
                        &q_ref[t * d..(t + 1) * d],
                        &mut ao[t * d..(t + 1) * d],
                        sc,
                    );
                }
            });
        }
        for t in 0..s {
            capture.record(li, LinearKind::Wo, &attn_out[t * d..(t + 1) * d]);
        }
        m.linear_batch(li, LinearKind::Wo, &attn_out, &mut out, s);
        for (hi, &o) in h.iter_mut().zip(out.iter()) {
            *hi += o;
        }
        // --- MLP ---
        for t in 0..s {
            let normed = &mut xs[t * d..(t + 1) * d];
            normed.copy_from_slice(&h[t * d..(t + 1) * d]);
            rmsnorm(normed, m.norm2(li));
            capture.record(li, LinearKind::W1, normed);
        }
        m.linear_batch(li, LinearKind::W1, &xs, &mut ff, s);
        for x in ff.iter_mut() {
            *x = silu(*x);
        }
        for t in 0..s {
            capture.record(li, LinearKind::W2, &ff[t * cfg.d_ff..(t + 1) * cfg.d_ff]);
        }
        m.linear_batch(li, LinearKind::W2, &ff, &mut out, s);
        for (hi, &o) in h.iter_mut().zip(out.iter()) {
            *hi += o;
        }
    }
    cache.commit(s);
    h
}

/// Run the model on a token sequence, returning per-position logits
/// (seq × vocab, row-major). Optionally captures linear-layer inputs.
/// Generic over [`ForwardOps`], so the same pass serves dense [`Weights`]
/// and every packed execution backend. Implemented over a scratch
/// [`KvCache`], so it is the bit-exact oracle for the incremental
/// [`prefill`] / [`forward_step`] path by construction.
pub fn forward<M: ForwardOps + ?Sized>(
    m: &M,
    tokens: &[u8],
    capture: &mut ActivationCapture,
) -> Vec<f32> {
    let cfg = m.cfg();
    assert!(tokens.len() <= cfg.max_seq);
    // scratch cache sized to the request, not to max_seq — a short NEXT
    // allocates (and zeroes) only s×d K/V per layer
    let mut cache = KvCache::with_capacity(cfg, tokens.len().max(1));
    let h = run_blocks(m, &mut cache, tokens, capture);
    let (s, d) = (tokens.len(), cfg.d_model);
    let mut normed = vec![0f32; d];
    let mut logits = vec![0f32; s * cfg.vocab];
    for t in 0..s {
        normed.copy_from_slice(&h[t * d..(t + 1) * d]);
        rmsnorm(&mut normed, m.norm_f());
        m.lm_head(&normed, &mut logits[t * cfg.vocab..(t + 1) * cfg.vocab]);
    }
    logits
}

/// Append `tokens` to a generation session, returning the logits at the
/// last appended position (vocab-sized) — bit-identical to the last row
/// of [`forward`] over the session's whole token history.
pub fn prefill<M: ForwardOps + ?Sized, C: KvStore + ?Sized>(
    m: &M,
    cache: &mut C,
    tokens: &[u8],
) -> Vec<f32> {
    let cfg = m.cfg();
    let mut cap = ActivationCapture::default();
    let h = run_blocks(m, cache, tokens, &mut cap);
    let (s, d) = (tokens.len(), cfg.d_model);
    let mut normed = vec![0f32; d];
    normed.copy_from_slice(&h[(s - 1) * d..s * d]);
    rmsnorm(&mut normed, m.norm_f());
    let mut logits = vec![0f32; cfg.vocab];
    m.lm_head(&normed, &mut logits);
    logits
}

/// Resumable chunked prefill: append `tokens` through repeated [`prefill`]
/// calls of at most `chunk` tokens each, returning the logits at the last
/// position. Because `prefill` is incremental by construction (every chunk
/// replays the same [`run_blocks`] float-op sequence at the same
/// positions), this is **bit-identical** to one-shot `prefill` for every
/// chunk size — the property the coordinator's pipelined prefill scheduler
/// rests on, pinned across quantizer specs and thread counts by proptests
/// in `rust/tests/generation.rs`.
pub fn prefill_chunked<M: ForwardOps + ?Sized, C: KvStore + ?Sized>(
    m: &M,
    cache: &mut C,
    tokens: &[u8],
    chunk: usize,
) -> Vec<f32> {
    assert!(!tokens.is_empty(), "empty token sequence");
    let chunk = chunk.max(1);
    let mut logits = Vec::new();
    for c in tokens.chunks(chunk) {
        logits = prefill(m, cache, c);
    }
    logits
}

/// Append one token to a session and return its logits — the single-lane
/// decode step (see [`forward_step_batch`] for the slate version).
pub fn forward_step<M: ForwardOps + ?Sized, C: KvStore + ?Sized>(
    m: &M,
    cache: &mut C,
    token: u8,
) -> Vec<f32> {
    prefill(m, cache, &[token])
}

/// One batch lane of a decode step: a session cache plus the token to
/// append to it. Lanes may sit at different positions. The cache is a
/// [`KvStore`] trait object so dense and paged sessions share a slate.
pub struct StepLane<'a> {
    pub cache: &'a mut dyn KvStore,
    pub token: u8,
}

/// Advance `n` independent sessions by one token each, returning their
/// last-position logits (`n × vocab`, row-major). Linear layers run
/// through [`ForwardOps::linear_batch`] with the whole slate at once, so
/// backends amortize per-row work (code-stream decode) across lanes;
/// per-lane results are bit-identical to looping [`forward_step`].
pub fn forward_step_batch<M: ForwardOps + ?Sized>(
    m: &M,
    lanes: &mut [StepLane<'_>],
) -> Vec<f32> {
    let cfg = m.cfg();
    let n = lanes.len();
    if n == 0 {
        return Vec::new();
    }
    let d = cfg.d_model;
    let hd = cfg.head_dim();
    let nh = cfg.n_heads;

    let (tok_emb, pos_emb) = (m.tok_emb(), m.pos_emb());
    let mut h = vec![0f32; n * d];
    for (l, lane) in lanes.iter_mut().enumerate() {
        let tok = lane.token as usize;
        assert!(tok < cfg.vocab, "token id {tok} >= vocab {}", cfg.vocab);
        lane.cache.check_model(cfg);
        let p = lane.cache.len();
        if let Err(e) = lane.cache.reserve(1) {
            panic!("{e}");
        }
        for i in 0..d {
            h[l * d + i] = tok_emb[tok * d + i] + pos_emb[p * d + i];
        }
    }

    let mut xs = vec![0f32; n * d];
    let mut q = vec![0f32; n * d];
    let mut k = vec![0f32; n * d];
    let mut v = vec![0f32; n * d];
    let mut attn_out = vec![0f32; n * d];
    let mut ff = vec![0f32; n * cfg.d_ff];
    let mut out = vec![0f32; n * d];
    let mut scores: Vec<f32> = Vec::new();

    for li in 0..cfg.n_layers {
        // --- attention ---
        for l in 0..n {
            let normed = &mut xs[l * d..(l + 1) * d];
            normed.copy_from_slice(&h[l * d..(l + 1) * d]);
            rmsnorm(normed, m.norm1(li));
        }
        m.linear_batch(li, LinearKind::Wq, &xs, &mut q, n);
        m.linear_batch(li, LinearKind::Wk, &xs, &mut k, n);
        m.linear_batch(li, LinearKind::Wv, &xs, &mut v, n);
        for (l, lane) in lanes.iter_mut().enumerate() {
            let t = lane.cache.len();
            let (q_row, ao, sc) = (
                &q[l * d..(l + 1) * d],
                &mut attn_out[l * d..(l + 1) * d],
                &mut scores,
            );
            lane.cache.append_layer(
                li,
                &k[l * d..(l + 1) * d],
                &v[l * d..(l + 1) * d],
                &mut |kc, vc| attend(kc, vc, t, d, hd, nh, q_row, ao, sc),
            );
        }
        m.linear_batch(li, LinearKind::Wo, &attn_out, &mut out, n);
        for (hi, &o) in h.iter_mut().zip(out.iter()) {
            *hi += o;
        }
        // --- MLP ---
        for l in 0..n {
            let normed = &mut xs[l * d..(l + 1) * d];
            normed.copy_from_slice(&h[l * d..(l + 1) * d]);
            rmsnorm(normed, m.norm2(li));
        }
        m.linear_batch(li, LinearKind::W1, &xs, &mut ff, n);
        for x in ff.iter_mut() {
            *x = silu(*x);
        }
        m.linear_batch(li, LinearKind::W2, &ff, &mut out, n);
        for (hi, &o) in h.iter_mut().zip(out.iter()) {
            *hi += o;
        }
    }
    for lane in lanes.iter_mut() {
        lane.cache.commit(1);
    }

    let mut normed = vec![0f32; d];
    let mut logits = vec![0f32; n * cfg.vocab];
    for l in 0..n {
        normed.copy_from_slice(&h[l * d..(l + 1) * d]);
        rmsnorm(&mut normed, m.norm_f());
        m.lm_head(&normed, &mut logits[l * cfg.vocab..(l + 1) * cfg.vocab]);
    }
    logits
}

/// Cross-entropy (nats) of targets under the logits; also returns top-1
/// accuracy overall and on masked positions.
pub fn sequence_loss(
    logits: &[f32],
    targets: &[u8],
    det_mask: &[bool],
    vocab: usize,
) -> (f64, f64, f64) {
    let s = targets.len();
    assert_eq!(logits.len(), s * vocab);
    let mut nll = 0.0f64;
    let (mut hit, mut det_hit, mut det_n) = (0usize, 0usize, 0usize);
    for t in 0..s {
        let row = &logits[t * vocab..(t + 1) * vocab];
        let maxv = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0f64;
        for &l in row {
            z += ((l - maxv) as f64).exp();
        }
        let tgt = targets[t] as usize;
        nll += -((row[tgt] - maxv) as f64 - z.ln());
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if argmax == tgt {
            hit += 1;
            if det_mask[t] {
                det_hit += 1;
            }
        }
        if det_mask[t] {
            det_n += 1;
        }
    }
    (
        nll / s as f64,
        hit as f64 / s as f64,
        if det_n > 0 {
            det_hit as f64 / det_n as f64
        } else {
            0.0
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::config_by_name;

    #[test]
    fn forward_shapes_and_finiteness() {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 3);
        let toks: Vec<u8> = (0..32).map(|i| (i * 7 % 64) as u8).collect();
        let mut cap = ActivationCapture::default();
        let logits = forward(&w, &toks, &mut cap);
        assert_eq!(logits.len(), 32 * cfg.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(cap.store.is_empty());
    }

    #[test]
    fn capture_collects_layer_inputs() {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 3);
        let toks: Vec<u8> = (0..16).map(|i| (i % 64) as u8).collect();
        let mut cap = ActivationCapture::enabled();
        forward(&w, &toks, &mut cap);
        for li in 0..cfg.n_layers {
            for kind in LINEAR_KINDS {
                let (_, d_in) = kind.shape(&cfg);
                let x = cap.store.get(&(li, kind)).expect("missing capture");
                assert_eq!(x.len(), 16 * d_in, "{li} {:?}", kind);
            }
        }
    }

    #[test]
    fn causality_prefix_invariance() {
        // logits at position t must not change when future tokens change
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 5);
        let mut cap = ActivationCapture::default();
        let a: Vec<u8> = (0..20).map(|i| (i * 3 % 64) as u8).collect();
        let mut b = a.clone();
        b[15] = 9;
        b[19] = 1;
        let la = forward(&w, &a, &mut cap);
        let lb = forward(&w, &b, &mut cap);
        for t in 0..15 {
            for c in 0..cfg.vocab {
                assert!(
                    (la[t * cfg.vocab + c] - lb[t * cfg.vocab + c]).abs() < 1e-5,
                    "future token leaked into position {t}"
                );
            }
        }
    }

    #[test]
    fn forward_step_matches_full_forward_bitwise() {
        // the KV-cache correctness oracle: prefill + N decode steps must
        // reproduce full-forward last-position logits bit-for-bit
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 11);
        let mut cap = ActivationCapture::default();
        let prefix: Vec<u8> = vec![3, 1, 4, 1, 5];
        let mut cache = KvCache::new(&cfg);
        let mut step_logits = prefill(&w, &mut cache, &prefix);
        let mut toks = prefix.clone();
        for step in 0..6 {
            let full = forward(&w, &toks, &mut cap);
            let last = &full[(toks.len() - 1) * cfg.vocab..toks.len() * cfg.vocab];
            assert!(
                step_logits.iter().zip(last).all(|(a, b)| a.to_bits() == b.to_bits()),
                "step {step}: cached logits diverged from full forward"
            );
            let next = (step * 7 % cfg.vocab) as u8;
            toks.push(next);
            step_logits = forward_step(&w, &mut cache, next);
        }
        assert_eq!(cache.len(), prefix.len() + 6);
    }

    #[test]
    fn prefill_is_incremental() {
        // feeding a prefix in two runs equals feeding it in one
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 13);
        let toks: Vec<u8> = (0..12).map(|i| (i * 5 % 64) as u8).collect();
        let mut one = KvCache::new(&cfg);
        let a = prefill(&w, &mut one, &toks);
        let mut two = KvCache::new(&cfg);
        prefill(&w, &mut two, &toks[..7]);
        let b = prefill(&w, &mut two, &toks[7..]);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert_eq!(one.len(), two.len());
    }

    #[test]
    fn step_batch_matches_single_lane_bitwise() {
        // slate decode must equal per-lane stepping even with lanes at
        // different positions
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 17);
        let prefixes: [&[u8]; 3] = [&[1, 2, 3], &[9, 8, 7, 6, 5], &[4]];
        let mut batch_caches: Vec<KvCache> =
            prefixes.iter().map(|_| KvCache::new(&cfg)).collect();
        let mut solo_caches: Vec<KvCache> =
            prefixes.iter().map(|_| KvCache::new(&cfg)).collect();
        for (i, p) in prefixes.iter().enumerate() {
            prefill(&w, &mut batch_caches[i], p);
            prefill(&w, &mut solo_caches[i], p);
        }
        let toks = [10u8, 20, 30];
        let mut lanes: Vec<StepLane<'_>> = batch_caches
            .iter_mut()
            .zip(toks)
            .map(|(cache, token)| StepLane { cache, token })
            .collect();
        let batched = forward_step_batch(&w, &mut lanes);
        for (l, (cache, token)) in solo_caches.iter_mut().zip(toks).enumerate() {
            let solo = forward_step(&w, cache, token);
            let row = &batched[l * cfg.vocab..(l + 1) * cfg.vocab];
            assert!(
                solo.iter().zip(row).all(|(a, b)| a.to_bits() == b.to_bits()),
                "lane {l} diverged from single-lane step"
            );
        }
    }

    #[test]
    fn chunked_prefill_matches_one_shot_bitwise() {
        // the scheduler's pipelined prefill slices a prompt into chunks;
        // every chunk size must reproduce the one-shot logits bit for bit
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 23);
        let toks: Vec<u8> = (0..33).map(|i| (i * 11 % 64) as u8).collect();
        let mut one = KvCache::new(&cfg);
        let want = prefill(&w, &mut one, &toks);
        for chunk in [1usize, 3, 8, 64] {
            let mut c = KvCache::new(&cfg);
            let got = prefill_chunked(&w, &mut c, &toks, chunk);
            assert_eq!(c.len(), toks.len());
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "chunk={chunk} diverged from one-shot prefill"
            );
        }
    }

    #[test]
    fn check_append_guards_capacity() {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let mut cache = KvCache::with_capacity(&cfg, 4);
        assert!(cache.check_append(4).is_ok());
        assert!(cache.check_append(5).is_err());
        let w = Weights::random(&cfg, 3);
        prefill(&w, &mut cache, &[1, 2, 3]);
        assert!(cache.check_append(1).is_ok());
        assert!(cache.check_append(2).is_err());
    }

    #[test]
    #[should_panic(expected = "exceeds cache capacity")]
    fn step_past_capacity_panics() {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 3);
        let mut cache = KvCache::new(&cfg);
        let toks: Vec<u8> = (0..cfg.max_seq).map(|i| (i % 64) as u8).collect();
        prefill(&w, &mut cache, &toks);
        assert_eq!(cache.remaining(), 0);
        let _ = forward_step(&w, &mut cache, 1);
    }

    #[test]
    fn loss_of_uniform_logits_is_log_vocab() {
        let vocab = 64;
        let logits = vec![0f32; 10 * vocab];
        let targets = [5u8; 10];
        let mask = [false; 10];
        let (nll, _, _) = sequence_loss(&logits, &targets, &mask, vocab);
        assert!((nll - (vocab as f64).ln()).abs() < 1e-9);
    }
}
