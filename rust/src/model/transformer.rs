//! Decoder-only transformer forward pass (Rust-native f32 oracle).
//!
//! This is the evaluation substrate for the PTQ experiments: the same
//! architecture is trained in JAX (`python/compile/train.py`), its weights
//! load here, and the quantization pipeline swaps individual linear-layer
//! weights while this module measures perplexity / probe accuracy. It also
//! exposes *activation capture* for Hessian calibration (layer inputs X
//! feed `pipeline::hessian`).
//!
//! Architecture (kept deliberately mirror-friendly with the JAX side):
//! token embedding + learned positional embedding → N × [RMSNorm →
//! causal MHA (head dim 24) → residual → RMSNorm → MLP (SiLU) → residual]
//! → final RMSNorm → LM head.

use crate::model::config::ModelConfig;

/// Which linear layers exist per block (the quantization targets).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinearKind {
    Wq,
    Wk,
    Wv,
    Wo,
    W1,
    W2,
}

pub const LINEAR_KINDS: [LinearKind; 6] = [
    LinearKind::Wq,
    LinearKind::Wk,
    LinearKind::Wv,
    LinearKind::Wo,
    LinearKind::W1,
    LinearKind::W2,
];

impl LinearKind {
    pub fn label(&self) -> &'static str {
        match self {
            LinearKind::Wq => "wq",
            LinearKind::Wk => "wk",
            LinearKind::Wv => "wv",
            LinearKind::Wo => "wo",
            LinearKind::W1 => "w1",
            LinearKind::W2 => "w2",
        }
    }

    /// (rows, cols) = (d_out, d_in) for this layer under `cfg`.
    pub fn shape(&self, cfg: &ModelConfig) -> (usize, usize) {
        let d = cfg.d_model;
        match self {
            LinearKind::Wq | LinearKind::Wk | LinearKind::Wv | LinearKind::Wo => (d, d),
            LinearKind::W1 => (cfg.d_ff, d),
            LinearKind::W2 => (d, cfg.d_ff),
        }
    }
}

/// One transformer block's weights (row-major `(d_out × d_in)` matrices).
#[derive(Clone, Debug)]
pub struct BlockWeights {
    pub norm1: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub norm2: Vec<f32>,
    pub w1: Vec<f32>,
    pub w2: Vec<f32>,
}

impl BlockWeights {
    pub fn linear(&self, k: LinearKind) -> &Vec<f32> {
        match k {
            LinearKind::Wq => &self.wq,
            LinearKind::Wk => &self.wk,
            LinearKind::Wv => &self.wv,
            LinearKind::Wo => &self.wo,
            LinearKind::W1 => &self.w1,
            LinearKind::W2 => &self.w2,
        }
    }

    pub fn linear_mut(&mut self, k: LinearKind) -> &mut Vec<f32> {
        match k {
            LinearKind::Wq => &mut self.wq,
            LinearKind::Wk => &mut self.wk,
            LinearKind::Wv => &mut self.wv,
            LinearKind::Wo => &mut self.wo,
            LinearKind::W1 => &mut self.w1,
            LinearKind::W2 => &mut self.w2,
        }
    }
}

/// Full model weights.
#[derive(Clone, Debug)]
pub struct Weights {
    pub cfg: ModelConfig,
    pub tok_emb: Vec<f32>,  // vocab × d
    pub pos_emb: Vec<f32>,  // max_seq × d
    pub blocks: Vec<BlockWeights>,
    pub norm_f: Vec<f32>,   // d
    pub lm_head: Vec<f32>,  // vocab × d
}

impl Weights {
    /// Random initialization (for tests and the untrained baseline).
    pub fn random(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = crate::util::rng::Xoshiro256pp::new(seed);
        let d = cfg.d_model;
        let mut mk = |n: usize, scale: f64| -> Vec<f32> {
            (0..n).map(|_| (rng.next_gaussian() * scale) as f32).collect()
        };
        let s_attn = 1.0 / (d as f64).sqrt();
        let s_mlp = 1.0 / (cfg.d_ff as f64).sqrt();
        let blocks = (0..cfg.n_layers)
            .map(|_| BlockWeights {
                norm1: vec![1.0; d],
                wq: mk(d * d, s_attn),
                wk: mk(d * d, s_attn),
                wv: mk(d * d, s_attn),
                wo: mk(d * d, s_attn),
                norm2: vec![1.0; d],
                w1: mk(cfg.d_ff * d, s_attn),
                w2: mk(d * cfg.d_ff, s_mlp),
            })
            .collect();
        Self {
            cfg: cfg.clone(),
            tok_emb: mk(cfg.vocab * d, 0.05),
            pos_emb: mk(cfg.max_seq * d, 0.05),
            blocks,
            norm_f: vec![1.0; d],
            lm_head: mk(cfg.vocab * d, s_attn),
        }
    }
}

/// Captured layer inputs during a forward pass, keyed (layer, kind).
/// Row-major token activations; feeds the Hessian accumulator.
#[derive(Default)]
pub struct ActivationCapture {
    pub store: std::collections::HashMap<(usize, LinearKind), Vec<f32>>,
    pub enabled: bool,
}

impl ActivationCapture {
    pub fn enabled() -> Self {
        Self {
            enabled: true,
            ..Default::default()
        }
    }

    fn record(&mut self, layer: usize, kind: LinearKind, x: &[f32]) {
        if self.enabled {
            self.store
                .entry((layer, kind))
                .or_default()
                .extend_from_slice(x);
        }
    }
}

fn rmsnorm(x: &mut [f32], gamma: &[f32]) {
    let d = x.len();
    let ms: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    for (xi, g) in x.iter_mut().zip(gamma) {
        *xi = ((*xi as f64) * inv) as f32 * g;
    }
}

/// y = W·x for row-major W (d_out × d_in) — the dense matvec kernel shared
/// by the Weights fast path and `model::backend::DenseOp` (keeping the two
/// bit-identical).
pub(crate) fn linear(w: &[f32], d_out: usize, d_in: usize, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(w.len(), d_out * d_in);
    for (o, yo) in y.iter_mut().enumerate().take(d_out) {
        let row = &w[o * d_in..(o + 1) * d_in];
        let mut acc = 0f32;
        for (ri, xi) in row.iter().zip(x) {
            acc += ri * xi;
        }
        *yo = acc;
    }
}

#[inline]
fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// What [`forward`] needs from a model representation: the dense fp32
/// parts (embeddings, norms) by reference, plus every linear layer as an
/// *operation* `y = W·x` rather than a materialized matrix. [`Weights`]
/// implements it directly (the f32 oracle); `model::backend::
/// ExecutionBackend` implements it over [`model::backend::LinearOp`]s so
/// the same forward pass runs on dense, lazily-decoded, or fused
/// bit-packed representations.
///
/// `Sync` is a supertrait because evaluation fans sequences out over the
/// thread pool.
pub trait ForwardOps: Sync {
    fn cfg(&self) -> &ModelConfig;
    fn tok_emb(&self) -> &[f32];
    fn pos_emb(&self) -> &[f32];
    fn norm1(&self, layer: usize) -> &[f32];
    fn norm2(&self, layer: usize) -> &[f32];
    fn norm_f(&self) -> &[f32];
    /// `y = W_{layer,kind} · x`.
    fn linear(&self, layer: usize, kind: LinearKind, x: &[f32], y: &mut [f32]);
    /// `y = W_head · x` (vocab × d_model).
    fn lm_head(&self, x: &[f32], y: &mut [f32]);
}

impl ForwardOps for Weights {
    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn tok_emb(&self) -> &[f32] {
        &self.tok_emb
    }

    fn pos_emb(&self) -> &[f32] {
        &self.pos_emb
    }

    fn norm1(&self, layer: usize) -> &[f32] {
        &self.blocks[layer].norm1
    }

    fn norm2(&self, layer: usize) -> &[f32] {
        &self.blocks[layer].norm2
    }

    fn norm_f(&self) -> &[f32] {
        &self.norm_f
    }

    fn linear(&self, layer: usize, kind: LinearKind, x: &[f32], y: &mut [f32]) {
        let (rows, cols) = kind.shape(&self.cfg);
        linear(self.blocks[layer].linear(kind), rows, cols, x, y);
    }

    fn lm_head(&self, x: &[f32], y: &mut [f32]) {
        linear(&self.lm_head, self.cfg.vocab, self.cfg.d_model, x, y);
    }
}

/// Run the model on a token sequence, returning per-position logits
/// (seq × vocab, row-major). Optionally captures linear-layer inputs.
/// Generic over [`ForwardOps`], so the same pass serves dense [`Weights`]
/// and every packed execution backend.
pub fn forward<M: ForwardOps + ?Sized>(
    m: &M,
    tokens: &[u8],
    capture: &mut ActivationCapture,
) -> Vec<f32> {
    let cfg = m.cfg();
    let (s, d) = (tokens.len(), cfg.d_model);
    assert!(s <= cfg.max_seq);
    let hd = cfg.head_dim();
    let nh = cfg.n_heads;

    // embeddings
    let (tok_emb, pos_emb) = (m.tok_emb(), m.pos_emb());
    let mut h = vec![0f32; s * d];
    for t in 0..s {
        let tok = tokens[t] as usize;
        for i in 0..d {
            h[t * d + i] = tok_emb[tok * d + i] + pos_emb[t * d + i];
        }
    }

    let mut q = vec![0f32; s * d];
    let mut k = vec![0f32; s * d];
    let mut v = vec![0f32; s * d];
    let mut attn_out = vec![0f32; s * d];
    let mut normed = vec![0f32; d];
    let mut ff = vec![0f32; cfg.d_ff];
    let mut ff2 = vec![0f32; d];

    for li in 0..cfg.n_layers {
        // --- attention ---
        for t in 0..s {
            normed.copy_from_slice(&h[t * d..(t + 1) * d]);
            rmsnorm(&mut normed, m.norm1(li));
            capture.record(li, LinearKind::Wq, &normed);
            capture.record(li, LinearKind::Wk, &normed);
            capture.record(li, LinearKind::Wv, &normed);
            m.linear(li, LinearKind::Wq, &normed, &mut q[t * d..(t + 1) * d]);
            m.linear(li, LinearKind::Wk, &normed, &mut k[t * d..(t + 1) * d]);
            m.linear(li, LinearKind::Wv, &normed, &mut v[t * d..(t + 1) * d]);
        }
        let scale = 1.0 / (hd as f32).sqrt();
        for t in 0..s {
            let ao = &mut attn_out[t * d..(t + 1) * d];
            ao.iter_mut().for_each(|x| *x = 0.0);
            for head in 0..nh {
                let off = head * hd;
                // scores over 0..=t
                let mut scores = vec![0f32; t + 1];
                let qt = &q[t * d + off..t * d + off + hd];
                let mut maxs = f32::NEG_INFINITY;
                for u in 0..=t {
                    let ku = &k[u * d + off..u * d + off + hd];
                    let mut sdot = 0f32;
                    for (qi, ki) in qt.iter().zip(ku) {
                        sdot += qi * ki;
                    }
                    scores[u] = sdot * scale;
                    maxs = maxs.max(scores[u]);
                }
                let mut z = 0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - maxs).exp();
                    z += *sc;
                }
                let zi = 1.0 / z;
                for u in 0..=t {
                    let p = scores[u] * zi;
                    let vu = &v[u * d + off..u * d + off + hd];
                    for i in 0..hd {
                        ao[off + i] += p * vu[i];
                    }
                }
            }
        }
        for t in 0..s {
            capture.record(li, LinearKind::Wo, &attn_out[t * d..(t + 1) * d]);
            m.linear(li, LinearKind::Wo, &attn_out[t * d..(t + 1) * d], &mut normed);
            for i in 0..d {
                h[t * d + i] += normed[i];
            }
        }
        // --- MLP ---
        for t in 0..s {
            normed.copy_from_slice(&h[t * d..(t + 1) * d]);
            rmsnorm(&mut normed, m.norm2(li));
            capture.record(li, LinearKind::W1, &normed);
            m.linear(li, LinearKind::W1, &normed, &mut ff);
            for x in ff.iter_mut() {
                *x = silu(*x);
            }
            capture.record(li, LinearKind::W2, &ff);
            m.linear(li, LinearKind::W2, &ff, &mut ff2);
            for i in 0..d {
                h[t * d + i] += ff2[i];
            }
        }
    }

    // final norm + head
    let mut logits = vec![0f32; s * cfg.vocab];
    for t in 0..s {
        normed.copy_from_slice(&h[t * d..(t + 1) * d]);
        rmsnorm(&mut normed, m.norm_f());
        m.lm_head(&normed, &mut logits[t * cfg.vocab..(t + 1) * cfg.vocab]);
    }
    logits
}

/// Cross-entropy (nats) of targets under the logits; also returns top-1
/// accuracy overall and on masked positions.
pub fn sequence_loss(
    logits: &[f32],
    targets: &[u8],
    det_mask: &[bool],
    vocab: usize,
) -> (f64, f64, f64) {
    let s = targets.len();
    assert_eq!(logits.len(), s * vocab);
    let mut nll = 0.0f64;
    let (mut hit, mut det_hit, mut det_n) = (0usize, 0usize, 0usize);
    for t in 0..s {
        let row = &logits[t * vocab..(t + 1) * vocab];
        let maxv = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0f64;
        for &l in row {
            z += ((l - maxv) as f64).exp();
        }
        let tgt = targets[t] as usize;
        nll += -((row[tgt] - maxv) as f64 - z.ln());
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if argmax == tgt {
            hit += 1;
            if det_mask[t] {
                det_hit += 1;
            }
        }
        if det_mask[t] {
            det_n += 1;
        }
    }
    (
        nll / s as f64,
        hit as f64 / s as f64,
        if det_n > 0 {
            det_hit as f64 / det_n as f64
        } else {
            0.0
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::config_by_name;

    #[test]
    fn forward_shapes_and_finiteness() {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 3);
        let toks: Vec<u8> = (0..32).map(|i| (i * 7 % 64) as u8).collect();
        let mut cap = ActivationCapture::default();
        let logits = forward(&w, &toks, &mut cap);
        assert_eq!(logits.len(), 32 * cfg.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(cap.store.is_empty());
    }

    #[test]
    fn capture_collects_layer_inputs() {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 3);
        let toks: Vec<u8> = (0..16).map(|i| (i % 64) as u8).collect();
        let mut cap = ActivationCapture::enabled();
        forward(&w, &toks, &mut cap);
        for li in 0..cfg.n_layers {
            for kind in LINEAR_KINDS {
                let (_, d_in) = kind.shape(&cfg);
                let x = cap.store.get(&(li, kind)).expect("missing capture");
                assert_eq!(x.len(), 16 * d_in, "{li} {:?}", kind);
            }
        }
    }

    #[test]
    fn causality_prefix_invariance() {
        // logits at position t must not change when future tokens change
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 5);
        let mut cap = ActivationCapture::default();
        let a: Vec<u8> = (0..20).map(|i| (i * 3 % 64) as u8).collect();
        let mut b = a.clone();
        b[15] = 9;
        b[19] = 1;
        let la = forward(&w, &a, &mut cap);
        let lb = forward(&w, &b, &mut cap);
        for t in 0..15 {
            for c in 0..cfg.vocab {
                assert!(
                    (la[t * cfg.vocab + c] - lb[t * cfg.vocab + c]).abs() < 1e-5,
                    "future token leaked into position {t}"
                );
            }
        }
    }

    #[test]
    fn loss_of_uniform_logits_is_log_vocab() {
        let vocab = 64;
        let logits = vec![0f32; 10 * vocab];
        let targets = vec![5u8; 10];
        let mask = vec![false; 10];
        let (nll, _, _) = sequence_loss(&logits, &targets, &mask, vocab);
        assert!((nll - (vocab as f64).ln()).abs() < 1e-9);
    }
}
