//! Model weight serialization — the `artifacts/<name>.llvqw` format shared
//! with the JAX training script.
//!
//! Layout: magic `LLVQWTS1` · u32 LE header length · JSON header (config) ·
//! raw little-endian f32 tensors in canonical order (tok_emb, pos_emb,
//! per-block [norm1, wq, wk, wv, wo, norm2, w1, w2], norm_f, lm_head).
//! `python/compile/train.py` writes exactly this; both sides assert the
//! total byte count so silent shape drift is impossible.

use std::io::{Read, Write};
use std::path::Path;

use crate::model::config::ModelConfig;
use crate::model::transformer::{BlockWeights, Weights};
use crate::util::json::{self, Json};

const MAGIC: &[u8; 8] = b"LLVQWTS1";

pub(crate) fn header_json(cfg: &ModelConfig) -> Json {
    Json::obj(vec![
        ("name", Json::Str(cfg.name.clone())),
        ("vocab", Json::Int(cfg.vocab as i64)),
        ("d_model", Json::Int(cfg.d_model as i64)),
        ("n_layers", Json::Int(cfg.n_layers as i64)),
        ("n_heads", Json::Int(cfg.n_heads as i64)),
        ("d_ff", Json::Int(cfg.d_ff as i64)),
        ("max_seq", Json::Int(cfg.max_seq as i64)),
    ])
}

pub(crate) fn config_from_header(j: &Json) -> Result<ModelConfig, String> {
    let geti = |k: &str| -> Result<usize, String> {
        j.get(k)
            .and_then(|v| v.as_i64())
            .map(|v| v as usize)
            .ok_or_else(|| format!("header missing {k}"))
    };
    Ok(ModelConfig {
        name: j
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("unnamed")
            .to_string(),
        vocab: geti("vocab")?,
        d_model: geti("d_model")?,
        n_layers: geti("n_layers")?,
        n_heads: geti("n_heads")?,
        d_ff: geti("d_ff")?,
        max_seq: geti("max_seq")?,
    })
}

pub(crate) fn push_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Serialize weights to bytes.
pub fn to_bytes(w: &Weights) -> Vec<u8> {
    let hdr = header_json(&w.cfg).to_string_compact();
    let mut buf = Vec::with_capacity(hdr.len() + 64 + 4 * w.cfg.num_params());
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(hdr.len() as u32).to_le_bytes());
    buf.extend_from_slice(hdr.as_bytes());
    push_f32s(&mut buf, &w.tok_emb);
    push_f32s(&mut buf, &w.pos_emb);
    for b in &w.blocks {
        push_f32s(&mut buf, &b.norm1);
        push_f32s(&mut buf, &b.wq);
        push_f32s(&mut buf, &b.wk);
        push_f32s(&mut buf, &b.wv);
        push_f32s(&mut buf, &b.wo);
        push_f32s(&mut buf, &b.norm2);
        push_f32s(&mut buf, &b.w1);
        push_f32s(&mut buf, &b.w2);
    }
    push_f32s(&mut buf, &w.norm_f);
    push_f32s(&mut buf, &w.lm_head);
    buf
}

/// Exact on-disk size of the dense `.llvqw` artifact for `cfg`, without
/// serializing any weights — the pack/unpack stats lines use this instead
/// of materializing a full dense copy just to measure it.
pub fn dense_file_size(cfg: &ModelConfig) -> usize {
    12 + header_json(cfg).to_string_compact().len() + 4 * cfg.num_params()
}

/// Parse weights from bytes.
pub fn from_bytes(data: &[u8]) -> Result<Weights, String> {
    if data.len() < 12 || &data[..8] != MAGIC {
        return Err("bad magic".into());
    }
    let hlen = u32::from_le_bytes(data[8..12].try_into().unwrap()) as usize;
    if 12 + hlen > data.len() {
        return Err("truncated header".into());
    }
    let hdr = std::str::from_utf8(&data[12..12 + hlen]).map_err(|e| e.to_string())?;
    let cfg = config_from_header(&json::parse(hdr)?)?;
    cfg.check()?;
    let mut off = 12 + hlen;
    let mut take = |n: usize| -> Result<Vec<f32>, String> {
        let bytes = n * 4;
        if off + bytes > data.len() {
            return Err(format!("truncated tensor at byte {off}"));
        }
        let mut v = Vec::with_capacity(n);
        for c in data[off..off + bytes].chunks_exact(4) {
            v.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        off += bytes;
        Ok(v)
    };
    let d = cfg.d_model;
    let tok_emb = take(cfg.vocab * d)?;
    let pos_emb = take(cfg.max_seq * d)?;
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    for _ in 0..cfg.n_layers {
        blocks.push(BlockWeights {
            norm1: take(d)?,
            wq: take(d * d)?,
            wk: take(d * d)?,
            wv: take(d * d)?,
            wo: take(d * d)?,
            norm2: take(d)?,
            w1: take(cfg.d_ff * d)?,
            w2: take(d * cfg.d_ff)?,
        });
    }
    let norm_f = take(d)?;
    let lm_head = take(cfg.vocab * d)?;
    if off != data.len() {
        return Err(format!(
            "trailing bytes: consumed {off}, file has {}",
            data.len()
        ));
    }
    Ok(Weights {
        cfg,
        tok_emb,
        pos_emb,
        blocks,
        norm_f,
        lm_head,
    })
}

pub fn save(w: &Weights, path: &Path) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&to_bytes(w))
}

pub fn load(path: &Path) -> Result<Weights, String> {
    let mut data = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| format!("open {}: {e}", path.display()))?
        .read_to_end(&mut data)
        .map_err(|e| e.to_string())?;
    from_bytes(&data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::config_by_name;

    #[test]
    fn roundtrip_bytes() {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 11);
        let bytes = to_bytes(&w);
        let back = from_bytes(&bytes).unwrap();
        assert_eq!(back.cfg, cfg);
        assert_eq!(back.tok_emb, w.tok_emb);
        assert_eq!(back.blocks.len(), w.blocks.len());
        assert_eq!(back.blocks[1].w2, w.blocks[1].w2);
        assert_eq!(back.lm_head, w.lm_head);
        // the analytic size must track the serializer exactly
        assert_eq!(dense_file_size(&cfg), bytes.len());
    }

    #[test]
    fn rejects_corruption() {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let w = Weights::random(&cfg, 1);
        let mut bytes = to_bytes(&w);
        assert!(from_bytes(&bytes[..100]).is_err()); // truncated
        bytes[0] = b'X';
        assert!(from_bytes(&bytes).is_err()); // bad magic
        let mut extra = to_bytes(&w);
        extra.extend_from_slice(&[0, 0, 0, 0]);
        assert!(from_bytes(&extra).is_err()); // trailing bytes
    }
}
