//! Multi-model registry behind the HTTP front door (`llvq serve-http`).
//!
//! One process, many named `.llvqm` artifacts. Registration is
//! header-only ([`crate::model::packed::PackedModel::load_meta`]): the
//! file is parse-validated and its config captured without touching the
//! payload, so registering N models costs N header reads. The first
//! request against a model builds its execution backend and starts a
//! dedicated [`Coordinator`] (its own scheduler worker, its own
//! [`crate::coordinator::Metrics`]); subsequent requests reuse it.
//!
//! Residency is a byte-budgeted LRU hot set: after every touch the
//! registry sums `resident_weight_bytes()` across resident backends and,
//! while the sum exceeds `max_resident_bytes`, stops and drops the
//! least-recently-used resident model — but **never** one with open
//! sessions (eviction must not kill in-flight generations), and never
//! the model that was just requested. A budget small enough that nothing
//! is evictable is therefore a soft limit: the process temporarily
//! overshoots rather than aborting live work, and re-checks on the next
//! touch. See `docs/OPERATIONS.md` for sizing guidance.
//!
//! Every per-model [`crate::coordinator::Metrics`] shares one
//! registered-model gauge, surfaced as the `models=` STATS field (the
//! single-model `llvq serve` path reports `models=1`).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::{BackendEngine, BatcherConfig, Coordinator};
use crate::model::backend::{BackendKind, ExecutionBackend};
use crate::model::kvpage::KvQuantKind;
use crate::model::packed::{PackedFile, PackedMeta, PackedModel};
use crate::quant::kernel::Kernel;

/// One `name=path` registration unit.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub path: PathBuf,
}

/// Parse the `--model name=path[,name=path...]` CLI value. A bare path
/// (no `=`) names itself after its file stem. Names must be non-empty,
/// unique, and URL-safe (`[A-Za-z0-9._-]`) so they can appear verbatim
/// in routes and JSON without escaping.
pub fn parse_model_specs(arg: &str) -> Result<Vec<ModelSpec>, String> {
    let mut specs: Vec<ModelSpec> = Vec::new();
    for part in arg.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, path) = match part.split_once('=') {
            Some((n, p)) => (n.trim().to_string(), PathBuf::from(p.trim())),
            None => {
                let path = PathBuf::from(part);
                let stem = path
                    .file_stem()
                    .map(|s| s.to_string_lossy().to_string())
                    .unwrap_or_default();
                (stem, path)
            }
        };
        if name.is_empty() {
            return Err(format!("model spec '{part}' has an empty name"));
        }
        if !name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
        {
            return Err(format!(
                "model name '{name}' must match [A-Za-z0-9._-] (it appears in URLs and JSON)"
            ));
        }
        if specs.iter().any(|s| s.name == name) {
            return Err(format!("duplicate model name '{name}'"));
        }
        specs.push(ModelSpec { name, path });
    }
    if specs.is_empty() {
        return Err("no model specs (expected name=path[,name=path...])".into());
    }
    Ok(specs)
}

/// How the registry builds backends and schedulers for its models: one
/// shared policy, applied to every model on its first request.
#[derive(Clone, Copy, Debug)]
pub struct RegistryConfig {
    /// Execution backend for every model (dense | cached | fused).
    pub backend: BackendKind,
    /// Kernel worker threads per backend.
    pub threads: usize,
    /// Fused-kernel SIMD selection.
    pub simd: Kernel,
    /// Scheduler configuration for every per-model [`Coordinator`].
    pub batcher: BatcherConfig,
    /// KV page-arena budget in pages (0 = dense worst-case caches).
    pub kv_pages: usize,
    /// Tokens per KV page.
    pub kv_page_tokens: usize,
    /// f32 hot window in tokens.
    pub kv_hot: usize,
    /// Cold-page codec.
    pub kv_quant: KvQuantKind,
    /// LRU hot-set budget over `resident_weight_bytes()` sums
    /// (0 = unlimited).
    pub max_resident_bytes: usize,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        Self {
            backend: BackendKind::Cached,
            threads: 1,
            simd: Kernel::Scalar,
            batcher: BatcherConfig::default(),
            kv_pages: 0,
            kv_page_tokens: 16,
            kv_hot: 32,
            kv_quant: KvQuantKind::None,
            max_resident_bytes: 0,
        }
    }
}

/// Registration-time identity of one model — everything `GET /v1/models`
/// reports, readable without building a backend.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    /// Config name from the packed header (e.g. `qwen3-4b-tiny`).
    pub config: String,
    pub vocab: usize,
    pub max_seq: usize,
    /// Linear (quantized) parameter count.
    pub params: usize,
    /// On-disk artifact size.
    pub file_bytes: usize,
    /// Whether a backend + coordinator currently exist for this model.
    pub resident: bool,
    /// `resident_weight_bytes()` of the live backend (0 when cold).
    pub resident_bytes: usize,
}

struct Entry {
    spec: ModelSpec,
    meta: PackedMeta,
    coord: Option<Arc<Coordinator>>,
    /// LRU clock value of the last touch (higher = more recent).
    last_touch: u64,
}

struct Inner {
    entries: Vec<Entry>,
    clock: u64,
}

/// The registry: see the module docs for the residency model.
pub struct ModelRegistry {
    cfg: RegistryConfig,
    inner: Mutex<Inner>,
    /// Shared into every per-model `Metrics` as the `models=` gauge.
    models_gauge: Arc<AtomicU64>,
}

impl ModelRegistry {
    /// Register every spec (header-only — fails fast on a bad artifact,
    /// duplicate names are rejected by [`parse_model_specs`]).
    pub fn open(specs: Vec<ModelSpec>, cfg: RegistryConfig) -> Result<Arc<Self>, String> {
        let mut entries = Vec::with_capacity(specs.len());
        for spec in specs {
            let meta = PackedModel::load_meta(&spec.path)
                .map_err(|e| format!("model '{}' ({}): {e}", spec.name, spec.path.display()))?;
            entries.push(Entry {
                spec,
                meta,
                coord: None,
                last_touch: 0,
            });
        }
        let gauge = Arc::new(AtomicU64::new(entries.len() as u64));
        Ok(Arc::new(Self {
            cfg,
            inner: Mutex::new(Inner { entries, clock: 0 }),
            models_gauge: gauge,
        }))
    }

    /// Registered model count.
    pub fn len(&self) -> usize {
        self.lock().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The configured residency budget (0 = unlimited).
    pub fn max_resident_bytes(&self) -> usize {
        self.cfg.max_resident_bytes
    }

    /// Models currently holding a live backend.
    pub fn resident_count(&self) -> usize {
        self.lock().entries.iter().filter(|e| e.coord.is_some()).count()
    }

    /// Sum of `resident_weight_bytes()` over resident backends.
    pub fn resident_bytes(&self) -> usize {
        let inner = self.lock();
        inner
            .entries
            .iter()
            .filter_map(|e| e.coord.as_ref())
            .map(|c| c.engine().resident_weight_bytes())
            .sum()
    }

    /// Identity of every registered model, sorted by name.
    pub fn models(&self) -> Vec<ModelInfo> {
        let inner = self.lock();
        let mut out: Vec<ModelInfo> = inner
            .entries
            .iter()
            .map(|e| ModelInfo {
                name: e.spec.name.clone(),
                config: e.meta.cfg.name.clone(),
                vocab: e.meta.cfg.vocab,
                max_seq: e.meta.cfg.max_seq,
                params: e.meta.linear_params(),
                file_bytes: e.meta.file_len,
                resident: e.coord.is_some(),
                resident_bytes: e
                    .coord
                    .as_ref()
                    .map_or(0, |c| c.engine().resident_weight_bytes()),
            })
            .collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    /// The coordinator serving `name`, building backend + scheduler on
    /// first touch, then enforcing the LRU byte budget (the just-touched
    /// model is exempt; models with open sessions are never evicted).
    ///
    /// First-touch construction holds the registry lock — concurrent
    /// requests to *other* models briefly serialize behind a load. That
    /// is deliberate: it makes "construct, then evict under budget" one
    /// atomic decision, and loads are bounded (cached/fused backends
    /// only map the code streams; only `--backend dense` pays a full
    /// unpack here).
    pub fn coordinator(&self, name: &str) -> Result<Arc<Coordinator>, String> {
        let mut inner = self.lock();
        inner.clock += 1;
        let clock = inner.clock;
        let idx = inner
            .entries
            .iter()
            .position(|e| e.spec.name == name)
            .ok_or_else(|| format!("unknown model '{name}'"))?;
        inner.entries[idx].last_touch = clock;
        if inner.entries[idx].coord.is_none() {
            let entry = &inner.entries[idx];
            let backend = build_backend(&entry.spec.path, &self.cfg)?;
            let engine = build_engine(backend, &self.cfg)?;
            let coord = Coordinator::start(Arc::new(engine), self.cfg.batcher);
            // every per-model STATS surface reports the shared
            // registered-model gauge as `models=`
            let _ = coord.metrics.models.set(self.models_gauge.clone());
            inner.entries[idx].coord = Some(coord);
        }
        let coord = match inner.entries[idx].coord.as_ref() {
            Some(c) => Arc::clone(c),
            // unreachable: just constructed above — but a panic here
            // would tear down a serving thread, so fail the request
            None => return Err("model backend construction raced".into()),
        };
        self.enforce_budget(&mut inner, idx);
        Ok(coord)
    }

    /// Evict LRU resident models while over budget. Skips `keep` (the
    /// just-touched model) and any model with open sessions; if nothing
    /// is evictable the overshoot stands until the next touch.
    fn enforce_budget(&self, inner: &mut Inner, keep: usize) {
        let budget = self.cfg.max_resident_bytes;
        if budget == 0 {
            return;
        }
        loop {
            let total: usize = inner
                .entries
                .iter()
                .filter_map(|e| e.coord.as_ref())
                .map(|c| c.engine().resident_weight_bytes())
                .sum();
            if total <= budget {
                return;
            }
            // oldest-touched resident entry that is idle and not `keep`
            let victim = inner
                .entries
                .iter()
                .enumerate()
                .filter(|(i, e)| {
                    *i != keep
                        && e.coord.as_ref().is_some_and(|c| {
                            c.metrics.open_sessions.load(Ordering::SeqCst) == 0
                        })
                })
                .min_by_key(|(_, e)| e.last_touch)
                .map(|(i, _)| i);
            let Some(v) = victim else { return };
            if let Some(coord) = inner.entries[v].coord.take() {
                // stop() drains queued work and joins the worker; the
                // victim has no open sessions, so this is bounded
                coord.stop();
            }
        }
    }

    /// `(name, STATS snapshot)` for every *resident* model, sorted by
    /// name — the `/metrics` endpoint's per-model rows. Cold models have
    /// no metrics to report (registration alone runs nothing).
    pub fn snapshots(&self) -> Vec<(String, crate::coordinator::StatsSnapshot)> {
        let inner = self.lock();
        let mut out: Vec<(String, crate::coordinator::StatsSnapshot)> = inner
            .entries
            .iter()
            .filter_map(|e| {
                e.coord.as_ref().map(|c| {
                    (e.spec.name.clone(), c.metrics.snapshot(c.engine().as_ref()))
                })
            })
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Stop every resident coordinator (drains queued work; see
    /// [`Coordinator::stop`]).
    pub fn stop(&self) {
        // take the coordinators out under the lock, stop them outside it
        // so a slow drain never blocks registry reads
        let coords: Vec<Arc<Coordinator>> = {
            let mut inner = self.lock();
            inner.entries.iter_mut().filter_map(|e| e.coord.take()).collect()
        };
        for c in coords {
            c.stop();
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // entries/clock stay consistent across a client-thread panic —
        // recover the guard instead of propagating poison into serving
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Build one model's [`ExecutionBackend`] under the registry policy.
fn build_backend(path: &Path, cfg: &RegistryConfig) -> Result<ExecutionBackend, String> {
    match cfg.backend {
        BackendKind::Dense => {
            let packed = PackedModel::load(path)?;
            let w = packed
                .unpack(cfg.threads)
                .map_err(|e| format!("unpack failed: {e}"))?;
            Ok(ExecutionBackend::dense(w))
        }
        BackendKind::Cached => {
            ExecutionBackend::packed_cached(PackedFile::open(path)?, cfg.threads)
        }
        BackendKind::Fused => {
            ExecutionBackend::packed_fused_kernel(PackedFile::open(path)?, cfg.threads, cfg.simd)
        }
    }
}

/// Wrap a backend in the engine the registry policy asks for (paged KV
/// or dense worst-case caches).
fn build_engine(backend: ExecutionBackend, cfg: &RegistryConfig) -> Result<BackendEngine, String> {
    if cfg.kv_pages == 0 {
        if cfg.kv_quant != KvQuantKind::None {
            return Err("kv_quant requires kv_pages > 0".into());
        }
        return Ok(BackendEngine::new(backend));
    }
    BackendEngine::paged(
        backend,
        cfg.kv_pages,
        cfg.kv_page_tokens.max(1),
        cfg.kv_hot,
        cfg.kv_quant,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing_names_paths_and_rejects_junk() {
        let specs = parse_model_specs("a=/tmp/a.llvqm, b=/tmp/b.llvqm").unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "a");
        assert_eq!(specs[1].path, PathBuf::from("/tmp/b.llvqm"));
        // bare path names itself after the stem
        let bare = parse_model_specs("/models/tiny.llvqm").unwrap();
        assert_eq!(bare[0].name, "tiny");
        assert!(parse_model_specs("").is_err());
        assert!(parse_model_specs("a=/x,a=/y").is_err(), "duplicate name");
        assert!(parse_model_specs("bad name=/x").is_err(), "space in name");
        assert!(parse_model_specs("=/x").is_err(), "empty name");
    }

    #[test]
    fn open_rejects_missing_artifacts() {
        let specs = parse_model_specs("ghost=/nonexistent/ghost.llvqm").unwrap();
        let err = ModelRegistry::open(specs, RegistryConfig::default()).err();
        assert!(err.is_some_and(|e| e.contains("ghost")), "error names the model");
    }
}
