//! The extended binary Golay code G₂₄ = [24, 12, 8].
//!
//! The Leech lattice construction (paper §2.3, eqs. 7–8) is built on G₂₄:
//! the mod-2 reduction of the halved even-coset vectors (and of the shifted
//! odd-coset vectors) must be a Golay codeword. This module provides the
//! code itself plus everything the lattice layer needs:
//!
//! * all 4096 codewords as 24-bit masks,
//! * codewords grouped by Hamming weight {0, 8, 12, 16, 24} with
//!   cardinalities {1, 759, 2576, 759, 1} (the `A` factors of eq. 12),
//! * O(1) rank/unrank of codewords (globally and within a weight class),
//!   which the bijective indexing scheme of §3.2 consumes,
//! * a syndrome decoder correcting up to 3 bit errors (substrate utility,
//!   also a strong self-test of the generator matrix).
//!
//! Construction: generator `[I₁₂ | B]` with `B` derived from quadratic
//! residues mod 11 (bordered circulant). The exact matrix below was
//! validated by weight-distribution check (1/759/2576/759/1) — see
//! `tests::weight_distribution`.

use std::collections::HashMap;

/// Number of codewords.
pub const NUM_CODEWORDS: usize = 4096;

/// The admissible Hamming weights of G₂₄ codewords.
pub const WEIGHTS: [usize; 5] = [0, 8, 12, 16, 24];

/// Codeword counts per weight (the `A` factors of paper eq. 12).
pub const WEIGHT_COUNTS: [usize; 5] = [1, 759, 2576, 759, 1];

/// The 12×12 `B` block of the generator matrix `[I₁₂ | B]`, row-major bits.
/// Row 0 is the all-ones-but-corner border; rows 1..11 are the QR-mod-11
/// circulant with a trailing 1 border column.
const B_ROWS: [u16; 12] = [
    0b0111_1111_1111, // 111111111110 (bit j = col j)
    0b1010_0011_1011,
    0b1100_0111_0110,
    0b1000_1110_1101,
    0b1001_1101_1010,
    0b1011_1011_0100,
    0b1111_0110_1000,
    0b1110_1101_0001,
    0b1101_1010_0011,
    0b1011_0100_0111,
    0b1110_1000_1110,
    0b1101_0001_1101,
];

/// Build the 24-bit generator rows: message bit i occupies bit i, parity
/// bits occupy bits 12..24.
fn generator_rows() -> [u32; 12] {
    let mut rows = [0u32; 12];
    // B_ROWS above encodes col j at bit j; assemble from the validated
    // string form to avoid transcription slips.
    const B_STR: [&str; 12] = [
        "111111111110",
        "110111000101",
        "011011100011",
        "101101110001",
        "010110111001",
        "001011011101",
        "000101101111",
        "100010110111",
        "110001011011",
        "111000101101",
        "011100010111",
        "101110001011",
    ];
    for (i, s) in B_STR.iter().enumerate() {
        let mut w = 1u32 << i;
        for (j, c) in s.bytes().enumerate() {
            if c == b'1' {
                w |= 1u32 << (12 + j);
            }
        }
        rows[i] = w;
    }
    let _ = B_ROWS; // keep the bit-literal form documented
    rows
}

/// The extended Golay code with all lookup structures precomputed.
pub struct GolayCode {
    rows: [u32; 12],
    /// All 4096 codewords, sorted ascending by 24-bit value.
    codewords: Vec<u32>,
    /// codeword value → rank in `codewords` (global rank; used for odd
    /// Leech classes where every codeword is admissible).
    rank_all: HashMap<u32, u32>,
    /// Per weight bucket: sorted codewords of that weight.
    by_weight: [Vec<u32>; 5],
    /// codeword value → (weight bucket index, rank within bucket).
    rank_in_weight: HashMap<u32, (u8, u32)>,
    /// Syndrome (12 bits) → minimal-weight error pattern (24 bits).
    syndrome_table: Vec<u32>,
}

impl GolayCode {
    pub fn new() -> Self {
        let rows = generator_rows();
        let mut codewords = Vec::with_capacity(NUM_CODEWORDS);
        for m in 0..NUM_CODEWORDS as u32 {
            codewords.push(Self::encode_with(&rows, m));
        }
        codewords.sort_unstable();

        let mut rank_all = HashMap::with_capacity(NUM_CODEWORDS);
        for (r, &c) in codewords.iter().enumerate() {
            rank_all.insert(c, r as u32);
        }

        let mut by_weight: [Vec<u32>; 5] = Default::default();
        for &c in &codewords {
            let w = c.count_ones() as usize;
            let bucket = WEIGHTS.iter().position(|&x| x == w).expect("bad weight");
            by_weight[bucket].push(c);
        }
        let mut rank_in_weight = HashMap::with_capacity(NUM_CODEWORDS);
        for (b, bucket) in by_weight.iter().enumerate() {
            for (r, &c) in bucket.iter().enumerate() {
                rank_in_weight.insert(c, (b as u8, r as u32));
            }
        }

        let syndrome_table = Self::build_syndrome_table(&rows, &codewords);

        Self {
            rows,
            codewords,
            rank_all,
            by_weight,
            rank_in_weight,
            syndrome_table,
        }
    }

    #[inline]
    fn encode_with(rows: &[u32; 12], msg: u32) -> u32 {
        let mut c = 0u32;
        let mut m = msg;
        let mut i = 0;
        while m != 0 {
            if m & 1 != 0 {
                c ^= rows[i];
            }
            m >>= 1;
            i += 1;
        }
        c
    }

    /// Encode a 12-bit message into a 24-bit codeword (systematic: message
    /// occupies bits 0..12).
    #[inline]
    pub fn encode(&self, msg: u32) -> u32 {
        debug_assert!(msg < 4096);
        Self::encode_with(&self.rows, msg)
    }

    /// All codewords, ascending.
    pub fn codewords(&self) -> &[u32] {
        &self.codewords
    }

    /// Is `word` (24-bit mask) a codeword?
    #[inline]
    pub fn contains(&self, word: u32) -> bool {
        self.rank_all.contains_key(&(word & 0xFF_FFFF))
    }

    /// Global rank of a codeword among all 4096 (sorted ascending).
    #[inline]
    pub fn rank(&self, word: u32) -> Option<u32> {
        self.rank_all.get(&word).copied()
    }

    /// Inverse of [`rank`](Self::rank).
    #[inline]
    pub fn unrank(&self, rank: u32) -> u32 {
        self.codewords[rank as usize]
    }

    /// Codewords of the given Hamming weight, sorted ascending.
    pub fn of_weight(&self, weight: usize) -> &[u32] {
        let bucket = WEIGHTS
            .iter()
            .position(|&x| x == weight)
            .unwrap_or_else(|| panic!("{weight} is not a Golay weight"));
        &self.by_weight[bucket]
    }

    /// Number of codewords of the given weight (`A` of eq. 12); 0 if the
    /// weight is not admissible.
    pub fn count_of_weight(&self, weight: usize) -> usize {
        WEIGHTS
            .iter()
            .position(|&x| x == weight)
            .map(|b| WEIGHT_COUNTS[b])
            .unwrap_or(0)
    }

    /// Rank of `word` within its weight bucket.
    #[inline]
    pub fn rank_in_weight(&self, word: u32) -> Option<u32> {
        self.rank_in_weight.get(&word).map(|&(_, r)| r)
    }

    /// Inverse of [`rank_in_weight`](Self::rank_in_weight).
    #[inline]
    pub fn unrank_in_weight(&self, weight: usize, rank: u32) -> u32 {
        self.of_weight(weight)[rank as usize]
    }

    /// Syndrome of a received 24-bit word under `H = [Bᵀ | I]`.
    #[inline]
    pub fn syndrome(&self, word: u32) -> u32 {
        // s_j = parity bit j of re-encoded message XOR received parity bit j
        let msg = word & 0xFFF;
        let reenc = self.encode(msg);
        ((reenc ^ word) >> 12) & 0xFFF
    }

    /// Maximum-likelihood decoding of up to 3 bit errors (and detection of
    /// many weight-4 patterns). Returns the corrected codeword.
    pub fn decode(&self, word: u32) -> u32 {
        let word = word & 0xFF_FFFF;
        let s = self.syndrome(word);
        let err = self.syndrome_table[s as usize];
        word ^ err
    }

    fn build_syndrome_table(rows: &[u32; 12], _codewords: &[u32]) -> Vec<u32> {
        // For G = [I|B] systematic, the syndrome of an error pattern e is
        // syndrome(e) computed exactly as in `syndrome`: re-encode low 12
        // bits and XOR high bits. Fill table with min-weight patterns,
        // weight 0..4 (the covering radius of G24 is 4).
        let syn = |word: u32| -> u32 {
            let msg = word & 0xFFF;
            let reenc = GolayCode::encode_with(rows, msg);
            ((reenc ^ word) >> 12) & 0xFFF
        };
        let mut table = vec![u32::MAX; 4096];
        table[0] = 0;
        let mut remaining = 4095usize;
        // weight 1..4 in order => first hit is minimal weight
        for w in 1..=4usize {
            let mut idx: Vec<usize> = (0..w).collect();
            loop {
                let mut e = 0u32;
                for &i in &idx {
                    e |= 1 << i;
                }
                let s = syn(e) as usize;
                if table[s] == u32::MAX {
                    table[s] = e;
                    remaining -= 1;
                }
                // next combination of `w` out of 24
                let mut i = w;
                loop {
                    if i == 0 {
                        break;
                    }
                    i -= 1;
                    if idx[i] != i + 24 - w {
                        idx[i] += 1;
                        for j in i + 1..w {
                            idx[j] = idx[j - 1] + 1;
                        }
                        break;
                    }
                    if i == 0 {
                        idx.clear();
                        break;
                    }
                }
                if idx.is_empty() {
                    break;
                }
            }
            if remaining == 0 {
                break;
            }
        }
        assert_eq!(remaining, 0, "covering radius violated — bad generator");
        table
    }
}

impl Default for GolayCode {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_distribution() {
        let g = GolayCode::new();
        let mut counts = [0usize; 25];
        for &c in g.codewords() {
            counts[c.count_ones() as usize] += 1;
        }
        assert_eq!(counts[0], 1);
        assert_eq!(counts[8], 759);
        assert_eq!(counts[12], 2576);
        assert_eq!(counts[16], 759);
        assert_eq!(counts[24], 1);
        assert_eq!(counts.iter().sum::<usize>(), 4096);
        // no other weights
        for w in 0..25 {
            if !WEIGHTS.contains(&w) {
                assert_eq!(counts[w], 0, "unexpected weight {w}");
            }
        }
    }

    #[test]
    fn linearity_and_self_duality() {
        let g = GolayCode::new();
        // closed under XOR (spot-check a grid of pairs)
        for i in (0..4096).step_by(97) {
            for j in (0..4096).step_by(113) {
                let c = g.codewords()[i] ^ g.codewords()[j];
                assert!(g.contains(c));
            }
        }
        // self-dual: every pair of codewords has even overlap (in fact ≡ 0 mod 2,
        // and G24 is doubly-even: weights ≡ 0 mod 4)
        for &c in g.codewords().iter().step_by(61) {
            assert_eq!(c.count_ones() % 4, 0);
        }
    }

    #[test]
    fn rank_unrank_roundtrip() {
        let g = GolayCode::new();
        for r in 0..NUM_CODEWORDS as u32 {
            let c = g.unrank(r);
            assert_eq!(g.rank(c), Some(r));
        }
        for &w in &WEIGHTS {
            let n = g.count_of_weight(w);
            for r in 0..n as u32 {
                let c = g.unrank_in_weight(w, r);
                assert_eq!(c.count_ones() as usize, w);
                assert_eq!(g.rank_in_weight(c), Some(r));
            }
        }
    }

    #[test]
    fn min_distance_is_8() {
        let g = GolayCode::new();
        let mut min = 24;
        for &c in g.codewords().iter().skip(1) {
            min = min.min(c.count_ones());
        }
        assert_eq!(min, 8);
    }

    #[test]
    fn syndrome_decoding_corrects_3_errors() {
        let g = GolayCode::new();
        let mut rng = crate::util::rng::Xoshiro256pp::new(99);
        for _ in 0..500 {
            let c = g.unrank(rng.next_range(4096) as u32);
            // inject 1..3 errors at distinct positions
            let nerr = 1 + rng.next_range(3) as usize;
            let mut e = 0u32;
            while (e.count_ones() as usize) < nerr {
                e |= 1 << rng.next_range(24);
            }
            let decoded = g.decode(c ^ e);
            assert_eq!(decoded, c, "failed to correct {nerr} errors");
        }
    }

    #[test]
    fn encode_is_systematic() {
        let g = GolayCode::new();
        for msg in [0u32, 1, 0xABC, 4095] {
            assert_eq!(g.encode(msg) & 0xFFF, msg);
        }
    }
}
