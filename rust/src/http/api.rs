//! The HTTP/SSE front door's routes: an OpenAI-style completions API
//! over the [`ModelRegistry`], plus model listing and a metrics dump.
//! Request/response schemas and the error-code table live in
//! `docs/PROTOCOL.md`; this module is deliberately a thin adapter — all
//! scheduling goes through the same [`Coordinator`] /
//! [`crate::coordinator::SchedulerCore`] the TCP worker uses, so the
//! sim-pinned scheduling semantics carry over unchanged.
//!
//! Routes:
//! * `POST /v1/completions` — token-in/token-out completion against a
//!   named model; `"stream": true` switches the response to SSE.
//! * `GET /v1/models` — every registered model with residency state.
//! * `GET /metrics` — text dump: one registry summary line plus one
//!   [`crate::coordinator::Metrics::snapshot`] STATS line per resident
//!   model.
//!
//! Every error body is `{"error": {"code": …, "message": …}}`; codes
//! (`bad-request`, `unknown-model`, `session-limit`, `kv-oom`, `busy`,
//! `internal`, …) are part of the wire contract and documented in
//! `docs/PROTOCOL.md`.

use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::coordinator::{accept_capped, Coordinator, GenEvent, ServeOptions};
use crate::http::wire::{
    read_request, sse_event, start_sse, write_response, Request, WireError,
};
use crate::model::registry::ModelRegistry;
use crate::model::sample::SampleParams;
use crate::util::json::{self, Json};

/// Serve the HTTP front door until the listener errors. Connection
/// capping reuses the TCP worker's claim/decrement machinery
/// ([`accept_capped`]); overflow connections get a one-shot `503 busy`
/// JSON body instead of the line protocol's `ERR busy`.
pub fn serve_http(
    registry: Arc<ModelRegistry>,
    listener: TcpListener,
    opts: ServeOptions,
) -> std::io::Result<()> {
    let max = opts.max_conns;
    accept_capped(
        listener,
        max,
        move |stream| {
            let _ = write_error(stream, 503, "busy", &format!("max {max} connections"), false);
        },
        move |stream| {
            let _ = handle_http_conn(&registry, stream);
        },
    )
}

/// One connection: keep-alive loop reading requests until the peer
/// closes, a handler asks for close (SSE), or a protocol error.
fn handle_http_conn(reg: &Arc<ModelRegistry>, stream: TcpStream) -> std::io::Result<()> {
    let peer = stream.try_clone()?;
    let mut reader = BufReader::new(peer);
    let mut out = stream;
    loop {
        match read_request(&mut reader) {
            Ok(None) => return Ok(()),
            Err(WireError { status, message }) => {
                // answer the protocol violation, then drop the
                // connection — framing is not recoverable
                return write_error(&mut out, status, "bad-request", &message, false);
            }
            Ok(Some(req)) => {
                let keep = route(reg, &req, &mut out)? && !req.wants_close();
                if !keep {
                    return Ok(());
                }
            }
        }
    }
}

/// Dispatch one request; returns whether the connection may be kept
/// alive (SSE responses are delimited by close, so they return false).
fn route(reg: &Arc<ModelRegistry>, req: &Request, out: &mut TcpStream) -> std::io::Result<bool> {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/completions") => completions(reg, req, out),
        ("GET", "/v1/models") => {
            let body = models_json(reg).to_string_compact();
            write_response(out, 200, "application/json", body.as_bytes(), true)?;
            Ok(true)
        }
        ("GET", "/metrics") => {
            let body = metrics_text(reg);
            write_response(out, 200, "text/plain; charset=utf-8", body.as_bytes(), true)?;
            Ok(true)
        }
        (_, "/v1/completions") | (_, "/v1/models") | (_, "/metrics") => {
            write_error(
                out,
                405,
                "method-not-allowed",
                &format!("{} not allowed on {}", req.method, req.path),
                true,
            )?;
            Ok(true)
        }
        _ => {
            write_error(
                out,
                404,
                "not-found",
                &format!("no route for {}", req.path),
                true,
            )?;
            Ok(true)
        }
    }
}

/// `GET /v1/models` body.
fn models_json(reg: &ModelRegistry) -> Json {
    let data: Vec<Json> = reg
        .models()
        .into_iter()
        .map(|m| {
            Json::obj(vec![
                ("id", Json::Str(m.name)),
                ("object", Json::Str("model".into())),
                ("config", Json::Str(m.config)),
                ("vocab", Json::Int(m.vocab as i64)),
                ("max_seq", Json::Int(m.max_seq as i64)),
                ("params", Json::Int(m.params as i64)),
                ("file_bytes", Json::Int(m.file_bytes as i64)),
                ("resident", Json::Bool(m.resident)),
                ("resident_bytes", Json::Int(m.resident_bytes as i64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("object", Json::Str("list".into())),
        ("data", Json::Arr(data)),
    ])
}

/// `GET /metrics` body: a registry summary line, then one canonical
/// STATS snapshot line per resident model (cold models report only
/// registration identity — nothing has run for them).
fn metrics_text(reg: &ModelRegistry) -> String {
    let mut s = String::new();
    s.push_str("# llvq serve-http metrics — field glossary: docs/OPERATIONS.md\n");
    s.push_str(&format!(
        "registry models={} resident={} budget_bytes={} total_resident_bytes={}\n",
        reg.len(),
        reg.resident_count(),
        reg.max_resident_bytes(),
        reg.resident_bytes(),
    ));
    let snaps = reg.snapshots();
    for (name, snap) in &snaps {
        s.push_str(&format!("model name={name} {snap}\n"));
    }
    for m in reg.models() {
        if !m.resident {
            s.push_str(&format!(
                "model name={} cold file_bytes={}\n",
                m.name, m.file_bytes
            ));
        }
    }
    s
}

/// A parsed `POST /v1/completions` body.
struct CompletionReq {
    model: String,
    prompt: Vec<u8>,
    max_tokens: usize,
    params: SampleParams,
    stream: bool,
}

/// Parse and shape-validate the completions request body (token-level
/// validation — vocab range, max_seq — happens against the model's
/// engine after registry lookup).
fn parse_completion(body: &[u8]) -> Result<CompletionReq, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let doc = json::parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let model = doc
        .get("model")
        .and_then(|v| v.as_str())
        .ok_or("missing string field 'model'")?
        .to_string();
    let prompt_field = doc.get("prompt").ok_or("missing field 'prompt'")?;
    let arr = prompt_field
        .as_arr()
        .ok_or("'prompt' must be an array of token ids")?;
    if arr.is_empty() {
        return Err("'prompt' must be non-empty".into());
    }
    let mut prompt = Vec::with_capacity(arr.len());
    for v in arr {
        let t = v
            .as_i64()
            .filter(|t| (0..=255).contains(t))
            .ok_or("'prompt' tokens must be integers in 0..=255")?;
        prompt.push(t as u8);
    }
    let max_tokens = match doc.get("max_tokens") {
        None => 16,
        Some(v) => v
            .as_i64()
            .filter(|n| *n >= 1)
            .ok_or("'max_tokens' must be an integer >= 1")? as usize,
    };
    let temperature = match doc.get("temperature") {
        None => 0.0,
        Some(v) => v.as_f64().ok_or("'temperature' must be a number")? as f32,
    };
    let top_k = match doc.get("top_k") {
        None => 0,
        Some(v) => v
            .as_i64()
            .filter(|n| *n >= 0)
            .ok_or("'top_k' must be an integer >= 0")? as usize,
    };
    let seed = match doc.get("seed") {
        None => 0,
        Some(v) => v
            .as_i64()
            .filter(|n| *n >= 0)
            .ok_or("'seed' must be an integer >= 0")? as u64,
    };
    let stream = match doc.get("stream") {
        None | Some(Json::Null) => false,
        Some(Json::Bool(b)) => *b,
        Some(_) => return Err("'stream' must be a boolean".into()),
    };
    Ok(CompletionReq {
        model,
        prompt,
        max_tokens,
        params: SampleParams {
            temperature,
            top_k,
            seed,
        },
        stream,
    })
}

/// Map a coordinator/scheduler error string to (status, wire code). The
/// scheduler's error texts are part of the TCP wire contract, so keying
/// on their stable prefixes is safe (pinned by `rust/tests/http.rs`).
fn map_coord_error(e: &str) -> (u16, &'static str) {
    if e.starts_with("kv-oom") {
        (503, "kv-oom")
    } else if e.starts_with("too many sessions") {
        (429, "session-limit")
    } else if e.starts_with("coordinator stopped") || e.starts_with("worker") {
        (500, "internal")
    } else {
        // validation-shaped: bad tokens, bad lengths, unknown session
        (400, "bad-request")
    }
}

/// Closes the session on every exit path — including a client that
/// disconnects mid-stream — unless the handler already closed it.
struct SessionGuard<'a> {
    coord: &'a Coordinator,
    sid: u64,
}

impl Drop for SessionGuard<'_> {
    fn drop(&mut self) {
        let _ = self.coord.close_session(self.sid);
    }
}

/// `POST /v1/completions`.
fn completions(
    reg: &Arc<ModelRegistry>,
    req: &Request,
    out: &mut TcpStream,
) -> std::io::Result<bool> {
    let c = match parse_completion(&req.body) {
        Ok(c) => c,
        Err(e) => {
            write_error(out, 400, "bad-request", &e, true)?;
            return Ok(true);
        }
    };
    let coord = match reg.coordinator(&c.model) {
        Ok(k) => k,
        Err(e) => {
            let (status, code) = if e.starts_with("unknown model") {
                (404, "unknown-model")
            } else {
                (500, "internal")
            };
            write_error(out, status, code, &e, true)?;
            return Ok(true);
        }
    };
    let max_seq = coord.engine().max_seq();
    if c.prompt.len() + c.max_tokens > max_seq {
        write_error(
            out,
            400,
            "bad-request",
            &format!(
                "prompt ({}) + max_tokens ({}) exceeds max_seq {max_seq}",
                c.prompt.len(),
                c.max_tokens
            ),
            true,
        )?;
        return Ok(true);
    }
    let sid = match coord.open_session() {
        Ok(s) => s,
        Err(e) => {
            let (status, code) = map_coord_error(&e);
            write_error(out, status, code, &e, true)?;
            return Ok(true);
        }
    };
    let guard = SessionGuard {
        coord: &coord,
        sid,
    };
    if let Err(e) = coord.feed(sid, c.prompt.clone()) {
        let (status, code) = map_coord_error(&e);
        write_error(out, status, code, &e, true)?;
        return Ok(true);
    }
    let events = match coord.generate(sid, c.max_tokens, c.params) {
        Ok(rx) => rx,
        Err(e) => {
            let (status, code) = map_coord_error(&e);
            write_error(out, status, code, &e, true)?;
            return Ok(true);
        }
    };
    let id = format!("cmpl-{sid}");
    if c.stream {
        // peek the first event before committing to SSE: admission
        // errors (kv-oom, bad session) still get a proper HTTP status
        let first = events.recv();
        let first_tok = match first {
            Ok(Ok(GenEvent::Token(t))) => Some(t),
            Ok(Ok(GenEvent::Done { .. })) => None,
            Ok(Err(e)) => {
                let (status, code) = map_coord_error(&e);
                write_error(out, status, code, &e, true)?;
                return Ok(true);
            }
            Err(_) => {
                write_error(out, 500, "internal", "generation aborted", true)?;
                return Ok(true);
            }
        };
        start_sse(out)?;
        if let Some(t) = first_tok {
            sse_event(out, &chunk_json(&id, &c.model, t))?;
            loop {
                match events.recv() {
                    Ok(Ok(GenEvent::Token(t))) => {
                        sse_event(out, &chunk_json(&id, &c.model, t))?
                    }
                    Ok(Ok(GenEvent::Done { .. })) | Err(_) => break,
                    Ok(Err(e)) => {
                        // mid-stream failure: surface it as a final
                        // error event — the HTTP status is already sent
                        sse_event(out, &error_json("internal", &e).to_string_compact())?;
                        break;
                    }
                }
            }
        }
        sse_event(out, "[DONE]")?;
        drop(guard); // close the session before the connection
        Ok(false) // SSE is delimited by connection close
    } else {
        let mut tokens: Vec<u8> = Vec::with_capacity(c.max_tokens);
        loop {
            match events.recv() {
                Ok(Ok(GenEvent::Token(t))) => tokens.push(t),
                Ok(Ok(GenEvent::Done { .. })) => break,
                Ok(Err(e)) => {
                    let (status, code) = map_coord_error(&e);
                    write_error(out, status, code, &e, true)?;
                    return Ok(true);
                }
                Err(_) => {
                    write_error(out, 500, "internal", "generation aborted", true)?;
                    return Ok(true);
                }
            }
        }
        drop(guard);
        let completion_tokens = tokens.len();
        let body = Json::obj(vec![
            ("id", Json::Str(id)),
            ("object", Json::Str("text_completion".into())),
            ("model", Json::Str(c.model.clone())),
            (
                "choices",
                Json::Arr(vec![Json::obj(vec![
                    ("index", Json::Int(0)),
                    (
                        "tokens",
                        Json::Arr(tokens.iter().map(|&t| Json::Int(t as i64)).collect()),
                    ),
                    ("finish_reason", Json::Str("length".into())),
                ])]),
            ),
            (
                "usage",
                Json::obj(vec![
                    ("prompt_tokens", Json::Int(c.prompt.len() as i64)),
                    ("completion_tokens", Json::Int(completion_tokens as i64)),
                    (
                        "total_tokens",
                        Json::Int((c.prompt.len() + completion_tokens) as i64),
                    ),
                ]),
            ),
        ]);
        write_response(
            out,
            200,
            "application/json",
            body.to_string_compact().as_bytes(),
            true,
        )?;
        Ok(true)
    }
}

/// One SSE completion chunk.
fn chunk_json(id: &str, model: &str, token: u8) -> String {
    Json::obj(vec![
        ("id", Json::Str(id.into())),
        ("object", Json::Str("text_completion.chunk".into())),
        ("model", Json::Str(model.into())),
        (
            "choices",
            Json::Arr(vec![Json::obj(vec![
                ("index", Json::Int(0)),
                ("token", Json::Int(token as i64)),
            ])]),
        ),
    ])
    .to_string_compact()
}

/// The canonical error body.
fn error_json(code: &str, message: &str) -> Json {
    Json::obj(vec![(
        "error",
        Json::obj(vec![
            ("code", Json::Str(code.into())),
            ("message", Json::Str(message.into())),
        ]),
    )])
}

/// Write one JSON error response.
fn write_error<W: Write>(
    w: &mut W,
    status: u16,
    code: &str,
    message: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let body = error_json(code, message).to_string_compact();
    write_response(w, status, "application/json", body.as_bytes(), keep_alive)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completion_body_parsing_defaults_and_errors() {
        let c = parse_completion(br#"{"model":"a","prompt":[1,2,3]}"#).unwrap();
        assert_eq!(c.model, "a");
        assert_eq!(c.prompt, vec![1, 2, 3]);
        assert_eq!(c.max_tokens, 16);
        assert_eq!(c.params.temperature, 0.0);
        assert!(!c.stream);
        let c = parse_completion(
            br#"{"model":"a","prompt":[0],"max_tokens":4,"temperature":0.5,"top_k":8,"seed":9,"stream":true}"#,
        )
        .unwrap();
        assert_eq!(c.max_tokens, 4);
        assert_eq!(c.params.top_k, 8);
        assert_eq!(c.params.seed, 9);
        assert!(c.stream);
        let bads: [&[u8]; 8] = [
            b"not json",
            br#"{"prompt":[1]}"#,
            br#"{"model":"a"}"#,
            br#"{"model":"a","prompt":[]}"#,
            br#"{"model":"a","prompt":["x"]}"#,
            br#"{"model":"a","prompt":[300]}"#,
            br#"{"model":"a","prompt":[1],"max_tokens":0}"#,
            br#"{"model":"a","prompt":[1],"stream":"yes"}"#,
        ];
        for bad in bads {
            assert!(parse_completion(bad).is_err(), "{:?}", String::from_utf8_lossy(bad));
        }
    }

    #[test]
    fn coord_errors_map_to_stable_codes() {
        assert_eq!(map_coord_error("kv-oom: page arena exhausted (4 pages of 16 tokens)").1, "kv-oom");
        assert_eq!(map_coord_error("too many sessions (max 64)"), (429, "session-limit"));
        assert_eq!(map_coord_error("worker gone").0, 500);
        assert_eq!(map_coord_error("token id 99 out of range (vocab 64)").0, 400);
    }
}
