//! Minimal HTTP/1.1 wire handling over `std::net` — request parsing,
//! response writing, and SSE streaming. Dependency-free by design, like
//! the rest of the crate: the front door needs exactly one verb pair
//! (`GET`/`POST`), fixed-length bodies, and `text/event-stream` output,
//! so a full HTTP stack would be dead weight. Protocol reference:
//! `docs/PROTOCOL.md`.
//!
//! Bounds are explicit and conservative (one request line ≤ 8 KiB, ≤ 64
//! header lines, body ≤ 1 MiB via `Content-Length`; chunked
//! transfer-encoding is refused with `501`): a completions request is a
//! few hundred bytes of JSON, so anything near the limits is abuse, not
//! traffic.

use std::io::{BufRead, Read, Write};

/// Request-line + header-line length bound.
const MAX_LINE: usize = 8 * 1024;
/// Header count bound.
const MAX_HEADERS: usize = 64;
/// `Content-Length` bound.
pub const MAX_BODY: usize = 1024 * 1024;

/// One parsed request. Header names are lowercased; the target is split
/// at `?` into `path` + `query`.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First value of a (lowercase) header name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// `Connection: close` requested (HTTP/1.1 defaults to keep-alive).
    pub fn wants_close(&self) -> bool {
        self.header("connection")
            .is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }
}

/// A malformed request, mapped to an HTTP status before any route runs.
#[derive(Debug)]
pub struct WireError {
    pub status: u16,
    pub message: String,
}

impl WireError {
    fn new(status: u16, message: impl Into<String>) -> Self {
        Self {
            status,
            message: message.into(),
        }
    }
}

/// Read one request off `r`. `Ok(None)` means the peer closed (or died
/// mid-request) — the caller just drops the connection. `Err` is a
/// protocol violation worth answering with its status before closing.
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Option<Request>, WireError> {
    // request line (tolerate blank lines between keep-alive requests)
    let line = loop {
        match read_line_bounded(r)? {
            None => return Ok(None),
            Some(l) if l.is_empty() => continue,
            Some(l) => break l,
        }
    };
    let mut parts = line.split(' ');
    let method = parts.next().unwrap_or_default().to_string();
    let target = parts.next().unwrap_or_default().to_string();
    let version = parts.next().unwrap_or_default();
    if method.is_empty() || target.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(WireError::new(400, format!("malformed request line '{line}'")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    // headers
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let l = match read_line_bounded(r)? {
            None => return Ok(None),
            Some(l) => l,
        };
        if l.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(WireError::new(431, "too many header lines"));
        }
        let Some((name, value)) = l.split_once(':') else {
            return Err(WireError::new(400, format!("malformed header line '{l}'")));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let req = Request {
        method,
        path,
        query,
        headers,
        body: Vec::new(),
    };
    if req
        .header("transfer-encoding")
        .is_some_and(|v| !v.eq_ignore_ascii_case("identity"))
    {
        return Err(WireError::new(501, "chunked transfer encoding not supported"));
    }
    let len = match req.header("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| WireError::new(400, format!("bad content-length '{v}'")))?,
    };
    if len > MAX_BODY {
        return Err(WireError::new(
            413,
            format!("body of {len} B exceeds the {MAX_BODY} B limit"),
        ));
    }
    let mut req = req;
    if len > 0 {
        let mut body = vec![0u8; len];
        if r.read_exact(&mut body).is_err() {
            return Ok(None); // peer died mid-body
        }
        req.body = body;
    }
    Ok(Some(req))
}

/// One `\r\n`- (or `\n`-) terminated line, byte-bounded. `Ok(None)` on
/// clean EOF or read error, `Err(431)` past [`MAX_LINE`].
fn read_line_bounded<R: BufRead>(r: &mut R) -> Result<Option<String>, WireError> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte) {
            Ok(0) | Err(_) => {
                return if buf.is_empty() { Ok(None) } else { Ok(Some(trim_line(buf))) }
            }
            Ok(_) => {}
        }
        if byte[0] == b'\n' {
            return Ok(Some(trim_line(buf)));
        }
        buf.push(byte[0]);
        if buf.len() > MAX_LINE {
            return Err(WireError::new(431, "request or header line too long"));
        }
    }
}

fn trim_line(mut buf: Vec<u8>) -> String {
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8_lossy(&buf).into_owned()
}

/// Canonical reason phrase for the statuses this server emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one fixed-length response. `keep_alive: false` advertises
/// `Connection: close`; the caller then drops the connection.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {conn}\r\n\r\n",
        status_reason(status),
        body.len(),
    )?;
    w.write_all(body)?;
    w.flush()
}

/// Start a `text/event-stream` response. SSE responses carry no
/// `Content-Length`, so the stream is delimited by connection close —
/// the caller must drop the connection after the final event.
pub fn start_sse<W: Write>(w: &mut W) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n"
    )?;
    w.flush()
}

/// One SSE event (`data: <payload>\n\n`), flushed so the client sees it
/// as soon as it is produced, not when the socket buffer fills.
pub fn sse_event<W: Write>(w: &mut W, data: &str) -> std::io::Result<()> {
    write!(w, "data: {data}\n\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(input: &str) -> Result<Option<Request>, WireError> {
        read_request(&mut BufReader::new(input.as_bytes()))
    }

    #[test]
    fn parses_request_line_headers_and_body() {
        let req = parse(
            "POST /v1/completions?x=1 HTTP/1.1\r\nHost: a\r\nContent-Length: 4\r\n\r\nbody",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.query, "x=1");
        assert_eq!(req.header("host"), Some("a"));
        assert_eq!(req.body, b"body");
        assert!(!req.wants_close());
    }

    #[test]
    fn eof_and_malformed_inputs() {
        assert!(parse("").unwrap().is_none(), "clean EOF");
        assert_eq!(parse("garbage\r\n\r\n").err().map(|e| e.status), Some(400));
        assert_eq!(
            parse("GET / HTTP/1.1\r\nno-colon-here\r\n\r\n")
                .err()
                .map(|e| e.status),
            Some(400)
        );
        let huge = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_LINE + 2));
        assert_eq!(parse(&huge).err().map(|e| e.status), Some(431));
        let chunked = "POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        assert_eq!(parse(chunked).err().map(|e| e.status), Some(501));
        let big = "POST / HTTP/1.1\r\nContent-Length: 9999999\r\n\r\n";
        assert_eq!(parse(big).err().map(|e| e.status), Some(413));
    }

    #[test]
    fn response_and_sse_shapes() {
        let mut out: Vec<u8> = Vec::new();
        write_response(&mut out, 200, "application/json", b"{}", true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
        let mut sse: Vec<u8> = Vec::new();
        start_sse(&mut sse).unwrap();
        sse_event(&mut sse, "{\"x\":1}").unwrap();
        let text = String::from_utf8(sse).unwrap();
        assert!(text.contains("Content-Type: text/event-stream\r\n"));
        assert!(text.ends_with("data: {\"x\":1}\n\n"));
    }
}
