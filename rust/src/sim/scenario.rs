//! The named workload corpus: ~6 scripted scenarios covering the
//! scheduler shapes that historically only the real-time soak test
//! sampled. Each is a seeded [`Trace`] generator — the structure is
//! fixed, the seed varies prompt contents and lengths through the
//! crate's own [`Xoshiro256pp`], so `(scenario, seed)` fully determines
//! the run and any failure replays from just those two values (or from
//! the committed `.trace` file `llvq sim --save-trace` writes).
//!
//! Every scenario is run by the `sim-scenarios` CI job and the
//! `rust/tests/sim.rs` suite (per-tick invariants + bit-identical
//! replay), and timed into `BENCH_serving.json` by `benches/serving.rs`.

use std::time::Duration;

use crate::coordinator::BatcherConfig;
use crate::model::kvpage::KvQuantKind;
use crate::model::sample::SampleParams;
use crate::util::rng::Xoshiro256pp;

use super::trace::{Action, EngineSpec, Trace};

/// Tiny-model vocabulary (qwen3-4b-tiny) — scenario tokens stay below
/// this.
const VOCAB: u64 = 64;

fn toks(rng: &mut Xoshiro256pp, n: usize) -> Vec<u8> {
    (0..n).map(|_| rng.next_range(VOCAB) as u8).collect()
}

fn greedy() -> SampleParams {
    SampleParams::default()
}

fn seeded(seed: u64) -> SampleParams {
    SampleParams {
        temperature: 0.8,
        top_k: 8,
        seed,
    }
}

/// The scheduler shapes under test. See each constructor for the story.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// Everyone arrives at once: 8 sessions open, feed, and generate on
    /// tick 0–1 against a 4-lane slate.
    Burst,
    /// Near-max_seq prompts from 4 sessions at `prefill_chunk=4`: tens
    /// of ticks of chunked prefill with GENs parked behind their jobs.
    LongPromptFlood,
    /// Streamers that trickle 2–4 token FEEDs for dozens of ticks,
    /// extending half-drained prefill jobs, then generate.
    SlowDrip,
    /// Rude clients: mid-prefill and mid-GEN disconnects under load,
    /// then a polite second wave that must find every slot reclaimed.
    DisconnectStorm,
    /// A 6-page arena thrashed by competing sessions: `kv-oom` refusals
    /// must leave sessions alive, and every page must drain back.
    KvOomThrash,
    /// v1 `NEXT` floods interleaved with v2 GEN streams plus one
    /// injected engine panic — the fairness and containment mix.
    MixedV1V2,
}

impl Scenario {
    pub const ALL: [Scenario; 6] = [
        Scenario::Burst,
        Scenario::LongPromptFlood,
        Scenario::SlowDrip,
        Scenario::DisconnectStorm,
        Scenario::KvOomThrash,
        Scenario::MixedV1V2,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Burst => "burst",
            Scenario::LongPromptFlood => "long-prompt-flood",
            Scenario::SlowDrip => "slow-drip",
            Scenario::DisconnectStorm => "disconnect-storm",
            Scenario::KvOomThrash => "kv-oom-thrash",
            Scenario::MixedV1V2 => "mixed-v1-v2",
        }
    }

    pub fn parse(s: &str) -> Result<Scenario, String> {
        Scenario::ALL
            .iter()
            .copied()
            .find(|sc| sc.name() == s)
            .ok_or_else(|| {
                let names: Vec<&str> = Scenario::ALL.iter().map(|sc| sc.name()).collect();
                format!("unknown scenario '{s}' ({})", names.join("|"))
            })
    }

    /// Generous quiescence bound for [`Simulator::run_to_end`]
    /// (exceeding it is a liveness violation, so the slack is deliberate).
    ///
    /// [`Simulator::run_to_end`]: super::harness::Simulator::run_to_end
    pub fn max_ticks(&self) -> u64 {
        match self {
            Scenario::LongPromptFlood => 400,
            _ => 200,
        }
    }

    /// Build the seeded trace.
    pub fn trace(&self, seed: u64) -> Trace {
        let mut rng = Xoshiro256pp::new(seed ^ 0x5eed_51u64);
        let mut t = match self {
            Scenario::Burst => burst(&mut rng),
            Scenario::LongPromptFlood => long_prompt_flood(&mut rng),
            Scenario::SlowDrip => slow_drip(&mut rng),
            Scenario::DisconnectStorm => disconnect_storm(&mut rng),
            Scenario::KvOomThrash => kv_oom_thrash(&mut rng),
            Scenario::MixedV1V2 => mixed_v1_v2(&mut rng),
        };
        t.normalize();
        t
    }
}

fn base_config() -> BatcherConfig {
    BatcherConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        max_sessions: 8,
        prefill_chunk: 4,
    }
}

fn burst(rng: &mut Xoshiro256pp) -> Trace {
    let mut t = Trace::new(base_config(), EngineSpec::Dense { seed: 9 });
    for c in 1..=8u32 {
        let prompt = 8 + rng.next_range(9) as usize; // 8..=16
        let n = 4 + rng.next_range(3) as usize; // 4..=6
        t.push(0, c, Action::Open);
        t.push(0, c, Action::Feed(toks(rng, prompt)));
        let params = if c % 2 == 0 { greedy() } else { seeded(c as u64) };
        t.push(1, c, Action::Gen { n, params });
        t.push(90, c, Action::Close);
    }
    t.push(91, 1, Action::Stats);
    t
}

fn long_prompt_flood(rng: &mut Xoshiro256pp) -> Trace {
    let mut t = Trace::new(base_config(), EngineSpec::Dense { seed: 9 });
    for c in 1..=4u32 {
        let prompt = 56 + rng.next_range(5) as usize; // 56..=60 of max_seq 64
        t.push(u64::from(c) - 1, c, Action::Open);
        t.push(u64::from(c) - 1, c, Action::Feed(toks(rng, prompt)));
        // parks behind the still-draining job (waiting_gen path)
        t.push(u64::from(c), c, Action::Gen { n: 2, params: greedy() });
        t.push(150, c, Action::Close);
    }
    t.push(151, 1, Action::Stats);
    t
}

fn slow_drip(rng: &mut Xoshiro256pp) -> Trace {
    let mut t = Trace::new(base_config(), EngineSpec::Dense { seed: 9 });
    for c in 1..=3u32 {
        t.push(0, c, Action::Open);
        // 6 drips of 2–4 tokens, 5 ticks apart, staggered per conn:
        // some land on an idle session, some extend a half-drained job
        for drip in 0..6u64 {
            let n = 2 + rng.next_range(3) as usize; // 2..=4
            t.push(1 + drip * 5 + u64::from(c), c, Action::Feed(toks(rng, n)));
        }
        t.push(40, c, Action::Gen { n: 8, params: seeded(u64::from(c) * 7) });
        t.push(80, c, Action::Close);
    }
    t.push(81, 1, Action::Stats);
    t
}

fn disconnect_storm(rng: &mut Xoshiro256pp) -> Trace {
    let mut t = Trace::new(
        BatcherConfig {
            max_sessions: 12,
            ..base_config()
        },
        EngineSpec::Dense { seed: 9 },
    );
    // first wave: 8 sessions under load, all of them rude
    for c in 1..=8u32 {
        let prompt = 20 + rng.next_range(21) as usize; // 20..=40
        t.push(0, c, Action::Open);
        t.push(0, c, Action::Feed(toks(rng, prompt)));
        if c % 2 == 0 {
            // disconnects land mid-GEN
            t.push(2, c, Action::Gen { n: 6, params: seeded(u64::from(c)) });
        }
        // staggered drops: mid-prefill for the odd conns, mid-GEN for
        // the even ones
        t.push(3 + u64::from(c), c, Action::Disconnect);
    }
    // second wave: polite clients must find every slot and page back
    for c in 9..=12u32 {
        let prompt = 6 + rng.next_range(7) as usize; // 6..=12
        t.push(20, c, Action::Open);
        t.push(20, c, Action::Feed(toks(rng, prompt)));
        t.push(21, c, Action::Gen { n: 4, params: greedy() });
        t.push(70, c, Action::Close);
    }
    t.push(71, 9, Action::Stats);
    t
}

fn kv_oom_thrash(rng: &mut Xoshiro256pp) -> Trace {
    // 6-page arena of 4-token pages: three 6-token prompts fill it
    // (2 pages each), so the fourth session's FEED must answer kv-oom
    // and survive to retry after the disconnect wave frees pages
    let mut t = Trace::new(
        base_config(),
        EngineSpec::Paged {
            seed: 9,
            pages: 6,
            page_tokens: 4,
            hot_window: 8,
            quant: KvQuantKind::None,
        },
    );
    for c in 1..=3u32 {
        t.push(0, c, Action::Open);
        t.push(0, c, Action::Feed(toks(rng, 6)));
    }
    // fits the slack of conn 1's two reserved pages (6 used of 8)
    t.push(2, 1, Action::Gen { n: 2, params: greedy() });
    t.push(0, 4, Action::Open);
    t.push(1, 4, Action::Feed(toks(rng, 8))); // arena full -> ERR kv-oom
    t.push(4, 2, Action::Disconnect); // frees 2 pages
    t.push(6, 3, Action::Disconnect); // frees 2 more
    t.push(8, 4, Action::Feed(toks(rng, 6))); // retry now fits
    t.push(10, 4, Action::Gen { n: 2, params: greedy() });
    t.push(12, 5, Action::Open);
    t.push(12, 5, Action::Feed(toks(rng, 20))); // 5 pages -> kv-oom again
    t.push(14, 5, Action::Feed(toks(rng, 4)));
    t.push(16, 5, Action::Gen { n: 1, params: greedy() });
    t.push(18, 5, Action::Disconnect);
    t.push(22, 1, Action::Close);
    t.push(26, 4, Action::Close);
    t.push(27, 1, Action::Stats);
    t
}

fn mixed_v1_v2(rng: &mut Xoshiro256pp) -> Trace {
    let mut t = Trace::new(base_config(), EngineSpec::Dense { seed: 9 });
    // v2 streamers
    for c in 1..=2u32 {
        let prompt = 10 + rng.next_range(11) as usize; // 10..=20
        t.push(0, c, Action::Open);
        t.push(0, c, Action::Feed(toks(rng, prompt)));
        t.push(1, c, Action::Gen { n: 10, params: seeded(u64::from(c) * 13) });
    }
    // v1 NEXT flood riding alongside — one prefix batch per tick keeps
    // these from starving the decode slate (the fairness fix this
    // scenario pins)
    for c in 3..=4u32 {
        for i in 0..6u64 {
            let n = 2 + rng.next_range(5) as usize; // 2..=6
            t.push(1 + i, c, Action::Next(toks(rng, n)));
        }
    }
    // one contained engine fault mid-storm: whichever call it lands on,
    // exactly one batch/job fails and the scheduler survives
    t.push(4, 0, Action::Panic { calls: 1 });
    t.push(60, 1, Action::Close);
    t.push(60, 2, Action::Close);
    t.push(61, 3, Action::Stats);
    t
}
