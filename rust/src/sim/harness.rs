//! The deterministic simulator: a virtual-clock driver of
//! [`SchedulerCore`].
//!
//! [`Simulator::step`] runs one virtual tick: apply every scripted
//! [`TraceEvent`] due at the current tick (event intake through the same
//! [`SchedulerCore::handle`] the worker thread uses), run one
//! [`SchedulerCore::tick`], drain every reply/stream channel, then
//! assert the per-tick invariants. No threads, no sockets, no wall
//! time — `Pending::enqueued` is `None`, so not even the latency metric
//! reads a clock. The same trace therefore produces a bit-identical
//! reply log, final [`Metrics::snapshot`] line, and
//! [`SimReport::fingerprint`] on every run, on every machine, at every
//! kernel thread count.
//!
//! Replies are logged in the TCP front-end's exact wire formats
//! (`OK session=…`, `QUEUED n`, `TOK t`, `ERR kv-oom: …`), so a
//! simulator log reads like a multiplexed protocol transcript and the
//! TCP-equivalence test can diff the two surfaces line-for-line.
//!
//! Engine faults are scripted through [`FaultInjector`], a
//! [`BatchForward`] wrapper that panics on the next N forward calls —
//! exercising the scheduler's `catch_unwind` containment without a real
//! bug.
//!
//! # Per-tick invariants (first violation wins; see
//! [`Simulator::violation`])
//!
//! * session accounting — `Metrics::open_sessions` equals parked +
//!   active + prefilling, and never exceeds `max_sessions`;
//! * slate bounds — at most one batched decode step per tick, carrying
//!   at most `max_batch` lanes;
//! * page balance — `allocated ≤ budget` and
//!   `alloc_total − freed_total == allocated` (a leaked or double-freed
//!   page trips this the tick it happens);
//! * no starved prefill — every queued prefill job makes cursor
//!   progress at least once per `max_sessions + 2` ticks (the fair
//!   rotation bound).

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, TryRecvError};
use std::sync::Arc;

use crate::coordinator::{
    validate_tokens, BatchForward, GenEvent, Metrics, Msg, Pending, SchedulerCore,
};
use crate::model::kvpage::KvPageCounters;
use crate::model::sample::argmax;
use crate::model::transformer::{KvStore, StepLane};

use super::trace::{Action, Trace, TraceEvent};

/// A [`BatchForward`] wrapper that injects engine panics on demand: each
/// [`FaultInjector::arm`]ed charge makes the next forward-path call
/// (`forward_batch` / `prefill` / `decode_step`) panic. Identity
/// methods and session open/close always delegate — a fault engine must
/// still free pages, or the page-balance invariant (rightly) trips.
pub struct FaultInjector {
    inner: Arc<dyn BatchForward>,
    armed: AtomicU64,
}

impl FaultInjector {
    pub fn new(inner: Arc<dyn BatchForward>) -> Self {
        Self {
            inner,
            armed: AtomicU64::new(0),
        }
    }

    /// Arm `calls` more one-shot faults.
    pub fn arm(&self, calls: u64) {
        self.armed.fetch_add(calls, Ordering::SeqCst);
    }

    fn trip(&self) {
        if self.armed.load(Ordering::SeqCst) > 0 {
            self.armed.fetch_sub(1, Ordering::SeqCst);
            panic!("sim: injected engine fault");
        }
    }
}

impl BatchForward for FaultInjector {
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn max_seq(&self) -> usize {
        self.inner.max_seq()
    }

    fn forward_batch(&self, batch: &[Vec<u8>]) -> Vec<Vec<f32>> {
        self.trip();
        self.inner.forward_batch(batch)
    }

    fn open_session(&self) -> Box<dyn KvStore> {
        self.inner.open_session()
    }

    fn prefill(&self, cache: &mut dyn KvStore, tokens: &[u8]) -> Vec<f32> {
        self.trip();
        self.inner.prefill(cache, tokens)
    }

    fn decode_step(&self, lanes: &mut [StepLane<'_>]) -> Vec<Vec<f32>> {
        self.trip();
        self.inner.decode_step(lanes)
    }

    fn close_session(&self, cache: Box<dyn KvStore>) {
        self.inner.close_session(cache)
    }

    fn kv_counters(&self) -> Option<Arc<KvPageCounters>> {
        self.inner.kv_counters()
    }

    fn kv_page_budget(&self) -> usize {
        self.inner.kv_page_budget()
    }

    fn kv_page_tokens(&self) -> usize {
        self.inner.kv_page_tokens()
    }

    fn kv_quant_label(&self) -> String {
        self.inner.kv_quant_label()
    }

    fn backend_name(&self) -> String {
        self.inner.backend_name()
    }

    fn resident_weight_bytes(&self) -> usize {
        self.inner.resident_weight_bytes()
    }

    fn threads(&self) -> usize {
        self.inner.threads()
    }

    fn simd_label(&self) -> String {
        self.inner.simd_label()
    }
}

/// One scripted client connection's live state.
#[derive(Default)]
struct Conn {
    sid: Option<u64>,
    /// Streaming GEN in flight (the stream receiver the TCP handler
    /// would be blocking on).
    gen: Option<Receiver<Result<GenEvent, String>>>,
    /// Tokens streamed by the current GEN (resets per GEN, for the
    /// `OK generated=` count).
    gen_count: usize,
    /// Outstanding v1 NEXT replies, FIFO (the prefix queue answers in
    /// order).
    pending_next: VecDeque<Receiver<Result<Vec<f32>, String>>>,
    /// Every TOK payload this connection ever received, in order.
    toks: Vec<u8>,
    /// Every reply line, in order, wire-format — diffable against a
    /// real TCP transcript.
    replies: Vec<String>,
}

/// Deltas and streaks the per-tick invariant checks compare against.
#[derive(Default)]
struct Book {
    steps: u64,
    lanes: u64,
    /// Per prefilling sid: (last cursor seen, consecutive no-progress
    /// ticks).
    prefill: HashMap<u64, (usize, u64)>,
}

/// The result of a completed (or aborted) simulator run.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Virtual ticks executed.
    pub ticks: u64,
    /// The full reply log: `t=<tick> c=<conn> <wire line>`.
    pub log: Vec<String>,
    /// Final [`Metrics::snapshot`] line.
    pub stats: String,
    /// Per-connection TOK payloads, in stream order.
    pub conn_tokens: BTreeMap<u32, Vec<u8>>,
    /// Per-connection reply lines, in wire format.
    pub conn_replies: BTreeMap<u32, Vec<String>>,
    /// First invariant violation (or non-quiescence), if any.
    pub violation: Option<String>,
}

impl SimReport {
    /// No invariant tripped and the run quiesced.
    pub fn ok(&self) -> bool {
        self.violation.is_none()
    }

    /// The log as one newline-joined block (byte-exact across runs).
    pub fn log_text(&self) -> String {
        let mut s = self.log.join("\n");
        s.push('\n');
        s
    }

    /// FNV-1a over log + final stats — the one-number determinism
    /// check two runs of the same trace must agree on.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |s: &str| {
            for &b in s.as_bytes() {
                h ^= u64::from(b);
                h = h.wrapping_mul(0x1_0000_0000_01b3);
            }
            h ^= u64::from(b'\n');
            h = h.wrapping_mul(0x1_0000_0000_01b3);
        };
        for line in &self.log {
            eat(line);
        }
        eat(&self.stats);
        h
    }
}

fn sync_reply<T>(rx: Receiver<Result<T, String>>) -> Result<T, String> {
    // `SchedulerCore::handle` answers every reply channel synchronously,
    // so the reply is already buffered by the time handle() returns
    match rx.try_recv() {
        Ok(r) => r,
        Err(_) => Err("worker dropped request".into()),
    }
}

/// The virtual-clock scheduler simulator. See the module doc.
pub struct Simulator {
    core: SchedulerCore,
    fault: Arc<FaultInjector>,
    now: u64,
    events: VecDeque<TraceEvent>,
    conns: BTreeMap<u32, Conn>,
    log: Vec<String>,
    violation: Option<String>,
    book: Book,
}

impl Simulator {
    /// Build the trace's own engine spec and simulate over it.
    pub fn new(trace: &Trace) -> Result<Simulator, String> {
        Ok(Self::with_engine(trace.setup.engine.build()?, trace))
    }

    /// Simulate `trace`'s events and scheduler config over a caller-built
    /// engine (e.g. a fused-backend engine the spec line cannot
    /// describe). The engine is wrapped in a [`FaultInjector`] either
    /// way, so `panic` events keep working.
    pub fn with_engine(engine: Arc<dyn BatchForward>, trace: &Trace) -> Simulator {
        let fault = Arc::new(FaultInjector::new(engine));
        let core = SchedulerCore::new(
            fault.clone() as Arc<dyn BatchForward>,
            trace.setup.batcher,
            Arc::new(Metrics::default()),
        );
        let mut events = trace.events.clone();
        events.sort_by_key(|e| e.at);
        Simulator {
            core,
            fault,
            now: 0,
            events: events.into(),
            conns: BTreeMap::new(),
            log: Vec::new(),
            violation: None,
            book: Book::default(),
        }
    }

    /// Current virtual tick.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// The scheduler state under test.
    pub fn core(&self) -> &SchedulerCore {
        &self.core
    }

    /// First invariant violation so far, if any.
    pub fn violation(&self) -> Option<&str> {
        self.violation.as_deref()
    }

    /// The reply log so far.
    pub fn log_lines(&self) -> &[String] {
        &self.log
    }

    /// All scripted events applied, every queue idle, every stream and
    /// one-shot reply delivered.
    pub fn done(&self) -> bool {
        self.events.is_empty()
            && !self.core.has_runnable_work()
            && self
                .conns
                .values()
                .all(|c| c.gen.is_none() && c.pending_next.is_empty())
    }

    /// One virtual tick: due events → scheduler tick → channel drain →
    /// invariant checks.
    pub fn step(&mut self) {
        while self.events.front().is_some_and(|e| e.at <= self.now) {
            let ev = self.events.pop_front().expect("front checked");
            self.apply(ev);
        }
        self.core.tick();
        self.drain();
        self.check_invariants();
        self.now += 1;
    }

    /// Step until [`Simulator::done`] or `max_ticks`, then report.
    /// Non-quiescence within the bound is itself recorded as a
    /// violation — a liveness failure, not a timeout.
    pub fn run_to_end(&mut self, max_ticks: u64) -> SimReport {
        while !self.done() && self.now < max_ticks {
            self.step();
        }
        if !self.done() && self.violation.is_none() {
            self.violation = Some(format!("did not quiesce within {max_ticks} ticks"));
        }
        self.report()
    }

    /// Snapshot the run so far as a [`SimReport`].
    pub fn report(&self) -> SimReport {
        SimReport {
            ticks: self.now,
            log: self.log.clone(),
            stats: self
                .core
                .metrics()
                .snapshot(self.core.engine().as_ref())
                .line(),
            conn_tokens: self
                .conns
                .iter()
                .map(|(&c, conn)| (c, conn.toks.clone()))
                .collect(),
            conn_replies: self
                .conns
                .iter()
                .map(|(&c, conn)| (c, conn.replies.clone()))
                .collect(),
            violation: self.violation.clone(),
        }
    }

    /// The step-through debug printer: one occupancy line plus the
    /// shared stats line (same [`Metrics::snapshot`] formatter as the
    /// TCP `STATS` reply).
    pub fn dump(&self) -> String {
        let occ = self.core.occupancy();
        let parked: Vec<String> = occ.parked.iter().map(|s| s.to_string()).collect();
        let active: Vec<String> = occ
            .active
            .iter()
            .map(|(s, r)| format!("{s}:{r}"))
            .collect();
        let pre: Vec<String> = occ
            .prefilling
            .iter()
            .map(|(s, c, n)| format!("{s}:{c}/{n}"))
            .collect();
        format!(
            "t={} parked=[{}] active=[{}] prefill=[{}] prefix={}\nstats: {}",
            self.now,
            parked.join(","),
            active.join(","),
            pre.join(","),
            occ.prefix_queued,
            self.core
                .metrics()
                .snapshot(self.core.engine().as_ref())
                .line()
        )
    }

    fn conn(&mut self, c: u32) -> &mut Conn {
        self.conns.entry(c).or_default()
    }

    /// Record one wire-format reply line for `conn`, tick-stamped in the
    /// global log.
    fn reply(&mut self, conn: u32, line: String) {
        self.log.push(format!("t={} c={conn} {line}", self.now));
        self.conn(conn).replies.push(line);
    }

    fn apply(&mut self, ev: TraceEvent) {
        let TraceEvent { conn, action, .. } = ev;
        match action {
            Action::Open => {
                if self.conn(conn).sid.is_some() {
                    self.reply(conn, "ERR session already open on this connection".into());
                    return;
                }
                let (tx, rx) = channel();
                self.core.handle(Msg::Open { reply: tx });
                let line = match sync_reply(rx) {
                    Ok(s) => {
                        self.conn(conn).sid = Some(s);
                        format!("OK session={s}")
                    }
                    Err(e) => format!("ERR {e}"),
                };
                self.reply(conn, line);
            }
            Action::Feed(tokens) => {
                let Some(sid) = self.conn(conn).sid else {
                    self.reply(conn, "ERR no open session (send OPEN first)".into());
                    return;
                };
                // client-surface validation parity with Coordinator::feed
                if let Err(e) = validate_tokens(self.core.engine().as_ref(), &tokens) {
                    self.reply(conn, format!("ERR {e}"));
                    return;
                }
                let (tx, rx) = channel();
                self.core.handle(Msg::Feed {
                    sid,
                    tokens,
                    reply: tx,
                });
                let line = match sync_reply(rx) {
                    Ok(n) => format!("QUEUED {n}"),
                    Err(e) => format!("ERR {e}"),
                };
                self.reply(conn, line);
            }
            Action::Gen { n, params } => {
                let Some(sid) = self.conn(conn).sid else {
                    self.reply(conn, "ERR no open session (send OPEN first)".into());
                    return;
                };
                if self.conn(conn).gen.is_some() {
                    // a real TCP client cannot pipeline GENs (the handler
                    // blocks on the stream); a scripted one can — reject
                    self.reply(conn, "ERR previous GEN still streaming".into());
                    return;
                }
                if n == 0 {
                    // mirrors Coordinator::generate's pre-check
                    self.reply(conn, "ERR GEN needs n >= 1".into());
                    return;
                }
                let (tx, rx) = channel();
                self.core.handle(Msg::Gen {
                    sid,
                    n,
                    params,
                    stream: tx,
                });
                let c = self.conn(conn);
                c.gen = Some(rx);
                c.gen_count = 0;
            }
            Action::Close => {
                let Some(sid) = self.conn(conn).sid.take() else {
                    self.reply(conn, "ERR no open session".into());
                    return;
                };
                let (tx, rx) = channel();
                self.core.handle(Msg::Close { sid, reply: tx });
                let line = match sync_reply(rx) {
                    Ok(len) => format!("OK closed len={len}"),
                    Err(e) => format!("ERR {e}"),
                };
                self.reply(conn, line);
            }
            Action::Disconnect => {
                // rude drop, in handle_conn's order: the GEN stream
                // receiver dies with the socket, then the session closes
                let c = self.conn(conn);
                c.gen = None;
                c.pending_next.clear();
                let sid = c.sid.take();
                self.log
                    .push(format!("t={} c={conn} <disconnected>", self.now));
                if let Some(sid) = sid {
                    let (tx, rx) = channel();
                    self.core.handle(Msg::Close { sid, reply: tx });
                    let _ = rx.try_recv(); // a rude client never reads it
                }
            }
            Action::Next(tokens) => {
                // validation parity with Coordinator::submit
                if let Err(e) = validate_tokens(self.core.engine().as_ref(), &tokens) {
                    self.reply(conn, format!("ERR {e}"));
                    return;
                }
                let (tx, rx) = channel();
                self.core.handle(Msg::Prefix(Pending {
                    tokens,
                    reply: tx,
                    enqueued: None, // virtual time: never read a wall clock
                }));
                self.conn(conn).pending_next.push_back(rx);
            }
            Action::Stats => {
                let line = format!(
                    "OK {}",
                    self.core
                        .metrics()
                        .snapshot(self.core.engine().as_ref())
                        .line()
                );
                self.reply(conn, line);
            }
            Action::Panic { calls } => {
                self.fault.arm(calls);
                self.log
                    .push(format!("t={} <panic armed x{calls}>", self.now));
            }
        }
    }

    /// Deliver everything the tick produced: outstanding NEXT replies
    /// (front-first — the prefix queue is FIFO) and GEN stream events,
    /// per connection in ascending id order (a BTreeMap, so the log
    /// order is deterministic).
    fn drain(&mut self) {
        let ids: Vec<u32> = self.conns.keys().copied().collect();
        for id in ids {
            loop {
                let res = match self.conns.get(&id).and_then(|c| c.pending_next.front()) {
                    Some(rx) => match rx.try_recv() {
                        Ok(r) => Some(r),
                        Err(TryRecvError::Empty) => None,
                        Err(TryRecvError::Disconnected) => {
                            Some(Err("worker dropped request".into()))
                        }
                    },
                    None => None,
                };
                let Some(r) = res else { break };
                self.conns
                    .get_mut(&id)
                    .expect("id from keys")
                    .pending_next
                    .pop_front();
                let line = match r {
                    Ok(logits) => {
                        let bi = argmax(&logits);
                        format!("OK next={bi} logit={:.4}", logits[bi])
                    }
                    Err(e) => format!("ERR {e}"),
                };
                self.reply(id, line);
            }
            loop {
                let res = match self.conns.get(&id).and_then(|c| c.gen.as_ref()) {
                    Some(rx) => match rx.try_recv() {
                        Ok(r) => Some(Some(r)),
                        Err(TryRecvError::Empty) => None,
                        Err(TryRecvError::Disconnected) => Some(None),
                    },
                    None => None,
                };
                let Some(r) = res else { break };
                match r {
                    Some(Ok(GenEvent::Token(t))) => {
                        let c = self.conns.get_mut(&id).expect("id from keys");
                        c.toks.push(t);
                        c.gen_count += 1;
                        self.reply(id, format!("TOK {t}"));
                    }
                    Some(Ok(GenEvent::Done { len })) => {
                        let g = {
                            let c = self.conns.get_mut(&id).expect("id from keys");
                            c.gen = None;
                            c.gen_count
                        };
                        self.reply(id, format!("OK generated={g} len={len}"));
                    }
                    Some(Err(e)) => {
                        self.conns.get_mut(&id).expect("id from keys").gen = None;
                        self.reply(id, format!("ERR {e}"));
                    }
                    None => {
                        // sender dropped without Done/Err — mirror the
                        // TCP handler's abort line
                        self.conns.get_mut(&id).expect("id from keys").gen = None;
                        self.reply(id, "ERR generation aborted".into());
                    }
                }
            }
        }
    }

    fn violate(&mut self, msg: String) {
        if self.violation.is_none() {
            self.violation = Some(format!("tick {}: {msg}", self.now));
        }
    }

    fn check_invariants(&mut self) {
        let occ = self.core.occupancy();
        let cfg = *self.core.config();
        let m = Arc::clone(self.core.metrics());
        let open = occ.parked.len() + occ.active.len() + occ.prefilling.len();
        let counted = m.open_sessions.load(Ordering::Relaxed) as usize;
        if counted != open {
            self.violate(format!(
                "session leak: metrics count {counted} open sessions, scheduler holds {open}"
            ));
        }
        if open > cfg.max_sessions {
            self.violate(format!(
                "admission breach: {open} sessions open, max_sessions={}",
                cfg.max_sessions
            ));
        }
        // the STATS/metrics snapshot surface must agree with the
        // scheduler's own occupancy — this is what /metrics and the
        // registry's per-model rows report, so drift here is a lie to
        // operators (the registry-aware `models=` gauge must also stay a
        // sane count: >= 1 always, single-model default exactly 1)
        let snap = m.snapshot(self.core.engine().as_ref());
        match snap.get("sessions").and_then(|v| v.parse::<usize>().ok()) {
            Some(s) if s == open => {}
            other => self.violate(format!(
                "snapshot sessions={other:?} disagrees with scheduler occupancy {open}"
            )),
        }
        match snap.get("models").and_then(|v| v.parse::<u64>().ok()) {
            Some(n) if n >= 1 => {}
            other => self.violate(format!(
                "snapshot models={other:?} is not a sane registry gauge (expected >= 1)"
            )),
        }
        let steps = m.decode_steps.load(Ordering::Relaxed);
        let lanes = m.decode_lanes.load(Ordering::Relaxed);
        let dsteps = steps.saturating_sub(self.book.steps);
        let dlanes = lanes.saturating_sub(self.book.lanes);
        if dsteps > 1 {
            self.violate(format!("{dsteps} decode steps in one tick"));
        }
        if dlanes > dsteps * cfg.max_batch as u64 {
            self.violate(format!(
                "decode slate carried {dlanes} lanes in one tick (max_batch={})",
                cfg.max_batch
            ));
        }
        self.book.steps = steps;
        self.book.lanes = lanes;
        if let Some(c) = m.kv.get() {
            let allocated = c.allocated.load(Ordering::Relaxed);
            let budget = self.core.engine().kv_page_budget();
            if allocated > budget {
                self.violate(format!("kv arena over budget: {allocated}/{budget} pages"));
            }
            let at = c.alloc_total.load(Ordering::Relaxed);
            let ft = c.freed_total.load(Ordering::Relaxed);
            if at.checked_sub(ft) != Some(allocated as u64) {
                self.violate(format!(
                    "kv page counters do not balance: alloc_total={at} freed_total={ft} allocated={allocated}"
                ));
            }
        }
        // fair rotation grants every queued job a chunk at least once per
        // queue-length ticks; max_sessions bounds the queue, +2 is slack
        // for the tick the job was queued on
        let bound = cfg.max_sessions as u64 + 2;
        let mut prefill = HashMap::new();
        for &(sid, cursor, _len) in &occ.prefilling {
            let streak = match self.book.prefill.get(&sid) {
                Some(&(c, s)) if c == cursor => s + 1,
                _ => 0,
            };
            if streak > bound {
                self.violate(format!(
                    "prefill starvation: session {sid} made no progress for {streak} ticks"
                ));
            }
            prefill.insert(sid, (cursor, streak));
        }
        self.book.prefill = prefill;
    }
}
