//! Scripted event traces for the deterministic scheduler simulator.
//!
//! A trace is a self-contained description of one simulator run: the
//! scheduler configuration, the engine to build, and a list of client
//! events pinned to virtual ticks. The text form is line-oriented so a
//! failing run can be committed verbatim (see `rust/tests/sim_traces/`)
//! and replayed byte-exactly forever — [`Trace::parse`] and
//! [`Trace::to_text`] round-trip, pinned by a unit test.
//!
//! # Format (`.trace`, one directive per line)
//!
//! ```text
//! # comments and blank lines are ignored
//! config max_batch=4 max_wait_ms=2 max_sessions=8 prefill_chunk=4
//! engine paged seed=9 pages=6 page_tokens=4 hot=8 quant=none
//! tick 0 conn 1 open
//! tick 0 conn 1 feed 5,6,7
//! tick 1 conn 1 gen 4 temp=0 topk=0 seed=0
//! tick 2 conn 2 next 5,6
//! tick 2 conn 2 stats
//! tick 3 panic 1
//! tick 30 conn 1 close
//! tick 31 conn 2 disconnect
//! ```
//!
//! * `config` / `engine` — the [`SimSetup`] header. Omitted keys take
//!   the defaults of [`BatcherConfig`] / [`EngineSpec`]. `engine dense`
//!   builds a dense-KV tiny-model engine; `engine paged` an arena-backed
//!   one (`quant` ∈ `none|e8|llvq`). Weights are `Weights::random` over
//!   the committed `qwen3-4b-tiny` config, so a seed fully determines
//!   the model.
//! * `tick <t> conn <c> <action>` — apply a client action at virtual
//!   tick `t` (before that tick's scheduler pass). `open`, `feed`,
//!   `gen`, `close`, `disconnect`, `next`, `stats` mirror the TCP verbs
//!   (`disconnect` is a rude drop: the GEN stream is abandoned and the
//!   session closed, exactly what `handle_conn` does when a socket
//!   dies).
//! * `tick <t> panic <k>` — arm the fault injector: the next `k` engine
//!   calls (prefill / decode / one-shot forward) panic, exercising the
//!   scheduler's `catch_unwind` containment.
//!
//! Events within one tick apply in file order; [`Trace::normalize`]
//! stable-sorts by tick without disturbing that order.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::{BackendEngine, BatchForward, BatcherConfig};
use crate::model::backend::ExecutionBackend;
use crate::model::config::config_by_name;
use crate::model::kvpage::KvQuantKind;
use crate::model::sample::SampleParams;
use crate::model::transformer::Weights;

/// Which engine a trace runs against. Everything is derived from the
/// committed tiny-model config plus the seeds below, so a spec line
/// fully determines the forward pass.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EngineSpec {
    /// Dense worst-case KV sessions (`BackendEngine::dense`).
    Dense { seed: u64 },
    /// Arena-backed paged KV sessions (`BackendEngine::paged`) — the
    /// shape every kv-oom scenario needs.
    Paged {
        seed: u64,
        pages: usize,
        page_tokens: usize,
        hot_window: usize,
        quant: KvQuantKind,
    },
}

impl Default for EngineSpec {
    fn default() -> Self {
        EngineSpec::Dense { seed: 9 }
    }
}

impl EngineSpec {
    /// Build the engine this spec describes (tiny zoo model, seeded
    /// random weights).
    pub fn build(&self) -> Result<Arc<dyn BatchForward>, String> {
        let cfg = config_by_name("qwen3-4b-tiny").ok_or("model zoo is missing qwen3-4b-tiny")?;
        Ok(match *self {
            EngineSpec::Dense { seed } => {
                Arc::new(BackendEngine::dense(Weights::random(&cfg, seed)))
            }
            EngineSpec::Paged {
                seed,
                pages,
                page_tokens,
                hot_window,
                quant,
            } => {
                let backend = ExecutionBackend::dense(Weights::random(&cfg, seed));
                Arc::new(BackendEngine::paged(
                    backend,
                    pages,
                    page_tokens,
                    hot_window,
                    quant,
                )?)
            }
        })
    }

    fn to_line(&self) -> String {
        match *self {
            EngineSpec::Dense { seed } => format!("engine dense seed={seed}"),
            EngineSpec::Paged {
                seed,
                pages,
                page_tokens,
                hot_window,
                quant,
            } => format!(
                "engine paged seed={seed} pages={pages} page_tokens={page_tokens} hot={hot_window} quant={}",
                quant.label()
            ),
        }
    }

    fn parse(rest: &str) -> Result<Self, String> {
        let mut it = rest.split_whitespace();
        let kind = it.next().ok_or("engine needs a kind (dense|paged)")?;
        let mut seed = 9u64;
        let mut pages = 8usize;
        let mut page_tokens = 4usize;
        let mut hot = 8usize;
        let mut quant = KvQuantKind::None;
        for a in it {
            let (k, v) = a
                .split_once('=')
                .ok_or_else(|| format!("bad engine arg '{a}' (want key=value)"))?;
            match k {
                "seed" => seed = v.parse().map_err(|_| format!("bad seed '{v}'"))?,
                "pages" => pages = v.parse().map_err(|_| format!("bad pages '{v}'"))?,
                "page_tokens" => {
                    page_tokens = v.parse().map_err(|_| format!("bad page_tokens '{v}'"))?
                }
                "hot" => hot = v.parse().map_err(|_| format!("bad hot '{v}'"))?,
                "quant" => quant = KvQuantKind::parse(v)?,
                other => return Err(format!("unknown engine arg '{other}'")),
            }
        }
        match kind {
            "dense" => Ok(EngineSpec::Dense { seed }),
            "paged" => Ok(EngineSpec::Paged {
                seed,
                pages,
                page_tokens,
                hot_window: hot,
                quant,
            }),
            other => Err(format!("unknown engine kind '{other}' (dense|paged)")),
        }
    }
}

/// The run header of a trace: scheduler config plus engine spec.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimSetup {
    pub batcher: BatcherConfig,
    pub engine: EngineSpec,
}

/// One scripted client action (mirrors a TCP verb; see the module doc).
#[derive(Clone, Debug, PartialEq)]
pub enum Action {
    Open,
    Feed(Vec<u8>),
    Gen { n: usize, params: SampleParams },
    Close,
    /// Rude drop: abandon any streaming GEN, then close the session —
    /// what the TCP front-end does when a socket dies mid-flight.
    Disconnect,
    /// v1 one-shot `NEXT` request (answered on a later tick's prefix
    /// batch).
    Next(Vec<u8>),
    /// Log the shared [`Metrics::snapshot`] line at this point.
    Stats,
    /// Arm the fault injector: the next `calls` engine calls panic.
    Panic { calls: u64 },
}

/// One scripted event: `action` on connection `conn` applied at virtual
/// tick `at` (conn 0 for [`Action::Panic`], which has no client).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub at: u64,
    pub conn: u32,
    pub action: Action,
}

/// A full simulator run script: setup header plus tick-pinned events.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub setup: SimSetup,
    pub events: Vec<TraceEvent>,
}

fn parse_tokens(s: &str) -> Result<Vec<u8>, String> {
    let toks: Result<Vec<u8>, _> = s.split(',').map(|t| t.trim().parse::<u8>()).collect();
    match toks {
        Ok(v) if !v.is_empty() => Ok(v),
        _ => Err(format!("bad token list '{s}'")),
    }
}

fn fmt_tokens(toks: &[u8]) -> String {
    toks.iter()
        .map(|t| t.to_string())
        .collect::<Vec<_>>()
        .join(",")
}

impl Trace {
    /// Empty trace over a setup (scenario builders start here).
    pub fn new(batcher: BatcherConfig, engine: EngineSpec) -> Self {
        Self {
            setup: SimSetup { batcher, engine },
            events: Vec::new(),
        }
    }

    /// Append one event.
    pub fn push(&mut self, at: u64, conn: u32, action: Action) {
        self.events.push(TraceEvent { at, conn, action });
    }

    /// Stable-sort events by tick (within-tick file order is preserved —
    /// it is part of the replay contract).
    pub fn normalize(&mut self) {
        self.events.sort_by_key(|e| e.at);
    }

    /// Parse the text format of the module doc. Later `config` /
    /// `engine` lines override earlier ones; event order is kept.
    pub fn parse(text: &str) -> Result<Trace, String> {
        let mut trace = Trace::default();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            Self::parse_line(line, &mut trace).map_err(|e| format!("trace line {}: {e}", i + 1))?;
        }
        trace.normalize();
        Ok(trace)
    }

    fn parse_line(line: &str, trace: &mut Trace) -> Result<(), String> {
        if let Some(rest) = line.strip_prefix("config ") {
            for a in rest.split_whitespace() {
                let (k, v) = a
                    .split_once('=')
                    .ok_or_else(|| format!("bad config arg '{a}' (want key=value)"))?;
                let b = &mut trace.setup.batcher;
                match k {
                    "max_batch" => {
                        b.max_batch = v.parse().map_err(|_| format!("bad max_batch '{v}'"))?
                    }
                    "max_wait_ms" => {
                        let ms: u64 = v.parse().map_err(|_| format!("bad max_wait_ms '{v}'"))?;
                        b.max_wait = Duration::from_millis(ms);
                    }
                    "max_sessions" => {
                        b.max_sessions = v.parse().map_err(|_| format!("bad max_sessions '{v}'"))?
                    }
                    "prefill_chunk" => {
                        b.prefill_chunk =
                            v.parse().map_err(|_| format!("bad prefill_chunk '{v}'"))?
                    }
                    other => return Err(format!("unknown config arg '{other}'")),
                }
            }
            return Ok(());
        }
        if let Some(rest) = line.strip_prefix("engine ") {
            trace.setup.engine = EngineSpec::parse(rest)?;
            return Ok(());
        }
        let Some(rest) = line.strip_prefix("tick ") else {
            return Err(format!("unrecognized directive '{line}'"));
        };
        let mut it = rest.split_whitespace();
        let at: u64 = it
            .next()
            .ok_or("tick needs a number")?
            .parse()
            .map_err(|_| "bad tick number".to_string())?;
        match it.next() {
            Some("panic") => {
                let calls: u64 = it
                    .next()
                    .ok_or("panic needs a call count")?
                    .parse()
                    .map_err(|_| "bad panic call count".to_string())?;
                trace.push(at, 0, Action::Panic { calls });
            }
            Some("conn") => {
                let conn: u32 = it
                    .next()
                    .ok_or("conn needs a number")?
                    .parse()
                    .map_err(|_| "bad conn number".to_string())?;
                let verb = it.next().ok_or("event needs an action")?;
                let action = match verb {
                    "open" => Action::Open,
                    "close" => Action::Close,
                    "disconnect" => Action::Disconnect,
                    "stats" => Action::Stats,
                    "feed" => Action::Feed(parse_tokens(it.next().ok_or("feed needs tokens")?)?),
                    "next" => Action::Next(parse_tokens(it.next().ok_or("next needs tokens")?)?),
                    "gen" => {
                        let n: usize = it
                            .next()
                            .ok_or("gen needs a token count")?
                            .parse()
                            .map_err(|_| "bad gen token count".to_string())?;
                        Action::Gen {
                            n,
                            params: SampleParams::from_kv_args(it)?,
                        }
                    }
                    other => return Err(format!("unknown action '{other}'")),
                };
                trace.push(at, conn, action);
            }
            _ => return Err("tick needs 'conn <c> <action>' or 'panic <k>'".into()),
        }
        Ok(())
    }

    /// Render the canonical text form (normalized; re-parsing yields an
    /// equal trace — `f32` `Display` is shortest-roundtrip, so sampler
    /// temperatures survive the trip bit-exactly).
    pub fn to_text(&self) -> String {
        let b = &self.setup.batcher;
        let mut s = String::new();
        s.push_str("# llvq scheduler-simulator trace (format: rust/src/sim/trace.rs)\n");
        s.push_str(&format!(
            "config max_batch={} max_wait_ms={} max_sessions={} prefill_chunk={}\n",
            b.max_batch,
            b.max_wait.as_millis(),
            b.max_sessions,
            b.prefill_chunk
        ));
        s.push_str(&self.setup.engine.to_line());
        s.push('\n');
        let mut events = self.events.clone();
        events.sort_by_key(|e| e.at);
        for ev in &events {
            match &ev.action {
                Action::Panic { calls } => {
                    s.push_str(&format!("tick {} panic {calls}\n", ev.at));
                }
                action => {
                    s.push_str(&format!("tick {} conn {} ", ev.at, ev.conn));
                    match action {
                        Action::Open => s.push_str("open"),
                        Action::Close => s.push_str("close"),
                        Action::Disconnect => s.push_str("disconnect"),
                        Action::Stats => s.push_str("stats"),
                        Action::Feed(t) => s.push_str(&format!("feed {}", fmt_tokens(t))),
                        Action::Next(t) => s.push_str(&format!("next {}", fmt_tokens(t))),
                        Action::Gen { n, params } => s.push_str(&format!(
                            "gen {n} temp={} topk={} seed={}",
                            params.temperature, params.top_k, params.seed
                        )),
                        Action::Panic { .. } => unreachable!("matched above"),
                    }
                    s.push('\n');
                }
            }
        }
        s
    }

    /// Parse a committed `.trace` file.
    pub fn load(path: &Path) -> Result<Trace, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// Write the canonical text form to `path` (the "commit this failing
    /// trace" workflow).
    pub fn save(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_text()).map_err(|e| format!("write {}: {e}", path.display()))
    }
}
