// std::simd is nightly-only; the portable kernel in quant::kernel is
// opt-in behind this feature so stable builds never see the gate.
#![cfg_attr(feature = "portable_simd", feature(portable_simd))]
// Every unsafe operation inside an `unsafe fn` must sit in an explicit
// `unsafe {}` block with its own SAFETY justification — the fn-level
// keyword only states the *caller's* obligation. Enforced together with
// the repo-native `llvq lint` safety-comment rule (see LINTS.md).
#![deny(unsafe_op_in_unsafe_fn)]
// Curated warn set (verify.sh runs clippy with -D warnings, so these are
// effectively denies in CI): cheap hygiene lints that never fight the
// codebase's established idioms.
#![warn(
    missing_abi,
    non_ascii_idents,
    keyword_idents,
    unused_extern_crates,
    unused_lifetimes
)]

//! # LLVQ — Leech Lattice Vector Quantization for LLM compression
//!
//! Reproduction of *"Leech Lattice Vector Quantization for Efficient LLM
//! Compression"* (van der Ouderaa et al., 2026) as a production-shaped
//! three-layer system:
//!
//! * **L3 (this crate)** — the coordination layer: the Leech lattice
//!   substrate (Golay code, shell/class enumeration, exact coset decoding,
//!   the paper's bijective indexing scheme), the quantizer zoo (LLVQ
//!   spherical-shaping and shape–gain plus all same-pipeline baselines),
//!   the GPTQ-style PTQ pipeline with Hessian corrections, a tiny
//!   transformer model substrate, a PJRT runtime that executes AOT-lowered
//!   JAX/Pallas artifacts, and a batching inference coordinator.
//! * **L2 (python/compile)** — JAX compute graphs (quantized linear /
//!   transformer forward), lowered once to HLO text.
//! * **L1 (python/compile/kernels)** — the Pallas dequantization kernel
//!   (paper §3.3 step 5), interpret-mode on CPU.
//!
//! Python never runs on the request path: artifacts are produced by
//! `make artifacts` and the rust binary is self-contained afterwards.
//!
//! ## The packed `.llvqm` layer stack
//!
//! The paper's storage claim — bijective indices convert "to and from
//! bitstrings without materializing the codebook" — is realized as a codec
//! stack that every layer of the crate speaks:
//!
//! ```text
//! quant::VectorQuantizer      code_widths / encode_into / decode_from /
//!                             spec  — per-block codec + self-describing
//!                             quantizer header (all five quantizers);
//!                             decode_blocks_into streams whole segments
//!                             of consecutive blocks for the SIMD tier
//! quant::kernel               SIMD kernel dispatch for the fused matvec:
//!                             runtime CPU-feature detection (AVX2/NEON/
//!                             portable std::simd/scalar oracle) with the
//!                             LLVQ_SIMD / --simd override, a fixed
//!                             documented partial-sum shape, and segment-
//!                             grouped block decode feeding the vector
//!                             accumulators
//! util::bits                  MSB-first BitWriter/BitReader substrate
//! util::threadpool            scoped one-shots (parallel_map/chunks) for
//!                             cold paths + the persistent Pool (long-lived
//!                             workers, per-executor Scratch, ShardedSlice)
//!                             the serving kernels row-shard over
//! pipeline::gptq              emits per-row bit-packed code streams while
//!                             quantizing (one scratch Code per row worker)
//! pipeline::driver            quantize_model_packed → PtqArtifacts
//!                             { weights, report, PackedModel }
//! model::packed               the .llvqm on-disk format (magic LLVQMDL1):
//!                             JSON header + per-layer code streams + σ /
//!                             rotation-seed / fine-tune-scale metadata +
//!                             dense fp32 embeddings/norms/head; unpack()
//!                             dequantizes block-parallel and reproduces
//!                             the driver's reconstruction bit-exactly;
//!                             load_meta/PackedFile give header-only stats
//!                             and random access to per-layer byte ranges
//! model::backend              the execution layer: LinearOp (shape /
//!                             matvec / resident_bytes) + ExecutionBackend
//!                             with three op families — dense (oracle),
//!                             cached (lazy per-layer decode on first
//!                             touch), fused (matvec straight over the
//!                             bit-packed code streams; the dense matrix
//!                             never exists in memory); the fused matmul
//!                             and cached first-touch decode row-shard
//!                             over the backend's persistent worker pool
//!                             (--threads), bit-identically to threads=1
//!                             for the quant::kernel kernel fixed at load
//! model::transformer          forward() is generic over ForwardOps, so
//!                             Weights and every ExecutionBackend share
//!                             one forward pass (and one eval path);
//!                             KvCache + prefill/forward_step[_batch] add
//!                             the incremental decode path, bit-identical
//!                             to full forward per position
//! model::kvpage               paged KV storage: PageArena (budgeted
//!                             free-list of fixed-size token pages shared
//!                             by every session) + PagedKvCache, a KvStore
//!                             admitting against actual pages instead of
//!                             worst-case max_seq; cold pages (behind the
//!                             hot window) optionally re-encoded through
//!                             the weight codecs (--kv-quant none|e8|llvq)
//!                             and decoded page-at-a-time on attention
//!                             reads — quant=none is bit-identical to the
//!                             dense KvCache
//! model::sample               seeded Sampler (greedy / temperature /
//!                             top-k) + the GEN argument parser
//! coordinator                 BackendEngine: batched serving over any
//!                             backend, now session-aware (open_session /
//!                             prefill / decode_step over a slate of
//!                             lanes / close_session) with a continuous-
//!                             batching worker running a two-queue tick:
//!                             one decode slate plus up to --prefill-chunk
//!                             prompt tokens of queued FEED jobs per tick
//!                             (pipelined chunked prefill — long prompts
//!                             no longer stall active generations; FEED
//!                             answers QUEUED immediately); sessions are
//!                             dense slabs or paged caches (--kv-pages),
//!                             admitted against the live arena with a
//!                             distinct kv-oom error; STATS reports
//!                             backend + resident weight bytes + session,
//!                             prefill, and kv-page counters; the per-tick
//!                             state machine lives in SchedulerCore, which
//!                             the worker thread and the simulator both
//!                             drive, and STATS formats through the shared
//!                             Metrics::snapshot
//! model::registry             multi-model serving registry behind the
//!                             HTTP front door: named .llvqm artifacts
//!                             registered header-only (load_meta), each
//!                             built into a backend + Coordinator on
//!                             first request, held as a byte-budgeted
//!                             LRU hot set (--max-resident-bytes; models
//!                             with open sessions are never evicted) with
//!                             per-model Metrics sharing one models= gauge
//! http                        dependency-free HTTP/1.1 + SSE front door
//!                             (llvq serve-http) over std::net: wire
//!                             parsing/limits (http::wire) and the
//!                             OpenAI-style routes (http::api) — POST
//!                             /v1/completions (SSE or fixed-length),
//!                             GET /v1/models, GET /metrics — all driving
//!                             the same SchedulerCore as the TCP worker
//!                             through the registry's per-model
//!                             Coordinators; see docs/PROTOCOL.md
//! sim                         deterministic scheduler simulator: a
//!                             virtual-clock driver of SchedulerCore — no
//!                             threads, sockets, or wall time — with
//!                             scripted/seeded event traces (sim::trace,
//!                             committed replayable .trace files), per-tick
//!                             invariant checks + step-through dump
//!                             (sim::harness), and the named workload
//!                             corpus (sim::scenario) that tests, CI's
//!                             sim-scenarios job, and BENCH_serving.json
//!                             all run against
//! lint                        repo-native static analysis: a minimal
//!                             Rust token scanner (lint::source), the
//!                             rule set encoding the crate's own
//!                             conventions — SAFETY-commented unsafe,
//!                             panic-free serving paths, poison-recovering
//!                             locks, dispatch-gated target_feature, and
//!                             STATS/wire-literal consistency
//!                             (lint::rules) — and the deterministic
//!                             text/JSON reporter (lint::engine) behind
//!                             `llvq lint`, scripts/verify.sh, and CI's
//!                             lint job; LINTS.md documents every rule
//! main (llvq pack/unpack/     CLI: produce, expand, inspect, serve, and
//!       stats/serve/generate) generate from packed artifacts; serve
//!                             --backend dense|cached|fused selects the
//!                             op family, v2 protocol streams GEN tokens
//! ```
//!
//! Entry points:
//! * [`leech::index::LeechIndexer`] — index ↔ lattice-point bijection.
//! * [`leech::decode`] — nearest-neighbour search (Euclidean + angular).
//! * [`quant`] — the [`quant::VectorQuantizer`] trait and implementations.
//! * [`pipeline`] — layer-wise PTQ with Hessian correction.
//! * [`model::packed`] — the packed quantized-model artifact (`.llvqm`).
//! * [`model::backend`] — [`model::backend::LinearOp`] /
//!   [`model::backend::ExecutionBackend`]: dense, lazily-decoded, and
//!   fused execution over packed artifacts.
//! * [`model::sample`] — seeded greedy / temperature / top-k sampling.
//! * [`coordinator`] — batched + sessioned inference service over any
//!   backend (v1 `NEXT` and the streaming v2 `OPEN`/`FEED`/`GEN` wire
//!   protocol).
//! * [`model::registry`] / [`http`] — the multi-model HTTP/SSE front
//!   door (`llvq serve-http`): lazy registration, LRU residency budget,
//!   OpenAI-style completions. Canonical reference: `docs/PROTOCOL.md`,
//!   `docs/ARCHITECTURE.md`, `docs/OPERATIONS.md`.
//! * [`sim`] — the deterministic virtual-clock scheduler simulator:
//!   scripted/replayable event traces, per-tick invariants, and the named
//!   workload scenario corpus.
//! * [`experiments`] — regenerators for every table/figure in the paper.

pub mod util {
    pub mod rng;
    pub mod json;
    pub mod cli;
    pub mod bench;
    pub mod bits;
    pub mod threadpool;
    pub mod proptest;
}

pub mod math {
    pub mod linalg;
    pub mod hadamard;
    pub mod stats;
}

pub mod golay;

pub mod leech {
    pub mod theta;
    pub mod leaders;
    pub mod coset;
    pub mod decode;
    pub mod index;
    pub mod tables;
}

pub mod quant {
    mod traits;
    pub use traits::*;
    pub mod scalar;
    pub mod gain;
    pub mod e8;
    pub mod kernel;
    pub mod llvq;
    pub mod product;
}

pub mod pipeline {
    pub mod hessian;
    pub mod rotation;
    pub mod gptq;
    pub mod finetune;
    pub mod driver;
}

pub mod model {
    pub mod config;
    pub mod transformer;
    pub mod kvpage;
    pub mod io;
    pub mod packed;
    pub mod backend;
    pub mod sample;
    pub mod eval;
    pub mod corpus;
    pub mod registry;
}

pub mod runtime;
pub mod coordinator;

pub mod http {
    //! Dependency-free HTTP/1.1 + SSE front door — see [`wire`] for
    //! parsing/limits, [`api`] for the routes, and `docs/PROTOCOL.md`
    //! for the canonical request/response reference.
    pub mod wire;
    pub mod api;
}

pub mod lint {
    //! Repo-native static analysis — see [`engine`] for the driver,
    //! [`rules`] for the rule set, [`source`] for the token scanner, and
    //! `LINTS.md` at the repo root for rationale and escape hatches.
    pub mod source;
    pub mod rules;
    pub mod engine;
}

pub mod sim {
    //! Deterministic scheduler simulator — see [`harness`] for the
    //! virtual-clock driver, [`trace`] for the committed-replay text
    //! format, [`scenario`] for the named workload corpus.
    pub mod trace;
    pub mod harness;
    pub mod scenario;
}

pub mod experiments;

/// Dimension of the Leech lattice and of every LLVQ block.
pub const DIM: usize = 24;
