//! Quantization metrics and distribution utilities (paper §3 eqs. 16–20,
//! App. F).
//!
//! * MSE / SQNR-in-bits / Shannon retention — the paper's Gaussian-source
//!   scoreboard (Fig. 1, Table 4).
//! * χ distribution with 24 degrees of freedom — the gain prior of the
//!   shape–gain construction; quantile tables are built by numerical
//!   integration of the χ²₂₄ density plus bisection (no special-function
//!   dependency).
//! * Simple summary-statistics helpers for the violin data of Fig. 6.

/// Mean squared error per weight between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let s: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum();
    s / a.len() as f64
}

/// SQNR in *bits* (paper eq. 17): −½·log₂(MSE) for a unit-variance source.
pub fn sqnr_bits(mse_val: f64) -> f64 {
    -0.5 * mse_val.log2()
}

/// Shannon retention at rate R bits/dim (paper eq. 20).
pub fn retention_pct(sqnr: f64, rate: f64) -> f64 {
    100.0 * sqnr / rate
}

/// SQNR in dB: bits × 20·log₁₀(2) ≈ bits × 6.0206 (paper §3).
pub fn sqnr_db(sqnr_bits: f64) -> f64 {
    sqnr_bits * 20.0 * std::f64::consts::LOG10_2
}

/// Percentile of a (sorted-in-place) sample; p ∈ [0, 100].
pub fn percentile(samples: &mut [f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = (p / 100.0 * (samples.len() - 1) as f64).round() as usize;
    samples[idx.min(samples.len() - 1)]
}

/// Five-number summary used for the Fig. 6 violin rows.
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    pub p5: f64,
    pub p25: f64,
    pub p50: f64,
    pub p75: f64,
    pub p95: f64,
    pub mean: f64,
}

pub fn summarize(samples: &mut [f64]) -> Summary {
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Summary {
        p5: percentile(samples, 5.0),
        p25: percentile(samples, 25.0),
        p50: percentile(samples, 50.0),
        p75: percentile(samples, 75.0),
        p95: percentile(samples, 95.0),
        mean,
    }
}

// ---------------------------------------------------------------------------
// χ²₂₄ / χ₂₄ distribution (gain prior for 24-dim Gaussian blocks)
// ---------------------------------------------------------------------------

/// χ² density with k degrees of freedom.
fn chi2_pdf(k: usize, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    // f(x) = x^{k/2-1} e^{-x/2} / (2^{k/2} Γ(k/2)); k = 24 ⇒ Γ(12) = 11!
    let half_k = k as f64 / 2.0;
    let ln_gamma_half_k = ln_gamma(half_k);
    ((half_k - 1.0) * x.ln() - x / 2.0 - half_k * std::f64::consts::LN_2 - ln_gamma_half_k).exp()
}

/// Lanczos log-gamma (g = 7, n = 9) — standard coefficients.
pub fn ln_gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection
        return (std::f64::consts::PI / (std::f64::consts::PI * x).sin()).ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = C[0];
    let t = x + G + 0.5;
    for (i, &c) in C.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// χ²_k CDF by adaptive Simpson integration of the density (k = 24 use).
pub fn chi2_cdf(k: usize, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    // Simpson on [0, x] with enough panels for 1e-10-ish accuracy at k=24
    let n = 2000;
    let h = x / n as f64;
    let mut s = chi2_pdf(k, 0.0) + chi2_pdf(k, x);
    for i in 1..n {
        let w = if i % 2 == 1 { 4.0 } else { 2.0 };
        s += w * chi2_pdf(k, i as f64 * h);
    }
    (s * h / 3.0).min(1.0)
}

/// Quantile (inverse CDF) of χ_k — i.e. of the NORM √(χ²_k) — by bisection.
pub fn chi_quantile(k: usize, p: f64) -> f64 {
    assert!((0.0..1.0).contains(&p));
    if p == 0.0 {
        return 0.0;
    }
    let (mut lo, mut hi) = (0.0f64, (k as f64).sqrt() * 6.0 + 10.0);
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if chi2_cdf(k, mid * mid) < p {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Lloyd–Max-style codebook for the χ_k gain prior: centroids of
/// equal-probability bins (a strong, standard gain quantizer; App. F's
/// "χ-matched scalar quantizer").
pub fn chi_gain_codebook(k: usize, levels: usize) -> Vec<f64> {
    assert!(levels >= 1);
    let mut out = Vec::with_capacity(levels);
    for i in 0..levels {
        // centroid ≈ median of the bin [i/L, (i+1)/L]
        let p = (i as f64 + 0.5) / levels as f64;
        out.push(chi_quantile(k, p));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sqnr_and_retention_examples_from_table4() {
        // Table 4: MSE 0.078 → SQNR 1.84 bits → 92.1% at R=2
        let s = sqnr_bits(0.078);
        assert!((s - 1.84).abs() < 0.005, "sqnr {s}");
        assert!((retention_pct(s, 2.0) - 92.1).abs() < 0.3);
        // theoretical limit: MSE 0.0625 → 2 bits → 100%
        assert!((sqnr_bits(0.0625) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ln_gamma_matches_factorials() {
        // Γ(12) = 11! = 39916800
        assert!((ln_gamma(12.0) - (39_916_800f64).ln()).abs() < 1e-9);
        assert!((ln_gamma(0.5) - (std::f64::consts::PI.sqrt()).ln()).abs() < 1e-9);
    }

    #[test]
    fn chi2_cdf_sane() {
        // mean of chi2_24 is 24; CDF at the mean is a bit over 0.5
        let c = chi2_cdf(24, 24.0);
        assert!(c > 0.5 && c < 0.56, "cdf(24) = {c}");
        assert!(chi2_cdf(24, 1.0) < 1e-6);
        assert!(chi2_cdf(24, 80.0) > 0.999999);
    }

    #[test]
    fn chi_quantile_roundtrip() {
        for &p in &[0.05, 0.25, 0.5, 0.75, 0.95] {
            let q = chi_quantile(24, p);
            let back = chi2_cdf(24, q * q);
            assert!((back - p).abs() < 1e-6, "p {p} → q {q} → {back}");
        }
        // E[χ_24] ≈ √24·(1 − 1/(4·24)) ≈ 4.85 ⇒ median close to that
        let med = chi_quantile(24, 0.5);
        assert!((med - 4.88).abs() < 0.1, "median {med}");
    }

    #[test]
    fn gain_codebook_monotone() {
        let cb = chi_gain_codebook(24, 16);
        assert_eq!(cb.len(), 16);
        for w in cb.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(cb[0] > 2.5 && cb[15] < 8.5);
    }

    #[test]
    fn summary_orders() {
        let mut v: Vec<f64> = (0..1000).map(|i| (i as f64) / 999.0).collect();
        let s = summarize(&mut v);
        assert!(s.p5 < s.p25 && s.p25 < s.p50 && s.p50 < s.p75 && s.p75 < s.p95);
        assert!((s.mean - 0.5).abs() < 1e-9);
    }
}
