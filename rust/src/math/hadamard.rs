//! Hadamard transforms for incoherence processing (paper §5.3).
//!
//! QuIP#/QuaRot-style randomized Hadamard rotations make weight marginals
//! more Gaussian before quantization. We implement the fast Walsh–Hadamard
//! transform for power-of-two sizes and a block-diagonal extension for
//! arbitrary dimensions (largest power-of-two blocks, remainder handled by
//! a smaller block), plus the sign-randomized orthogonal variant
//! `H·diag(s)/√n` used by the pipeline.

use crate::util::rng::Xoshiro256pp;

/// In-place fast Walsh–Hadamard transform (unnormalized). `data.len()`
/// must be a power of two.
///
/// The butterfly is expressed over paired half-slices rather than indexed
/// loads so each stage is a bounds-check-free streaming add/sub the
/// autovectorizer can widen — the activation-rotate half of the SIMD
/// serving path (`quant::kernel`). The pair arithmetic is unchanged from
/// the classic indexed form, so results are bit-identical.
pub fn fwht(data: &mut [f64]) {
    let n = data.len();
    assert!(n.is_power_of_two(), "FWHT needs a power-of-two length");
    let mut h = 1;
    while h < n {
        for block in data.chunks_exact_mut(2 * h) {
            let (lo, hi) = block.split_at_mut(h);
            for (a, b) in lo.iter_mut().zip(hi.iter_mut()) {
                let x = *a;
                let y = *b;
                *a = x + y;
                *b = x - y;
            }
        }
        h *= 2;
    }
}

/// Orthonormal FWHT: divides by √n so the transform is an isometry.
pub fn fwht_orthonormal(data: &mut [f64]) {
    let n = data.len();
    fwht(data);
    let s = 1.0 / (n as f64).sqrt();
    for v in data.iter_mut() {
        *v *= s;
    }
}

/// A randomized Hadamard rotation `R = H·diag(s)/√n` over a (possibly
/// non-power-of-two) dimension, realized block-diagonally: the dimension is
/// split into power-of-two blocks (greedy largest-first). Orthogonal, so
/// `inverse ∘ forward = id` and norms are preserved.
#[derive(Clone, Debug)]
pub struct RandomizedHadamard {
    pub dim: usize,
    /// (offset, len) of each power-of-two block.
    blocks: Vec<(usize, usize)>,
    /// Random ±1 signs, one per coordinate.
    signs: Vec<f64>,
}

impl RandomizedHadamard {
    pub fn new(dim: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::new(seed);
        let mut blocks = Vec::new();
        let mut off = 0;
        let mut rem = dim;
        while rem > 0 {
            let b = if rem.is_power_of_two() {
                rem
            } else {
                rem.next_power_of_two() / 2
            };
            blocks.push((off, b));
            off += b;
            rem -= b;
        }
        let signs = (0..dim)
            .map(|_| if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
            .collect();
        Self { dim, blocks, signs }
    }

    /// y = R·x (in place).
    pub fn forward(&self, x: &mut [f64]) {
        assert_eq!(x.len(), self.dim);
        for (v, s) in x.iter_mut().zip(&self.signs) {
            *v *= s;
        }
        for &(off, len) in &self.blocks {
            fwht_orthonormal(&mut x[off..off + len]);
        }
    }

    /// x = Rᵀ·y (in place) — R is orthogonal so this is the inverse.
    pub fn inverse(&self, x: &mut [f64]) {
        assert_eq!(x.len(), self.dim);
        for &(off, len) in &self.blocks {
            // H is symmetric; orthonormal H is its own inverse
            fwht_orthonormal(&mut x[off..off + len]);
        }
        for (v, s) in x.iter_mut().zip(&self.signs) {
            *v *= s; // s ∈ {±1} ⇒ s⁻¹ = s
        }
    }

    /// Apply to every row of a row-major matrix.
    pub fn forward_rows(&self, data: &mut [f64], cols: usize) {
        assert_eq!(cols, self.dim);
        for row in data.chunks_mut(cols) {
            self.forward(row);
        }
    }

    /// Apply the inverse to every row.
    pub fn inverse_rows(&self, data: &mut [f64], cols: usize) {
        assert_eq!(cols, self.dim);
        for row in data.chunks_mut(cols) {
            self.inverse(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_matches_definition_small() {
        // H2 = [[1,1],[1,-1]]
        let mut v = [3.0, 5.0];
        fwht(&mut v);
        assert_eq!(v, [8.0, -2.0]);
        // H4 on a unit vector gives a ±1 column
        let mut e = [0.0, 1.0, 0.0, 0.0];
        fwht(&mut e);
        assert_eq!(e, [1.0, -1.0, 1.0, -1.0]);
    }

    #[test]
    fn orthonormal_preserves_norm() {
        let mut rng = Xoshiro256pp::new(9);
        let mut v: Vec<f64> = (0..256).map(|_| rng.next_gaussian()).collect();
        let n0: f64 = v.iter().map(|x| x * x).sum();
        fwht_orthonormal(&mut v);
        let n1: f64 = v.iter().map(|x| x * x).sum();
        assert!((n0 - n1).abs() < 1e-9 * n0);
    }

    #[test]
    fn randomized_roundtrip_non_pow2() {
        for dim in [24usize, 96, 100, 768, 257] {
            let h = RandomizedHadamard::new(dim, 77);
            let mut rng = Xoshiro256pp::new(13);
            let orig: Vec<f64> = (0..dim).map(|_| rng.next_gaussian()).collect();
            let mut v = orig.clone();
            h.forward(&mut v);
            // norm preserved
            let n0: f64 = orig.iter().map(|x| x * x).sum();
            let n1: f64 = v.iter().map(|x| x * x).sum();
            assert!((n0 - n1).abs() < 1e-9 * n0.max(1.0));
            h.inverse(&mut v);
            for (a, b) in v.iter().zip(&orig) {
                assert!((a - b).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn gaussianizes_spiky_vectors() {
        // a one-hot "outlier" spreads to uniform magnitude — the incoherence
        // property the rotation exists for
        let dim = 128;
        let h = RandomizedHadamard::new(dim, 5);
        let mut v = vec![0.0; dim];
        v[17] = 1.0;
        h.forward(&mut v);
        let maxabs = v.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        assert!(maxabs < 2.5 / (dim as f64).sqrt(), "max |v| = {maxabs}");
    }
}
