//! Dense linear algebra substrate (no external BLAS in the offline build).
//!
//! Provides exactly what the PTQ pipeline (App. D.2) needs: a row-major
//! `Matrix`, Cholesky factorization with diagonal jitter, triangular solves
//! (single and batched RHS), SPD solves, and least squares via normal
//! equations — all in f64 for numerical headroom, with f32 views at the
//! model boundary.

/// Row-major dense matrix.
#[derive(Clone, Debug)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Self {
            rows: r,
            cols: c,
            data,
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.at(i, j);
            }
        }
        t
    }

    /// C = A · B (naive triple loop with the k-j inner order for locality).
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows);
        let mut c = Matrix::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a_ik = self.at(i, k);
                if a_ik == 0.0 {
                    continue;
                }
                let brow = &b.data[k * b.cols..(k + 1) * b.cols];
                let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
                for j in 0..b.cols {
                    crow[j] += a_ik * brow[j];
                }
            }
        }
        c
    }

    /// y = A·x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut s = 0.0;
            for j in 0..self.cols {
                s += row[j] * x[j];
            }
            y[i] = s;
        }
        y
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Add `eps · mean(diag)` to the diagonal (GPTQ-style damping).
    pub fn damp_diagonal(&mut self, eps: f64) {
        assert_eq!(self.rows, self.cols);
        let mean_diag = (0..self.rows).map(|i| self.at(i, i)).sum::<f64>() / self.rows as f64;
        let add = eps * mean_diag.max(1e-12);
        for i in 0..self.rows {
            *self.at_mut(i, i) += add;
        }
    }
}

/// Lower-triangular Cholesky factor L with A = L·Lᵀ. Fails on non-SPD
/// input (after optional damping the pipeline applies).
pub fn cholesky(a: &Matrix) -> Result<Matrix, String> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j);
            for k in 0..j {
                s -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    return Err(format!("not SPD at pivot {i} (s = {s:.3e})"));
                }
                *l.at_mut(i, j) = s.sqrt();
            } else {
                *l.at_mut(i, j) = s / l.at(j, j);
            }
        }
    }
    Ok(l)
}

/// Solve L·x = b with L lower-triangular.
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l.at(i, k) * x[k];
        }
        x[i] = s / l.at(i, i);
    }
    x
}

/// Solve Lᵀ·x = b with L lower-triangular.
pub fn solve_lower_t(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= l.at(k, i) * x[k];
        }
        x[i] = s / l.at(i, i);
    }
    x
}

/// Solve the SPD system A·x = b via Cholesky.
pub fn solve_spd(a: &Matrix, b: &[f64]) -> Result<Vec<f64>, String> {
    let l = cholesky(a)?;
    Ok(solve_lower_t(&l, &solve_lower(&l, b)))
}

/// Least squares: minimize ‖A·x − b‖² via damped normal equations.
pub fn least_squares(a: &Matrix, b: &[f64], damp: f64) -> Result<Vec<f64>, String> {
    let at = a.transpose();
    let mut ata = at.matmul(a);
    ata.damp_diagonal(damp.max(1e-10));
    let atb = at.matvec(b);
    solve_spd(&ata, &atb)
}

/// Inverse of an SPD matrix via Cholesky (used once per layer — not hot).
pub fn invert_spd(a: &Matrix) -> Result<Matrix, String> {
    let n = a.rows;
    let l = cholesky(a)?;
    let mut inv = Matrix::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = solve_lower_t(&l, &solve_lower(&l, &e));
        for i in 0..n {
            *inv.at_mut(i, j) = col[i];
        }
        e[j] = 0.0;
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256pp::new(seed);
        let mut g = Matrix::zeros(n, n);
        for v in g.data.iter_mut() {
            *v = rng.next_gaussian();
        }
        let mut a = g.transpose().matmul(&g);
        a.damp_diagonal(0.05);
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(16, 1);
        let l = cholesky(&a).unwrap();
        let back = l.matmul(&l.transpose());
        for i in 0..16 {
            for j in 0..16 {
                assert!((back.at(i, j) - a.at(i, j)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn spd_solve_accuracy() {
        let a = random_spd(24, 2);
        let mut rng = Xoshiro256pp::new(3);
        let x_true: Vec<f64> = (0..24).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&x_true);
        let x = solve_spd(&a, &b).unwrap();
        for (xs, xt) in x.iter().zip(&x_true) {
            assert!((xs - xt).abs() < 1e-8, "{xs} vs {xt}");
        }
    }

    #[test]
    fn triangular_solves_invert_each_other() {
        let a = random_spd(12, 4);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..12).map(|i| i as f64 - 3.0).collect();
        let y = solve_lower(&l, &b);
        // L·y should equal b
        let ly = l.matvec(&y);
        for (u, v) in ly.iter().zip(&b) {
            assert!((u - v).abs() < 1e-10);
        }
        let z = solve_lower_t(&l, &b);
        let ltz = l.transpose().matvec(&z);
        for (u, v) in ltz.iter().zip(&b) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn least_squares_recovers_planted_solution() {
        let mut rng = Xoshiro256pp::new(5);
        let mut a = Matrix::zeros(64, 8);
        for v in a.data.iter_mut() {
            *v = rng.next_gaussian();
        }
        let x_true: Vec<f64> = (0..8).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&x_true);
        let x = least_squares(&a, &b, 1e-9).unwrap();
        for (xs, xt) in x.iter().zip(&x_true) {
            assert!((xs - xt).abs() < 1e-6);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }
}
