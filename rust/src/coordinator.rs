//! Inference coordinator: dynamic batching over a forward engine.
//!
//! The serving-side L3 piece (vLLM-router-shaped, scaled to this paper):
//! requests arrive asynchronously, a batcher thread coalesces them up to
//! `max_batch` or `max_wait`, a worker executes the batch on the forward
//! engine (PJRT artifact or the Rust-native oracle), and responses flow
//! back through per-request channels. A line-protocol TCP front-end and
//! latency/throughput metrics round out the service.
//!
//! The quantized model's weights were produced by the PTQ pipeline and are
//! deployed as a packed `.llvqm` artifact (`model::packed`). Serving runs
//! through a [`BackendEngine`] over any `model::backend::ExecutionBackend`:
//! `serve --backend dense` dequantizes at load (the historical behavior,
//! bit-exact oracle), `--backend cached` decodes layers lazily on first
//! touch, and `--backend fused` executes matvecs straight over the
//! bit-packed code streams — the paper's "no expensive lookups on the
//! inference path" claim served without ever materializing dense f32.
//! `STATS` reports which backend is live and its resident weight bytes.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::model::backend::ExecutionBackend;
use crate::model::transformer::{forward, ActivationCapture, Weights};

/// A forward engine maps a batch of token sequences to per-sequence
/// last-position logits (vocab-sized each).
pub trait BatchForward: Send + Sync {
    fn vocab(&self) -> usize;
    fn max_seq(&self) -> usize;
    /// `batch[i]` has uniform length ≤ max_seq; returns, per sequence, the
    /// logits at the LAST position.
    fn forward_batch(&self, batch: &[Vec<u8>]) -> Vec<Vec<f32>>;

    /// Label of the executing representation (for `STATS`).
    fn backend_name(&self) -> String {
        "unknown".into()
    }

    /// Weight-payload bytes currently resident (for `STATS`; 0 when the
    /// engine does not track it).
    fn resident_weight_bytes(&self) -> usize {
        0
    }
}

/// Rust-native engine over an [`ExecutionBackend`] — dense (the oracle),
/// lazily-decoded packed, or fused packed, all behind one forward pass.
pub struct BackendEngine {
    pub backend: ExecutionBackend,
}

impl BackendEngine {
    /// Wrap dense weights (the no-artifacts fallback and oracle).
    pub fn dense(weights: Weights) -> Self {
        Self {
            backend: ExecutionBackend::dense(weights),
        }
    }
}

impl BatchForward for BackendEngine {
    fn vocab(&self) -> usize {
        self.backend.cfg().vocab
    }

    fn max_seq(&self) -> usize {
        self.backend.cfg().max_seq
    }

    fn forward_batch(&self, batch: &[Vec<u8>]) -> Vec<Vec<f32>> {
        let v = self.vocab();
        batch
            .iter()
            .map(|toks| {
                let mut cap = ActivationCapture::default();
                let logits = forward(&self.backend, toks, &mut cap);
                logits[(toks.len() - 1) * v..toks.len() * v].to_vec()
            })
            .collect()
    }

    fn backend_name(&self) -> String {
        self.backend.kind().label().into()
    }

    fn resident_weight_bytes(&self) -> usize {
        self.backend.resident_weight_bytes()
    }
}

/// One queued request.
struct Pending {
    tokens: Vec<u8>,
    reply: Sender<Vec<f32>>,
    enqueued: Instant,
}

/// Service metrics (atomic, cheap to read while serving).
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    /// Total queue+execute latency in microseconds.
    pub total_latency_us: AtomicU64,
}

impl Metrics {
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn mean_latency_ms(&self) -> f64 {
        let r = self.requests.load(Ordering::Relaxed);
        if r == 0 {
            0.0
        } else {
            self.total_latency_us.load(Ordering::Relaxed) as f64 / r as f64 / 1000.0
        }
    }
}

/// Dynamic batcher configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// The coordinator: submit() from any thread; a dedicated worker drains
/// the queue in batches.
pub struct Coordinator {
    tx: Mutex<Option<Sender<Pending>>>,
    pub metrics: Arc<Metrics>,
    /// Kept for live introspection (`STATS` queries backend name and
    /// resident bytes while the worker owns its own clone).
    engine: Arc<dyn BatchForward>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
    stopping: Arc<AtomicBool>,
}

impl Coordinator {
    pub fn start(engine: Arc<dyn BatchForward>, cfg: BatcherConfig) -> Arc<Self> {
        let (tx, rx) = channel::<Pending>();
        let metrics = Arc::new(Metrics::default());
        let stopping = Arc::new(AtomicBool::new(false));
        let m2 = metrics.clone();
        let s2 = stopping.clone();
        let e2 = engine.clone();
        let worker = std::thread::spawn(move || batch_loop(e2, rx, cfg, m2, s2));
        Arc::new(Self {
            tx: Mutex::new(Some(tx)),
            metrics,
            engine,
            worker: Mutex::new(Some(worker)),
            stopping,
        })
    }

    /// The engine being served (for stats surfaces).
    pub fn engine(&self) -> &Arc<dyn BatchForward> {
        &self.engine
    }

    /// Blocking request: returns last-position logits.
    pub fn submit(&self, tokens: Vec<u8>) -> Result<Vec<f32>, String> {
        let (rtx, rrx) = channel();
        {
            let guard = self.tx.lock().unwrap();
            let tx = guard.as_ref().ok_or("coordinator stopped")?;
            tx.send(Pending {
                tokens,
                reply: rtx,
                enqueued: Instant::now(),
            })
            .map_err(|_| "worker gone".to_string())?;
        }
        rrx.recv().map_err(|_| "worker dropped request".to_string())
    }

    /// Shut down: no new submissions are accepted, every request already
    /// queued is still answered (the worker drains the channel without
    /// holding the batch window open), then the worker exits and is
    /// joined — deterministic, no sleeps.
    pub fn stop(&self) {
        self.stopping.store(true, Ordering::SeqCst);
        self.tx.lock().unwrap().take(); // close the channel
        if let Some(h) = self.worker.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn batch_loop(
    engine: Arc<dyn BatchForward>,
    rx: Receiver<Pending>,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
    stopping: Arc<AtomicBool>,
) {
    loop {
        // block for the first item
        let first = match rx.recv() {
            Ok(p) => p,
            Err(_) => return, // channel closed
        };
        let mut batch = vec![first];
        if stopping.load(Ordering::SeqCst) {
            // draining after stop(): the sender is closed, so everything
            // still queued is final — take it all immediately instead of
            // holding each batch open for max_wait. In-flight requests are
            // answered deterministically, then recv() errors and we exit.
            while batch.len() < cfg.max_batch {
                match rx.try_recv() {
                    Ok(p) => batch.push(p),
                    Err(TryRecvError::Empty | TryRecvError::Disconnected) => break,
                }
            }
        } else {
            let deadline = Instant::now() + cfg.max_wait;
            while batch.len() < cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(p) => batch.push(p),
                    Err(_) => break,
                }
            }
        }
        let inputs: Vec<Vec<u8>> = batch.iter().map(|p| p.tokens.clone()).collect();
        let outputs = engine.forward_batch(&inputs);
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics
            .batched_items
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        for (p, out) in batch.into_iter().zip(outputs) {
            metrics.requests.fetch_add(1, Ordering::Relaxed);
            metrics.total_latency_us.fetch_add(
                p.enqueued.elapsed().as_micros() as u64,
                Ordering::Relaxed,
            );
            let _ = p.reply.send(out);
        }
    }
}

// ---------------------------------------------------------------------------
// TCP front-end (line protocol)
// ---------------------------------------------------------------------------

/// Protocol: one request per line.
///   `NEXT 3,17,42,…`  → `OK next=<argmax> logit=<v>`
///   `STATS`           → `OK requests=… mean_batch=… mean_latency_ms=…
///                        backend=… resident_bytes=…`
///   `QUIT`            → closes the connection.
pub fn serve_tcp(coord: Arc<Coordinator>, listener: TcpListener) -> std::io::Result<()> {
    for stream in listener.incoming() {
        let stream = stream?;
        let c = coord.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(c, stream);
        });
    }
    Ok(())
}

fn handle_conn(coord: Arc<Coordinator>, stream: TcpStream) -> std::io::Result<()> {
    let peer = stream.try_clone()?;
    let mut reader = BufReader::new(peer);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let line = line.trim();
        if line == "QUIT" {
            return Ok(());
        }
        if line == "STATS" {
            writeln!(
                out,
                "OK requests={} mean_batch={:.2} mean_latency_ms={:.3} \
                 backend={} resident_bytes={}",
                coord.metrics.requests.load(Ordering::Relaxed),
                coord.metrics.mean_batch(),
                coord.metrics.mean_latency_ms(),
                coord.engine().backend_name(),
                coord.engine().resident_weight_bytes(),
            )?;
            continue;
        }
        if let Some(rest) = line.strip_prefix("NEXT ") {
            let tokens: Result<Vec<u8>, _> =
                rest.split(',').map(|t| t.trim().parse::<u8>()).collect();
            match tokens {
                Ok(toks) if !toks.is_empty() => match coord.submit(toks) {
                    Ok(logits) => {
                        let (mut bi, mut bv) = (0usize, f32::NEG_INFINITY);
                        for (i, &v) in logits.iter().enumerate() {
                            if v > bv {
                                bv = v;
                                bi = i;
                            }
                        }
                        writeln!(out, "OK next={bi} logit={bv:.4}")?;
                    }
                    Err(e) => writeln!(out, "ERR {e}")?,
                },
                _ => writeln!(out, "ERR bad token list")?,
            }
            continue;
        }
        writeln!(out, "ERR unknown command")?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::config_by_name;

    fn tiny_engine() -> Arc<dyn BatchForward> {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        Arc::new(BackendEngine::dense(Weights::random(&cfg, 9)))
    }

    #[test]
    fn coordinator_answers_requests() {
        let coord = Coordinator::start(tiny_engine(), BatcherConfig::default());
        let logits = coord.submit(vec![1, 2, 3, 4]).unwrap();
        assert_eq!(logits.len(), 64);
        coord.stop();
    }

    #[test]
    fn batching_accumulates_under_load() {
        let coord = Coordinator::start(
            tiny_engine(),
            BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(20),
            },
        );
        std::thread::scope(|s| {
            for t in 0..24 {
                let c = coord.clone();
                s.spawn(move || {
                    let toks: Vec<u8> = (0..10).map(|i| ((t + i) % 64) as u8).collect();
                    c.submit(toks).unwrap();
                });
            }
        });
        assert_eq!(coord.metrics.requests.load(Ordering::Relaxed), 24);
        assert!(
            coord.metrics.mean_batch() > 1.2,
            "no batching happened: {}",
            coord.metrics.mean_batch()
        );
        coord.stop();
    }

    #[test]
    fn stop_answers_or_rejects_every_inflight_request() {
        // stop() closes the door and drains: a concurrent submit either
        // gets real logits (it was queued in time) or the "coordinator
        // stopped" rejection — never a dropped reply channel.
        let coord = Coordinator::start(
            tiny_engine(),
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(50),
            },
        );
        let answered = std::sync::atomic::AtomicU64::new(0);
        std::thread::scope(|s| {
            for t in 0..16u8 {
                let c = coord.clone();
                let answered = &answered;
                s.spawn(move || match c.submit(vec![1, 2, t % 64]) {
                    Ok(logits) => {
                        assert_eq!(logits.len(), 64);
                        answered.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => assert_eq!(e, "coordinator stopped"),
                });
            }
            std::thread::sleep(Duration::from_millis(5));
            coord.stop();
        });
        assert_eq!(
            coord.metrics.requests.load(Ordering::Relaxed),
            answered.load(Ordering::Relaxed),
            "metrics must count exactly the answered requests"
        );
        // idempotent
        coord.stop();
    }

    #[test]
    fn tcp_roundtrip() {
        let coord = Coordinator::start(tiny_engine(), BatcherConfig::default());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let c2 = coord.clone();
        std::thread::spawn(move || {
            let _ = serve_tcp(c2, listener);
        });
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "NEXT 5,6,7").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK next="), "{line}");
        writeln!(s, "STATS").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("requests=1"), "{line}");
        assert!(line.contains("backend=dense"), "{line}");
        assert!(line.contains("resident_bytes="), "{line}");
        writeln!(s, "QUIT").unwrap();
        coord.stop();
    }

    #[test]
    fn deterministic_between_native_batches() {
        let engine = tiny_engine();
        let a = engine.forward_batch(&[vec![1, 2, 3]]);
        let b = engine.forward_batch(&[vec![9, 9], vec![1, 2, 3]]);
        for (x, y) in a[0].iter().zip(&b[1]) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}
