//! Inference coordinator: continuous batching over a session-aware engine.
//!
//! The serving-side L3 piece (vLLM-router-shaped, scaled to this paper):
//! requests arrive asynchronously and flow through one worker thread that
//! interleaves two kinds of work:
//!
//! * **one-shot prefix requests** (the v1 `NEXT` path) — coalesced up to
//!   `max_batch` or `max_wait` and answered with last-position logits from
//!   a full forward pass, exactly as before;
//! * **generation sessions** (the v2 path) — `OPEN` allocates a per-session
//!   [`KvCache`], `FEED` *queues* its tokens as a prefill job and
//!   returns immediately (`QUEUED n`), and `GEN` joins the session to the
//!   *active slate* once its prefill has drained: every scheduler tick
//!   advances up to `max_batch` sessions by one token through a single
//!   batched [`BatchForward::decode_step`] **and** grants up to
//!   `prefill_chunk` prompt tokens to queued prefill jobs, so the fused
//!   backend decodes each weight row once per tick for the whole slate and
//!   a 10k-token FEED no longer freezes active generations — prompt
//!   latency hides under the decode slate (pipelined chunked prefill, the
//!   Orca/vLLM scheduling shape). Chunked prefill is bit-identical to
//!   one-shot prefill by construction (`prefill` is incremental — see
//!   `model::transformer::prefill_chunked`). Half-done jobs rotate behind
//!   other waiting jobs for fairness; mid-prefill sessions park out of the
//!   session map and rejoin when their job drains (or is closed). New
//!   requests are absorbed between ticks (continuous batching), and
//!   sampled tokens stream back to each client as they are produced.
//!
//! The quantized model's weights were produced by the PTQ pipeline and are
//! deployed as a packed `.llvqm` artifact (`model::packed`). Serving runs
//! through a [`BackendEngine`] over any `model::backend::ExecutionBackend`:
//! `serve --backend dense` dequantizes at load (the historical behavior,
//! bit-exact oracle), `--backend cached` decodes layers lazily on first
//! touch, and `--backend fused` executes matvecs straight over the
//! bit-packed code streams — the paper's "no expensive lookups on the
//! inference path" claim served without ever materializing dense f32.
//! `STATS` reports which backend is live, its resident weight bytes, and
//! the session counters.
//!
//! Robustness: token ids are validated at `submit`/`feed` time (an id ≥
//! vocab can never reach the embedding lookup), and every engine call —
//! including each individual prefill chunk — runs under `catch_unwind`: a
//! panicking forward pass answers `ERR` (or fails the waiting `GEN`
//! stream) and destroys only the sessions it touched instead of killing
//! the worker and hanging every later request.
//!
//! The per-tick state machine itself — event intake → admission/reserve →
//! one-shot prefix batch → decode slate → prefill chunk budget → metrics —
//! lives in [`SchedulerCore`], which owns no thread, socket, or wall
//! clock. The worker thread here is one driver of that core (real channel
//! + wall-clock batch window); the deterministic simulator in
//! [`crate::sim`] is another (virtual clock, scripted event traces,
//! byte-exact replay). `STATS` formatting is shared the same way:
//! [`Metrics::snapshot`] produces the one ordered field list both the TCP
//! reply and the simulator's per-tick dump print.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::model::backend::ExecutionBackend;
use crate::model::kvpage::{KvCodec, KvPageCounters, KvQuantKind, PageArena, PagedKvCache};
use crate::model::sample::{argmax, SampleParams, Sampler};
use crate::model::transformer::{
    forward, forward_step_batch, ActivationCapture, KvCache, KvStore, StepLane, Weights,
};

/// A forward engine: one-shot batched prefix inference plus the stateful
/// generation-session surface (`open_session` / `prefill` / `decode_step`
/// over a slate of lanes / `close_session`).
pub trait BatchForward: Send + Sync {
    fn vocab(&self) -> usize;
    fn max_seq(&self) -> usize;
    /// `batch[i]` has length ≤ max_seq; returns, per sequence, the logits
    /// at the LAST position.
    fn forward_batch(&self, batch: &[Vec<u8>]) -> Vec<Vec<f32>>;

    /// Open a generation session: a KV store sized for this engine's
    /// model — a dense worst-case [`KvCache`] slab, or (for paged
    /// engines) a zero-page [`PagedKvCache`] whose pages are reserved as
    /// tokens actually arrive. Sessions are pure state — any number may
    /// exist per engine.
    fn open_session(&self) -> Box<dyn KvStore>;

    /// Append `tokens` to a session and return the logits at the last
    /// appended position (bit-identical to `forward_batch` over the
    /// session's full history).
    fn prefill(&self, cache: &mut dyn KvStore, tokens: &[u8]) -> Vec<f32>;

    /// Advance a slate of sessions by one token each, returning per-lane
    /// last-position logits. Backends amortize per-weight-row work across
    /// the whole slate; per-lane results are bit-identical to a one-lane
    /// step.
    fn decode_step(&self, lanes: &mut [StepLane<'_>]) -> Vec<Vec<f32>>;

    /// Recycle hook for a finished session (default: drop the cache —
    /// which, for paged sessions, returns every page to the arena).
    fn close_session(&self, _cache: Box<dyn KvStore>) {}

    /// Live page-arena counters when this engine serves paged KV
    /// sessions (None for dense worst-case sessions).
    fn kv_counters(&self) -> Option<Arc<KvPageCounters>> {
        None
    }

    /// Page budget of the engine's KV arena (0 = dense sessions).
    fn kv_page_budget(&self) -> usize {
        0
    }

    /// Tokens per KV page (0 = dense sessions).
    fn kv_page_tokens(&self) -> usize {
        0
    }

    /// Cold-page codec label (for `STATS`; "none" when unquantized).
    fn kv_quant_label(&self) -> String {
        "none".into()
    }

    /// Label of the executing representation (for `STATS`).
    fn backend_name(&self) -> String {
        "unknown".into()
    }

    /// Weight-payload bytes currently resident (for `STATS`; 0 when the
    /// engine does not track it).
    fn resident_weight_bytes(&self) -> usize {
        0
    }

    /// Kernel worker threads the engine's backend runs with (for `STATS`;
    /// 1 when the engine has no parallel kernel).
    fn threads(&self) -> usize {
        1
    }

    /// SIMD kernel label of the engine's fused dispatch (for `STATS`;
    /// "scalar" when the engine has no vector kernel).
    fn simd_label(&self) -> String {
        "scalar".into()
    }
}

/// Paged-KV session configuration of a [`BackendEngine`]: the shared
/// arena plus the cold-page codec every session opens against.
struct PagedKv {
    arena: Arc<PageArena>,
    codec: Option<Arc<KvCodec>>,
    hot_window: usize,
    quant: KvQuantKind,
}

/// Rust-native engine over an [`ExecutionBackend`] — dense (the oracle),
/// lazily-decoded packed, or fused packed, all behind one forward pass and
/// one decode-step path. Sessions are dense worst-case [`KvCache`] slabs
/// by default; [`BackendEngine::paged`] switches them to arena-backed
/// [`PagedKvCache`]s (optionally with lattice-quantized cold pages).
pub struct BackendEngine {
    pub backend: ExecutionBackend,
    kv: Option<PagedKv>,
}

impl BackendEngine {
    /// Engine with dense worst-case KV sessions (the historical shape).
    pub fn new(backend: ExecutionBackend) -> Self {
        Self { backend, kv: None }
    }

    /// Wrap dense weights (the no-artifacts fallback and oracle).
    pub fn dense(weights: Weights) -> Self {
        Self::new(ExecutionBackend::dense(weights))
    }

    /// Engine whose sessions draw fixed-size KV pages from a shared
    /// arena of at most `pages` buffers of `page_tokens` tokens each,
    /// quantizing pages older than the last `hot_window` tokens with
    /// `quant` (`None` keeps every page f32 — bit-identical to dense
    /// sessions). Errs on an unbuildable codec spec.
    pub fn paged(
        backend: ExecutionBackend,
        pages: usize,
        page_tokens: usize,
        hot_window: usize,
        quant: KvQuantKind,
    ) -> Result<Self, String> {
        let cfg = backend.cfg();
        let page_tokens = page_tokens.clamp(1, cfg.max_seq);
        let codec = KvCodec::build(quant, cfg.d_model)?;
        let arena = PageArena::new(cfg, pages.max(1), page_tokens);
        Ok(Self {
            backend,
            kv: Some(PagedKv {
                arena,
                codec,
                hot_window,
                quant,
            }),
        })
    }
}

impl BatchForward for BackendEngine {
    fn vocab(&self) -> usize {
        self.backend.cfg().vocab
    }

    fn max_seq(&self) -> usize {
        self.backend.cfg().max_seq
    }

    fn forward_batch(&self, batch: &[Vec<u8>]) -> Vec<Vec<f32>> {
        let v = self.vocab();
        batch
            .iter()
            .map(|toks| {
                let mut cap = ActivationCapture::default();
                let logits = forward(&self.backend, toks, &mut cap);
                logits[(toks.len() - 1) * v..toks.len() * v].to_vec()
            })
            .collect()
    }

    fn open_session(&self) -> Box<dyn KvStore> {
        match &self.kv {
            Some(kv) => Box::new(PagedKvCache::new(
                self.backend.cfg(),
                Arc::clone(&kv.arena),
                kv.codec.clone(),
                kv.hot_window,
            )),
            None => Box::new(KvCache::new(self.backend.cfg())),
        }
    }

    fn prefill(&self, cache: &mut dyn KvStore, tokens: &[u8]) -> Vec<f32> {
        crate::model::transformer::prefill(&self.backend, cache, tokens)
    }

    fn decode_step(&self, lanes: &mut [StepLane<'_>]) -> Vec<Vec<f32>> {
        let v = self.vocab();
        forward_step_batch(&self.backend, lanes)
            .chunks_exact(v)
            .map(|row| row.to_vec())
            .collect()
    }

    fn backend_name(&self) -> String {
        self.backend.kind().label().into()
    }

    fn resident_weight_bytes(&self) -> usize {
        self.backend.resident_weight_bytes()
    }

    fn threads(&self) -> usize {
        self.backend.threads()
    }

    fn simd_label(&self) -> String {
        self.backend.simd().label().into()
    }

    fn kv_counters(&self) -> Option<Arc<KvPageCounters>> {
        self.kv.as_ref().map(|kv| kv.arena.counters())
    }

    fn kv_page_budget(&self) -> usize {
        self.kv.as_ref().map_or(0, |kv| kv.arena.max_pages())
    }

    fn kv_page_tokens(&self) -> usize {
        self.kv.as_ref().map_or(0, |kv| kv.arena.page_tokens())
    }

    fn kv_quant_label(&self) -> String {
        self.kv
            .as_ref()
            .map_or("none", |kv| kv.quant.label())
            .into()
    }
}

/// One queued one-shot request. `enqueued` is the wall-clock arrival time
/// feeding the latency metric; the simulator passes `None` — virtual time
/// has no wall clock, and a deterministic replay must never read one.
pub(crate) struct Pending {
    pub(crate) tokens: Vec<u8>,
    pub(crate) reply: Sender<Result<Vec<f32>, String>>,
    pub(crate) enqueued: Option<Instant>,
}

/// One streamed generation event.
#[derive(Clone, Debug, PartialEq)]
pub enum GenEvent {
    /// The next sampled token (already appended to the session).
    Token(u8),
    /// Generation finished; the session now holds `len` tokens and can be
    /// FED or GENerated again.
    Done { len: usize },
}

/// Worker-side message set — the event-intake surface of
/// [`SchedulerCore::handle`], shared by the channel-fed worker thread and
/// the simulator's scripted traces.
pub(crate) enum Msg {
    Prefix(Pending),
    Open {
        reply: Sender<Result<u64, String>>,
    },
    Feed {
        sid: u64,
        tokens: Vec<u8>,
        reply: Sender<Result<usize, String>>,
    },
    Gen {
        sid: u64,
        n: usize,
        params: SampleParams,
        stream: Sender<Result<GenEvent, String>>,
    },
    Close {
        sid: u64,
        reply: Sender<Result<usize, String>>,
    },
}

/// A parked session: its KV cache plus the logits at its last position
/// (present once the first FEED has drained).
struct Session {
    cache: Box<dyn KvStore>,
    last_logits: Option<Vec<f32>>,
}

/// A generation request that arrived while its session's prefill was
/// still draining; it runs (through normal admission) the moment the job
/// completes.
struct WaitingGen {
    n: usize,
    params: SampleParams,
    stream: Sender<Result<GenEvent, String>>,
}

/// A queued chunked-prefill unit: the session's cache (parked out of the
/// session map) plus its prompt tokens, of which `tokens[..cursor]` have
/// already been appended. The scheduler grants each job at most
/// `prefill_chunk` tokens per tick via `BatchForward::prefill` (prefill is
/// incremental, so N chunks are bit-identical to one shot) and rotates
/// half-done jobs behind other waiting ones.
struct PrefillJob {
    sid: u64,
    cache: Box<dyn KvStore>,
    tokens: Vec<u8>,
    cursor: usize,
    /// Logits of the most recently completed chunk (the session's
    /// `last_logits` once the job drains).
    last_logits: Option<Vec<f32>>,
    waiting_gen: Option<WaitingGen>,
}

impl PrefillJob {
    /// Tokens still waiting to be appended.
    fn queued(&self) -> usize {
        self.tokens.len() - self.cursor
    }
}

/// A session currently on the active decode slate.
struct GenJob {
    sid: u64,
    cache: Box<dyn KvStore>,
    last_logits: Vec<f32>,
    sampler: Sampler,
    remaining: usize,
    stream: Sender<Result<GenEvent, String>>,
}

/// Service metrics (atomic, cheap to read while serving).
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    /// Total queue+execute latency in microseconds (one-shot requests).
    pub total_latency_us: AtomicU64,
    /// Sessions currently open.
    pub open_sessions: AtomicU64,
    /// Sessions opened over the service lifetime.
    pub sessions_opened: AtomicU64,
    /// Tokens produced by GEN streaming.
    pub gen_tokens: AtomicU64,
    /// Batched decode steps executed, and the lanes they carried.
    pub decode_steps: AtomicU64,
    pub decode_lanes: AtomicU64,
    /// Prefill jobs enqueued by FEED over the service lifetime.
    pub prefill_jobs: AtomicU64,
    /// Prompt tokens appended through chunked prefill ticks.
    pub prefill_toks: AtomicU64,
    /// KV page-arena counters, set once at startup for paged engines
    /// (absent on dense engines — STATS then reports zeros).
    pub kv: std::sync::OnceLock<Arc<KvPageCounters>>,
    /// Registered-model gauge, set once by the HTTP front door's
    /// [`crate::model::registry::ModelRegistry`] (one shared gauge
    /// across every per-model Metrics). Absent — the single-model
    /// `llvq serve` path — STATS reports `models=1`.
    pub models: std::sync::OnceLock<Arc<AtomicU64>>,
}

impl Metrics {
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn mean_latency_ms(&self) -> f64 {
        let r = self.requests.load(Ordering::Relaxed);
        if r == 0 {
            0.0
        } else {
            self.total_latency_us.load(Ordering::Relaxed) as f64 / r as f64 / 1000.0
        }
    }

    /// Mean lanes per decode step — the slate occupancy the fused backend
    /// amortizes its row decode across.
    pub fn mean_lanes(&self) -> f64 {
        let s = self.decode_steps.load(Ordering::Relaxed);
        if s == 0 {
            0.0
        } else {
            self.decode_lanes.load(Ordering::Relaxed) as f64 / s as f64
        }
    }

    /// Snapshot every `STATS` field, in wire order, against `engine`'s
    /// identity fields. The TCP `STATS` handler and the simulator's
    /// per-tick dump both format through this — one source of truth, so
    /// the two surfaces can never diverge. Field order is part of the
    /// wire contract (`resident_bytes` stays LAST — parsers rsplit on
    /// `=`; the kv fields sit before `threads=`) and is pinned by a unit
    /// test.
    pub fn snapshot(&self, engine: &dyn BatchForward) -> StatsSnapshot {
        let (kv_alloc, kv_quantized, kv_oom) = match self.kv.get() {
            Some(c) => (
                c.allocated.load(Ordering::Relaxed),
                c.quantized.load(Ordering::Relaxed),
                c.oom.load(Ordering::Relaxed),
            ),
            None => (0, 0, 0),
        };
        StatsSnapshot {
            fields: vec![
                ("requests", self.requests.load(Ordering::Relaxed).to_string()),
                ("mean_batch", format!("{:.2}", self.mean_batch())),
                ("mean_latency_ms", format!("{:.3}", self.mean_latency_ms())),
                (
                    "sessions",
                    self.open_sessions.load(Ordering::Relaxed).to_string(),
                ),
                (
                    "gen_tokens",
                    self.gen_tokens.load(Ordering::Relaxed).to_string(),
                ),
                ("mean_lanes", format!("{:.2}", self.mean_lanes())),
                (
                    "prefill_jobs",
                    self.prefill_jobs.load(Ordering::Relaxed).to_string(),
                ),
                (
                    "prefill_toks",
                    self.prefill_toks.load(Ordering::Relaxed).to_string(),
                ),
                ("kv_pages", format!("{kv_alloc}/{}", engine.kv_page_budget())),
                ("kv_quantized", kv_quantized.to_string()),
                ("kv_oom", kv_oom.to_string()),
                ("kv_quant", engine.kv_quant_label()),
                (
                    "models",
                    self.models
                        .get()
                        .map_or(1, |g| g.load(Ordering::Relaxed))
                        .to_string(),
                ),
                ("threads", engine.threads().to_string()),
                ("backend", engine.backend_name()),
                ("simd", engine.simd_label()),
                (
                    "resident_bytes",
                    engine.resident_weight_bytes().to_string(),
                ),
            ],
        }
    }
}

/// An ordered key→value snapshot of [`Metrics`] plus engine identity,
/// produced by [`Metrics::snapshot`]. `Display` renders the canonical
/// `k=v k=v …` line (without the protocol's `OK ` prefix).
pub struct StatsSnapshot {
    fields: Vec<(&'static str, String)>,
}

impl StatsSnapshot {
    /// The ordered fields.
    pub fn fields(&self) -> &[(&'static str, String)] {
        &self.fields
    }

    /// Value of one key, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.fields
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, v)| v.as_str())
    }

    /// The canonical single-line rendering.
    pub fn line(&self) -> String {
        let mut s = String::new();
        for (i, (k, v)) in self.fields.iter().enumerate() {
            if i > 0 {
                s.push(' ');
            }
            s.push_str(k);
            s.push('=');
            s.push_str(v);
        }
        s
    }
}

impl std::fmt::Display for StatsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.line())
    }
}

/// Scheduler configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// One-shot batch limit AND the decode-slate width per tick.
    pub max_batch: usize,
    /// Batch window for one-shot requests while the worker is idle.
    pub max_wait: Duration,
    /// Concurrently open generation sessions the worker admits; OPEN
    /// beyond this answers an error.
    pub max_sessions: usize,
    /// Prompt tokens granted to queued prefill jobs per scheduler tick.
    /// Bounds how long a decode slate can stall behind FEED work: a long
    /// prompt prefills in `ceil(len / prefill_chunk)` ticks, interleaved
    /// with decode steps, instead of one monolithic call.
    pub prefill_chunk: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            max_sessions: 64,
            prefill_chunk: 64,
        }
    }
}

/// The coordinator: `submit()` / session calls from any thread; a
/// dedicated worker runs the continuous-batching scheduler.
pub struct Coordinator {
    tx: Mutex<Option<Sender<Msg>>>,
    pub metrics: Arc<Metrics>,
    /// Kept for live introspection (`STATS` queries backend name and
    /// resident bytes while the worker owns its own clone).
    engine: Arc<dyn BatchForward>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
    stopping: Arc<AtomicBool>,
}

impl Coordinator {
    pub fn start(engine: Arc<dyn BatchForward>, cfg: BatcherConfig) -> Arc<Self> {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Metrics::default());
        if let Some(counters) = engine.kv_counters() {
            let _ = metrics.kv.set(counters);
        }
        let stopping = Arc::new(AtomicBool::new(false));
        let m2 = metrics.clone();
        let s2 = stopping.clone();
        let e2 = engine.clone();
        let worker = std::thread::spawn(move || worker_loop(e2, rx, cfg, m2, s2));
        Arc::new(Self {
            tx: Mutex::new(Some(tx)),
            metrics,
            engine,
            worker: Mutex::new(Some(worker)),
            stopping,
        })
    }

    /// The engine being served (for stats surfaces).
    pub fn engine(&self) -> &Arc<dyn BatchForward> {
        &self.engine
    }

    fn send(&self, msg: Msg) -> Result<(), String> {
        // a client thread that panics while holding this lock (anywhere up
        // its stack) poisons it; the sender inside is still perfectly
        // consistent — Option<Sender> has no invariants a panic can tear —
        // so recover the guard instead of turning every later request into
        // a panic
        let guard = self.tx.lock().unwrap_or_else(|e| e.into_inner());
        let tx = guard.as_ref().ok_or("coordinator stopped")?;
        tx.send(msg).map_err(|_| "worker gone".to_string())
    }

    /// Blocking one-shot request: returns last-position logits.
    pub fn submit(&self, tokens: Vec<u8>) -> Result<Vec<f32>, String> {
        validate_tokens(self.engine.as_ref(), &tokens)?;
        let (rtx, rrx) = channel();
        self.send(Msg::Prefix(Pending {
            tokens,
            reply: rtx,
            enqueued: Some(Instant::now()),
        }))?;
        match rrx.recv() {
            Ok(r) => r,
            Err(_) => Err("worker dropped request".into()),
        }
    }

    /// Open a generation session; returns its id.
    pub fn open_session(&self) -> Result<u64, String> {
        let (rtx, rrx) = channel();
        self.send(Msg::Open { reply: rtx })?;
        match rrx.recv() {
            Ok(r) => r,
            Err(_) => Err("worker dropped request".into()),
        }
    }

    /// Queue prompt tokens for chunked prefill; returns the number of
    /// tokens queued (immediately — the prefill itself drains at
    /// `prefill_chunk` tokens per scheduler tick, interleaved with decode
    /// work, so a long FEED never stalls active generations). A FEED on a
    /// session whose previous job is still draining extends that job; a
    /// subsequent [`Coordinator::generate`] blocks until the queue drains.
    pub fn feed(&self, sid: u64, tokens: Vec<u8>) -> Result<usize, String> {
        validate_tokens(self.engine.as_ref(), &tokens)?;
        let (rtx, rrx) = channel();
        self.send(Msg::Feed {
            sid,
            tokens,
            reply: rtx,
        })?;
        match rrx.recv() {
            Ok(r) => r,
            Err(_) => Err("worker dropped request".into()),
        }
    }

    /// Generate `n` tokens on a session; events stream back as they are
    /// produced (admission errors arrive as the first event).
    pub fn generate(
        &self,
        sid: u64,
        n: usize,
        params: SampleParams,
    ) -> Result<Receiver<Result<GenEvent, String>>, String> {
        if n == 0 {
            return Err("GEN needs n >= 1".into());
        }
        let (stx, srx) = channel();
        self.send(Msg::Gen {
            sid,
            n,
            params,
            stream: stx,
        })?;
        Ok(srx)
    }

    /// Close a session, freeing its KV cache; returns its final length.
    pub fn close_session(&self, sid: u64) -> Result<usize, String> {
        let (rtx, rrx) = channel();
        self.send(Msg::Close { sid, reply: rtx })?;
        match rrx.recv() {
            Ok(r) => r,
            Err(_) => Err("worker dropped request".into()),
        }
    }

    /// Shut down: no new submissions are accepted, every request already
    /// queued is still answered, every queued prefill job drains, and
    /// every active generation runs to completion (FEED and GEN lengths
    /// are bounded by max_seq), then the worker exits and is joined —
    /// deterministic, no sleeps.
    pub fn stop(&self) {
        self.stopping.store(true, Ordering::SeqCst);
        // recover from poison (see send()): stop must always close the
        // channel and join, even after some client thread panicked
        self.tx.lock().unwrap_or_else(|e| e.into_inner()).take(); // close the channel
        if let Some(h) = self
            .worker
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
        {
            let _ = h.join();
        }
    }
}

/// Reject malformed token runs before they reach the scheduler: an id ≥
/// vocab would index the embedding table out of bounds (the panic is also
/// contained by catch_unwind, but validation gives the caller a precise
/// error and keeps poison out of the batch). Shared by the coordinator's
/// client surface and the simulator's scripted FEED/NEXT intake, so both
/// drivers reject exactly the same inputs.
pub(crate) fn validate_tokens(engine: &dyn BatchForward, tokens: &[u8]) -> Result<(), String> {
    if tokens.is_empty() {
        return Err("empty token list".into());
    }
    if tokens.len() > engine.max_seq() {
        return Err(format!(
            "sequence length {} exceeds max_seq {}",
            tokens.len(),
            engine.max_seq()
        ));
    }
    let vocab = engine.vocab();
    if let Some(&bad) = tokens.iter().find(|&&t| (t as usize) >= vocab) {
        return Err(format!("token id {bad} out of range (vocab {vocab})"));
    }
    Ok(())
}

/// The scheduler's per-tick state machine, extracted from the worker
/// thread so two drivers can share it verbatim: the threaded TCP path
/// ([`worker_loop`]: real channel, wall-clock batch window) and the
/// deterministic simulator ([`crate::sim`]: virtual clock, scripted
/// traces). No thread, socket, or wall time lives in here.
///
/// [`SchedulerCore::handle`] is event intake — admission, page
/// reservation, and queue mutation for one message, every reply channel
/// answered synchronously (GEN streams answer over their lifetime).
/// [`SchedulerCore::tick`] runs one scheduler tick in the order the
/// worker thread has always run: one one-shot prefix batch, then the
/// decode slate, then the prefill chunk budget.
pub struct SchedulerCore {
    engine: Arc<dyn BatchForward>,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
    sessions: HashMap<u64, Session>,
    active: Vec<GenJob>,
    /// Queued chunked-prefill jobs, front = next to be granted tokens.
    prefilling: VecDeque<PrefillJob>,
    prefix: Vec<Pending>,
    next_sid: u64,
}

/// Point-in-time queue/slate occupancy of a [`SchedulerCore`] — the
/// introspection surface behind the simulator's per-tick invariant checks
/// and step-through dump. Parked sids are sorted: the session map is a
/// HashMap, and its iteration order must never leak into deterministic
/// output.
pub struct SchedOccupancy {
    /// Parked sessions, sorted by sid.
    pub parked: Vec<u64>,
    /// Active decode lanes in slate order: (sid, tokens remaining).
    pub active: Vec<(u64, usize)>,
    /// Queued prefill jobs in queue order: (sid, cursor, prompt length).
    pub prefilling: Vec<(u64, usize, usize)>,
    /// One-shot prefix requests waiting for the next batch.
    pub prefix_queued: usize,
}

impl SchedulerCore {
    /// Fresh scheduler state over `engine`. Wires the engine's KV
    /// page-arena counters into `metrics` (paged engines only) so every
    /// driver's STATS surface sees them.
    pub fn new(engine: Arc<dyn BatchForward>, cfg: BatcherConfig, metrics: Arc<Metrics>) -> Self {
        if let Some(counters) = engine.kv_counters() {
            let _ = metrics.kv.set(counters);
        }
        Self {
            engine,
            cfg,
            metrics,
            sessions: HashMap::new(),
            active: Vec::new(),
            prefilling: VecDeque::new(),
            prefix: Vec::new(),
            next_sid: 1,
        }
    }

    /// The engine this scheduler drives.
    pub fn engine(&self) -> &Arc<dyn BatchForward> {
        &self.engine
    }

    /// The scheduler configuration.
    pub fn config(&self) -> &BatcherConfig {
        &self.cfg
    }

    /// The shared metrics block.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Decode lanes or prefill jobs waiting — a driver must keep ticking
    /// (never block on its event source) while any exist.
    pub fn has_scheduled_work(&self) -> bool {
        !self.active.is_empty() || !self.prefilling.is_empty()
    }

    /// Anything at all for [`SchedulerCore::tick`] to do, queued one-shot
    /// requests included. Drivers may only block or exit when this is
    /// false (one prefix batch runs per tick, so a burst of one-shots can
    /// outlive the tick that admitted it).
    pub fn has_runnable_work(&self) -> bool {
        self.has_scheduled_work() || !self.prefix.is_empty()
    }

    /// One-shot requests currently queued (the worker's batch window).
    pub(crate) fn prefix_queued(&self) -> usize {
        self.prefix.len()
    }

    /// One scheduler tick: one one-shot prefix batch, then the decode
    /// slate, then the prefill chunk budget.
    pub fn tick(&mut self) {
        self.run_prefix_batch();
        self.run_decode_tick();
        self.run_prefill_tick();
    }

    /// Snapshot the queues and slate for invariant checks / debugging.
    pub fn occupancy(&self) -> SchedOccupancy {
        let mut parked: Vec<u64> = self.sessions.keys().copied().collect();
        parked.sort_unstable();
        SchedOccupancy {
            parked,
            active: self.active.iter().map(|j| (j.sid, j.remaining)).collect(),
            prefilling: self
                .prefilling
                .iter()
                .map(|j| (j.sid, j.cursor, j.tokens.len()))
                .collect(),
            prefix_queued: self.prefix.len(),
        }
    }
}

fn worker_loop(
    engine: Arc<dyn BatchForward>,
    rx: Receiver<Msg>,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
    stopping: Arc<AtomicBool>,
) {
    let mut core = SchedulerCore::new(engine, cfg, metrics);
    let mut closed = false;
    loop {
        if !core.has_runnable_work() {
            if closed {
                return;
            }
            // idle: block for the next message
            match rx.recv() {
                Ok(m) => core.handle(m),
                Err(_) => {
                    closed = true;
                    continue;
                }
            }
            if stopping.load(Ordering::SeqCst) {
                // draining after stop(): the sender is closed, so
                // everything still queued is final — take it all now
                // instead of holding a batch window open
                closed |= drain_all(&rx, &mut core);
            } else if core.prefix_queued() > 0 && !core.has_scheduled_work() {
                // legacy dynamic batching: hold the window open for more
                // one-shot requests, but only while no decode or prefill
                // work waits
                let deadline = Instant::now() + core.config().max_wait;
                while core.prefix_queued() < core.config().max_batch
                    && !core.has_scheduled_work()
                {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(m) => core.handle(m),
                        Err(_) => break, // timeout or disconnect
                    }
                }
            }
        } else {
            // continuous batching: absorb whatever arrived between ticks
            closed |= drain_all(&rx, &mut core);
        }
        core.tick();
    }
}

/// Drain every queued message without blocking; true if the channel is
/// closed.
fn drain_all(rx: &Receiver<Msg>, core: &mut SchedulerCore) -> bool {
    loop {
        match rx.try_recv() {
            Ok(m) => core.handle(m),
            Err(TryRecvError::Empty) => return false,
            Err(TryRecvError::Disconnected) => return true,
        }
    }
}

impl SchedulerCore {
    /// Why a GEN request cannot join the slate (None = admissible).
    fn gen_admit_error(&self, sid: u64, n: usize) -> Option<String> {
        if n == 0 {
            return Some("GEN needs n >= 1".into());
        }
        if self.active.iter().any(|j| j.sid == sid) {
            return Some(format!("session {sid} is busy generating"));
        }
        let Some(sess) = self.sessions.get(&sid) else {
            return Some(format!("unknown session {sid}"));
        };
        if sess.last_logits.is_none() {
            return Some("FEED tokens before GEN".into());
        }
        if self.engine.vocab() > 256 {
            return Some("GEN requires vocab <= 256 (u8 token ids)".into());
        }
        if sess.cache.len() + n > self.engine.max_seq() {
            return Some(format!(
                "GEN {n} would exceed max_seq {} (session holds {} tokens)",
                self.engine.max_seq(),
                sess.cache.len()
            ));
        }
        None
    }

    /// Event intake: admission, page reservation, and queue mutation for
    /// one message.
    pub(crate) fn handle(&mut self, msg: Msg) {
        match msg {
            Msg::Prefix(p) => self.prefix.push(p),
            Msg::Open { reply } => {
                let open = self.sessions.len() + self.active.len() + self.prefilling.len();
                let r = if open >= self.cfg.max_sessions {
                    Err(format!("too many sessions (max {})", self.cfg.max_sessions))
                } else {
                    let sid = self.next_sid;
                    self.next_sid += 1;
                    self.sessions.insert(
                        sid,
                        Session {
                            cache: self.engine.open_session(),
                            last_logits: None,
                        },
                    );
                    self.metrics.sessions_opened.fetch_add(1, Ordering::Relaxed);
                    self.metrics.open_sessions.fetch_add(1, Ordering::Relaxed);
                    Ok(sid)
                };
                let _ = reply.send(r);
            }
            Msg::Feed { sid, tokens, reply } => {
                let _ = reply.send(self.queue_feed(sid, tokens));
            }
            Msg::Gen {
                sid,
                n,
                params,
                stream,
            } => {
                let vocab = self.engine.vocab();
                let max_seq = self.engine.max_seq();
                if let Some(job) = self.prefilling.iter_mut().find(|j| j.sid == sid) {
                    // GEN on a still-prefilling session parks behind the
                    // job and runs through normal admission when it
                    // drains; the bounds that can be checked now are
                    // checked now
                    let err = if job.waiting_gen.is_some() {
                        Some(format!("session {sid} is busy generating"))
                    } else if n == 0 {
                        Some("GEN needs n >= 1".into())
                    } else if vocab > 256 {
                        Some("GEN requires vocab <= 256 (u8 token ids)".into())
                    } else if job.cache.len() + job.queued() + n > max_seq {
                        Some(format!(
                            "GEN {n} would exceed max_seq {max_seq} (session holds {} tokens, {} queued)",
                            job.cache.len(),
                            job.queued()
                        ))
                    } else {
                        None
                    };
                    // reserve pages for the queued prompt plus the
                    // generated tokens now, so a paged arena that cannot
                    // hold the run answers `kv-oom` here instead of
                    // panicking mid-decode
                    let err = err.or_else(|| job.cache.reserve(job.queued() + n).err());
                    match err {
                        Some(e) => {
                            let _ = stream.send(Err(e));
                        }
                        None => job.waiting_gen = Some(WaitingGen { n, params, stream }),
                    }
                } else {
                    self.admit_gen(sid, n, params, stream);
                }
            }
            Msg::Close { sid, reply } => {
                let r = if let Some(sess) = self.sessions.remove(&sid) {
                    let len = sess.cache.len();
                    self.engine.close_session(sess.cache);
                    self.metrics.open_sessions.fetch_sub(1, Ordering::Relaxed);
                    Ok(len)
                } else if let Some(i) = self.active.iter().position(|j| j.sid == sid) {
                    // closing mid-GEN aborts the stream
                    let job = self.active.remove(i);
                    let _ = job.stream.send(Err("session closed".into()));
                    let len = job.cache.len();
                    self.engine.close_session(job.cache);
                    self.metrics.open_sessions.fetch_sub(1, Ordering::Relaxed);
                    Ok(len)
                } else if let Some(i) = self.prefilling.iter().position(|j| j.sid == sid) {
                    // closing mid-prefill (e.g. the client disconnected
                    // with its FEED still queued) frees the cache, drops
                    // the queued tokens, and fails any GEN waiting on the
                    // job
                    // lint:allow(no-panic-serving): `i` came from
                    // position() on this same Vec one line up
                    let mut job = self.prefilling.remove(i).expect("index from position");
                    if let Some(wg) = job.waiting_gen.take() {
                        let _ = wg.stream.send(Err("session closed".into()));
                    }
                    let len = job.cache.len();
                    self.engine.close_session(job.cache);
                    self.metrics.open_sessions.fetch_sub(1, Ordering::Relaxed);
                    Ok(len)
                } else {
                    Err(format!("unknown session {sid}"))
                };
                let _ = reply.send(r);
            }
        }
    }
}

impl SchedulerCore {
    /// Queue `tokens` as chunked-prefill work for session `sid`, replying
    /// with the number of tokens queued. The engine never runs here — the
    /// prompt drains at `prefill_chunk` tokens per scheduler tick, so a
    /// long FEED cannot stall the decode slate. A FEED on a session whose
    /// job is still draining extends that job (chunked FEED); once a GEN
    /// is waiting on the job, further FEEDs are rejected (the GEN pinned
    /// the token run).
    fn queue_feed(&mut self, sid: u64, tokens: Vec<u8>) -> Result<usize, String> {
        let n = tokens.len();
        let max_seq = self.engine.max_seq();
        if n == 0 {
            return Err("empty token list".into());
        }
        if self.active.iter().any(|j| j.sid == sid) {
            return Err(format!("session {sid} is busy generating"));
        }
        if let Some(job) = self.prefilling.iter_mut().find(|j| j.sid == sid) {
            if job.waiting_gen.is_some() {
                return Err(format!("session {sid} is busy generating"));
            }
            if job.cache.len() + job.queued() + n > max_seq {
                return Err(format!(
                    "FEED of {n} tokens would exceed max_seq {max_seq} (session holds {}, {} queued)",
                    job.cache.len(),
                    job.queued()
                ));
            }
            // admission against the *live* page budget: reserve pages
            // through the whole queued run now (reserve is monotonic, so
            // the earlier reservation still covers tokens already queued)
            // — an exhausted arena answers `kv-oom` and leaves the job
            // untouched
            job.cache.reserve(job.queued() + n)?;
            job.tokens.extend_from_slice(&tokens);
            return Ok(n);
        }
        let Some(sess) = self.sessions.get(&sid) else {
            return Err(format!("unknown session {sid}"));
        };
        if sess.cache.len() + n > max_seq {
            return Err(format!(
                "FEED of {n} tokens would exceed max_seq {max_seq} (session holds {})",
                sess.cache.len()
            ));
        }
        // lint:allow(no-panic-serving): the admission block above returned
        // early unless `sid` is present in the map
        let mut sess = self.sessions.remove(&sid).expect("looked up above");
        // paged engines admit against actual pages, not worst-case
        // max_seq: an exhausted arena parks the session back and answers
        // `kv-oom` (the client may retry after other sessions close)
        if let Err(e) = sess.cache.reserve(n) {
            self.sessions.insert(sid, sess);
            return Err(e);
        }
        self.prefilling.push_back(PrefillJob {
            sid,
            cache: sess.cache,
            tokens,
            cursor: 0,
            last_logits: sess.last_logits,
            waiting_gen: None,
        });
        self.metrics.prefill_jobs.fetch_add(1, Ordering::Relaxed);
        Ok(n)
    }

    /// Run GEN admission on a parked session: on success the session
    /// moves to the active decode slate; on failure the error arrives as
    /// the stream's first event and the session stays parked.
    fn admit_gen(
        &mut self,
        sid: u64,
        n: usize,
        params: SampleParams,
        stream: Sender<Result<GenEvent, String>>,
    ) {
        if let Some(e) = self.gen_admit_error(sid, n) {
            let _ = stream.send(Err(e));
            return;
        }
        // lint:allow(no-panic-serving): gen_admit_error just verified the
        // session exists and holds logits
        let mut sess = self.sessions.remove(&sid).expect("admission checked");
        // reserve pages for the whole run before joining the slate: a
        // paged arena without room answers `kv-oom` as the stream's first
        // event and the session parks again, untouched
        if let Err(e) = sess.cache.reserve(n) {
            self.sessions.insert(sid, sess);
            let _ = stream.send(Err(e));
            return;
        }
        self.active.push(GenJob {
            sid,
            cache: sess.cache,
            // lint:allow(no-panic-serving): gen_admit_error rejects
            // sessions without logits before this point
            last_logits: sess.last_logits.expect("admission checked"),
            sampler: Sampler::new(params),
            remaining: n,
            stream,
        });
    }
}

impl SchedulerCore {
    /// One prefill tick: grant up to `prefill_chunk` prompt tokens to
    /// queued prefill jobs, front of the queue first. A job with tokens
    /// left after the tick's budget is spent rotates to the back
    /// (fairness between concurrent long FEEDs); a drained job parks its
    /// session again and launches any GEN that was waiting on it. Every
    /// chunk runs under `catch_unwind`: a panicking engine destroys
    /// exactly that job's session, never the scheduler.
    fn run_prefill_tick(&mut self) {
        let engine = Arc::clone(&self.engine);
        let mut budget = self.cfg.prefill_chunk.max(1);
        while budget > 0 {
            let Some(mut job) = self.prefilling.pop_front() else {
                return;
            };
            // jobs always hold ≥ 1 queued token (drained jobs leave the
            // queue immediately below), so take ≥ 1 and the loop
            // terminates
            let take = budget.min(job.queued());
            let res = {
                let chunk = &job.tokens[job.cursor..job.cursor + take];
                let cache = &mut job.cache;
                catch_unwind(AssertUnwindSafe(|| engine.prefill(cache.as_mut(), chunk)))
            };
            match res {
                Ok(logits) => {
                    job.cursor += take;
                    budget -= take;
                    job.last_logits = Some(logits);
                    self.metrics
                        .prefill_toks
                        .fetch_add(take as u64, Ordering::Relaxed);
                    if job.queued() == 0 {
                        self.finish_prefill_job(job);
                    } else {
                        self.prefilling.push_back(job);
                    }
                }
                Err(_) => {
                    // the cache is indeterminate after a panic: destroy
                    // the session; a waiting GEN learns through its
                    // stream (the FEED itself was already answered at
                    // queue time)
                    if let Some(wg) = job.waiting_gen.take() {
                        let _ = wg.stream.send(Err(
                            "engine panicked during prefill; session destroyed".into(),
                        ));
                    }
                    self.metrics.open_sessions.fetch_sub(1, Ordering::Relaxed);
                    engine.close_session(job.cache);
                }
            }
        }
    }

    /// A drained prefill job parks its session (with the final chunk's
    /// logits) and, if a GEN was waiting on it, runs that GEN's admission
    /// now.
    fn finish_prefill_job(&mut self, job: PrefillJob) {
        let PrefillJob {
            sid,
            cache,
            last_logits,
            waiting_gen,
            ..
        } = job;
        self.sessions.insert(
            sid,
            Session {
                cache,
                // lint:allow(no-panic-serving): a job only drains after
                // its final chunk ran, and every chunk stores logits
                last_logits: Some(last_logits.expect("a drained job ran at least one chunk")),
            },
        );
        if let Some(wg) = waiting_gen {
            self.admit_gen(sid, wg.n, wg.params, wg.stream);
        }
    }

    /// Answer ONE batch of queued one-shot requests (up to `max_batch`).
    /// One batch per tick — not the whole queue — so a NEXT flood
    /// interleaves with decode slates instead of running all its forward
    /// passes back-to-back while active generations stall (the fairness
    /// fix the simulator's mixed v1/v2 scenario pins). A panic inside the
    /// engine answers `ERR` for that batch instead of killing the worker
    /// (the historical poison-hang).
    fn run_prefix_batch(&mut self) {
        if self.prefix.is_empty() {
            return;
        }
        let engine = Arc::clone(&self.engine);
        let take = self.prefix.len().min(self.cfg.max_batch.max(1));
        let batch: Vec<Pending> = self.prefix.drain(..take).collect();
        let inputs: Vec<Vec<u8>> = batch.iter().map(|p| p.tokens.clone()).collect();
        let outputs = catch_unwind(AssertUnwindSafe(|| engine.forward_batch(&inputs)));
        self.metrics.batches.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .batched_items
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        let outs: Vec<Result<Vec<f32>, String>> = match outputs {
            Ok(outs) => outs.into_iter().map(Ok).collect(),
            Err(_) => batch
                .iter()
                .map(|_| Err("forward pass panicked".to_string()))
                .collect(),
        };
        for (p, out) in batch.into_iter().zip(outs) {
            self.metrics.requests.fetch_add(1, Ordering::Relaxed);
            // virtual-clock drivers pass no arrival time (see Pending):
            // the latency metric then counts the request at zero cost
            // instead of reading a wall clock mid-replay
            let waited = p.enqueued.map_or(0, |t| t.elapsed().as_micros() as u64);
            self.metrics
                .total_latency_us
                .fetch_add(waited, Ordering::Relaxed);
            let _ = p.reply.send(out);
        }
    }

    /// One scheduler tick over the active slate: sample a token per lane
    /// from its current logits, stream it, and append it via a single
    /// batched decode step. Finished (or abandoned) jobs park their
    /// sessions again.
    fn run_decode_tick(&mut self) {
        if self.active.is_empty() {
            return;
        }
        let engine = Arc::clone(&self.engine);
        let take = self.active.len().min(self.cfg.max_batch.max(1));
        let toks: Vec<u8> = self
            .active
            .iter_mut()
            .take(take)
            .map(|job| job.sampler.sample(&job.last_logits) as u8)
            .collect();
        let step = {
            let mut lanes: Vec<StepLane<'_>> = self
                .active
                .iter_mut()
                .take(take)
                .zip(&toks)
                .map(|(job, &token)| StepLane {
                    cache: job.cache.as_mut(),
                    token,
                })
                .collect();
            catch_unwind(AssertUnwindSafe(|| engine.decode_step(&mut lanes)))
        };
        match step {
            Ok(logits) => {
                debug_assert_eq!(logits.len(), take);
                self.metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
                self.metrics
                    .decode_lanes
                    .fetch_add(take as u64, Ordering::Relaxed);
                self.metrics
                    .gen_tokens
                    .fetch_add(take as u64, Ordering::Relaxed);
                let mut finished: Vec<usize> = Vec::new();
                for (i, (job, out)) in self.active.iter_mut().take(take).zip(logits).enumerate() {
                    let alive = job.stream.send(Ok(GenEvent::Token(toks[i]))).is_ok();
                    job.last_logits = out;
                    job.remaining -= 1;
                    if job.remaining == 0 || !alive {
                        finished.push(i);
                    }
                }
                for &i in finished.iter().rev() {
                    let job = self.active.remove(i);
                    let _ = job.stream.send(Ok(GenEvent::Done {
                        len: job.cache.len(),
                    }));
                    self.sessions.insert(
                        job.sid,
                        Session {
                            cache: job.cache,
                            last_logits: Some(job.last_logits),
                        },
                    );
                }
                // fairness: served lanes rotate behind any waiting ones
                let served = take - finished.len();
                if served > 0 && self.active.len() > served {
                    self.active.rotate_left(served);
                }
            }
            Err(_) => {
                // a panicking decode leaves the slate's caches
                // indeterminate: fail and destroy exactly those sessions,
                // keep the rest
                for job in self.active.drain(..take) {
                    let _ = job
                        .stream
                        .send(Err("decode step panicked; session destroyed".into()));
                    self.metrics.open_sessions.fetch_sub(1, Ordering::Relaxed);
                    engine.close_session(job.cache);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// TCP front-end (line protocol)
// ---------------------------------------------------------------------------

/// TCP front-end limits.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Concurrent connections; beyond this the listener answers
    /// `ERR busy` and closes instead of spawning an unbounded thread.
    pub max_conns: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { max_conns: 64 }
    }
}

/// Serve the line protocol with default [`ServeOptions`].
///
/// Forward work runs on the engine's backend, whose fused/cached kernels
/// row-shard over a persistent worker pool sized by `llvq serve
/// --threads` (default: `threadpool::default_threads()`); `STATS` reports
/// the live thread count as `threads=`. The fused backend's SIMD kernel is
/// fixed at load time — runtime CPU-feature detection, overridable with
/// `LLVQ_SIMD` / `llvq serve --simd` — and reported as `simd=` (always
/// `scalar` for dense/cached backends).
///
/// # Protocol reference
///
/// This rustdoc is the summary; the canonical reference — full
/// transcripts, the HTTP/SSE front door (`llvq serve-http`), JSON
/// schemas, and the error-code table — is `docs/PROTOCOL.md` at the
/// repo root.
///
/// One command per line; every reply line starts with `OK`, `ERR`,
/// `QUEUED` (the FEED acknowledgement), or (during GEN streaming) `TOK`.
///
/// **v1 — stateless (back-compatible):**
///
/// | command            | reply                                              |
/// |--------------------|----------------------------------------------------|
/// | `NEXT t1,t2,…`     | `OK next=<argmax> logit=<v>` — full-prefix forward |
/// | `STATS`            | `OK requests=… mean_batch=… mean_latency_ms=… sessions=… gen_tokens=… mean_lanes=… prefill_jobs=… prefill_toks=… kv_pages=<allocated>/<budget> kv_quantized=… kv_oom=… kv_quant=… models=… threads=… backend=… simd=… resident_bytes=…` |
/// | `QUIT`             | closes the connection                              |
///
/// **v2 — generation sessions (one session per connection):**
///
/// | command                               | reply                         |
/// |---------------------------------------|-------------------------------|
/// | `OPEN`                                | `OK session=<id>`             |
/// | `FEED t1,t2,…`                        | `QUEUED <n>` — returns immediately; the prompt prefills in `--prefill-chunk`-token slices interleaved with decode ticks |
/// | `GEN <n> [temp=…] [topk=…] [seed=…]`  | blocks until the session's queued prefill drains, then `n` × `TOK <id>` lines streamed as sampled, then `OK generated=<n> len=<total>` |
/// | `CLOSE`                               | `OK closed len=<total>`       |
///
/// Greedy `GEN` (`temp=0`, the default) is bit-identical to issuing `NEXT`
/// with the growing prefix `n` times — the KV-cache correctness oracle
/// (chunked prefill is itself bit-identical to one-shot prefill, so the
/// oracle is independent of `--prefill-chunk`). Disconnecting closes the
/// session, including mid-prefill: a queued or half-done FEED's cache is
/// freed and its session slot reclaimed.
///
/// **Paged KV sessions** (`llvq serve --kv-pages N [--kv-page-size T]
/// [--kv-quant none|e8|llvq]`): session caches draw fixed-size token
/// pages from a shared arena instead of dense worst-case slabs, and an
/// exhausted arena answers a distinct `ERR kv-oom: page arena exhausted
/// (…)` line with the session left open for retry. Full semantics
/// (cold-page codecs, hot window, occupancy fields) are in
/// `docs/PROTOCOL.md`; dense engines report `kv_pages=0/0`.
///
/// Example transcript (`>` client, `<` server):
///
/// ```text
/// > OPEN
/// < OK session=1
/// > FEED 5,6,7,8
/// < QUEUED 4
/// > GEN 3 temp=0.8 topk=8 seed=42
/// < TOK 17
/// < TOK 3
/// < TOK 44
/// < OK generated=3 len=7
/// > STATS
/// < OK requests=0 mean_batch=0.00 mean_latency_ms=0.000 sessions=1 gen_tokens=3 mean_lanes=1.00 prefill_jobs=1 prefill_toks=4 kv_pages=0/0 kv_quantized=0 kv_oom=0 kv_quant=none models=1 threads=4 backend=fused simd=avx2 resident_bytes=48768
/// > CLOSE
/// < OK closed len=7
/// > QUIT
/// ```
pub fn serve_tcp(coord: Arc<Coordinator>, listener: TcpListener) -> std::io::Result<()> {
    serve_tcp_opts(coord, listener, ServeOptions::default())
}

/// [`serve_tcp`] with explicit limits: at most `max_conns` connection
/// threads run at once; excess connections get one `ERR busy` line and
/// are closed immediately.
pub fn serve_tcp_opts(
    coord: Arc<Coordinator>,
    listener: TcpListener,
    opts: ServeOptions,
) -> std::io::Result<()> {
    let max = opts.max_conns;
    accept_capped(
        listener,
        max,
        move |stream| {
            let _ = writeln!(stream, "ERR busy (max {max} connections)");
        },
        move |stream| {
            let _ = handle_conn(coord.clone(), stream);
        },
    )
}

/// The connection-capped accept loop shared by the TCP line protocol
/// and the HTTP front door ([`crate::http::api::serve_http`]): claim a
/// slot under `max_conns` with a lock-free `fetch_update`, spawn one
/// handler thread per claimed connection, and release the slot when the
/// handler exits. Overflow connections get one `busy` reply (the
/// front-end-specific format is the caller's) and are closed — the
/// server never spawns unboundedly.
pub(crate) fn accept_capped(
    listener: TcpListener,
    max_conns: usize,
    busy: impl Fn(&mut TcpStream) + Send + Sync + 'static,
    handler: impl Fn(TcpStream) + Send + Sync + 'static,
) -> std::io::Result<()> {
    let live = Arc::new(AtomicUsize::new(0));
    let handler = Arc::new(handler);
    for stream in listener.incoming() {
        let mut stream = stream?;
        let claimed = live
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                if n < max_conns {
                    Some(n + 1)
                } else {
                    None
                }
            })
            .is_ok();
        if !claimed {
            busy(&mut stream);
            continue; // dropping the stream closes it
        }
        let h = Arc::clone(&handler);
        let live2 = live.clone();
        std::thread::spawn(move || {
            h(stream);
            live2.fetch_sub(1, Ordering::SeqCst);
        });
    }
    Ok(())
}

fn parse_token_list(s: &str) -> Result<Vec<u8>, String> {
    let toks: Result<Vec<u8>, _> = s.split(',').map(|t| t.trim().parse::<u8>()).collect();
    match toks {
        Ok(v) if !v.is_empty() => Ok(v),
        _ => Err("bad token list".into()),
    }
}

/// `GEN <n> [temp=…] [topk=…] [seed=…]`
fn parse_gen(s: &str) -> Result<(usize, SampleParams), String> {
    let mut it = s.split_whitespace();
    let n: usize = it
        .next()
        .ok_or("GEN needs a token count")?
        .parse()
        .map_err(|_| "bad GEN token count".to_string())?;
    let params = SampleParams::from_kv_args(it)?;
    Ok((n, params))
}

fn handle_conn(coord: Arc<Coordinator>, stream: TcpStream) -> std::io::Result<()> {
    let peer = stream.try_clone()?;
    let mut reader = BufReader::new(peer);
    let mut out = stream;
    // one generation session per connection, closed with it
    let mut sid: Option<u64> = None;
    let r = serve_lines(&coord, &mut reader, &mut out, &mut sid);
    if let Some(s) = sid {
        let _ = coord.close_session(s);
    }
    r
}

fn serve_lines(
    coord: &Arc<Coordinator>,
    reader: &mut BufReader<TcpStream>,
    out: &mut TcpStream,
    sid: &mut Option<u64>,
) -> std::io::Result<()> {
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let line = line.trim();
        if line == "QUIT" {
            return Ok(());
        }
        if line == "STATS" {
            // one formatter for every stats surface: Metrics::snapshot
            // (field order pinned there — resident_bytes stays last, kv
            // fields before threads=)
            writeln!(
                out,
                "OK {}",
                coord.metrics.snapshot(coord.engine().as_ref())
            )?;
            continue;
        }
        if line == "OPEN" {
            if sid.is_some() {
                writeln!(out, "ERR session already open on this connection")?;
                continue;
            }
            match coord.open_session() {
                Ok(s) => {
                    *sid = Some(s);
                    writeln!(out, "OK session={s}")?;
                }
                Err(e) => writeln!(out, "ERR {e}")?,
            }
            continue;
        }
        if line == "CLOSE" {
            match sid.take() {
                Some(s) => match coord.close_session(s) {
                    Ok(len) => writeln!(out, "OK closed len={len}")?,
                    Err(e) => writeln!(out, "ERR {e}")?,
                },
                None => writeln!(out, "ERR no open session")?,
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("FEED ") {
            let Some(s) = *sid else {
                writeln!(out, "ERR no open session (send OPEN first)")?;
                continue;
            };
            match parse_token_list(rest).and_then(|toks| coord.feed(s, toks)) {
                Ok(n) => writeln!(out, "QUEUED {n}")?,
                Err(e) => writeln!(out, "ERR {e}")?,
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("GEN ") {
            let Some(s) = *sid else {
                writeln!(out, "ERR no open session (send OPEN first)")?;
                continue;
            };
            match parse_gen(rest).and_then(|(n, params)| coord.generate(s, n, params)) {
                Ok(events) => {
                    let mut generated = 0usize;
                    loop {
                        match events.recv() {
                            Ok(Ok(GenEvent::Token(t))) => {
                                writeln!(out, "TOK {t}")?;
                                generated += 1;
                            }
                            Ok(Ok(GenEvent::Done { len })) => {
                                writeln!(out, "OK generated={generated} len={len}")?;
                                break;
                            }
                            Ok(Err(e)) => {
                                writeln!(out, "ERR {e}")?;
                                break;
                            }
                            Err(_) => {
                                writeln!(out, "ERR generation aborted")?;
                                break;
                            }
                        }
                    }
                }
                Err(e) => writeln!(out, "ERR {e}")?,
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("NEXT ") {
            match parse_token_list(rest).and_then(|toks| coord.submit(toks)) {
                Ok(logits) => {
                    let bi = argmax(&logits);
                    writeln!(out, "OK next={bi} logit={:.4}", logits[bi])?;
                }
                Err(e) => writeln!(out, "ERR {e}")?,
            }
            continue;
        }
        writeln!(out, "ERR unknown command")?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::config_by_name;

    fn tiny_engine() -> Arc<dyn BatchForward> {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        Arc::new(BackendEngine::dense(Weights::random(&cfg, 9)))
    }

    #[test]
    fn stats_snapshot_field_order_is_pinned() {
        // the STATS wire contract: exactly these keys, in exactly this
        // order, resident_bytes LAST (parsers rsplit on `=`) — both the
        // TCP reply and the simulator dump format through this snapshot
        let engine = tiny_engine();
        let m = Metrics::default();
        let snap = m.snapshot(engine.as_ref());
        let keys: Vec<&str> = snap.fields().iter().map(|(k, _)| *k).collect();
        assert_eq!(
            keys,
            [
                "requests",
                "mean_batch",
                "mean_latency_ms",
                "sessions",
                "gen_tokens",
                "mean_lanes",
                "prefill_jobs",
                "prefill_toks",
                "kv_pages",
                "kv_quantized",
                "kv_oom",
                "kv_quant",
                "models",
                "threads",
                "backend",
                "simd",
                "resident_bytes",
            ]
        );
        assert!(
            snap.line()
                .starts_with("requests=0 mean_batch=0.00 mean_latency_ms=0.000 sessions=0"),
            "{}",
            snap.line()
        );
        assert_eq!(snap.get("backend"), Some("dense"));
        assert_eq!(snap.get("kv_pages"), Some("0/0"), "dense engine has no arena");
        assert_eq!(snap.get("models"), Some("1"), "no registry gauge: single-model default");
        assert!(snap.get("nope").is_none());
    }

    #[test]
    fn coordinator_answers_requests() {
        let coord = Coordinator::start(tiny_engine(), BatcherConfig::default());
        let logits = coord.submit(vec![1, 2, 3, 4]).unwrap();
        assert_eq!(logits.len(), 64);
        coord.stop();
    }

    #[test]
    fn submit_rejects_bad_token_ids() {
        // satellite fix: an id ≥ vocab used to panic the worker thread and
        // hang every later submit — now it is rejected at submit() time
        let coord = Coordinator::start(tiny_engine(), BatcherConfig::default());
        let err = coord.submit(vec![1, 200, 3]).unwrap_err();
        assert!(err.contains("out of range"), "{err}");
        assert!(coord.submit(Vec::new()).is_err());
        assert!(coord.submit(vec![0; 65]).is_err(), "max_seq is 64");
        // the worker is still alive and serving
        assert_eq!(coord.submit(vec![1, 2, 3]).unwrap().len(), 64);
        coord.stop();
    }

    #[test]
    fn panicking_engine_answers_err_instead_of_hanging() {
        // an engine panic (anything validation misses) must turn into an
        // ERR reply, not a dead worker
        struct PanickyEngine;
        impl BatchForward for PanickyEngine {
            fn vocab(&self) -> usize {
                64
            }
            fn max_seq(&self) -> usize {
                64
            }
            fn forward_batch(&self, _batch: &[Vec<u8>]) -> Vec<Vec<f32>> {
                panic!("simulated engine bug")
            }
            fn open_session(&self) -> Box<dyn KvStore> {
                Box::new(KvCache::new(&config_by_name("qwen3-4b-tiny").unwrap()))
            }
            fn prefill(&self, _cache: &mut dyn KvStore, _tokens: &[u8]) -> Vec<f32> {
                panic!("simulated engine bug")
            }
            fn decode_step(&self, _lanes: &mut [StepLane<'_>]) -> Vec<Vec<f32>> {
                panic!("simulated engine bug")
            }
        }
        // silence the expected panic backtraces for readable test output
        crate::util::proptest::with_silenced_panics(|| {
            let coord = Coordinator::start(Arc::new(PanickyEngine), BatcherConfig::default());
            let err = coord.submit(vec![1, 2, 3]).unwrap_err();
            assert!(err.contains("panicked"), "{err}");
            // worker survived: it answers again rather than blocking forever
            let err2 = coord.submit(vec![4, 5]).unwrap_err();
            assert!(err2.contains("panicked"), "{err2}");
            // session path: FEED queues fine (the engine has not run yet);
            // the panic surfaces when its chunk executes, destroying the
            // session — the GEN waiting on it gets a clean stream error
            let sid = coord.open_session().unwrap();
            assert_eq!(coord.feed(sid, vec![1, 2]).unwrap(), 2);
            let events = coord.generate(sid, 2, SampleParams::default()).unwrap();
            let gerr = events.recv().unwrap().unwrap_err();
            assert!(
                gerr.contains("panicked") || gerr.contains("unknown session"),
                "{gerr}"
            );
            // the destroyed session is gone; the worker is still serving
            let ferr2 = coord.feed(sid, vec![1]).unwrap_err();
            assert!(ferr2.contains("unknown session"), "{ferr2}");
            coord.stop();
        });
    }

    #[test]
    fn poisoned_send_lock_recovers_instead_of_panicking() {
        // regression: a client thread panicking while holding the tx lock
        // used to poison it, turning every later submit()/stop() into a
        // panic despite the engine-side catch_unwind hardening
        let coord = Coordinator::start(tiny_engine(), BatcherConfig::default());
        let c2 = coord.clone();
        crate::util::proptest::with_silenced_panics(|| {
            let poisoner = std::thread::spawn(move || {
                let _guard = c2.tx.lock().unwrap();
                panic!("simulated client panic while holding the send lock");
            });
            assert!(poisoner.join().is_err(), "poisoner must panic");
        });
        assert!(coord.tx.lock().is_err(), "lock must actually be poisoned");
        // the coordinator still serves…
        assert_eq!(coord.submit(vec![1, 2, 3]).unwrap().len(), 64);
        let sid = coord.open_session().unwrap();
        assert_eq!(coord.feed(sid, vec![4, 5]).unwrap(), 2);
        coord.close_session(sid).unwrap();
        // …and still stops cleanly
        coord.stop();
        assert!(coord.submit(vec![1]).is_err(), "stopped coordinator rejects");
    }

    /// Delegating engine whose prefill sleeps per call — pins "job still
    /// draining" scheduler states deterministically in tests.
    struct SlowPrefill {
        inner: Arc<dyn BatchForward>,
        delay: Duration,
    }

    impl BatchForward for SlowPrefill {
        fn vocab(&self) -> usize {
            self.inner.vocab()
        }
        fn max_seq(&self) -> usize {
            self.inner.max_seq()
        }
        fn forward_batch(&self, batch: &[Vec<u8>]) -> Vec<Vec<f32>> {
            self.inner.forward_batch(batch)
        }
        fn open_session(&self) -> Box<dyn KvStore> {
            self.inner.open_session()
        }
        fn prefill(&self, cache: &mut dyn KvStore, tokens: &[u8]) -> Vec<f32> {
            std::thread::sleep(self.delay);
            self.inner.prefill(cache, tokens)
        }
        fn close_session(&self, cache: Box<dyn KvStore>) {
            self.inner.close_session(cache)
        }
        fn decode_step(&self, lanes: &mut [StepLane<'_>]) -> Vec<Vec<f32>> {
            self.inner.decode_step(lanes)
        }
    }

    #[test]
    fn chunked_prefill_scheduler_matches_monolithic_greedy() {
        // the same prompt fed through a 3-token-per-tick scheduler and a
        // monolithic one must stream identical greedy tokens (chunked
        // prefill is bit-identical to one-shot prefill by construction)
        let engine = tiny_engine();
        let prompt: Vec<u8> = (0..17).map(|i| (i * 7 % 64) as u8).collect();
        let run = |prefill_chunk: usize| -> Vec<u8> {
            let coord = Coordinator::start(
                engine.clone(),
                BatcherConfig {
                    prefill_chunk,
                    ..Default::default()
                },
            );
            let sid = coord.open_session().unwrap();
            assert_eq!(coord.feed(sid, prompt.clone()).unwrap(), prompt.len());
            let events = coord.generate(sid, 5, SampleParams::default()).unwrap();
            let mut toks = Vec::new();
            loop {
                match events.recv().unwrap() {
                    Ok(GenEvent::Token(t)) => toks.push(t),
                    Ok(GenEvent::Done { len }) => {
                        assert_eq!(len, prompt.len() + 5);
                        break;
                    }
                    Err(e) => panic!("{e}"),
                }
            }
            coord.close_session(sid).unwrap();
            assert_eq!(coord.metrics.prefill_jobs.load(Ordering::Relaxed), 1);
            assert_eq!(
                coord.metrics.prefill_toks.load(Ordering::Relaxed),
                prompt.len() as u64
            );
            coord.stop();
            toks
        };
        assert_eq!(run(3), run(64), "chunked scheduler diverged from monolithic");
    }

    #[test]
    fn feed_or_gen_on_a_still_prefilling_session_answers_clean_errors() {
        let coord = Coordinator::start(
            Arc::new(SlowPrefill {
                inner: tiny_engine(),
                delay: Duration::from_millis(5),
            }),
            BatcherConfig {
                prefill_chunk: 1,
                ..Default::default()
            },
        );
        let sid = coord.open_session().unwrap();
        assert_eq!(coord.feed(sid, vec![1; 30]).unwrap(), 30);
        // ~150 ms of chunked prefill ahead; park a GEN behind it…
        let events = coord.generate(sid, 3, SampleParams::default()).unwrap();
        // …then a FEED and a second GEN race the still-draining job: both
        // must answer clean errors (the waiting GEN pinned the token run)
        let ferr = coord.feed(sid, vec![2]).unwrap_err();
        assert!(ferr.contains("busy generating"), "{ferr}");
        let e2 = coord.generate(sid, 1, SampleParams::default()).unwrap();
        let gerr = e2.recv().unwrap().unwrap_err();
        assert!(gerr.contains("busy generating"), "{gerr}");
        // the parked GEN still runs to completion once the prefill drains
        let mut got = 0;
        loop {
            match events.recv().unwrap() {
                Ok(GenEvent::Token(_)) => got += 1,
                Ok(GenEvent::Done { len }) => {
                    assert_eq!(len, 33);
                    break;
                }
                Err(e) => panic!("{e}"),
            }
        }
        assert_eq!(got, 3);
        assert_eq!(coord.metrics.prefill_toks.load(Ordering::Relaxed), 30);
        coord.stop();
    }

    #[test]
    fn close_mid_prefill_reclaims_the_session_slot() {
        // a disconnecting client closes its session while its FEED is
        // still queued/half-done: the cache is freed, queued tokens are
        // dropped, and the session slot is reclaimed
        let coord = Coordinator::start(
            Arc::new(SlowPrefill {
                inner: tiny_engine(),
                delay: Duration::from_millis(5),
            }),
            BatcherConfig {
                prefill_chunk: 1,
                max_sessions: 1,
                ..Default::default()
            },
        );
        let sid = coord.open_session().unwrap();
        assert_eq!(coord.feed(sid, vec![3; 40]).unwrap(), 40);
        let closed_len = coord.close_session(sid).unwrap();
        assert!(closed_len < 40, "close mid-prefill reported len {closed_len}");
        assert_eq!(coord.metrics.open_sessions.load(Ordering::Relaxed), 0);
        // the single session slot is free again and fully usable
        let sid2 = coord.open_session().unwrap();
        assert_eq!(coord.feed(sid2, vec![1, 2]).unwrap(), 2);
        coord.close_session(sid2).unwrap();
        coord.stop();
    }

    #[test]
    fn batching_accumulates_under_load() {
        let coord = Coordinator::start(
            tiny_engine(),
            BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(20),
                ..Default::default()
            },
        );
        std::thread::scope(|s| {
            for t in 0..24 {
                let c = coord.clone();
                s.spawn(move || {
                    let toks: Vec<u8> = (0..10).map(|i| ((t + i) % 64) as u8).collect();
                    c.submit(toks).unwrap();
                });
            }
        });
        assert_eq!(coord.metrics.requests.load(Ordering::Relaxed), 24);
        assert!(
            coord.metrics.mean_batch() > 1.2,
            "no batching happened: {}",
            coord.metrics.mean_batch()
        );
        coord.stop();
    }

    #[test]
    fn stop_answers_or_rejects_every_inflight_request() {
        // stop() closes the door and drains: a concurrent submit either
        // gets real logits (it was queued in time) or the "coordinator
        // stopped" rejection — never a dropped reply channel.
        let coord = Coordinator::start(
            tiny_engine(),
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(50),
                ..Default::default()
            },
        );
        let answered = std::sync::atomic::AtomicU64::new(0);
        std::thread::scope(|s| {
            for t in 0..16u8 {
                let c = coord.clone();
                let answered = &answered;
                s.spawn(move || match c.submit(vec![1, 2, t % 64]) {
                    Ok(logits) => {
                        assert_eq!(logits.len(), 64);
                        answered.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => assert_eq!(e, "coordinator stopped"),
                });
            }
            std::thread::sleep(Duration::from_millis(5));
            coord.stop();
        });
        assert_eq!(
            coord.metrics.requests.load(Ordering::Relaxed),
            answered.load(Ordering::Relaxed),
            "metrics must count exactly the answered requests"
        );
        // idempotent
        coord.stop();
    }

    #[test]
    fn greedy_session_generation_matches_repeated_next() {
        // the KV-cache correctness oracle at the coordinator level:
        // GEN n (greedy) ≡ n × NEXT with the growing prefix, bit for bit
        let coord = Coordinator::start(tiny_engine(), BatcherConfig::default());
        let prefix = vec![5u8, 6, 7];
        let n = 6usize;

        // oracle: repeated one-shot resubmission
        let mut toks = prefix.clone();
        let mut oracle = Vec::new();
        for _ in 0..n {
            let logits = coord.submit(toks.clone()).unwrap();
            let t = argmax(&logits) as u8;
            oracle.push(t);
            toks.push(t);
        }

        // session path
        let sid = coord.open_session().unwrap();
        assert_eq!(coord.feed(sid, prefix.clone()).unwrap(), prefix.len());
        let events = coord.generate(sid, n, SampleParams::default()).unwrap();
        let mut got = Vec::new();
        loop {
            match events.recv().unwrap() {
                Ok(GenEvent::Token(t)) => got.push(t),
                Ok(GenEvent::Done { len }) => {
                    assert_eq!(len, prefix.len() + n);
                    break;
                }
                Err(e) => panic!("generation failed: {e}"),
            }
        }
        assert_eq!(got, oracle, "cached GEN diverged from repeated NEXT");
        assert_eq!(coord.close_session(sid).unwrap(), prefix.len() + n);
        assert_eq!(coord.metrics.gen_tokens.load(Ordering::Relaxed), n as u64);
        coord.stop();
    }

    #[test]
    fn session_admission_and_limits() {
        let coord = Coordinator::start(
            tiny_engine(),
            BatcherConfig {
                max_sessions: 1,
                ..Default::default()
            },
        );
        let sid = coord.open_session().unwrap();
        let err = coord.open_session().unwrap_err();
        assert!(err.contains("too many sessions"), "{err}");
        // GEN before FEED is rejected through the stream
        let events = coord.generate(sid, 2, SampleParams::default()).unwrap();
        let first = events.recv().unwrap();
        assert!(first.unwrap_err().contains("FEED"), "GEN before FEED");
        // FEED past max_seq is rejected
        assert_eq!(coord.feed(sid, vec![1; 60]).unwrap(), 60);
        let err = coord.feed(sid, vec![1; 10]).unwrap_err();
        assert!(err.contains("max_seq"), "{err}");
        // GEN past max_seq is rejected
        let events = coord.generate(sid, 10, SampleParams::default()).unwrap();
        assert!(events.recv().unwrap().is_err());
        // closing frees the slot
        coord.close_session(sid).unwrap();
        assert!(coord.open_session().is_ok());
        coord.stop();
    }

    #[test]
    fn concurrent_sessions_interleave_on_the_slate() {
        // several sessions generating at once share batched decode ticks
        let coord = Coordinator::start(tiny_engine(), BatcherConfig::default());
        let n = 5usize;
        std::thread::scope(|s| {
            for c in 0..4u8 {
                let coord = coord.clone();
                s.spawn(move || {
                    let sid = coord.open_session().unwrap();
                    coord.feed(sid, vec![c % 64, (c + 1) % 64]).unwrap();
                    let events = coord
                        .generate(
                            sid,
                            n,
                            SampleParams {
                                temperature: 0.9,
                                top_k: 8,
                                seed: c as u64,
                            },
                        )
                        .unwrap();
                    let mut got = 0;
                    loop {
                        match events.recv().unwrap() {
                            Ok(GenEvent::Token(t)) => {
                                assert!((t as usize) < 64);
                                got += 1;
                            }
                            Ok(GenEvent::Done { len }) => {
                                assert_eq!(len, 2 + n);
                                break;
                            }
                            Err(e) => panic!("{e}"),
                        }
                    }
                    assert_eq!(got, n);
                    coord.close_session(sid).unwrap();
                });
            }
        });
        assert_eq!(
            coord.metrics.gen_tokens.load(Ordering::Relaxed),
            4 * n as u64
        );
        assert_eq!(coord.metrics.open_sessions.load(Ordering::Relaxed), 0);
        coord.stop();
    }

    #[test]
    fn tcp_roundtrip() {
        let coord = Coordinator::start(tiny_engine(), BatcherConfig::default());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let c2 = coord.clone();
        std::thread::spawn(move || {
            let _ = serve_tcp(c2, listener);
        });
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "NEXT 5,6,7").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK next="), "{line}");
        writeln!(s, "STATS").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("requests=1"), "{line}");
        assert!(line.contains("backend=dense"), "{line}");
        assert!(line.contains("sessions=0"), "{line}");
        assert!(line.contains("resident_bytes="), "{line}");
        writeln!(s, "QUIT").unwrap();
        coord.stop();
    }

    #[test]
    fn deterministic_between_native_batches() {
        let engine = tiny_engine();
        let a = engine.forward_batch(&[vec![1, 2, 3]]);
        let b = engine.forward_batch(&[vec![9, 9], vec![1, 2, 3]]);
        for (x, y) in a[0].iter().zip(&b[1]) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    fn paged_engine(
        pages: usize,
        page_tokens: usize,
        hot_window: usize,
        quant: KvQuantKind,
    ) -> Arc<BackendEngine> {
        let cfg = config_by_name("qwen3-4b-tiny").unwrap();
        let backend = ExecutionBackend::dense(Weights::random(&cfg, 9));
        Arc::new(BackendEngine::paged(backend, pages, page_tokens, hot_window, quant).unwrap())
    }

    #[test]
    fn paged_admission_beats_dense_worst_case_and_answers_kv_oom() {
        // a 6-page × 16-token arena holds 96 tokens of KV; dense
        // worst-case admission (max_seq = 64 per session) would fit ONE
        // session in that budget — paging admits three 16-token sessions
        // concurrently, and the arena answers `kv-oom` only when a
        // reservation genuinely cannot fit
        let engine = paged_engine(6, 16, 32, KvQuantKind::None);
        let coord = Coordinator::start(engine.clone(), BatcherConfig::default());
        let counters = engine.kv_counters().unwrap();

        let mut sids = Vec::new();
        for c in 0..3u8 {
            let sid = coord.open_session().unwrap();
            assert_eq!(coord.feed(sid, vec![c; 16]).unwrap(), 16);
            sids.push(sid);
        }
        // 3 pages reserved; a 64-token FEED needs 4 of the 3 remaining
        let big = coord.open_session().unwrap();
        let err = coord.feed(big, vec![1; 64]).unwrap_err();
        assert!(err.starts_with("kv-oom"), "{err}");
        assert!(counters.oom.load(Ordering::Relaxed) >= 1);
        // the refused session is still open and usable at a smaller size
        assert_eq!(coord.feed(big, vec![1; 16]).unwrap(), 16);

        // greedy GEN over paged caches still streams fine
        let events = coord.generate(sids[0], 2, SampleParams::default()).unwrap();
        loop {
            match events.recv().unwrap() {
                Ok(GenEvent::Token(_)) => {}
                Ok(GenEvent::Done { len }) => {
                    assert_eq!(len, 18);
                    break;
                }
                Err(e) => panic!("{e}"),
            }
        }

        // closing every session drains the arena to zero allocated pages
        for sid in sids {
            coord.close_session(sid).unwrap();
        }
        coord.close_session(big).unwrap();
        assert_eq!(counters.allocated.load(Ordering::Relaxed), 0, "page leak");
        coord.stop();
    }

    #[test]
    fn paged_greedy_generation_matches_dense() {
        // same weights, same prompt: greedy GEN over a paged cache
        // (quant=none, pages cooling behind an 8-token hot window) must
        // stream the exact tokens the dense cache streams
        let prompt: Vec<u8> = (0..13).map(|i| (i * 5 % 64) as u8).collect();
        let n = 6usize;
        let run = |engine: Arc<dyn BatchForward>| -> Vec<u8> {
            let coord = Coordinator::start(engine, BatcherConfig::default());
            let sid = coord.open_session().unwrap();
            coord.feed(sid, prompt.clone()).unwrap();
            let events = coord.generate(sid, n, SampleParams::default()).unwrap();
            let mut toks = Vec::new();
            loop {
                match events.recv().unwrap() {
                    Ok(GenEvent::Token(t)) => toks.push(t),
                    Ok(GenEvent::Done { .. }) => break,
                    Err(e) => panic!("{e}"),
                }
            }
            coord.close_session(sid).unwrap();
            coord.stop();
            toks
        };
        let dense = run(tiny_engine());
        let paged = run(paged_engine(16, 4, 8, KvQuantKind::None));
        assert_eq!(dense, paged, "paged greedy decode diverged from dense");
        // llvq-quantized cold pages keep greedy argmax parity on this
        // seeded prompt (the acceptance bar for lossy cold storage)
        let quantized = run(paged_engine(16, 4, 8, KvQuantKind::Llvq));
        assert_eq!(dense, quantized, "llvq cold pages flipped a greedy token");
    }

    #[test]
    fn paged_prefill_panic_frees_pages() {
        // the panic-containment path must return reserved pages to the
        // arena when it destroys the session (Box drop → PagedKvCache
        // drop), not leak them
        struct PanickyPaged {
            inner: Arc<BackendEngine>,
        }
        impl BatchForward for PanickyPaged {
            fn vocab(&self) -> usize {
                self.inner.vocab()
            }
            fn max_seq(&self) -> usize {
                self.inner.max_seq()
            }
            fn forward_batch(&self, batch: &[Vec<u8>]) -> Vec<Vec<f32>> {
                self.inner.forward_batch(batch)
            }
            fn open_session(&self) -> Box<dyn KvStore> {
                self.inner.open_session()
            }
            fn prefill(&self, _cache: &mut dyn KvStore, _tokens: &[u8]) -> Vec<f32> {
                panic!("simulated engine bug")
            }
            fn decode_step(&self, lanes: &mut [StepLane<'_>]) -> Vec<Vec<f32>> {
                self.inner.decode_step(lanes)
            }
            fn close_session(&self, cache: Box<dyn KvStore>) {
                self.inner.close_session(cache)
            }
            fn kv_counters(&self) -> Option<Arc<KvPageCounters>> {
                self.inner.kv_counters()
            }
        }
        let inner = paged_engine(8, 4, 8, KvQuantKind::None);
        let counters = inner.kv_counters().unwrap();
        crate::util::proptest::with_silenced_panics(|| {
            let coord = Coordinator::start(
                Arc::new(PanickyPaged { inner }),
                BatcherConfig::default(),
            );
            let sid = coord.open_session().unwrap();
            // queue_feed reserves 4 pages up front; the first prefill
            // chunk then panics and the job's session is destroyed
            assert_eq!(coord.feed(sid, vec![1; 16]).unwrap(), 16);
            // the destroyed session answers "unknown" once the tick ran
            loop {
                match coord.feed(sid, vec![1]) {
                    Err(e) if e.contains("unknown session") => break,
                    _ => std::thread::sleep(Duration::from_millis(2)),
                }
            }
            assert_eq!(
                counters.allocated.load(Ordering::Relaxed),
                0,
                "prefill panic leaked arena pages"
            );
            coord.stop();
        });
    }

    #[test]
    fn paged_stats_report_occupancy_over_tcp() {
        let engine = paged_engine(8, 8, 16, KvQuantKind::Llvq);
        let coord = Coordinator::start(engine, BatcherConfig::default());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let c2 = coord.clone();
        std::thread::spawn(move || {
            let _ = serve_tcp(c2, listener);
        });
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        writeln!(s, "OPEN").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK session="), "{line}");
        writeln!(s, "FEED 1,2,3,4,5,6,7,8,9").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("QUEUED 9"), "{line}");
        writeln!(s, "STATS").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        // 9 tokens over 8-token pages = 2 pages reserved at admission
        assert!(line.contains("kv_pages=2/8"), "{line}");
        assert!(line.contains("kv_quant=llvq"), "{line}");
        assert!(line.contains("kv_oom=0"), "{line}");
        // the resident_bytes-last invariant survives the new fields
        let last_key = line
            .trim()
            .rsplit(' ')
            .next()
            .unwrap()
            .split('=')
            .next()
            .unwrap();
        assert_eq!(last_key, "resident_bytes", "{line}");
        writeln!(s, "CLOSE").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK closed"), "{line}");
        writeln!(s, "STATS").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("kv_pages=0/8"), "{line}");
        writeln!(s, "QUIT").unwrap();
        coord.stop();
    }
}
