//! Scalar quantization baselines (paper Fig. 1, Table 4, Table 6).
//!
//! * [`UniformQuantizer`] — symmetric uniform mid-rise quantizer with a
//!   clipping range optimized for the N(0,1) source at each bit width
//!   (the "Uniform" row of Table 4, and the RTN baseline of §5).
//! * [`LloydMaxQuantizer`] — the optimal scalar quantizer for a Gaussian
//!   source, trained by Lloyd's algorithm on a large sample (Table 4's
//!   "Lloyd-Max" row).

use crate::quant::{Code, VectorQuantizer};
use crate::util::bits::BitReader;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256pp;

/// Symmetric uniform quantizer with 2^bits levels over [−c·σ, +c·σ].
#[derive(Clone, Debug)]
pub struct UniformQuantizer {
    pub bits: u32,
    pub clip: f64,
    step: f64,
    levels: i64,
}

impl UniformQuantizer {
    /// Gaussian-optimal clip ranges (minimize MSE for N(0,1)): found by a
    /// quick golden-section sweep; values match the classical tables
    /// (e.g. 2 bits → clip ≈ 1.49·σ... computed at construction).
    pub fn new_gaussian_optimal(bits: u32) -> Self {
        // golden-section search on clip ∈ [0.5, 6.0] minimizing analytic MSE
        // approximated by dense numerical integration of the N(0,1) density.
        let mse_for = |clip: f64| -> f64 {
            let levels = 1i64 << bits;
            let step = 2.0 * clip / levels as f64;
            // integrate (x - q(x))² φ(x) dx over [-8, 8]
            let n = 4000;
            let lo = -8.0;
            let hi = 8.0;
            let h = (hi - lo) / n as f64;
            let mut acc = 0.0;
            for i in 0..=n {
                let x = lo + i as f64 * h;
                let q = {
                    let k = ((x + clip) / step).floor();
                    let k = k.clamp(0.0, (levels - 1) as f64);
                    -clip + (k + 0.5) * step
                };
                let phi = (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt();
                let w = if i == 0 || i == n { 0.5 } else { 1.0 };
                acc += w * (x - q) * (x - q) * phi;
            }
            acc * h
        };
        let (mut a, mut b) = (0.5f64, 6.0f64);
        let inv_phi = (5f64.sqrt() - 1.0) / 2.0;
        for _ in 0..60 {
            let c = b - (b - a) * inv_phi;
            let d = a + (b - a) * inv_phi;
            if mse_for(c) < mse_for(d) {
                b = d;
            } else {
                a = c;
            }
        }
        let clip = 0.5 * (a + b);
        Self::with_clip(bits, clip)
    }

    pub fn with_clip(bits: u32, clip: f64) -> Self {
        let levels = 1i64 << bits;
        Self {
            bits,
            clip,
            step: 2.0 * clip / levels as f64,
            levels,
        }
    }

    #[inline]
    fn level_of(&self, x: f64) -> i64 {
        let k = ((x + self.clip) / self.step).floor() as i64;
        k.clamp(0, self.levels - 1)
    }

    #[inline]
    fn value_of(&self, k: i64) -> f64 {
        -self.clip + (k as f64 + 0.5) * self.step
    }
}

impl VectorQuantizer for UniformQuantizer {
    fn dim(&self) -> usize {
        1
    }

    fn bits_per_weight(&self) -> f64 {
        self.bits as f64
    }

    fn quantize(&self, x: &[f32]) -> Code {
        Code {
            words: vec![self.level_of(x[0] as f64) as u64],
            bits: self.bits,
        }
    }

    fn quantize_into(&self, x: &[f32], code: &mut Code) {
        code.words.clear();
        code.words.push(self.level_of(x[0] as f64) as u64);
        code.bits = self.bits;
    }

    fn dequantize(&self, code: &Code, out: &mut [f32]) {
        out[0] = self.value_of(code.words[0] as i64) as f32;
    }

    fn code_widths(&self) -> Vec<u32> {
        vec![self.bits]
    }

    fn decode_blocks_into(
        &self,
        _widths: &[u32],
        r: &mut BitReader,
        _code: &mut Code,
        _scratch: &mut [f32],
        out: &mut [f32],
    ) {
        // dim = 1: every element is one whole code — stream the raw field
        // through the same value_of expression as dequantize (bit-exact).
        for o in out.iter_mut() {
            *o = self.value_of(r.read(self.bits) as i64) as f32;
        }
    }

    fn spec(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("uniform".into())),
            ("name", Json::Str(self.name())),
            ("dim", Json::Int(1)),
            ("bits", Json::Int(self.bits as i64)),
            ("clip", Json::Num(self.clip)),
        ])
    }

    fn name(&self) -> String {
        format!("uniform-{}b", self.bits)
    }
}

/// Lloyd–Max quantizer trained on a Gaussian sample.
#[derive(Clone, Debug)]
pub struct LloydMaxQuantizer {
    pub bits: u32,
    /// Sorted reconstruction levels.
    pub centers: Vec<f64>,
    /// Decision boundaries (midpoints), len = centers.len() − 1.
    boundaries: Vec<f64>,
}

impl LloydMaxQuantizer {
    /// Train on `n` Gaussian samples with Lloyd iterations to convergence.
    pub fn train_gaussian(bits: u32, n: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::new(seed);
        let mut samples: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let k = 1usize << bits;
        // init: quantiles
        let mut centers: Vec<f64> = (0..k)
            .map(|i| samples[(i * n + n / (2 * k)) / k])
            .collect();
        for _ in 0..200 {
            // assignment via sorted sweep
            let mut sums = vec![0.0f64; k];
            let mut counts = vec![0usize; k];
            let mut ci = 0usize;
            for &s in &samples {
                while ci + 1 < k && (centers[ci + 1] + centers[ci]) * 0.5 < s {
                    ci += 1;
                }
                // ci may need to move back for the next (sorted) sample? no:
                // samples ascend, boundaries ascend → monotone sweep is exact
                sums[ci] += s;
                counts[ci] += 1;
            }
            let mut moved = 0.0f64;
            for i in 0..k {
                if counts[i] > 0 {
                    let c = sums[i] / counts[i] as f64;
                    moved += (c - centers[i]).abs();
                    centers[i] = c;
                }
            }
            if moved < 1e-9 {
                break;
            }
        }
        Self::from_centers(bits, centers)
    }

    /// Rebuild from serialized reconstruction levels (the `.llvqm` load
    /// path); boundaries are re-derived exactly as training derives them.
    pub fn from_centers(bits: u32, centers: Vec<f64>) -> Self {
        assert_eq!(centers.len(), 1usize << bits, "center count vs bits");
        let boundaries = centers
            .windows(2)
            .map(|w| 0.5 * (w[0] + w[1]))
            .collect();
        Self {
            bits,
            centers,
            boundaries,
        }
    }

    #[inline]
    fn level_of(&self, x: f64) -> usize {
        match self
            .boundaries
            .binary_search_by(|b| b.partial_cmp(&x).unwrap())
        {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }
}

impl VectorQuantizer for LloydMaxQuantizer {
    fn dim(&self) -> usize {
        1
    }

    fn bits_per_weight(&self) -> f64 {
        self.bits as f64
    }

    fn quantize(&self, x: &[f32]) -> Code {
        Code {
            words: vec![self.level_of(x[0] as f64) as u64],
            bits: self.bits,
        }
    }

    fn quantize_into(&self, x: &[f32], code: &mut Code) {
        code.words.clear();
        code.words.push(self.level_of(x[0] as f64) as u64);
        code.bits = self.bits;
    }

    fn dequantize(&self, code: &Code, out: &mut [f32]) {
        out[0] = self.centers[code.words[0] as usize] as f32;
    }

    fn code_widths(&self) -> Vec<u32> {
        vec![self.bits]
    }

    fn decode_blocks_into(
        &self,
        _widths: &[u32],
        r: &mut BitReader,
        _code: &mut Code,
        _scratch: &mut [f32],
        out: &mut [f32],
    ) {
        // dim = 1: stream each code straight through the center table —
        // the same lookup dequantize performs (bit-exact).
        for o in out.iter_mut() {
            *o = self.centers[r.read(self.bits) as usize] as f32;
        }
    }

    fn spec(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("lloyd-max".into())),
            ("name", Json::Str(self.name())),
            ("dim", Json::Int(1)),
            ("bits", Json::Int(self.bits as i64)),
            ("centers", Json::arr_f64(&self.centers)),
        ])
    }

    fn name(&self) -> String {
        format!("lloyd-max-{}b", self.bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gaussian_rd;

    #[test]
    fn uniform_2bit_matches_table4() {
        // Table 4: Uniform @2 bits → MSE ≈ 0.12 (clip-optimized uniform on
        // a Gaussian achieves ≈ 0.118; the paper prints 0.15 for a
        // non-optimized range — we accept the tighter value and assert the
        // qualitative band).
        let q = UniformQuantizer::new_gaussian_optimal(2);
        let (mse, bits) = gaussian_rd(&q, 200_000, 42);
        assert_eq!(bits, 2.0);
        assert!(mse > 0.10 && mse < 0.16, "mse = {mse}");
    }

    #[test]
    fn lloyd_max_beats_uniform() {
        let u = UniformQuantizer::new_gaussian_optimal(2);
        let l = LloydMaxQuantizer::train_gaussian(2, 400_000, 7);
        let (mu, _) = gaussian_rd(&u, 100_000, 1);
        let (ml, _) = gaussian_rd(&l, 100_000, 1);
        assert!(ml < mu, "lloyd {ml} !< uniform {mu}");
        // Table 4: Lloyd-Max 2-bit ≈ 0.117–0.12
        assert!((ml - 0.118).abs() < 0.01, "lloyd mse {ml}");
    }

    #[test]
    fn lloyd_max_centers_symmetric_and_sorted() {
        let l = LloydMaxQuantizer::train_gaussian(3, 400_000, 9);
        for w in l.centers.windows(2) {
            assert!(w[0] < w[1]);
        }
        // symmetry of the Gaussian → centers ≈ mirrored
        let k = l.centers.len();
        for i in 0..k / 2 {
            assert!(
                (l.centers[i] + l.centers[k - 1 - i]).abs() < 0.05,
                "asymmetric centers {} vs {}",
                l.centers[i],
                l.centers[k - 1 - i]
            );
        }
    }

    #[test]
    fn quantize_dequantize_hits_nearest_center() {
        let l = LloydMaxQuantizer::train_gaussian(2, 100_000, 3);
        let mut out = [0f32];
        for &x in &[-3.0f32, -0.2, 0.0, 0.7, 2.5] {
            l.reconstruct(&[x], &mut out);
            // verify it picked the argmin center
            let best = l
                .centers
                .iter()
                .map(|&c| (c as f32 - x).abs())
                .fold(f32::INFINITY, f32::min);
            assert!(((out[0] - x).abs() - best).abs() < 1e-6);
        }
    }
}
