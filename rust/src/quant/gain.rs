//! Gain quantizers for the shape–gain construction (paper App. B, App. F).
//!
//! The gain of a 24-dim Gaussian block follows the χ₂₄ distribution; the
//! paper matches the scalar gain code to it. [`ChiGainQuantizer`] holds a
//! fixed codebook of equal-probability χ₂₄ centroids ("b χ-gain bits" rows
//! of Table 7); `bits = 0` degenerates to the single median centroid.
//!
//! The *shape-conditioned optimal-scales* flow (paper Fig. 4): the LLVQ
//! shape–gain quantizer first picks the shape ŝ, computes the optimal gain
//! γ* = ⟨w, ŝ⟩ (App. D.1), and quantizes γ* with this codebook — that
//! logic lives in [`crate::quant::llvq`], conditioned on the chosen shape.

use crate::math::stats;
use crate::quant::{Code, VectorQuantizer};
use crate::util::bits::BitReader;
use crate::util::json::Json;

/// Scalar quantizer over gains with a χ_k-matched codebook.
#[derive(Clone, Debug)]
pub struct ChiGainQuantizer {
    pub bits: u32,
    /// Sorted reconstruction levels (χ_k bin centroids).
    pub levels: Vec<f64>,
}

impl ChiGainQuantizer {
    pub fn new(k: usize, bits: u32) -> Self {
        let levels = stats::chi_gain_codebook(k, 1usize << bits);
        Self { bits, levels }
    }

    /// Rebuild from serialized levels (the `.llvqm` load path) — exact,
    /// including any [`ChiGainQuantizer::scaled`] correction baked in.
    pub fn from_levels(bits: u32, levels: Vec<f64>) -> Self {
        assert_eq!(levels.len(), 1usize << bits, "level count vs bits");
        Self { bits, levels }
    }

    /// Scale every level by `s` (used when the source has σ ≠ 1 or when a
    /// cosine-retention correction is applied).
    pub fn scaled(mut self, s: f64) -> Self {
        for l in self.levels.iter_mut() {
            *l *= s;
        }
        self
    }

    /// Index of the nearest level.
    pub fn nearest(&self, g: f64) -> usize {
        let mut best = 0usize;
        let mut bd = f64::INFINITY;
        for (i, &l) in self.levels.iter().enumerate() {
            let d = (l - g).abs();
            if d < bd {
                bd = d;
                best = i;
            }
        }
        best
    }

    pub fn level(&self, idx: usize) -> f64 {
        self.levels[idx]
    }
}

impl VectorQuantizer for ChiGainQuantizer {
    fn dim(&self) -> usize {
        1
    }

    fn bits_per_weight(&self) -> f64 {
        self.bits as f64
    }

    fn quantize(&self, x: &[f32]) -> Code {
        Code {
            words: vec![self.nearest(x[0] as f64) as u64],
            bits: self.bits,
        }
    }

    fn quantize_into(&self, x: &[f32], code: &mut Code) {
        code.words.clear();
        code.words.push(self.nearest(x[0] as f64) as u64);
        code.bits = self.bits;
    }

    fn dequantize(&self, code: &Code, out: &mut [f32]) {
        out[0] = self.levels[code.words[0] as usize] as f32;
    }

    fn code_widths(&self) -> Vec<u32> {
        vec![self.bits]
    }

    fn decode_blocks_into(
        &self,
        _widths: &[u32],
        r: &mut BitReader,
        _code: &mut Code,
        _scratch: &mut [f32],
        out: &mut [f32],
    ) {
        // dim = 1: stream each code straight through the level table —
        // the same lookup dequantize performs (bit-exact; bits may be 0,
        // where read(0) = 0 selects the single centroid).
        for o in out.iter_mut() {
            *o = self.levels[r.read(self.bits) as usize] as f32;
        }
    }

    fn spec(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("chi-gain".into())),
            ("name", Json::Str(self.name())),
            ("dim", Json::Int(1)),
            ("bits", Json::Int(self.bits as i64)),
            ("levels", Json::arr_f64(&self.levels)),
        ])
    }

    fn name(&self) -> String {
        format!("chi24-gain-{}b", self.bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn zero_bit_gain_is_mean_like() {
        let g = ChiGainQuantizer::new(24, 0);
        assert_eq!(g.levels.len(), 1);
        // median of chi_24 ≈ 4.88
        assert!((g.levels[0] - 4.88).abs() < 0.1);
    }

    #[test]
    fn gain_quantizer_matches_chi24_statistics() {
        // quantizing ‖N(0,I_24)‖ with 4 bits must give small relative error
        let g = ChiGainQuantizer::new(24, 4);
        let mut rng = Xoshiro256pp::new(21);
        let mut rel = 0.0;
        let n = 20_000;
        for _ in 0..n {
            let mut v = [0f64; 24];
            rng.fill_gaussian_f64(&mut v);
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            let q = g.level(g.nearest(norm));
            rel += ((q - norm) / norm).abs();
        }
        rel /= n as f64;
        assert!(rel < 0.03, "mean relative gain error {rel}");
    }

    #[test]
    fn nearest_is_argmin() {
        let g = ChiGainQuantizer::new(24, 3);
        for &x in &[0.1, 3.0, 4.9, 6.2, 12.0] {
            let i = g.nearest(x);
            for (j, &l) in g.levels.iter().enumerate() {
                assert!((g.levels[i] - x).abs() <= (l - x).abs() + 1e-12, "level {j} beats chosen");
            }
        }
    }
}
