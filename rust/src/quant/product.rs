//! Product-code blocking over arbitrary dimensions (paper App. D.3).
//!
//! A D-dimensional row is split into ⌈D/dim⌉ consecutive blocks; the final
//! block is zero-padded. The product quantizer applies the inner quantizer
//! independently per block — "assigning a dedicated dtype to an entire
//! block of weights" (paper §1).

use crate::quant::{Code, VectorQuantizer};

/// Quantize a full row (any length) with `q`, writing the reconstruction
/// into `out`, and returning total bits consumed.
pub fn quantize_row(q: &dyn VectorQuantizer, row: &[f32], out: &mut [f32]) -> u64 {
    assert_eq!(row.len(), out.len());
    let d = q.dim();
    let mut bits = 0u64;
    let mut scratch_in = vec![0f32; d];
    let mut scratch_out = vec![0f32; d];
    let mut i = 0;
    while i < row.len() {
        let take = d.min(row.len() - i);
        scratch_in[..take].copy_from_slice(&row[i..i + take]);
        for v in scratch_in[take..].iter_mut() {
            *v = 0.0; // zero-pad the tail block
        }
        let c = q.quantize(&scratch_in);
        bits += c.bits as u64;
        q.dequantize(&c, &mut scratch_out);
        out[i..i + take].copy_from_slice(&scratch_out[..take]);
        i += take;
    }
    bits
}

/// Quantize a whole row returning the codes (for serialization paths).
pub fn quantize_row_codes(q: &dyn VectorQuantizer, row: &[f32]) -> Vec<Code> {
    let d = q.dim();
    let mut scratch = vec![0f32; d];
    let mut codes = Vec::with_capacity(row.len().div_ceil(d));
    let mut i = 0;
    while i < row.len() {
        let take = d.min(row.len() - i);
        scratch[..take].copy_from_slice(&row[i..i + take]);
        for v in scratch[take..].iter_mut() {
            *v = 0.0;
        }
        codes.push(q.quantize(&scratch));
        i += take;
    }
    codes
}

/// Reconstruct a row from its codes.
pub fn dequantize_row(q: &dyn VectorQuantizer, codes: &[Code], out: &mut [f32]) {
    let d = q.dim();
    let mut scratch = vec![0f32; d];
    let mut i = 0;
    for c in codes {
        q.dequantize(c, &mut scratch);
        let take = d.min(out.len() - i);
        out[i..i + take].copy_from_slice(&scratch[..take]);
        i += take;
    }
    assert_eq!(i, out.len(), "codes did not cover the row exactly");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scalar::UniformQuantizer;

    #[test]
    fn row_blocking_handles_remainders() {
        let q = UniformQuantizer::new_gaussian_optimal(4);
        for len in [1usize, 23, 24, 25, 48, 100] {
            let row: Vec<f32> = (0..len).map(|i| (i as f32 / len as f32) - 0.5).collect();
            let mut out = vec![0f32; len];
            let bits = quantize_row(&q, &row, &mut out);
            assert_eq!(bits, 4 * len as u64); // scalar quantizer: d=1, no padding
            for (a, b) in row.iter().zip(&out) {
                assert!((a - b).abs() < 0.3);
            }
        }
    }

    #[test]
    fn codes_roundtrip_matches_direct() {
        let q = UniformQuantizer::new_gaussian_optimal(3);
        let row: Vec<f32> = (0..50).map(|i| ((i * 37 % 17) as f32 - 8.0) / 8.0).collect();
        let mut direct = vec![0f32; 50];
        quantize_row(&q, &row, &mut direct);
        let codes = quantize_row_codes(&q, &row);
        let mut via_codes = vec![0f32; 50];
        dequantize_row(&q, &codes, &mut via_codes);
        assert_eq!(direct, via_codes);
    }
}
