//! Product-code blocking over arbitrary dimensions (paper App. D.3).
//!
//! A D-dimensional row is split into ⌈D/dim⌉ consecutive blocks; the final
//! block is zero-padded. The product quantizer applies the inner quantizer
//! independently per block — "assigning a dedicated dtype to an entire
//! block of weights" (paper §1).

use crate::quant::{write_code_with, Code, VectorQuantizer};
use crate::util::bits::{BitReader, BitWriter};

/// Quantize a full row (any length) with `q`, writing the reconstruction
/// into `out`, and returning total bits consumed.
pub fn quantize_row(q: &dyn VectorQuantizer, row: &[f32], out: &mut [f32]) -> u64 {
    assert_eq!(row.len(), out.len());
    let d = q.dim();
    let mut bits = 0u64;
    let mut scratch_in = vec![0f32; d];
    let mut scratch_out = vec![0f32; d];
    let mut i = 0;
    while i < row.len() {
        let take = d.min(row.len() - i);
        scratch_in[..take].copy_from_slice(&row[i..i + take]);
        for v in scratch_in[take..].iter_mut() {
            *v = 0.0; // zero-pad the tail block
        }
        let c = q.quantize(&scratch_in);
        bits += c.bits as u64;
        q.dequantize(&c, &mut scratch_out);
        out[i..i + take].copy_from_slice(&scratch_out[..take]);
        i += take;
    }
    bits
}

/// Quantize a whole row returning the codes (for serialization paths).
pub fn quantize_row_codes(q: &dyn VectorQuantizer, row: &[f32]) -> Vec<Code> {
    let d = q.dim();
    let mut scratch = vec![0f32; d];
    let mut codes = Vec::with_capacity(row.len().div_ceil(d));
    let mut i = 0;
    while i < row.len() {
        let take = d.min(row.len() - i);
        scratch[..take].copy_from_slice(&row[i..i + take]);
        for v in scratch[take..].iter_mut() {
            *v = 0.0;
        }
        codes.push(q.quantize(&scratch));
        i += take;
    }
    codes
}

/// Quantize a full row (any length, tail zero-padded) straight into an
/// MSB-first bitstream — the product-code serialization path of the packed
/// `.llvqm` format. One scratch code is reused across blocks, so the loop
/// is allocation-free after warm-up. Returns total bits written.
pub fn encode_row_into(q: &dyn VectorQuantizer, row: &[f32], w: &mut BitWriter) -> u64 {
    let d = q.dim();
    let widths = q.code_widths();
    let mut scratch = vec![0f32; d];
    let mut code = Code::empty();
    let mut bits = 0u64;
    let mut i = 0;
    while i < row.len() {
        let take = d.min(row.len() - i);
        scratch[..take].copy_from_slice(&row[i..i + take]);
        for v in scratch[take..].iter_mut() {
            *v = 0.0;
        }
        q.quantize_into(&scratch, &mut code);
        write_code_with(&widths, &code, w);
        bits += code.bits as u64;
        i += take;
    }
    bits
}

/// Inverse of [`encode_row_into`]: read `⌈out.len()/dim⌉` codes from the
/// bitstream and reconstruct the row (padding lanes discarded).
pub fn decode_row_from(q: &dyn VectorQuantizer, r: &mut BitReader, out: &mut [f32]) {
    let mut scratch = vec![0f32; q.dim()];
    let mut code = Code::empty();
    decode_row_with(q, &q.code_widths(), r, &mut code, &mut scratch, out);
}

/// [`decode_row_from`] against pre-fetched widths and caller-owned scratch
/// (`scratch.len() == q.dim()`) — the block-parallel unpack path hoists
/// these out of its row loop, mirroring the encode side in
/// `pipeline::gptq`.
pub fn decode_row_with(
    q: &dyn VectorQuantizer,
    widths: &[u32],
    r: &mut BitReader,
    code: &mut Code,
    scratch: &mut [f32],
    out: &mut [f32],
) {
    // Grouped decode produces bit-identical values to the old per-block
    // loop here (see the decode_blocks_into contract), so every unpack
    // path inherits the streaming overrides for free.
    q.decode_blocks_into(widths, r, code, scratch, out);
}

/// Reconstruct a row from its codes.
pub fn dequantize_row(q: &dyn VectorQuantizer, codes: &[Code], out: &mut [f32]) {
    let d = q.dim();
    let mut scratch = vec![0f32; d];
    let mut i = 0;
    for c in codes {
        q.dequantize(c, &mut scratch);
        let take = d.min(out.len() - i);
        out[i..i + take].copy_from_slice(&scratch[..take]);
        i += take;
    }
    assert_eq!(i, out.len(), "codes did not cover the row exactly");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scalar::UniformQuantizer;

    #[test]
    fn row_blocking_handles_remainders() {
        let q = UniformQuantizer::new_gaussian_optimal(4);
        for len in [1usize, 23, 24, 25, 48, 100] {
            let row: Vec<f32> = (0..len).map(|i| (i as f32 / len as f32) - 0.5).collect();
            let mut out = vec![0f32; len];
            let bits = quantize_row(&q, &row, &mut out);
            assert_eq!(bits, 4 * len as u64); // scalar quantizer: d=1, no padding
            for (a, b) in row.iter().zip(&out) {
                assert!((a - b).abs() < 0.3);
            }
        }
    }

    #[test]
    fn bitstream_roundtrip_matches_direct_any_length() {
        let q = UniformQuantizer::new_gaussian_optimal(5);
        for len in [1usize, 7, 24, 25, 60] {
            let row: Vec<f32> = (0..len).map(|i| ((i * 31 % 13) as f32 - 6.0) / 7.0).collect();
            let mut direct = vec![0f32; len];
            quantize_row(&q, &row, &mut direct);
            let mut w = BitWriter::new();
            let bits = encode_row_into(&q, &row, &mut w);
            assert_eq!(bits, 5 * len as u64);
            let bytes = w.finish();
            let mut via_stream = vec![0f32; len];
            decode_row_from(&q, &mut BitReader::new(&bytes), &mut via_stream);
            assert_eq!(direct, via_stream);
        }
    }

    #[test]
    fn codes_roundtrip_matches_direct() {
        let q = UniformQuantizer::new_gaussian_optimal(3);
        let row: Vec<f32> = (0..50).map(|i| ((i * 37 % 17) as f32 - 8.0) / 8.0).collect();
        let mut direct = vec![0f32; 50];
        quantize_row(&q, &row, &mut direct);
        let codes = quantize_row_codes(&q, &row);
        let mut via_codes = vec![0f32; 50];
        dequantize_row(&q, &codes, &mut via_codes);
        assert_eq!(direct, via_codes);
    }
}
