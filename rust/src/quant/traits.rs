//! The quantizer abstraction shared by LLVQ and every baseline.
//!
//! A [`VectorQuantizer`] maps a `dim`-length block of weights to a compact
//! integer code and back. The PTQ pipeline (and the Gaussian-source
//! experiments) treat all methods through this trait, which is what makes
//! the paper's "same pipeline, swap the representation" comparisons
//! apples-to-apples.
//!
//! Beyond quantize/dequantize, the trait carries the **codec surface** of
//! the packed `.llvqm` model format:
//!
//! * [`VectorQuantizer::code_widths`] — the bit width of every code field;
//! * [`VectorQuantizer::encode_into`] / [`VectorQuantizer::decode_from`] —
//!   (de)serialization of one block against an MSB-first bitstream;
//! * [`VectorQuantizer::spec`] — a self-describing JSON header (kind, dim,
//!   rate, parameters) from which [`quantizer_from_spec`] reconstructs the
//!   exact quantizer at model-load time, so a packed artifact is
//!   self-contained: no codebook is ever materialized on disk.

use crate::util::bits::{BitReader, BitWriter};
use crate::util::json::Json;

/// A quantized block: the stored code plus its bit cost.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Code {
    /// Opaque integer payload(s). For product codes, one entry per sub-block.
    pub words: Vec<u64>,
    /// Exact bits this code occupies in the serialized model.
    pub bits: u32,
}

impl Code {
    /// An empty scratch code for reuse in hot loops (see
    /// [`VectorQuantizer::quantize_into`]).
    pub fn empty() -> Self {
        Self {
            words: Vec::new(),
            bits: 0,
        }
    }
}

/// Bit-packed code streams for one weight matrix: `rows` independent
/// MSB-first streams of `blocks_per_row` codes, each stream padded to a
/// whole byte so rows can be decoded in parallel from byte offsets.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedCodes {
    /// Bits per block code (sum of the quantizer's field widths).
    pub code_bits: u32,
    pub blocks_per_row: usize,
    /// `ceil(blocks_per_row · code_bits / 8)` — stride between row streams.
    pub row_bytes: usize,
    /// `rows × row_bytes` payload.
    pub data: Vec<u8>,
}

impl PackedCodes {
    pub fn rows(&self) -> usize {
        if self.row_bytes == 0 {
            0
        } else {
            self.data.len() / self.row_bytes
        }
    }
}

/// Write one code against pre-fetched field widths (alloc-free; hot loops
/// hoist `q.code_widths()` out of their block loop).
pub fn write_code_with(widths: &[u32], code: &Code, w: &mut BitWriter) {
    debug_assert_eq!(widths.len(), code.words.len(), "code field count mismatch");
    for (&width, &word) in widths.iter().zip(&code.words) {
        w.write(word, width);
    }
}

/// Read one code into caller-provided scratch against pre-fetched field
/// widths (alloc-free after the scratch warms up).
pub fn read_code_with(widths: &[u32], r: &mut BitReader, code: &mut Code) {
    code.words.clear();
    code.bits = 0;
    for &width in widths {
        code.words.push(r.read(width));
        code.bits += width;
    }
}

/// A (possibly vector) quantizer over fixed-length blocks.
pub trait VectorQuantizer: Send + Sync {
    /// Block length this quantizer consumes (1 for scalar quantizers).
    fn dim(&self) -> usize;

    /// Nominal rate in bits per weight.
    fn bits_per_weight(&self) -> f64;

    /// Quantize one block (`x.len() == self.dim()`), returning the code.
    fn quantize(&self, x: &[f32]) -> Code;

    /// Reconstruct a block from its code into `out`.
    fn dequantize(&self, code: &Code, out: &mut [f32]);

    /// Quantize into caller-provided scratch, reusing its `words`
    /// allocation. The PTQ inner loop calls this once per 24-dim block, so
    /// implementations should avoid allocating.
    fn quantize_into(&self, x: &[f32], code: &mut Code) {
        let c = self.quantize(x);
        code.bits = c.bits;
        code.words.clear();
        code.words.extend_from_slice(&c.words);
    }

    /// Bit width of each `Code::words` field, in order. Constant for a
    /// given quantizer instance; every width is ≤ 64.
    fn code_widths(&self) -> Vec<u32>;

    /// Serialize one code into an MSB-first bitstream.
    fn encode_into(&self, code: &Code, w: &mut BitWriter) {
        write_code_with(&self.code_widths(), code, w);
    }

    /// Read one code from the bitstream and reconstruct the block into
    /// `out` — the exact inverse of [`VectorQuantizer::encode_into`]
    /// followed by [`VectorQuantizer::dequantize`].
    fn decode_from(&self, r: &mut BitReader, out: &mut [f32]) {
        let widths = self.code_widths();
        let mut code = Code::empty();
        self.decode_from_with(&widths, r, &mut code, out);
    }

    /// [`VectorQuantizer::decode_from`] against pre-fetched widths and a
    /// caller-owned scratch code — the same hoisted-scratch shape as
    /// [`VectorQuantizer::decode_row_dot`], so per-block decode loops
    /// (unpack, cached first touch) stay allocation-free after warm-up.
    fn decode_from_with(
        &self,
        widths: &[u32],
        r: &mut BitReader,
        code: &mut Code,
        out: &mut [f32],
    ) {
        read_code_with(widths, r, code);
        self.dequantize(code, out);
    }

    /// Decode `⌈out.len()/dim⌉` consecutive codes from the bitstream into
    /// the flat row segment `out` (any length; padding lanes of the final
    /// block are discarded). This is the grouped-decode half of the SIMD
    /// kernel tier (`quant::kernel`): decoding a whole segment at once
    /// gives the dot-stage vector kernels a contiguous run to consume.
    ///
    /// The default decodes block-by-block through
    /// [`VectorQuantizer::dequantize`]; overrides stream the raw fields
    /// directly but must stay **bit-exact** vs this default — same fields,
    /// same arithmetic expressions per element (pinned by
    /// `rust/tests/kernels.rs` across all five quantizer specs).
    /// `scratch` is `dim`-length spill space for the final partial block.
    fn decode_blocks_into(
        &self,
        widths: &[u32],
        r: &mut BitReader,
        code: &mut Code,
        scratch: &mut [f32],
        out: &mut [f32],
    ) {
        let d = self.dim();
        debug_assert_eq!(scratch.len(), d);
        let mut i = 0;
        while i < out.len() {
            read_code_with(widths, r, code);
            let take = d.min(out.len() - i);
            if take == d {
                self.dequantize(code, &mut out[i..i + d]);
            } else {
                self.dequantize(code, scratch);
                out[i..i + take].copy_from_slice(&scratch[..take]);
            }
            i += take;
        }
    }

    /// Decode one product-coded row (`⌈x.len()/dim⌉` consecutive codes)
    /// from the bitstream and return its dot product with `x`, **without
    /// materializing the row**: each block lands in the caller's
    /// `dim`-length `scratch` and is accumulated immediately (f64). This
    /// is the fused serving backend's inner loop — `widths` must be
    /// [`VectorQuantizer::code_widths`], `code`/`scratch` are reusable
    /// hot-loop state; padding lanes beyond `x.len()` are discarded.
    /// Implementations with table-driven kernels may override it.
    fn decode_row_dot(
        &self,
        widths: &[u32],
        r: &mut BitReader,
        code: &mut Code,
        scratch: &mut [f32],
        x: &[f64],
    ) -> f64 {
        let d = self.dim();
        debug_assert_eq!(scratch.len(), d);
        let mut acc = 0f64;
        let mut i = 0;
        while i < x.len() {
            read_code_with(widths, r, code);
            self.dequantize(code, scratch);
            let take = d.min(x.len() - i);
            for (s, xi) in scratch[..take].iter().zip(&x[i..i + take]) {
                acc += *s as f64 * xi;
            }
            i += take;
        }
        acc
    }

    /// Decode one product-coded row **once** and dot it against `n`
    /// activation lanes at a time: `xs` holds `n` row-major `cols`-length
    /// lanes and `accs` (length `n`) receives each lane's dot product.
    /// Per lane, the accumulation order (block-major, f64, same zip order
    /// inside each block) is identical to [`VectorQuantizer::
    /// decode_row_dot`], so every lane's result is bit-identical to a
    /// single-lane pass — the batched fused backend relies on this to
    /// amortize the code-stream decode across batch lanes without leaving
    /// the single-vector numerical contract.
    #[allow(clippy::too_many_arguments)]
    fn decode_row_dot_multi(
        &self,
        widths: &[u32],
        r: &mut BitReader,
        code: &mut Code,
        scratch: &mut [f32],
        xs: &[f64],
        cols: usize,
        accs: &mut [f64],
    ) {
        let d = self.dim();
        debug_assert_eq!(scratch.len(), d);
        debug_assert_eq!(xs.len(), accs.len() * cols);
        for a in accs.iter_mut() {
            *a = 0.0;
        }
        let mut i = 0;
        while i < cols {
            read_code_with(widths, r, code);
            self.dequantize(code, scratch);
            let take = d.min(cols - i);
            for (lane, acc) in accs.iter_mut().enumerate() {
                let x = &xs[lane * cols + i..lane * cols + i + take];
                for (s, xi) in scratch[..take].iter().zip(x) {
                    *acc += *s as f64 * xi;
                }
            }
            i += take;
        }
    }

    /// Self-describing spec: JSON with a `kind` tag plus every parameter
    /// needed to rebuild this exact quantizer via [`quantizer_from_spec`].
    /// The default is display-only (no `kind`), which the factory rejects —
    /// serializable quantizers override it.
    fn spec(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name())),
            ("dim", Json::Int(self.dim() as i64)),
            ("bits_per_weight", Json::Num(self.bits_per_weight())),
        ])
    }

    /// Convenience: quantize-dequantize round trip.
    fn reconstruct(&self, x: &[f32], out: &mut [f32]) {
        let c = self.quantize(x);
        self.dequantize(&c, out);
    }

    /// Human-readable name for experiment tables.
    fn name(&self) -> String;
}

/// Rebuild a quantizer from its [`VectorQuantizer::spec`] header — the
/// model-load half of the `.llvqm` codec. Reconstruction is exact: the
/// rebuilt quantizer dequantizes every code to bit-identical f32 values.
pub fn quantizer_from_spec(spec: &Json) -> Result<Box<dyn VectorQuantizer>, String> {
    use std::sync::Arc;

    use crate::leech::index::LeechIndexer;
    use crate::quant::e8::{E8Codebook, E8Cut};
    use crate::quant::gain::ChiGainQuantizer;
    use crate::quant::llvq::{LlvqShapeGain, LlvqSpherical};
    use crate::quant::scalar::{LloydMaxQuantizer, UniformQuantizer};

    let kind = spec
        .get("kind")
        .and_then(|v| v.as_str())
        .ok_or_else(|| "quantizer spec missing string field 'kind'".to_string())?;
    let geti = |k: &str| -> Result<i64, String> {
        spec.get(k)
            .and_then(|v| v.as_i64())
            .ok_or_else(|| format!("quantizer spec ({kind}) missing int field '{k}'"))
    };
    // range-checked integers: specs come from untrusted `.llvqm` headers,
    // so out-of-range values must Err here, not panic (shift overflow,
    // 2^bits allocations) inside a constructor.
    let getr = |k: &str, lo: i64, hi: i64| -> Result<i64, String> {
        match geti(k)? {
            v if (lo..=hi).contains(&v) => Ok(v),
            v => Err(format!(
                "quantizer spec ({kind}): '{k}' = {v} outside [{lo}, {hi}]"
            )),
        }
    };
    let getf = |k: &str| -> Result<f64, String> {
        let v = spec
            .get(k)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("quantizer spec ({kind}) missing number field '{k}'"))?;
        if v.is_finite() {
            Ok(v)
        } else {
            Err(format!("quantizer spec ({kind}): '{k}' is not finite"))
        }
    };
    let getfs = |k: &str| -> Result<Vec<f64>, String> {
        spec.get(k)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| format!("quantizer spec ({kind}) missing array field '{k}'"))?
            .iter()
            .map(|x| {
                x.as_f64()
                    .ok_or_else(|| format!("non-numeric entry in '{k}'"))
            })
            .collect()
    };

    // scalar codebooks materialize 2^bits levels; 24 bits is already far
    // beyond any rate the pipeline produces. Shell counts explode
    // combinatorially in max_m, so cap it well past the paper's M range.
    const MAX_BITS: i64 = 24;
    const MAX_M: i64 = 32;
    let levels_for = |bits: u32, v: Vec<f64>, k: &str| -> Result<Vec<f64>, String> {
        if v.len() == 1usize << bits {
            Ok(v)
        } else {
            Err(format!(
                "quantizer spec ({kind}): '{k}' has {} entries, bits={bits} needs {}",
                v.len(),
                1usize << bits
            ))
        }
    };

    match kind {
        "uniform" => Ok(Box::new(UniformQuantizer::with_clip(
            getr("bits", 1, MAX_BITS)? as u32,
            getf("clip")?,
        ))),
        "lloyd-max" => {
            let bits = getr("bits", 1, MAX_BITS)? as u32;
            let centers = levels_for(bits, getfs("centers")?, "centers")?;
            Ok(Box::new(LloydMaxQuantizer::from_centers(bits, centers)))
        }
        "chi-gain" => {
            let bits = getr("bits", 0, MAX_BITS)? as u32;
            let levels = levels_for(bits, getfs("levels")?, "levels")?;
            Ok(Box::new(ChiGainQuantizer::from_levels(bits, levels)))
        }
        "e8" => {
            let cut = match spec.get("cut").and_then(|v| v.as_str()) {
                Some("ball") => E8Cut::Ball,
                Some("cube") => E8Cut::Cube,
                other => return Err(format!("bad e8 cut {other:?}")),
            };
            Ok(Box::new(E8Codebook::with_scale(cut, getf("scale")?)))
        }
        "llvq-spherical" => {
            let ix = Arc::new(LeechIndexer::new(getr("max_m", 2, MAX_M)? as usize));
            Ok(Box::new(LlvqSpherical::with_scale(ix, getf("scale")?)))
        }
        "llvq-shape-gain" => {
            let max_m = getr("max_m", 2, MAX_M)?;
            let ix = Arc::new(LeechIndexer::new(max_m as usize));
            let gain_bits = getr("gain_bits", 0, MAX_BITS)? as u32;
            let gain = ChiGainQuantizer::from_levels(
                gain_bits,
                levels_for(gain_bits, getfs("gain_levels")?, "gain_levels")?,
            );
            Ok(Box::new(LlvqShapeGain::with_parts(
                ix,
                gain,
                getr("min_m", 1, max_m)? as usize,
            )))
        }
        other => Err(format!("unknown quantizer kind '{other}'")),
    }
}

/// Measure empirical rate–distortion of `q` on an i.i.d. N(0,1) source
/// (paper eq. 16): returns (mse_per_weight, actual_bits_per_weight).
pub fn gaussian_rd(
    q: &dyn VectorQuantizer,
    num_blocks: usize,
    seed: u64,
) -> (f64, f64) {
    let mut rng = crate::util::rng::Xoshiro256pp::new(seed);
    let d = q.dim();
    let mut x = vec![0f32; d];
    let mut y = vec![0f32; d];
    let mut se = 0f64;
    let mut bits = 0u64;
    for _ in 0..num_blocks {
        rng.fill_gaussian_f32(&mut x);
        let c = q.quantize(&x);
        bits += c.bits as u64;
        q.dequantize(&c, &mut y);
        for i in 0..d {
            let e = x[i] as f64 - y[i] as f64;
            se += e * e;
        }
    }
    let n = (num_blocks * d) as f64;
    (se / n, bits as f64 / n)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trivial pass-through quantizer for trait plumbing tests.
    struct Identity(usize);
    impl VectorQuantizer for Identity {
        fn dim(&self) -> usize {
            self.0
        }
        fn bits_per_weight(&self) -> f64 {
            32.0
        }
        fn quantize(&self, x: &[f32]) -> Code {
            Code {
                words: x.iter().map(|&v| v.to_bits() as u64).collect(),
                bits: 32 * x.len() as u32,
            }
        }
        fn dequantize(&self, code: &Code, out: &mut [f32]) {
            for (o, &w) in out.iter_mut().zip(&code.words) {
                *o = f32::from_bits(w as u32);
            }
        }
        fn code_widths(&self) -> Vec<u32> {
            vec![32; self.0]
        }
        fn name(&self) -> String {
            "identity".into()
        }
    }

    #[test]
    fn identity_has_zero_distortion() {
        let q = Identity(8);
        let (mse, bits) = gaussian_rd(&q, 100, 1);
        assert_eq!(mse, 0.0);
        assert_eq!(bits, 32.0);
    }

    #[test]
    fn default_codec_roundtrips_through_bitstream() {
        let q = Identity(6);
        let x: Vec<f32> = (0..6).map(|i| i as f32 * 0.25 - 0.7).collect();
        let code = q.quantize(&x);
        let mut w = BitWriter::new();
        q.encode_into(&code, &mut w);
        assert_eq!(w.bit_len() as u32, code.bits);
        let bytes = w.finish();
        let mut out = vec![0f32; 6];
        q.decode_from(&mut BitReader::new(&bytes), &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn default_quantize_into_reuses_scratch() {
        let q = Identity(4);
        let mut code = Code::empty();
        q.quantize_into(&[1.0, 2.0, 3.0, 4.0], &mut code);
        assert_eq!(code.bits, 128);
        assert_eq!(code.words.len(), 4);
        q.quantize_into(&[5.0, 6.0, 7.0, 8.0], &mut code);
        assert_eq!(code.words.len(), 4);
        assert_eq!(code.words[0], 5f32.to_bits() as u64);
    }

    #[test]
    fn decode_row_dot_matches_dense_reconstruction() {
        // fused-path contract: dotting the stream against x equals
        // materializing the row first (Identity decodes exactly, so the
        // two are equal up to f64 summation of identical terms)
        let q = Identity(4);
        let row: Vec<f32> = (0..10).map(|i| i as f32 * 0.5 - 2.0).collect();
        let mut w = BitWriter::new();
        crate::quant::product::encode_row_into(&q, &row, &mut w);
        let bytes = w.finish();
        let x: Vec<f64> = (0..10).map(|i| (i as f64) * 0.1 - 0.4).collect();
        let widths = q.code_widths();
        let mut code = Code::empty();
        let mut scratch = vec![0f32; 4];
        let dot = q.decode_row_dot(
            &widths,
            &mut BitReader::new(&bytes),
            &mut code,
            &mut scratch,
            &x,
        );
        let want: f64 = row.iter().zip(&x).map(|(&r, &xi)| r as f64 * xi).sum();
        assert!((dot - want).abs() < 1e-12, "{dot} vs {want}");
    }

    #[test]
    fn decode_row_dot_multi_is_bitwise_per_lane() {
        // the slate contract: lane i of a multi-lane pass must equal a
        // fresh single-lane decode_row_dot of the same stream, bit for bit
        let q = Identity(4);
        let row: Vec<f32> = (0..10).map(|i| i as f32 * 0.3 - 1.1).collect();
        let mut w = BitWriter::new();
        crate::quant::product::encode_row_into(&q, &row, &mut w);
        let bytes = w.finish();
        let widths = q.code_widths();
        let cols = row.len();
        let n = 3usize;
        let xs: Vec<f64> = (0..n * cols).map(|i| (i as f64) * 0.07 - 0.9).collect();
        let mut code = Code::empty();
        let mut scratch = vec![0f32; 4];
        let mut accs = vec![0f64; n];
        q.decode_row_dot_multi(
            &widths,
            &mut BitReader::new(&bytes),
            &mut code,
            &mut scratch,
            &xs,
            cols,
            &mut accs,
        );
        for lane in 0..n {
            let solo = q.decode_row_dot(
                &widths,
                &mut BitReader::new(&bytes),
                &mut code,
                &mut scratch,
                &xs[lane * cols..(lane + 1) * cols],
            );
            assert_eq!(solo.to_bits(), accs[lane].to_bits(), "lane {lane}");
        }
    }

    #[test]
    fn decode_blocks_into_matches_per_block_decode() {
        // grouped segment decode (the SIMD tier's dequant stage) must be
        // bit-exact vs the one-block-at-a-time path, partial tail included
        let q = Identity(4);
        let row: Vec<f32> = (0..10).map(|i| i as f32 * 0.3 - 1.1).collect();
        let mut w = BitWriter::new();
        crate::quant::product::encode_row_into(&q, &row, &mut w);
        let bytes = w.finish();
        let widths = q.code_widths();
        let mut code = Code::empty();
        let mut scratch = vec![0f32; 4];
        let mut per_block = vec![0f32; row.len()];
        crate::quant::product::decode_row_with(
            &q,
            &widths,
            &mut BitReader::new(&bytes),
            &mut code,
            &mut scratch,
            &mut per_block,
        );
        let mut grouped = vec![0f32; row.len()];
        q.decode_blocks_into(
            &widths,
            &mut BitReader::new(&bytes),
            &mut code,
            &mut scratch,
            &mut grouped,
        );
        assert_eq!(
            per_block.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            grouped.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn factory_rejects_unknown_and_specless() {
        let q = Identity(2);
        assert!(quantizer_from_spec(&q.spec()).is_err());
        let bad = crate::util::json::parse(r#"{"kind":"warp-drive"}"#).unwrap();
        assert!(quantizer_from_spec(&bad).is_err());
    }

    #[test]
    fn factory_rejects_out_of_range_specs() {
        // hostile .llvqm headers must Err, not panic/OOM in a constructor
        for s in [
            r#"{"kind":"uniform","bits":70,"clip":2.0}"#,
            r#"{"kind":"uniform","bits":0,"clip":2.0}"#,
            r#"{"kind":"lloyd-max","bits":3,"centers":[0.0]}"#,
            r#"{"kind":"chi-gain","bits":2,"levels":[1.0,2.0,3.0]}"#,
            r#"{"kind":"llvq-spherical","max_m":-3,"scale":1.0}"#,
            r#"{"kind":"llvq-spherical","max_m":4096,"scale":1.0}"#,
            r#"{"kind":"llvq-shape-gain","max_m":4,"min_m":9,"gain_bits":1,"gain_levels":[1.0,2.0]}"#,
            r#"{"kind":"e8","cut":"donut","scale":1.0}"#,
        ] {
            let spec = crate::util::json::parse(s).unwrap();
            assert!(quantizer_from_spec(&spec).is_err(), "accepted: {s}");
        }
    }
}
