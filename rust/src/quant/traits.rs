//! The quantizer abstraction shared by LLVQ and every baseline.
//!
//! A [`VectorQuantizer`] maps a `dim`-length block of weights to a compact
//! integer code and back. The PTQ pipeline (and the Gaussian-source
//! experiments) treat all methods through this trait, which is what makes
//! the paper's "same pipeline, swap the representation" comparisons
//! apples-to-apples.

/// A quantized block: the stored code plus its bit cost.
#[derive(Clone, Debug, PartialEq)]
pub struct Code {
    /// Opaque integer payload(s). For product codes, one entry per sub-block.
    pub words: Vec<u64>,
    /// Exact bits this code occupies in the serialized model.
    pub bits: u32,
}

/// A (possibly vector) quantizer over fixed-length blocks.
pub trait VectorQuantizer: Send + Sync {
    /// Block length this quantizer consumes (1 for scalar quantizers).
    fn dim(&self) -> usize;

    /// Nominal rate in bits per weight.
    fn bits_per_weight(&self) -> f64;

    /// Quantize one block (`x.len() == self.dim()`), returning the code.
    fn quantize(&self, x: &[f32]) -> Code;

    /// Reconstruct a block from its code into `out`.
    fn dequantize(&self, code: &Code, out: &mut [f32]);

    /// Convenience: quantize-dequantize round trip.
    fn reconstruct(&self, x: &[f32], out: &mut [f32]) {
        let c = self.quantize(x);
        self.dequantize(&c, out);
    }

    /// Human-readable name for experiment tables.
    fn name(&self) -> String;
}

/// Measure empirical rate–distortion of `q` on an i.i.d. N(0,1) source
/// (paper eq. 16): returns (mse_per_weight, actual_bits_per_weight).
pub fn gaussian_rd(
    q: &dyn VectorQuantizer,
    num_blocks: usize,
    seed: u64,
) -> (f64, f64) {
    let mut rng = crate::util::rng::Xoshiro256pp::new(seed);
    let d = q.dim();
    let mut x = vec![0f32; d];
    let mut y = vec![0f32; d];
    let mut se = 0f64;
    let mut bits = 0u64;
    for _ in 0..num_blocks {
        rng.fill_gaussian_f32(&mut x);
        let c = q.quantize(&x);
        bits += c.bits as u64;
        q.dequantize(&c, &mut y);
        for i in 0..d {
            let e = x[i] as f64 - y[i] as f64;
            se += e * e;
        }
    }
    let n = (num_blocks * d) as f64;
    (se / n, bits as f64 / n)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trivial pass-through quantizer for trait plumbing tests.
    struct Identity(usize);
    impl VectorQuantizer for Identity {
        fn dim(&self) -> usize {
            self.0
        }
        fn bits_per_weight(&self) -> f64 {
            32.0
        }
        fn quantize(&self, x: &[f32]) -> Code {
            Code {
                words: x.iter().map(|&v| v.to_bits() as u64).collect(),
                bits: 32 * x.len() as u32,
            }
        }
        fn dequantize(&self, code: &Code, out: &mut [f32]) {
            for (o, &w) in out.iter_mut().zip(&code.words) {
                *o = f32::from_bits(w as u32);
            }
        }
        fn name(&self) -> String {
            "identity".into()
        }
    }

    #[test]
    fn identity_has_zero_distortion() {
        let q = Identity(8);
        let (mse, bits) = gaussian_rd(&q, 100, 1);
        assert_eq!(mse, 0.0);
        assert_eq!(bits, 32.0);
    }
}
