//! SIMD execution tier for the fused dequant-matvec kernel.
//!
//! The scalar path in [`VectorQuantizer::decode_row_dot_multi`] decodes one
//! block at a time into a `dim()`-float scratch and dots it in a scalar f64
//! loop. This module restructures that hot loop so the vector units see it:
//! a whole *group* of consecutive blocks is decoded into a flat row-segment
//! scratch ([`SEGMENT`] weights per iteration, via
//! [`VectorQuantizer::decode_blocks_into`]), and the segment × activation
//! accumulation runs through an ISA-specific inner kernel selected **once**
//! at backend construction ([`Kernel`]).
//!
//! ## Determinism contract
//!
//! The dequant stage is bit-exact vs the scalar path: `decode_blocks_into`
//! overrides stream the same bit fields through the same arithmetic
//! expressions as `dequantize`. The dot stage reassociates, so it fixes a
//! documented partial-sum shape instead: within a segment, element `j`
//! feeds partial sum `j % 4`, and the four partials reduce as
//! `(p0 + p1) + (p2 + p3)` once per row. Segment boundaries depend only on
//! `dim()` and `cols` — never on thread count or lane count — so results
//! are identical across pool sizes and batch shapes for a given kernel,
//! and every kernel stays within 1e-5 relative error of the scalar oracle
//! (pinned by `rust/tests/kernels.rs` across all five quantizer specs).
//!
//! ## Dispatch
//!
//! [`Kernel::detect`] picks the best runtime-supported kernel (AVX2+FMA on
//! x86-64, NEON on aarch64, `std::simd` when the nightly-only
//! `portable_simd` cargo feature is on, scalar otherwise). The
//! `LLVQ_SIMD=off|scalar|avx2|neon|portable` environment variable or the
//! `--simd` CLI flag overrides detection; forcing a kernel the host cannot
//! run is an error, not a silent fallback.

use crate::quant::{Code, VectorQuantizer};
use crate::util::bits::BitReader;

/// Weights decoded per segment iteration. Divisible by every shipped block
/// dimension (1 scalar/gain, 8 E8, 24 Leech) and by the 4-wide partial-sum
/// shape, so segments always end on block *and* accumulator boundaries.
pub const SEGMENT: usize = 192;

/// Inner-kernel selection for the fused backend, resolved once at backend
/// construction (see [`Kernel::resolve`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// The always-available oracle: delegate to the per-block scalar path
    /// in `decode_row_dot_multi`, bit-identical to pre-dispatch builds.
    Scalar,
    /// AVX2 + FMA intrinsics (x86-64).
    Avx2,
    /// NEON intrinsics (aarch64).
    Neon,
    /// `std::simd` (any arch; requires the nightly-gated `portable_simd`
    /// cargo feature).
    Portable,
}

impl Kernel {
    /// Parse an `LLVQ_SIMD` / `--simd` value. `"off"` is an alias for
    /// `"scalar"` — both force the oracle path.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "off" | "scalar" => Ok(Kernel::Scalar),
            "avx2" => Ok(Kernel::Avx2),
            "neon" => Ok(Kernel::Neon),
            "portable" => Ok(Kernel::Portable),
            other => Err(format!(
                "unknown SIMD kernel '{other}' (expected off|scalar|avx2|neon|portable)"
            )),
        }
    }

    /// Stable label, as reported by `STATS` and the bench `simd` column.
    pub fn label(&self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
            Kernel::Portable => "portable",
        }
    }

    /// Can this kernel run on the current host (arch + runtime CPU
    /// features + crate features)?
    pub fn available(&self) -> bool {
        match self {
            Kernel::Scalar => true,
            Kernel::Avx2 => avx2_available(),
            Kernel::Neon => neon_available(),
            Kernel::Portable => cfg!(feature = "portable_simd"),
        }
    }

    /// Best available kernel on this host (vector kernels first, scalar as
    /// the universal fallback).
    pub fn detect() -> Self {
        [Kernel::Avx2, Kernel::Neon, Kernel::Portable]
            .into_iter()
            .find(Kernel::available)
            .unwrap_or(Kernel::Scalar)
    }

    /// Resolve an explicit preference: `None` auto-detects, `Some(name)`
    /// parses it and errors if the host cannot run the forced kernel.
    pub fn resolve_pref(pref: Option<&str>) -> Result<Self, String> {
        let Some(name) = pref else {
            return Ok(Self::detect());
        };
        let k = Self::parse(name)?;
        if !k.available() {
            return Err(format!(
                "SIMD kernel '{}' is not available on this host (auto-detect picks '{}')",
                k.label(),
                Self::detect().label()
            ));
        }
        Ok(k)
    }

    /// Resolve a CLI `--simd` flag value: a non-empty flag wins, then a
    /// non-empty `LLVQ_SIMD` environment variable, then auto-detection.
    pub fn resolve(flag: &str) -> Result<Self, String> {
        if !flag.is_empty() {
            return Self::resolve_pref(Some(flag));
        }
        match std::env::var("LLVQ_SIMD") {
            Ok(v) if !v.is_empty() => Self::resolve_pref(Some(&v)),
            _ => Ok(Self::detect()),
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(target_arch = "aarch64"))]
fn neon_available() -> bool {
    false
}

/// Per-worker scratch for [`decode_row_dot_multi_kernel`] — one per pool
/// worker, reused across rows so the dispatch loop is allocation-free
/// after warm-up.
#[derive(Default)]
pub struct KernelScratch {
    code: Code,
    block: Vec<f32>,
    seg: Vec<f32>,
    accs: Vec<[f64; 4]>,
}

/// Fused decode + multi-lane dot through the selected kernel.
///
/// Semantics match [`VectorQuantizer::decode_row_dot_multi`]: read
/// `⌈cols/dim⌉` codes from `r` and accumulate the decoded row against
/// `accs.len()` activation lanes of length `cols` (concatenated in `xs`),
/// overwriting `accs`. [`Kernel::Scalar`] delegates to the per-block
/// scalar path verbatim (the oracle); vector kernels use the segmented
/// partial-sum shape documented at module level.
#[allow(clippy::too_many_arguments)]
pub fn decode_row_dot_multi_kernel(
    q: &dyn VectorQuantizer,
    kind: Kernel,
    widths: &[u32],
    r: &mut BitReader,
    s: &mut KernelScratch,
    xs: &[f64],
    cols: usize,
    accs: &mut [f64],
) {
    let d = q.dim();
    s.block.clear();
    s.block.resize(d, 0.0);
    if kind == Kernel::Scalar {
        q.decode_row_dot_multi(widths, r, &mut s.code, &mut s.block, xs, cols, accs);
        return;
    }
    let n = accs.len();
    debug_assert_eq!(xs.len(), n * cols, "xs must hold accs.len() lanes of cols");
    // Largest multiple of `dim` that fits the segment budget: segments end
    // on block boundaries except the final partial block of the row.
    let seg_cap = if d >= SEGMENT { d } else { SEGMENT - SEGMENT % d };
    s.seg.clear();
    s.seg.resize(seg_cap, 0.0);
    s.accs.clear();
    s.accs.resize(n, [0.0; 4]);
    let mut i = 0;
    while i < cols {
        let take = seg_cap.min(cols - i);
        q.decode_blocks_into(widths, r, &mut s.code, &mut s.block, &mut s.seg[..take]);
        for (lane, acc4) in s.accs.iter_mut().enumerate() {
            let x = &xs[lane * cols + i..lane * cols + i + take];
            dot_accumulate(kind, &s.seg[..take], x, acc4);
        }
        i += take;
    }
    for (acc, a) in accs.iter_mut().zip(&s.accs) {
        *acc = (a[0] + a[1]) + (a[2] + a[3]);
    }
}

/// Accumulate `seg[j] * x[j]` into `acc[j % 4]` through the selected
/// kernel. All kernels share this shape; they differ only in whether the
/// multiply-add is fused (one rounding) or split (two), which is what the
/// 1e-5 oracle tolerance absorbs.
fn dot_accumulate(kind: Kernel, seg: &[f32], x: &[f64], acc: &mut [f64; 4]) {
    debug_assert_eq!(seg.len(), x.len());
    match kind {
        Kernel::Scalar => dot_acc_generic(seg, x, acc),
        Kernel::Avx2 => dot_acc_avx2(seg, x, acc),
        Kernel::Neon => dot_acc_neon(seg, x, acc),
        Kernel::Portable => dot_acc_portable(seg, x, acc),
    }
}

/// Portable reference accumulator — same partial-sum shape, plain
/// mul-then-add. The compiler is free to autovectorize it; the result is
/// fixed either way.
fn dot_acc_generic(seg: &[f32], x: &[f64], acc: &mut [f64; 4]) {
    let n4 = seg.len() / 4 * 4;
    for (s4, x4) in seg[..n4].chunks_exact(4).zip(x[..n4].chunks_exact(4)) {
        acc[0] += s4[0] as f64 * x4[0];
        acc[1] += s4[1] as f64 * x4[1];
        acc[2] += s4[2] as f64 * x4[2];
        acc[3] += s4[3] as f64 * x4[3];
    }
    for j in 0..seg.len() - n4 {
        acc[j] += seg[n4 + j] as f64 * x[n4 + j];
    }
}

#[cfg(target_arch = "x86_64")]
fn dot_acc_avx2(seg: &[f32], x: &[f64], acc: &mut [f64; 4]) {
    // SAFETY: dispatch reaches here only when Kernel::Avx2.available()
    // confirmed AVX2+FMA at backend construction.
    unsafe { dot_acc_avx2_impl(seg, x, acc) }
}

#[cfg(not(target_arch = "x86_64"))]
fn dot_acc_avx2(seg: &[f32], x: &[f64], acc: &mut [f64; 4]) {
    dot_acc_generic(seg, x, acc)
}

// SAFETY(contract): callers must have verified AVX2+FMA support — the
// runtime dispatch above is the only caller and checks once at backend
// construction.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_acc_avx2_impl(seg: &[f32], x: &[f64], acc: &mut [f64; 4]) {
    use std::arch::x86_64::*;
    let n4 = seg.len() / 4 * 4;
    // SAFETY: intrinsics require AVX2+FMA (the fn contract); every
    // unaligned load/store stays in bounds — `i < n4 <= seg.len()`,
    // `x.len() == seg.len()` per the kernel layout, and `acc` is 4 wide.
    unsafe {
        let mut a = _mm256_loadu_pd(acc.as_ptr());
        let mut i = 0;
        while i < n4 {
            let s = _mm256_cvtps_pd(_mm_loadu_ps(seg.as_ptr().add(i)));
            let xv = _mm256_loadu_pd(x.as_ptr().add(i));
            a = _mm256_fmadd_pd(s, xv, a);
            i += 4;
        }
        _mm256_storeu_pd(acc.as_mut_ptr(), a);
    }
    for j in 0..seg.len() - n4 {
        acc[j] += seg[n4 + j] as f64 * x[n4 + j];
    }
}

#[cfg(target_arch = "aarch64")]
fn dot_acc_neon(seg: &[f32], x: &[f64], acc: &mut [f64; 4]) {
    // SAFETY: dispatch reaches here only when Kernel::Neon.available()
    // confirmed NEON at backend construction.
    unsafe { dot_acc_neon_impl(seg, x, acc) }
}

#[cfg(not(target_arch = "aarch64"))]
fn dot_acc_neon(seg: &[f32], x: &[f64], acc: &mut [f64; 4]) {
    dot_acc_generic(seg, x, acc)
}

// SAFETY(contract): callers must have verified NEON support — the
// runtime dispatch above is the only caller and checks once at backend
// construction.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_acc_neon_impl(seg: &[f32], x: &[f64], acc: &mut [f64; 4]) {
    use std::arch::aarch64::*;
    let n4 = seg.len() / 4 * 4;
    // SAFETY: intrinsics require NEON (the fn contract); every load/store
    // stays in bounds — `i + 3 < n4 <= seg.len()`, `x.len() == seg.len()`
    // per the kernel layout, and `acc` is 4 wide.
    unsafe {
        let mut a01 = vld1q_f64(acc.as_ptr());
        let mut a23 = vld1q_f64(acc.as_ptr().add(2));
        let mut i = 0;
        while i < n4 {
            let s = vld1q_f32(seg.as_ptr().add(i));
            let lo = vcvt_f64_f32(vget_low_f32(s));
            let hi = vcvt_high_f64_f32(s);
            a01 = vfmaq_f64(a01, lo, vld1q_f64(x.as_ptr().add(i)));
            a23 = vfmaq_f64(a23, hi, vld1q_f64(x.as_ptr().add(i + 2)));
            i += 4;
        }
        vst1q_f64(acc.as_mut_ptr(), a01);
        vst1q_f64(acc.as_mut_ptr().add(2), a23);
    }
    for j in 0..seg.len() - n4 {
        acc[j] += seg[n4 + j] as f64 * x[n4 + j];
    }
}

#[cfg(feature = "portable_simd")]
fn dot_acc_portable(seg: &[f32], x: &[f64], acc: &mut [f64; 4]) {
    use std::simd::prelude::*;
    let n4 = seg.len() / 4 * 4;
    let mut a = f64x4::from_array(*acc);
    for (s4, x4) in seg[..n4].chunks_exact(4).zip(x[..n4].chunks_exact(4)) {
        a += f32x4::from_slice(s4).cast::<f64>() * f64x4::from_slice(x4);
    }
    *acc = a.to_array();
    for j in 0..seg.len() - n4 {
        acc[j] += seg[n4 + j] as f64 * x[n4 + j];
    }
}

#[cfg(not(feature = "portable_simd"))]
fn dot_acc_portable(seg: &[f32], x: &[f64], acc: &mut [f64; 4]) {
    dot_acc_generic(seg, x, acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scalar::UniformQuantizer;
    use crate::util::bits::BitWriter;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn parse_labels_roundtrip_and_reject_unknown() {
        for k in [Kernel::Scalar, Kernel::Avx2, Kernel::Neon, Kernel::Portable] {
            assert_eq!(Kernel::parse(k.label()), Ok(k));
        }
        assert_eq!(Kernel::parse("off"), Ok(Kernel::Scalar));
        let err = Kernel::parse("sse9000").unwrap_err();
        assert!(err.contains("sse9000") && err.contains("portable"), "{err}");
    }

    #[test]
    fn detection_and_forced_selection() {
        // Auto-detection always lands on something the host can run.
        let auto = Kernel::detect();
        assert!(auto.available());
        assert_eq!(Kernel::resolve_pref(None), Ok(auto));
        // Forcing the fallback always works.
        assert_eq!(Kernel::resolve_pref(Some("off")), Ok(Kernel::Scalar));
        assert_eq!(Kernel::resolve_pref(Some("scalar")), Ok(Kernel::Scalar));
        // Forcing any named kernel succeeds exactly when it is available.
        for name in ["avx2", "neon", "portable"] {
            let k = Kernel::parse(name).unwrap();
            match Kernel::resolve_pref(Some(name)) {
                Ok(got) => {
                    assert!(k.available());
                    assert_eq!(got, k);
                }
                Err(e) => {
                    assert!(!k.available());
                    assert!(e.contains(name), "{e}");
                }
            }
        }
        assert!(Kernel::resolve_pref(Some("bogus")).is_err());
    }

    #[test]
    fn scalar_is_always_available() {
        assert!(Kernel::Scalar.available());
    }

    /// Every available accumulator follows the documented partial-sum
    /// shape: close to the generic reference (FMA vs split rounding only)
    /// and bit-identical across reruns.
    #[test]
    fn dot_accumulators_share_shape_and_are_deterministic() {
        let mut rng = Xoshiro256pp::new(0x51AD);
        for len in [0usize, 1, 3, 4, 7, 48, 191, 192] {
            let mut seg = vec![0f32; len];
            rng.fill_gaussian_f32(&mut seg);
            let mut x = vec![0f64; len];
            rng.fill_gaussian_f64(&mut x);
            let mut want = [0f64; 4];
            dot_acc_generic(&seg, &x, &mut want);
            for kind in [Kernel::Avx2, Kernel::Neon, Kernel::Portable] {
                if !kind.available() {
                    continue;
                }
                let mut got = [0f64; 4];
                dot_accumulate(kind, &seg, &x, &mut got);
                for (w, g) in want.iter().zip(&got) {
                    let tol = 1e-12 * w.abs().max(1.0);
                    assert!((w - g).abs() <= tol, "{kind:?} len {len}: {w} vs {g}");
                }
                let mut again = [0f64; 4];
                dot_accumulate(kind, &seg, &x, &mut again);
                assert_eq!(got.map(f64::to_bits), again.map(f64::to_bits));
            }
        }
    }

    /// The dispatch entry point agrees with the scalar oracle, and the
    /// Scalar kind *is* the oracle (bit-identical delegation).
    #[test]
    fn dispatch_matches_scalar_oracle() {
        let q = UniformQuantizer::new_gaussian_optimal(4);
        let widths = q.code_widths();
        let mut rng = Xoshiro256pp::new(0xD15);
        for cols in [1usize, 4, 191, 192, 193, 400] {
            let mut row = vec![0f32; cols];
            rng.fill_gaussian_f32(&mut row);
            let mut w = BitWriter::new();
            crate::quant::product::encode_row_into(&q, &row, &mut w);
            let bytes = w.finish();
            let n = 3;
            let mut xs = vec![0f64; n * cols];
            rng.fill_gaussian_f64(&mut xs);
            let mut want = vec![0f64; n];
            let mut code = Code::empty();
            let mut block = vec![0f32; q.dim()];
            q.decode_row_dot_multi(
                &widths,
                &mut BitReader::new(&bytes),
                &mut code,
                &mut block,
                &xs,
                cols,
                &mut want,
            );
            for kind in [Kernel::Scalar, Kernel::detect()] {
                let mut s = KernelScratch::default();
                let mut got = vec![0f64; n];
                decode_row_dot_multi_kernel(
                    &q,
                    kind,
                    &widths,
                    &mut BitReader::new(&bytes),
                    &mut s,
                    &xs,
                    cols,
                    &mut got,
                );
                for (a, b) in want.iter().zip(&got) {
                    if kind == Kernel::Scalar {
                        assert_eq!(a.to_bits(), b.to_bits(), "scalar kind must be the oracle");
                    } else {
                        let tol = 1e-5 * a.abs().max(1.0);
                        assert!((a - b).abs() <= tol, "{kind:?} cols {cols}: {a} vs {b}");
                    }
                }
            }
        }
    }
}
