//! LLVQ — the paper's quantizers (§3, App. C).
//!
//! * [`LlvqSpherical`] — *spherical shaping*: quantize `x/β` to the nearest
//!   point of the ball cut Λ₂₄(M) (Fig. 2), store the bijective index.
//!   The global scale β is Gaussian-optimized at construction.
//! * [`LlvqShapeGain`] — *shape–gain with optimal scales* (Fig. 4, the
//!   paper's main configuration): the direction is quantized by angular
//!   search over the union of shells 2..=M (§3.1), the gain is the
//!   shape-conditioned optimum γ* = ⟨w, ŝ⟩ (App. D.1) quantized with a
//!   χ₂₄-matched codebook. `M` and the gain bits trade off per Table 7
//!   (2 bits/dim ⇒ M=12 shape + 1 gain bit is the paper's best).
//!
//! Both are **codebook-free**: codes are lattice indices, reconstruction
//! goes through the hierarchical dequantizer — never a materialized table.

use std::sync::Arc;

use crate::leech::coset;
use crate::leech::decode::LeechDecoder;
use crate::leech::index::LeechIndexer;
use crate::quant::gain::ChiGainQuantizer;
use crate::quant::{Code, VectorQuantizer};
use crate::util::bits::BitReader;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256pp;
use crate::DIM;

/// √8 — scale between Λ₂₄ (unit covolume) and the integer embedding.
const SQRT8: f64 = 2.828_427_124_746_190_3;

/// Shared lattice machinery for both LLVQ variants.
pub struct LlvqContext {
    pub indexer: Arc<LeechIndexer>,
}

impl LlvqContext {
    pub fn new(max_m: usize) -> Arc<Self> {
        Arc::new(Self {
            indexer: Arc::new(LeechIndexer::new(max_m)),
        })
    }
}

// ---------------------------------------------------------------------------
// Spherical shaping
// ---------------------------------------------------------------------------

pub struct LlvqSpherical {
    indexer: Arc<LeechIndexer>,
    /// Input scale β: quantize x/β, reconstruct ×β.
    pub scale: f64,
    bits: u32,
}

impl LlvqSpherical {
    /// Build with a Gaussian-optimal scale (golden-section on sampled MSE).
    pub fn new(indexer: Arc<LeechIndexer>) -> Self {
        let bits = indexer.index_bits();
        let mut q = Self {
            indexer,
            scale: 1.0,
            bits,
        };
        q.scale = q.optimize_scale(1500, 0x5CA1E);
        q
    }

    /// Build with an explicit scale (used by the pipeline's per-group
    /// scaling and by tests).
    pub fn with_scale(indexer: Arc<LeechIndexer>, scale: f64) -> Self {
        let bits = indexer.index_bits();
        Self {
            indexer,
            scale,
            bits,
        }
    }

    fn optimize_scale(&self, blocks: usize, seed: u64) -> f64 {
        let mut rng = Xoshiro256pp::new(seed);
        let mut sample = vec![0f32; DIM * blocks];
        rng.fill_gaussian_f32(&mut sample);
        let mse_at = |beta: f64| -> f64 {
            let mut se = 0.0;
            let golay = self.indexer.golay();
            let dec = LeechDecoder::new(golay);
            for blk in sample.chunks_exact(DIM) {
                let mut t = [0f64; DIM];
                for i in 0..DIM {
                    t[i] = blk[i] as f64 * SQRT8 / beta;
                }
                let d = dec.decode_in_ball(&t, self.indexer.max_m());
                for i in 0..DIM {
                    let r = d.point[i] as f64 / SQRT8 * beta;
                    let e = blk[i] as f64 - r;
                    se += e * e;
                }
            }
            se
        };
        // the ball radius √(2·max_m) should cover ≈ the χ₂₄ bulk (~√24·σ):
        // β ≈ √24/√(2M) is the right ballpark; search around it
        let center = (24.0f64).sqrt() / (2.0 * self.indexer.max_m() as f64).sqrt();
        let (mut a, mut b) = (center * 0.5, center * 2.0);
        let inv_phi = (5f64.sqrt() - 1.0) / 2.0;
        for _ in 0..18 {
            let c = b - (b - a) * inv_phi;
            let d = a + (b - a) * inv_phi;
            if mse_at(c) < mse_at(d) {
                b = d;
            } else {
                a = c;
            }
        }
        0.5 * (a + b)
    }
}

impl VectorQuantizer for LlvqSpherical {
    fn dim(&self) -> usize {
        DIM
    }

    fn bits_per_weight(&self) -> f64 {
        self.bits as f64 / DIM as f64
    }

    fn quantize(&self, x: &[f32]) -> Code {
        let mut code = Code::empty();
        self.quantize_into(x, &mut code);
        code
    }

    fn quantize_into(&self, x: &[f32], code: &mut Code) {
        let mut t = [0f64; DIM];
        for i in 0..DIM {
            t[i] = x[i] as f64 * SQRT8 / self.scale;
        }
        let dec = LeechDecoder::new(self.indexer.golay());
        let d = dec.decode_in_ball(&t, self.indexer.max_m());
        let idx = self
            .indexer
            .encode_point(&d.point)
            .expect("in-ball decode produced unindexable point");
        code.words.clear();
        code.words.push(idx);
        code.bits = self.bits;
    }

    fn dequantize(&self, code: &Code, out: &mut [f32]) {
        let x = self.indexer.decode_index(code.words[0]);
        for i in 0..DIM {
            out[i] = (x[i] as f64 / SQRT8 * self.scale) as f32;
        }
    }

    fn code_widths(&self) -> Vec<u32> {
        vec![self.bits]
    }

    fn decode_blocks_into(
        &self,
        _widths: &[u32],
        r: &mut BitReader,
        _code: &mut Code,
        _scratch: &mut [f32],
        out: &mut [f32],
    ) {
        // Stream one lattice index per block and write every element
        // through the same expression as dequantize (bit-exact); the final
        // block may be partial and its padding lanes are dropped.
        let mut i = 0;
        while i < out.len() {
            let x = self.indexer.decode_index(r.read(self.bits));
            let take = DIM.min(out.len() - i);
            for (o, &v) in out[i..i + take].iter_mut().zip(x.iter()) {
                *o = (v as f64 / SQRT8 * self.scale) as f32;
            }
            i += take;
        }
    }

    fn spec(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("llvq-spherical".into())),
            ("name", Json::Str(self.name())),
            ("dim", Json::Int(DIM as i64)),
            ("max_m", Json::Int(self.indexer.max_m() as i64)),
            ("scale", Json::Num(self.scale)),
        ])
    }

    fn name(&self) -> String {
        format!(
            "llvq-spherical-M{} ({:.3} bpw)",
            self.indexer.max_m(),
            self.bits_per_weight()
        )
    }
}

// ---------------------------------------------------------------------------
// Shape–gain with optimal scales
// ---------------------------------------------------------------------------

pub struct LlvqShapeGain {
    indexer: Arc<LeechIndexer>,
    pub gain: ChiGainQuantizer,
    shape_bits: u32,
    /// Lowest shell included in the angular search (2 = full union).
    pub min_m: usize,
}

impl LlvqShapeGain {
    /// `gain_bits` of χ₂₄-matched gain; the shape code is the normalized
    /// union of shells 2..=max_m of `indexer` (App. F's norm(Λ₂₄(m)) + b
    /// χ-gain bits construction).
    pub fn new(indexer: Arc<LeechIndexer>, gain_bits: u32) -> Self {
        // Optimal-scales gain: γ* = ‖x‖·cos θ. cosθ loses ≈ 1−angular-MSE/2;
        // the χ codebook is left unscaled — γ* is quantized directly against
        // it, and empirically the cos-retention shrinkage is < 1%, inside
        // one bin width even at 4 gain bits.
        let gain = ChiGainQuantizer::new(DIM, gain_bits);
        Self::with_parts(indexer, gain, 2)
    }

    /// Assemble from explicit parts (the `.llvqm` load path: the gain
    /// codebook comes from the serialized spec instead of being re-fit).
    pub fn with_parts(indexer: Arc<LeechIndexer>, gain: ChiGainQuantizer, min_m: usize) -> Self {
        let shape_bits = indexer.index_bits();
        Self {
            indexer,
            gain,
            shape_bits,
            min_m,
        }
    }

    /// Quantize returning (shape index, gain level index).
    fn quantize_parts(&self, x: &[f32]) -> (u64, u64) {
        let mut u = [0f64; DIM];
        for i in 0..DIM {
            u[i] = x[i] as f64;
        }
        let dec = LeechDecoder::new(self.indexer.golay());
        let d = dec.decode_angular(&u, self.min_m, self.indexer.max_m());
        let shape_idx = self
            .indexer
            .encode_point(&d.point)
            .expect("angular decode produced unindexable point");
        // optimal gain given the chosen shape: γ* = ⟨x, ŝ⟩
        let m = coset::shell_of(&d.point).expect("angular returned origin");
        let pnorm = (16.0 * m as f64).sqrt();
        let mut dot = 0.0;
        for i in 0..DIM {
            dot += x[i] as f64 * d.point[i] as f64;
        }
        let gamma_star = (dot / pnorm).max(0.0);
        let g_idx = self.gain.nearest(gamma_star) as u64;
        (shape_idx, g_idx)
    }
}

impl VectorQuantizer for LlvqShapeGain {
    fn dim(&self) -> usize {
        DIM
    }

    fn bits_per_weight(&self) -> f64 {
        (self.shape_bits + self.gain.bits) as f64 / DIM as f64
    }

    fn quantize(&self, x: &[f32]) -> Code {
        let (s, g) = self.quantize_parts(x);
        Code {
            words: vec![s, g],
            bits: self.shape_bits + self.gain.bits,
        }
    }

    fn quantize_into(&self, x: &[f32], code: &mut Code) {
        let (s, g) = self.quantize_parts(x);
        code.words.clear();
        code.words.push(s);
        code.words.push(g);
        code.bits = self.shape_bits + self.gain.bits;
    }

    fn dequantize(&self, code: &Code, out: &mut [f32]) {
        let v = self.indexer.decode_index(code.words[0]);
        let m = coset::shell_of(&v).expect("bad shape index");
        let pnorm = (16.0 * m as f64).sqrt();
        let g = self.gain.level(code.words[1] as usize);
        for i in 0..DIM {
            out[i] = (v[i] as f64 / pnorm * g) as f32;
        }
    }

    /// Split shape/gain fields: the shape index and the gain level are
    /// serialized as two separate bit fields.
    fn code_widths(&self) -> Vec<u32> {
        vec![self.shape_bits, self.gain.bits]
    }

    fn decode_blocks_into(
        &self,
        _widths: &[u32],
        r: &mut BitReader,
        _code: &mut Code,
        _scratch: &mut [f32],
        out: &mut [f32],
    ) {
        // Stream the (shape, gain) field pair per block in serialization
        // order and write every element through the same expressions as
        // dequantize (bit-exact); partial final block padding is dropped.
        let mut i = 0;
        while i < out.len() {
            let v = self.indexer.decode_index(r.read(self.shape_bits));
            let m = coset::shell_of(&v).expect("bad shape index");
            let pnorm = (16.0 * m as f64).sqrt();
            let g = self.gain.level(r.read(self.gain.bits) as usize);
            let take = DIM.min(out.len() - i);
            for (o, &c) in out[i..i + take].iter_mut().zip(v.iter()) {
                *o = (c as f64 / pnorm * g) as f32;
            }
            i += take;
        }
    }

    fn spec(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("llvq-shape-gain".into())),
            ("name", Json::Str(self.name())),
            ("dim", Json::Int(DIM as i64)),
            ("max_m", Json::Int(self.indexer.max_m() as i64)),
            ("min_m", Json::Int(self.min_m as i64)),
            ("gain_bits", Json::Int(self.gain.bits as i64)),
            ("gain_levels", Json::arr_f64(&self.gain.levels)),
        ])
    }

    fn name(&self) -> String {
        format!(
            "llvq-shape-gain-M{}+{}g ({:.3} bpw)",
            self.indexer.max_m(),
            self.gain.bits,
            self.bits_per_weight()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gaussian_rd;

    fn small_ctx() -> Arc<LeechIndexer> {
        Arc::new(LeechIndexer::new(4))
    }

    #[test]
    fn spherical_roundtrip_is_lattice_consistent() {
        let ix = small_ctx();
        let q = LlvqSpherical::with_scale(ix.clone(), 0.8);
        let mut rng = Xoshiro256pp::new(2);
        let mut x = [0f32; DIM];
        let mut y = [0f32; DIM];
        let mut z = [0f32; DIM];
        for _ in 0..20 {
            rng.fill_gaussian_f32(&mut x);
            let c = q.quantize(&x);
            assert_eq!(c.bits, ix.index_bits());
            q.dequantize(&c, &mut y);
            // quantizing the reconstruction must be a fixed point
            let c2 = q.quantize(&y);
            q.dequantize(&c2, &mut z);
            for i in 0..DIM {
                assert!((y[i] - z[i]).abs() < 1e-6, "not a fixed point");
            }
        }
    }

    #[test]
    fn spherical_beats_naive_rate_distortion_floor() {
        // At M=4 the rate is 29/24 ≈ 1.21 bpw; Shannon MSE* = 2^-2.42 ≈ 0.187.
        // A structured lattice quantizer must land well under 2× Shannon.
        let ix = small_ctx();
        let q = LlvqSpherical::new(ix);
        let (mse, bits) = gaussian_rd(&q, 1200, 3);
        assert!((bits - 29.0 / 24.0).abs() < 1e-9);
        assert!(mse < 0.30, "mse {mse} too high for {bits} bpw");
    }

    #[test]
    fn shape_gain_roundtrip_and_rate() {
        let ix = small_ctx();
        let q = LlvqShapeGain::new(ix, 2);
        let mut rng = Xoshiro256pp::new(4);
        let mut x = [0f32; DIM];
        let mut y = [0f32; DIM];
        rng.fill_gaussian_f32(&mut x);
        let c = q.quantize(&x);
        assert_eq!(c.bits, 29 + 2);
        q.dequantize(&c, &mut y);
        // direction of y must be the quantized shape: renormalized y is a
        // lattice direction; cosine with x should be high
        let dot: f64 = x.iter().zip(&y).map(|(&a, &b)| a as f64 * b as f64).sum();
        let nx: f64 = x.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
        let ny: f64 = y.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
        assert!(dot / (nx * ny) > 0.8, "cos {}", dot / (nx * ny));
    }

    #[test]
    fn gain_bits_accounting() {
        let ix = small_ctx();
        for gb in [0u32, 1, 2, 4] {
            let q = LlvqShapeGain::new(ix.clone(), gb);
            assert!((q.bits_per_weight() - (29 + gb) as f64 / 24.0).abs() < 1e-12);
        }
    }
}
