//! E₈-lattice baselines (the QuIP#/E8P family, paper Table 4 & Table 6).
//!
//! E₈ = D₈ ∪ (D₈ + ½·𝟙): the optimal 8-dimensional packing. We provide:
//!
//! * an exact infinite-lattice decoder (round-to-D₈ with parity repair on
//!   both cosets, pick the better),
//! * two finite 2¹⁶-point codebooks at 2 bits/weight over 8-dim blocks —
//!   **ball-cut** ("E8P"-style, matching QuIP#'s spherically shaped 2^16
//!   codebook) and **cube-cut** ("E8 coset" row of Table 4) — built by
//!   enumerating lattice points inside the region and breaking ties
//!   deterministically to land on exactly 65 536 points,
//! * a Gaussian-optimized global scale found by golden-section search.
//!
//! At dimension 8 the codebook is small enough to materialize (this is what
//! QuIP# itself does); the contrast with the codebook-free 24-dim LLVQ
//! path is exactly the paper's point.

use std::collections::HashMap;

use crate::quant::{Code, VectorQuantizer};
use crate::util::bits::BitReader;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256pp;

const D8: usize = 8;

/// Half-integer grid is represented by doubling: points live in (2ℤ)⁸ or
/// (2ℤ+1)⁸ after ×2, keeping everything integral.
type Pt = [i32; D8]; // DOUBLED coordinates

#[inline]
fn dist2_doubled(p: &Pt, t: &[f64; D8]) -> f64 {
    let mut s = 0.0;
    for i in 0..D8 {
        let d = p[i] as f64 * 0.5 - t[i];
        s += d * d;
    }
    s
}

/// Exact nearest point of E₈ (in doubled coordinates) to `t`.
pub fn decode_e8(t: &[f64; D8]) -> Pt {
    let mut best: Pt = [0; D8];
    let mut best_d = f64::INFINITY;
    // coset 0: integers (doubled: even), coset 1: half-integers (doubled: odd)
    for half in [false, true] {
        let mut p = [0i32; D8];
        let mut err = [0f64; D8];
        let mut sum = 0i64;
        for i in 0..D8 {
            // nearest (half-)integer: in doubled coords nearest even/odd int
            let target = t[i] * 2.0;
            let r = if half {
                // nearest odd integer
                let f = ((target - 1.0) / 2.0).round() as i32;
                2 * f + 1
            } else {
                2.0f64.mul_add((target / 2.0).round(), 0.0) as i32
            };
            p[i] = r;
            err[i] = target - r as f64; // in doubled units
            sum += r as i64;
        }
        // D8 constraint: Σ (undoubled) ∈ 2ℤ ⇔ Σ doubled ≡ 0 (mod 4)
        if sum.rem_euclid(4) != 0 {
            // flip the coordinate with the largest |err| toward the target
            let mut worst = 0usize;
            for i in 1..D8 {
                if err[i].abs() > err[worst].abs() {
                    worst = i;
                }
            }
            p[worst] += if err[worst] >= 0.0 { 2 } else { -2 };
        }
        let d = dist2_doubled(&p, t);
        if d < best_d {
            best_d = d;
            best = p;
        }
    }
    best
}

/// Region used to cut the infinite lattice to 2^16 points.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum E8Cut {
    /// Spherical shaping (QuIP#'s E8P flavour).
    Ball,
    /// Cubic shaping (the weaker "E8 coset" baseline).
    Cube,
}

/// A finite 16-bit E₈ codebook over 8-dim blocks (2 bits/weight).
pub struct E8Codebook {
    pub cut: E8Cut,
    /// Gaussian-optimized input scale: quantize x/scale, reconstruct ×scale.
    pub scale: f64,
    points: Vec<Pt>,
    index_of: HashMap<Pt, u32>,
    /// max squared norm (doubled coords) of any codebook point, for sweeps
    max_norm2_doubled: i64,
}

fn norm2_doubled(p: &Pt) -> i64 {
    p.iter().map(|&v| (v as i64) * (v as i64)).sum()
}

fn linf_doubled(p: &Pt) -> i64 {
    p.iter().map(|&v| (v as i64).abs()).max().unwrap()
}

impl E8Codebook {
    /// Enumerate E₈ points ordered by the cut functional and keep exactly
    /// 2^16, then optimize the Gaussian scale.
    pub fn new(cut: E8Cut) -> Self {
        let mut cb = Self::with_scale(cut, 1.0);
        cb.scale = cb.optimize_scale();
        cb
    }

    /// Build the (deterministic) codebook with an explicit scale, skipping
    /// scale optimization — the `.llvqm` load path, where the scale comes
    /// from the serialized spec.
    pub fn with_scale(cut: E8Cut, scale: f64) -> Self {
        let target = 1usize << 16;
        // enumerate all points with doubled norm² ≤ bound (bound chosen to
        // comfortably exceed 2^16 points: E8 cumulative counts reach 117k
        // by norm² ≤ 12, i.e. doubled ≤ 48)
        let bound_doubled = 64i64;
        let mut pts: Vec<Pt> = Vec::with_capacity(300_000);
        // recursive enumeration over doubled coords of one parity
        fn rec(
            i: usize,
            rem: i64,
            parity: i32,
            cur: &mut Pt,
            sum: i64,
            out: &mut Vec<Pt>,
        ) {
            if i == D8 {
                if sum.rem_euclid(4) == 0 {
                    out.push(*cur);
                }
                return;
            }
            let max_v = (rem as f64).sqrt() as i64;
            let mut v = -(max_v + 2);
            while v <= max_v + 2 {
                if (v - parity as i64).rem_euclid(2) == 0 && v * v <= rem {
                    cur[i] = v as i32;
                    rec(i + 1, rem - v * v, parity, cur, sum + v, out);
                }
                v += 1;
            }
            cur[i] = 0;
        }
        let mut cur = [0i32; D8];
        rec(0, bound_doubled, 0, &mut cur, 0, &mut pts); // integer coset
        rec(0, bound_doubled, 1, &mut cur, 0, &mut pts); // half-integer coset
        assert!(pts.len() >= target, "enumeration bound too small: {}", pts.len());

        // order by cut functional, then lexicographically (deterministic)
        match cut {
            E8Cut::Ball => pts.sort_by_key(|p| (norm2_doubled(p), *p)),
            E8Cut::Cube => pts.sort_by_key(|p| (linf_doubled(p), norm2_doubled(p), *p)),
        }
        pts.truncate(target);
        let max_norm2_doubled = pts.iter().map(norm2_doubled).max().unwrap();
        let mut index_of = HashMap::with_capacity(target);
        for (i, p) in pts.iter().enumerate() {
            index_of.insert(*p, i as u32);
        }
        Self {
            cut,
            scale,
            points: pts,
            index_of,
            max_norm2_doubled,
        }
    }

    /// Golden-section search for the Gaussian-MSE-optimal input scale.
    fn optimize_scale(&self) -> f64 {
        let sample = {
            let mut rng = Xoshiro256pp::new(0xE8);
            let mut v = vec![0f32; 8 * 4000];
            rng.fill_gaussian_f32(&mut v);
            v
        };
        let mse_at = |s: f64| -> f64 {
            let mut se = 0.0;
            for blk in sample.chunks_exact(D8) {
                let mut t = [0f64; D8];
                for i in 0..D8 {
                    t[i] = blk[i] as f64 / s;
                }
                let p = self.nearest_in_book(&t);
                for i in 0..D8 {
                    let d = blk[i] as f64 - p[i] as f64 * 0.5 * s;
                    se += d * d;
                }
            }
            se
        };
        let (mut a, mut b) = (0.2f64, 1.4f64);
        let inv_phi = (5f64.sqrt() - 1.0) / 2.0;
        for _ in 0..24 {
            let c = b - (b - a) * inv_phi;
            let d = a + (b - a) * inv_phi;
            if mse_at(c) < mse_at(d) {
                b = d;
            } else {
                a = c;
            }
        }
        0.5 * (a + b)
    }

    /// Nearest codebook point to `t` (pre-scaled coordinates).
    fn nearest_in_book(&self, t: &[f64; D8]) -> Pt {
        let first = decode_e8(t);
        if self.index_of.contains_key(&first) {
            return first;
        }
        // outside the cut: shrink toward the region boundary and keep the
        // best in-book candidate (same strategy as the Leech ball search)
        let tn: f64 = t.iter().map(|&x| x * x).sum::<f64>().sqrt();
        let r_max = (self.max_norm2_doubled as f64).sqrt() * 0.5;
        let base = if tn > 1e-12 { r_max / tn } else { 0.0 };
        let mut best: Option<(Pt, f64)> = None;
        for &g in &[1.05, 1.0, 0.97, 0.93, 0.88, 0.8, 0.7, 0.55, 0.4, 0.25] {
            let mut ts = [0.0; D8];
            for i in 0..D8 {
                ts[i] = t[i] * base * g;
            }
            let cand = decode_e8(&ts);
            if self.index_of.contains_key(&cand) {
                let d = dist2_doubled(&cand, t);
                if best.map_or(true, |(_, bd)| d < bd) {
                    best = Some((cand, d));
                }
            }
        }
        best.map(|(p, _)| p).unwrap_or([0; D8])
    }
}

impl VectorQuantizer for E8Codebook {
    fn dim(&self) -> usize {
        D8
    }

    fn bits_per_weight(&self) -> f64 {
        2.0
    }

    fn quantize(&self, x: &[f32]) -> Code {
        let mut code = Code::empty();
        self.quantize_into(x, &mut code);
        code
    }

    fn quantize_into(&self, x: &[f32], code: &mut Code) {
        let mut t = [0f64; D8];
        for i in 0..D8 {
            t[i] = x[i] as f64 / self.scale;
        }
        let p = self.nearest_in_book(&t);
        code.words.clear();
        code.words.push(self.index_of[&p] as u64);
        code.bits = 16;
    }

    fn dequantize(&self, code: &Code, out: &mut [f32]) {
        let p = &self.points[code.words[0] as usize];
        for i in 0..D8 {
            out[i] = (p[i] as f64 * 0.5 * self.scale) as f32;
        }
    }

    fn code_widths(&self) -> Vec<u32> {
        vec![16]
    }

    fn decode_blocks_into(
        &self,
        _widths: &[u32],
        r: &mut BitReader,
        _code: &mut Code,
        _scratch: &mut [f32],
        out: &mut [f32],
    ) {
        // Stream 16-bit indices straight into the point table, writing each
        // element through the same expression as dequantize (bit-exact);
        // the final block may be partial and its padding lanes are dropped.
        let mut i = 0;
        while i < out.len() {
            let p = &self.points[r.read(16) as usize];
            let take = D8.min(out.len() - i);
            for (o, &v) in out[i..i + take].iter_mut().zip(p.iter()) {
                *o = (v as f64 * 0.5 * self.scale) as f32;
            }
            i += take;
        }
    }

    fn spec(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("e8".into())),
            ("name", Json::Str(self.name())),
            ("dim", Json::Int(D8 as i64)),
            (
                "cut",
                Json::Str(match self.cut {
                    E8Cut::Ball => "ball".into(),
                    E8Cut::Cube => "cube".into(),
                }),
            ),
            ("scale", Json::Num(self.scale)),
        ])
    }

    fn name(&self) -> String {
        match self.cut {
            E8Cut::Ball => "e8p-ball-2b".into(),
            E8Cut::Cube => "e8-cube-2b".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gaussian_rd;

    #[test]
    fn decoder_returns_lattice_members() {
        let mut rng = Xoshiro256pp::new(3);
        for _ in 0..200 {
            let mut t = [0f64; D8];
            for v in t.iter_mut() {
                *v = rng.next_gaussian() * 2.0;
            }
            let p = decode_e8(&t);
            // membership: all-even or all-odd doubled coords, Σ ≡ 0 mod 4
            let par = p[0].rem_euclid(2);
            assert!(p.iter().all(|&v| v.rem_euclid(2) == par));
            assert_eq!(p.iter().map(|&v| v as i64).sum::<i64>().rem_euclid(4), 0);
        }
    }

    #[test]
    fn decoder_is_locally_optimal() {
        // decoded point must beat 200 random lattice points
        let mut rng = Xoshiro256pp::new(5);
        let book = E8Codebook::new(E8Cut::Ball);
        for _ in 0..20 {
            let mut t = [0f64; D8];
            for v in t.iter_mut() {
                *v = rng.next_gaussian();
            }
            let p = decode_e8(&t);
            let dp = dist2_doubled(&p, &t);
            for _ in 0..200 {
                let q = book.points[rng.next_range(65536) as usize];
                assert!(dist2_doubled(&q, &t) >= dp - 1e-12);
            }
        }
    }

    #[test]
    fn kissing_number_240() {
        // E8 minimal vectors: norm² = 2 (doubled norm² = 8)
        let book = E8Codebook::new(E8Cut::Ball);
        let n_min = book
            .points
            .iter()
            .filter(|p| norm2_doubled(p) == 8)
            .count();
        assert_eq!(n_min, 240);
        // origin included once
        assert_eq!(book.points.iter().filter(|p| norm2_doubled(p) == 0).count(), 1);
    }

    #[test]
    fn ball_beats_cube_on_gaussian() {
        let ball = E8Codebook::new(E8Cut::Ball);
        let cube = E8Codebook::new(E8Cut::Cube);
        let (mb, bits_b) = gaussian_rd(&ball, 20_000, 11);
        let (mc, bits_c) = gaussian_rd(&cube, 20_000, 11);
        assert_eq!(bits_b, 2.0);
        assert_eq!(bits_c, 2.0);
        assert!(mb < mc, "ball {mb} !< cube {mc}");
        // Table 4 bands: E8 ≈ 0.09–0.11 at 2 bits
        assert!(mb > 0.07 && mb < 0.12, "ball mse {mb}");
    }

    #[test]
    fn roundtrip_identity_on_codewords() {
        let book = E8Codebook::new(E8Cut::Ball);
        let mut rng = Xoshiro256pp::new(8);
        let mut out = [0f32; D8];
        for _ in 0..100 {
            let idx = rng.next_range(65536);
            let p = book.points[idx as usize];
            let x: Vec<f32> = p.iter().map(|&v| (v as f64 * 0.5 * book.scale) as f32).collect();
            let c = book.quantize(&x);
            assert_eq!(c.words[0], idx as u64, "codeword not fixed point");
            book.dequantize(&c, &mut out);
            for i in 0..D8 {
                assert!((out[i] - x[i]).abs() < 1e-6);
            }
        }
    }
}
