//! Benchmark harness (criterion replacement for the offline build).
//!
//! `cargo bench` runs our bench binaries with `harness = false`; they call
//! into this module. Methodology: warmup, then timed batches whose size is
//! auto-scaled so each measurement batch takes ≥ `min_batch_time`, with
//! mean/median/p10/p90 over `samples` batches, plus items/sec throughput.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters_per_batch: u64,
    pub samples: Vec<f64>, // seconds per iteration
    pub mean: f64,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} mean {:>12}  median {:>12}  p10 {:>12}  p90 {:>12}",
            self.name,
            fmt_time(self.mean),
            fmt_time(self.median),
            fmt_time(self.p10),
            fmt_time(self.p90),
        )
    }
}

pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

pub struct Bench {
    pub warmup: Duration,
    pub min_batch_time: Duration,
    pub num_samples: usize,
}

/// True when `LLVQ_BENCH_SMOKE` is set (to anything but `0`): CI's
/// bench-smoke tier runs every harness with shrunken sample counts and
/// model/codebook dims so every `BENCH_*.json` artifact is produced on
/// each PR in seconds. Harnesses tag their JSON rows with `"smoke": true`
/// in this mode, so trajectory readers can tell the tiers apart.
pub fn smoke() -> bool {
    std::env::var("LLVQ_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

impl Default for Bench {
    fn default() -> Self {
        // Keep whole-suite runtime reasonable; override via env for deep runs.
        if smoke() {
            return Self {
                warmup: Duration::from_millis(10),
                min_batch_time: Duration::from_millis(5),
                num_samples: 2,
            };
        }
        let quick = std::env::var("LLVQ_BENCH_QUICK").is_ok();
        Self {
            warmup: Duration::from_millis(if quick { 50 } else { 300 }),
            min_batch_time: Duration::from_millis(if quick { 30 } else { 150 }),
            num_samples: if quick { 5 } else { 12 },
        }
    }
}

impl Bench {
    /// Measure `f`, which performs ONE logical iteration per call.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // warmup + calibration
        let cal_start = Instant::now();
        let mut cal_iters = 0u64;
        while cal_start.elapsed() < self.warmup {
            f();
            cal_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / cal_iters.max(1) as f64;
        let iters_per_batch =
            ((self.min_batch_time.as_secs_f64() / per_iter.max(1e-9)).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.num_samples);
        for _ in 0..self.num_samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_batch {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / iters_per_batch as f64);
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let pct = |p: f64| sorted[((p * (sorted.len() - 1) as f64).round()) as usize];
        let res = BenchResult {
            name: name.to_string(),
            iters_per_batch,
            mean,
            median: pct(0.5),
            p10: pct(0.1),
            p90: pct(0.9),
            samples,
        };
        println!("{}", res.report());
        res
    }

    /// Measure with an explicit item count per iteration; also prints
    /// throughput.
    pub fn run_throughput<F: FnMut()>(
        &self,
        name: &str,
        items_per_iter: f64,
        f: F,
    ) -> BenchResult {
        let res = self.run(name, f);
        println!(
            "{:<44} throughput {:>14.0} items/s",
            format!("{name} [thpt]"),
            res.throughput(items_per_iter)
        );
        res
    }
}

/// Black-box: prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            min_batch_time: Duration::from_millis(2),
            num_samples: 3,
        };
        let mut acc = 0u64;
        let r = b.run("spin", || {
            for i in 0..100u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.mean > 0.0);
        assert!(r.p10 <= r.p90);
        assert_eq!(r.samples.len(), 3);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2e-9).contains("ns"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2.0).contains(" s"));
    }
}
