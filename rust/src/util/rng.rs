//! Deterministic pseudo-random number generation.
//!
//! The crate builds fully offline, so we carry our own generators instead of
//! depending on `rand`: [`SplitMix64`] for seeding and [`Xoshiro256pp`]
//! (xoshiro256++, Blackman & Vigna) as the workhorse. Gaussian variates use
//! Box–Muller with cached second sample.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ generator. Not cryptographic; excellent statistical quality
/// for simulation workloads, 2^256−1 period, jumpable if ever needed.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
    /// Cached second Box–Muller sample.
    gauss_cache: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Xoshiro256pp {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_cache: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_range(&mut self, n: u64) -> u64 {
        // Lemire's unbiased bounded generation.
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_cache.take() {
            return g;
        }
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_cache = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Fill a slice with i.i.d. standard normals (f32).
    pub fn fill_gaussian_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_gaussian() as f32;
        }
    }

    /// Fill a slice with i.i.d. standard normals (f64).
    pub fn fill_gaussian_f64(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.next_gaussian();
        }
    }

    /// Random permutation of 0..n (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.next_range((i + 1) as u64) as usize;
            p.swap(i, j);
        }
        p
    }

    /// Random sign vector of ±1.
    pub fn signs(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Xoshiro256pp::new(42);
        let mut b = Xoshiro256pp::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256pp::new(43);
        assert_ne!(Xoshiro256pp::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range_bounds() {
        let mut r = Xoshiro256pp::new(1);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            let k = r.next_range(7);
            assert!(k < 7);
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256pp::new(7);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let g = r.next_gaussian();
            sum += g;
            sumsq += g * g;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Xoshiro256pp::new(3);
        let p = r.permutation(100);
        let mut seen = [false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }
}
