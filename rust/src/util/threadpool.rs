//! Scoped data-parallel helpers over `std::thread` (no rayon offline).
//!
//! The PTQ pipeline quantizes thousands of independent 24-dim blocks per
//! layer; [`parallel_chunks`] splits an index range across worker threads
//! with static partitioning (blocks are uniform cost), and
//! [`parallel_map`] collects per-item results in order.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (env `LLVQ_THREADS` overrides).
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("LLVQ_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Run `f(start, end)` over `nthreads` contiguous chunks of `0..n` in
/// parallel. `f` must be `Sync` (immutable captures; use interior
/// mutability or per-chunk outputs for writes).
pub fn parallel_chunks<F>(n: usize, nthreads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let nthreads = nthreads.max(1).min(n.max(1));
    if nthreads <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(nthreads);
    std::thread::scope(|s| {
        for t in 0..nthreads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let fr = &f;
            s.spawn(move || fr(lo, hi));
        }
    });
}

/// Work-stealing flavour for uneven item costs: threads grab items from a
/// shared atomic counter in small batches.
pub fn parallel_dynamic<F>(n: usize, nthreads: usize, batch: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let nthreads = nthreads.max(1).min(n.max(1));
    if nthreads <= 1 || n == 0 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    let batch = batch.max(1);
    std::thread::scope(|s| {
        for _ in 0..nthreads {
            let fr = &f;
            let c = &counter;
            s.spawn(move || loop {
                let start = c.fetch_add(batch, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + batch).min(n) {
                    fr(i);
                }
            });
        }
    });
}

/// Parallel map preserving order. `f` runs on worker threads; results land
/// in a `Vec<T>` indexed by item.
pub fn parallel_map<T, F>(n: usize, nthreads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_dynamic(n, nthreads, 8, |i| {
            let r = f(i);
            **slots[i].lock().unwrap() = r;
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(1000, 7, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn dynamic_covers_range_exactly_once() {
        let hits: Vec<AtomicU64> = (0..537).map(|_| AtomicU64::new(0)).collect();
        parallel_dynamic(537, 5, 3, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(100, 4, |i| i * i);
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn single_thread_degenerate() {
        // empty range degenerates to a single (0, 0) call
        parallel_chunks(0, 4, |lo, hi| assert_eq!((lo, hi), (0, 0)));
        parallel_dynamic(0, 4, 2, |_| panic!("no items to visit"));
    }
}
