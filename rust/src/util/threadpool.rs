//! Data-parallel helpers over `std::thread` (no rayon offline).
//!
//! Two tiers:
//!
//! * **Scoped one-shots** — [`parallel_chunks`] / [`parallel_dynamic`] /
//!   [`parallel_map`] spawn scoped threads per call. Right for cold paths
//!   (PTQ quantizes thousands of independent 24-dim blocks per layer;
//!   whole-model unpack) where the spawn cost amortizes over a lot of work.
//! * **The persistent [`Pool`]** — long-lived workers that park on a
//!   condvar between jobs, so a serving hot loop (the fused per-token
//!   dequant-matmul, which runs once per linear layer per decode step) pays
//!   a wakeup instead of `threads × thread::spawn` per call.
//!   [`Pool::run_partitioned`] statically shards `0..n` across the calling
//!   thread plus the workers; each executor gets its own reusable
//!   [`Scratch`] slot, and [`ShardedSlice`] lets shards write disjoint
//!   ranges of one output buffer without locks.

use std::any::Any;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Number of worker threads to use (env `LLVQ_THREADS` overrides).
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("LLVQ_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Run `f(start, end)` over `nthreads` contiguous chunks of `0..n` in
/// parallel. `f` must be `Sync` (immutable captures; use interior
/// mutability or per-chunk outputs for writes).
pub fn parallel_chunks<F>(n: usize, nthreads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let nthreads = nthreads.max(1).min(n.max(1));
    if nthreads <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(nthreads);
    std::thread::scope(|s| {
        for t in 0..nthreads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let fr = &f;
            s.spawn(move || fr(lo, hi));
        }
    });
}

/// Work-stealing flavour for uneven item costs: threads grab items from a
/// shared atomic counter in small batches.
pub fn parallel_dynamic<F>(n: usize, nthreads: usize, batch: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let nthreads = nthreads.max(1).min(n.max(1));
    if nthreads <= 1 || n == 0 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    let batch = batch.max(1);
    std::thread::scope(|s| {
        for _ in 0..nthreads {
            let fr = &f;
            let c = &counter;
            s.spawn(move || loop {
                let start = c.fetch_add(batch, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + batch).min(n) {
                    fr(i);
                }
            });
        }
    });
}

/// Parallel map preserving order. `f` runs on worker threads; results land
/// in a `Vec<T>` indexed by item.
pub fn parallel_map<T, F>(n: usize, nthreads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_dynamic(n, nthreads, 8, |i| {
            let r = f(i);
            **slots[i].lock().unwrap_or_else(|e| e.into_inner()) = r;
        });
    }
    out
}

// ---------------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------------

/// Per-executor scratch: a type-erased box that persists across
/// [`Pool::run_partitioned`] calls on the same executor, so hot kernels
/// keep their decode buffers warm instead of reallocating per call.
pub struct Scratch(Option<Box<dyn Any + Send>>);

impl Scratch {
    fn new() -> Self {
        Self(None)
    }

    /// The scratch value, lazily initialized with `init` (also re-created
    /// if a previous job parked a different type here).
    pub fn get_or<T: Any + Send>(&mut self, init: impl FnOnce() -> T) -> &mut T {
        let reusable = self.0.as_ref().is_some_and(|b| b.is::<T>());
        if !reusable {
            self.0 = Some(Box::new(init()));
        }
        self.0
            .as_mut()
            .and_then(|b| b.downcast_mut::<T>())
            // lint:allow(no-panic-serving): the branch above just stored a
            // Box<T> whenever the downcast could fail, so this is proven
            // infallible two lines up, not a recoverable condition
            .expect("scratch was just set to T")
    }
}

/// A `&mut [T]` that pool shards may write through concurrently, PROVIDED
/// every concurrently-outstanding [`ShardedSlice::range_mut`] range is
/// disjoint. [`Pool::run_partitioned`] hands each executor a disjoint
/// index range, so "my range ↦ my output rows" uses are safe by
/// construction.
pub struct ShardedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _life: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: a ShardedSlice is just the base pointer and length of the
// caller's `&mut [T]`; moving it to another thread moves T values only
// through the `range_mut` views, so `T: Send` is the whole obligation.
unsafe impl<T: Send> Send for ShardedSlice<'_, T> {}
// SAFETY: `&ShardedSlice` exposes mutation solely via `range_mut`, whose
// contract demands disjoint ranges across concurrent users — shared
// access is therefore equivalent to `&mut [T]` split into disjoint parts.
unsafe impl<T: Send> Sync for ShardedSlice<'_, T> {}

impl<'a, T> ShardedSlice<'a, T> {
    pub fn new(s: &'a mut [T]) -> Self {
        Self {
            ptr: s.as_mut_ptr(),
            len: s.len(),
            _life: std::marker::PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `range`.
    ///
    /// # Safety
    ///
    /// `range` must be in bounds, and ranges handed out to code that runs
    /// concurrently (distinct pool shards) must never overlap.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range_mut(&self, range: Range<usize>) -> &mut [T] {
        debug_assert!(range.start <= range.end && range.end <= self.len);
        // SAFETY: the caller contract above — `range` in bounds of the
        // slice this was built from (so the pointer arithmetic stays
        // inside the allocation) and concurrently-outstanding ranges
        // disjoint (so the &mut views never alias).
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.end - range.start) }
    }
}

/// One type-erased `run_partitioned` job. `data` borrows the caller's
/// closure; it is only dereferenced while the caller blocks inside
/// `run_partitioned`, which is what makes the erased lifetime sound.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    call: unsafe fn(*const (), Range<usize>, &mut Scratch),
    n: usize,
    chunk: usize,
}

// SAFETY: the raw closure pointer is only dereferenced during the epoch,
// while the owning `run_partitioned` frame is alive and blocked.
unsafe impl Send for Job {}

struct PoolState {
    epoch: u64,
    job: Option<Job>,
    /// Workers that have not finished the current epoch yet.
    active: usize,
    /// Worker shards that panicked during the current epoch.
    panicked: usize,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// One reusable scratch slot per executor (0 = the calling thread,
    /// 1.. = pool workers). Each executor locks only its own slot.
    scratch: Vec<Mutex<Scratch>>,
}

/// Recover a guard from a possibly-poisoned lock: pool state stays
/// consistent across a panicking shard (panics are caught, counted, and
/// re-raised on the caller), so poison carries no information here.
fn relock<T>(r: Result<T, std::sync::PoisonError<T>>) -> T {
    r.unwrap_or_else(|e| e.into_inner())
}

/// A persistent worker pool for repeated data-parallel kernels.
///
/// `Pool::new(t)` spawns `t - 1` long-lived workers; the calling thread is
/// executor 0 of every job, so `t = 1` runs everything inline with zero
/// threads spawned. Dropping the pool shuts the workers down and joins
/// them.
pub struct Pool {
    shared: Arc<PoolShared>,
    /// Serializes whole jobs: concurrent callers queue here, keeping the
    /// epoch protocol single-writer.
    run_lock: Mutex<()>,
    threads: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

thread_local! {
    /// Scratch for inline execution (`threads = 1` pools and single-chunk
    /// jobs): per *calling* thread, so concurrent callers of a sequential
    /// pool never contend — they bypass the run lock entirely and touch
    /// no shared state.
    static INLINE_SCRATCH: std::cell::RefCell<Scratch> =
        std::cell::RefCell::new(Scratch::new());
}

impl Pool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                active: 0,
                panicked: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            scratch: (0..threads).map(|_| Mutex::new(Scratch::new())).collect(),
        });
        let handles = (1..threads)
            .map(|t| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("llvq-pool-{t}"))
                    .spawn(move || worker_loop(sh, t))
                    // lint:allow(no-panic-serving): pool construction
                    // happens once at backend startup, before any request
                    // is accepted — failing to spawn an OS thread there is
                    // fatal by design, not a serving-path error
                    .expect("spawn pool worker")
            })
            .collect();
        Self {
            shared,
            run_lock: Mutex::new(()),
            threads,
            handles,
        }
    }

    /// Executors per job (workers + the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(range, scratch)` over `threads` contiguous chunks of `0..n`,
    /// one per executor (static partitioning — row costs are uniform).
    /// The calling thread executes chunk 0; the call returns only after
    /// every shard finished, so `f` may borrow from the caller's frame.
    /// A panic inside any shard is caught, the job still completes on the
    /// other shards, and the panic resumes on the calling thread — the
    /// pool stays usable. Concurrent callers of one pool serialize on the
    /// worker set (they queue for whole jobs); a `threads = 1` pool runs
    /// inline on the calling thread with thread-local scratch, so
    /// concurrent sequential callers never contend at all.
    pub fn run_partitioned<F>(&self, n: usize, f: F)
    where
        F: Fn(Range<usize>, &mut Scratch) + Sync,
    {
        if n == 0 {
            return;
        }
        if self.handles.is_empty() || n == 1 {
            INLINE_SCRATCH.with(|cell| f(0..n, &mut cell.borrow_mut()));
            return;
        }
        let _serial = relock(self.run_lock.lock());
        let chunk = n.div_ceil(self.threads);

        // SAFETY(contract): `data` must be the `&F` of a live closure of
        // exactly this `F` — guaranteed below, where the only caller
        // erases `&f` and then blocks in this frame until the epoch ends.
        unsafe fn shim<F: Fn(Range<usize>, &mut Scratch) + Sync>(
            data: *const (),
            range: Range<usize>,
            scratch: &mut Scratch,
        ) {
            // SAFETY: see the fn contract — `data` points at a live `F`
            // borrowed by the blocked `run_partitioned` frame.
            let f = unsafe { &*(data as *const F) };
            f(range, scratch)
        }

        {
            let mut st = relock(self.shared.state.lock());
            st.job = Some(Job {
                data: &f as *const F as *const (),
                call: shim::<F>,
                n,
                chunk,
            });
            st.epoch += 1;
            st.active = self.handles.len();
            st.panicked = 0;
            self.shared.work_cv.notify_all();
        }
        // the caller is executor 0
        let caller = {
            let mut s = relock(self.shared.scratch[0].lock());
            catch_unwind(AssertUnwindSafe(|| f(0..chunk.min(n), &mut s)))
        };
        // wait for every worker before returning (or unwinding): `f` and
        // its captures must outlive all shards
        let worker_panics = {
            let mut st = relock(self.shared.state.lock());
            while st.active > 0 {
                st = relock(self.shared.done_cv.wait(st));
            }
            st.job = None;
            st.panicked
        };
        if let Err(p) = caller {
            std::panic::resume_unwind(p);
        }
        assert!(
            worker_panics == 0,
            "{worker_panics} pool shard(s) panicked in run_partitioned"
        );
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = relock(self.shared.state.lock());
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>, t: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = relock(shared.state.lock());
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    if let Some(j) = st.job {
                        seen = st.epoch;
                        break j;
                    }
                }
                st = relock(shared.work_cv.wait(st));
            }
        };
        let lo = (t * job.chunk).min(job.n);
        let hi = ((t + 1) * job.chunk).min(job.n);
        let mut bad = false;
        if lo < hi {
            let mut scratch = relock(shared.scratch[t].lock());
            // SAFETY: `job` was published for this epoch by a
            // `run_partitioned` frame that stays blocked until `active`
            // drains, so the erased closure behind `job.data` is alive for
            // the whole call.
            bad = catch_unwind(AssertUnwindSafe(|| unsafe {
                (job.call)(job.data, lo..hi, &mut scratch)
            }))
            .is_err();
        }
        let mut st = relock(shared.state.lock());
        if bad {
            st.panicked += 1;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(1000, 7, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn dynamic_covers_range_exactly_once() {
        let hits: Vec<AtomicU64> = (0..537).map(|_| AtomicU64::new(0)).collect();
        parallel_dynamic(537, 5, 3, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(100, 4, |i| i * i);
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn single_thread_degenerate() {
        // empty range degenerates to a single (0, 0) call
        parallel_chunks(0, 4, |lo, hi| assert_eq!((lo, hi), (0, 0)));
        parallel_dynamic(0, 4, 2, |_| panic!("no items to visit"));
    }

    #[test]
    fn pool_covers_range_exactly_once_across_repeated_jobs() {
        let pool = Pool::new(5);
        for n in [1usize, 4, 5, 37, 1000] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.run_partitioned(n, |range, _s| {
                for i in range {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "n = {n}"
            );
        }
        pool.run_partitioned(0, |_r, _s| panic!("no items"));
    }

    #[test]
    fn pool_of_one_runs_inline_without_workers() {
        let pool = Pool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut seen = vec![false; 9];
        {
            let shard = ShardedSlice::new(&mut seen);
            pool.run_partitioned(9, |range, _s| {
                // SAFETY: run_partitioned hands each executor a disjoint
                // in-bounds range of 0..9
                let out = unsafe { shard.range_mut(range) };
                out.iter_mut().for_each(|v| *v = true);
            });
        }
        assert!(seen.iter().all(|&v| v));
    }

    #[test]
    fn pool_scratch_persists_per_executor() {
        // each executor initializes its scratch at most once across many
        // jobs — the alloc-free-after-warm-up property the fused kernel
        // relies on
        let pool = Pool::new(3);
        let inits = AtomicU64::new(0);
        for _ in 0..20 {
            pool.run_partitioned(64, |range, s| {
                let buf: &mut Vec<u64> = s.get_or(|| {
                    inits.fetch_add(1, Ordering::Relaxed);
                    Vec::with_capacity(64)
                });
                buf.clear();
                buf.extend(range.map(|i| i as u64));
            });
        }
        assert!(
            inits.load(Ordering::Relaxed) <= 3,
            "scratch re-initialized: {} inits over 20 jobs on 3 executors",
            inits.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn pool_sharded_writes_match_sequential() {
        let n = 501usize;
        let pool = Pool::new(4);
        let mut par = vec![0u64; n];
        {
            let shard = ShardedSlice::new(&mut par);
            pool.run_partitioned(n, |range, _s| {
                let lo = range.start;
                // SAFETY: run_partitioned hands each executor a disjoint
                // in-bounds range of 0..n
                let out = unsafe { shard.range_mut(range) };
                for (k, v) in out.iter_mut().enumerate() {
                    *v = ((lo + k) as u64).wrapping_mul(0x9E3779B9);
                }
            });
        }
        let seq: Vec<u64> = (0..n as u64).map(|i| i.wrapping_mul(0x9E3779B9)).collect();
        assert_eq!(par, seq);
    }

    #[test]
    fn pool_survives_a_panicking_shard() {
        let pool = Pool::new(3);
        let r = crate::util::proptest::with_silenced_panics(|| {
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.run_partitioned(30, |range, _s| {
                    if range.contains(&0) {
                        panic!("shard bug");
                    }
                });
            }))
        });
        assert!(r.is_err(), "shard panic must surface to the caller");
        // the pool remains fully usable for the next job
        let hits: Vec<AtomicU64> = (0..30).map(|_| AtomicU64::new(0)).collect();
        pool.run_partitioned(30, |range, _s| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn sequential_pool_serves_concurrent_callers_inline() {
        // a threads=1 pool runs jobs inline with thread-local scratch:
        // many caller threads may share it concurrently (the eval path
        // fans forward passes over one backend) without contention
        let pool = Pool::new(1);
        let total = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = &pool;
                let total = &total;
                s.spawn(move || {
                    for _ in 0..50 {
                        pool.run_partitioned(10, |range, scratch| {
                            let buf: &mut Vec<u64> = scratch.get_or(Vec::new);
                            buf.clear();
                            buf.extend(range.map(|i| i as u64));
                            total.fetch_add(buf.iter().sum(), Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        // 4 threads × 50 jobs × Σ(0..10)
        assert_eq!(total.load(Ordering::Relaxed), 4 * 50 * 45);
    }
}
